//! Synthetic serving workload generator: Poisson arrivals over a Zipf
//! adapter-popularity distribution — the multi-tenant request mix the
//! paper's LLM-customization setting implies.

use crate::coordinator::registry::AdapterId;
use crate::testutil::Rng;
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// Workload parameters.
#[derive(Debug, Clone, Copy)]
pub struct WorkloadConfig {
    /// Mean request rate (requests/second) for the open-loop generator.
    pub rate: f64,
    /// Zipf exponent of adapter popularity (0 = uniform).
    pub zipf_alpha: f64,
    /// Number of requests to generate.
    pub n_requests: usize,
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        Self { rate: 200.0, zipf_alpha: 1.1, n_requests: 200, seed: 7 }
    }
}

/// One generated arrival.
#[derive(Debug, Clone)]
pub struct Arrival {
    /// Offset from workload start.
    pub at: Duration,
    pub adapter: AdapterId,
}

/// Generate an open-loop arrival schedule over the given adapters.
pub fn generate(cfg: &WorkloadConfig, adapters: &[AdapterId]) -> Vec<Arrival> {
    assert!(!adapters.is_empty());
    let mut rng = Rng::new(cfg.seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(cfg.n_requests);
    // Zipf over a popularity permutation so "popular" ids are arbitrary
    let mut perm: Vec<usize> = (0..adapters.len()).collect();
    rng.shuffle(&mut perm);
    for _ in 0..cfg.n_requests {
        t += rng.exp(cfg.rate);
        let pick = if cfg.zipf_alpha <= 0.0 {
            rng.below(adapters.len())
        } else {
            rng.zipf(adapters.len(), cfg.zipf_alpha)
        };
        out.push(Arrival { at: Duration::from_secs_f64(t), adapter: adapters[perm[pick]] });
    }
    out
}

/// Per-tenant arrival tracking for predictive prefetch: a bounded hot
/// set of tenants, each with an EWMA of its inter-arrival gap. Under the
/// Zipf mix the head tenants re-arrive on a stable cadence, so "predicted
/// next arrival = last arrival + EWMA gap" is enough signal to pull an
/// adapter's factors off disk *before* the request that needs them
/// (DESIGN.md §14). Driven entirely by the injected clock's instants, so
/// predictions are deterministic under the scenario simulator.
#[derive(Debug)]
pub struct ArrivalPredictor {
    tracks: HashMap<AdapterId, Track>,
    /// Hot-set bound: when full, the least-seen tenant is dropped (Zipf
    /// tail tenants never accumulate enough arrivals to predict anyway).
    capacity: usize,
}

#[derive(Debug, Clone, Copy)]
struct Track {
    count: u64,
    last: Instant,
    /// EWMA of the inter-arrival gap (undefined until `count >= 2`).
    ewma_gap: Duration,
}

/// EWMA smoothing factor: new gap weighted 0.3 (integer arithmetic:
/// 3/10), history 0.7.
const EWMA_NUM: u32 = 3;
const EWMA_DEN: u32 = 10;

impl Default for ArrivalPredictor {
    fn default() -> Self {
        Self::new()
    }
}

impl ArrivalPredictor {
    pub fn new() -> Self {
        Self::with_capacity(64)
    }

    pub fn with_capacity(capacity: usize) -> Self {
        Self { tracks: HashMap::new(), capacity: capacity.max(1) }
    }

    /// Record one arrival for `id` at `now`.
    pub fn observe(&mut self, id: AdapterId, now: Instant) {
        if let Some(t) = self.tracks.get_mut(&id) {
            let gap = now.duration_since(t.last);
            t.ewma_gap = if t.count == 1 {
                gap
            } else {
                (t.ewma_gap * (EWMA_DEN - EWMA_NUM) + gap * EWMA_NUM) / EWMA_DEN
            };
            t.count += 1;
            t.last = now;
            return;
        }
        if self.tracks.len() >= self.capacity {
            // evict the least-seen (ties: oldest last-arrival, then the
            // smallest id, so eviction is deterministic)
            if let Some((&victim, _)) = self
                .tracks
                .iter()
                .min_by_key(|(&vid, t)| (t.count, t.last, vid))
            {
                self.tracks.remove(&victim);
            }
        }
        self.tracks.insert(id, Track { count: 1, last: now, ewma_gap: Duration::ZERO });
    }

    /// Tenants whose predicted next arrival (`last + ewma_gap`) is due at
    /// `now`, sorted by id (deterministic). A tenant needs at least two
    /// observed arrivals to have a gap estimate, and goes stale — no
    /// prediction — once `now` exceeds four estimated gaps since its last
    /// arrival (its cadence evidently broke).
    pub fn due(&self, now: Instant) -> Vec<AdapterId> {
        let mut out: Vec<AdapterId> = self
            .tracks
            .iter()
            .filter(|(_, t)| {
                if t.count < 2 || t.ewma_gap.is_zero() {
                    return false;
                }
                let since = now.duration_since(t.last);
                since + t.ewma_gap / 2 >= t.ewma_gap && since <= t.ewma_gap * 4
            })
            .map(|(&id, _)| id)
            .collect();
        out.sort_unstable();
        out
    }

    /// Drop a tenant's track entirely — called when its adapter is
    /// removed or quarantined, so a broken tenant can't keep triggering
    /// speculative prefetches of an unloadable adapter.
    pub fn forget(&mut self, id: AdapterId) {
        self.tracks.remove(&id);
    }

    /// Tracked-tenant count (tests/diagnostics).
    pub fn len(&self) -> usize {
        self.tracks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tracks.is_empty()
    }
}

/// Closed-loop variant: just the Zipf-popular adapter sequence, no
/// arrival times. Saturation benches (and the multi-worker scaling
/// scenario) submit these back-to-back to measure peak throughput
/// instead of open-loop latency.
pub fn zipf_ids(cfg: &WorkloadConfig, adapters: &[AdapterId]) -> Vec<AdapterId> {
    generate(cfg, adapters).into_iter().map(|a| a.adapter).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_monotone_and_counted() {
        let cfg = WorkloadConfig { n_requests: 100, ..Default::default() };
        let arr = generate(&cfg, &[0, 1, 2]);
        assert_eq!(arr.len(), 100);
        for w in arr.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
    }

    #[test]
    fn rate_controls_density() {
        let slow = generate(&WorkloadConfig { rate: 10.0, n_requests: 50, ..Default::default() }, &[0]);
        let fast = generate(&WorkloadConfig { rate: 1000.0, n_requests: 50, ..Default::default() }, &[0]);
        assert!(slow.last().unwrap().at > fast.last().unwrap().at);
    }

    #[test]
    fn zipf_skews_popularity() {
        let cfg = WorkloadConfig { zipf_alpha: 1.3, n_requests: 2000, ..Default::default() };
        let ids: Vec<AdapterId> = (0..20).collect();
        let arr = generate(&cfg, &ids);
        let mut counts = vec![0usize; 20];
        for a in &arr {
            counts[a.adapter as usize] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        assert!(counts[0] > 3 * counts[10].max(1), "head {} vs mid {}", counts[0], counts[10]);
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = WorkloadConfig::default();
        let a = generate(&cfg, &[0, 1]);
        let b = generate(&cfg, &[0, 1]);
        assert_eq!(a.len(), b.len());
        assert!(a.iter().zip(&b).all(|(x, y)| x.at == y.at && x.adapter == y.adapter));
    }

    /// Poisson arrivals: the mean inter-arrival gap of the generated
    /// schedule must match `1/rate`. Tolerance pinned against an exact
    /// Python mirror of the xoshiro generator: worst observed relative
    /// error ≈ 3.3% at n = 2000 across seeds — asserted at 8%.
    #[test]
    fn poisson_interarrival_mean_matches_rate() {
        for (seed, rate) in [(7u64, 200.0f64), (23, 200.0), (7, 50.0)] {
            let cfg = WorkloadConfig { rate, zipf_alpha: 1.1, n_requests: 2000, seed };
            let arr = generate(&cfg, &[0, 1, 2, 3]);
            let mut prev = Duration::ZERO;
            let mut sum = 0.0f64;
            for a in &arr {
                sum += (a.at - prev).as_secs_f64();
                prev = a.at;
            }
            let mean = sum / arr.len() as f64;
            let rel = (mean - 1.0 / rate).abs() * rate;
            assert!(rel < 0.08, "seed {seed} rate {rate}: mean gap {mean} vs {}", 1.0 / rate);
        }
    }

    /// Exponential inter-arrivals have coefficient of variation 1 (the
    /// memoryless signature a deterministic or uniform spacing would
    /// fail): mirror-validated cv² ∈ [0.95, 1.03] across seeds at n=4000.
    #[test]
    fn interarrival_gaps_are_exponential_not_uniform() {
        let mut rng = Rng::new(97);
        let n = 4000;
        let xs: Vec<f64> = (0..n).map(|_| rng.exp(200.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let cv2 = var / (mean * mean);
        assert!((cv2 - 1.0).abs() < 0.2, "cv² {cv2} is not exponential-like");
        assert!((mean * 200.0 - 1.0).abs() < 0.05, "mean {mean} vs 1/rate 0.005");
    }

    /// Least-squares slope of ln(count) against ln(rank) — the Zipf
    /// rank-frequency fit shared by the two slope tests below.
    fn rank_freq_slope(counts: &[usize]) -> f64 {
        let pts: Vec<(f64, f64)> = counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(k, &c)| ((k as f64 + 1.0).ln(), (c as f64).ln()))
            .collect();
        let n = pts.len() as f64;
        let mx = pts.iter().map(|p| p.0).sum::<f64>() / n;
        let my = pts.iter().map(|p| p.1).sum::<f64>() / n;
        let num: f64 = pts.iter().map(|p| (p.0 - mx) * (p.1 - my)).sum();
        let den: f64 = pts.iter().map(|p| (p.0 - mx) * (p.0 - mx)).sum();
        num / den
    }

    /// Zipf popularity: the log-log rank-frequency slope of the sampled
    /// distribution must be ≈ −α. Mirror-validated: slope within ±0.022
    /// of −α at 40k samples over 16 ranks, across seeds — asserted ±0.1.
    #[test]
    fn zipf_rank_frequency_slope_matches_alpha() {
        let alpha = 1.2f64;
        let mut rng = Rng::new(131);
        let n_ranks = 16;
        let mut counts = vec![0usize; n_ranks];
        for _ in 0..40_000 {
            counts[rng.zipf(n_ranks, alpha)] += 1;
        }
        let slope = rank_freq_slope(&counts);
        assert!(
            (slope + alpha).abs() < 0.1,
            "rank-frequency slope {slope:.3} should be ≈ {:.1}",
            -alpha
        );
    }

    /// The same slope law must survive the workload layer's popularity
    /// permutation: sorting adapter counts descending recovers the ranks.
    #[test]
    fn workload_zipf_slope_survives_permutation() {
        let alpha = 1.2f64;
        let cfg = WorkloadConfig { rate: 1e4, zipf_alpha: alpha, n_requests: 40_000, seed: 99 };
        let ids: Vec<AdapterId> = (0..16).collect();
        let mut counts = vec![0usize; 16];
        for a in generate(&cfg, &ids) {
            counts[a.adapter as usize] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let slope = rank_freq_slope(&counts);
        assert!(
            (slope + alpha).abs() < 0.15,
            "permuted rank-frequency slope {slope:.3} should be ≈ {:.1}",
            -alpha
        );
    }

    #[test]
    fn predictor_learns_cadence_and_predicts_due() {
        let t0 = Instant::now();
        let mut p = ArrivalPredictor::new();
        let ms = Duration::from_millis;
        // tenant 1 arrives every 10ms; tenant 2 seen once (no estimate)
        for k in 0..5u64 {
            p.observe(1, t0 + ms(10 * k));
        }
        p.observe(2, t0 + ms(3));
        assert!(p.due(t0 + ms(41)).is_empty(), "half a gap early: not due yet");
        assert_eq!(p.due(t0 + ms(50)), vec![1], "one full gap after last arrival");
        assert_eq!(p.due(t0 + ms(46)), vec![1], "due fires from half a gap out");
        assert!(p.due(t0 + ms(200)).is_empty(), "stale after 4 gaps without arrivals");
    }

    #[test]
    fn predictor_forget_drops_the_track() {
        let t0 = Instant::now();
        let ms = Duration::from_millis;
        let mut p = ArrivalPredictor::new();
        for k in 0..4u64 {
            p.observe(1, t0 + ms(10 * k));
            p.observe(2, t0 + ms(10 * k + 3));
        }
        assert_eq!(p.due(t0 + ms(43)), vec![1, 2], "both tenants predict before the forget");
        p.forget(1);
        assert_eq!(p.len(), 1);
        assert_eq!(p.due(t0 + ms(43)), vec![2], "forgotten tenant must not predict");
        p.forget(99); // unknown id is a no-op
        assert_eq!(p.len(), 1);
    }

    #[test]
    fn predictor_capacity_evicts_least_seen() {
        let t0 = Instant::now();
        let ms = Duration::from_millis;
        let mut p = ArrivalPredictor::with_capacity(3);
        // tenants 0,1 get two arrivals; 2 gets one; 3 displaces 2
        for k in 0..2u64 {
            p.observe(0, t0 + ms(k * 10));
            p.observe(1, t0 + ms(k * 10 + 1));
        }
        p.observe(2, t0 + ms(5));
        assert_eq!(p.len(), 3);
        p.observe(3, t0 + ms(20));
        assert_eq!(p.len(), 3, "capacity bound holds");
        // 2 (count 1) was the eviction victim: 0 and 1 still predict
        let due = p.due(t0 + ms(30));
        assert!(due.contains(&0) && due.contains(&1), "{due:?}");
    }

    #[test]
    fn predictor_is_deterministic_for_equal_inputs() {
        let t0 = Instant::now();
        let ms = Duration::from_millis;
        let run = || {
            let mut p = ArrivalPredictor::with_capacity(8);
            for k in 0..30u64 {
                p.observe((k % 5) as AdapterId, t0 + ms(k * 3));
            }
            p.due(t0 + ms(100))
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn closed_loop_ids_match_open_loop_mix() {
        let cfg = WorkloadConfig { n_requests: 64, ..Default::default() };
        let ids: Vec<AdapterId> = (0..8).collect();
        let closed = zipf_ids(&cfg, &ids);
        let open: Vec<AdapterId> = generate(&cfg, &ids).into_iter().map(|a| a.adapter).collect();
        assert_eq!(closed, open, "same seed must yield the same adapter mix");
        assert_eq!(closed.len(), 64);
    }
}
