//! Synthetic serving workload generator: Poisson arrivals over a Zipf
//! adapter-popularity distribution — the multi-tenant request mix the
//! paper's LLM-customization setting implies.

use crate::coordinator::registry::AdapterId;
use crate::testutil::Rng;
use std::time::Duration;

/// Workload parameters.
#[derive(Debug, Clone, Copy)]
pub struct WorkloadConfig {
    /// Mean request rate (requests/second) for the open-loop generator.
    pub rate: f64,
    /// Zipf exponent of adapter popularity (0 = uniform).
    pub zipf_alpha: f64,
    /// Number of requests to generate.
    pub n_requests: usize,
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        Self { rate: 200.0, zipf_alpha: 1.1, n_requests: 200, seed: 7 }
    }
}

/// One generated arrival.
#[derive(Debug, Clone)]
pub struct Arrival {
    /// Offset from workload start.
    pub at: Duration,
    pub adapter: AdapterId,
}

/// Generate an open-loop arrival schedule over the given adapters.
pub fn generate(cfg: &WorkloadConfig, adapters: &[AdapterId]) -> Vec<Arrival> {
    assert!(!adapters.is_empty());
    let mut rng = Rng::new(cfg.seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(cfg.n_requests);
    // Zipf over a popularity permutation so "popular" ids are arbitrary
    let mut perm: Vec<usize> = (0..adapters.len()).collect();
    rng.shuffle(&mut perm);
    for _ in 0..cfg.n_requests {
        t += rng.exp(cfg.rate);
        let pick = if cfg.zipf_alpha <= 0.0 {
            rng.below(adapters.len())
        } else {
            rng.zipf(adapters.len(), cfg.zipf_alpha)
        };
        out.push(Arrival { at: Duration::from_secs_f64(t), adapter: adapters[perm[pick]] });
    }
    out
}

/// Closed-loop variant: just the Zipf-popular adapter sequence, no
/// arrival times. Saturation benches (and the multi-worker scaling
/// scenario) submit these back-to-back to measure peak throughput
/// instead of open-loop latency.
pub fn zipf_ids(cfg: &WorkloadConfig, adapters: &[AdapterId]) -> Vec<AdapterId> {
    generate(cfg, adapters).into_iter().map(|a| a.adapter).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_monotone_and_counted() {
        let cfg = WorkloadConfig { n_requests: 100, ..Default::default() };
        let arr = generate(&cfg, &[0, 1, 2]);
        assert_eq!(arr.len(), 100);
        for w in arr.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
    }

    #[test]
    fn rate_controls_density() {
        let slow = generate(&WorkloadConfig { rate: 10.0, n_requests: 50, ..Default::default() }, &[0]);
        let fast = generate(&WorkloadConfig { rate: 1000.0, n_requests: 50, ..Default::default() }, &[0]);
        assert!(slow.last().unwrap().at > fast.last().unwrap().at);
    }

    #[test]
    fn zipf_skews_popularity() {
        let cfg = WorkloadConfig { zipf_alpha: 1.3, n_requests: 2000, ..Default::default() };
        let ids: Vec<AdapterId> = (0..20).collect();
        let arr = generate(&cfg, &ids);
        let mut counts = vec![0usize; 20];
        for a in &arr {
            counts[a.adapter as usize] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        assert!(counts[0] > 3 * counts[10].max(1), "head {} vs mid {}", counts[0], counts[10]);
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = WorkloadConfig::default();
        let a = generate(&cfg, &[0, 1]);
        let b = generate(&cfg, &[0, 1]);
        assert_eq!(a.len(), b.len());
        assert!(a.iter().zip(&b).all(|(x, y)| x.at == y.at && x.adapter == y.adapter));
    }

    #[test]
    fn closed_loop_ids_match_open_loop_mix() {
        let cfg = WorkloadConfig { n_requests: 64, ..Default::default() };
        let ids: Vec<AdapterId> = (0..8).collect();
        let closed = zipf_ids(&cfg, &ids);
        let open: Vec<AdapterId> = generate(&cfg, &ids).into_iter().map(|a| a.adapter).collect();
        assert_eq!(closed, open, "same seed must yield the same adapter mix");
        assert_eq!(closed.len(), 64);
    }
}
