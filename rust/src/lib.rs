//! # LoRAQuant
//!
//! Production-oriented reproduction of *"LoRAQuant: Mixed-Precision
//! Quantization of LoRA to Ultra-Low Bits"* (Mirzaei et al., 2025) as a
//! three-layer Rust + JAX/Pallas system:
//!
//! * **L3 (this crate)** — the quantization pipeline (SVD reparameterization,
//!   dynamic variance-ratio split, straight-through-estimator refinement,
//!   mixed-precision RTN/binary quantization), all evaluation baselines
//!   (GPTQ, PB-LLM, BiLLM, JD-Diagonal, …), and a multi-LoRA serving
//!   coordinator (adapter registry, merged-weight cache, dynamic batcher,
//!   thread-pool server).
//! * **L2 (python/compile/model.py)** — a tiny decoder-only transformer whose
//!   forward pass is AOT-lowered to HLO text and executed here via PJRT.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the fused
//!   quantized sub-LoRA apply and group-wise (de)quantization.
//!
//! Python never runs on the request path: `make artifacts` is the only
//! python invocation, producing `artifacts/*.hlo.txt` plus trained weights,
//! and everything afterwards is this crate.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index,
//! and `EXPERIMENTS.md` for paper-vs-measured results.

// Numeric kernels here index several parallel buffers per loop; iterator
// rewrites obscure the math without changing codegen.
#![allow(clippy::needless_range_loop)]

pub mod adapter;
pub mod baselines;
pub mod bench;
pub mod cli;
pub mod clock;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod experiments;
pub mod linalg;
pub mod loraquant;
pub mod model;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod scenario;
pub mod scheduler;
pub mod tensor;
pub mod testutil;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
