//! Property-based testing mini-framework (proptest is unavailable offline).
//!
//! ```
//! use loraquant::testutil::{check, Rng};
//! check("dot is symmetric", |rng: &mut Rng| {
//!     let a: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
//!     let b: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
//!     let d1 = loraquant::tensor::dot(&a, &b);
//!     let d2 = loraquant::tensor::dot(&b, &a);
//!     assert!((d1 - d2).abs() < 1e-5);
//! });
//! ```

use super::Rng;

/// Property-run configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed; case i runs with seed `seed + i`.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 64, seed: 0xC0FFEE }
    }
}

/// Run `prop` over `Config::default().cases` random cases. The property
/// receives a per-case seeded [`Rng`]; assertion failures are caught and
/// re-raised with the replaying seed + case index in the message.
pub fn check(name: &str, prop: impl Fn(&mut Rng) + std::panic::RefUnwindSafe) {
    check_with(Config::default(), name, prop);
}

/// [`check`] with an explicit configuration.
pub fn check_with(cfg: Config, name: &str, prop: impl Fn(&mut Rng) + std::panic::RefUnwindSafe) {
    for case in 0..cfg.cases {
        let seed = cfg.seed.wrapping_add(case as u64);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed at case {case} (replay seed {seed}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("trivial", |rng| {
            let x = rng.f32();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        check_with(Config { cases: 8, seed: 1 }, "always fails", |_rng| {
            panic!("boom");
        });
    }
}
