//! Synthetic model artifacts: a tiny, randomly-initialized base model (and
//! matching quantized adapters) written in the real on-disk layout, so the
//! serving stack — coordinator pool, merge pipeline, cache, batcher — can
//! be exercised end-to-end without `make artifacts` or PJRT.
//!
//! The reference engine (`runtime::sim`) only needs `meta.bin` +
//! `base.bin`; stub `.hlo.txt` markers are still written so presence
//! checks shared with the PJRT path (e.g. `experiments::Settings`) pass.

use super::Rng;
use crate::adapter::fmt::{save_tensorfile, Tensor};
use crate::coordinator::StoredAdapter;
use crate::loraquant::{quantize_site, LoraQuantConfig, QuantizedLora};
use crate::model::ModelConfig;
use anyhow::Context;
use std::collections::BTreeMap;
use std::path::Path;

/// The default synthetic model: small enough that a forward is
/// microseconds, shaped like the real tiny-llama family.
pub fn synth_model_config() -> ModelConfig {
    ModelConfig {
        d_model: 32,
        n_layers: 1,
        n_heads: 2,
        d_ff: 64,
        vocab: 64,
        seq_len: 16,
        lora_rank: 8,
        lora_alpha: 16,
        act_silu: false,
    }
}

/// Write `<artifacts>/<model>/{meta,base}.bin` plus stub
/// `<model>.fwd.b<bucket>.hlo.txt` markers for each bucket.
///
/// The base weights are scaled-normal initialized exactly like
/// python/compile/model.py `init_params` (std 0.02, LN gains 1, biases 0),
/// seeded for reproducibility.
pub fn write_synth_model(
    artifacts: &Path,
    model: &str,
    cfg: &ModelConfig,
    buckets: &[usize],
    seed: u64,
) -> anyhow::Result<()> {
    let dir = artifacts.join(model);
    cfg.save(&dir)?;
    let mut rng = Rng::new(seed);
    let mut t = BTreeMap::new();
    let (d, f, v, tl) = (cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq_len);
    let normal = |dims: Vec<usize>, rng: &mut Rng| {
        let n: usize = dims.iter().product();
        Tensor::f32(dims, (0..n).map(|_| rng.normal() * 0.02).collect())
    };
    t.insert("embed".to_string(), normal(vec![v, d], &mut rng));
    t.insert("pos".to_string(), normal(vec![tl, d], &mut rng));
    for i in 0..cfg.n_layers {
        t.insert(format!("l{i}.ln1.g"), Tensor::f32(vec![d], vec![1.0; d]));
        t.insert(format!("l{i}.ln1.b"), Tensor::f32(vec![d], vec![0.0; d]));
        for w in ["wq", "wk", "wv", "wo"] {
            t.insert(format!("l{i}.{w}"), normal(vec![d, d], &mut rng));
        }
        t.insert(format!("l{i}.ln2.g"), Tensor::f32(vec![d], vec![1.0; d]));
        t.insert(format!("l{i}.ln2.b"), Tensor::f32(vec![d], vec![0.0; d]));
        t.insert(format!("l{i}.w1"), normal(vec![d, f], &mut rng));
        t.insert(format!("l{i}.w2"), normal(vec![f, d], &mut rng));
    }
    t.insert("lnf.g".to_string(), Tensor::f32(vec![d], vec![1.0; d]));
    t.insert("lnf.b".to_string(), Tensor::f32(vec![d], vec![0.0; d]));
    t.insert("head".to_string(), normal(vec![d, v], &mut rng));
    save_tensorfile(dir.join("base.bin"), &t)?;
    for &b in buckets {
        let marker = artifacts.join(format!("{model}.fwd.b{b}.hlo.txt"));
        std::fs::write(&marker, "synthetic artifact marker (reference engine; no HLO)\n")
            .with_context(|| format!("writing {}", marker.display()))?;
    }
    Ok(())
}

/// A LoRAQuant(2@0.9) adapter covering every LoRA site of `cfg`, built
/// from a seeded decaying-spectrum factor pair per site. STE refinement
/// is disabled so construction stays fast in tests and benches.
pub fn synth_quantized_adapter(cfg: &ModelConfig, seed: u64) -> StoredAdapter {
    let mut rng = Rng::new(seed);
    let qcfg = LoraQuantConfig {
        ste: None,
        group: 16,
        ..LoraQuantConfig::variant(2, 0.9)
    };
    let mut q = QuantizedLora::default();
    for site in cfg.lora_site_names() {
        let short = site.rsplit_once('.').map(|(_, s)| s).unwrap_or(site.as_str());
        let (n_in, m_out) = cfg.site_shape(short).expect("known site");
        let (b, a) = rng.lora_pair(m_out, n_in, cfg.lora_rank, 0.7);
        q.sites.insert(site, quantize_site(&b, &a, &qcfg).expect("synth config is well-formed"));
    }
    StoredAdapter::Quantized(q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::BaseWeights;

    #[test]
    fn synth_model_loads_as_base_weights() {
        let dir = std::env::temp_dir().join(format!("lq_synth_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = synth_model_config();
        write_synth_model(&dir, "m", &cfg, &[1, 8], 1).unwrap();
        assert!(dir.join("m.fwd.b8.hlo.txt").exists());
        let base = BaseWeights::load(dir.join("m")).unwrap();
        assert_eq!(base.cfg, cfg);
        assert!(base.param_count() > 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn synth_adapter_covers_all_sites() {
        let cfg = synth_model_config();
        let ad = synth_quantized_adapter(&cfg, 5);
        let StoredAdapter::Quantized(q) = &ad else {
            panic!("expected quantized")
        };
        assert_eq!(q.sites.len(), cfg.lora_site_names().len());
        assert!(ad.avg_bits() < 16.0);
        // deltas must match every merged site's expected orientation
        for (site, delta) in ad.deltas() {
            let short = site.rsplit_once('.').unwrap().1;
            let (n_in, m_out) = cfg.site_shape(short).unwrap();
            assert_eq!(delta.shape(), (m_out, n_in), "{site}");
        }
    }
}
