//! Deterministic PRNG + a small property-based testing framework.
//!
//! `proptest` is not available offline, so `proptests.rs` (the integration
//! suite) uses this mini-framework: a generator produces random cases from a
//! seeded [`Rng`], `check` runs the property over many cases and, on
//! failure, reports the seed + case index so the exact case replays.

mod prng;
mod property;
pub mod synth;

pub use prng::Rng;
pub use property::{check, check_with, Config};
pub use synth::{synth_model_config, synth_quantized_adapter, write_synth_model};
