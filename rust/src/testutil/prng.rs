//! xoshiro256** — small, fast, deterministic PRNG (no rand crate offline).

use crate::tensor::Matrix;

/// Deterministic PRNG for tests, workloads and initializations.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seeded construction (SplitMix64 expansion of the seed).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal (Box-Muller).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-9);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Matrix of iid N(0, std²) entries.
    pub fn matrix(&mut self, rows: usize, cols: usize, std: f32) -> Matrix {
        Matrix::from_fn(rows, cols, |_, _| self.normal() * std)
    }

    /// Exponential with given rate (inter-arrival sampling).
    pub fn exp(&mut self, rate: f64) -> f64 {
        let u = (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
        -(1.0 - u).ln() / rate
    }

    /// Zipf-distributed index in [0, n) with exponent `alpha` (adapter
    /// popularity skew in the serving workload).
    pub fn zipf(&mut self, n: usize, alpha: f64) -> usize {
        // cumulative-scan inverse CDF (n is at most a few thousand adapters;
        // the serving workload caches popularity tables anyway).
        let mut total = 0.0;
        for k in 1..=n {
            total += 1.0 / (k as f64).powf(alpha);
        }
        let u = ((self.next_u64() >> 11) as f64 / (1u64 << 53) as f64).min(0.999_999);
        let target = u * total;
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(alpha);
            if acc >= target {
                return k - 1;
            }
        }
        n - 1
    }

    /// Shuffle a slice in place (Fisher-Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// LoRA-like factor pair with geometrically decaying spectrum — the
    /// realistic input distribution for quantizer tests (trained adapters
    /// have fast-decaying singular values).
    pub fn lora_pair(&mut self, m: usize, n: usize, r: usize, decay: f32) -> (Matrix, Matrix) {
        let mut b = Matrix::zeros(m, r);
        let mut a = Matrix::zeros(r, n);
        for k in 0..r {
            let s = decay.powi(k as i32);
            let u: Vec<f32> = (0..m).map(|_| self.normal() / (m as f32).sqrt()).collect();
            let v: Vec<f32> = (0..n).map(|_| self.normal() / (n as f32).sqrt()).collect();
            for i in 0..m {
                b.set(i, k, u[i] * s.sqrt() * 3.0);
            }
            for j in 0..n {
                a.set(k, j, v[j] * s.sqrt() * 3.0);
            }
        }
        (b, a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f32_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let xs: Vec<f32> = (0..20000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn zipf_skewed() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..5000 {
            counts[r.zipf(10, 1.2)] += 1;
        }
        assert!(counts[0] > counts[9], "zipf head {} tail {}", counts[0], counts[9]);
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(4);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
