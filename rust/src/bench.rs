//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets are `harness = false` binaries built on this
//! module: warmup, timed iterations, mean/p50/p99, and a uniform
//! row-printing helper for the paper-table benches.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub min: Duration,
}

impl BenchResult {
    /// Items/second at `items` per iteration.
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.mean.as_secs_f64()
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>10.3?} mean  {:>10.3?} p50  {:>10.3?} p99  ({} iters)",
            self.name, self.mean, self.p50, self.p99, self.iters
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort_unstable();
    let sum: Duration = samples.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters,
        mean: sum / iters as u32,
        p50: samples[iters / 2],
        p99: samples[(iters * 99 / 100).min(iters - 1)],
        min: samples[0],
    }
}

/// Auto-calibrated: picks an iteration count that fits the time budget.
pub fn bench_for<T>(name: &str, budget: Duration, mut f: impl FnMut() -> T) -> BenchResult {
    // one probe run to estimate cost
    let t0 = Instant::now();
    std::hint::black_box(f());
    let probe = t0.elapsed().max(Duration::from_nanos(100));
    let iters = ((budget.as_secs_f64() / probe.as_secs_f64()) as usize).clamp(3, 10_000);
    bench(name, iters.div_ceil(10), iters, f)
}

/// Fixed-width table printer for the paper-reproduction benches.
pub struct Table {
    widths: Vec<usize>,
}

impl Table {
    pub fn new(widths: &[usize]) -> Self {
        Self { widths: widths.to_vec() }
    }

    pub fn row(&self, cells: &[String]) -> String {
        let mut out = String::new();
        for (i, c) in cells.iter().enumerate() {
            let w = self.widths.get(i).copied().unwrap_or(12);
            out.push_str(&format!("{c:<w$} "));
        }
        out.trim_end().to_string()
    }

    pub fn sep(&self) -> String {
        self.widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("-")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_ordered_quantiles() {
        let r = bench("noop", 2, 50, || 1 + 1);
        assert_eq!(r.iters, 50);
        assert!(r.min <= r.p50 && r.p50 <= r.p99);
        assert!(r.throughput(1.0) > 0.0);
    }

    #[test]
    fn bench_for_calibrates() {
        let r = bench_for("sleepless", Duration::from_millis(5), || {
            std::hint::black_box((0..100).sum::<u64>())
        });
        assert!(r.iters >= 3);
    }

    #[test]
    fn table_alignment() {
        let t = Table::new(&[8, 6]);
        let row = t.row(&["abc".into(), "1.23".into()]);
        assert!(row.starts_with("abc"));
        assert!(row.len() >= 12);
    }
}
