//! Matrix products. Row-major, cache-blocked enough for LoRA-sized work.

use super::{dot, Matrix};

/// `C = A @ B` (A: m×k, B: k×n).
///
/// i-k-j loop order: the inner j-loop streams one row of B and one row of C,
/// which autovectorizes and stays in L1 for LoRA-factor shapes.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul {:?} x {:?}", a.shape(), b.shape());
    let (m, k) = a.shape();
    let n = b.cols();
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        let arow = a.row(i);
        // split borrows: write through raw row pointer of c
        let crow = unsafe {
            std::slice::from_raw_parts_mut(c.data_mut().as_mut_ptr().add(i * n), n)
        };
        for p in 0..k {
            let av = arow[p];
            if av == 0.0 {
                continue;
            }
            let brow = b.row(p);
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    c
}

/// `C = Aᵀ @ B` (A: k×m, B: k×n) without materializing the transpose.
pub fn matmul_at_b(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows(), b.rows(), "matmul_at_b {:?} x {:?}", a.shape(), b.shape());
    let (k, m) = a.shape();
    let n = b.cols();
    let mut c = Matrix::zeros(m, n);
    for p in 0..k {
        let arow = a.row(p);
        let brow = b.row(p);
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = unsafe {
                std::slice::from_raw_parts_mut(c.data_mut().as_mut_ptr().add(i * n), n)
            };
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    c
}

/// `C = A @ Bᵀ` (A: m×k, B: n×k) — rows of both operands are contiguous,
/// so every inner product is a pair of streamed slices.
pub fn matmul_a_bt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.cols(), "matmul_a_bt {:?} x {:?}", a.shape(), b.shape());
    let m = a.rows();
    let n = b.rows();
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        let arow = a.row(i);
        for j in 0..n {
            c.set(i, j, dot(arow, b.row(j)));
        }
    }
    c
}

/// Outer product `u vᵀ` as an m×n matrix.
pub fn outer(u: &[f32], v: &[f32]) -> Matrix {
    let mut c = Matrix::zeros(u.len(), v.len());
    for (i, &ui) in u.iter().enumerate() {
        let row = c.row_mut(i);
        for (j, &vj) in v.iter().enumerate() {
            row[j] = ui * vj;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for p in 0..a.cols() {
                    s += a.at(i, p) * b.at(p, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    fn rand_mat(r: usize, c: usize, seed: u64) -> Matrix {
        let mut s = seed.wrapping_mul(2862933555777941757).wrapping_add(3037000493);
        Matrix::from_fn(r, c, |_, _| {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            (s as f64 / u64::MAX as f64) as f32 - 0.5
        })
    }

    #[test]
    fn matmul_matches_naive() {
        let a = rand_mat(7, 11, 1);
        let b = rand_mat(11, 5, 2);
        let c = matmul(&a, &b);
        assert!(c.rel_err(&naive(&a, &b)) < 1e-5);
    }

    #[test]
    fn at_b_matches() {
        let a = rand_mat(9, 6, 3);
        let b = rand_mat(9, 4, 4);
        let c = matmul_at_b(&a, &b);
        assert!(c.rel_err(&naive(&a.transpose(), &b)) < 1e-5);
    }

    #[test]
    fn a_bt_matches() {
        let a = rand_mat(5, 8, 5);
        let b = rand_mat(6, 8, 6);
        let c = matmul_a_bt(&a, &b);
        assert!(c.rel_err(&naive(&a, &b.transpose())) < 1e-5);
    }

    #[test]
    fn outer_matches() {
        let u = vec![1.0, 2.0];
        let v = vec![3.0, 4.0, 5.0];
        let c = outer(&u, &v);
        assert_eq!(c.at(1, 2), 10.0);
        assert_eq!(c.shape(), (2, 3));
    }
}
