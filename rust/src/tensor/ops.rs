//! Matrix products. Row-major, cache-blocked enough for LoRA-sized work.
//!
//! Three families:
//!
//! * dense × dense ([`matmul`], [`matmul_at_b`], [`matmul_a_bt`], [`outer`]);
//! * dense × dense on **flat slices** ([`matmul_flat`],
//!   [`matmul_flat_threaded`]) — the reference engine's hot projection
//!   kernel, with an output-row-partitioned `std::thread::scope` variant
//!   for batched prefill. Each output row accumulates in the same order
//!   regardless of thread count, so the threaded product is bit-identical
//!   to the serial one;
//! * dense × **quantized** ([`matmul_qdequant_acc`],
//!   [`matmul_qdequant_bt_acc`]) — skinny GEMMs whose right operand stays
//!   packed: each stored row is unpacked + scaled once into an O(cols)
//!   scratch buffer and streamed through the product, so the dense matrix
//!   is never materialized. These are the factor-form serving kernels
//!   (DESIGN.md §8); anything implementing [`DequantRows`] can be the
//!   right operand. The `_into` variants take the scratch row from the
//!   caller, so steady-state decode allocates nothing (DESIGN.md §10).
//!
//! # Semantics: strict IEEE accumulation, no sparsity shortcuts
//!
//! Every kernel issues the full `c + a·b` for every operand pair — there
//! is **no** `a == 0.0` skip-branch anywhere. Skipping zero scalars turns
//! `0·NaN` and `0·∞` into silent zeros and flips the sign of `-0.0` sums,
//! so a skipping scalar kernel and a non-skipping SIMD kernel disagree
//! bitwise on exactly the inputs that matter for debugging. (Activations
//! *do* produce exact zeros: saturated `gelu` returns `0.0`, `silu`
//! returns `-0.0` for large negative inputs.) A sparsity fast path may
//! only return if `bench_kernels` proves it wins *and* it preserves these
//! bits. Reduction orders are fixed per family — see `tensor/simd.rs` —
//! and the [`scalar`] module keeps naive implementations of the same
//! orders as oracles for property tests and as `bench_kernels` baselines.

use super::{dot, simd, Matrix};

/// A matrix whose rows can be produced densely one at a time — the
/// contract between the packed quantized formats in `quant/` (and plain
/// [`Matrix`]) and the streaming GEMM kernels below.
pub trait DequantRows {
    /// Stored row count.
    fn src_rows(&self) -> usize;
    /// Stored column count.
    fn src_cols(&self) -> usize;
    /// Dequantize stored row `i` into `out` (`out.len() == src_cols()`).
    fn dequant_row_into(&self, i: usize, out: &mut [f32]);
}

impl DequantRows for Matrix {
    fn src_rows(&self) -> usize {
        self.rows()
    }

    fn src_cols(&self) -> usize {
        self.cols()
    }

    fn dequant_row_into(&self, i: usize, out: &mut [f32]) {
        out.copy_from_slice(self.row(i));
    }
}

/// `C = A @ B` (A: m×k, B: k×n).
///
/// Same blocked kernel as [`matmul_flat`] (i-k-j order, 4×8 register
/// tiles): Matrix data is already flat row-major, so the two entry points
/// are bit-identical by construction.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul {:?} x {:?}", a.shape(), b.shape());
    let (m, k) = a.shape();
    let n = b.cols();
    let mut c = Matrix::zeros(m, n);
    matmul_flat_rows(a.data(), m, k, b.data(), n, c.data_mut());
    c
}

/// `C = Aᵀ @ B` (A: k×m, B: k×n) without materializing the transpose.
///
/// p-i-j order; per output element the accumulation runs over ascending
/// `p`, the axpy-family canonical order.
pub fn matmul_at_b(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows(), b.rows(), "matmul_at_b {:?} x {:?}", a.shape(), b.shape());
    let (k, m) = a.shape();
    let n = b.cols();
    let mut c = Matrix::zeros(m, n);
    let cdata = c.data_mut();
    for p in 0..k {
        let arow = a.row(p);
        let brow = b.row(p);
        for i in 0..m {
            simd::axpy(&mut cdata[i * n..(i + 1) * n], arow[i], brow);
        }
    }
    c
}

/// `C = A @ Bᵀ` (A: m×k, B: n×k) — rows of both operands are contiguous,
/// so every inner product is a pair of streamed slices.
pub fn matmul_a_bt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.cols(), "matmul_a_bt {:?} x {:?}", a.shape(), b.shape());
    let m = a.rows();
    let n = b.rows();
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        let arow = a.row(i);
        for j in 0..n {
            c.set(i, j, dot(arow, b.row(j)));
        }
    }
    c
}

/// The serial row kernel shared by [`matmul`], [`matmul_flat`], every
/// partition of [`matmul_flat_threaded`], and the persistent compute
/// pool's partitions (`scheduler::workers::ComputePool::matmul_flat`):
/// `c[rows×n] += a[rows×k] @ b[k×n]` (callers zero `c` first).
///
/// Blocking: 4 `p` steps register-blocked per [`simd::axpy4`] panel, 8
/// output columns per lane group. Per output element the adds still land
/// one at a time in ascending `p`, so the blocked kernel is bit-identical
/// to [`scalar::matmul_flat_rows`] — and, because the blocking is
/// per-row, identical at every thread partitioning.
pub(crate) fn matmul_flat_rows(
    a: &[f32],
    rows: usize,
    k: usize,
    b: &[f32],
    n: usize,
    c: &mut [f32],
) {
    let kb = k / 4 * 4;
    for i in 0..rows {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        let mut p = 0;
        while p < kb {
            simd::axpy4(
                crow,
                [arow[p], arow[p + 1], arow[p + 2], arow[p + 3]],
                &b[p * n..(p + 1) * n],
                &b[(p + 1) * n..(p + 2) * n],
                &b[(p + 2) * n..(p + 3) * n],
                &b[(p + 3) * n..(p + 4) * n],
            );
            p += 4;
        }
        while p < k {
            simd::axpy(crow, arow[p], &b[p * n..(p + 1) * n]);
            p += 1;
        }
    }
}

/// `C[m,n] = A[m,k] @ B[k,n]` on flat row-major slices (i-k-j order, the
/// same kernel shape as [`matmul`]).
pub fn matmul_flat(a: &[f32], m: usize, k: usize, b: &[f32], n: usize, c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.fill(0.0);
    matmul_flat_rows(a, m, k, b, n, c);
}

/// [`matmul_flat`] with the output rows partitioned across `threads`
/// scoped worker threads (no thread pool, no dependencies — workers live
/// for one product). Every output row runs the identical serial
/// accumulation, so the result is **bit-identical** for every thread
/// count; `threads <= 1` is exactly the serial kernel.
///
/// This is the legacy per-call-spawn variant: the engine's hot paths now
/// go through the persistent `scheduler::workers::ComputePool` (same
/// partitioning, same bits, no spawn/join per product — DESIGN.md §11);
/// this one remains for one-shot callers and as the scoped-spawn
/// baseline `bench_decode`'s kernel row measures the pool against.
pub fn matmul_flat_threaded(
    a: &[f32],
    m: usize,
    k: usize,
    b: &[f32],
    n: usize,
    c: &mut [f32],
    threads: usize,
) {
    let threads = threads.max(1).min(m.max(1));
    if threads <= 1 || n == 0 {
        return matmul_flat(a, m, k, b, n, c);
    }
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let chunk = m.div_ceil(threads);
    std::thread::scope(|s| {
        for (ci, cs) in c.chunks_mut(chunk * n).enumerate() {
            let rows = cs.len() / n;
            let asub = &a[ci * chunk * k..(ci * chunk + rows) * k];
            s.spawn(move || {
                cs.fill(0.0);
                matmul_flat_rows(asub, rows, k, b, n, cs);
            });
        }
    });
}

/// Outer product `u vᵀ` as an m×n matrix.
pub fn outer(u: &[f32], v: &[f32]) -> Matrix {
    let mut c = Matrix::zeros(u.len(), v.len());
    for (i, &ui) in u.iter().enumerate() {
        let row = c.row_mut(i);
        for (j, &vj) in v.iter().enumerate() {
            row[j] = ui * vj;
        }
    }
    c
}

/// `out += alpha · X @ deq(Q)` on flat row-major buffers
/// (X: rows×k, Q stored k×n, out: rows×n), with the O(n) dequant row
/// supplied by the caller (resized in place, so a warm buffer makes the
/// kernel allocation-free).
///
/// p-i-j loop order so each packed row of Q is dequantized exactly once
/// per call, then streamed against column p of X — the full dense Q never
/// exists.
pub fn matmul_qdequant_acc_into(
    x: &[f32],
    rows: usize,
    k: usize,
    q: &dyn DequantRows,
    alpha: f32,
    out: &mut [f32],
    qrow: &mut Vec<f32>,
) {
    assert_eq!(q.src_rows(), k, "qdequant: Q has {} rows, X has {} cols", q.src_rows(), k);
    let n = q.src_cols();
    assert_eq!(x.len(), rows * k, "qdequant: X len {} != {}x{}", x.len(), rows, k);
    assert_eq!(out.len(), rows * n, "qdequant: out len {} != {}x{}", out.len(), rows, n);
    qrow.resize(n, 0.0);
    let qrow = &mut qrow[..n];
    for p in 0..k {
        q.dequant_row_into(p, qrow);
        for i in 0..rows {
            simd::axpy(&mut out[i * n..(i + 1) * n], alpha * x[i * k + p], qrow);
        }
    }
}

/// [`matmul_qdequant_acc_into`] with a one-shot scratch row.
pub fn matmul_qdequant_acc(
    x: &[f32],
    rows: usize,
    k: usize,
    q: &dyn DequantRows,
    alpha: f32,
    out: &mut [f32],
) {
    let mut qrow = Vec::new();
    matmul_qdequant_acc_into(x, rows, k, q, alpha, out, &mut qrow);
}

/// `out += alpha · X @ deq(Q)ᵀ` on flat row-major buffers
/// (X: rows×k, Q stored n×k, out: rows×n), dequant row supplied by the
/// caller.
///
/// Each packed row of Q is dequantized once, then dotted with every row
/// of X (both contiguous), writing one output column.
pub fn matmul_qdequant_bt_acc_into(
    x: &[f32],
    rows: usize,
    k: usize,
    q: &dyn DequantRows,
    alpha: f32,
    out: &mut [f32],
    qrow: &mut Vec<f32>,
) {
    assert_eq!(q.src_cols(), k, "qdequant_bt: Q has {} cols, X has {} cols", q.src_cols(), k);
    let n = q.src_rows();
    assert_eq!(x.len(), rows * k, "qdequant_bt: X len {} != {}x{}", x.len(), rows, k);
    assert_eq!(out.len(), rows * n, "qdequant_bt: out len {} != {}x{}", out.len(), rows, n);
    qrow.resize(k, 0.0);
    let qrow = &mut qrow[..k];
    for j in 0..n {
        q.dequant_row_into(j, qrow);
        for i in 0..rows {
            out[i * n + j] += alpha * dot(&x[i * k..(i + 1) * k], qrow);
        }
    }
}

/// [`matmul_qdequant_bt_acc_into`] with a one-shot scratch row.
pub fn matmul_qdequant_bt_acc(
    x: &[f32],
    rows: usize,
    k: usize,
    q: &dyn DequantRows,
    alpha: f32,
    out: &mut [f32],
) {
    let mut qrow = Vec::new();
    matmul_qdequant_bt_acc_into(x, rows, k, q, alpha, out, &mut qrow);
}

/// Matrix-shaped convenience over [`matmul_qdequant_acc`]:
/// `X @ deq(Q)` (X: m×k, Q stored k×n).
pub fn matmul_qdequant(x: &Matrix, q: &dyn DequantRows) -> Matrix {
    let mut out = Matrix::zeros(x.rows(), q.src_cols());
    let (rows, k) = x.shape();
    matmul_qdequant_acc(x.data(), rows, k, q, 1.0, out.data_mut());
    out
}

/// Matrix-shaped convenience over [`matmul_qdequant_bt_acc`]:
/// `X @ deq(Q)ᵀ` (X: m×k, Q stored n×k).
pub fn matmul_qdequant_bt(x: &Matrix, q: &dyn DequantRows) -> Matrix {
    let mut out = Matrix::zeros(x.rows(), q.src_rows());
    let (rows, k) = x.shape();
    matmul_qdequant_bt_acc(x.data(), rows, k, q, 1.0, out.data_mut());
    out
}

/// Naive single-element-at-a-time implementations of the **same**
/// canonical reduction orders as the blocked kernels above. These are the
/// oracles the property tests pin the blocked kernels against bit for
/// bit, and the baselines `bench_kernels` measures speedups over. They
/// must stay unblocked and unoptimized — their value is being obviously
/// correct, not fast.
pub mod scalar {
    use super::DequantRows;

    /// Canonical dot order written naively: `acc[i % 8] += a[i]*b[i]`,
    /// fixed pairwise combine, sequential tail (`tensor::simd::dot8`).
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        assert_eq!(a.len(), b.len());
        let lanes = super::simd::LANES;
        let full = a.len() / lanes * lanes;
        let mut acc = [0.0f32; 8];
        for i in 0..full {
            acc[i % lanes] += a[i] * b[i];
        }
        let mut s =
            ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
        for i in full..a.len() {
            s += a[i] * b[i];
        }
        s
    }

    /// Axpy-family oracle: i-p-j triple loop, one add per element in
    /// ascending `p`, no skip-branches. `c += a @ b`.
    pub fn matmul_flat_rows(a: &[f32], rows: usize, k: usize, b: &[f32], n: usize, c: &mut [f32]) {
        for i in 0..rows {
            for p in 0..k {
                let av = a[i * k + p];
                for j in 0..n {
                    c[i * n + j] += av * b[p * n + j];
                }
            }
        }
    }

    /// `c = a @ b` on flat slices, oracle form.
    pub fn matmul_flat(a: &[f32], m: usize, k: usize, b: &[f32], n: usize, c: &mut [f32]) {
        c.fill(0.0);
        matmul_flat_rows(a, m, k, b, n, c);
    }

    /// Oracle for [`super::matmul_qdequant_acc_into`]: p-i-j, one dequant
    /// per stored row, naive inner loop.
    pub fn matmul_qdequant_acc(
        x: &[f32],
        rows: usize,
        k: usize,
        q: &dyn DequantRows,
        alpha: f32,
        out: &mut [f32],
    ) {
        let n = q.src_cols();
        let mut qrow = vec![0.0f32; n];
        for p in 0..k {
            q.dequant_row_into(p, &mut qrow);
            for i in 0..rows {
                let av = alpha * x[i * k + p];
                for j in 0..n {
                    out[i * n + j] += av * qrow[j];
                }
            }
        }
    }

    /// Oracle for [`super::matmul_qdequant_bt_acc_into`]: per stored row
    /// one dequant, then the canonical naive dot against every x row.
    pub fn matmul_qdequant_bt_acc(
        x: &[f32],
        rows: usize,
        k: usize,
        q: &dyn DequantRows,
        alpha: f32,
        out: &mut [f32],
    ) {
        let n = q.src_rows();
        let mut qrow = vec![0.0f32; k];
        for j in 0..n {
            q.dequant_row_into(j, &mut qrow);
            for i in 0..rows {
                out[i * n + j] += alpha * dot(&x[i * k..(i + 1) * k], qrow.as_slice());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for p in 0..a.cols() {
                    s += a.at(i, p) * b.at(p, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    fn rand_mat(r: usize, c: usize, seed: u64) -> Matrix {
        let mut s = seed.wrapping_mul(2862933555777941757).wrapping_add(3037000493);
        Matrix::from_fn(r, c, |_, _| {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            (s as f64 / u64::MAX as f64) as f32 - 0.5
        })
    }

    #[test]
    fn matmul_matches_naive() {
        let a = rand_mat(7, 11, 1);
        let b = rand_mat(11, 5, 2);
        let c = matmul(&a, &b);
        assert!(c.rel_err(&naive(&a, &b)) < 1e-5);
    }

    #[test]
    fn at_b_matches() {
        let a = rand_mat(9, 6, 3);
        let b = rand_mat(9, 4, 4);
        let c = matmul_at_b(&a, &b);
        assert!(c.rel_err(&naive(&a.transpose(), &b)) < 1e-5);
    }

    #[test]
    fn a_bt_matches() {
        let a = rand_mat(5, 8, 5);
        let b = rand_mat(6, 8, 6);
        let c = matmul_a_bt(&a, &b);
        assert!(c.rel_err(&naive(&a, &b.transpose())) < 1e-5);
    }

    #[test]
    fn outer_matches() {
        let u = vec![1.0, 2.0];
        let v = vec![3.0, 4.0, 5.0];
        let c = outer(&u, &v);
        assert_eq!(c.at(1, 2), 10.0);
        assert_eq!(c.shape(), (2, 3));
    }

    #[test]
    fn qdequant_with_dense_rows_matches_matmul() {
        // Matrix implements DequantRows, so the streaming kernel must
        // reproduce the dense product exactly.
        let x = rand_mat(5, 9, 7);
        let q = rand_mat(9, 6, 8);
        let c = matmul_qdequant(&x, &q);
        assert!(c.rel_err(&matmul(&x, &q)) < 1e-6);
    }

    #[test]
    fn qdequant_bt_with_dense_rows_matches_matmul() {
        let x = rand_mat(4, 7, 9);
        let q = rand_mat(5, 7, 10);
        let c = matmul_qdequant_bt(&x, &q);
        assert!(c.rel_err(&matmul(&x, &q.transpose())) < 1e-6);
    }

    #[test]
    fn flat_matmul_matches_matrix_kernel() {
        let a = rand_mat(9, 7, 21);
        let b = rand_mat(7, 5, 22);
        let mut c = vec![f32::NAN; 9 * 5];
        matmul_flat(a.data(), 9, 7, b.data(), 5, &mut c);
        assert_eq!(c, matmul(&a, &b).into_vec(), "flat kernel must match Matrix matmul exactly");
    }

    #[test]
    fn threaded_flat_matmul_bit_identical_for_every_thread_count() {
        // ragged row counts so chunking hits partial final partitions
        for m in [1usize, 2, 5, 8, 13] {
            let a = rand_mat(m, 11, 31 + m as u64);
            let b = rand_mat(11, 6, 32);
            let mut serial = vec![0.0f32; m * 6];
            matmul_flat(a.data(), m, 11, b.data(), 6, &mut serial);
            for threads in [1usize, 2, 3, 4, 16] {
                let mut par = vec![f32::NAN; m * 6];
                matmul_flat_threaded(a.data(), m, 11, b.data(), 6, &mut par, threads);
                assert_eq!(par, serial, "m={m} threads={threads} must be bit-identical");
            }
        }
    }

    #[test]
    fn qdequant_into_reuses_caller_scratch() {
        let x = rand_mat(4, 6, 41);
        let q = rand_mat(6, 9, 42);
        let qt = rand_mat(9, 6, 43);
        let mut scratch = Vec::new();
        let mut out = vec![0.0f32; 4 * 9];
        matmul_qdequant_acc_into(x.data(), 4, 6, &q, 1.0, &mut out, &mut scratch);
        assert_eq!(out, matmul_qdequant(&x, &q).into_vec());
        assert_eq!(scratch.len(), 9, "scratch holds one dequant row");
        let cap = scratch.capacity();
        // the bt kernel resizes the same buffer down and reuses it
        let mut out_bt = vec![0.0f32; 4 * 9];
        matmul_qdequant_bt_acc_into(x.data(), 4, 6, &qt, 1.0, &mut out_bt, &mut scratch);
        assert_eq!(out_bt, matmul_qdequant_bt(&x, &qt).into_vec());
        assert_eq!(scratch.capacity(), cap, "warm scratch must not reallocate");
    }

    /// Seeds a matrix, then plants exact zeros, -0.0, NaN, and inf — the
    /// operands the removed skip-branch used to mishandle.
    fn hazard_mat(r: usize, c: usize, seed: u64) -> Matrix {
        let mut m = rand_mat(r, c, seed);
        let n = m.len();
        let d = m.data_mut();
        d[0] = 0.0;
        d[n / 2] = -0.0;
        if n > 3 {
            d[1] = f32::NAN;
            d[n - 1] = f32::INFINITY;
        }
        m
    }

    /// Bitwise equality that treats any-NaN == any-NaN (assert_eq on f32
    /// fails on NaN even when both sides are NaN).
    fn assert_bits_eq(got: &[f32], want: &[f32], ctx: &str) {
        assert_eq!(got.len(), want.len(), "{ctx}: len");
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            let ok = g.to_bits() == w.to_bits() || (g.is_nan() && w.is_nan());
            assert!(ok, "{ctx}: [{i}] {g:?} ({:#x}) vs {w:?} ({:#x})", g.to_bits(), w.to_bits());
        }
    }

    #[test]
    fn blocked_matmul_bit_identical_to_scalar_oracle() {
        // k and n not multiples of the blocking widths (4 and 8), plus
        // hazard operands (0.0 / -0.0 / NaN / inf).
        for (m, k, n) in [(1usize, 1usize, 1usize), (3, 5, 7), (4, 9, 11), (7, 12, 16), (2, 13, 3)]
        {
            let a = hazard_mat(m, k, 61 + n as u64);
            let b = hazard_mat(k, n, 62 + m as u64);
            let mut blocked = vec![f32::NAN; m * n];
            matmul_flat(a.data(), m, k, b.data(), n, &mut blocked);
            let mut oracle = vec![f32::NAN; m * n];
            scalar::matmul_flat(a.data(), m, k, b.data(), n, &mut oracle);
            assert_bits_eq(&blocked, &oracle, &format!("{m}x{k}x{n}"));
        }
    }

    #[test]
    fn at_b_bit_identical_to_oracle_order() {
        let a = hazard_mat(9, 6, 63);
        let b = hazard_mat(9, 5, 64);
        let c = matmul_at_b(&a, &b);
        // same canonical order: transpose then run the flat oracle
        let at = a.transpose();
        let mut oracle = vec![0.0f32; 6 * 5];
        scalar::matmul_flat(at.data(), 6, 9, b.data(), 5, &mut oracle);
        assert_bits_eq(c.data(), &oracle, "at_b");
    }

    #[test]
    fn a_bt_uses_canonical_dot() {
        let a = hazard_mat(5, 13, 65);
        let b = hazard_mat(4, 13, 66);
        let c = matmul_a_bt(&a, &b);
        for i in 0..5 {
            for j in 0..4 {
                let want = scalar::dot(a.row(i), b.row(j));
                let got = c.at(i, j);
                assert!(
                    got.to_bits() == want.to_bits() || (got.is_nan() && want.is_nan()),
                    "({i},{j}): {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn zero_times_nan_propagates_through_matmul() {
        // a has an exact 0.0 facing a NaN in b: the product row must be
        // NaN, not silently zero (the old skip-branch bug).
        let a = Matrix::from_vec(1, 2, vec![0.0, 1.0]);
        let b = Matrix::from_vec(2, 2, vec![f32::NAN, 2.0, 3.0, 4.0]);
        let c = matmul(&a, &b);
        assert!(c.at(0, 0).is_nan(), "0 * NaN must propagate");
        let at = matmul_at_b(&a.transpose(), &b);
        assert!(at.at(0, 0).is_nan(), "at_b: 0 * NaN must propagate");
    }

    #[test]
    fn qdequant_kernels_bit_identical_to_scalar_oracles() {
        for (rows, k, n) in [(1usize, 3usize, 5usize), (4, 6, 9), (5, 11, 13)] {
            let x = hazard_mat(rows, k, 71);
            let q = hazard_mat(k, n, 72);
            let qt = hazard_mat(n, k, 73);
            let mut got = vec![0.1f32; rows * n];
            let mut want = got.clone();
            let mut scratch = Vec::new();
            matmul_qdequant_acc_into(x.data(), rows, k, &q, 1.7, &mut got, &mut scratch);
            scalar::matmul_qdequant_acc(x.data(), rows, k, &q, 1.7, &mut want);
            assert_bits_eq(&got, &want, &format!("qdequant {rows}x{k}x{n}"));
            let mut got_bt = vec![-0.2f32; rows * n];
            let mut want_bt = got_bt.clone();
            matmul_qdequant_bt_acc_into(x.data(), rows, k, &qt, 0.3, &mut got_bt, &mut scratch);
            scalar::matmul_qdequant_bt_acc(x.data(), rows, k, &qt, 0.3, &mut want_bt);
            assert_bits_eq(&got_bt, &want_bt, &format!("qdequant_bt {rows}x{k}x{n}"));
        }
    }

    #[test]
    fn qdequant_acc_accumulates_with_alpha() {
        let x = rand_mat(3, 4, 11);
        let q = rand_mat(4, 5, 12);
        let mut out = vec![1.0f32; 3 * 5];
        matmul_qdequant_acc(x.data(), 3, 4, &q, 2.0, &mut out);
        let expect = matmul(&x, &q);
        for i in 0..3 {
            for j in 0..5 {
                let got = out[i * 5 + j];
                let want = 1.0 + 2.0 * expect.at(i, j);
                assert!((got - want).abs() < 1e-5, "({i},{j}): {got} vs {want}");
            }
        }
    }
}
