//! Matrix products. Row-major, cache-blocked enough for LoRA-sized work.
//!
//! Two families:
//!
//! * dense × dense ([`matmul`], [`matmul_at_b`], [`matmul_a_bt`], [`outer`]);
//! * dense × **quantized** ([`matmul_qdequant_acc`],
//!   [`matmul_qdequant_bt_acc`]) — skinny GEMMs whose right operand stays
//!   packed: each stored row is unpacked + scaled once into an O(cols)
//!   scratch buffer and streamed through the product, so the dense matrix
//!   is never materialized. These are the factor-form serving kernels
//!   (DESIGN.md §8); anything implementing [`DequantRows`] can be the
//!   right operand.

use super::{dot, Matrix};

/// A matrix whose rows can be produced densely one at a time — the
/// contract between the packed quantized formats in `quant/` (and plain
/// [`Matrix`]) and the streaming GEMM kernels below.
pub trait DequantRows {
    /// Stored row count.
    fn src_rows(&self) -> usize;
    /// Stored column count.
    fn src_cols(&self) -> usize;
    /// Dequantize stored row `i` into `out` (`out.len() == src_cols()`).
    fn dequant_row_into(&self, i: usize, out: &mut [f32]);
}

impl DequantRows for Matrix {
    fn src_rows(&self) -> usize {
        self.rows()
    }

    fn src_cols(&self) -> usize {
        self.cols()
    }

    fn dequant_row_into(&self, i: usize, out: &mut [f32]) {
        out.copy_from_slice(self.row(i));
    }
}

/// `C = A @ B` (A: m×k, B: k×n).
///
/// i-k-j loop order: the inner j-loop streams one row of B and one row of C,
/// which autovectorizes and stays in L1 for LoRA-factor shapes.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul {:?} x {:?}", a.shape(), b.shape());
    let (m, k) = a.shape();
    let n = b.cols();
    let mut c = Matrix::zeros(m, n);
    let cdata = c.data_mut();
    for i in 0..m {
        let arow = a.row(i);
        let crow = &mut cdata[i * n..(i + 1) * n];
        for p in 0..k {
            let av = arow[p];
            if av == 0.0 {
                continue;
            }
            let brow = b.row(p);
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    c
}

/// `C = Aᵀ @ B` (A: k×m, B: k×n) without materializing the transpose.
pub fn matmul_at_b(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows(), b.rows(), "matmul_at_b {:?} x {:?}", a.shape(), b.shape());
    let (k, m) = a.shape();
    let n = b.cols();
    let mut c = Matrix::zeros(m, n);
    let cdata = c.data_mut();
    for p in 0..k {
        let arow = a.row(p);
        let brow = b.row(p);
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut cdata[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    c
}

/// `C = A @ Bᵀ` (A: m×k, B: n×k) — rows of both operands are contiguous,
/// so every inner product is a pair of streamed slices.
pub fn matmul_a_bt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.cols(), "matmul_a_bt {:?} x {:?}", a.shape(), b.shape());
    let m = a.rows();
    let n = b.rows();
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        let arow = a.row(i);
        for j in 0..n {
            c.set(i, j, dot(arow, b.row(j)));
        }
    }
    c
}

/// Outer product `u vᵀ` as an m×n matrix.
pub fn outer(u: &[f32], v: &[f32]) -> Matrix {
    let mut c = Matrix::zeros(u.len(), v.len());
    for (i, &ui) in u.iter().enumerate() {
        let row = c.row_mut(i);
        for (j, &vj) in v.iter().enumerate() {
            row[j] = ui * vj;
        }
    }
    c
}

/// `out += alpha · X @ deq(Q)` on flat row-major buffers
/// (X: rows×k, Q stored k×n, out: rows×n).
///
/// p-i-j loop order so each packed row of Q is dequantized exactly once
/// per call into an O(n) scratch buffer, then streamed against column p
/// of X — the full dense Q never exists.
pub fn matmul_qdequant_acc(
    x: &[f32],
    rows: usize,
    k: usize,
    q: &dyn DequantRows,
    alpha: f32,
    out: &mut [f32],
) {
    assert_eq!(q.src_rows(), k, "qdequant: Q has {} rows, X has {} cols", q.src_rows(), k);
    let n = q.src_cols();
    assert_eq!(x.len(), rows * k, "qdequant: X len {} != {}x{}", x.len(), rows, k);
    assert_eq!(out.len(), rows * n, "qdequant: out len {} != {}x{}", out.len(), rows, n);
    let mut qrow = vec![0.0f32; n];
    for p in 0..k {
        q.dequant_row_into(p, &mut qrow);
        for i in 0..rows {
            let av = alpha * x[i * k + p];
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * qrow[j];
            }
        }
    }
}

/// `out += alpha · X @ deq(Q)ᵀ` on flat row-major buffers
/// (X: rows×k, Q stored n×k, out: rows×n).
///
/// Each packed row of Q is dequantized once, then dotted with every row
/// of X (both contiguous), writing one output column.
pub fn matmul_qdequant_bt_acc(
    x: &[f32],
    rows: usize,
    k: usize,
    q: &dyn DequantRows,
    alpha: f32,
    out: &mut [f32],
) {
    assert_eq!(q.src_cols(), k, "qdequant_bt: Q has {} cols, X has {} cols", q.src_cols(), k);
    let n = q.src_rows();
    assert_eq!(x.len(), rows * k, "qdequant_bt: X len {} != {}x{}", x.len(), rows, k);
    assert_eq!(out.len(), rows * n, "qdequant_bt: out len {} != {}x{}", out.len(), rows, n);
    let mut qrow = vec![0.0f32; k];
    for j in 0..n {
        q.dequant_row_into(j, &mut qrow);
        for i in 0..rows {
            out[i * n + j] += alpha * dot(&x[i * k..(i + 1) * k], &qrow);
        }
    }
}

/// Matrix-shaped convenience over [`matmul_qdequant_acc`]:
/// `X @ deq(Q)` (X: m×k, Q stored k×n).
pub fn matmul_qdequant(x: &Matrix, q: &dyn DequantRows) -> Matrix {
    let mut out = Matrix::zeros(x.rows(), q.src_cols());
    let (rows, k) = x.shape();
    matmul_qdequant_acc(x.data(), rows, k, q, 1.0, out.data_mut());
    out
}

/// Matrix-shaped convenience over [`matmul_qdequant_bt_acc`]:
/// `X @ deq(Q)ᵀ` (X: m×k, Q stored n×k).
pub fn matmul_qdequant_bt(x: &Matrix, q: &dyn DequantRows) -> Matrix {
    let mut out = Matrix::zeros(x.rows(), q.src_rows());
    let (rows, k) = x.shape();
    matmul_qdequant_bt_acc(x.data(), rows, k, q, 1.0, out.data_mut());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for p in 0..a.cols() {
                    s += a.at(i, p) * b.at(p, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    fn rand_mat(r: usize, c: usize, seed: u64) -> Matrix {
        let mut s = seed.wrapping_mul(2862933555777941757).wrapping_add(3037000493);
        Matrix::from_fn(r, c, |_, _| {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            (s as f64 / u64::MAX as f64) as f32 - 0.5
        })
    }

    #[test]
    fn matmul_matches_naive() {
        let a = rand_mat(7, 11, 1);
        let b = rand_mat(11, 5, 2);
        let c = matmul(&a, &b);
        assert!(c.rel_err(&naive(&a, &b)) < 1e-5);
    }

    #[test]
    fn at_b_matches() {
        let a = rand_mat(9, 6, 3);
        let b = rand_mat(9, 4, 4);
        let c = matmul_at_b(&a, &b);
        assert!(c.rel_err(&naive(&a.transpose(), &b)) < 1e-5);
    }

    #[test]
    fn a_bt_matches() {
        let a = rand_mat(5, 8, 5);
        let b = rand_mat(6, 8, 6);
        let c = matmul_a_bt(&a, &b);
        assert!(c.rel_err(&naive(&a, &b.transpose())) < 1e-5);
    }

    #[test]
    fn outer_matches() {
        let u = vec![1.0, 2.0];
        let v = vec![3.0, 4.0, 5.0];
        let c = outer(&u, &v);
        assert_eq!(c.at(1, 2), 10.0);
        assert_eq!(c.shape(), (2, 3));
    }

    #[test]
    fn qdequant_with_dense_rows_matches_matmul() {
        // Matrix implements DequantRows, so the streaming kernel must
        // reproduce the dense product exactly.
        let x = rand_mat(5, 9, 7);
        let q = rand_mat(9, 6, 8);
        let c = matmul_qdequant(&x, &q);
        assert!(c.rel_err(&matmul(&x, &q)) < 1e-6);
    }

    #[test]
    fn qdequant_bt_with_dense_rows_matches_matmul() {
        let x = rand_mat(4, 7, 9);
        let q = rand_mat(5, 7, 10);
        let c = matmul_qdequant_bt(&x, &q);
        assert!(c.rel_err(&matmul(&x, &q.transpose())) < 1e-6);
    }

    #[test]
    fn qdequant_acc_accumulates_with_alpha() {
        let x = rand_mat(3, 4, 11);
        let q = rand_mat(4, 5, 12);
        let mut out = vec![1.0f32; 3 * 5];
        matmul_qdequant_acc(x.data(), 3, 4, &q, 2.0, &mut out);
        let expect = matmul(&x, &q);
        for i in 0..3 {
            for j in 0..5 {
                let got = out[i * 5 + j];
                let want = 1.0 + 2.0 * expect.at(i, j);
                assert!((got - want).abs() < 1e-5, "({i},{j}): {got} vs {want}");
            }
        }
    }
}
