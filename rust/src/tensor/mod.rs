//! Dense f32 matrix/vector substrate.
//!
//! The whole quantization stack works on small dense matrices (LoRA factors
//! are at most `max(d_model, d_ff) x rank`), so a simple row-major `Matrix`
//! with cache-friendly kernels is all we need — no BLAS available offline.

mod ops;
pub mod simd;

pub(crate) use ops::matmul_flat_rows;
pub use ops::scalar;
pub use ops::{
    matmul, matmul_a_bt, matmul_at_b, matmul_flat, matmul_flat_threaded, matmul_qdequant,
    matmul_qdequant_acc, matmul_qdequant_acc_into, matmul_qdequant_bt, matmul_qdequant_bt_acc,
    matmul_qdequant_bt_acc_into, outer, DequantRows,
};

/// Row-major dense f32 matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl std::fmt::Debug for Matrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)
    }
}

impl Matrix {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a row-major vec; panics if sizes mismatch.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape {}x{} vs len {}", rows, cols, data.len());
        Self { rows, cols, data }
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Immutable view of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable view of row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy of column `j`.
    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.set(j, i, self.at(i, j));
            }
        }
        t
    }

    /// Sub-matrix of columns `[c0, c1)`.
    pub fn slice_cols(&self, c0: usize, c1: usize) -> Matrix {
        assert!(c0 <= c1 && c1 <= self.cols);
        Matrix::from_fn(self.rows, c1 - c0, |i, j| self.at(i, c0 + j))
    }

    /// Sub-matrix of rows `[r0, r1)`.
    pub fn slice_rows(&self, r0: usize, r1: usize) -> Matrix {
        assert!(r0 <= r1 && r1 <= self.rows);
        let data = self.data[r0 * self.cols..r1 * self.cols].to_vec();
        Matrix::from_vec(r1 - r0, self.cols, data)
    }

    /// Select rows by index (gather).
    pub fn gather_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (k, &i) in idx.iter().enumerate() {
            out.row_mut(k).copy_from_slice(self.row(i));
        }
        out
    }

    /// Select columns by index (gather).
    pub fn gather_cols(&self, idx: &[usize]) -> Matrix {
        Matrix::from_fn(self.rows, idx.len(), |i, k| self.at(i, idx[k]))
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    /// Sum of absolute values.
    pub fn l1_norm(&self) -> f32 {
        self.data.iter().map(|v| v.abs()).sum()
    }

    /// Element-wise `self - other`.
    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Matrix::from_vec(self.rows, self.cols, data)
    }

    /// Element-wise `self + other`.
    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Matrix::from_vec(self.rows, self.cols, data)
    }

    /// In-place `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Scaled copy.
    pub fn scale(&self, alpha: f32) -> Matrix {
        let data = self.data.iter().map(|v| v * alpha).collect();
        Matrix::from_vec(self.rows, self.cols, data)
    }

    /// Relative Frobenius error `||self - other||_F / max(||other||_F, eps)`.
    pub fn rel_err(&self, other: &Matrix) -> f32 {
        self.sub(other).fro_norm() / other.fro_norm().max(1e-12)
    }

    /// Horizontal concatenation `[self | other]`.
    pub fn hcat(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows);
        Matrix::from_fn(self.rows, self.cols + other.cols, |i, j| {
            if j < self.cols { self.at(i, j) } else { other.at(i, j - self.cols) }
        })
    }

    /// Vertical concatenation.
    pub fn vcat(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Matrix::from_vec(self.rows + other.rows, self.cols, data)
    }

    /// Max absolute element.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, v| m.max(v.abs()))
    }
}

/// Dot product of two slices — the canonical 8-lane split-accumulator
/// order ([`simd::dot8`]). Attention scores, `matmul_a_bt`, and the
/// `qdequant_bt` kernel all reduce in exactly this order; changing it
/// changes bits everywhere (see DESIGN.md §10 on the PR-6 re-bless).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    simd::dot8(a, b)
}

/// Euclidean norm of a slice.
pub fn norm2(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let m = Matrix::from_fn(2, 3, |i, j| (i * 3 + j) as f32);
        assert_eq!(m.shape(), (2, 3));
        assert_eq!(m.at(1, 2), 5.0);
        assert_eq!(m.row(1), &[3.0, 4.0, 5.0]);
        assert_eq!(m.col(2), vec![2.0, 5.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Matrix::from_fn(3, 5, |i, j| (i * 7 + j * 3) as f32);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn slices() {
        let m = Matrix::from_fn(4, 4, |i, j| (i * 4 + j) as f32);
        let c = m.slice_cols(1, 3);
        assert_eq!(c.shape(), (4, 2));
        assert_eq!(c.at(2, 0), m.at(2, 1));
        let r = m.slice_rows(2, 4);
        assert_eq!(r.shape(), (2, 4));
        assert_eq!(r.at(0, 3), m.at(2, 3));
    }

    #[test]
    fn norms_and_arith() {
        let a = Matrix::from_vec(1, 3, vec![3.0, 0.0, 4.0]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-6);
        assert!((a.l1_norm() - 7.0).abs() < 1e-6);
        let b = a.scale(2.0);
        assert_eq!(b.data(), &[6.0, 0.0, 8.0]);
        assert_eq!(b.sub(&a).data(), &[3.0, 0.0, 4.0]);
    }

    #[test]
    fn cat() {
        let a = Matrix::eye(2);
        let b = Matrix::zeros(2, 1);
        let h = a.hcat(&b);
        assert_eq!(h.shape(), (2, 3));
        let v = a.vcat(&Matrix::eye(2));
        assert_eq!(v.shape(), (4, 2));
        assert_eq!(v.at(3, 1), 1.0);
    }

    #[test]
    fn gather() {
        let m = Matrix::from_fn(3, 3, |i, j| (i * 3 + j) as f32);
        let g = m.gather_rows(&[2, 0]);
        assert_eq!(g.row(0), m.row(2));
        let gc = m.gather_cols(&[1]);
        assert_eq!(gc.col(0), m.col(1));
    }

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..37).map(|i| i as f32 * 0.1).collect();
        let b: Vec<f32> = (0..37).map(|i| (36 - i) as f32 * 0.2).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-3);
    }

    #[test]
    fn dot_is_the_canonical_scalar_order_bitwise() {
        let a: Vec<f32> = (0..37).map(|i| i as f32 * 0.1).collect();
        let b: Vec<f32> = (0..37).map(|i| (36 - i) as f32 * 0.2).collect();
        assert_eq!(dot(&a, &b).to_bits(), scalar::dot(&a, &b).to_bits());
    }
}
