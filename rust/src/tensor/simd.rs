//! Dependency-free f32x8 helpers: manual 8-wide unrolls the compiler can
//! lower to SIMD (like the vendored `anyhow`, this pulls in nothing).
//!
//! # Canonical reduction orders
//!
//! Every kernel in `tensor/ops.rs` (and the attention loops in
//! `runtime/sim.rs`) reduces in one of exactly two orders, both fixed
//! here so that blocking, unrolling, and thread count can never change a
//! single output bit:
//!
//! * **axpy family** (`c[j] += a_p * b_p[j]`, accumulated over `p`): each
//!   output element is one sequential chain of adds in ascending `p`.
//!   [`axpy`] unrolls the `j` loop 8-wide — `j` lanes are independent, so
//!   unrolling them changes nothing — and [`axpy4`] register-blocks four
//!   `p` steps while still issuing one add per element per step, in `p`
//!   order. Both are therefore bit-identical to the naive two-loop form.
//! * **dot family** ([`dot8`]): 8 split accumulators with `lane = i % 8`,
//!   combined by the fixed pairwise tree
//!   `((s0+s1)+(s2+s3)) + ((s4+s5)+(s6+s7))`, then tail elements
//!   (`i >= 8*(len/8)`) added sequentially. This *is* the definition of
//!   the dot product here — the scalar oracle
//!   (`tensor::scalar::dot`) implements the same order naively.
//!
//! Products are written `c + a * b` (separate mul + add, never a fused
//! FMA): rustc without fast-math keeps that exact, so results are
//! reproducible across platforms regardless of FMA hardware.

/// Lane width all kernels block against.
pub const LANES: usize = 8;

/// Canonical dot product: 8 split accumulators (`lane = i % 8`), fixed
/// pairwise combine, sequential tail. See the module docs.
#[inline]
pub fn dot8(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let full = a.len() / LANES * LANES;
    let mut acc = [0.0f32; LANES];
    let (ah, at) = a.split_at(full);
    let (bh, bt) = b.split_at(full);
    for (av, bv) in ah.chunks_exact(LANES).zip(bh.chunks_exact(LANES)) {
        acc[0] += av[0] * bv[0];
        acc[1] += av[1] * bv[1];
        acc[2] += av[2] * bv[2];
        acc[3] += av[3] * bv[3];
        acc[4] += av[4] * bv[4];
        acc[5] += av[5] * bv[5];
        acc[6] += av[6] * bv[6];
        acc[7] += av[7] * bv[7];
    }
    let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for (av, bv) in at.iter().zip(bt) {
        s += av * bv;
    }
    s
}

/// `c[j] += av * b[j]`, 8-wide unrolled. One add per element, so the
/// per-element reduction order is whatever order the caller issues its
/// `axpy` calls in — bit-identical to the naive `for j` loop.
#[inline]
pub fn axpy(c: &mut [f32], av: f32, b: &[f32]) {
    debug_assert_eq!(c.len(), b.len());
    let full = c.len() / LANES * LANES;
    let (ch, ct) = c.split_at_mut(full);
    let (bh, bt) = b.split_at(full);
    for (cv, bv) in ch.chunks_exact_mut(LANES).zip(bh.chunks_exact(LANES)) {
        cv[0] += av * bv[0];
        cv[1] += av * bv[1];
        cv[2] += av * bv[2];
        cv[3] += av * bv[3];
        cv[4] += av * bv[4];
        cv[5] += av * bv[5];
        cv[6] += av * bv[6];
        cv[7] += av * bv[7];
    }
    for (cv, bv) in ct.iter_mut().zip(bt) {
        *cv += av * bv;
    }
}

/// Register-blocked 4-step panel: bit-identical to
/// `axpy(c, a[0], b0); axpy(c, a[1], b1); axpy(c, a[2], b2);
/// axpy(c, a[3], b3)` — per output element the four adds land
/// sequentially in `p` order — but blocked 8 columns at a time so the
/// output tile stays in registers across all four steps.
#[inline]
pub fn axpy4(c: &mut [f32], a: [f32; 4], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) {
    let n = c.len();
    debug_assert!(b0.len() == n && b1.len() == n && b2.len() == n && b3.len() == n);
    let full = n / LANES * LANES;
    let mut o = 0;
    while o < full {
        let ct = &mut c[o..o + LANES];
        let (t0, t1, t2, t3) =
            (&b0[o..o + LANES], &b1[o..o + LANES], &b2[o..o + LANES], &b3[o..o + LANES]);
        let mut j = 0;
        while j < LANES {
            let mut v = ct[j];
            v += a[0] * t0[j];
            v += a[1] * t1[j];
            v += a[2] * t2[j];
            v += a[3] * t3[j];
            ct[j] = v;
            j += 1;
        }
        o += LANES;
    }
    while o < n {
        let mut v = c[o];
        v += a[0] * b0[o];
        v += a[1] * b1[o];
        v += a[2] * b2[o];
        v += a[3] * b3[o];
        c[o] = v;
        o += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(n: usize, seed: u64) -> Vec<f32> {
        let mut s = seed | 1;
        (0..n)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                (s as f64 / u64::MAX as f64) as f32 - 0.5
            })
            .collect()
    }

    /// The canonical order written naively — this is the oracle the
    /// unrolled body must match bit for bit.
    fn dot_naive_canonical(a: &[f32], b: &[f32]) -> f32 {
        let full = a.len() / LANES * LANES;
        let mut acc = [0.0f32; LANES];
        for i in 0..full {
            acc[i % LANES] += a[i] * b[i];
        }
        let mut s =
            ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
        for i in full..a.len() {
            s += a[i] * b[i];
        }
        s
    }

    #[test]
    fn dot8_matches_canonical_order_bitwise() {
        for n in [0usize, 1, 7, 8, 9, 15, 16, 17, 64, 100] {
            let a = seq(n, 3 + n as u64);
            let b = seq(n, 5 + n as u64);
            assert_eq!(
                dot8(&a, &b).to_bits(),
                dot_naive_canonical(&a, &b).to_bits(),
                "n={n}"
            );
        }
    }

    #[test]
    fn dot8_propagates_nan() {
        let mut a = seq(20, 7);
        let b = seq(20, 9);
        a[13] = f32::NAN;
        assert!(dot8(&a, &b).is_nan());
    }

    #[test]
    fn axpy_matches_naive_bitwise() {
        for n in [0usize, 1, 7, 8, 9, 23, 64, 81] {
            let b = seq(n, 11 + n as u64);
            let mut c = seq(n, 13 + n as u64);
            let mut naive = c.clone();
            axpy(&mut c, 0.37, &b);
            for j in 0..n {
                naive[j] += 0.37 * b[j];
            }
            assert_eq!(c, naive, "n={n}");
        }
    }

    #[test]
    fn axpy4_is_four_sequential_axpys() {
        for n in [1usize, 5, 8, 13, 40] {
            let rows: Vec<Vec<f32>> = (0..4).map(|p| seq(n, 17 + p as u64)).collect();
            let a = [0.9f32, -0.4, 0.05, 2.5];
            let mut blocked = seq(n, 23);
            let mut serial = blocked.clone();
            axpy4(&mut blocked, a, &rows[0], &rows[1], &rows[2], &rows[3]);
            for p in 0..4 {
                axpy(&mut serial, a[p], &rows[p]);
            }
            assert_eq!(blocked, serial, "n={n}");
        }
    }

    #[test]
    fn axpy_does_not_skip_zero_scalars() {
        // 0 * NaN must poison the output — the old kernels' `av == 0.0`
        // skip-branch silently dropped this.
        let b = vec![f32::NAN, 1.0, f32::INFINITY];
        let mut c = vec![1.0f32, 2.0, 3.0];
        axpy(&mut c, 0.0, &b);
        assert!(c[0].is_nan());
        assert!(c[2].is_nan()); // 0 * inf = NaN
        assert_eq!(c[1], 2.0);
    }
}
