//! Key-value configuration files (serde is unavailable offline, so the
//! format is a minimal, typed `key = value` dialect with `#` comments and
//! `[section]` headers flattened to `section.key`).
//!
//! ```text
//! # serving config
//! model = tiny-llama-s
//! [batcher]
//! bucket = 8
//! max_wait_ms = 10
//! ```

use anyhow::{bail, Context};
use std::collections::BTreeMap;
use std::path::Path;
use std::time::Duration;

/// Parsed configuration: flattened dotted keys → raw string values.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    /// Parse from text.
    pub fn parse(text: &str) -> anyhow::Result<Self> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(s) = line.strip_prefix('[') {
                let s = s.strip_suffix(']').with_context(|| format!("line {}: bad section", lineno + 1))?;
                section = s.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            if values.insert(key.clone(), v.trim().to_string()).is_some() {
                bail!("line {}: duplicate key {key}", lineno + 1);
            }
        }
        Ok(Self { values })
    }

    /// Load from a file.
    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    /// Merge CLI-style `key=value` overrides on top.
    pub fn with_overrides(mut self, overrides: &[(String, String)]) -> Self {
        for (k, v) in overrides {
            self.values.insert(k.clone(), v.clone());
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("{key}: bad usize '{v}'")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("{key}: bad float '{v}'")),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> anyhow::Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true" | "1" | "yes") => Ok(true),
            Some("false" | "0" | "no") => Ok(false),
            Some(v) => bail!("{key}: bad bool '{v}'"),
        }
    }

    pub fn duration_ms_or(&self, key: &str, default_ms: u64) -> anyhow::Result<Duration> {
        Ok(Duration::from_millis(self.usize_or(key, default_ms as usize)? as u64))
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_comments_types() {
        let cfg = Config::parse(
            "# top\nmodel = tiny-llama-s\n[batcher]\nbucket = 8 # inline\nmax_wait_ms = 10\n[flags]\nfast = true\n",
        )
        .unwrap();
        assert_eq!(cfg.str_or("model", ""), "tiny-llama-s");
        assert_eq!(cfg.usize_or("batcher.bucket", 0).unwrap(), 8);
        assert_eq!(cfg.duration_ms_or("batcher.max_wait_ms", 0).unwrap(), Duration::from_millis(10));
        assert!(cfg.bool_or("flags.fast", false).unwrap());
        assert_eq!(cfg.usize_or("missing", 42).unwrap(), 42);
    }

    #[test]
    fn rejects_duplicates_and_garbage() {
        assert!(Config::parse("a = 1\na = 2").is_err());
        assert!(Config::parse("no equals sign").is_err());
        assert!(Config::parse("[unclosed").is_err());
    }

    #[test]
    fn overrides_win() {
        let cfg = Config::parse("a = 1").unwrap().with_overrides(&[("a".into(), "2".into())]);
        assert_eq!(cfg.usize_or("a", 0).unwrap(), 2);
    }

    #[test]
    fn bad_typed_values_error() {
        let cfg = Config::parse("x = notanum").unwrap();
        assert!(cfg.usize_or("x", 0).is_err());
        assert!(cfg.bool_or("x", false).is_err());
    }
}
