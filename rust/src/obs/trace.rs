//! Request-lifecycle span recording on the shared [`Clock`] timeline
//! (DESIGN.md §16).
//!
//! ## Determinism contract
//!
//! Every span carries offsets from a fixed trace **origin**, stamped
//! from the pool's [`crate::clock::Clock`]. Under the virtual clock the
//! scenario driver only advances time at quiescence barriers, so every
//! worker and merge thread reads a *frozen* clock between advances: the
//! timestamp a span gets is a function of the schedule, never of thread
//! interleaving. Span identity is logical — request tag and adapter id,
//! never a worker index or OS thread id (routing changes with the
//! worker count; thread ids change run to run). Draining canonically
//! sorts the per-thread ring buffers with the same discipline as
//! `scenario/events.rs`, so the exported trace is **byte-identical
//! across runs, compute-thread counts, and worker counts**.
//!
//! ## Stage accounting
//!
//! [`StageTrack`] attributes a request's lifetime to stages by
//! boundary differencing: each transition adds `now − last_boundary`
//! to the stage being left, and retirement attributes the tail to the
//! terminal stage. The resulting [`StageBreakdown`] therefore
//! telescopes — `queued + merge_wait + fetch_wait + prefill + decode
//! == e2e` holds *by construction*, on any clock, faulted or not.
//! Exported stage spans are synthesized from the cumulative breakdown
//! as one contiguous run per visited stage (in pipeline order: queued,
//! fetch, merge, prefill, decode); the breakdown is the source of
//! truth, the spans visualize it.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Lifecycle stage of a request inside the serving pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum Stage {
    /// Admission-queued behind the dynamic batcher's release deadline.
    #[default]
    Queued = 0,
    /// Parked behind a dequant+merge job on the merge pool.
    MergeWait = 1,
    /// Parked behind a disk-tier factor fetch (incl. retries/backoff).
    FetchWait = 2,
    /// Prompt prefill (admission passes, chunked or monolithic).
    Prefill = 3,
    /// Decoding on a live lane (first token → retirement).
    Decode = 4,
}

/// All stages, in `StageBreakdown` accounting order.
pub const STAGES: [Stage; 5] =
    [Stage::Queued, Stage::MergeWait, Stage::FetchWait, Stage::Prefill, Stage::Decode];

/// Stage-span synthesis order: the tiered pipeline fetches factors
/// before it merges, so exported timelines read
/// queued → fetch → merge → prefill → decode.
const SYNTH_ORDER: [Stage; 5] =
    [Stage::Queued, Stage::FetchWait, Stage::MergeWait, Stage::Prefill, Stage::Decode];

impl Stage {
    /// Span name in the exported Chrome trace (the DESIGN.md §16
    /// taxonomy).
    pub fn span_name(self) -> &'static str {
        match self {
            Stage::Queued => "Queued",
            Stage::MergeWait => "MergeWait",
            Stage::FetchWait => "FetchWait",
            Stage::Prefill => "PrefillChunk",
            Stage::Decode => "DecodeActive",
        }
    }

    /// Kebab-case label for metrics and reports.
    pub fn label(self) -> &'static str {
        match self {
            Stage::Queued => "queued",
            Stage::MergeWait => "merge-wait",
            Stage::FetchWait => "fetch-wait",
            Stage::Prefill => "prefill",
            Stage::Decode => "decode",
        }
    }
}

impl std::fmt::Display for Stage {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Cumulative per-stage durations of one retired request. Telescoping
/// (see the module docs): [`Self::sum`] equals the end-to-end latency
/// exactly, so these exact durations — not the bucketed
/// [`crate::coordinator::Histogram`] — are the source of truth for
/// assertions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct StageBreakdown {
    pub queued: Duration,
    pub merge_wait: Duration,
    pub fetch_wait: Duration,
    pub prefill: Duration,
    pub decode: Duration,
    /// Stage the request was in when it retired. For failures this
    /// names where the [`crate::coordinator::FailKind`] struck (a
    /// queued timeout retires in `Queued`, a mid-decode cancel in
    /// `Decode`, a merge-panic casualty in `MergeWait`, …).
    pub terminal: Stage,
}

impl StageBreakdown {
    pub fn get(&self, s: Stage) -> Duration {
        match s {
            Stage::Queued => self.queued,
            Stage::MergeWait => self.merge_wait,
            Stage::FetchWait => self.fetch_wait,
            Stage::Prefill => self.prefill,
            Stage::Decode => self.decode,
        }
    }

    fn get_mut(&mut self, s: Stage) -> &mut Duration {
        match s {
            Stage::Queued => &mut self.queued,
            Stage::MergeWait => &mut self.merge_wait,
            Stage::FetchWait => &mut self.fetch_wait,
            Stage::Prefill => &mut self.prefill,
            Stage::Decode => &mut self.decode,
        }
    }

    /// Σ stages — equals the request's end-to-end latency exactly.
    pub fn sum(&self) -> Duration {
        self.queued + self.merge_wait + self.fetch_wait + self.prefill + self.decode
    }
}

/// Boundary-differencing stage accounting for one in-flight request.
///
/// Created at admission; [`Self::advance`]d at every stage transition;
/// consumed by [`Self::finish`] at retirement. Monotone inputs only
/// (all instants come from one `Clock`), but every subtraction
/// saturates so a pathological timeline degrades to zero rather than
/// panicking.
#[derive(Debug, Clone)]
pub struct StageTrack {
    started: Instant,
    last: Instant,
    current: Stage,
    acc: StageBreakdown,
}

impl StageTrack {
    /// Start tracking at admission time (stage = `Queued`).
    pub fn begin(now: Instant) -> Self {
        Self { started: now, last: now, current: Stage::Queued, acc: StageBreakdown::default() }
    }

    /// The stage the request is currently in.
    pub fn current(&self) -> Stage {
        self.current
    }

    /// Admission instant (the `e2e` epoch).
    pub fn started(&self) -> Instant {
        self.started
    }

    /// Leave the current stage at `now`, attributing the elapsed time
    /// to it, and enter `next`.
    pub fn advance(&mut self, now: Instant, next: Stage) {
        *self.acc.get_mut(self.current) += now.saturating_duration_since(self.last);
        self.last = now;
        self.current = next;
    }

    /// Retire at `now`: the tail is attributed to the current stage,
    /// which becomes the breakdown's `terminal`.
    pub fn finish(mut self, now: Instant) -> StageBreakdown {
        *self.acc.get_mut(self.current) += now.saturating_duration_since(self.last);
        self.acc.terminal = self.current;
        self.acc
    }
}

/// What a span describes. Identity is logical (request tag, adapter
/// id) — see the module docs' determinism contract.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SpanKind {
    /// One lifecycle stage of a request (synthesized at retirement).
    Stage { req: u64, adapter: u64, stage: Stage },
    /// Terminal marker: the request retired with a response.
    Retired { req: u64, adapter: u64 },
    /// Terminal marker: the request failed; `kind` is the
    /// [`crate::coordinator::FailKind`] kebab-case name.
    Failed { req: u64, adapter: u64, kind: String },
    /// A dequant+merge job on the merge pool (`ok = false`: the job
    /// panicked or errored; containment is the pool's problem).
    MergeJob { adapter: u64, ok: bool },
    /// A disk-tier factor fetch on the merge pool (one span covers the
    /// whole retry/backoff loop).
    FetchJob { adapter: u64, ok: bool },
}

impl SpanKind {
    /// Canonical same-instant ordering rank (cf.
    /// `scenario::EventKind::rank`).
    fn rank(&self) -> u8 {
        match self {
            SpanKind::Stage { .. } => 0,
            SpanKind::Retired { .. } => 1,
            SpanKind::Failed { .. } => 2,
            SpanKind::MergeJob { .. } => 3,
            SpanKind::FetchJob { .. } => 4,
        }
    }

    fn adapter(&self) -> u64 {
        match *self {
            SpanKind::Stage { adapter, .. }
            | SpanKind::Retired { adapter, .. }
            | SpanKind::Failed { adapter, .. }
            | SpanKind::MergeJob { adapter, .. }
            | SpanKind::FetchJob { adapter, .. } => adapter,
        }
    }

    fn req(&self) -> u64 {
        match *self {
            SpanKind::Stage { req, .. }
            | SpanKind::Retired { req, .. }
            | SpanKind::Failed { req, .. } => req,
            SpanKind::MergeJob { .. } | SpanKind::FetchJob { .. } => 0,
        }
    }

    fn detail(&self) -> u8 {
        match *self {
            SpanKind::Stage { stage, .. } => stage as u8,
            SpanKind::MergeJob { ok, .. } | SpanKind::FetchJob { ok, .. } => u8::from(ok),
            _ => 0,
        }
    }

    fn fail_kind(&self) -> &str {
        match self {
            SpanKind::Failed { kind, .. } => kind,
            _ => "",
        }
    }
}

/// One recorded span: `[t0, t1]` offsets from the trace origin.
/// Instant markers have `t0 == t1`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Span {
    pub t0: Duration,
    pub t1: Duration,
    pub kind: SpanKind,
}

/// Canonical total order: `(t0, kind rank, adapter, req, detail, t1)`,
/// then the failure-kind string. Any remaining ties are identical
/// spans, so the order is schedule-deterministic.
pub fn sort_spans(spans: &mut [Span]) {
    spans.sort_by(|a, b| {
        let ka = (a.t0, a.kind.rank(), a.kind.adapter(), a.kind.req(), a.kind.detail(), a.t1);
        let kb = (b.t0, b.kind.rank(), b.kind.adapter(), b.kind.req(), b.kind.detail(), b.t1);
        ka.cmp(&kb).then_with(|| a.kind.fail_kind().cmp(b.kind.fail_kind()))
    });
}

struct RecorderInner {
    origin: Instant,
    /// Ring-buffer capacity per shard; the oldest span is dropped (and
    /// counted) on overflow so recording never blocks or allocates
    /// unboundedly.
    cap: usize,
    shards: Mutex<Vec<Arc<Mutex<VecDeque<Span>>>>>,
    dropped: AtomicU64,
}

/// A cloneable span recorder. Each recording thread takes its own
/// [`TraceHandle`] (one ring-buffer shard, one mutex nobody else
/// touches on the hot path); [`Self::drain`] collects and canonically
/// sorts all shards at a quiescence barrier.
#[derive(Clone)]
pub struct TraceRecorder {
    inner: Arc<RecorderInner>,
}

// `CoordinatorConfig`/`WorkerConfig` derive Debug; the shard contents
// are noise, so render opaquely like `MergeHook`.
impl std::fmt::Debug for TraceRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("TraceRecorder(..)")
    }
}

impl TraceRecorder {
    /// Default per-thread ring capacity: ~6 spans per request means
    /// this absorbs >10k retirements per thread between drains.
    pub const DEFAULT_CAP: usize = 1 << 16;

    /// A recorder whose spans are offsets from `origin` (the scenario
    /// trace start, or pool startup for a live server).
    pub fn new(origin: Instant, cap_per_thread: usize) -> Self {
        Self {
            inner: Arc::new(RecorderInner {
                origin,
                cap: cap_per_thread.max(1),
                shards: Mutex::new(Vec::new()),
                dropped: AtomicU64::new(0),
            }),
        }
    }

    pub fn origin(&self) -> Instant {
        self.inner.origin
    }

    /// Register a fresh per-thread shard. Call once per recording
    /// thread (workers call this at thread start, so a respawned
    /// phoenix thread gets its own shard too).
    pub fn handle(&self) -> TraceHandle {
        let shard = Arc::new(Mutex::new(VecDeque::new()));
        self.inner
            .shards
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push(Arc::clone(&shard));
        TraceHandle { inner: Arc::clone(&self.inner), shard }
    }

    /// Drain every shard and return the canonically-sorted spans. Only
    /// deterministic when the pool is quiescent (the scenario driver
    /// drains after its final metrics barrier).
    pub fn drain(&self) -> Vec<Span> {
        let shards = self.inner.shards.lock().unwrap_or_else(|e| e.into_inner());
        let mut out = Vec::new();
        for shard in shards.iter() {
            let mut buf = shard.lock().unwrap_or_else(|e| e.into_inner());
            out.extend(buf.drain(..));
        }
        drop(shards);
        sort_spans(&mut out);
        out
    }

    /// Spans discarded to ring overflow since construction.
    pub fn dropped(&self) -> u64 {
        self.inner.dropped.load(Ordering::Relaxed)
    }
}

/// One thread's recording endpoint (see [`TraceRecorder::handle`]).
pub struct TraceHandle {
    inner: Arc<RecorderInner>,
    shard: Arc<Mutex<VecDeque<Span>>>,
}

impl TraceHandle {
    /// Record a `[t0, t1]` span; instants convert to origin offsets
    /// here (clamping below the origin, and `t1` below `t0`, to zero
    /// length rather than panicking).
    pub fn span(&self, t0: Instant, t1: Instant, kind: SpanKind) {
        let a = t0.saturating_duration_since(self.inner.origin);
        let b = t1.saturating_duration_since(self.inner.origin).max(a);
        self.push(Span { t0: a, t1: b, kind });
    }

    /// Record an instant marker.
    pub fn instant(&self, t: Instant, kind: SpanKind) {
        self.span(t, t, kind);
    }

    /// Emit one retired request's synthesized stage timeline: a
    /// contiguous run per visited (non-zero) stage in pipeline order
    /// from `start`, plus the terminal `Retired`/`Failed` marker.
    pub fn record_request(
        &self,
        req: u64,
        adapter: u64,
        start: Instant,
        b: &StageBreakdown,
        failed: Option<&str>,
    ) {
        let mut cursor = start;
        for stage in SYNTH_ORDER {
            let d = b.get(stage);
            if d.is_zero() {
                continue;
            }
            let end = cursor + d;
            self.span(cursor, end, SpanKind::Stage { req, adapter, stage });
            cursor = end;
        }
        let kind = match failed {
            Some(k) => SpanKind::Failed { req, adapter, kind: k.to_string() },
            None => SpanKind::Retired { req, adapter },
        };
        self.instant(cursor, kind);
    }

    fn push(&self, s: Span) {
        let mut buf = self.shard.lock().unwrap_or_else(|e| e.into_inner());
        if buf.len() >= self.inner.cap {
            buf.pop_front();
            self.inner.dropped.fetch_add(1, Ordering::Relaxed);
        }
        buf.push_back(s);
    }
}

// ---- Chrome trace-event export -----------------------------------------

/// Microseconds with nanosecond decimals — Chrome's `ts`/`dur` unit is
/// µs and accepts fractional values, so nothing is truncated.
fn us(d: Duration) -> String {
    let ns = d.as_nanos();
    format!("{}.{:03}", ns / 1_000, ns % 1_000)
}

fn event_json(s: &Span) -> String {
    let dur = us(s.t1.saturating_sub(s.t0));
    let ts = us(s.t0);
    match &s.kind {
        SpanKind::Stage { req, adapter, stage } => format!(
            "{{\"name\":\"{}\",\"cat\":\"request\",\"ph\":\"X\",\"pid\":0,\"tid\":{req},\
             \"ts\":{ts},\"dur\":{dur},\"args\":{{\"adapter\":{adapter},\"req\":{req}}}}}",
            stage.span_name()
        ),
        SpanKind::Retired { req, adapter } => format!(
            "{{\"name\":\"Retired\",\"cat\":\"request\",\"ph\":\"i\",\"s\":\"t\",\"pid\":0,\
             \"tid\":{req},\"ts\":{ts},\"args\":{{\"adapter\":{adapter},\"req\":{req}}}}}"
        ),
        SpanKind::Failed { req, adapter, kind } => format!(
            "{{\"name\":\"Failed:{kind}\",\"cat\":\"request\",\"ph\":\"i\",\"s\":\"t\",\
             \"pid\":0,\"tid\":{req},\"ts\":{ts},\
             \"args\":{{\"adapter\":{adapter},\"req\":{req}}}}}"
        ),
        SpanKind::MergeJob { adapter, ok } => format!(
            "{{\"name\":\"MergeJob\",\"cat\":\"merge\",\"ph\":\"X\",\"pid\":0,\
             \"tid\":{},\"ts\":{ts},\"dur\":{dur},\
             \"args\":{{\"adapter\":{adapter},\"ok\":{ok}}}}}",
            JOB_TID_BASE + adapter
        ),
        SpanKind::FetchJob { adapter, ok } => format!(
            "{{\"name\":\"FetchJob\",\"cat\":\"fetch\",\"ph\":\"X\",\"pid\":0,\
             \"tid\":{},\"ts\":{ts},\"dur\":{dur},\
             \"args\":{{\"adapter\":{adapter},\"ok\":{ok}}}}}",
            JOB_TID_BASE + adapter
        ),
    }
}

/// Request tracks use `tid = req`; merge-pool job tracks live above
/// this base at `tid = JOB_TID_BASE + adapter`. Both are logical ids,
/// so the layout (and the bytes) are identical at any worker count.
const JOB_TID_BASE: u64 = 1_000_000;

/// Render canonically-sorted spans as Chrome trace-event JSON
/// (`chrome://tracing` / Perfetto's legacy loader). One event per
/// line; `ts`/`dur` in fractional microseconds.
pub fn chrome_trace_json(spans: &[Span]) -> String {
    let mut out = String::with_capacity(32 + spans.len() * 160);
    out.push_str("{\"traceEvents\":[");
    for (i, s) in spans.iter().enumerate() {
        out.push_str(if i == 0 { "\n" } else { ",\n" });
        out.push_str(&event_json(s));
    }
    out.push_str("\n]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(base: Instant, ms: u64) -> Instant {
        base + Duration::from_millis(ms)
    }

    #[test]
    fn stage_track_telescopes_exactly() {
        let base = Instant::now();
        let mut track = StageTrack::begin(base);
        track.advance(t(base, 3), Stage::FetchWait);
        track.advance(t(base, 10), Stage::MergeWait);
        track.advance(t(base, 11), Stage::Prefill);
        track.advance(t(base, 11), Stage::Decode);
        let b = track.finish(t(base, 25));
        assert_eq!(b.queued, Duration::from_millis(3));
        assert_eq!(b.fetch_wait, Duration::from_millis(7));
        assert_eq!(b.merge_wait, Duration::from_millis(1));
        assert_eq!(b.prefill, Duration::ZERO);
        assert_eq!(b.decode, Duration::from_millis(14));
        assert_eq!(b.terminal, Stage::Decode);
        // The invariant the scenario driver asserts per request.
        assert_eq!(b.sum(), Duration::from_millis(25));
    }

    #[test]
    fn stage_track_tail_goes_to_terminal_stage() {
        let base = Instant::now();
        let track = StageTrack::begin(base);
        let b = track.finish(t(base, 5));
        assert_eq!(b.terminal, Stage::Queued);
        assert_eq!(b.queued, Duration::from_millis(5));
        assert_eq!(b.sum(), Duration::from_millis(5));
    }

    #[test]
    fn ring_buffer_drops_oldest_and_counts() {
        let base = Instant::now();
        let rec = TraceRecorder::new(base, 2);
        let h = rec.handle();
        for i in 0..5u64 {
            h.instant(t(base, i), SpanKind::Retired { req: i, adapter: 0 });
        }
        assert_eq!(rec.dropped(), 3);
        let spans = rec.drain();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].t0, Duration::from_millis(3));
        assert_eq!(rec.drain().len(), 0, "drain must empty the shards");
    }

    #[test]
    fn drain_is_canonical_across_shards() {
        let base = Instant::now();
        let rec = TraceRecorder::new(base, 64);
        let h1 = rec.handle();
        let h2 = rec.handle();
        // Same spans pushed to different shards in different orders
        // must drain identically.
        h1.instant(t(base, 2), SpanKind::Retired { req: 1, adapter: 0 });
        h2.span(t(base, 1), t(base, 2), SpanKind::MergeJob { adapter: 0, ok: true });
        h2.instant(t(base, 1), SpanKind::Failed { req: 0, adapter: 1, kind: "timeout".into() });
        let a = rec.drain();
        let h1 = rec.handle();
        let h2 = rec.handle();
        h1.instant(t(base, 1), SpanKind::Failed { req: 0, adapter: 1, kind: "timeout".into() });
        h1.instant(t(base, 2), SpanKind::Retired { req: 1, adapter: 0 });
        h2.span(t(base, 1), t(base, 2), SpanKind::MergeJob { adapter: 0, ok: true });
        let b = rec.drain();
        assert_eq!(a, b);
        assert_eq!(chrome_trace_json(&a), chrome_trace_json(&b));
    }

    #[test]
    fn record_request_synthesizes_contiguous_spans() {
        let base = Instant::now();
        let rec = TraceRecorder::new(base, 64);
        let h = rec.handle();
        let b = StageBreakdown {
            queued: Duration::from_millis(2),
            fetch_wait: Duration::from_millis(3),
            merge_wait: Duration::ZERO,
            prefill: Duration::from_millis(1),
            decode: Duration::from_millis(4),
            terminal: Stage::Decode,
        };
        h.record_request(7, 3, base, &b, None);
        let spans = rec.drain();
        // queued, fetch-wait, prefill, decode (merge-wait skipped), + marker
        assert_eq!(spans.len(), 5);
        let mut cursor = Duration::ZERO;
        for s in spans.iter().take(4) {
            assert_eq!(s.t0, cursor, "stage spans must be contiguous");
            cursor = s.t1;
        }
        assert_eq!(cursor, b.sum());
        assert!(matches!(spans[4].kind, SpanKind::Retired { req: 7, adapter: 3 }));
        assert_eq!(spans[4].t0, b.sum());
    }

    #[test]
    fn chrome_json_shape() {
        let base = Instant::now();
        let rec = TraceRecorder::new(base, 64);
        let h = rec.handle();
        h.span(
            base,
            base + Duration::from_nanos(1_500),
            SpanKind::Stage { req: 0, adapter: 2, stage: Stage::Queued },
        );
        let json = chrome_trace_json(&rec.drain());
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.contains("\"name\":\"Queued\""));
        assert!(json.contains("\"dur\":1.500"), "ns must survive as fractional µs: {json}");
        assert!(json.trim_end().ends_with("]}"));
        assert_eq!(chrome_trace_json(&[]), "{\"traceEvents\":[\n]}\n");
    }
}
