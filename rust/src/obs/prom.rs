//! Minimal Prometheus text-format metrics registry (DESIGN.md §16).
//!
//! A [`MetricsRegistry`] is a point-in-time snapshot: callers *set*
//! fully-aggregated values (the pool's counters and gauges already
//! exist elsewhere; this layer only names and renders them). Rendering
//! is deterministic — metrics sort by name, samples by label string —
//! so the exposition can be golden-tested byte-for-byte.
//!
//! Conventions: counters end in `_total`, histogram/duration metrics
//! carry a `_us` unit suffix (bucket edges are integral microseconds),
//! and every metric in this repo is prefixed `lq_`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// One sample value.
#[derive(Debug, Clone, PartialEq)]
pub enum Sample {
    Counter(u64),
    Gauge(f64),
    /// Full-bucket histogram: `(upper edge, cumulative count)` pairs in
    /// ascending edge order (the `+Inf` row is appended from `count` at
    /// render time), plus the running sum and total count.
    Histogram { buckets: Vec<(u64, u64)>, sum: f64, count: u64 },
}

struct Metric {
    help: String,
    /// Prometheus TYPE: `counter` | `gauge` | `histogram`.
    kind: &'static str,
    /// Serialized label pairs (without braces, e.g. `worker="0"`) →
    /// sample. BTreeMap keeps the render order stable.
    samples: BTreeMap<String, Sample>,
}

/// A metrics snapshot rendering Prometheus text exposition format.
#[derive(Default)]
pub struct MetricsRegistry {
    metrics: BTreeMap<String, Metric>,
}

/// Serialize label pairs in the order given (callers pass a fixed
/// order, so identical inputs render identical lines). Values are
/// escaped per the exposition format.
fn label_str(labels: &[(&str, &str)]) -> String {
    let mut out = String::new();
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let escaped = v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n");
        let _ = write!(out, "{k}=\"{escaped}\"");
    }
    out
}

/// `123` for whole numbers, shortest-roundtrip decimals otherwise —
/// both deterministic.
fn fmt_f64(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    fn set(&mut self, name: &str, help: &str, kind: &'static str, labels: &[(&str, &str)], s: Sample) {
        self.metrics
            .entry(name.to_string())
            .or_insert_with(|| Metric { help: help.to_string(), kind, samples: BTreeMap::new() })
            .samples
            .insert(label_str(labels), s);
    }

    pub fn counter(&mut self, name: &str, help: &str, labels: &[(&str, &str)], v: u64) {
        self.set(name, help, "counter", labels, Sample::Counter(v));
    }

    pub fn gauge(&mut self, name: &str, help: &str, labels: &[(&str, &str)], v: f64) {
        self.set(name, help, "gauge", labels, Sample::Gauge(v));
    }

    /// `buckets` are `(upper edge, cumulative count)` in ascending edge
    /// order; `count` is the total (and the implied `+Inf` row).
    pub fn histogram(
        &mut self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        buckets: Vec<(u64, u64)>,
        sum: f64,
        count: u64,
    ) {
        self.set(name, help, "histogram", labels, Sample::Histogram { buckets, sum, count });
    }

    /// Render the exposition text. Stable: metrics in name order,
    /// samples in label order, one trailing newline.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, m) in &self.metrics {
            let _ = writeln!(out, "# HELP {name} {}", m.help);
            let _ = writeln!(out, "# TYPE {name} {}", m.kind);
            for (labels, sample) in &m.samples {
                match sample {
                    Sample::Counter(v) => {
                        let _ = writeln!(out, "{name}{} {v}", braced(labels));
                    }
                    Sample::Gauge(v) => {
                        let _ = writeln!(out, "{name}{} {}", braced(labels), fmt_f64(*v));
                    }
                    Sample::Histogram { buckets, sum, count } => {
                        for (le, cum) in buckets {
                            let _ = writeln!(
                                out,
                                "{name}_bucket{} {cum}",
                                braced(&join(labels, &format!("le=\"{le}\"")))
                            );
                        }
                        let _ = writeln!(
                            out,
                            "{name}_bucket{} {count}",
                            braced(&join(labels, "le=\"+Inf\""))
                        );
                        let _ = writeln!(out, "{name}_sum{} {}", braced(labels), fmt_f64(*sum));
                        let _ = writeln!(out, "{name}_count{} {count}", braced(labels));
                    }
                }
            }
        }
        out
    }
}

fn braced(labels: &str) -> String {
    if labels.is_empty() {
        String::new()
    } else {
        format!("{{{labels}}}")
    }
}

fn join(labels: &str, extra: &str) -> String {
    if labels.is_empty() {
        extra.to_string()
    } else {
        format!("{labels},{extra}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_sorted_and_stable() {
        let mut reg = MetricsRegistry::new();
        reg.gauge("lq_queue_depth", "Admission-queued requests.", &[("worker", "1")], 3.0);
        reg.gauge("lq_queue_depth", "Admission-queued requests.", &[("worker", "0")], 0.5);
        reg.counter("lq_requests_total", "Requests admitted.", &[], 42);
        let text = reg.render();
        let expected = "\
# HELP lq_queue_depth Admission-queued requests.
# TYPE lq_queue_depth gauge
lq_queue_depth{worker=\"0\"} 0.5
lq_queue_depth{worker=\"1\"} 3
# HELP lq_requests_total Requests admitted.
# TYPE lq_requests_total counter
lq_requests_total 42
";
        assert_eq!(text, expected);
        // Insertion order must not matter.
        let mut reg2 = MetricsRegistry::new();
        reg2.counter("lq_requests_total", "Requests admitted.", &[], 42);
        reg2.gauge("lq_queue_depth", "Admission-queued requests.", &[("worker", "0")], 0.5);
        reg2.gauge("lq_queue_depth", "Admission-queued requests.", &[("worker", "1")], 3.0);
        assert_eq!(reg2.render(), expected);
    }

    #[test]
    fn histogram_renders_cumulative_buckets_and_inf() {
        let mut reg = MetricsRegistry::new();
        reg.histogram(
            "lq_e2e_latency_us",
            "End-to-end latency (µs).",
            &[],
            vec![(2, 1), (4, 3)],
            7.0,
            4,
        );
        let text = reg.render();
        let expected = "\
# HELP lq_e2e_latency_us End-to-end latency (µs).
# TYPE lq_e2e_latency_us histogram
lq_e2e_latency_us_bucket{le=\"2\"} 1
lq_e2e_latency_us_bucket{le=\"4\"} 3
lq_e2e_latency_us_bucket{le=\"+Inf\"} 4
lq_e2e_latency_us_sum 7
lq_e2e_latency_us_count 4
";
        assert_eq!(text, expected);
    }

    #[test]
    fn label_values_escape() {
        assert_eq!(label_str(&[("k", "a\"b\\c")]), "k=\"a\\\"b\\\\c\"");
    }
}
