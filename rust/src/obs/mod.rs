//! Observability: deterministic request-lifecycle tracing,
//! stage-attributed latency, and Prometheus-style metrics exposition
//! (DESIGN.md §16).
//!
//! [`trace`] records typed stage and job spans through per-thread ring
//! buffers stamped from the shared [`crate::clock::Clock`]; under the
//! virtual clock the drained, canonically-ordered trace is
//! byte-identical across runs, compute-thread counts, and worker
//! counts — the same discipline as `scenario/events.rs`. [`prom`] is a
//! minimal counters/gauges/histograms registry rendering the
//! Prometheus text exposition format with a stable line order.
//!
//! Nothing in here depends on the coordinator: the pool, the merge
//! workers, and the scenario driver all consume these types, never the
//! other way around.

pub mod prom;
pub mod trace;

pub use prom::{MetricsRegistry, Sample};
pub use trace::{
    chrome_trace_json, Span, SpanKind, Stage, StageBreakdown, StageTrack, TraceHandle,
    TraceRecorder, STAGES,
};
