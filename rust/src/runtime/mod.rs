//! Execution runtime behind the coordinator: one of two backends with an
//! identical surface (`Engine`, `DeviceWeights`, `TokenBuffer`).
//!
//! * **`pjrt`** (feature `pjrt`) — loads AOT HLO-text artifacts and runs
//!   them through the PJRT CPU client (the original three-layer path:
//!   Pallas/JAX lowering at build time, XLA execution at serve time).
//!   Requires the local `xla` bindings; see rust/Cargo.toml.
//! * **`sim`** (default) — a pure-Rust reference engine that executes the
//!   same tiny-transformer forward (mirroring python/compile/model.py)
//!   directly on host f32 buffers. No artifacts beyond `meta.bin` +
//!   weights are needed, so the full serving stack — registry, cache,
//!   batcher, executor pool, merge pipeline — builds and tests
//!   hermetically offline.
//!
//! Both backends are deliberately compute-bound in `Engine::forward` and
//! cheap in `Engine::upload_weights`, which is the cost model the
//! coordinator's off-hot-path merge pipeline is built around: host-side
//! dequant+merge runs on merge workers, and only the upload happens on
//! the executor thread.
//!
//! The reference engine additionally exposes `forward_with_adapters` —
//! the factor-form execution path (DESIGN.md §8): per-batch-row adapter
//! deltas applied on the activation path over unmerged base weights. The
//! PJRT backend stubs it with an error (AOT programs bake their arity).
//!
//! Both backends expose the stateful incremental-decode surface
//! (`prefill` → `decode_step` over a [`DecodeState`], DESIGN.md §10).
//! On the reference engine it is the real KV-cached O(T)-per-step path
//! ([`kv`]); the PJRT backend satisfies the same contract by full
//! recompute (AOT HLO programs take whole padded sequences), so the
//! serving pool and evaluator drive one protocol everywhere.

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{DecodeState, DeviceWeights, Engine, Program};

#[cfg(not(feature = "pjrt"))]
pub mod kv;
#[cfg(not(feature = "pjrt"))]
mod sim;
#[cfg(not(feature = "pjrt"))]
pub use kv::{DecodeState, KvCache};
#[cfg(not(feature = "pjrt"))]
pub use sim::{DeviceWeights, Engine, Program, TokenBuffer};
