//! Reference engine: the tiny-transformer forward executed directly on
//! host f32 buffers (mirrors python/compile/model.py step for step —
//! layernorm, multi-head causal attention, gelu/silu FFN, untied head).
//!
//! This backend exists so the serving stack is testable and benchable
//! with no PJRT and no build-time python: `load_model_fwd` only needs
//! `<artifacts>/<model>/meta.bin` for the hyper-parameters, and
//! `upload_weights` keeps the merged weights as host tensors. Raw HLO
//! programs (`load_program`) are a PJRT-only capability and return an
//! error here.

use crate::adapter::fmt::{Tensor, TensorData};
use crate::loraquant::QFactors;
use crate::model::ModelConfig;
use crate::tensor::dot;
use anyhow::{bail, Context};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// A loaded forward "program": the model hyper-parameters plus the
/// expected input arity (tokens + weights), keyed like the PJRT backend
/// (`<model>/b<bucket>`).
pub struct Program {
    cfg: ModelConfig,
    /// Number of inputs expected (tokens + weights).
    pub arity: usize,
}

/// Reference engine: a set of loaded model configs.
pub struct Engine {
    programs: BTreeMap<String, Program>,
    artifacts_dir: PathBuf,
}

/// "Device"-resident weights — host tensors in `param_names` order (the
/// unit the coordinator's merged-weight cache holds).
pub struct DeviceWeights {
    pub tensors: Vec<Tensor>,
    /// f32 count (for cache byte accounting).
    pub elements: usize,
}

impl DeviceWeights {
    /// Resident bytes (f32).
    pub fn bytes(&self) -> usize {
        self.elements * 4
    }
}

/// An uploaded token batch (API parity with the PJRT backend's buffer).
pub struct TokenBuffer {
    tokens: Vec<i32>,
    dims: Vec<usize>,
}

impl Engine {
    /// Create an engine rooted at an artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        Ok(Self { programs: BTreeMap::new(), artifacts_dir: artifacts_dir.as_ref().into() })
    }

    /// The artifacts directory this engine loads from.
    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// Raw HLO programs require PJRT.
    pub fn load_program(&mut self, name: &str, file: &str, _arity: usize) -> anyhow::Result<()> {
        bail!(
            "reference engine cannot execute HLO artifact {file} (program {name}); \
             build with --features pjrt"
        )
    }

    /// Load the batched-forward "program" of a model for one batch bucket.
    /// Program key: `<model>/b<bucket>` (any batch size executes; the key
    /// keeps parity with the PJRT backend's compiled buckets).
    pub fn load_model_fwd(
        &mut self,
        model: &str,
        bucket: usize,
        n_params: usize,
    ) -> anyhow::Result<()> {
        let cfg = ModelConfig::load(self.artifacts_dir.join(model))
            .with_context(|| format!("loading {model} hyper-parameters"))?;
        let expected = cfg.param_names().len();
        if n_params != expected {
            bail!("model {model} has {expected} parameters, caller expected {n_params}");
        }
        self.programs.insert(format!("{model}/b{bucket}"), Program { cfg, arity: 1 + n_params });
        Ok(())
    }

    pub fn has_program(&self, name: &str) -> bool {
        self.programs.contains_key(name)
    }

    /// Keep a weight list (in `param_names` order) as host tensors.
    pub fn upload_weights(&self, weights: &[Tensor]) -> anyhow::Result<DeviceWeights> {
        let elements = weights
            .iter()
            .map(|t| match &t.data {
                TensorData::F32(v) => v.len(),
                _ => 0,
            })
            .sum();
        Ok(DeviceWeights { tensors: weights.to_vec(), elements })
    }

    /// Upload an i32 token batch.
    pub fn upload_tokens(&self, tokens: &[i32], dims: &[usize]) -> anyhow::Result<TokenBuffer> {
        Ok(TokenBuffer { tokens: tokens.to_vec(), dims: dims.to_vec() })
    }

    /// Execute a forward: tokens `[bsz, t]` → flattened logits
    /// `[bsz * t * vocab]`.
    pub fn execute(
        &self,
        name: &str,
        tokens: &TokenBuffer,
        weights: &DeviceWeights,
    ) -> anyhow::Result<Vec<f32>> {
        self.execute_with_adapters(name, tokens, weights, &[])
    }

    /// Execute a forward over **unmerged base weights**, applying each
    /// batch element's adapter delta in factor form on the activation
    /// path (`y += s · (x @ A′ᵀ) @ B′ᵀ` per LoRA site). `adapters` is
    /// per-batch-row (empty = no adapters anywhere), so one program
    /// serves a heterogeneous multi-adapter batch.
    pub fn execute_with_adapters(
        &self,
        name: &str,
        tokens: &TokenBuffer,
        weights: &DeviceWeights,
        adapters: &[Option<&QFactors<'_>>],
    ) -> anyhow::Result<Vec<f32>> {
        let prog = self.programs.get(name).with_context(|| format!("program {name} not loaded"))?;
        if 1 + weights.tensors.len() != prog.arity {
            bail!(
                "program {name} expects {} inputs, got {}",
                prog.arity,
                1 + weights.tensors.len()
            );
        }
        if tokens.dims.len() != 2 {
            bail!("token batch must be 2-D, got dims {:?}", tokens.dims);
        }
        if !adapters.is_empty() {
            if adapters.len() != tokens.dims[0] {
                bail!(
                    "adapter list has {} entries for a batch of {}",
                    adapters.len(),
                    tokens.dims[0]
                );
            }
            validate_adapter_shapes(&prog.cfg, adapters)?;
        }
        ref_forward(
            &prog.cfg,
            &weights.tensors,
            &tokens.tokens,
            tokens.dims[0],
            tokens.dims[1],
            adapters,
        )
    }

    /// Convenience: host-side tokens → logits.
    pub fn forward(
        &self,
        name: &str,
        tokens: &[i32],
        dims: &[usize],
        weights: &DeviceWeights,
    ) -> anyhow::Result<Vec<f32>> {
        let tok = self.upload_tokens(tokens, dims)?;
        self.execute(name, &tok, weights)
    }

    /// Convenience: host-side tokens → logits with per-request factor-form
    /// adapters over unmerged base weights.
    pub fn forward_with_adapters(
        &self,
        name: &str,
        tokens: &[i32],
        dims: &[usize],
        weights: &DeviceWeights,
        adapters: &[Option<&QFactors<'_>>],
    ) -> anyhow::Result<Vec<f32>> {
        let tok = self.upload_tokens(tokens, dims)?;
        self.execute_with_adapters(name, &tok, weights, adapters)
    }
}

/// Every adapter site must name a known LoRA site with the model's
/// (m_out, n_in) — checked once up front so the apply loop can't panic
/// mid-forward on a shape mismatch.
fn validate_adapter_shapes(
    cfg: &ModelConfig,
    adapters: &[Option<&QFactors<'_>>],
) -> anyhow::Result<()> {
    for qf in adapters.iter().flatten() {
        for (site, sf) in &qf.sites {
            let short = site.rsplit_once('.').map_or(site.as_str(), |(_, s)| s);
            let (n_in, m_out) = cfg
                .site_shape(short)
                .with_context(|| format!("adapter targets unknown site {site}"))?;
            if (sf.m, sf.n) != (m_out, n_in) {
                bail!(
                    "adapter site {site}: ΔW is {}x{}, model expects {}x{}",
                    sf.m,
                    sf.n,
                    m_out,
                    n_in
                );
            }
        }
    }
    Ok(())
}

/// Accumulate every present adapter's factor-form delta for `site` into
/// `y`: rows `b·t .. (b+1)·t` of `x` (rows×n) and `y` (rows×m) belong to
/// batch element `b`; `(n, m)` is the site's (input, output) width.
fn apply_adapter_site(
    adapters: &[Option<&QFactors<'_>>],
    site: &str,
    x: &[f32],
    t: usize,
    (n, m): (usize, usize),
    scaling: f32,
    y: &mut [f32],
) {
    for (b, qf) in adapters.iter().enumerate() {
        let Some(sf) = qf.and_then(|q| q.site(site)) else { continue };
        sf.apply_delta_acc(
            &x[b * t * n..(b + 1) * t * n],
            t,
            scaling,
            &mut y[b * t * m..(b + 1) * t * m],
        );
    }
}

/// Named f32 views over the flat weight list (param_names order).
struct Params<'a> {
    by_name: BTreeMap<String, &'a Tensor>,
}

impl<'a> Params<'a> {
    fn new(cfg: &ModelConfig, weights: &'a [Tensor]) -> anyhow::Result<Self> {
        let names = cfg.param_names();
        if names.len() != weights.len() {
            bail!("weight list has {} tensors, schema has {}", weights.len(), names.len());
        }
        Ok(Self { by_name: names.into_iter().zip(weights).collect() })
    }

    fn get(&self, name: &str) -> anyhow::Result<&'a [f32]> {
        self.by_name
            .get(name)
            .with_context(|| format!("missing parameter {name}"))?
            .as_f32()
            .with_context(|| format!("parameter {name} is not f32"))
    }
}

/// `C[m,n] = A[m,k] @ B[k,n]`, row-major flat slices (i-k-j order, same
/// kernel shape as tensor::ops::matmul).
fn matmul_flat(a: &[f32], m: usize, k: usize, b: &[f32], n: usize, c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.fill(0.0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// Row-wise layernorm with gain/bias (eps matches model.py).
fn layernorm(x: &[f32], rows: usize, d: usize, g: &[f32], b: &[f32], out: &mut [f32]) {
    for i in 0..rows {
        let row = &x[i * d..(i + 1) * d];
        let orow = &mut out[i * d..(i + 1) * d];
        let mu = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for j in 0..d {
            orow[j] = g[j] * (row[j] - mu) * inv + b[j];
        }
    }
}

/// jax.nn.gelu's default tanh approximation.
fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// The reference forward (python/compile/model.py `_forward_impl`), with
/// optional per-batch-row factor-form adapter deltas on every LoRA site.
fn ref_forward(
    cfg: &ModelConfig,
    weights: &[Tensor],
    tokens: &[i32],
    bsz: usize,
    t: usize,
    adapters: &[Option<&QFactors<'_>>],
) -> anyhow::Result<Vec<f32>> {
    let p = Params::new(cfg, weights)?;
    let (d, f, v) = (cfg.d_model, cfg.d_ff, cfg.vocab);
    let nh = cfg.n_heads;
    if d % nh != 0 {
        bail!("d_model {d} not divisible by n_heads {nh}");
    }
    let hd = d / nh;
    if tokens.len() != bsz * t {
        bail!("token batch {}, expected {}x{}", tokens.len(), bsz, t);
    }
    if t > cfg.seq_len {
        bail!("sequence length {t} exceeds model seq_len {}", cfg.seq_len);
    }

    // x = embed[tokens] + pos[:t]
    let embed = p.get("embed")?;
    let pos = p.get("pos")?;
    let rows = bsz * t;
    let mut x = vec![0.0f32; rows * d];
    for b in 0..bsz {
        for i in 0..t {
            let tok = tokens[b * t + i];
            if tok < 0 || tok as usize >= cfg.vocab {
                bail!("token {tok} out of vocab range 0..{}", cfg.vocab);
            }
            let e = &embed[tok as usize * d..(tok as usize + 1) * d];
            let po = &pos[i * d..(i + 1) * d];
            let row = &mut x[(b * t + i) * d..(b * t + i + 1) * d];
            for j in 0..d {
                row[j] = e[j] + po[j];
            }
        }
    }

    let lora_s = cfg.lora_scaling();
    let att_scale = 1.0 / (hd as f32).sqrt();
    let mut hx = vec![0.0f32; rows * d];
    let mut q = vec![0.0f32; rows * d];
    let mut k = vec![0.0f32; rows * d];
    let mut vv = vec![0.0f32; rows * d];
    let mut att_out = vec![0.0f32; rows * d];
    let mut proj = vec![0.0f32; rows * d];
    let mut h1 = vec![0.0f32; rows * f];
    let mut h2 = vec![0.0f32; rows * d];
    let mut scores = vec![0.0f32; t];

    for l in 0..cfg.n_layers {
        // attention block
        let (g1, b1) = (p.get(&format!("l{l}.ln1.g"))?, p.get(&format!("l{l}.ln1.b"))?);
        layernorm(&x, rows, d, g1, b1, &mut hx);
        matmul_flat(&hx, rows, d, p.get(&format!("l{l}.wq"))?, d, &mut q);
        apply_adapter_site(adapters, &format!("l{l}.wq"), &hx, t, (d, d), lora_s, &mut q);
        matmul_flat(&hx, rows, d, p.get(&format!("l{l}.wk"))?, d, &mut k);
        apply_adapter_site(adapters, &format!("l{l}.wk"), &hx, t, (d, d), lora_s, &mut k);
        matmul_flat(&hx, rows, d, p.get(&format!("l{l}.wv"))?, d, &mut vv);
        apply_adapter_site(adapters, &format!("l{l}.wv"), &hx, t, (d, d), lora_s, &mut vv);
        att_out.fill(0.0);
        for b in 0..bsz {
            for h in 0..nh {
                let off = h * hd;
                for i in 0..t {
                    let qrow = &q[(b * t + i) * d + off..(b * t + i) * d + off + hd];
                    // causal scores, masked positions at -1e9 (as in the
                    // jax model: mask *before* softmax over the full row)
                    for (j, s) in scores.iter_mut().enumerate() {
                        *s = if j > i {
                            -1e9
                        } else {
                            let krow = &k[(b * t + j) * d + off..(b * t + j) * d + off + hd];
                            dot(qrow, krow) * att_scale
                        };
                    }
                    let max = scores.iter().fold(f32::NEG_INFINITY, |m, &s| m.max(s));
                    let mut denom = 0.0;
                    for s in scores.iter_mut() {
                        *s = (*s - max).exp();
                        denom += *s;
                    }
                    let orow =
                        &mut att_out[(b * t + i) * d + off..(b * t + i) * d + off + hd];
                    for (j, &w) in scores.iter().enumerate() {
                        let w = w / denom;
                        let vrow = &vv[(b * t + j) * d + off..(b * t + j) * d + off + hd];
                        for u in 0..hd {
                            orow[u] += w * vrow[u];
                        }
                    }
                }
            }
        }
        matmul_flat(&att_out, rows, d, p.get(&format!("l{l}.wo"))?, d, &mut proj);
        apply_adapter_site(adapters, &format!("l{l}.wo"), &att_out, t, (d, d), lora_s, &mut proj);
        for (xi, pi) in x.iter_mut().zip(&proj) {
            *xi += pi;
        }

        // FFN block
        let (g2, b2) = (p.get(&format!("l{l}.ln2.g"))?, p.get(&format!("l{l}.ln2.b"))?);
        layernorm(&x, rows, d, g2, b2, &mut hx);
        matmul_flat(&hx, rows, d, p.get(&format!("l{l}.w1"))?, f, &mut h1);
        apply_adapter_site(adapters, &format!("l{l}.w1"), &hx, t, (d, f), lora_s, &mut h1);
        if cfg.act_silu {
            for z in h1.iter_mut() {
                *z = silu(*z);
            }
        } else {
            for z in h1.iter_mut() {
                *z = gelu(*z);
            }
        }
        matmul_flat(&h1, rows, f, p.get(&format!("l{l}.w2"))?, d, &mut h2);
        apply_adapter_site(adapters, &format!("l{l}.w2"), &h1, t, (f, d), lora_s, &mut h2);
        for (xi, hi) in x.iter_mut().zip(&h2) {
            *xi += hi;
        }
    }

    layernorm(&x, rows, d, p.get("lnf.g")?, p.get("lnf.b")?, &mut hx);
    let mut logits = vec![0.0f32; rows * v];
    matmul_flat(&hx, rows, d, p.get("head")?, v, &mut logits);
    Ok(logits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{merge_adapter, BaseWeights};
    use crate::testutil::synth::{synth_model_config, synth_quantized_adapter, write_synth_model};

    fn temp_artifacts(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("lq_sim_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn forward_shapes_and_determinism() {
        let dir = temp_artifacts("fwd");
        let cfg = synth_model_config();
        write_synth_model(&dir, "synth", &cfg, &[4], 7).unwrap();
        let base = BaseWeights::load(dir.join("synth")).unwrap();
        let mut engine = Engine::new(&dir).unwrap();
        engine.load_model_fwd("synth", 4, base.cfg.param_names().len()).unwrap();
        assert!(engine.has_program("synth/b4"));
        let merged = merge_adapter(&base, &std::collections::BTreeMap::new()).unwrap();
        let w = engine.upload_weights(&merged).unwrap();
        assert!(w.bytes() > 0);
        let tokens = vec![1i32; 4 * cfg.seq_len];
        let l1 = engine.forward("synth/b4", &tokens, &[4, cfg.seq_len], &w).unwrap();
        let l2 = engine.forward("synth/b4", &tokens, &[4, cfg.seq_len], &w).unwrap();
        assert_eq!(l1.len(), 4 * cfg.seq_len * cfg.vocab);
        assert_eq!(l1, l2, "same inputs must give identical logits");
        assert!(l1.iter().all(|x| x.is_finite()));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn forward_depends_on_tokens_and_weights() {
        let dir = temp_artifacts("sens");
        let cfg = synth_model_config();
        write_synth_model(&dir, "synth", &cfg, &[1], 11).unwrap();
        let base = BaseWeights::load(dir.join("synth")).unwrap();
        let mut engine = Engine::new(&dir).unwrap();
        engine.load_model_fwd("synth", 1, base.cfg.param_names().len()).unwrap();
        let merged = merge_adapter(&base, &std::collections::BTreeMap::new()).unwrap();
        let w = engine.upload_weights(&merged).unwrap();
        let mut t1 = vec![1i32; cfg.seq_len];
        let l1 = engine.forward("synth/b1", &t1, &[1, cfg.seq_len], &w).unwrap();
        t1[1] = 5;
        let l2 = engine.forward("synth/b1", &t1, &[1, cfg.seq_len], &w).unwrap();
        assert_ne!(l1, l2, "different tokens must change logits");
        let _ = std::fs::remove_dir_all(&dir);
    }

    fn rel_err(a: &[f32], b: &[f32]) -> f32 {
        let num: f32 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>().sqrt();
        let den: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
        num / den.max(1e-12)
    }

    #[test]
    fn factor_form_matches_merged_forward() {
        let dir = temp_artifacts("factor");
        let cfg = synth_model_config();
        write_synth_model(&dir, "synth", &cfg, &[2], 19).unwrap();
        let base = BaseWeights::load(dir.join("synth")).unwrap();
        let mut engine = Engine::new(&dir).unwrap();
        engine.load_model_fwd("synth", 2, base.cfg.param_names().len()).unwrap();
        let stored = synth_quantized_adapter(&cfg, 33);
        let w_merged = engine
            .upload_weights(&merge_adapter(&base, &stored.deltas()).unwrap())
            .unwrap();
        let w_base = engine
            .upload_weights(&merge_adapter(&base, &std::collections::BTreeMap::new()).unwrap())
            .unwrap();
        let t = cfg.seq_len;
        let mut tokens = vec![1i32; 2 * t];
        tokens[t] = 7; // distinct second row
        let l_merged = engine.forward("synth/b2", &tokens, &[2, t], &w_merged).unwrap();
        let qf = stored.factors();
        let l_factor = engine
            .forward_with_adapters("synth/b2", &tokens, &[2, t], &w_base, &[Some(&qf), Some(&qf)])
            .unwrap();
        // identical math up to f32 re-association: merged folds ΔW into W,
        // factor-form adds s·(x@A′ᵀ)@B′ᵀ on the activations
        assert!(rel_err(&l_factor, &l_merged) < 1e-4, "rel {}", rel_err(&l_factor, &l_merged));

        // heterogeneous batch: row 0 unadapted, row 1 adapted — per-row
        // outputs must be bitwise identical to the homogeneous runs
        let l_base = engine.forward("synth/b2", &tokens, &[2, t], &w_base).unwrap();
        let l_mixed = engine
            .forward_with_adapters("synth/b2", &tokens, &[2, t], &w_base, &[None, Some(&qf)])
            .unwrap();
        let row = t * cfg.vocab;
        assert_eq!(l_mixed[..row], l_base[..row], "unadapted row must be pure base");
        assert_eq!(l_mixed[row..], l_factor[row..], "adapted row must match factor path");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn factor_form_rejects_bad_adapters() {
        let dir = temp_artifacts("factorbad");
        let cfg = synth_model_config();
        write_synth_model(&dir, "synth", &cfg, &[2], 23).unwrap();
        let base = BaseWeights::load(dir.join("synth")).unwrap();
        let mut engine = Engine::new(&dir).unwrap();
        engine.load_model_fwd("synth", 2, base.cfg.param_names().len()).unwrap();
        let w_base = engine
            .upload_weights(&merge_adapter(&base, &std::collections::BTreeMap::new()).unwrap())
            .unwrap();
        let stored = synth_quantized_adapter(&cfg, 5);
        let qf = stored.factors();
        let t = cfg.seq_len;
        let tokens = vec![1i32; 2 * t];
        // arity mismatch: one adapter entry for a batch of two
        let err = engine
            .forward_with_adapters("synth/b2", &tokens, &[2, t], &w_base, &[Some(&qf)])
            .unwrap_err();
        assert!(err.to_string().contains("adapter list"));
        // shape mismatch: wrong model for this adapter
        let bigger = ModelConfig { d_model: cfg.d_model * 2, ..cfg };
        let wrong = synth_quantized_adapter(&bigger, 6);
        let wrong_qf = wrong.factors();
        let err = engine
            .forward_with_adapters(
                "synth/b2",
                &tokens,
                &[2, t],
                &w_base,
                &[Some(&wrong_qf), None],
            )
            .unwrap_err();
        assert!(err.to_string().contains("model expects"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_bad_inputs() {
        let dir = temp_artifacts("bad");
        let cfg = synth_model_config();
        write_synth_model(&dir, "synth", &cfg, &[1], 3).unwrap();
        let mut engine = Engine::new(&dir).unwrap();
        assert!(engine.load_program("x", "x.hlo.txt", 2).is_err());
        assert!(engine.load_model_fwd("synth", 1, 3).is_err(), "wrong n_params must fail");
        engine
            .load_model_fwd("synth", 1, cfg.param_names().len())
            .unwrap();
        let w = engine.upload_weights(&[]).unwrap();
        let err = engine.forward("synth/b1", &[1], &[1, 1], &w).unwrap_err();
        assert!(err.to_string().contains("expects"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
