//! Reference engine: the tiny-transformer forward executed directly on
//! host f32 buffers (mirrors python/compile/model.py step for step —
//! layernorm, multi-head causal attention, gelu/silu FFN, untied head).
//!
//! This backend exists so the serving stack is testable and benchable
//! with no PJRT and no build-time python: `load_model_fwd` only needs
//! `<artifacts>/<model>/meta.bin` for the hyper-parameters, and
//! `upload_weights` keeps the merged weights as host tensors. Raw HLO
//! programs (`load_program`) are a PJRT-only capability and return an
//! error here.
//!
//! Two execution shapes share one layer core ([`forward_core`],
//! DESIGN.md §10):
//!
//! * **full forward** (`execute` / `execute_with_adapters`) — every
//!   (lane, position) row of a padded batch in one pass. O(L·T²·d) per
//!   call; kept as the decode *oracle* the incremental path is
//!   property-tested against.
//! * **incremental decode** (`prefill` → `decode_step`) — prefill runs
//!   one batched pass over the prompts, writing per-layer K/V into a
//!   [`KvCache`]; each step then embeds one token per still-active lane
//!   and attends against the cache: O(L·T·d) per generated token instead
//!   of O(L·T²·d). Retired lanes cost nothing, and the session's
//!   [`DecodeState`] scratch arena makes steady-state steps
//!   allocation-free.
//!
//! Projections, the attention inner loop, and decode-step matmuls are
//! row-partitioned across a **persistent per-engine compute pool**
//! (`Engine::set_compute_threads` →
//! [`crate::scheduler::workers::ComputePool`], DESIGN.md §11); per-row
//! accumulation order is unchanged, so logits are bit-identical at every
//! thread count.
//!
//! Beyond the one-shot `prefill` → `decode_step` session shape, the
//! engine supports **continuous batching** (DESIGN.md §11):
//! [`Engine::new_session`] opens an empty session (every lane retired,
//! no forward), and [`Engine::admit`] prefills fresh prompts into
//! retired lanes of a *warm* session mid-flight — the scheduler retires
//! finished lanes and admits queued requests into the freed slots
//! between steps instead of tearing the session down per batch.

use super::kv::{DecodeState, KvCache, Scratch};
use crate::adapter::fmt::{Tensor, TensorData};
use crate::loraquant::{FactorScratch, FactorSource, QFactors, SiteFactors};
use crate::model::ModelConfig;
use crate::scheduler::workers::{ComputePool, SendPtr};
use crate::tensor::{dot, matmul_flat, simd};
use anyhow::{anyhow, bail, Context};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// A loaded forward "program": the model hyper-parameters plus the
/// expected input arity (tokens + weights), keyed like the PJRT backend
/// (`<model>/b<bucket>`).
pub struct Program {
    cfg: ModelConfig,
    /// Number of inputs expected (tokens + weights).
    pub arity: usize,
}

/// Reference engine: a set of loaded model configs.
pub struct Engine {
    programs: BTreeMap<String, Program>,
    artifacts_dir: PathBuf,
    /// Persistent compute pool for row-partitioned kernels (None = fully
    /// serial; results are identical either way).
    pool: Option<ComputePool>,
}

/// "Device"-resident weights — host tensors in `param_names` order (the
/// unit the coordinator's merged-weight cache holds).
pub struct DeviceWeights {
    pub tensors: Vec<Tensor>,
    /// f32 count (for cache byte accounting).
    pub elements: usize,
}

impl DeviceWeights {
    /// Resident bytes (f32).
    pub fn bytes(&self) -> usize {
        self.elements * 4
    }
}

/// An uploaded token batch (API parity with the PJRT backend's buffer).
pub struct TokenBuffer {
    tokens: Vec<i32>,
    dims: Vec<usize>,
}

impl Engine {
    /// Create an engine rooted at an artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        Ok(Self {
            programs: BTreeMap::new(),
            artifacts_dir: artifacts_dir.as_ref().into(),
            pool: None,
        })
    }

    /// The artifacts directory this engine loads from.
    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// Row-partition the engine's kernels — prefill/full-forward matmuls,
    /// the attention inner loop, and decode-step matmuls — across a
    /// **persistent** `threads`-wide compute pool (clamped to ≥ 1; 1
    /// drops the pool and runs fully serial). Workers live as long as the
    /// engine, so a partitioned kernel call costs two condvar handshakes
    /// instead of a round of thread spawns. Thread count never changes
    /// results — each output row accumulates in the same order — so 1
    /// (the default) only pins the serial schedule.
    pub fn set_compute_threads(&mut self, threads: usize) {
        let threads = threads.max(1);
        if threads == self.compute_threads() {
            return;
        }
        self.pool = (threads > 1).then(|| ComputePool::new(threads));
    }

    /// Current compute-pool width (1 = serial).
    pub fn compute_threads(&self) -> usize {
        self.pool.as_ref().map_or(1, ComputePool::threads)
    }

    /// Raw HLO programs require PJRT.
    pub fn load_program(&mut self, name: &str, file: &str, _arity: usize) -> anyhow::Result<()> {
        bail!(
            "reference engine cannot execute HLO artifact {file} (program {name}); \
             build with --features pjrt"
        )
    }

    /// Load the batched-forward "program" of a model for one batch bucket.
    /// Program key: `<model>/b<bucket>` (any batch size executes; the key
    /// keeps parity with the PJRT backend's compiled buckets).
    pub fn load_model_fwd(
        &mut self,
        model: &str,
        bucket: usize,
        n_params: usize,
    ) -> anyhow::Result<()> {
        let cfg = ModelConfig::load(self.artifacts_dir.join(model))
            .with_context(|| format!("loading {model} hyper-parameters"))?;
        let expected = cfg.param_names().len();
        if n_params != expected {
            bail!("model {model} has {expected} parameters, caller expected {n_params}");
        }
        self.programs.insert(format!("{model}/b{bucket}"), Program { cfg, arity: 1 + n_params });
        Ok(())
    }

    pub fn has_program(&self, name: &str) -> bool {
        self.programs.contains_key(name)
    }

    /// Keep a weight list (in `param_names` order) as host tensors.
    pub fn upload_weights(&self, weights: &[Tensor]) -> anyhow::Result<DeviceWeights> {
        let elements = weights
            .iter()
            .map(|t| match &t.data {
                TensorData::F32(v) => v.len(),
                _ => 0,
            })
            .sum();
        Ok(DeviceWeights { tensors: weights.to_vec(), elements })
    }

    /// Upload an i32 token batch.
    pub fn upload_tokens(&self, tokens: &[i32], dims: &[usize]) -> anyhow::Result<TokenBuffer> {
        Ok(TokenBuffer { tokens: tokens.to_vec(), dims: dims.to_vec() })
    }

    /// Execute a forward: tokens `[bsz, t]` → flattened logits
    /// `[bsz * t * vocab]`.
    pub fn execute(
        &self,
        name: &str,
        tokens: &TokenBuffer,
        weights: &DeviceWeights,
    ) -> anyhow::Result<Vec<f32>> {
        self.execute_with_adapters(name, tokens, weights, &[])
    }

    /// Execute a forward over **unmerged base weights**, applying each
    /// batch element's adapter delta in factor form on the activation
    /// path (`y += s · (x @ A′ᵀ) @ B′ᵀ` per LoRA site). `adapters` is
    /// per-batch-row (empty = no adapters anywhere), so one program
    /// serves a heterogeneous multi-adapter batch.
    pub fn execute_with_adapters(
        &self,
        name: &str,
        tokens: &TokenBuffer,
        weights: &DeviceWeights,
        adapters: &[Option<&QFactors<'_>>],
    ) -> anyhow::Result<Vec<f32>> {
        let prog = self.programs.get(name).with_context(|| format!("program {name} not loaded"))?;
        if 1 + weights.tensors.len() != prog.arity {
            bail!(
                "program {name} expects {} inputs, got {}",
                prog.arity,
                1 + weights.tensors.len()
            );
        }
        if tokens.dims.len() != 2 {
            bail!("token batch must be 2-D, got dims {:?}", tokens.dims);
        }
        if !adapters.is_empty() {
            if adapters.len() != tokens.dims[0] {
                bail!(
                    "adapter list has {} entries for a batch of {}",
                    adapters.len(),
                    tokens.dims[0]
                );
            }
            validate_adapter_shapes(&prog.cfg, adapters)?;
        }
        ref_forward(
            &prog.cfg,
            &weights.tensors,
            &tokens.tokens,
            tokens.dims[0],
            tokens.dims[1],
            adapters,
            self.pool.as_ref(),
        )
    }

    /// Convenience: host-side tokens → logits.
    pub fn forward(
        &self,
        name: &str,
        tokens: &[i32],
        dims: &[usize],
        weights: &DeviceWeights,
    ) -> anyhow::Result<Vec<f32>> {
        let tok = self.upload_tokens(tokens, dims)?;
        self.execute(name, &tok, weights)
    }

    /// Convenience: host-side tokens → logits with per-request factor-form
    /// adapters over unmerged base weights.
    pub fn forward_with_adapters(
        &self,
        name: &str,
        tokens: &[i32],
        dims: &[usize],
        weights: &DeviceWeights,
        adapters: &[Option<&QFactors<'_>>],
    ) -> anyhow::Result<Vec<f32>> {
        let tok = self.upload_tokens(tokens, dims)?;
        self.execute_with_adapters(name, &tok, weights, adapters)
    }

    /// Start an incremental-decode session: one batched forward over the
    /// prompts (lane `k` holds `lens[k]` tokens at the front of
    /// `seqs[k]`), writing every position's K/V into the session's cache.
    ///
    /// Returns the session state plus the batch's next-token logits
    /// (`lanes × vocab`; row `k` is the logits row after
    /// `seqs[k][lens[k]-1]`, exactly the row the full forward would put
    /// at position `lens[k]-1`). `adapters` is per-lane, as in
    /// [`Engine::execute_with_adapters`], and applies to both the prefill
    /// and every later [`Engine::decode_step`] of this session.
    pub fn prefill(
        &self,
        name: &str,
        seqs: &[Vec<i32>],
        lens: &[usize],
        weights: &DeviceWeights,
        adapters: &[Option<&QFactors<'_>>],
    ) -> anyhow::Result<(DecodeState, Vec<f32>)> {
        let prog = self.programs.get(name).with_context(|| format!("program {name} not loaded"))?;
        if 1 + weights.tensors.len() != prog.arity {
            bail!(
                "program {name} expects {} inputs, got {}",
                prog.arity,
                1 + weights.tensors.len()
            );
        }
        let cfg = prog.cfg;
        let bsz = seqs.len();
        if bsz == 0 {
            bail!("prefill: empty lane set");
        }
        if lens.len() != bsz {
            bail!("prefill: {bsz} lanes vs {} lens", lens.len());
        }
        for (k, (&len, seq)) in lens.iter().zip(seqs).enumerate() {
            if len == 0 || len > cfg.seq_len {
                bail!("prefill: lane {k} length {len} out of range 1..={}", cfg.seq_len);
            }
            if seq.len() < len {
                bail!("prefill: lane {k} holds {} tokens, needs {len}", seq.len());
            }
        }
        if !adapters.is_empty() {
            if adapters.len() != bsz {
                bail!("adapter list has {} entries for a batch of {bsz}", adapters.len());
            }
            validate_adapter_shapes(&cfg, adapters)?;
        }
        let t = lens.iter().copied().max().unwrap_or(1);
        // name/position resolution happens once here; every later step
        // reuses the session's index and allocates nothing for lookups
        let mut state =
            DecodeState::new(name, cfg, prog.arity, lens.to_vec(), ParamIndex::new(&cfg));
        state.idx.validate(&weights.tensors)?;
        state.scratch.ensure(bsz * t, &cfg, self.compute_threads());
        // Embed the prompt region. Positions at or past a short lane's
        // length embed PAD (0); their K/V columns are overwritten by the
        // lane's own decode steps before anything can attend to them.
        let embed = pget(&weights.tensors, state.idx.embed)?;
        let pos = pget(&weights.tensors, state.idx.pos)?;
        let d = cfg.d_model;
        for b in 0..bsz {
            for i in 0..t {
                let tok = if i < lens[b] { seqs[b][i] } else { 0 };
                if tok < 0 || tok as usize >= cfg.vocab {
                    bail!("token {tok} out of vocab range 0..{}", cfg.vocab);
                }
                embed_row(
                    embed,
                    pos,
                    tok as usize,
                    i,
                    d,
                    &mut state.scratch.x[(b * t + i) * d..(b * t + i + 1) * d],
                );
            }
        }
        forward_core(
            &cfg,
            &weights.tensors,
            &state.idx,
            &Rows::Full { bsz, t },
            &views(adapters),
            &mut state.kv,
            &mut state.scratch,
            self.pool.as_ref(),
        )?;
        let vo = cfg.vocab;
        let mut out = vec![0.0f32; bsz * vo];
        for b in 0..bsz {
            let src = (b * t + lens[b] - 1) * vo;
            out[b * vo..(b + 1) * vo].copy_from_slice(&state.scratch.logits[src..src + vo]);
        }
        Ok((state, out))
    }

    /// Advance an incremental-decode session by one token: `last[k]` is
    /// the newest token of lane `k` (consumed at position
    /// `state.lane_len(k)`; ignored for retired lanes). Returns the
    /// per-lane next-token logits (`lanes × vocab`, retired rows zero),
    /// borrowed from the session's scratch — O(layers · seq · d) per
    /// active lane and allocation-free once the session is warm.
    ///
    /// Adapter precedence: a non-empty `adapters` slice (explicit
    /// per-lane views, re-validated here) wins; otherwise lanes bound via
    /// [`DecodeState::bind_adapter`] apply — validated at bind time, so
    /// the step itself does only site lookups.
    pub fn decode_step<'s>(
        &self,
        state: &'s mut DecodeState,
        weights: &DeviceWeights,
        adapters: &[Option<&QFactors<'_>>],
        last: &[i32],
    ) -> anyhow::Result<&'s [f32]> {
        let cfg = state.cfg;
        if 1 + weights.tensors.len() != state.arity {
            bail!(
                "program {} expects {} inputs, got {}",
                state.prog,
                state.arity,
                1 + weights.tensors.len()
            );
        }
        let bsz = state.lanes();
        if last.len() != bsz {
            bail!("decode_step: {} tokens for {bsz} lanes", last.len());
        }
        if !adapters.is_empty() {
            if adapters.len() != bsz {
                bail!("adapter list has {} entries for a batch of {bsz}", adapters.len());
            }
            // a handful of integer compares per step — keeps the
            // "no panic mid-forward" shape guarantee even if a caller
            // swaps adapters between steps
            validate_adapter_shapes(&cfg, adapters)?;
        }
        state.map.clear();
        for b in 0..bsz {
            if state.retired[b] {
                continue;
            }
            let pos = state.lens[b];
            if pos >= state.kv.capacity() {
                bail!(
                    "decode_step: lane {b} is full ({pos} tokens, kv capacity {})",
                    state.kv.capacity()
                );
            }
            let tok = last[b];
            if tok < 0 || tok as usize >= cfg.vocab {
                bail!("token {tok} out of vocab range 0..{}", cfg.vocab);
            }
            state.map.push((b, pos));
        }
        let vo = cfg.vocab;
        state.out.resize(bsz * vo, 0.0);
        state.out.fill(0.0);
        let n = state.map.len();
        if n == 0 {
            // every lane retired: nothing to compute
            return Ok(&state.out);
        }
        state.idx.validate(&weights.tensors)?;
        state.scratch.ensure(n, &cfg, self.compute_threads());
        let embed = pget(&weights.tensors, state.idx.embed)?;
        let pos_tab = pget(&weights.tensors, state.idx.pos)?;
        let d = cfg.d_model;
        for (r, &(b, pos)) in state.map.iter().enumerate() {
            embed_row(
                embed,
                pos_tab,
                last[b] as usize,
                pos,
                d,
                &mut state.scratch.x[r * d..(r + 1) * d],
            );
        }
        forward_core(
            &cfg,
            &weights.tensors,
            &state.idx,
            &Rows::Step { map: &state.map },
            &step_adapters(&state.sources, state.bound_sources, adapters),
            &mut state.kv,
            &mut state.scratch,
            // the persistent pool makes partitioned steps affordable
            // (two handshakes, no spawns); the pool clamps its width to
            // the row count, so a one-lane step stays fully serial
            self.pool.as_ref(),
        )?;
        for (r, &(b, _)) in state.map.iter().enumerate() {
            state.out[b * vo..(b + 1) * vo]
                .copy_from_slice(&state.scratch.logits[r * vo..(r + 1) * vo]);
        }
        for &(b, _) in &state.map {
            state.lens[b] += 1;
        }
        Ok(&state.out)
    }

    /// Open an **empty** continuous-batching session: `lanes` lanes, all
    /// retired with zero consumed tokens, no forward run. Lanes come live
    /// through [`Engine::admit`]; the session's KV/scratch allocations
    /// persist across [`DecodeState::reset`], so one long-lived session
    /// can serve many decode groups (DESIGN.md §11).
    pub fn new_session(
        &self,
        name: &str,
        lanes: usize,
        weights: &DeviceWeights,
    ) -> anyhow::Result<DecodeState> {
        let prog = self.programs.get(name).with_context(|| format!("program {name} not loaded"))?;
        if 1 + weights.tensors.len() != prog.arity {
            bail!(
                "program {name} expects {} inputs, got {}",
                prog.arity,
                1 + weights.tensors.len()
            );
        }
        if lanes == 0 {
            bail!("new_session: zero lanes");
        }
        let cfg = prog.cfg;
        let mut state =
            DecodeState::new(name, cfg, prog.arity, vec![0; lanes], ParamIndex::new(&cfg));
        state.idx.validate(&weights.tensors)?;
        state.reset();
        Ok(state)
    }

    /// Admit fresh prompts into **retired** lanes of a live session
    /// (continuous batching): lane `lanes[i]` restarts with
    /// `prompts[i]`, running one forward over every admitted prompt row —
    /// publishing K/V exactly like a batched prefill — and leaving each
    /// admitted lane's next-token logits in the session-wide output
    /// buffer (`lanes × vocab`; non-admitted rows zero). Bit-identical to
    /// prefilling the same prompt in a fresh session: every row-wise
    /// kernel is per-lane independent and a lane's attention window only
    /// covers positions it wrote itself, so a previous occupant's stale
    /// cache columns are unreachable.
    ///
    /// `adapters` is per-lane over the **whole** session, exactly as in
    /// [`Engine::decode_step`] — and with the same precedence: empty
    /// falls back to sources bound via [`DecodeState::bind_adapter`].
    pub fn admit<'s>(
        &self,
        state: &'s mut DecodeState,
        lanes: &[usize],
        prompts: &[&[i32]],
        weights: &DeviceWeights,
        adapters: &[Option<&QFactors<'_>>],
    ) -> anyhow::Result<&'s [f32]> {
        let cfg = state.cfg;
        if 1 + weights.tensors.len() != state.arity {
            bail!(
                "program {} expects {} inputs, got {}",
                state.prog,
                state.arity,
                1 + weights.tensors.len()
            );
        }
        let bsz = state.lanes();
        if lanes.len() != prompts.len() {
            bail!("admit: {} lanes for {} prompts", lanes.len(), prompts.len());
        }
        if !adapters.is_empty() {
            if adapters.len() != bsz {
                bail!("adapter list has {} entries for a session of {bsz}", adapters.len());
            }
            validate_adapter_shapes(&cfg, adapters)?;
        }
        // validate everything before any state mutation
        let cap = state.kv.capacity();
        for (i, (&l, prompt)) in lanes.iter().zip(prompts).enumerate() {
            if l >= bsz {
                bail!("admit: lane {l} out of range 0..{bsz}");
            }
            if !state.retired[l] {
                bail!("admit: lane {l} is still live");
            }
            if state.prefilling[l] {
                bail!("admit: lane {l} has a chunked prefill in flight");
            }
            if lanes[..i].contains(&l) {
                bail!("admit: lane {l} admitted twice in one call");
            }
            if prompt.is_empty() || prompt.len() > cap {
                bail!("admit: lane {l} prompt length {} out of range 1..={cap}", prompt.len());
            }
            for &tok in prompt.iter() {
                if tok < 0 || tok as usize >= cfg.vocab {
                    bail!("token {tok} out of vocab range 0..{}", cfg.vocab);
                }
            }
        }
        let vo = cfg.vocab;
        state.out.resize(bsz * vo, 0.0);
        state.out.fill(0.0);
        state.map.clear();
        for (&l, prompt) in lanes.iter().zip(prompts) {
            for p in 0..prompt.len() {
                state.map.push((l, p));
            }
        }
        let n = state.map.len();
        if n == 0 {
            return Ok(&state.out); // nothing admitted
        }
        state.idx.validate(&weights.tensors)?;
        state.scratch.ensure(n, &cfg, self.compute_threads());
        let embed = pget(&weights.tensors, state.idx.embed)?;
        let pos_tab = pget(&weights.tensors, state.idx.pos)?;
        let d = cfg.d_model;
        let mut r = 0;
        for prompt in prompts {
            for (p, &tok) in prompt.iter().enumerate() {
                embed_row(
                    embed,
                    pos_tab,
                    tok as usize,
                    p,
                    d,
                    &mut state.scratch.x[r * d..(r + 1) * d],
                );
                r += 1;
            }
        }
        forward_core(
            &cfg,
            &weights.tensors,
            &state.idx,
            &Rows::Step { map: &state.map },
            &step_adapters(&state.sources, state.bound_sources, adapters),
            &mut state.kv,
            &mut state.scratch,
            self.pool.as_ref(),
        )?;
        // each admitted lane's next-token logits = its last prompt row
        let mut r = 0;
        for (&l, prompt) in lanes.iter().zip(prompts) {
            r += prompt.len();
            state.out[l * vo..(l + 1) * vo]
                .copy_from_slice(&state.scratch.logits[(r - 1) * vo..r * vo]);
        }
        for (&l, prompt) in lanes.iter().zip(prompts) {
            state.retired[l] = false;
            state.lens[l] = prompt.len();
        }
        Ok(&state.out)
    }

    /// Prefill one **chunk** of a prompt into lane `lane` — the
    /// incremental form of [`Engine::admit`] that lets the continuous
    /// scheduler interleave decode steps of other lanes while a long
    /// prompt streams into the cache (DESIGN.md §13).
    ///
    /// `chunk` holds the prompt tokens at absolute positions
    /// `start .. start + chunk.len()`. The first chunk (`start == 0`)
    /// claims a retired lane and marks it *prefilling*: the lane is
    /// excluded from steps and admissions until its `last` chunk lands.
    /// Continuation chunks must arrive in order (`start` equals the
    /// lane's consumed-token count). The `last` chunk leaves the lane's
    /// next-token logits in the session output buffer (row `lane`;
    /// earlier chunks leave it zero) and brings the lane live, exactly
    /// where a monolithic admission would.
    ///
    /// Bit-identity with the monolithic path, at any chunk size and
    /// thread count: every non-attention kernel is row-local, and the
    /// attention row at position `p` reads only lane `lane`'s cached
    /// K/V columns `0..=p` — values published either by this very pass
    /// (positions inside the chunk) or by earlier chunks, and identical
    /// either way because each cache row is written exactly once, by the
    /// same row-local projection over the same inputs. Chunking therefore
    /// changes *when* rows are computed, never *what* any row reads.
    ///
    /// `adapters` follows [`Engine::decode_step`] precedence (explicit
    /// per-lane views over the whole session, else bound sources). The
    /// caller must pass the same adapter for every chunk of one prompt —
    /// bindings via [`DecodeState::bind_adapter`] make that automatic.
    #[allow(clippy::too_many_arguments)]
    pub fn prefill_chunk<'s>(
        &self,
        state: &'s mut DecodeState,
        lane: usize,
        chunk: &[i32],
        start: usize,
        last: bool,
        weights: &DeviceWeights,
        adapters: &[Option<&QFactors<'_>>],
    ) -> anyhow::Result<&'s [f32]> {
        let cfg = state.cfg;
        if 1 + weights.tensors.len() != state.arity {
            bail!(
                "program {} expects {} inputs, got {}",
                state.prog,
                state.arity,
                1 + weights.tensors.len()
            );
        }
        let bsz = state.lanes();
        if lane >= bsz {
            bail!("prefill_chunk: lane {lane} out of range 0..{bsz}");
        }
        if !adapters.is_empty() {
            if adapters.len() != bsz {
                bail!("adapter list has {} entries for a session of {bsz}", adapters.len());
            }
            validate_adapter_shapes(&cfg, adapters)?;
        }
        let cap = state.kv.capacity();
        if chunk.is_empty() || start + chunk.len() > cap {
            bail!(
                "prefill_chunk: lane {lane} rows {start}..{} out of range 1..={cap}",
                start + chunk.len()
            );
        }
        if start == 0 {
            if !state.retired[lane] {
                bail!("prefill_chunk: lane {lane} is still live");
            }
            if state.prefilling[lane] {
                bail!("prefill_chunk: lane {lane} already has a chunked prefill in flight");
            }
        } else {
            if !state.prefilling[lane] {
                bail!("prefill_chunk: lane {lane} has no chunked prefill in flight");
            }
            if start != state.lens[lane] {
                bail!(
                    "prefill_chunk: lane {lane} chunk starts at {start}, expected {}",
                    state.lens[lane]
                );
            }
        }
        for &tok in chunk.iter() {
            if tok < 0 || tok as usize >= cfg.vocab {
                bail!("token {tok} out of vocab range 0..{}", cfg.vocab);
            }
        }
        state.idx.validate(&weights.tensors)?;
        let vo = cfg.vocab;
        state.out.resize(bsz * vo, 0.0);
        state.out[lane * vo..(lane + 1) * vo].fill(0.0);
        state.map.clear();
        for p in start..start + chunk.len() {
            state.map.push((lane, p));
        }
        let n = state.map.len();
        state.scratch.ensure(n, &cfg, self.compute_threads());
        let embed = pget(&weights.tensors, state.idx.embed)?;
        let pos_tab = pget(&weights.tensors, state.idx.pos)?;
        let d = cfg.d_model;
        for (i, &tok) in chunk.iter().enumerate() {
            embed_row(
                embed,
                pos_tab,
                tok as usize,
                start + i,
                d,
                &mut state.scratch.x[i * d..(i + 1) * d],
            );
        }
        if start == 0 {
            state.lens[lane] = 0;
            state.prefilling[lane] = true;
        }
        forward_core(
            &cfg,
            &weights.tensors,
            &state.idx,
            &Rows::Step { map: &state.map },
            &step_adapters(&state.sources, state.bound_sources, adapters),
            &mut state.kv,
            &mut state.scratch,
            self.pool.as_ref(),
        )?;
        state.lens[lane] = start + n;
        if last {
            // the lane's next-token logits = its final prompt row
            state.out[lane * vo..(lane + 1) * vo]
                .copy_from_slice(&state.scratch.logits[(n - 1) * vo..n * vo]);
            state.prefilling[lane] = false;
            state.retired[lane] = false;
        }
        Ok(&state.out)
    }
}

/// Every adapter site must name a known LoRA site with the model's
/// (m_out, n_in) — checked once up front so the apply loop can't panic
/// mid-forward on a shape mismatch. Also invoked by
/// [`DecodeState::bind_adapter`] so bound sources are validated once at
/// bind time, not per step.
pub(crate) fn validate_adapter_shapes(
    cfg: &ModelConfig,
    adapters: &[Option<&QFactors<'_>>],
) -> anyhow::Result<()> {
    for qf in adapters.iter().flatten() {
        for (site, sf) in &qf.sites {
            let short = site.rsplit_once('.').map_or(site.as_str(), |(_, s)| s);
            let (n_in, m_out) = cfg
                .site_shape(short)
                .with_context(|| format!("adapter targets unknown site {site}"))?;
            if (sf.m, sf.n) != (m_out, n_in) {
                bail!(
                    "adapter site {site}: ΔW is {}x{}, model expects {}x{}",
                    sf.m,
                    sf.n,
                    m_out,
                    n_in
                );
            }
        }
    }
    Ok(())
}

/// Positional indices into the flat weight list (param_names order) plus
/// the pre-rendered per-layer LoRA site names — resolved **once per
/// session** (or per one-shot forward), so the per-step hot loop performs
/// no name formatting, no map building, and no string allocation at all.
/// The weight list is positional by contract (`upload_weights` keeps
/// caller order, callers pass `param_names` order), exactly the contract
/// the old name map relied on when zipping names with tensors.
pub(crate) struct ParamIndex {
    n_params: usize,
    embed: usize,
    pos: usize,
    lnf_g: usize,
    lnf_b: usize,
    head: usize,
    /// Per layer: [ln1.g, ln1.b, wq, wk, wv, wo, ln2.g, ln2.b, w1, w2].
    layers: Vec<[usize; 10]>,
    /// Per layer, the adapter-site name strings, kernel order:
    /// [wq, wk, wv, wo, w1, w2].
    sites: Vec<[String; 6]>,
}

impl ParamIndex {
    pub(crate) fn new(cfg: &ModelConfig) -> Self {
        let names = cfg.param_names();
        let by_name: BTreeMap<&str, usize> =
            names.iter().enumerate().map(|(i, n)| (n.as_str(), i)).collect();
        // every looked-up name comes from the same schema that produced
        // `names`, so resolution cannot fail
        let find = |n: &str| *by_name.get(n).expect("schema name");
        let sites: Vec<[String; 6]> = (0..cfg.n_layers)
            .map(|l| {
                [
                    format!("l{l}.wq"),
                    format!("l{l}.wk"),
                    format!("l{l}.wv"),
                    format!("l{l}.wo"),
                    format!("l{l}.w1"),
                    format!("l{l}.w2"),
                ]
            })
            .collect();
        let layers = (0..cfg.n_layers)
            .map(|l| {
                let s = &sites[l];
                [
                    find(&format!("l{l}.ln1.g")),
                    find(&format!("l{l}.ln1.b")),
                    find(&s[0]),
                    find(&s[1]),
                    find(&s[2]),
                    find(&s[3]),
                    find(&format!("l{l}.ln2.g")),
                    find(&format!("l{l}.ln2.b")),
                    find(&s[4]),
                    find(&s[5]),
                ]
            })
            .collect();
        Self {
            n_params: names.len(),
            embed: find("embed"),
            pos: find("pos"),
            lnf_g: find("lnf.g"),
            lnf_b: find("lnf.b"),
            head: find("head"),
            layers,
            sites,
        }
    }

    /// The weight list must carry one tensor per schema parameter.
    fn validate(&self, weights: &[Tensor]) -> anyhow::Result<()> {
        if weights.len() != self.n_params {
            bail!("weight list has {} tensors, schema has {}", weights.len(), self.n_params);
        }
        Ok(())
    }
}

/// Parameter `i` of the weight list as an f32 slice.
#[inline]
fn pget(weights: &[Tensor], i: usize) -> anyhow::Result<&[f32]> {
    weights[i].as_f32().with_context(|| format!("parameter #{i} is not f32"))
}

/// `x_row = embed[tok] + pos[at]`.
#[inline]
fn embed_row(embed: &[f32], pos: &[f32], tok: usize, at: usize, d: usize, row: &mut [f32]) {
    let e = &embed[tok * d..(tok + 1) * d];
    let po = &pos[at * d..(at + 1) * d];
    for j in 0..d {
        row[j] = e[j] + po[j];
    }
}

/// Row-wise layernorm with gain/bias (eps matches model.py).
fn layernorm(x: &[f32], rows: usize, d: usize, g: &[f32], b: &[f32], out: &mut [f32]) {
    for i in 0..rows {
        let row = &x[i * d..(i + 1) * d];
        let orow = &mut out[i * d..(i + 1) * d];
        let mu = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for j in 0..d {
            orow[j] = g[j] * (row[j] - mu) * inv + b[j];
        }
    }
}

/// jax.nn.gelu's default tanh approximation.
fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// The row → (lane, position) mapping of one pass through
/// [`forward_core`].
enum Rows<'a> {
    /// `bsz` lanes × `t` contiguous positions starting at 0, row-major
    /// (full forward and prefill).
    Full { bsz: usize, t: usize },
    /// One row per still-active lane (incremental decode).
    Step { map: &'a [(usize, usize)] },
}

impl Rows<'_> {
    #[inline]
    fn n_rows(&self) -> usize {
        match *self {
            Rows::Full { bsz, t } => bsz * t,
            Rows::Step { map } => map.len(),
        }
    }

    #[inline]
    fn lane_pos(&self, r: usize) -> (usize, usize) {
        match *self {
            Rows::Full { t, .. } => (r / t, r % t),
            Rows::Step { map } => map[r],
        }
    }
}

/// The per-lane adapter inputs of one pass through [`forward_core`]:
/// either explicit borrowed [`QFactors`] views (the per-call surface) or
/// the session's bound `Arc<dyn FactorSource>` handles resolved per site
/// on demand — the continuous-batching hot path, which never rebuilds a
/// per-lane `QFactors` map per step ([`DecodeState::bind_adapter`]).
pub(crate) enum PassAdapters<'a> {
    None,
    /// Explicit per-lane factor views (one per batch lane; `None` = base).
    Views(&'a [Option<&'a QFactors<'a>>]),
    /// Session-owned per-lane sources, asked per (layer, site) directly.
    Sources(&'a [Option<Arc<dyn FactorSource>>]),
}

impl PassAdapters<'_> {
    #[inline]
    fn is_none(&self) -> bool {
        matches!(self, PassAdapters::None)
    }

    /// Run `apply` on lane `b`'s factors for `site`, if the lane has an
    /// adapter exposing that site.
    #[inline]
    fn with_site(&self, b: usize, site: &str, apply: impl FnOnce(&SiteFactors<'_>)) {
        match self {
            PassAdapters::None => {}
            PassAdapters::Views(v) => {
                if let Some(sf) = v[b].and_then(|q| q.site(site)) {
                    apply(sf);
                }
            }
            PassAdapters::Sources(s) => {
                if let Some(sf) = s[b].as_ref().and_then(|src| src.site(site)) {
                    apply(&sf);
                }
            }
        }
    }
}

/// Wrap an explicit per-call adapter slice (empty = none anywhere).
#[inline]
fn views<'a>(adapters: &'a [Option<&'a QFactors<'a>>]) -> PassAdapters<'a> {
    if adapters.is_empty() {
        PassAdapters::None
    } else {
        PassAdapters::Views(adapters)
    }
}

/// Adapter inputs for a step/admit: explicit views win, otherwise the
/// session's bound sources, otherwise none. Takes the `DecodeState`
/// fields rather than the state so callers keep disjoint borrows of
/// `state.kv`/`state.scratch` for `forward_core`.
#[inline]
fn step_adapters<'a>(
    sources: &'a [Option<Arc<dyn FactorSource>>],
    bound: usize,
    adapters: &'a [Option<&'a QFactors<'a>>],
) -> PassAdapters<'a> {
    if !adapters.is_empty() {
        PassAdapters::Views(adapters)
    } else if bound > 0 {
        PassAdapters::Sources(sources)
    } else {
        PassAdapters::None
    }
}

/// Accumulate every present adapter's factor-form delta for `site` into
/// `y`. In `Full` mode lane `b` owns rows `b·t .. (b+1)·t`; in `Step`
/// mode each row is its own lane. `(n, m)` is the site's
/// (input, output) width.
#[allow(clippy::too_many_arguments)] // one GEMM epilogue, not an API
fn apply_adapters(
    rows: &Rows<'_>,
    adapters: &PassAdapters<'_>,
    site: &str,
    x: &[f32],
    (n, m): (usize, usize),
    scaling: f32,
    y: &mut [f32],
    fs: &mut FactorScratch,
) {
    if adapters.is_none() {
        return;
    }
    match *rows {
        Rows::Full { bsz, t } => {
            for b in 0..bsz {
                adapters.with_site(b, site, |sf| {
                    sf.apply_delta_acc_into(
                        &x[b * t * n..(b + 1) * t * n],
                        t,
                        scaling,
                        &mut y[b * t * m..(b + 1) * t * m],
                        fs,
                    );
                });
            }
        }
        Rows::Step { map } => {
            for (r, &(b, _)) in map.iter().enumerate() {
                adapters.with_site(b, site, |sf| {
                    sf.apply_delta_acc_into(
                        &x[r * n..(r + 1) * n],
                        1,
                        scaling,
                        &mut y[r * m..(r + 1) * m],
                        fs,
                    );
                });
            }
        }
    }
}

/// One partitioned (or serial) matmul: the pool variant is bit-identical
/// to the serial kernel (whole output rows, same accumulation order).
/// A panicking pool partition surfaces as `Err` (contained by the pool;
/// the caller fails only this forward's request group).
#[inline]
fn mm(
    pool: Option<&ComputePool>,
    a: &[f32],
    m: usize,
    k: usize,
    b: &[f32],
    n: usize,
    c: &mut [f32],
) -> anyhow::Result<()> {
    match pool {
        Some(p) => p.matmul_flat(a, m, k, b, n, c).map_err(|p| anyhow!("compute pool: {p}")),
        None => {
            matmul_flat(a, m, k, b, n, c);
            Ok(())
        }
    }
}

/// The attention inner loop over global rows `lo..hi` of one pass: each
/// row's causal windowed softmax against its lane's cache. `att` holds
/// exactly the `(hi - lo) × d` output rows of this partition; `scores`
/// is this partition's private score window (≥ the largest window). One
/// partition per compute-pool task — row content is partition-invariant,
/// so threading never changes a bit.
#[allow(clippy::too_many_arguments)] // the engine's inner loop, not an API
fn attention_rows(
    rows: &Rows<'_>,
    lo: usize,
    hi: usize,
    q: &[f32],
    kv: &KvCache,
    layer: usize,
    nh: usize,
    hd: usize,
    att_scale: f32,
    att: &mut [f32],
    scores: &mut [f32],
) {
    let d = nh * hd;
    att.fill(0.0);
    for r in lo..hi {
        let (b, pos) = rows.lane_pos(r);
        let klane = kv.k_lane(layer, b);
        let vlane = kv.v_lane(layer, b);
        for h in 0..nh {
            let off = h * hd;
            let qrow = &q[r * d + off..r * d + off + hd];
            // causal window: this row's lane has exactly pos + 1
            // cached positions (its own K/V was just published).
            // Masked-future terms of the full-row softmax exp to 0.0
            // exactly, so restricting to the window is bit-identical.
            let win = &mut scores[..pos + 1];
            for (j, s) in win.iter_mut().enumerate() {
                *s = dot(qrow, &klane[j * d + off..j * d + off + hd]) * att_scale;
            }
            let max = win.iter().fold(f32::NEG_INFINITY, |m, &s| m.max(s));
            let mut denom = 0.0;
            for s in win.iter_mut() {
                *s = (*s - max).exp();
                denom += *s;
            }
            // weighted V accumulation: simd::axpy adds element-wise in
            // the same order as the scalar loop, so lane-blocking the
            // head dim never changes a bit
            let orow = &mut att[(r - lo) * d + off..(r - lo) * d + off + hd];
            for (j, &w) in win.iter().enumerate() {
                let w = w / denom;
                simd::axpy(orow, w, &vlane[j * d + off..j * d + off + hd]);
            }
        }
    }
}

/// The shared layer core (python/compile/model.py `_forward_impl`): runs
/// every transformer layer plus the head over the rows described by
/// `rows`, with optional per-lane factor-form adapter deltas on every
/// LoRA site. `sc.x` must hold the embedded input rows; K/V of each row
/// is published to `kv` before attention, and attention *reads the
/// cache*, so a step row attends across everything its lane has consumed.
/// `weights` is the positional parameter list addressed through `idx`
/// (resolved once per session). Leaves `rows × vocab` logits in
/// `sc.logits`. When `pool` is set, projections and the attention rows
/// are partitioned across it (bit-identical at any width).
#[allow(clippy::too_many_arguments)] // the engine's one inner loop, not an API
fn forward_core(
    cfg: &ModelConfig,
    weights: &[Tensor],
    idx: &ParamIndex,
    rows: &Rows<'_>,
    adapters: &PassAdapters<'_>,
    kv: &mut KvCache,
    sc: &mut Scratch,
    pool: Option<&ComputePool>,
) -> anyhow::Result<()> {
    let (d, f, vo) = (cfg.d_model, cfg.d_ff, cfg.vocab);
    let nh = cfg.n_heads;
    if d % nh != 0 {
        bail!("d_model {d} not divisible by n_heads {nh}");
    }
    let hd = d / nh;
    let n = rows.n_rows();
    let lora_s = cfg.lora_scaling();
    let att_scale = 1.0 / (hd as f32).sqrt();
    // per-partition score-window stride (Scratch::ensure sized one slot
    // per pool thread)
    let sstride = cfg.seq_len.max(1);
    let Scratch { x, hx, q, k, v, att, proj, h1, h2, scores, logits, factor } = sc;

    for l in 0..cfg.n_layers {
        let li = &idx.layers[l];
        let site = &idx.sites[l];
        // attention block
        let (g1, b1) = (pget(weights, li[0])?, pget(weights, li[1])?);
        layernorm(x, n, d, g1, b1, hx);
        mm(pool, hx, n, d, pget(weights, li[2])?, d, q)?;
        apply_adapters(rows, adapters, &site[0], hx, (d, d), lora_s, q, factor);
        mm(pool, hx, n, d, pget(weights, li[3])?, d, k)?;
        apply_adapters(rows, adapters, &site[1], hx, (d, d), lora_s, k, factor);
        mm(pool, hx, n, d, pget(weights, li[4])?, d, v)?;
        apply_adapters(rows, adapters, &site[2], hx, (d, d), lora_s, v, factor);
        // publish this pass's K/V columns, then attend reading the cache
        for r in 0..n {
            let (b, pos) = rows.lane_pos(r);
            kv.write(l, b, pos, &k[r * d..(r + 1) * d], &v[r * d..(r + 1) * d]);
        }
        match pool {
            Some(p) if p.threads() > 1 && n > 1 => {
                let t = p.threads().min(n);
                let chunk = n.div_ceil(t);
                let tasks = n.div_ceil(chunk);
                let att_ptr = SendPtr(att.as_mut_ptr());
                let sc_ptr = SendPtr(scores.as_mut_ptr());
                let kv_ro: &KvCache = kv;
                let q_ro: &[f32] = q;
                p.run(tasks, &|i| {
                    let lo = i * chunk;
                    let hi = (lo + chunk).min(n);
                    // Safety: tasks cover disjoint row ranges of `att`
                    // and disjoint score slots; the run barrier bounds
                    // every borrow.
                    let att_c = unsafe {
                        std::slice::from_raw_parts_mut(att_ptr.0.add(lo * d), (hi - lo) * d)
                    };
                    let sc_c = unsafe {
                        std::slice::from_raw_parts_mut(sc_ptr.0.add(i * sstride), sstride)
                    };
                    attention_rows(rows, lo, hi, q_ro, kv_ro, l, nh, hd, att_scale, att_c, sc_c);
                })
                .map_err(|p| anyhow!("compute pool: {p}"))?;
            }
            _ => {
                attention_rows(rows, 0, n, q, kv, l, nh, hd, att_scale, att, &mut scores[..sstride])
            }
        }
        mm(pool, att, n, d, pget(weights, li[5])?, d, proj)?;
        apply_adapters(rows, adapters, &site[3], att, (d, d), lora_s, proj, factor);
        for (xi, pi) in x.iter_mut().zip(proj.iter()) {
            *xi += pi;
        }

        // FFN block
        let (g2, b2) = (pget(weights, li[6])?, pget(weights, li[7])?);
        layernorm(x, n, d, g2, b2, hx);
        mm(pool, hx, n, d, pget(weights, li[8])?, f, h1)?;
        apply_adapters(rows, adapters, &site[4], hx, (d, f), lora_s, h1, factor);
        if cfg.act_silu {
            for z in h1.iter_mut() {
                *z = silu(*z);
            }
        } else {
            for z in h1.iter_mut() {
                *z = gelu(*z);
            }
        }
        mm(pool, h1, n, f, pget(weights, li[9])?, d, h2)?;
        apply_adapters(rows, adapters, &site[5], h1, (f, d), lora_s, h2, factor);
        for (xi, hi) in x.iter_mut().zip(h2.iter()) {
            *xi += hi;
        }
    }

    layernorm(x, n, d, pget(weights, idx.lnf_g)?, pget(weights, idx.lnf_b)?, hx);
    mm(pool, hx, n, d, pget(weights, idx.head)?, vo, logits)?;
    Ok(())
}

/// The full-recompute forward (the decode oracle): every (lane, position)
/// row of a padded `[bsz, t]` batch through the shared core, returning
/// `bsz · t · vocab` logits.
fn ref_forward(
    cfg: &ModelConfig,
    weights: &[Tensor],
    tokens: &[i32],
    bsz: usize,
    t: usize,
    adapters: &[Option<&QFactors<'_>>],
    pool: Option<&ComputePool>,
) -> anyhow::Result<Vec<f32>> {
    let idx = ParamIndex::new(cfg);
    idx.validate(weights)?;
    if tokens.len() != bsz * t {
        bail!("token batch {}, expected {}x{}", tokens.len(), bsz, t);
    }
    if t > cfg.seq_len {
        bail!("sequence length {t} exceeds model seq_len {}", cfg.seq_len);
    }
    let d = cfg.d_model;
    let embed = pget(weights, idx.embed)?;
    let pos = pget(weights, idx.pos)?;
    let mut sc = Scratch::default();
    sc.ensure(bsz * t, cfg, pool.map_or(1, ComputePool::threads));
    for r in 0..bsz * t {
        let tok = tokens[r];
        if tok < 0 || tok as usize >= cfg.vocab {
            bail!("token {tok} out of vocab range 0..{}", cfg.vocab);
        }
        embed_row(embed, pos, tok as usize, r % t, d, &mut sc.x[r * d..(r + 1) * d]);
    }
    // The oracle path allocates per call by design (it always did — the
    // pre-KV forward built ~10 per-call buffers); the K/V cache here is
    // just two more of the same size, routing attention through the one
    // shared core. Steady-state decode never takes this path.
    let mut kv = KvCache::new(cfg.n_layers, bsz, t.max(1), d);
    forward_core(cfg, weights, &idx, &Rows::Full { bsz, t }, &views(adapters), &mut kv, &mut sc, pool)?;
    Ok(sc.logits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{merge_adapter, BaseWeights};
    use crate::testutil::synth::{synth_model_config, synth_quantized_adapter, write_synth_model};

    /// The **pre-PR-4 forward, verbatim** (masked full-row softmax, no KV
    /// cache, per-call buffers): an oracle *independent of `forward_core`*
    /// so a numerical drift in the shared kernel cannot hide by agreeing
    /// with itself. Copied from git history (`0527b7e`), not refactored.
    mod legacy {
        use super::super::{gelu, layernorm, silu, validate_adapter_shapes};
        use crate::adapter::fmt::Tensor;
        use crate::loraquant::QFactors;
        use crate::model::ModelConfig;
        use crate::tensor::dot;
        use anyhow::{bail, Context};
        use std::collections::BTreeMap;

        struct Params<'a> {
            by_name: BTreeMap<String, &'a Tensor>,
        }

        impl<'a> Params<'a> {
            fn new(cfg: &ModelConfig, weights: &'a [Tensor]) -> anyhow::Result<Self> {
                let names = cfg.param_names();
                if names.len() != weights.len() {
                    bail!("weight list has {} tensors, schema has {}", weights.len(), names.len());
                }
                Ok(Self { by_name: names.into_iter().zip(weights).collect() })
            }

            fn get(&self, name: &str) -> anyhow::Result<&'a [f32]> {
                self.by_name
                    .get(name)
                    .with_context(|| format!("missing parameter {name}"))?
                    .as_f32()
                    .with_context(|| format!("parameter {name} is not f32"))
            }
        }

        fn matmul_flat(a: &[f32], m: usize, k: usize, b: &[f32], n: usize, c: &mut [f32]) {
            c.fill(0.0);
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n..(i + 1) * n];
                // One deliberate deviation from the historical copy: the
                // `av == 0.0 => continue` sparsity skip was removed, the
                // same acknowledged IEEE hazard fix applied to
                // `tensor::ops` (0·NaN/0·∞ must propagate, −0.0 terms
                // must participate in the sum). Both sides of the
                // bit-identity gate accumulate every term.
                for (p, &av) in arow.iter().enumerate() {
                    let brow = &b[p * n..(p + 1) * n];
                    for j in 0..n {
                        crow[j] += av * brow[j];
                    }
                }
            }
        }

        fn apply_adapter_site(
            adapters: &[Option<&QFactors<'_>>],
            site: &str,
            x: &[f32],
            t: usize,
            (n, m): (usize, usize),
            scaling: f32,
            y: &mut [f32],
        ) {
            for (b, qf) in adapters.iter().enumerate() {
                let Some(sf) = qf.and_then(|q| q.site(site)) else { continue };
                sf.apply_delta_acc(
                    &x[b * t * n..(b + 1) * t * n],
                    t,
                    scaling,
                    &mut y[b * t * m..(b + 1) * t * m],
                );
            }
        }

        pub(super) fn ref_forward(
            cfg: &ModelConfig,
            weights: &[Tensor],
            tokens: &[i32],
            bsz: usize,
            t: usize,
            adapters: &[Option<&QFactors<'_>>],
        ) -> anyhow::Result<Vec<f32>> {
            if !adapters.is_empty() {
                validate_adapter_shapes(cfg, adapters)?;
            }
            let p = Params::new(cfg, weights)?;
            let (d, f, v) = (cfg.d_model, cfg.d_ff, cfg.vocab);
            let nh = cfg.n_heads;
            if d % nh != 0 {
                bail!("d_model {d} not divisible by n_heads {nh}");
            }
            let hd = d / nh;
            if tokens.len() != bsz * t {
                bail!("token batch {}, expected {}x{}", tokens.len(), bsz, t);
            }
            if t > cfg.seq_len {
                bail!("sequence length {t} exceeds model seq_len {}", cfg.seq_len);
            }

            // x = embed[tokens] + pos[:t]
            let embed = p.get("embed")?;
            let pos = p.get("pos")?;
            let rows = bsz * t;
            let mut x = vec![0.0f32; rows * d];
            for b in 0..bsz {
                for i in 0..t {
                    let tok = tokens[b * t + i];
                    if tok < 0 || tok as usize >= cfg.vocab {
                        bail!("token {tok} out of vocab range 0..{}", cfg.vocab);
                    }
                    let e = &embed[tok as usize * d..(tok as usize + 1) * d];
                    let po = &pos[i * d..(i + 1) * d];
                    let row = &mut x[(b * t + i) * d..(b * t + i + 1) * d];
                    for j in 0..d {
                        row[j] = e[j] + po[j];
                    }
                }
            }

            let lora_s = cfg.lora_scaling();
            let att_scale = 1.0 / (hd as f32).sqrt();
            let mut hx = vec![0.0f32; rows * d];
            let mut q = vec![0.0f32; rows * d];
            let mut k = vec![0.0f32; rows * d];
            let mut vv = vec![0.0f32; rows * d];
            let mut att_out = vec![0.0f32; rows * d];
            let mut proj = vec![0.0f32; rows * d];
            let mut h1 = vec![0.0f32; rows * f];
            let mut h2 = vec![0.0f32; rows * d];
            let mut scores = vec![0.0f32; t];

            for l in 0..cfg.n_layers {
                // attention block
                let (g1, b1) =
                    (p.get(&format!("l{l}.ln1.g"))?, p.get(&format!("l{l}.ln1.b"))?);
                layernorm(&x, rows, d, g1, b1, &mut hx);
                matmul_flat(&hx, rows, d, p.get(&format!("l{l}.wq"))?, d, &mut q);
                apply_adapter_site(adapters, &format!("l{l}.wq"), &hx, t, (d, d), lora_s, &mut q);
                matmul_flat(&hx, rows, d, p.get(&format!("l{l}.wk"))?, d, &mut k);
                apply_adapter_site(adapters, &format!("l{l}.wk"), &hx, t, (d, d), lora_s, &mut k);
                matmul_flat(&hx, rows, d, p.get(&format!("l{l}.wv"))?, d, &mut vv);
                apply_adapter_site(adapters, &format!("l{l}.wv"), &hx, t, (d, d), lora_s, &mut vv);
                att_out.fill(0.0);
                for b in 0..bsz {
                    for h in 0..nh {
                        let off = h * hd;
                        for i in 0..t {
                            let qrow = &q[(b * t + i) * d + off..(b * t + i) * d + off + hd];
                            // causal scores, masked positions at -1e9 (as in
                            // the jax model: mask *before* softmax over the
                            // full row)
                            for (j, s) in scores.iter_mut().enumerate() {
                                *s = if j > i {
                                    -1e9
                                } else {
                                    let krow =
                                        &k[(b * t + j) * d + off..(b * t + j) * d + off + hd];
                                    dot(qrow, krow) * att_scale
                                };
                            }
                            let max = scores.iter().fold(f32::NEG_INFINITY, |m, &s| m.max(s));
                            let mut denom = 0.0;
                            for s in scores.iter_mut() {
                                *s = (*s - max).exp();
                                denom += *s;
                            }
                            let orow =
                                &mut att_out[(b * t + i) * d + off..(b * t + i) * d + off + hd];
                            for (j, &w) in scores.iter().enumerate() {
                                let w = w / denom;
                                let vrow =
                                    &vv[(b * t + j) * d + off..(b * t + j) * d + off + hd];
                                for u in 0..hd {
                                    orow[u] += w * vrow[u];
                                }
                            }
                        }
                    }
                }
                matmul_flat(&att_out, rows, d, p.get(&format!("l{l}.wo"))?, d, &mut proj);
                apply_adapter_site(
                    adapters,
                    &format!("l{l}.wo"),
                    &att_out,
                    t,
                    (d, d),
                    lora_s,
                    &mut proj,
                );
                for (xi, pi) in x.iter_mut().zip(&proj) {
                    *xi += pi;
                }

                // FFN block
                let (g2, b2) =
                    (p.get(&format!("l{l}.ln2.g"))?, p.get(&format!("l{l}.ln2.b"))?);
                layernorm(&x, rows, d, g2, b2, &mut hx);
                matmul_flat(&hx, rows, d, p.get(&format!("l{l}.w1"))?, f, &mut h1);
                apply_adapter_site(adapters, &format!("l{l}.w1"), &hx, t, (d, f), lora_s, &mut h1);
                if cfg.act_silu {
                    for z in h1.iter_mut() {
                        *z = silu(*z);
                    }
                } else {
                    for z in h1.iter_mut() {
                        *z = gelu(*z);
                    }
                }
                matmul_flat(&h1, rows, f, p.get(&format!("l{l}.w2"))?, d, &mut h2);
                apply_adapter_site(adapters, &format!("l{l}.w2"), &h1, t, (f, d), lora_s, &mut h2);
                for (xi, hi) in x.iter_mut().zip(&h2) {
                    *xi += hi;
                }
            }

            layernorm(&x, rows, d, p.get("lnf.g")?, p.get("lnf.b")?, &mut hx);
            let mut logits = vec![0.0f32; rows * v];
            matmul_flat(&hx, rows, d, p.get("head")?, v, &mut logits);
            Ok(logits)
        }
    }

    fn temp_artifacts(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("lq_sim_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn forward_shapes_and_determinism() {
        let dir = temp_artifacts("fwd");
        let cfg = synth_model_config();
        write_synth_model(&dir, "synth", &cfg, &[4], 7).unwrap();
        let base = BaseWeights::load(dir.join("synth")).unwrap();
        let mut engine = Engine::new(&dir).unwrap();
        engine.load_model_fwd("synth", 4, base.cfg.param_names().len()).unwrap();
        assert!(engine.has_program("synth/b4"));
        let merged = merge_adapter(&base, &std::collections::BTreeMap::new()).unwrap();
        let w = engine.upload_weights(&merged).unwrap();
        assert!(w.bytes() > 0);
        let tokens = vec![1i32; 4 * cfg.seq_len];
        let l1 = engine.forward("synth/b4", &tokens, &[4, cfg.seq_len], &w).unwrap();
        let l2 = engine.forward("synth/b4", &tokens, &[4, cfg.seq_len], &w).unwrap();
        assert_eq!(l1.len(), 4 * cfg.seq_len * cfg.vocab);
        assert_eq!(l1, l2, "same inputs must give identical logits");
        assert!(l1.iter().all(|x| x.is_finite()));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn forward_depends_on_tokens_and_weights() {
        let dir = temp_artifacts("sens");
        let cfg = synth_model_config();
        write_synth_model(&dir, "synth", &cfg, &[1], 11).unwrap();
        let base = BaseWeights::load(dir.join("synth")).unwrap();
        let mut engine = Engine::new(&dir).unwrap();
        engine.load_model_fwd("synth", 1, base.cfg.param_names().len()).unwrap();
        let merged = merge_adapter(&base, &std::collections::BTreeMap::new()).unwrap();
        let w = engine.upload_weights(&merged).unwrap();
        let mut t1 = vec![1i32; cfg.seq_len];
        let l1 = engine.forward("synth/b1", &t1, &[1, cfg.seq_len], &w).unwrap();
        t1[1] = 5;
        let l2 = engine.forward("synth/b1", &t1, &[1, cfg.seq_len], &w).unwrap();
        assert_ne!(l1, l2, "different tokens must change logits");
        let _ = std::fs::remove_dir_all(&dir);
    }

    fn rel_err(a: &[f32], b: &[f32]) -> f32 {
        let num: f32 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>().sqrt();
        let den: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
        num / den.max(1e-12)
    }

    #[test]
    fn factor_form_matches_merged_forward() {
        let dir = temp_artifacts("factor");
        let cfg = synth_model_config();
        write_synth_model(&dir, "synth", &cfg, &[2], 19).unwrap();
        let base = BaseWeights::load(dir.join("synth")).unwrap();
        let mut engine = Engine::new(&dir).unwrap();
        engine.load_model_fwd("synth", 2, base.cfg.param_names().len()).unwrap();
        let stored = synth_quantized_adapter(&cfg, 33);
        let w_merged = engine
            .upload_weights(&merge_adapter(&base, &stored.deltas()).unwrap())
            .unwrap();
        let w_base = engine
            .upload_weights(&merge_adapter(&base, &std::collections::BTreeMap::new()).unwrap())
            .unwrap();
        let t = cfg.seq_len;
        let mut tokens = vec![1i32; 2 * t];
        tokens[t] = 7; // distinct second row
        let l_merged = engine.forward("synth/b2", &tokens, &[2, t], &w_merged).unwrap();
        let qf = stored.factors();
        let l_factor = engine
            .forward_with_adapters("synth/b2", &tokens, &[2, t], &w_base, &[Some(&qf), Some(&qf)])
            .unwrap();
        // identical math up to f32 re-association: merged folds ΔW into W,
        // factor-form adds s·(x@A′ᵀ)@B′ᵀ on the activations
        assert!(rel_err(&l_factor, &l_merged) < 1e-4, "rel {}", rel_err(&l_factor, &l_merged));

        // heterogeneous batch: row 0 unadapted, row 1 adapted — per-row
        // outputs must be bitwise identical to the homogeneous runs
        let l_base = engine.forward("synth/b2", &tokens, &[2, t], &w_base).unwrap();
        let l_mixed = engine
            .forward_with_adapters("synth/b2", &tokens, &[2, t], &w_base, &[None, Some(&qf)])
            .unwrap();
        let row = t * cfg.vocab;
        assert_eq!(l_mixed[..row], l_base[..row], "unadapted row must be pure base");
        assert_eq!(l_mixed[row..], l_factor[row..], "adapted row must match factor path");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn factor_form_rejects_bad_adapters() {
        let dir = temp_artifacts("factorbad");
        let cfg = synth_model_config();
        write_synth_model(&dir, "synth", &cfg, &[2], 23).unwrap();
        let base = BaseWeights::load(dir.join("synth")).unwrap();
        let mut engine = Engine::new(&dir).unwrap();
        engine.load_model_fwd("synth", 2, base.cfg.param_names().len()).unwrap();
        let w_base = engine
            .upload_weights(&merge_adapter(&base, &std::collections::BTreeMap::new()).unwrap())
            .unwrap();
        let stored = synth_quantized_adapter(&cfg, 5);
        let qf = stored.factors();
        let t = cfg.seq_len;
        let tokens = vec![1i32; 2 * t];
        // arity mismatch: one adapter entry for a batch of two
        let err = engine
            .forward_with_adapters("synth/b2", &tokens, &[2, t], &w_base, &[Some(&qf)])
            .unwrap_err();
        assert!(err.to_string().contains("adapter list"));
        // shape mismatch: wrong model for this adapter
        let bigger = ModelConfig { d_model: cfg.d_model * 2, ..cfg };
        let wrong = synth_quantized_adapter(&bigger, 6);
        let wrong_qf = wrong.factors();
        let err = engine
            .forward_with_adapters(
                "synth/b2",
                &tokens,
                &[2, t],
                &w_base,
                &[Some(&wrong_qf), None],
            )
            .unwrap_err();
        assert!(err.to_string().contains("model expects"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_bad_inputs() {
        let dir = temp_artifacts("bad");
        let cfg = synth_model_config();
        write_synth_model(&dir, "synth", &cfg, &[1], 3).unwrap();
        let mut engine = Engine::new(&dir).unwrap();
        assert!(engine.load_program("x", "x.hlo.txt", 2).is_err());
        assert!(engine.load_model_fwd("synth", 1, 3).is_err(), "wrong n_params must fail");
        engine
            .load_model_fwd("synth", 1, cfg.param_names().len())
            .unwrap();
        let w = engine.upload_weights(&[]).unwrap();
        let err = engine.forward("synth/b1", &[1], &[1, 1], &w).unwrap_err();
        assert!(err.to_string().contains("expects"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Build the standard incremental-vs-oracle fixture: engine, merged
    /// base weights, quantized adapter.
    fn kv_fixture(tag: &str) -> (PathBuf, ModelConfig, Engine, DeviceWeights, DeviceWeights) {
        let dir = temp_artifacts(tag);
        let cfg = synth_model_config();
        write_synth_model(&dir, "synth", &cfg, &[4], 77).unwrap();
        let base = BaseWeights::load(dir.join("synth")).unwrap();
        let mut engine = Engine::new(&dir).unwrap();
        engine.load_model_fwd("synth", 4, base.cfg.param_names().len()).unwrap();
        let stored = synth_quantized_adapter(&cfg, 51);
        let w_merged = engine
            .upload_weights(&merge_adapter(&base, &stored.deltas()).unwrap())
            .unwrap();
        let w_base = engine
            .upload_weights(&merge_adapter(&base, &std::collections::BTreeMap::new()).unwrap())
            .unwrap();
        (dir, cfg, engine, w_merged, w_base)
    }

    /// The refactor gate: the shared-core forward (KV-cache reads,
    /// windowed softmax) must be **bit-identical** to the verbatim
    /// pre-PR-4 implementation — base weights, merged adapter, and the
    /// per-row factor path, over varied token patterns. This is the
    /// independent oracle: it shares no kernel code with `forward_core`.
    #[test]
    fn shared_core_bit_identical_to_legacy_forward() {
        let (dir, cfg, engine, w_merged, w_base) = kv_fixture("kvlegacy");
        let t = cfg.seq_len;
        let mut tokens = vec![0i32; 3 * t];
        for (i, tok) in tokens.iter_mut().enumerate() {
            *tok = ((i * 7 + i / t) % cfg.vocab) as i32;
        }
        for w in [&w_merged, &w_base] {
            let new = engine.forward("synth/b4", &tokens, &[3, t], w).unwrap();
            let old =
                legacy::ref_forward(&cfg, &w.tensors, &tokens, 3, t, &[]).unwrap();
            assert_eq!(new, old, "base/merged forward must match the pre-KV oracle bitwise");
        }
        let stored = synth_quantized_adapter(&cfg, 51);
        let qf = stored.factors();
        let adapters = [None, Some(&qf), Some(&qf)];
        let new = engine
            .forward_with_adapters("synth/b4", &tokens, &[3, t], &w_base, &adapters)
            .unwrap();
        let old =
            legacy::ref_forward(&cfg, &w_base.tensors, &tokens, 3, t, &adapters).unwrap();
        assert_eq!(new, old, "factor-path forward must match the pre-KV oracle bitwise");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prefill_rows_match_full_forward_exactly() {
        let (dir, cfg, engine, w, _) = kv_fixture("kvpre");
        let t = cfg.seq_len;
        let vo = cfg.vocab;
        // ragged prompts, padded full-length lanes (PAD = 0)
        let lens = [3usize, 7, 1];
        let mut seqs: Vec<Vec<i32>> = vec![vec![0; t]; 3];
        for (k, s) in seqs.iter_mut().enumerate() {
            for i in 0..lens[k] {
                s[i] = 1 + ((k * 7 + i * 3) % (cfg.vocab - 1)) as i32;
            }
        }
        let flat: Vec<i32> = seqs.iter().flatten().copied().collect();
        let full = engine.forward("synth/b4", &flat, &[3, t], &w).unwrap();
        let (state, logits) = engine.prefill("synth/b4", &seqs, &lens, &w, &[]).unwrap();
        assert_eq!(state.lanes(), 3);
        assert_eq!(logits.len(), 3 * vo);
        for (k, &len) in lens.iter().enumerate() {
            assert_eq!(state.lane_len(k), len);
            let want = &full[(k * t + len - 1) * vo..(k * t + len) * vo];
            assert_eq!(&logits[k * vo..(k + 1) * vo], want, "lane {k} prefill row");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Greedy-extend `steps` tokens two ways — full recompute vs
    /// prefill + decode_step — asserting bit-identical logits rows at
    /// every step. Covers merged (no adapters) and factor paths.
    fn assert_incremental_matches_full(
        engine: &Engine,
        cfg: &ModelConfig,
        w: &DeviceWeights,
        adapters: &[Option<&QFactors<'_>>],
        steps: usize,
    ) {
        let t = cfg.seq_len;
        let vo = cfg.vocab;
        let lens = [2usize, 5];
        let mut seqs: Vec<Vec<i32>> = vec![vec![0; t]; 2];
        for (k, s) in seqs.iter_mut().enumerate() {
            for i in 0..lens[k] {
                s[i] = 1 + ((k * 5 + i) % (cfg.vocab - 1)) as i32;
            }
        }
        let mut pos = lens;
        let (mut state, logits) =
            engine.prefill("synth/b4", &seqs, &lens, w, adapters).unwrap();
        let mut step_logits = logits;
        for step in 0..steps {
            // pick each lane's next token from the incremental logits...
            let mut last = vec![0i32; 2];
            for k in 0..2 {
                let row = &step_logits[k * vo..(k + 1) * vo];
                let best =
                    (0..vo).max_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap()).unwrap();
                seqs[k][pos[k]] = best as i32;
                last[k] = best as i32;
                pos[k] += 1;
            }
            // ...and check the oracle agrees on the *next* logits row
            let flat: Vec<i32> = seqs.iter().flatten().copied().collect();
            let full = engine
                .forward_with_adapters("synth/b4", &flat, &[2, t], w, adapters)
                .unwrap();
            step_logits =
                engine.decode_step(&mut state, w, adapters, &last).unwrap().to_vec();
            for k in 0..2 {
                let want = &full[(k * t + pos[k] - 1) * vo..(k * t + pos[k]) * vo];
                assert_eq!(
                    &step_logits[k * vo..(k + 1) * vo],
                    want,
                    "step {step} lane {k}: incremental must be bit-identical to the oracle"
                );
            }
        }
    }

    #[test]
    fn decode_step_bit_identical_to_full_recompute() {
        let (dir, cfg, engine, w_merged, w_base) = kv_fixture("kvstep");
        assert_incremental_matches_full(&engine, &cfg, &w_merged, &[], 6);
        let stored = synth_quantized_adapter(&cfg, 51);
        let qf = stored.factors();
        assert_incremental_matches_full(&engine, &cfg, &w_base, &[Some(&qf), Some(&qf)], 6);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn threaded_prefill_is_bit_identical() {
        let (dir, cfg, mut engine, w, _) = kv_fixture("kvthreads");
        let lens = [cfg.seq_len - 2, 4];
        let seqs: Vec<Vec<i32>> =
            (0..2).map(|k| (0..cfg.seq_len as i32).map(|i| (i + k) % 9 + 1).collect()).collect();
        let (_, serial) = engine.prefill("synth/b4", &seqs, &lens, &w, &[]).unwrap();
        for threads in [2usize, 4] {
            engine.set_compute_threads(threads);
            assert_eq!(engine.compute_threads(), threads);
            let (_, par) = engine.prefill("synth/b4", &seqs, &lens, &w, &[]).unwrap();
            assert_eq!(par, serial, "threads={threads} must not change logits");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn retired_lanes_stop_costing_and_zero_their_rows() {
        let (dir, cfg, engine, w, _) = kv_fixture("kvretire");
        let vo = cfg.vocab;
        let seqs: Vec<Vec<i32>> = vec![vec![1; cfg.seq_len]; 3];
        let lens = [2usize, 2, 2];
        let (mut state, _) = engine.prefill("synth/b4", &seqs, &lens, &w, &[]).unwrap();
        state.retire(1);
        assert_eq!(state.active_lanes(), 2);
        let logits = engine.decode_step(&mut state, &w, &[], &[3, 3, 3]).unwrap().to_vec();
        assert!(logits[vo..2 * vo].iter().all(|&x| x == 0.0), "retired row must be zero");
        assert!(logits[..vo].iter().any(|&x| x != 0.0));
        assert_eq!(state.lane_len(0), 3, "active lane advanced");
        assert_eq!(state.lane_len(1), 2, "retired lane frozen");
        // all lanes retired: a step computes nothing and returns zeros
        state.retire(0);
        state.retire(2);
        let logits = engine.decode_step(&mut state, &w, &[], &[3, 3, 3]).unwrap();
        assert!(logits.iter().all(|&x| x == 0.0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Continuous-batching surface: admitting a prompt into a retired
    /// lane of a warm session must be **bit-identical** to prefilling the
    /// same prompt in a fresh session — including when the lane carries a
    /// previous occupant's stale KV columns.
    #[test]
    fn admit_into_warm_session_matches_fresh_prefill() {
        let (dir, cfg, engine, w, _) = kv_fixture("kvadmit");
        let vo = cfg.vocab;
        let p0: Vec<i32> = (0..5).map(|i| 1 + (i * 3) % 9).collect();
        let p1: Vec<i32> = (0..3).map(|i| 2 + (i * 5) % 7).collect();
        let p2: Vec<i32> = (0..7).map(|i| 1 + (i * 2) % 11).collect();

        // fresh-prefill oracle rows
        let seqs = |p: &[i32]| {
            let mut s = vec![0i32; cfg.seq_len];
            s[..p.len()].copy_from_slice(p);
            vec![s]
        };
        let (_, solo0) = engine.prefill("synth/b4", &seqs(&p0), &[p0.len()], &w, &[]).unwrap();
        let (_, solo1) = engine.prefill("synth/b4", &seqs(&p1), &[p1.len()], &w, &[]).unwrap();
        let (_, solo2) = engine.prefill("synth/b4", &seqs(&p2), &[p2.len()], &w, &[]).unwrap();

        // empty session → admit lanes 0 and 2 in one pass
        let mut state = engine.new_session("synth/b4", 3, &w).unwrap();
        assert_eq!(state.active_lanes(), 0);
        let out = engine
            .admit(&mut state, &[0, 2], &[p0.as_slice(), p1.as_slice()], &w, &[])
            .unwrap()
            .to_vec();
        assert_eq!(&out[..vo], &solo0[..], "lane 0 admit row == fresh prefill row");
        assert_eq!(&out[2 * vo..3 * vo], &solo1[..], "lane 2 admit row == fresh prefill row");
        assert!(out[vo..2 * vo].iter().all(|&x| x == 0.0), "un-admitted lane stays zero");
        assert_eq!(state.active_lanes(), 2);
        assert_eq!((state.lane_len(0), state.lane_len(2)), (p0.len(), p1.len()));

        // retire lane 0, re-admit a different prompt into the same slot:
        // the stale cache columns of p0 must be unreachable
        state.retire(0);
        let out = engine.admit(&mut state, &[0], &[p2.as_slice()], &w, &[]).unwrap().to_vec();
        assert_eq!(&out[..vo], &solo2[..], "reused lane must match a fresh prefill bitwise");
        assert_eq!(state.lane_len(0), p2.len());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Mid-flight admission must not perturb surviving lanes: a lane
    /// stepped while its neighbors churn produces the same logits as the
    /// same lane decoded alone (per-lane independence, bitwise).
    #[test]
    fn mid_flight_admission_leaves_survivors_bit_identical() {
        let (dir, cfg, engine, w, _) = kv_fixture("kvmidflight");
        let vo = cfg.vocab;
        let p0: Vec<i32> = vec![3, 1, 4, 1, 5];
        let p1: Vec<i32> = vec![2, 7];
        let p2: Vec<i32> = vec![6, 2, 8];

        // solo run of lane-0's decode: prefill then 3 greedy steps
        let mut solo_seq = vec![0i32; cfg.seq_len];
        solo_seq[..p0.len()].copy_from_slice(&p0);
        let (mut solo_state, mut solo_logits) =
            engine.prefill("synth/b4", &[solo_seq.clone()], &[p0.len()], &w, &[]).unwrap();
        let mut solo_rows = Vec::new();
        let mut solo_pos = p0.len();
        for _ in 0..3 {
            let row = &solo_logits[..vo];
            let best = (0..vo).max_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap()).unwrap();
            solo_seq[solo_pos] = best as i32;
            solo_pos += 1;
            solo_logits =
                engine.decode_step(&mut solo_state, &w, &[], &[best as i32]).unwrap().to_vec();
            solo_rows.push(solo_logits[..vo].to_vec());
        }

        // churned run: same lane 0, while lane 1 is retired and re-admitted
        let mut state = engine.new_session("synth/b4", 2, &w).unwrap();
        let first = engine
            .admit(&mut state, &[0, 1], &[p0.as_slice(), p1.as_slice()], &w, &[])
            .unwrap()
            .to_vec();
        let mut pos0 = p0.len();
        let mut seq0 = vec![0i32; cfg.seq_len];
        seq0[..p0.len()].copy_from_slice(&p0);
        let mut cur = first;
        for (step, solo_row) in solo_rows.iter().enumerate() {
            let row = &cur[..vo];
            let best = (0..vo).max_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap()).unwrap();
            seq0[pos0] = best as i32;
            pos0 += 1;
            if step == 1 {
                // churn the neighbor mid-flight
                state.retire(1);
                engine.admit(&mut state, &[1], &[p2.as_slice()], &w, &[]).unwrap();
            }
            cur = engine.decode_step(&mut state, &w, &[], &[best as i32, 1]).unwrap().to_vec();
            assert_eq!(&cur[..vo], &solo_row[..], "step {step}: survivor must be unperturbed");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The factor-source binding surface: lanes bound once via
    /// [`DecodeState::bind_adapter`] must admit and decode
    /// **bit-identically** to the same lanes driven with explicit
    /// per-call `QFactors` views, bindings must clear on reset, and bad
    /// bindings must be rejected at bind time (never mid-step).
    #[test]
    fn bound_sources_bit_identical_to_explicit_views() {
        let (dir, cfg, engine, _w_merged, w_base) = kv_fixture("kvbind");
        let stored = synth_quantized_adapter(&cfg, 51);
        let p0: Vec<i32> = (0..5).map(|i| 1 + (i * 3) % 9).collect();
        let p1: Vec<i32> = (0..3).map(|i| 2 + (i * 5) % 7).collect();

        // explicit-views reference run: lane 0 adapted, lane 1 base
        let qf = stored.factors();
        let adapters = [Some(&qf), None];
        let mut s_view = engine.new_session("synth/b4", 2, &w_base).unwrap();
        let mut want = engine
            .admit(&mut s_view, &[0, 1], &[p0.as_slice(), p1.as_slice()], &w_base, &adapters)
            .unwrap()
            .to_vec();
        for tok in [3i32, 5, 7] {
            want.extend_from_slice(
                engine.decode_step(&mut s_view, &w_base, &adapters, &[tok, tok]).unwrap(),
            );
        }

        // bound-sources run: bind lane 0 once, never pass views again
        let src: Arc<dyn FactorSource> = Arc::new(stored.clone());
        let mut s_bind = engine.new_session("synth/b4", 2, &w_base).unwrap();
        s_bind.bind_adapter(0, Some(src)).unwrap();
        assert!(s_bind.has_bound_adapters());
        let mut got = engine
            .admit(&mut s_bind, &[0, 1], &[p0.as_slice(), p1.as_slice()], &w_base, &[])
            .unwrap()
            .to_vec();
        for tok in [3i32, 5, 7] {
            got.extend_from_slice(
                engine.decode_step(&mut s_bind, &w_base, &[], &[tok, tok]).unwrap(),
            );
        }
        assert_eq!(got, want, "bound sources must match explicit views bitwise");

        // reset clears bindings
        s_bind.reset();
        assert!(!s_bind.has_bound_adapters());
        // shape mismatches and bad lanes fail at bind time
        let bigger = ModelConfig { d_model: cfg.d_model * 2, ..cfg };
        let wrong: Arc<dyn FactorSource> = Arc::new(synth_quantized_adapter(&bigger, 6));
        assert!(s_bind.bind_adapter(0, Some(wrong)).is_err(), "bad shapes must fail at bind");
        assert!(s_bind.bind_adapter(9, None).is_err(), "lane out of range");
        assert!(!s_bind.has_bound_adapters());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn admit_validates_inputs_before_mutating() {
        let (dir, cfg, engine, w, _) = kv_fixture("kvadmitbad");
        let mut state = engine.new_session("synth/b4", 2, &w).unwrap();
        let good: Vec<i32> = vec![1, 2];
        // lane out of range / duplicate lane / prompt arity
        assert!(engine.admit(&mut state, &[5], &[good.as_slice()], &w, &[]).is_err());
        assert!(engine
            .admit(&mut state, &[0, 0], &[good.as_slice(), good.as_slice()], &w, &[])
            .is_err());
        assert!(engine.admit(&mut state, &[0, 1], &[good.as_slice()], &w, &[]).is_err());
        // empty / overlong prompt, bad token
        let long = vec![1i32; cfg.seq_len + 1];
        let empty: Vec<i32> = Vec::new();
        assert!(engine.admit(&mut state, &[0], &[empty.as_slice()], &w, &[]).is_err());
        assert!(engine.admit(&mut state, &[0], &[long.as_slice()], &w, &[]).is_err());
        let bad = vec![-1i32];
        assert!(engine.admit(&mut state, &[0], &[bad.as_slice()], &w, &[]).is_err());
        // nothing mutated: both lanes still empty and retired
        assert_eq!(state.active_lanes(), 0);
        assert_eq!((state.lane_len(0), state.lane_len(1)), (0, 0));
        // a live lane rejects re-admission
        engine.admit(&mut state, &[0], &[good.as_slice()], &w, &[]).unwrap();
        let err = engine.admit(&mut state, &[0], &[good.as_slice()], &w, &[]).unwrap_err();
        assert!(err.to_string().contains("still live"), "{err}");
        // zero-lane session is rejected at creation
        assert!(engine.new_session("synth/b4", 0, &w).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The persistent-pool determinism contract (DESIGN.md §11): prefill,
    /// admit, and decode-step logits are bit-identical at 1/2/4 compute
    /// threads — the pool partitions whole rows, never the math.
    #[test]
    fn persistent_pool_bit_identical_across_thread_counts() {
        let (dir, cfg, mut engine, w, w_base) = kv_fixture("kvpool");
        let vo = cfg.vocab;
        let stored = synth_quantized_adapter(&cfg, 51);
        let qf = stored.factors();
        let p0: Vec<i32> = (0..6).map(|i| 1 + (i * 3) % 9).collect();
        let p1: Vec<i32> = (0..4).map(|i| 2 + i).collect();

        let run = |engine: &Engine| {
            // factor-path session: admit two lanes, then three steps
            let adapters = [Some(&qf), Some(&qf)];
            let mut state = engine.new_session("synth/b4", 2, &w_base).unwrap();
            let mut trace =
                engine
                    .admit(&mut state, &[0, 1], &[p0.as_slice(), p1.as_slice()], &w_base, &adapters)
                    .unwrap()
                    .to_vec();
            for tok in [3i32, 5, 7] {
                let step =
                    engine.decode_step(&mut state, &w_base, &adapters, &[tok, tok]).unwrap();
                trace.extend_from_slice(step);
            }
            // merged full forward too (covers ref_forward's pool path)
            let flat = vec![1i32; 2 * cfg.seq_len];
            trace.extend(engine.forward("synth/b4", &flat, &[2, cfg.seq_len], &w).unwrap());
            trace
        };
        engine.set_compute_threads(1);
        let serial = run(&engine);
        assert_eq!(serial.len() % vo, 0);
        for threads in [2usize, 4] {
            engine.set_compute_threads(threads);
            assert_eq!(engine.compute_threads(), threads);
            assert_eq!(run(&engine), serial, "threads={threads} must not change any bit");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn decode_step_errors_at_capacity_and_on_bad_input() {
        let (dir, cfg, engine, w, _) = kv_fixture("kvcap");
        let seqs: Vec<Vec<i32>> = vec![vec![1; cfg.seq_len]];
        // prefill the whole window: the next step has no cache column left
        let lens = [cfg.seq_len];
        let (mut state, _) = engine.prefill("synth/b4", &seqs, &lens, &w, &[]).unwrap();
        let err = engine.decode_step(&mut state, &w, &[], &[1]).unwrap_err();
        assert!(err.to_string().contains("capacity"), "{err}");
        // lane arity and token range
        let (mut state, _) = engine.prefill("synth/b4", &seqs, &[2], &w, &[]).unwrap();
        assert!(engine.decode_step(&mut state, &w, &[], &[1, 1]).is_err());
        assert!(engine.decode_step(&mut state, &w, &[], &[-1]).is_err());
        assert!(engine
            .decode_step(&mut state, &w, &[], &[cfg.vocab as i32])
            .is_err());
        // prefill validation
        assert!(engine.prefill("synth/b4", &[], &[], &w, &[]).is_err(), "empty lane set");
        assert!(
            engine.prefill("synth/b4", &seqs, &[0], &w, &[]).is_err(),
            "zero-length lane"
        );
        assert!(
            engine.prefill("synth/b4", &seqs, &[cfg.seq_len + 1], &w, &[]).is_err(),
            "overlong lane"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
