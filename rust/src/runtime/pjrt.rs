//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute on the
//! request path.
//!
//! Interchange is HLO **text** (`artifacts/*.hlo.txt`): jax ≥ 0.5 emits
//! protos with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Threading: PJRT objects in the `xla` crate are not `Send` — the
//! coordinator confines one [`Engine`] to a dedicated executor thread and
//! feeds it through channels (see [`crate::coordinator`]).
//!
//! Hot path: merged adapter weights are uploaded once as device-resident
//! [`xla::PjRtBuffer`]s ([`Engine::upload_weights`]); a request then only
//! uploads its token batch and calls `execute_b`.

use crate::adapter::fmt::{Tensor, TensorData};
use anyhow::{bail, Context};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// A compiled HLO program plus its I/O metadata.
pub struct Program {
    exe: xla::PjRtLoadedExecutable,
    /// Number of inputs expected (tokens + weights).
    pub arity: usize,
}

/// PJRT engine: one CPU client + a set of compiled programs.
pub struct Engine {
    client: xla::PjRtClient,
    programs: BTreeMap<String, Program>,
    artifacts_dir: PathBuf,
}

/// Device-resident weights for one adapter (outputs of
/// [`Engine::upload_weights`]) — the unit the coordinator's merged-weight
/// cache holds.
pub struct DeviceWeights {
    pub buffers: Vec<xla::PjRtBuffer>,
    /// Host-side f32 count (for cache byte accounting).
    pub elements: usize,
}

impl DeviceWeights {
    /// Approximate device bytes (f32).
    pub fn bytes(&self) -> usize {
        self.elements * 4
    }
}

impl Engine {
    /// Create a CPU engine rooted at an artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, programs: BTreeMap::new(), artifacts_dir: artifacts_dir.as_ref().into() })
    }

    /// The artifacts directory this engine loads from.
    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// Load + compile `<artifacts>/<file>` under the key `name`.
    pub fn load_program(&mut self, name: &str, file: &str, arity: usize) -> anyhow::Result<()> {
        let path = self.artifacts_dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        self.programs.insert(name.to_string(), Program { exe, arity });
        Ok(())
    }

    /// Load the batched-forward program of a model for one batch bucket.
    /// Program key: `<model>/b<bucket>`.
    pub fn load_model_fwd(
        &mut self,
        model: &str,
        bucket: usize,
        n_params: usize,
    ) -> anyhow::Result<()> {
        let key = format!("{model}/b{bucket}");
        let file = format!("{model}.fwd.b{bucket}.hlo.txt");
        self.load_program(&key, &file, 1 + n_params)
    }

    pub fn has_program(&self, name: &str) -> bool {
        self.programs.contains_key(name)
    }

    /// Upload a weight list (in `param_names` order) to the device.
    pub fn upload_weights(&self, weights: &[Tensor]) -> anyhow::Result<DeviceWeights> {
        let mut buffers = Vec::with_capacity(weights.len());
        let mut elements = 0usize;
        for t in weights {
            let buf = match &t.data {
                TensorData::F32(v) => {
                    elements += v.len();
                    self.client.buffer_from_host_buffer::<f32>(v, &t.dims, None)?
                }
                TensorData::I32(v) => {
                    self.client.buffer_from_host_buffer::<i32>(v, &t.dims, None)?
                }
                TensorData::U8(v) => self.client.buffer_from_host_buffer::<u8>(v, &t.dims, None)?,
            };
            buffers.push(buf);
        }
        Ok(DeviceWeights { buffers, elements })
    }

    /// Upload an i32 token batch.
    pub fn upload_tokens(&self, tokens: &[i32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<i32>(tokens, dims, None)?)
    }

    /// Execute a program on device-resident inputs: tokens first, then the
    /// weight buffers. Returns the flattened f32 output (logits) — the
    /// artifacts are lowered with `return_tuple=True`, hence `to_tuple1`.
    pub fn execute(
        &self,
        name: &str,
        tokens: &xla::PjRtBuffer,
        weights: &DeviceWeights,
    ) -> anyhow::Result<Vec<f32>> {
        let prog = self.programs.get(name).with_context(|| format!("program {name} not loaded"))?;
        if 1 + weights.buffers.len() != prog.arity {
            bail!(
                "program {name} expects {} inputs, got {}",
                prog.arity,
                1 + weights.buffers.len()
            );
        }
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(prog.arity);
        args.push(tokens);
        args.extend(weights.buffers.iter());
        let out = prog.exe.execute_b(&args)?;
        let lit = out[0][0].to_literal_sync()?;
        let tup = lit.to_tuple1()?;
        Ok(tup.to_vec::<f32>()?)
    }

    /// Convenience: host-side tokens → logits.
    pub fn forward(
        &self,
        name: &str,
        tokens: &[i32],
        dims: &[usize],
        weights: &DeviceWeights,
    ) -> anyhow::Result<Vec<f32>> {
        let tok = self.upload_tokens(tokens, dims)?;
        self.execute(name, &tok, weights)
    }

    /// Factor-form execution is a reference-engine capability: the AOT
    /// HLO programs bake the weight arity in at lowering time and have no
    /// activation-path adapter inputs. API parity only.
    pub fn forward_with_adapters(
        &self,
        _name: &str,
        _tokens: &[i32],
        _dims: &[usize],
        _weights: &DeviceWeights,
        _adapters: &[Option<&crate::loraquant::QFactors<'_>>],
    ) -> anyhow::Result<Vec<f32>> {
        bail!(
            "factor-form adapter application is not supported by the PJRT backend; \
             use --merge-strategy merged (or build without --features pjrt)"
        )
    }

    /// Raw client access (tests / benches).
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }
}
