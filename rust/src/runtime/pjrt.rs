//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute on the
//! request path.
//!
//! Interchange is HLO **text** (`artifacts/*.hlo.txt`): jax ≥ 0.5 emits
//! protos with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Threading: PJRT objects in the `xla` crate are not `Send` — the
//! coordinator confines one [`Engine`] to a dedicated executor thread and
//! feeds it through channels (see [`crate::coordinator`]).
//!
//! Hot path: merged adapter weights are uploaded once as device-resident
//! [`xla::PjRtBuffer`]s ([`Engine::upload_weights`]); a request then only
//! uploads its token batch and calls `execute_b`.

use crate::adapter::fmt::{Tensor, TensorData};
use anyhow::{bail, Context};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// A compiled HLO program plus its I/O metadata.
pub struct Program {
    exe: xla::PjRtLoadedExecutable,
    /// Number of inputs expected (tokens + weights).
    pub arity: usize,
}

/// PJRT engine: one CPU client + a set of compiled programs.
pub struct Engine {
    client: xla::PjRtClient,
    programs: BTreeMap<String, Program>,
    artifacts_dir: PathBuf,
}

/// Incremental-decode session state, API parity with the reference
/// engine's KV-cached `DecodeState` (`runtime::kv`). The AOT HLO
/// programs take whole padded sequences, so each `decode_step` here is a
/// full recompute — same protocol, original cost; XLA owns any caching.
/// (`eval::decode::FullRecompute` implements the same recompute shape
/// one layer up; it is not reused here because `runtime` must not
/// depend on `eval` — keep the two row-extraction paths in sync.)
pub struct DecodeState {
    prog: String,
    /// Expected input arity (tokens + weights), revalidated per step
    /// **before** any lane state mutates.
    arity: usize,
    /// Owned padded working sequences (`bsz` lanes × `t` positions).
    seqs: Vec<Vec<i32>>,
    t: usize,
    /// Tokens consumed per lane.
    lens: Vec<usize>,
    retired: Vec<bool>,
    /// Per-lane step logits (`lanes × vocab`; retired rows zero).
    out: Vec<f32>,
    vocab: usize,
}

impl DecodeState {
    /// Program key this session decodes through.
    pub fn program(&self) -> &str {
        &self.prog
    }

    pub fn lanes(&self) -> usize {
        self.lens.len()
    }

    /// Tokens consumed by lane `lane` so far.
    pub fn lane_len(&self, lane: usize) -> usize {
        self.lens[lane]
    }

    pub fn is_retired(&self, lane: usize) -> bool {
        self.retired[lane]
    }

    /// Drop `lane` from subsequent steps (its logits row reads zero).
    /// The full-sequence forward still computes every lane, so on this
    /// backend retirement only affects bookkeeping.
    pub fn retire(&mut self, lane: usize) {
        self.retired[lane] = true;
    }

    /// Lanes still stepping.
    pub fn active_lanes(&self) -> usize {
        self.retired.iter().filter(|&&r| !r).count()
    }

    /// No KV cache on this backend.
    pub fn kv_bytes(&self) -> usize {
        0
    }
}

/// Device-resident weights for one adapter (outputs of
/// [`Engine::upload_weights`]) — the unit the coordinator's merged-weight
/// cache holds.
pub struct DeviceWeights {
    pub buffers: Vec<xla::PjRtBuffer>,
    /// Host-side f32 count (for cache byte accounting).
    pub elements: usize,
}

impl DeviceWeights {
    /// Approximate device bytes (f32).
    pub fn bytes(&self) -> usize {
        self.elements * 4
    }
}

impl Engine {
    /// Create a CPU engine rooted at an artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, programs: BTreeMap::new(), artifacts_dir: artifacts_dir.as_ref().into() })
    }

    /// The artifacts directory this engine loads from.
    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// Load + compile `<artifacts>/<file>` under the key `name`.
    pub fn load_program(&mut self, name: &str, file: &str, arity: usize) -> anyhow::Result<()> {
        let path = self.artifacts_dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        self.programs.insert(name.to_string(), Program { exe, arity });
        Ok(())
    }

    /// Load the batched-forward program of a model for one batch bucket.
    /// Program key: `<model>/b<bucket>`.
    pub fn load_model_fwd(
        &mut self,
        model: &str,
        bucket: usize,
        n_params: usize,
    ) -> anyhow::Result<()> {
        let key = format!("{model}/b{bucket}");
        let file = format!("{model}.fwd.b{bucket}.hlo.txt");
        self.load_program(&key, &file, 1 + n_params)
    }

    pub fn has_program(&self, name: &str) -> bool {
        self.programs.contains_key(name)
    }

    /// Upload a weight list (in `param_names` order) to the device.
    pub fn upload_weights(&self, weights: &[Tensor]) -> anyhow::Result<DeviceWeights> {
        let mut buffers = Vec::with_capacity(weights.len());
        let mut elements = 0usize;
        for t in weights {
            let buf = match &t.data {
                TensorData::F32(v) => {
                    elements += v.len();
                    self.client.buffer_from_host_buffer::<f32>(v, &t.dims, None)?
                }
                TensorData::I32(v) => {
                    self.client.buffer_from_host_buffer::<i32>(v, &t.dims, None)?
                }
                TensorData::U8(v) => self.client.buffer_from_host_buffer::<u8>(v, &t.dims, None)?,
            };
            buffers.push(buf);
        }
        Ok(DeviceWeights { buffers, elements })
    }

    /// Upload an i32 token batch.
    pub fn upload_tokens(&self, tokens: &[i32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<i32>(tokens, dims, None)?)
    }

    /// Execute a program on device-resident inputs: tokens first, then the
    /// weight buffers. Returns the flattened f32 output (logits) — the
    /// artifacts are lowered with `return_tuple=True`, hence `to_tuple1`.
    pub fn execute(
        &self,
        name: &str,
        tokens: &xla::PjRtBuffer,
        weights: &DeviceWeights,
    ) -> anyhow::Result<Vec<f32>> {
        let prog = self.programs.get(name).with_context(|| format!("program {name} not loaded"))?;
        if 1 + weights.buffers.len() != prog.arity {
            bail!(
                "program {name} expects {} inputs, got {}",
                prog.arity,
                1 + weights.buffers.len()
            );
        }
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(prog.arity);
        args.push(tokens);
        args.extend(weights.buffers.iter());
        let out = prog.exe.execute_b(&args)?;
        let lit = out[0][0].to_literal_sync()?;
        let tup = lit.to_tuple1()?;
        Ok(tup.to_vec::<f32>()?)
    }

    /// Convenience: host-side tokens → logits.
    pub fn forward(
        &self,
        name: &str,
        tokens: &[i32],
        dims: &[usize],
        weights: &DeviceWeights,
    ) -> anyhow::Result<Vec<f32>> {
        let tok = self.upload_tokens(tokens, dims)?;
        self.execute(name, &tok, weights)
    }

    /// Factor-form execution is a reference-engine capability: the AOT
    /// HLO programs bake the weight arity in at lowering time and have no
    /// activation-path adapter inputs. API parity only.
    pub fn forward_with_adapters(
        &self,
        _name: &str,
        _tokens: &[i32],
        _dims: &[usize],
        _weights: &DeviceWeights,
        _adapters: &[Option<&crate::loraquant::QFactors<'_>>],
    ) -> anyhow::Result<Vec<f32>> {
        bail!(
            "factor-form adapter application is not supported by the PJRT backend; \
             use --merge-strategy merged (or build without --features pjrt)"
        )
    }

    /// Host-side compute threading is a reference-engine knob; XLA owns
    /// its own thread pool here. Accepted for API parity.
    pub fn set_compute_threads(&mut self, _threads: usize) {}

    /// See [`Engine::set_compute_threads`].
    pub fn compute_threads(&self) -> usize {
        1
    }

    /// Start an incremental-decode session (API parity with the
    /// reference engine's KV-cached `prefill`): lane `k` holds `lens[k]`
    /// tokens at the front of `seqs[k]`, all lanes padded to one length.
    /// Returns the session plus `lanes × vocab` next-token logits.
    pub fn prefill(
        &self,
        name: &str,
        seqs: &[Vec<i32>],
        lens: &[usize],
        weights: &DeviceWeights,
        adapters: &[Option<&crate::loraquant::QFactors<'_>>],
    ) -> anyhow::Result<(DecodeState, Vec<f32>)> {
        if !adapters.is_empty() && adapters.iter().any(Option::is_some) {
            bail!(
                "factor-form adapter application is not supported by the PJRT backend; \
                 use --merge-strategy merged (or build without --features pjrt)"
            );
        }
        let arity = self
            .programs
            .get(name)
            .with_context(|| format!("program {name} not loaded"))?
            .arity;
        if 1 + weights.buffers.len() != arity {
            bail!("program {name} expects {arity} inputs, got {}", 1 + weights.buffers.len());
        }
        let bsz = seqs.len();
        if bsz == 0 {
            bail!("prefill: empty lane set");
        }
        if lens.len() != bsz {
            bail!("prefill: {bsz} lanes vs {} lens", lens.len());
        }
        let t = seqs[0].len();
        for (k, (&len, seq)) in lens.iter().zip(seqs).enumerate() {
            if seq.len() != t {
                bail!("prefill: lane {k} is {} long, lane 0 is {t}", seq.len());
            }
            if len == 0 || len > t {
                bail!("prefill: lane {k} length {len} out of range 1..={t}");
            }
        }
        let mut state = DecodeState {
            prog: name.to_string(),
            arity,
            seqs: seqs.to_vec(),
            t,
            lens: lens.to_vec(),
            retired: vec![false; bsz],
            out: Vec::new(),
            vocab: 0,
        };
        let logits = state.recompute(self, weights)?.to_vec();
        Ok((state, logits))
    }

    /// Advance a session by one token per still-active lane: `last[k]` is
    /// consumed at position `state.lane_len(k)`. Full recompute on this
    /// backend; retired rows read zero.
    pub fn decode_step<'s>(
        &self,
        state: &'s mut DecodeState,
        weights: &DeviceWeights,
        adapters: &[Option<&crate::loraquant::QFactors<'_>>],
        last: &[i32],
    ) -> anyhow::Result<&'s [f32]> {
        if !adapters.is_empty() && adapters.iter().any(Option::is_some) {
            bail!(
                "factor-form adapter application is not supported by the PJRT backend; \
                 use --merge-strategy merged (or build without --features pjrt)"
            );
        }
        let bsz = state.lanes();
        if last.len() != bsz {
            bail!("decode_step: {} tokens for {bsz} lanes", last.len());
        }
        if 1 + weights.buffers.len() != state.arity {
            bail!(
                "program {} expects {} inputs, got {}",
                state.prog,
                state.arity,
                1 + weights.buffers.len()
            );
        }
        // validate every active lane before mutating any (same contract
        // as the reference engine: errors surface before state changes)
        for k in 0..bsz {
            if state.retired[k] {
                continue;
            }
            if state.lens[k] >= state.t {
                bail!(
                    "decode_step: lane {k} is full ({} tokens, capacity {})",
                    state.lens[k],
                    state.t
                );
            }
            // vocab is known after the prefill recompute; match the
            // reference engine's token-range contract rather than
            // feeding the HLO gather an out-of-range index
            if state.vocab > 0 && (last[k] < 0 || last[k] as usize >= state.vocab) {
                bail!("token {} out of vocab range 0..{}", last[k], state.vocab);
            }
        }
        for k in 0..bsz {
            if state.retired[k] {
                continue;
            }
            let at = state.lens[k];
            state.seqs[k][at] = last[k];
            state.lens[k] += 1;
        }
        state.recompute(self, weights)
    }

    /// Raw client access (tests / benches).
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }
}

impl DecodeState {
    /// Full-sequence forward + per-lane row extraction into `self.out`.
    fn recompute(&mut self, engine: &Engine, weights: &DeviceWeights) -> anyhow::Result<&[f32]> {
        let bsz = self.lanes();
        let flat: Vec<i32> = self.seqs.iter().flatten().copied().collect();
        let logits = engine.forward(&self.prog, &flat, &[bsz, self.t], weights)?;
        if self.vocab == 0 {
            if logits.len() % (bsz * self.t) != 0 {
                bail!("forward returned {} logits for a {bsz}x{} batch", logits.len(), self.t);
            }
            self.vocab = logits.len() / (bsz * self.t);
        }
        let vo = self.vocab;
        self.out.clear();
        self.out.resize(bsz * vo, 0.0);
        for k in 0..bsz {
            if self.retired[k] {
                continue;
            }
            let src = (k * self.t + self.lens[k] - 1) * vo;
            self.out[k * vo..(k + 1) * vo].copy_from_slice(&logits[src..src + vo]);
        }
        Ok(&self.out)
    }
}
