//! KV cache + decode-session state for the reference engine's
//! incremental decode path (DESIGN.md §10).
//!
//! [`KvCache`] stores the per-layer key/value activations of a fixed lane
//! set: layer-major, then lane, then position, with the
//! `n_heads × head_dim` split fused into the model width `d` (head `h`
//! occupies columns `h·hd .. (h+1)·hd`, exactly the full-forward layout,
//! so attention reads the cache with the same slicing as the batched
//! path). A lane's entry at position `t` is written exactly when the
//! token at `t` is consumed — by the batched prefill or by a later
//! `decode_step` — and read by every subsequent causal attention over
//! that lane. Padding columns a batched prefill writes past a short
//! lane's prompt are overwritten by the lane's own steps before any
//! attention can read them, so they never influence logits.
//!
//! [`DecodeState`] owns lane lifecycle on top of the cache: per-lane
//! consumed-token counts, EOS retirement (a retired lane stops costing
//! any compute), and the reusable [`Scratch`] arena that makes
//! steady-state decode allocation-free.

use super::sim::ParamIndex;
use crate::loraquant::{FactorScratch, FactorSource};
use crate::model::ModelConfig;
use std::sync::Arc;

/// Per-layer K/V buffers for `bsz` lanes of up to `cap` positions each.
pub struct KvCache {
    bsz: usize,
    cap: usize,
    d: usize,
    /// `[n_layers][bsz][cap][d]`, row-major.
    k: Vec<f32>,
    v: Vec<f32>,
}

impl KvCache {
    pub(crate) fn new(n_layers: usize, bsz: usize, cap: usize, d: usize) -> Self {
        let len = n_layers * bsz * cap * d;
        Self { bsz, cap, d, k: vec![0.0; len], v: vec![0.0; len] }
    }

    /// Positions per lane (the model's `seq_len` for serving sessions).
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Resident bytes (both K and V, f32).
    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }

    /// The full key buffer (`[n_layers][bsz][cap][d]`, row-major) — for
    /// bit-exact equivalence tests (chunked vs monolithic prefill).
    pub fn keys(&self) -> &[f32] {
        &self.k
    }

    /// The full value buffer, same layout as [`KvCache::keys`].
    pub fn values(&self) -> &[f32] {
        &self.v
    }

    #[inline]
    fn lane_base(&self, layer: usize, lane: usize) -> usize {
        (layer * self.bsz + lane) * self.cap * self.d
    }

    /// Lane `lane`'s cached keys in `layer`: `cap × d`, position-major.
    #[inline]
    pub(crate) fn k_lane(&self, layer: usize, lane: usize) -> &[f32] {
        let base = self.lane_base(layer, lane);
        &self.k[base..base + self.cap * self.d]
    }

    /// Lane `lane`'s cached values in `layer`.
    #[inline]
    pub(crate) fn v_lane(&self, layer: usize, lane: usize) -> &[f32] {
        let base = self.lane_base(layer, lane);
        &self.v[base..base + self.cap * self.d]
    }

    /// Publish the K/V rows of one consumed token.
    #[inline]
    pub(crate) fn write(
        &mut self,
        layer: usize,
        lane: usize,
        t: usize,
        krow: &[f32],
        vrow: &[f32],
    ) {
        debug_assert!(t < self.cap);
        let at = self.lane_base(layer, lane) + t * self.d;
        self.k[at..at + self.d].copy_from_slice(krow);
        self.v[at..at + self.d].copy_from_slice(vrow);
    }
}

/// Reusable forward buffers, resized per pass (shrinking keeps capacity,
/// and a decode step is never larger than its prefill, so steady-state
/// decode performs zero allocations).
#[derive(Default)]
pub(crate) struct Scratch {
    /// Residual stream rows (`rows × d`), pre-filled with embed + pos.
    pub x: Vec<f32>,
    /// Layernorm output (`rows × d`).
    pub hx: Vec<f32>,
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub att: Vec<f32>,
    pub proj: Vec<f32>,
    /// FFN hidden (`rows × d_ff`).
    pub h1: Vec<f32>,
    pub h2: Vec<f32>,
    /// Attention score windows, one `seq_len` slot per compute-pool
    /// partition (slot 0 is the serial path's window).
    pub scores: Vec<f32>,
    /// Head output (`rows × vocab`).
    pub logits: Vec<f32>,
    /// Factor-form adapter scratch (bottleneck rows + dequant row).
    pub factor: FactorScratch,
}

impl Scratch {
    /// Size every buffer for an `rows`-row pass whose attention may be
    /// partitioned `slots` ways (each partition gets its own score
    /// window; `slots = 1` is the serial layout).
    pub(crate) fn ensure(&mut self, rows: usize, cfg: &ModelConfig, slots: usize) {
        let (d, f, v) = (cfg.d_model, cfg.d_ff, cfg.vocab);
        self.x.resize(rows * d, 0.0);
        self.hx.resize(rows * d, 0.0);
        self.q.resize(rows * d, 0.0);
        self.k.resize(rows * d, 0.0);
        self.v.resize(rows * d, 0.0);
        self.att.resize(rows * d, 0.0);
        self.proj.resize(rows * d, 0.0);
        self.h1.resize(rows * f, 0.0);
        self.h2.resize(rows * d, 0.0);
        self.scores.resize(slots.max(1) * cfg.seq_len.max(1), 0.0);
        self.logits.resize(rows * v, 0.0);
    }
}

/// A live incremental-decode session over one batch: the KV cache, each
/// lane's consumed-token count, retirement flags, and the scratch arena.
/// Created by `Engine::prefill`, advanced by `Engine::decode_step`.
pub struct DecodeState {
    /// Program key this session was prefilled under (diagnostics).
    pub(crate) prog: String,
    pub(crate) cfg: ModelConfig,
    /// Expected input arity (tokens + weights), revalidated per step.
    pub(crate) arity: usize,
    /// Positional parameter indices + site names, resolved at prefill so
    /// steps never format or look up names.
    pub(crate) idx: ParamIndex,
    pub(crate) kv: KvCache,
    /// Tokens consumed per lane == the lane's next cache write position.
    pub(crate) lens: Vec<usize>,
    pub(crate) retired: Vec<bool>,
    /// Lanes mid-way through a chunked prefill
    /// ([`crate::runtime::Engine::prefill_chunk`]): the prompt prefix up
    /// to `lens[lane]` is cached but the lane has produced no logits yet,
    /// so it must not be stepped or re-admitted until its final chunk
    /// lands.
    pub(crate) prefilling: Vec<bool>,
    /// Step row map `(lane, position)` — rebuilt in place every step.
    pub(crate) map: Vec<(usize, usize)>,
    /// Per-lane step logits (`lanes × vocab`; retired rows zero).
    pub(crate) out: Vec<f32>,
    pub(crate) scratch: Scratch,
    /// Per-lane adapter bindings ([`DecodeState::bind_adapter`]). When a
    /// step passes no explicit adapter views, `forward_core` resolves
    /// sites from these sources directly — no per-step `QFactors`
    /// rebuild (DESIGN.md §11 "known cost", retired).
    pub(crate) sources: Vec<Option<Arc<dyn FactorSource>>>,
    /// How many `sources` entries are `Some` (cheap is-any-bound check).
    pub(crate) bound_sources: usize,
}

impl DecodeState {
    pub(crate) fn new(
        prog: &str,
        cfg: ModelConfig,
        arity: usize,
        lens: Vec<usize>,
        idx: ParamIndex,
    ) -> Self {
        let bsz = lens.len();
        Self {
            prog: prog.to_string(),
            cfg,
            arity,
            idx,
            kv: KvCache::new(cfg.n_layers, bsz, cfg.seq_len, cfg.d_model),
            retired: vec![false; bsz],
            prefilling: vec![false; bsz],
            map: Vec::with_capacity(bsz),
            out: vec![0.0; bsz * cfg.vocab],
            scratch: Scratch::default(),
            sources: vec![None; bsz],
            bound_sources: 0,
            lens,
        }
    }

    /// Program key this session decodes through.
    pub fn program(&self) -> &str {
        &self.prog
    }

    /// Lane count (the batch bucket this session was prefilled at).
    pub fn lanes(&self) -> usize {
        self.lens.len()
    }

    /// Tokens consumed by lane `lane` so far.
    pub fn lane_len(&self, lane: usize) -> usize {
        self.lens[lane]
    }

    pub fn is_retired(&self, lane: usize) -> bool {
        self.retired[lane]
    }

    /// Whether `lane` is mid-way through a chunked prefill (prefix
    /// cached, no logits yet — not steppable until the final chunk).
    pub fn is_prefilling(&self, lane: usize) -> bool {
        self.prefilling[lane]
    }

    /// The lane's last produced logits row (`vocab` wide; zeros for a
    /// retired or still-prefilling lane). For equivalence tests.
    pub fn lane_logits(&self, lane: usize) -> &[f32] {
        &self.out[lane * self.cfg.vocab..(lane + 1) * self.cfg.vocab]
    }

    /// The session's KV cache — for bit-exact equivalence tests.
    pub fn kv_cache(&self) -> &KvCache {
        &self.kv
    }

    /// Permanently drop `lane` from every subsequent step: its rows are
    /// no longer embedded, projected or attended, and its logits row is
    /// zero. Used for EOS/budget-exhausted lanes so finished requests
    /// stop costing work.
    pub fn retire(&mut self, lane: usize) {
        self.retired[lane] = true;
    }

    /// Lanes still stepping.
    pub fn active_lanes(&self) -> usize {
        self.retired.iter().filter(|&&r| !r).count()
    }

    /// Return every lane to the retired-empty state, keeping the KV and
    /// scratch allocations warm — the continuous scheduler reuses one
    /// session across successive decode groups (possibly under different
    /// weight sets; stale cache columns are never read because a lane's
    /// attention window only covers positions it wrote itself).
    pub fn reset(&mut self) {
        self.retired.iter_mut().for_each(|r| *r = true);
        self.prefilling.iter_mut().for_each(|p| *p = false);
        self.lens.iter_mut().for_each(|l| *l = 0);
        self.out.fill(0.0);
        self.sources.iter_mut().for_each(|s| *s = None);
        self.bound_sources = 0;
    }

    /// Bind (or clear, with `None`) lane `lane`'s adapter for every
    /// subsequent admission/step of this session. Shapes are validated
    /// **here**, once per binding, so per-step adapter resolution is an
    /// unchecked site lookup. Steps that pass explicit adapter views
    /// override the bindings for that call.
    pub fn bind_adapter(
        &mut self,
        lane: usize,
        src: Option<Arc<dyn FactorSource>>,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            lane < self.sources.len(),
            "lane {lane} out of range for {}-lane session",
            self.sources.len()
        );
        if let Some(s) = &src {
            let qf = s.factors();
            super::sim::validate_adapter_shapes(&self.cfg, &[Some(&qf)])?;
        }
        if self.sources[lane].is_some() {
            self.bound_sources -= 1;
        }
        if src.is_some() {
            self.bound_sources += 1;
        }
        self.sources[lane] = src;
        Ok(())
    }

    /// Whether any lane currently has a bound adapter source.
    pub fn has_bound_adapters(&self) -> bool {
        self.bound_sources > 0
    }

    /// Resident KV bytes of this session.
    pub fn kv_bytes(&self) -> usize {
        self.kv.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_layout_roundtrip() {
        let mut kv = KvCache::new(2, 3, 4, 6);
        assert_eq!(kv.capacity(), 4);
        assert_eq!(kv.bytes(), 2 * 2 * 3 * 4 * 6 * 4);
        let krow: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let vrow: Vec<f32> = (0..6).map(|i| 10.0 + i as f32).collect();
        kv.write(1, 2, 3, &krow, &vrow);
        assert_eq!(&kv.k_lane(1, 2)[3 * 6..4 * 6], krow.as_slice());
        assert_eq!(&kv.v_lane(1, 2)[3 * 6..4 * 6], vrow.as_slice());
        // other lanes/layers untouched
        assert!(kv.k_lane(0, 2).iter().all(|&x| x == 0.0));
        assert!(kv.k_lane(1, 1).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn retirement_bookkeeping() {
        let cfg = crate::testutil::synth_model_config();
        let mut st = DecodeState::new("m/b2", cfg, 1, vec![3, 5], ParamIndex::new(&cfg));
        assert_eq!(st.lanes(), 2);
        assert_eq!(st.active_lanes(), 2);
        assert_eq!(st.lane_len(1), 5);
        st.retire(0);
        assert!(st.is_retired(0));
        assert!(!st.is_retired(1));
        assert_eq!(st.active_lanes(), 1);
        assert!(st.kv_bytes() > 0);
    }

    #[test]
    fn reset_retires_and_empties_every_lane() {
        let cfg = crate::testutil::synth_model_config();
        let mut st = DecodeState::new("m/b2", cfg, 1, vec![3, 5], ParamIndex::new(&cfg));
        st.out.resize(2 * cfg.vocab, 1.0);
        st.prefilling[1] = true;
        st.reset();
        assert!(!st.is_prefilling(1), "reset clears in-flight chunked prefills");
        assert_eq!(st.active_lanes(), 0);
        assert_eq!((st.lane_len(0), st.lane_len(1)), (0, 0));
        assert!(st.is_retired(0) && st.is_retired(1));
        assert!(st.out.iter().all(|&x| x == 0.0));
        assert!(st.kv_bytes() > 0, "reset keeps the cache allocation");
    }
}
