//! The paper's contribution: LoRAQuant mixed-precision quantization of a
//! LoRA adapter (§3, Algorithms 1–2).
//!
//! Pipeline per adapter matrix pair `(B m×r, A r×n)`:
//!
//! 1. [`split`] — SVD reparameterization `BA = U S Vᵀ`, `B' = U√S`,
//!    `A' = √S Vᵀ` (Eqs. 1–2), split at `h` into high/low sub-LoRAs
//!    (Eqs. 3–4).
//! 2. [`hselect`] — choose `h`: dynamic variance-ratio ρ (Eq. 5), static,
//!    or the Fig. 2 baseline strategies (random / norm-based column picks).
//! 3. [`ste`] — per-component straight-through-estimator refinement
//!    (§3.3, Alg. 2).
//! 4. [`pipeline`] — quantize high sub-LoRA with k-bit RTN, low with 1-bit
//!    sign binarization (§3.2); pack into a [`QuantizedLora`].
//! 5. [`factors`] — factor-form serving views ([`QFactors`]): apply the
//!    packed adapter on the activation path as two skinny GEMMs without
//!    materializing `ΔW` (DESIGN.md §8).

pub mod factors;
pub mod hselect;
pub mod pipeline;
pub mod split;
pub mod ste;

pub use factors::{
    fp_factors, fp_site_factors, FactorPair, FactorScratch, FactorSource, FactorView, QFactors,
    SiteFactors,
};
pub use hselect::{baseline_indices, select_h, HSelect, SplitStrategy};
pub use pipeline::{
    quantize_site, LoraQuantConfig, LowMode, LowQuantized, QuantizedLora, QuantizedSite,
};
pub use split::{reparameterize, split_at, split_by_indices, Reparam, SubLoras};
pub use ste::{optimize_component, optimize_factors, SteConfig, VecQuant};
