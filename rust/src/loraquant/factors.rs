//! Factor-form views: apply a (quantized) adapter's delta on the
//! activation path as two skinny GEMMs — `y += s · (x @ A′ᵀ) @ B′ᵀ` —
//! without ever materializing the dense `ΔW = B′A′` (DESIGN.md §8).
//!
//! A [`QFactors`] borrows the packed sub-LoRA factors straight out of a
//! [`QuantizedLora`] (or the dense factors of an FP adapter): nothing is
//! dequantized up front. The streaming kernels in `tensor::ops` unpack
//! one stored row at a time, so the working set per site is O(max(m, n))
//! floats regardless of rank or bitwidth.

use super::pipeline::{LowQuantized, QuantizedLora, QuantizedSite};
use crate::adapter::LoraAdapter;
use crate::quant::Axis;
use crate::tensor::{
    matmul_qdequant_acc_into, matmul_qdequant_bt_acc_into, DequantRows, Matrix,
};
use std::collections::BTreeMap;

/// Reusable scratch for factor-form applies: the rank-h bottleneck
/// activations (`u = x @ A′ᵀ`) and the single dequant row the streaming
/// kernels unpack into. A warm scratch makes every apply in the decode
/// hot loop allocation-free (DESIGN.md §10); `Default::default()` is a
/// valid cold scratch.
#[derive(Default)]
pub struct FactorScratch {
    u: Vec<f32>,
    qrow: Vec<f32>,
}

/// One stored factor plus how to contract activations against it.
///
/// `transposed == true` means the logical product needs `x @ deq(src)ᵀ`
/// (the stored rows are the sub-LoRA components); `false` means
/// `x @ deq(src)` (the stored rows are the model dimension).
#[derive(Clone, Copy)]
pub struct FactorView<'a> {
    pub src: &'a dyn DequantRows,
    pub transposed: bool,
}

impl<'a> FactorView<'a> {
    /// Contraction (input) dimension.
    pub fn in_dim(&self) -> usize {
        if self.transposed {
            self.src.src_cols()
        } else {
            self.src.src_rows()
        }
    }

    /// Output dimension.
    pub fn out_dim(&self) -> usize {
        if self.transposed {
            self.src.src_rows()
        } else {
            self.src.src_cols()
        }
    }

    /// `out[rows × out_dim] += alpha · x[rows × in_dim] @ factor`, dequant
    /// row supplied by the caller.
    pub fn contract_acc_into(
        &self,
        x: &[f32],
        rows: usize,
        alpha: f32,
        out: &mut [f32],
        qrow: &mut Vec<f32>,
    ) {
        if self.transposed {
            matmul_qdequant_bt_acc_into(x, rows, self.in_dim(), self.src, alpha, out, qrow);
        } else {
            matmul_qdequant_acc_into(x, rows, self.in_dim(), self.src, alpha, out, qrow);
        }
    }

    /// `out[rows × out_dim] += alpha · x[rows × in_dim] @ factor`.
    pub fn contract_acc(&self, x: &[f32], rows: usize, alpha: f32, out: &mut [f32]) {
        self.contract_acc_into(x, rows, alpha, out, &mut Vec::new());
    }
}

/// One sub-LoRA `(B′ m×h, A′ h×n)` in stored (packed) form.
pub struct FactorPair<'a> {
    /// Applied first: `u = x @ A′ᵀ` (rows × h).
    pub a: FactorView<'a>,
    /// Applied second: `y += s · u @ B′ᵀ` (rows × m).
    pub b: FactorView<'a>,
}

impl<'a> FactorPair<'a> {
    /// Component count `h` of this sub-LoRA.
    pub fn comps(&self) -> usize {
        self.a.out_dim()
    }

    /// `y[rows×m] += scaling · x[rows×n] @ (B′A′)ᵀ` via the rank-h
    /// bottleneck — 2·h·(m+n) MACs per activation row instead of m·n —
    /// with every intermediate taken from `fs` (allocation-free when the
    /// scratch is warm).
    pub fn apply_acc_into(
        &self,
        x: &[f32],
        rows: usize,
        scaling: f32,
        y: &mut [f32],
        fs: &mut FactorScratch,
    ) {
        let h = self.comps();
        if h == 0 || rows == 0 {
            return;
        }
        fs.u.clear();
        fs.u.resize(rows * h, 0.0);
        self.a.contract_acc_into(x, rows, 1.0, &mut fs.u, &mut fs.qrow);
        self.b.contract_acc_into(&fs.u, rows, scaling, y, &mut fs.qrow);
    }

    /// [`FactorPair::apply_acc_into`] with a one-shot scratch.
    pub fn apply_acc(&self, x: &[f32], rows: usize, scaling: f32, y: &mut [f32]) {
        self.apply_acc_into(x, rows, scaling, y, &mut FactorScratch::default());
    }
}

/// All sub-LoRAs of one adapter site, in factor form.
pub struct SiteFactors<'a> {
    /// `ΔW` shape (paper orientation: m_out × n_in).
    pub m: usize,
    pub n: usize,
    /// High- then low-precision pair (either may be absent).
    pub pairs: Vec<FactorPair<'a>>,
}

impl<'a> SiteFactors<'a> {
    /// `y[rows×m] += scaling · x[rows×n] @ ΔWᵀ` without densifying ΔW —
    /// the serving-orientation (`x @ W`) delta application, scratch
    /// supplied by the caller (the decode hot-loop entry point).
    pub fn apply_delta_acc_into(
        &self,
        x: &[f32],
        rows: usize,
        scaling: f32,
        y: &mut [f32],
        fs: &mut FactorScratch,
    ) {
        for p in &self.pairs {
            p.apply_acc_into(x, rows, scaling, y, fs);
        }
    }

    /// [`SiteFactors::apply_delta_acc_into`] with a one-shot scratch.
    pub fn apply_delta_acc(&self, x: &[f32], rows: usize, scaling: f32, y: &mut [f32]) {
        self.apply_delta_acc_into(x, rows, scaling, y, &mut FactorScratch::default());
    }

    /// Densify `ΔW` (m×n) *through the factor path* — test oracle glue;
    /// production code never calls this.
    pub fn materialize_delta(&self) -> Matrix {
        let eye = Matrix::eye(self.n);
        let mut y = Matrix::zeros(self.n, self.m);
        let rows = self.n;
        self.apply_delta_acc(eye.data(), rows, 1.0, y.data_mut());
        y.transpose()
    }
}

/// Factor-form view over a whole adapter: site name → [`SiteFactors`].
pub struct QFactors<'a> {
    pub sites: BTreeMap<String, SiteFactors<'a>>,
}

impl<'a> QFactors<'a> {
    pub fn site(&self, name: &str) -> Option<&SiteFactors<'a>> {
        self.sites.get(name)
    }
}

/// Anything that can expose a factor-form view of itself. This is the
/// type-erased handle the continuous-batching scheduler binds to a lane
/// at admission (DESIGN.md §11): engine-level code can hold adapters
/// (`Arc<dyn FactorSource>`) without depending on the serving layer's
/// concrete registry types. Implemented by `QuantizedLora` here and by
/// the coordinator's `StoredAdapter`.
pub trait FactorSource: Send + Sync {
    fn factors(&self) -> QFactors<'_>;

    /// Resolve one site's factor view directly — the per-step hot-path
    /// surface: a `DecodeState`-bound source is asked per (layer, site)
    /// instead of rebuilding the whole `QFactors` map (site-name `String`
    /// clones and a `BTreeMap`) every forward. The default is correct but
    /// cold (it builds the map and moves one entry out); implementors
    /// should override with a direct lookup.
    fn site(&self, name: &str) -> Option<SiteFactors<'_>> {
        self.factors().sites.remove(name)
    }
}

impl FactorSource for QuantizedLora {
    fn factors(&self) -> QFactors<'_> {
        QuantizedLora::factors(self)
    }

    fn site(&self, name: &str) -> Option<SiteFactors<'_>> {
        self.sites.get(name).map(QuantizedSite::factors)
    }
}

/// `transposed` flag for a stored A′ factor quantized along `axis`.
fn a_view(src: &dyn DequantRows, axis: Axis) -> FactorView<'_> {
    // Row axis ⇒ stored as A′ (h×n, component-major); Col ⇒ stored as A′ᵀ.
    FactorView { src, transposed: axis == Axis::Row }
}

/// `transposed` flag for a stored B′ factor quantized along `axis`.
fn b_view(src: &dyn DequantRows, axis: Axis) -> FactorView<'_> {
    // Col axis ⇒ stored as B′ᵀ (h×m) which is exactly what `u @ B′ᵀ`
    // contracts against; Row ⇒ stored as B′ (m×h).
    FactorView { src, transposed: axis == Axis::Row }
}

impl QuantizedSite {
    /// Borrowed factor-form view of this site (no dequantization).
    pub fn factors(&self) -> SiteFactors<'_> {
        let mut pairs = Vec::with_capacity(2);
        if let (Some(bh), Some(ah)) = (&self.bh, &self.ah) {
            pairs.push(FactorPair {
                a: a_view(ah, self.axis.a_axis),
                b: b_view(bh, self.axis.b_axis),
            });
        }
        if let (Some(bl), Some(al)) = (&self.bl, &self.al) {
            pairs.push(FactorPair {
                a: a_view(low_src(al), self.axis.a_axis),
                b: b_view(low_src(bl), self.axis.b_axis),
            });
        }
        SiteFactors { m: self.m, n: self.n, pairs }
    }
}

fn low_src(q: &LowQuantized) -> &dyn DequantRows {
    match q {
        LowQuantized::Bin(b) => b,
        LowQuantized::Rtn1(r) => r,
    }
}

impl QuantizedLora {
    /// Borrowed factor-form view of the whole adapter.
    pub fn factors(&self) -> QFactors<'_> {
        QFactors {
            sites: self.sites.iter().map(|(s, q)| (s.clone(), q.factors())).collect(),
        }
    }
}

/// Factor-form view of one **uncompressed** FP site `(A r×n, B m×r)` —
/// the single-site building block behind [`fp_factors`] and the
/// registry's per-site [`FactorSource::site`] lookups.
pub fn fp_site_factors<'a>(a: &'a Matrix, b: &'a Matrix) -> SiteFactors<'a> {
    let pair = FactorPair {
        a: FactorView { src: a, transposed: true }, // A is r×n
        b: FactorView { src: b, transposed: true }, // B is m×r
    };
    SiteFactors { m: b.rows(), n: a.cols(), pairs: vec![pair] }
}

/// Factor-form view of an **uncompressed** FP adapter — the factor path
/// serves FP16 and quantized tenants through one code path (dense
/// matrices implement [`DequantRows`] trivially).
pub fn fp_factors(adapter: &LoraAdapter) -> QFactors<'_> {
    QFactors {
        sites: adapter
            .sites
            .iter()
            .map(|(site, (a, b))| (site.clone(), fp_site_factors(a, b)))
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loraquant::{quantize_site, HSelect, LoraQuantConfig, LowMode};
    use crate::quant::QuantAxis;
    use crate::tensor::{matmul, matmul_a_bt};
    use crate::testutil::Rng;

    #[test]
    fn factor_apply_matches_dense_delta_all_axes() {
        let mut rng = Rng::new(81);
        let (b, a) = rng.lora_pair(48, 40, 8, 0.7);
        let x = rng.matrix(5, 40, 1.0);
        for axis in QuantAxis::all() {
            let cfg = LoraQuantConfig { axis, ste: None, group: 16, ..Default::default() };
            let site = quantize_site(&b, &a, &cfg).unwrap();
            let delta = site.dequant_delta();
            let oracle = matmul_a_bt(&x, &delta).scale(1.5);
            let mut y = Matrix::zeros(5, 48);
            site.factors().apply_delta_acc(x.data(), 5, 1.5, y.data_mut());
            assert!(y.rel_err(&oracle) < 1e-5, "axis {axis}: {}", y.rel_err(&oracle));
        }
    }

    #[test]
    fn materialize_matches_dequant_delta() {
        let mut rng = Rng::new(82);
        let (b, a) = rng.lora_pair(32, 48, 8, 0.6);
        for low_mode in [LowMode::Bin, LowMode::Rtn1, LowMode::Prune] {
            let cfg = LoraQuantConfig {
                low_mode,
                hselect: HSelect::Ratio(0.6),
                ste: None,
                group: 16,
                ..Default::default()
            };
            let site = quantize_site(&b, &a, &cfg).unwrap();
            let err = site.factors().materialize_delta().rel_err(&site.dequant_delta());
            assert!(err < 1e-5, "{low_mode:?}: {err}");
        }
    }

    #[test]
    fn fp_factors_apply_exact_lora_delta() {
        let mut rng = Rng::new(83);
        let (b, a) = rng.lora_pair(24, 32, 4, 0.8);
        let mut adapter = LoraAdapter::default();
        adapter.sites.insert("l0.wq".into(), (a.clone(), b.clone()));
        let qf = fp_factors(&adapter);
        let sf = qf.site("l0.wq").unwrap();
        assert_eq!((sf.m, sf.n), (24, 32));
        let x = rng.matrix(3, 32, 1.0);
        let oracle = matmul_a_bt(&x, &matmul(&b, &a)).scale(2.0);
        let mut y = Matrix::zeros(3, 24);
        sf.apply_delta_acc(x.data(), 3, 2.0, y.data_mut());
        assert!(y.rel_err(&oracle) < 1e-5);
    }

    #[test]
    fn warm_scratch_apply_matches_one_shot() {
        let mut rng = Rng::new(85);
        let (b, a) = rng.lora_pair(40, 32, 8, 0.7);
        let cfg = LoraQuantConfig { ste: None, group: 16, ..Default::default() };
        let site = quantize_site(&b, &a, &cfg).unwrap();
        let sf = site.factors();
        let mut fs = FactorScratch::default();
        // first apply warms the scratch; later applies must not change
        // results vs the one-shot, nor reallocate the warm buffers
        let (mut u_cap, mut q_cap) = (0, 0);
        for pass in 0..3 {
            let x = rng.matrix(4, 32, 1.0);
            let mut y_once = Matrix::zeros(4, 40);
            sf.apply_delta_acc(x.data(), 4, 1.5, y_once.data_mut());
            let mut y_warm = Matrix::zeros(4, 40);
            sf.apply_delta_acc_into(x.data(), 4, 1.5, y_warm.data_mut(), &mut fs);
            assert_eq!(y_warm.data(), y_once.data(), "pass {pass}");
            if pass == 0 {
                (u_cap, q_cap) = (fs.u.capacity(), fs.qrow.capacity());
                assert!(u_cap > 0 && q_cap > 0, "first apply must warm the scratch");
            } else {
                assert_eq!(fs.u.capacity(), u_cap, "warm u must not reallocate");
                assert_eq!(fs.qrow.capacity(), q_cap, "warm qrow must not reallocate");
            }
        }
    }

    #[test]
    fn all_binary_and_pruned_edges() {
        let mut rng = Rng::new(84);
        let (b, a) = rng.lora_pair(32, 32, 8, 0.7);
        // h == 0: only the low (binary) pair exists
        let cfg = LoraQuantConfig {
            hselect: HSelect::Static(0),
            ste: None,
            group: 16,
            ..Default::default()
        };
        let site = quantize_site(&b, &a, &cfg).unwrap();
        assert_eq!(site.factors().pairs.len(), 1);
        assert!(site.factors().materialize_delta().rel_err(&site.dequant_delta()) < 1e-5);
        // prune with h == r: only the high pair exists
        let cfg = LoraQuantConfig {
            hselect: HSelect::Static(8),
            low_mode: LowMode::Prune,
            ste: None,
            group: 16,
            ..Default::default()
        };
        let site = quantize_site(&b, &a, &cfg).unwrap();
        assert_eq!(site.factors().pairs.len(), 1);
        assert!(site.factors().materialize_delta().rel_err(&site.dequant_delta()) < 1e-5);
    }
}
