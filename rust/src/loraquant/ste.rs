//! Straight-through-estimator refinement of sub-LoRA components
//! (paper §3.3, Algorithm 2).
//!
//! For the i-th component pair (column `bᵢ` of B•, row `aᵢ` of A•) we
//! minimize  ‖bᵢaᵢᵀ − D(Q(bᵢ*)) D(Q(aᵢ*ᵀ))‖_F  by gradient descent,
//! treating round/sign as identity on the backward pass (STE) and the
//! group scales as per-step constants.
//!
//! Components are optimized **independently** (one pair at a time), exactly
//! as the paper argues: the SVD dimensions should not be mixed by joint
//! optimization. Both quantizers are positively scale-equivariant
//! (`D(Q(αv)) = α D(Q(v))` for α > 0), so we optimize unit-normalized
//! copies — this makes one learning rate work across components whose
//! magnitudes span the whole singular spectrum.

use crate::tensor::{dot, norm2, Matrix};

/// Which quantizer the component will eventually pass through.
#[derive(Debug, Clone, Copy)]
pub enum VecQuant {
    Rtn { bits: u32, group: usize },
    Bin { group: usize },
}

impl VecQuant {
    /// `D(Q(v))` for a vector. Semantically identical to quantize-then-
    /// dequantize through [`crate::quant`], but fused: no code packing, no
    /// matrix wrappers, no allocation beyond the output — this sits inside
    /// the STE step loop (EXPERIMENTS.md §Perf).
    pub fn roundtrip(&self, v: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; v.len()];
        self.roundtrip_into(v, &mut out);
        out
    }

    /// Allocation-free variant of [`VecQuant::roundtrip`].
    pub fn roundtrip_into(&self, v: &[f32], out: &mut [f32]) {
        debug_assert_eq!(v.len(), out.len());
        match *self {
            VecQuant::Rtn { bits, group } => {
                let qmax = ((1u32 << bits) - 1) as f32;
                for (chunk, ochunk) in v.chunks(group).zip(out.chunks_mut(group)) {
                    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
                    for &x in chunk {
                        lo = lo.min(x);
                        hi = hi.max(x);
                    }
                    if hi - lo <= 0.0 {
                        // degenerate group reconstructs the constant exactly
                        ochunk.copy_from_slice(chunk);
                        continue;
                    }
                    let s = (hi - lo) / qmax;
                    let inv_s = 1.0 / s;
                    let z = (-lo * inv_s).round();
                    for (x, o) in chunk.iter().zip(ochunk.iter_mut()) {
                        let q = ((x * inv_s).round() + z).clamp(0.0, qmax);
                        *o = s * (q - z);
                    }
                }
            }
            VecQuant::Bin { group } => {
                for (chunk, ochunk) in v.chunks(group).zip(out.chunks_mut(group)) {
                    let s = chunk.iter().map(|x| x.abs()).sum::<f32>() / chunk.len() as f32;
                    for (x, o) in chunk.iter().zip(ochunk.iter_mut()) {
                        *o = if *x >= 0.0 { s } else { -s };
                    }
                }
            }
        }
    }
}

/// STE optimization hyper-parameters (paper: converges within ~100 steps).
#[derive(Debug, Clone, Copy)]
pub struct SteConfig {
    pub steps: usize,
    pub lr: f32,
}

impl Default for SteConfig {
    fn default() -> Self {
        Self { steps: 100, lr: 0.05 }
    }
}

/// Algorithm 2 for ONE component: returns refined `(bᵢ*, aᵢ*)` minimizing
/// the post-quantization reconstruction error of the rank-1 term.
/// Keeps the best-seen iterate (GD on a non-smooth landscape can regress).
pub fn optimize_component(
    b: &[f32],
    a: &[f32],
    bq: VecQuant,
    aq: VecQuant,
    cfg: &SteConfig,
) -> (Vec<f32>, Vec<f32>) {
    let (m, n) = (b.len(), a.len());
    let cb = norm2(b);
    let ca = norm2(a);
    if cb <= 1e-20 || ca <= 1e-20 {
        return (b.to_vec(), a.to_vec());
    }
    // unit-normalized working copies (scale-equivariance of Q∘D)
    let bt: Vec<f32> = b.iter().map(|v| v / cb).collect();
    let at: Vec<f32> = a.iter().map(|v| v / ca).collect();
    let mut bo = bt.clone();
    let mut ao = at.clone();
    let mut best = (bo.clone(), ao.clone());
    let mut best_loss = f32::INFINITY;
    let inv_mn = 1.0 / (m as f32 * n as f32);

    let mut bqv = vec![0.0f32; m];
    let mut aqv = vec![0.0f32; n];
    for _ in 0..cfg.steps {
        bq.roundtrip_into(&bo, &mut bqv);
        aq.roundtrip_into(&ao, &mut aqv);
        // loss = ||bt at^T - bq aq^T||_F^2 / (mn), computed via rank-1 algebra
        let bq_bq = dot(&bqv, &bqv);
        let aq_aq = dot(&aqv, &aqv);
        let bt_bq = dot(&bt, &bqv);
        let at_aq = dot(&at, &aqv);
        // ||bt||=||at||=1
        let loss = (1.0 + bq_bq * aq_aq - 2.0 * bt_bq * at_aq) * inv_mn;
        if loss < best_loss {
            best_loss = loss;
            best = (bo.clone(), ao.clone());
        }
        // grads via STE: dL/dbq = 2/(mn) * (bq*(aq.aq) - bt*(at.aq)), etc.
        // (step size folds 2/(mn) with a sqrt(mn) un-shrink; hoisted)
        let step = cfg.lr * 2.0 * inv_mn * (m as f32 * n as f32).sqrt();
        for i in 0..m {
            bo[i] -= step * (bqv[i] * aq_aq - bt[i] * at_aq);
        }
        for j in 0..n {
            ao[j] -= step * (aqv[j] * bq_bq - at[j] * bt_bq);
        }
    }
    // check final iterate too
    {
        let bqv = bq.roundtrip(&bo);
        let aqv = aq.roundtrip(&ao);
        let loss =
            (1.0 + dot(&bqv, &bqv) * dot(&aqv, &aqv) - 2.0 * dot(&bt, &bqv) * dot(&at, &aqv)) * inv_mn;
        if loss < best_loss {
            best = (bo, ao);
        }
    }
    let (bo, ao) = best;
    (
        bo.iter().map(|v| v * cb).collect(),
        ao.iter().map(|v| v * ca).collect(),
    )
}

/// Algorithm 1 lines 9–14: refine every component of a factor pair in
/// place. `bm` is m×k (components are columns), `am` is k×n (rows).
pub fn optimize_factors(
    bm: &mut Matrix,
    am: &mut Matrix,
    bq: VecQuant,
    aq: VecQuant,
    cfg: &SteConfig,
) {
    let k = bm.cols();
    assert_eq!(k, am.rows());
    for i in 0..k {
        let bcol = bm.col(i);
        let arow = am.row(i).to_vec();
        let (nb, na) = optimize_component(&bcol, &arow, bq, aq, cfg);
        for (r, v) in nb.iter().enumerate() {
            bm.set(r, i, *v);
        }
        am.row_mut(i).copy_from_slice(&na);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::outer;
    use crate::testutil::Rng;

    fn rank1_err(b: &[f32], a: &[f32], bq: VecQuant, aq: VecQuant) -> f32 {
        let target = outer(b, a);
        let rec = outer(&bq.roundtrip(b), &aq.roundtrip(a));
        rec.sub(&target).fro_norm()
    }

    #[test]
    fn ste_reduces_quantization_error_rtn() {
        let mut rng = Rng::new(61);
        let q = VecQuant::Rtn { bits: 2, group: 32 };
        let mut improved = 0;
        for _ in 0..8 {
            let b: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
            let a: Vec<f32> = (0..96).map(|_| rng.normal()).collect();
            let before = rank1_err(&b, &a, q, q);
            let (bo, ao) = optimize_component(&b, &a, q, q, &SteConfig::default());
            // invariant: optimized pair must still approximate the SAME target
            let after = outer(&q.roundtrip(&bo), &q.roundtrip(&ao))
                .sub(&outer(&b, &a))
                .fro_norm();
            assert!(after <= before * 1.001, "after {after} > before {before}");
            if after < before * 0.98 {
                improved += 1;
            }
        }
        assert!(improved >= 5, "STE should usually improve: {improved}/8");
    }

    #[test]
    fn ste_reduces_quantization_error_bin() {
        let mut rng = Rng::new(62);
        let q = VecQuant::Bin { group: 32 };
        let b: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
        let a: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
        let before = rank1_err(&b, &a, q, q);
        let (bo, ao) = optimize_component(&b, &a, q, q, &SteConfig::default());
        let after = outer(&q.roundtrip(&bo), &q.roundtrip(&ao))
            .sub(&outer(&b, &a))
            .fro_norm();
        assert!(after <= before * 1.001);
    }

    #[test]
    fn zero_component_is_noop() {
        let q = VecQuant::Bin { group: 16 };
        let b = vec![0.0; 16];
        let a = vec![1.0; 16];
        let (bo, ao) = optimize_component(&b, &a, q, q, &SteConfig::default());
        assert_eq!(bo, b);
        assert_eq!(ao, a);
    }

    #[test]
    fn scale_equivariance_of_roundtrip() {
        let mut rng = Rng::new(63);
        for q in [VecQuant::Rtn { bits: 3, group: 16 }, VecQuant::Bin { group: 16 }] {
            let v: Vec<f32> = (0..48).map(|_| rng.normal()).collect();
            let d1: Vec<f32> = q.roundtrip(&v).iter().map(|x| x * 2.5).collect();
            let v2: Vec<f32> = v.iter().map(|x| x * 2.5).collect();
            let d2 = q.roundtrip(&v2);
            for (a, b) in d1.iter().zip(&d2) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
    }
}
