//! Algorithm 1: the end-to-end LoRAQuant pipeline for an adapter.

use super::hselect::{baseline_indices, select_h, HSelect, SplitStrategy};
use super::split::{reparameterize, split_at, split_by_indices, SubLoras};
use super::ste::{optimize_factors, SteConfig, VecQuant};
use crate::quant::{
    bin_dequant, bin_quant, rtn_dequant, rtn_quant, BinQuantized, QuantAxis, RtnQuantized,
};
use crate::tensor::{matmul, Matrix};
use std::collections::BTreeMap;

/// How the less-important sub-LoRA is treated (Fig. 3 ablations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LowMode {
    /// Sign binarization (the paper's method).
    Bin,
    /// 1-bit RTN (the "LoraQuant w/ RTN" ablation — collapses most weights).
    Rtn1,
    /// Drop it entirely (the "Prune" ablation).
    Prune,
}

/// Full pipeline configuration (defaults = the paper's 2@0.9 setting).
#[derive(Debug, Clone, Copy)]
pub struct LoraQuantConfig {
    /// RTN bitwidth for the high-precision sub-LoRA (paper: 2 or 3).
    pub bits_high: u32,
    /// h selection rule (paper default: dynamic variance ratio).
    pub hselect: HSelect,
    /// Split strategy (paper: SVD; Fig. 2 baselines: random / norm).
    pub strategy: SplitStrategy,
    /// Group size for group-wise quantization (paper: 128; our adapters
    /// are narrow, so the default here is 64 — see DESIGN.md §7).
    pub group: usize,
    /// Quantization axes for B'/A' (paper App. B default: B col, A row).
    pub axis: QuantAxis,
    /// STE refinement; `None` = the "No Opt" ablation.
    pub ste: Option<SteConfig>,
    /// Low sub-LoRA treatment.
    pub low_mode: LowMode,
}

impl Default for LoraQuantConfig {
    fn default() -> Self {
        Self {
            bits_high: 2,
            hselect: HSelect::Ratio(0.9),
            strategy: SplitStrategy::Svd,
            group: 64,
            axis: QuantAxis::default(),
            ste: Some(SteConfig::default()),
            low_mode: LowMode::Bin,
        }
    }
}

impl LoraQuantConfig {
    /// The paper's `i@ρ` shorthand, e.g. `LoraQuantConfig::variant(2, 0.9)`.
    pub fn variant(bits_high: u32, rho: f32) -> Self {
        Self { bits_high, hselect: HSelect::Ratio(rho), ..Default::default() }
    }
}

/// One quantized adapter matrix pair (one linear site).
#[derive(Debug, Clone)]
pub struct QuantizedSite {
    /// (m, n, r) of the original `B m×r, A r×n`.
    pub m: usize,
    pub n: usize,
    pub r: usize,
    /// Number of high-precision components actually used.
    pub h: usize,
    /// High sub-LoRA, RTN-quantized (stored in quantization orientation).
    pub bh: Option<RtnQuantized>,
    pub ah: Option<RtnQuantized>,
    /// Low sub-LoRA (None when pruned or h == r).
    pub bl: Option<LowQuantized>,
    pub al: Option<LowQuantized>,
    pub axis: QuantAxis,
}

/// Low sub-LoRA storage: binary or 1-bit RTN (ablation).
#[derive(Debug, Clone)]
pub enum LowQuantized {
    Bin(BinQuantized),
    Rtn1(RtnQuantized),
}

impl LowQuantized {
    fn dequant(&self) -> Matrix {
        match self {
            LowQuantized::Bin(q) => bin_dequant(q),
            LowQuantized::Rtn1(q) => rtn_dequant(q),
        }
    }

    fn storage_bits(&self) -> u64 {
        match self {
            LowQuantized::Bin(q) => q.storage_bits(),
            LowQuantized::Rtn1(q) => q.storage_bits(),
        }
    }

    fn packed_bytes(&self) -> usize {
        match self {
            LowQuantized::Bin(q) => q.packed_bytes(),
            LowQuantized::Rtn1(q) => q.packed_bytes(),
        }
    }
}

impl QuantizedSite {
    /// Dequantize the full adapter delta `ΔW = Bh Ah + Bl Al` (m×n).
    pub fn dequant_delta(&self) -> Matrix {
        let mut delta = Matrix::zeros(self.m, self.n);
        if let (Some(bh), Some(ah)) = (&self.bh, &self.ah) {
            let b = self.axis.b_axis.restore(rtn_dequant(bh));
            let a = self.axis.a_axis.restore(rtn_dequant(ah));
            delta.axpy(1.0, &matmul(&b, &a));
        }
        if let (Some(bl), Some(al)) = (&self.bl, &self.al) {
            let b = self.axis.b_axis.restore(bl.dequant());
            let a = self.axis.a_axis.restore(al.dequant());
            delta.axpy(1.0, &matmul(&b, &a));
        }
        delta
    }

    /// Eq. 10 numerator contribution.
    pub fn storage_bits(&self) -> u64 {
        let mut bits = 0;
        for q in [&self.bh, &self.ah].into_iter().flatten() {
            bits += q.storage_bits();
        }
        for q in [&self.bl, &self.al].into_iter().flatten() {
            bits += q.storage_bits();
        }
        bits
    }

    /// Original LoRA parameter count `r(m+n)` (Eq. 10 denominator).
    pub fn param_count(&self) -> usize {
        self.r * (self.m + self.n)
    }

    /// Average bits per original parameter.
    pub fn avg_bits(&self) -> f64 {
        self.storage_bits() as f64 / self.param_count() as f64
    }

    /// Actual in-memory packed footprint in bytes.
    pub fn packed_bytes(&self) -> usize {
        let mut bytes = 0;
        for q in [&self.bh, &self.ah].into_iter().flatten() {
            bytes += q.packed_bytes();
        }
        for q in [&self.bl, &self.al].into_iter().flatten() {
            bytes += q.packed_bytes();
        }
        bytes
    }
}

/// A whole quantized adapter: site name (e.g. `l2.wq`) → quantized pair.
#[derive(Debug, Clone, Default)]
pub struct QuantizedLora {
    pub sites: BTreeMap<String, QuantizedSite>,
}

impl QuantizedLora {
    pub fn storage_bits(&self) -> u64 {
        self.sites.values().map(|s| s.storage_bits()).sum()
    }

    pub fn param_count(&self) -> usize {
        self.sites.values().map(|s| s.param_count()).sum()
    }

    /// Eq. 10 over the whole adapter.
    pub fn avg_bits(&self) -> f64 {
        self.storage_bits() as f64 / self.param_count() as f64
    }

    pub fn packed_bytes(&self) -> usize {
        self.sites.values().map(|s| s.packed_bytes()).sum()
    }
}

/// Algorithm 1 for one site: split → (STE) → mixed-precision quantize.
/// Malformed inputs or configurations (shape mismatch, a baseline split
/// strategy paired with the variance-ratio rule) are structured errors,
/// not panics — a bad adapter fails its own registration, never the
/// process (DESIGN.md §15).
pub fn quantize_site(
    b: &Matrix,
    a: &Matrix,
    cfg: &LoraQuantConfig,
) -> anyhow::Result<QuantizedSite> {
    let (m, r) = b.shape();
    let n = a.cols();
    anyhow::ensure!(a.rows() == r, "rank mismatch: B {:?} vs A {:?}", b.shape(), a.shape());

    // 1) split
    let mut sub: SubLoras = match cfg.strategy {
        SplitStrategy::Svd => {
            let rp = reparameterize(b, a);
            let h = select_h(&rp.s, cfg.hselect);
            split_at(&rp, h)
        }
        _ => {
            let h = match cfg.hselect {
                HSelect::Static(h) => h,
                HSelect::Ratio(_) => anyhow::bail!(
                    "baseline split strategies (random/norm) require HSelect::Static \
                     — the variance-ratio rule is defined on the SVD spectrum"
                ),
            };
            let idx = baseline_indices(b, a, h, cfg.strategy)?;
            split_by_indices(b, a, &idx)
        }
    };

    let high_q = VecQuant::Rtn { bits: cfg.bits_high, group: cfg.group };
    let low_q = match cfg.low_mode {
        LowMode::Bin => VecQuant::Bin { group: cfg.group },
        LowMode::Rtn1 | LowMode::Prune => VecQuant::Rtn { bits: 1, group: cfg.group },
    };

    // 2) STE refinement (per component, high and low independently)
    if let Some(ste) = &cfg.ste {
        optimize_factors(&mut sub.bh, &mut sub.ah, high_q, high_q, ste);
        if cfg.low_mode != LowMode::Prune && sub.bl.cols() > 0 {
            optimize_factors(&mut sub.bl, &mut sub.al, low_q, low_q, ste);
        }
    }

    // 3) quantize in the configured orientation
    let (bh, ah) = if sub.h > 0 {
        (
            Some(rtn_quant(&cfg.axis.b_axis.orient(&sub.bh), cfg.bits_high, cfg.group)),
            Some(rtn_quant(&cfg.axis.a_axis.orient(&sub.ah), cfg.bits_high, cfg.group)),
        )
    } else {
        (None, None)
    };
    let (bl, al) = if cfg.low_mode == LowMode::Prune || sub.bl.cols() == 0 {
        (None, None)
    } else {
        match cfg.low_mode {
            LowMode::Bin => (
                Some(LowQuantized::Bin(bin_quant(&cfg.axis.b_axis.orient(&sub.bl), cfg.group))),
                Some(LowQuantized::Bin(bin_quant(&cfg.axis.a_axis.orient(&sub.al), cfg.group))),
            ),
            LowMode::Rtn1 => (
                Some(LowQuantized::Rtn1(rtn_quant(&cfg.axis.b_axis.orient(&sub.bl), 1, cfg.group))),
                Some(LowQuantized::Rtn1(rtn_quant(&cfg.axis.a_axis.orient(&sub.al), 1, cfg.group))),
            ),
            LowMode::Prune => unreachable!(),
        }
    };

    Ok(QuantizedSite { m, n, r, h: sub.h, bh, ah, bl, al, axis: cfg.axis })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;

    fn sample(rng: &mut Rng) -> (Matrix, Matrix, Matrix) {
        let (b, a) = rng.lora_pair(96, 64, 16, 0.65);
        let ba = matmul(&b, &a);
        (b, a, ba)
    }

    #[test]
    fn default_pipeline_reconstructs_reasonably() {
        let mut rng = Rng::new(71);
        let (b, a, ba) = sample(&mut rng);
        let site = quantize_site(&b, &a, &LoraQuantConfig::default()).unwrap();
        let err = site.dequant_delta().rel_err(&ba);
        // Weight-space error at <2 avg bits is sizeable; what matters (and
        // what the paper claims) is that it beats flat ultra-low-bit
        // quantization by a wide margin at similar storage.
        assert!(err < 0.8, "rel err {err}");
        assert!(site.avg_bits() < 2.0, "avg bits {}", site.avg_bits());
        assert!(site.avg_bits() > 1.0);
        // all-binary baseline at comparable bits is much worse
        let bin_only = quantize_site(
            &b,
            &a,
            &LoraQuantConfig {
                hselect: HSelect::Static(0),
                ste: None,
                ..Default::default()
            },
        )
        .unwrap();
        let bin_err = bin_only.dequant_delta().rel_err(&ba);
        assert!(err < bin_err * 0.85, "loraquant {err} vs all-binary {bin_err}");
    }

    #[test]
    fn higher_rho_more_bits_less_error() {
        let mut rng = Rng::new(72);
        let (b, a, ba) = sample(&mut rng);
        let lo = quantize_site(&b, &a, &LoraQuantConfig::variant(2, 0.5)).unwrap();
        let hi = quantize_site(&b, &a, &LoraQuantConfig::variant(2, 0.99)).unwrap();
        assert!(hi.avg_bits() > lo.avg_bits());
        let e_lo = lo.dequant_delta().rel_err(&ba);
        let e_hi = hi.dequant_delta().rel_err(&ba);
        assert!(e_hi < e_lo, "rho .99 err {e_hi} vs rho .5 err {e_lo}");
    }

    #[test]
    fn prune_drops_low_and_hurts() {
        let mut rng = Rng::new(73);
        let (b, a, ba) = sample(&mut rng);
        let cfg = LoraQuantConfig {
            low_mode: LowMode::Prune,
            hselect: HSelect::Ratio(0.5),
            ste: None,
            ..Default::default()
        };
        let pruned = quantize_site(&b, &a, &cfg).unwrap();
        assert!(pruned.bl.is_none());
        let full = quantize_site(
            &b,
            &a,
            &LoraQuantConfig { ste: None, hselect: HSelect::Ratio(0.5), ..Default::default() },
        )
        .unwrap();
        assert!(
            pruned.dequant_delta().rel_err(&ba) > full.dequant_delta().rel_err(&ba),
            "binary low sub-LoRA must beat pruning"
        );
        assert!(pruned.avg_bits() < full.avg_bits());
    }

    #[test]
    fn ste_improves_reconstruction() {
        let mut rng = Rng::new(74);
        let (b, a, ba) = sample(&mut rng);
        let base = LoraQuantConfig { ste: None, ..Default::default() };
        let opt = LoraQuantConfig::default();
        let e0 = quantize_site(&b, &a, &base).unwrap().dequant_delta().rel_err(&ba);
        let e1 = quantize_site(&b, &a, &opt).unwrap().dequant_delta().rel_err(&ba);
        assert!(e1 <= e0 * 1.02, "ste {e1} vs none {e0}");
    }

    #[test]
    fn static_h_boundaries() {
        let mut rng = Rng::new(75);
        let (b, a, ba) = sample(&mut rng);
        for h in [0usize, 16] {
            let cfg = LoraQuantConfig {
                hselect: HSelect::Static(h),
                ste: None,
                ..Default::default()
            };
            let site = quantize_site(&b, &a, &cfg).unwrap();
            assert_eq!(site.h, h);
            // still produces a usable delta
            assert!(site.dequant_delta().rel_err(&ba) < 1.0);
            if h == 0 {
                assert!(site.bh.is_none());
            } else {
                assert!(site.bl.is_none());
            }
        }
    }

    #[test]
    fn norm_split_strategy_works_end_to_end() {
        let mut rng = Rng::new(76);
        let (b, a, ba) = sample(&mut rng);
        let cfg = LoraQuantConfig {
            strategy: SplitStrategy::Norm,
            hselect: HSelect::Static(4),
            ste: None,
            ..Default::default()
        };
        let site = quantize_site(&b, &a, &cfg).unwrap();
        assert_eq!(site.h, 4);
        assert!(site.dequant_delta().rel_err(&ba) < 1.0);
    }

    #[test]
    fn malformed_configs_error_instead_of_panicking() {
        let mut rng = Rng::new(78);
        let (b, a, _) = sample(&mut rng);
        // variance-ratio rule with a non-SVD split: defined only on the
        // SVD spectrum, so this must be a structured Err
        let cfg = LoraQuantConfig {
            strategy: SplitStrategy::Norm,
            hselect: HSelect::Ratio(0.9),
            ..Default::default()
        };
        let err = quantize_site(&b, &a, &cfg).unwrap_err();
        assert!(err.to_string().contains("HSelect::Static"), "{err}");
        // rank mismatch between B and A
        let bad_a = Matrix::zeros(a.rows() + 1, a.cols());
        let err = quantize_site(&b, &bad_a, &LoraQuantConfig::default()).unwrap_err();
        assert!(err.to_string().contains("rank mismatch"), "{err}");
    }

    #[test]
    fn avg_bits_accounting_consistency() {
        let mut rng = Rng::new(77);
        let (b, a, _) = sample(&mut rng);
        let site = quantize_site(&b, &a, &LoraQuantConfig::default()).unwrap();
        let mut lora = QuantizedLora::default();
        lora.sites.insert("l0.wq".into(), site.clone());
        lora.sites.insert("l0.wk".into(), site);
        assert!((lora.avg_bits() - lora.sites["l0.wq"].avg_bits()).abs() < 1e-12);
    }
}
