//! SVD reparameterization and sub-LoRA splitting (paper §3.1, Eqs. 1–4).

use crate::linalg::{svd_lowrank_product, Svd};
use crate::tensor::Matrix;

/// The SVD-reparameterized adapter: `B' = U √S` (m×r), `A' = √S Vᵀ` (r×n),
/// with `B' A' = B A` and per-component importance = singular value.
#[derive(Debug, Clone)]
pub struct Reparam {
    pub b: Matrix,
    pub a: Matrix,
    /// Singular values, descending.
    pub s: Vec<f32>,
}

/// A split adapter: high-importance sub-LoRA (first `h` components) and
/// low-importance sub-LoRA (remaining `r - h`).
#[derive(Debug, Clone)]
pub struct SubLoras {
    pub bh: Matrix,
    pub ah: Matrix,
    pub bl: Matrix,
    pub al: Matrix,
    pub h: usize,
}

impl SubLoras {
    /// Reconstruct `Bh Ah + Bl Al` (== B'A' == BA exactly, Eq. 4).
    pub fn reconstruct(&self) -> Matrix {
        let mut out = crate::tensor::matmul(&self.bh, &self.ah);
        if self.bl.cols() > 0 {
            out.axpy(1.0, &crate::tensor::matmul(&self.bl, &self.al));
        }
        out
    }
}

/// Eq. 2: reparameterize `BA` as `B' = U√S`, `A' = √S Vᵀ` via the low-rank
/// product SVD (never materializes the m×n product).
pub fn reparameterize(b: &Matrix, a: &Matrix) -> Reparam {
    let Svd { u, s, vt } = svd_lowrank_product(b, a);
    let r = s.len();
    let (m, n) = (u.rows(), vt.cols());
    let mut bp = Matrix::zeros(m, r);
    let mut ap = Matrix::zeros(r, n);
    for k in 0..r {
        let sq = s[k].max(0.0).sqrt();
        for i in 0..m {
            bp.set(i, k, u.at(i, k) * sq);
        }
        for j in 0..n {
            ap.set(k, j, vt.at(k, j) * sq);
        }
    }
    Reparam { b: bp, a: ap, s }
}

/// Eqs. 3–4: split a reparameterized adapter at component `h`.
pub fn split_at(rp: &Reparam, h: usize) -> SubLoras {
    let r = rp.s.len();
    let h = h.min(r);
    SubLoras {
        bh: rp.b.slice_cols(0, h),
        ah: rp.a.slice_rows(0, h),
        bl: rp.b.slice_cols(h, r),
        al: rp.a.slice_rows(h, r),
        h,
    }
}

/// Split the **original** factors by explicit component indices — the
/// Fig. 2 baseline strategies (random / norm-based) that skip the SVD.
pub fn split_by_indices(b: &Matrix, a: &Matrix, high_idx: &[usize]) -> SubLoras {
    let r = b.cols();
    let high: std::collections::BTreeSet<usize> = high_idx.iter().copied().collect();
    let low: Vec<usize> = (0..r).filter(|i| !high.contains(i)).collect();
    let high: Vec<usize> = high.into_iter().collect();
    SubLoras {
        bh: b.gather_cols(&high),
        ah: a.gather_rows(&high),
        bl: b.gather_cols(&low),
        al: a.gather_rows(&low),
        h: high.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul;
    use crate::testutil::Rng;

    #[test]
    fn reparam_preserves_product() {
        let mut rng = Rng::new(51);
        let (b, a) = rng.lora_pair(96, 64, 16, 0.7);
        let ba = matmul(&b, &a);
        let rp = reparameterize(&b, &a);
        assert!(matmul(&rp.b, &rp.a).rel_err(&ba) < 1e-4);
    }

    #[test]
    fn split_sums_to_product() {
        let mut rng = Rng::new(52);
        let (b, a) = rng.lora_pair(64, 80, 16, 0.6);
        let ba = matmul(&b, &a);
        let rp = reparameterize(&b, &a);
        for h in [0, 1, 4, 8, 16] {
            let sl = split_at(&rp, h);
            assert!(sl.reconstruct().rel_err(&ba) < 1e-4, "h={h}");
            assert_eq!(sl.bh.cols(), h);
            assert_eq!(sl.al.rows(), 16 - h);
        }
    }

    #[test]
    fn importance_concentrated_in_leading_components() {
        let mut rng = Rng::new(53);
        let (b, a) = rng.lora_pair(64, 64, 16, 0.5);
        let rp = reparameterize(&b, &a);
        // ||b'_k a'_k|| = s_k, descending
        for k in 0..15 {
            let nk = crate::tensor::norm2(&rp.b.col(k)) * crate::tensor::norm2(rp.a.row(k));
            let nk1 = crate::tensor::norm2(&rp.b.col(k + 1)) * crate::tensor::norm2(rp.a.row(k + 1));
            assert!(nk >= nk1 * 0.99, "k={k}: {nk} < {nk1}");
        }
    }

    #[test]
    fn index_split_partitions() {
        let mut rng = Rng::new(54);
        let (b, a) = rng.lora_pair(32, 40, 8, 0.8);
        let ba = matmul(&b, &a);
        let sl = split_by_indices(&b, &a, &[0, 3, 5]);
        assert_eq!(sl.h, 3);
        assert!(sl.reconstruct().rel_err(&ba) < 1e-5);
    }
}
