//! Choosing the high-precision rank `h` and the split strategy.
//!
//! The paper's default is the **dynamic variance-ratio rule** (Eq. 5): the
//! smallest `h` whose top-h singular values explain at least ρ of the total
//! variance Σsᵢ². Fig. 4 compares it against a globally fixed `h`; Fig. 2
//! compares the SVD split itself against random / norm-based column picks.

use crate::tensor::{norm2, Matrix};
use crate::testutil::Rng;
use anyhow::bail;

/// How to pick the number of high-precision components.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum HSelect {
    /// Eq. 5: smallest h with Σ_{i<=h} sᵢ² / Σ sᵢ² >= ρ.
    Ratio(f32),
    /// Fixed h for every adapter (Fig. 4 "Static").
    Static(usize),
}

/// Which components go to the high-precision sub-LoRA (Fig. 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SplitStrategy {
    /// SVD reparameterization, leading components (the paper's method).
    Svd,
    /// Random component indices of the *original* factors.
    Random { seed: u64 },
    /// Components of the original factors with the largest ‖bᵢ‖‖aᵢ‖
    /// (Frobenius norm of the rank-1 term bᵢaᵢᵀ).
    Norm,
}

/// Eq. 5 on a singular-value vector (descending). Returns the smallest `h`
/// such that the top-h squared mass covers at least `rho` of the total.
/// Degenerate all-zero spectra return 0.
pub fn select_h(s: &[f32], rule: HSelect) -> usize {
    match rule {
        HSelect::Static(h) => h.min(s.len()),
        HSelect::Ratio(rho) => {
            assert!(rho > 0.0 && rho <= 1.0, "rho {rho}");
            let total: f64 = s.iter().map(|&x| (x as f64) * (x as f64)).sum();
            if total <= 0.0 {
                return 0;
            }
            let mut acc = 0.0f64;
            for (i, &x) in s.iter().enumerate() {
                acc += (x as f64) * (x as f64);
                if acc / total >= rho as f64 {
                    return i + 1;
                }
            }
            s.len()
        }
    }
}

/// Component indices of the original factors chosen as "important" under a
/// Fig. 2 baseline strategy (`h` many of `0..r`). `SplitStrategy::Svd`
/// is a configuration error here — the SVD split keeps leading
/// reparameterized components instead of selecting original indices.
pub fn baseline_indices(
    b: &Matrix,
    a: &Matrix,
    h: usize,
    strategy: SplitStrategy,
) -> anyhow::Result<Vec<usize>> {
    let r = b.cols();
    let h = h.min(r);
    match strategy {
        SplitStrategy::Svd => {
            bail!("SVD strategy does not use index selection (use the reparameterized split)")
        }
        SplitStrategy::Random { seed } => {
            let mut idx: Vec<usize> = (0..r).collect();
            let mut rng = Rng::new(seed);
            rng.shuffle(&mut idx);
            idx.truncate(h);
            idx.sort_unstable();
            Ok(idx)
        }
        SplitStrategy::Norm => {
            // ||b_i a_i^T||_F = ||b_i|| * ||a_i||
            let mut scored: Vec<(usize, f32)> = (0..r)
                .map(|i| (i, norm2(&b.col(i)) * norm2(a.row(i))))
                .collect();
            scored.sort_by(|x, y| y.1.total_cmp(&x.1));
            let mut idx: Vec<usize> = scored.into_iter().take(h).map(|(i, _)| i).collect();
            idx.sort_unstable();
            Ok(idx)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_rule_matches_hand_computation() {
        // s² = [16, 4, 1, 1]; total = 22
        let s = [4.0, 2.0, 1.0, 1.0];
        assert_eq!(select_h(&s, HSelect::Ratio(0.5)), 1); // 16/22 = .727
        assert_eq!(select_h(&s, HSelect::Ratio(0.73)), 2); // 20/22 = .909
        assert_eq!(select_h(&s, HSelect::Ratio(0.95)), 3); // 21/22 = .954
        assert_eq!(select_h(&s, HSelect::Ratio(1.0)), 4);
    }

    #[test]
    fn ratio_monotone_in_rho() {
        let s: Vec<f32> = (0..16).map(|i| 0.8f32.powi(i)).collect();
        let mut prev = 0;
        for k in 1..=19 {
            let h = select_h(&s, HSelect::Ratio(k as f32 * 0.05));
            assert!(h >= prev);
            prev = h;
        }
    }

    #[test]
    fn static_clamps() {
        assert_eq!(select_h(&[1.0, 1.0], HSelect::Static(5)), 2);
        assert_eq!(select_h(&[1.0, 1.0], HSelect::Static(1)), 1);
    }

    #[test]
    fn zero_spectrum() {
        assert_eq!(select_h(&[0.0, 0.0], HSelect::Ratio(0.9)), 0);
    }

    #[test]
    fn norm_strategy_picks_largest() {
        use crate::tensor::Matrix;
        // component 1 has much larger norm than 0 and 2
        let b = Matrix::from_fn(4, 3, |_, j| if j == 1 { 10.0 } else { 0.1 });
        let a = Matrix::from_fn(3, 4, |i, _| if i == 1 { 10.0 } else { 0.1 });
        assert_eq!(baseline_indices(&b, &a, 1, SplitStrategy::Norm).unwrap(), vec![1]);
    }

    #[test]
    fn random_strategy_deterministic_per_seed() {
        use crate::tensor::Matrix;
        let b = Matrix::zeros(4, 8);
        let a = Matrix::zeros(8, 4);
        let i1 = baseline_indices(&b, &a, 3, SplitStrategy::Random { seed: 7 }).unwrap();
        let i2 = baseline_indices(&b, &a, 3, SplitStrategy::Random { seed: 7 }).unwrap();
        assert_eq!(i1, i2);
        assert_eq!(i1.len(), 3);
    }

    #[test]
    fn svd_strategy_is_a_structured_error_not_a_panic() {
        use crate::tensor::Matrix;
        let b = Matrix::zeros(4, 3);
        let a = Matrix::zeros(3, 4);
        let err = baseline_indices(&b, &a, 2, SplitStrategy::Svd).unwrap_err();
        assert!(err.to_string().contains("index selection"), "{err}");
    }
}
