//! Time as a dependency: a [`Clock`] is either the real monotonic clock
//! or a shared **virtual clock** that only moves when a driver advances
//! it.
//!
//! Every timing-dependent behavior of the serving stack — batch max-wait
//! deadlines, cold-miss parking, auto-strategy merge races, latency
//! metrics — reads time through a `Clock` handle instead of calling
//! `Instant::now()` directly. Under [`Clock::real`] nothing changes; under
//! a [`VirtualClock`] the entire coordinator runs in simulated time, so a
//! scenario driver (see [`crate::scenario`]) can replay a multi-second
//! workload trace in microseconds of wall clock and get **deterministic**
//! timestamps: the clock only moves at driver-controlled barriers, so
//! every event lands at an exactly reproducible virtual instant.
//!
//! The virtual clock also plays the role of a discrete-event timer wheel:
//! threads (e.g. a fault-injected slow merge) block in
//! [`VirtualClock::sleep_until`], which registers the wake deadline where
//! the driver can see it ([`VirtualClock::sleepers`]) and include it in
//! its next-event computation. Advancing the clock wakes every sleeper
//! whose deadline has been reached.
//!
//! The observability layer ([`crate::obs`]) stamps its lifecycle spans
//! from this same clock: because workers only observe a frozen virtual
//! clock between driver barriers, span timestamps are a function of the
//! schedule, which is what makes exported traces byte-reproducible
//! (DESIGN.md §16).

use std::collections::BTreeMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// A time source: the real monotonic clock, or a shared virtual clock.
///
/// Cloning is cheap; clones of a virtual clock share the same timeline.
#[derive(Clone)]
pub struct Clock {
    inner: Inner,
}

#[derive(Clone)]
enum Inner {
    Real,
    Virtual(Arc<VirtualClock>),
}

impl Clock {
    /// The real monotonic clock (production default).
    pub fn real() -> Self {
        Self { inner: Inner::Real }
    }

    /// A handle onto a shared virtual clock.
    pub fn virtual_from(vc: &Arc<VirtualClock>) -> Self {
        Self { inner: Inner::Virtual(Arc::clone(vc)) }
    }

    /// Current instant on this clock's timeline.
    pub fn now(&self) -> Instant {
        match &self.inner {
            Inner::Real => Instant::now(),
            Inner::Virtual(vc) => vc.now(),
        }
    }

    /// Whether this is a virtual clock (event loops use this to pick a
    /// real-time poll interval instead of trusting virtual deadlines).
    pub fn is_virtual(&self) -> bool {
        matches!(self.inner, Inner::Virtual(_))
    }

    /// Block the calling thread until `deadline`. On the real clock this
    /// is a plain sleep; on a virtual clock the thread parks until a
    /// driver advances time past the deadline (registering itself as a
    /// sleeper the driver can observe).
    pub fn sleep_until(&self, deadline: Instant) {
        match &self.inner {
            Inner::Real => {
                let now = Instant::now();
                if deadline > now {
                    std::thread::sleep(deadline - now);
                }
            }
            Inner::Virtual(vc) => vc.sleep_until(deadline),
        }
    }
}

impl Default for Clock {
    fn default() -> Self {
        Self::real()
    }
}

impl std::fmt::Debug for Clock {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.inner {
            Inner::Real => f.write_str("Clock::Real"),
            Inner::Virtual(vc) => write!(f, "Clock::Virtual(t={:?})", vc.elapsed()),
        }
    }
}

/// Mutable state behind the virtual clock's mutex.
struct VcState {
    /// Nanoseconds since the clock's origin.
    now_ns: u64,
    /// Registered sleeper deadlines (absolute ns → count of threads).
    sleepers: BTreeMap<u64, usize>,
}

/// A driver-advanced timeline shared by every [`Clock`] handle cloned
/// from it. Time never moves on its own.
pub struct VirtualClock {
    /// Fixed real anchor: virtual instant = `origin + now_ns`.
    origin: Instant,
    state: Mutex<VcState>,
    wake: Condvar,
}

impl VirtualClock {
    /// A fresh timeline at t = 0.
    pub fn new() -> Arc<Self> {
        Arc::new(Self {
            origin: Instant::now(),
            state: Mutex::new(VcState { now_ns: 0, sleepers: BTreeMap::new() }),
            wake: Condvar::new(),
        })
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, VcState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Current virtual instant.
    pub fn now(&self) -> Instant {
        self.origin + Duration::from_nanos(self.lock().now_ns)
    }

    /// Virtual time elapsed since the origin.
    pub fn elapsed(&self) -> Duration {
        Duration::from_nanos(self.lock().now_ns)
    }

    /// Convert an instant on this timeline to an offset from the origin.
    /// Instants predating the origin clamp to zero.
    pub fn offset_of(&self, t: Instant) -> Duration {
        t.saturating_duration_since(self.origin)
    }

    /// Advance the timeline by `d`, waking any sleeper whose deadline has
    /// been reached.
    pub fn advance(&self, d: Duration) {
        let mut s = self.lock();
        s.now_ns = s.now_ns.saturating_add(d.as_nanos() as u64);
        drop(s);
        self.wake.notify_all();
    }

    /// Advance the timeline to the absolute offset `t` (no-op if already
    /// past it — the clock never goes backwards).
    pub fn advance_to(&self, t: Duration) {
        let mut s = self.lock();
        s.now_ns = s.now_ns.max(t.as_nanos() as u64);
        drop(s);
        self.wake.notify_all();
    }

    /// Block until the timeline reaches `deadline`, registering the
    /// deadline so a driver can see it via [`Self::sleepers`]. Returns
    /// immediately if the deadline has already passed.
    pub fn sleep_until(&self, deadline: Instant) {
        let target_ns = deadline.saturating_duration_since(self.origin).as_nanos() as u64;
        let mut s = self.lock();
        if s.now_ns >= target_ns {
            return;
        }
        *s.sleepers.entry(target_ns).or_insert(0) += 1;
        while s.now_ns < target_ns {
            s = self.wake.wait(s).unwrap_or_else(|e| e.into_inner());
        }
        match s.sleepers.get_mut(&target_ns) {
            Some(c) if *c > 1 => *c -= 1,
            _ => {
                s.sleepers.remove(&target_ns);
            }
        }
    }

    /// (number of sleeping threads, earliest wake offset): the driver's
    /// view of time-blocked work. A thread between deciding to sleep and
    /// registering its deadline is still invisible here, so drivers poll
    /// until counts stabilize against their own bookkeeping.
    pub fn sleepers(&self) -> (usize, Option<Duration>) {
        let s = self.lock();
        let count = s.sleepers.values().sum();
        let earliest = s.sleepers.keys().next().map(|&ns| Duration::from_nanos(ns));
        (count, earliest)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_clock_is_monotonic() {
        let c = Clock::real();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
        assert!(!c.is_virtual());
    }

    #[test]
    fn virtual_clock_only_moves_when_advanced() {
        let vc = VirtualClock::new();
        let c = Clock::virtual_from(&vc);
        assert!(c.is_virtual());
        let t0 = c.now();
        std::thread::sleep(Duration::from_millis(2));
        assert_eq!(c.now(), t0, "virtual time must not follow real time");
        vc.advance(Duration::from_millis(250));
        assert_eq!(c.now() - t0, Duration::from_millis(250));
        assert_eq!(vc.elapsed(), Duration::from_millis(250));
    }

    #[test]
    fn advance_to_is_monotone() {
        let vc = VirtualClock::new();
        vc.advance_to(Duration::from_millis(10));
        vc.advance_to(Duration::from_millis(5)); // must not rewind
        assert_eq!(vc.elapsed(), Duration::from_millis(10));
        vc.advance_to(Duration::from_millis(30));
        assert_eq!(vc.elapsed(), Duration::from_millis(30));
    }

    #[test]
    fn clones_share_one_timeline() {
        let vc = VirtualClock::new();
        let a = Clock::virtual_from(&vc);
        let b = a.clone();
        vc.advance(Duration::from_secs(1));
        assert_eq!(a.now(), b.now());
    }

    #[test]
    fn sleeper_blocks_until_advanced_and_is_observable() {
        let vc = VirtualClock::new();
        let c = Clock::virtual_from(&vc);
        let deadline = c.now() + Duration::from_millis(100);
        let vc2 = Arc::clone(&vc);
        let done = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let done2 = Arc::clone(&done);
        let j = std::thread::spawn(move || {
            Clock::virtual_from(&vc2).sleep_until(deadline);
            done2.store(true, std::sync::atomic::Ordering::SeqCst);
        });
        // wait (real time) until the sleeper registers
        let t0 = Instant::now();
        loop {
            let (n, earliest) = vc.sleepers();
            if n == 1 {
                assert_eq!(earliest, Some(Duration::from_millis(100)));
                break;
            }
            assert!(t0.elapsed() < Duration::from_secs(5), "sleeper never registered");
            std::thread::sleep(Duration::from_micros(200));
        }
        assert!(!done.load(std::sync::atomic::Ordering::SeqCst));
        // an advance short of the deadline must not wake it
        vc.advance(Duration::from_millis(50));
        std::thread::sleep(Duration::from_millis(2));
        assert!(!done.load(std::sync::atomic::Ordering::SeqCst));
        vc.advance(Duration::from_millis(50));
        j.join().unwrap();
        assert!(done.load(std::sync::atomic::Ordering::SeqCst));
        assert_eq!(vc.sleepers().0, 0, "woken sleeper must deregister");
    }

    #[test]
    fn sleep_until_past_deadline_returns_immediately() {
        let vc = VirtualClock::new();
        vc.advance(Duration::from_secs(1));
        let c = Clock::virtual_from(&vc);
        c.sleep_until(c.now()); // must not block
        assert_eq!(vc.sleepers().0, 0);
    }

    #[test]
    fn offset_roundtrip() {
        let vc = VirtualClock::new();
        vc.advance(Duration::from_micros(1234));
        let t = vc.now();
        assert_eq!(vc.offset_of(t), Duration::from_micros(1234));
    }
}
