//! The scenario event log: timestamped, canonically ordered, rendered as
//! stable text lines — the unit golden-trace tests compare.
//!
//! The request-lifecycle span trace ([`crate::obs::trace`]) applies the
//! same canonical-ordering discipline to its per-thread shards, so its
//! Chrome trace export is byte-reproducible for the same reason this
//! log is (DESIGN.md §16).

use crate::coordinator::AdapterId;
use std::time::Duration;

/// One thing that happened during a scenario, at a scenario-clock offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Event {
    pub t: Duration,
    pub kind: EventKind,
}

/// Event payloads. Request indices are positions in the arrival trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EventKind {
    /// Adapter registered (at setup or via churn).
    Register { adapter: AdapterId },
    /// Adapter removed via churn.
    Remove { adapter: AdapterId },
    /// A merge began on a merge-pool thread (before any scripted delay).
    MergeBegin { adapter: AdapterId },
    /// A disk-tier factor load began on a merge-pool thread (before any
    /// scripted disk-latency delay).
    DiskLoad { adapter: AdapterId },
    /// Prefetch acknowledged for an adapter.
    Prefetch { adapter: AdapterId, ok: bool },
    /// Request submitted to the coordinator.
    Submit { req: usize, adapter: AdapterId },
    /// Request completed; `t` is the completion offset (submit + e2e).
    Complete { req: usize, adapter: AdapterId, e2e: Duration, tokens: Vec<i32> },
    /// Request failed (e.g. its adapter was churned away).
    Fail { req: usize, adapter: AdapterId, error: String },
    /// A scripted disk-tier load failure (attempt is 0-based: 0 is the
    /// initial try, 1.. are retries).
    DiskError { adapter: AdapterId, attempt: u32 },
    /// A scripted merge-task panic fired on a pool thread.
    Panic { adapter: AdapterId },
    /// Adapter quarantined (scripted churn or permanent load failure).
    Quarantine { adapter: AdapterId },
    /// Adapter quarantine lifted via scripted churn.
    Recover { adapter: AdapterId },
}

impl EventKind {
    /// Rank for canonical ordering of same-instant events: registry
    /// mutations before merges before submissions before completions.
    fn rank(&self) -> u8 {
        match self {
            EventKind::Register { .. } => 0,
            EventKind::Remove { .. } => 1,
            EventKind::MergeBegin { .. } => 2,
            EventKind::DiskLoad { .. } => 3,
            EventKind::Prefetch { .. } => 4,
            EventKind::Submit { .. } => 5,
            EventKind::Complete { .. } => 6,
            EventKind::Fail { .. } => 7,
            EventKind::DiskError { .. } => 8,
            EventKind::Panic { .. } => 9,
            EventKind::Quarantine { .. } => 10,
            EventKind::Recover { .. } => 11,
        }
    }

    fn adapter(&self) -> AdapterId {
        match self {
            EventKind::Register { adapter }
            | EventKind::Remove { adapter }
            | EventKind::MergeBegin { adapter }
            | EventKind::DiskLoad { adapter }
            | EventKind::Prefetch { adapter, .. }
            | EventKind::Submit { adapter, .. }
            | EventKind::Complete { adapter, .. }
            | EventKind::Fail { adapter, .. }
            | EventKind::DiskError { adapter, .. }
            | EventKind::Panic { adapter }
            | EventKind::Quarantine { adapter }
            | EventKind::Recover { adapter } => *adapter,
        }
    }

    fn req(&self) -> usize {
        match self {
            EventKind::Submit { req, .. }
            | EventKind::Complete { req, .. }
            | EventKind::Fail { req, .. } => *req,
            _ => 0,
        }
    }
}

/// Canonical order: (time, kind rank, adapter, request index, retry
/// attempt). Events recorded concurrently (e.g. merge hooks on pool
/// threads) land in a reproducible order regardless of real-time
/// interleaving; the attempt tiebreak orders zero-backoff disk-error
/// retries that share a virtual instant.
pub fn sort_canonical(events: &mut [Event]) {
    events.sort_by_key(|e| {
        let attempt = match e.kind {
            EventKind::DiskError { attempt, .. } => attempt,
            _ => 0,
        };
        (e.t, e.kind.rank(), e.kind.adapter(), e.kind.req(), attempt)
    });
}

impl std::fmt::Display for Event {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let t_us = self.t.as_micros();
        match &self.kind {
            EventKind::Register { adapter } => write!(f, "{t_us:>10} register adapter={adapter}"),
            EventKind::Remove { adapter } => write!(f, "{t_us:>10} remove   adapter={adapter}"),
            EventKind::MergeBegin { adapter } => {
                write!(f, "{t_us:>10} merge    adapter={adapter}")
            }
            EventKind::DiskLoad { adapter } => {
                write!(f, "{t_us:>10} diskload adapter={adapter}")
            }
            EventKind::Prefetch { adapter, ok } => {
                write!(f, "{t_us:>10} prefetch adapter={adapter} ok={ok}")
            }
            EventKind::Submit { req, adapter } => {
                write!(f, "{t_us:>10} submit   req={req} adapter={adapter}")
            }
            EventKind::Complete { req, adapter, e2e, tokens } => {
                let toks: Vec<String> = tokens.iter().map(i32::to_string).collect();
                write!(
                    f,
                    "{t_us:>10} complete req={req} adapter={adapter} e2e_us={} tokens=[{}]",
                    e2e.as_micros(),
                    toks.join(",")
                )
            }
            EventKind::Fail { req, adapter, error } => {
                write!(f, "{t_us:>10} fail     req={req} adapter={adapter} error={error}")
            }
            EventKind::DiskError { adapter, attempt } => {
                write!(f, "{t_us:>10} diskerr  adapter={adapter} attempt={attempt}")
            }
            EventKind::Panic { adapter } => write!(f, "{t_us:>10} panic    adapter={adapter}"),
            EventKind::Quarantine { adapter } => {
                write!(f, "{t_us:>10} quarant  adapter={adapter}")
            }
            EventKind::Recover { adapter } => write!(f, "{t_us:>10} recover  adapter={adapter}"),
        }
    }
}

/// Render a sorted event slice as one line per event.
pub fn render(events: &[Event]) -> String {
    let mut out = String::new();
    for e in events {
        out.push_str(&e.to_string());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_sort_is_total_and_stable_under_shuffle() {
        let ms = Duration::from_millis;
        let mut a = vec![
            Event { t: ms(2), kind: EventKind::Submit { req: 1, adapter: 3 } },
            Event { t: ms(1), kind: EventKind::MergeBegin { adapter: 2 } },
            Event { t: ms(1), kind: EventKind::Register { adapter: 5 } },
            Event { t: ms(1), kind: EventKind::MergeBegin { adapter: 1 } },
            Event { t: ms(2), kind: EventKind::Submit { req: 0, adapter: 3 } },
        ];
        let mut b = a.clone();
        b.reverse();
        sort_canonical(&mut a);
        sort_canonical(&mut b);
        assert_eq!(a, b, "sort must not depend on input order");
        assert_eq!(a[0].kind, EventKind::Register { adapter: 5 }, "registry first at t=1");
        assert_eq!(a[1].kind, EventKind::MergeBegin { adapter: 1 }, "merges by adapter id");
        assert_eq!(a[3].kind, EventKind::Submit { req: 0, adapter: 3 }, "submits by req index");
    }

    #[test]
    fn rendering_is_line_per_event_and_stable() {
        let events = vec![
            Event { t: Duration::from_micros(1500), kind: EventKind::Submit { req: 0, adapter: 1 } },
            Event {
                t: Duration::from_micros(2500),
                kind: EventKind::Complete {
                    req: 0,
                    adapter: 1,
                    e2e: Duration::from_micros(1000),
                    tokens: vec![5, 9],
                },
            },
        ];
        let s = render(&events);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("submit   req=0 adapter=1"));
        assert!(lines[1].contains("e2e_us=1000 tokens=[5,9]"));
        assert_eq!(render(&events), s, "rendering must be pure");
    }
}
