//! The scenario driver: a discrete-event loop over the real coordinator.
//!
//! Under [`ClockMode::Virtual`] the driver owns the only way time moves.
//! Its invariant: **the clock only advances while the pool is quiescent**
//! — every request is either completed, queued behind a strictly-future
//! batch deadline, or parked behind a merge that is itself parked on the
//! virtual clock (a scripted slow merge). Quiescence is observed through
//! the metrics barrier (a worker snapshot is taken *after* its release
//! pass), the merge-pipeline counters, and the virtual clock's sleeper
//! registry. Between quiescent points the driver advances the clock to
//! the earliest next event — arrival, batch deadline, churn action, or
//! scripted merge wake — so every timestamp in the event log is exact
//! and reproducible.
//!
//! Real work (decode, ungated merges) takes **zero virtual time**: the
//! clock does not move while it runs. Simulated latencies therefore
//! isolate exactly the scheduling behavior — batching deadlines, parking,
//! fault delays — which is what the golden traces pin.

use super::events::{render, sort_canonical, Event, EventKind};
use super::spec::{ChurnAction, ClockMode, ScenarioEnv, ScenarioSpec, ScriptedPanic, SlowMerge};
use crate::clock::{Clock, VirtualClock};
use crate::coordinator::{
    pool_registry, AdapterId, CacheStats, Coordinator, CoordinatorConfig, DiskErrorFault,
    DiskFault, FailKind, GenRequest, GenResponse, LatencyStats, LoadHook, MergeHook,
    MergeStatsSnapshot, MergeStrategy, ServeError, ServerMetrics, TierConfig, TierEvent,
    TierEventHook, WorkerSnapshot,
};
use crate::obs::{chrome_trace_json, Span, Stage, StageBreakdown, TraceRecorder, STAGES};
use crate::eval::tasks::TOKENS;
use crate::testutil::Rng;
use crate::workload::{generate, Arrival};
use anyhow::{bail, ensure, Context};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// How long (real time) the driver will wait for background progress
/// (merges, thread wakeups) before declaring the scenario stalled.
const STALL_TIMEOUT: Duration = Duration::from_secs(30);
/// Real-time poll interval while waiting for background progress.
const POLL: Duration = Duration::from_micros(200);

type GenRx = mpsc::Receiver<Result<GenResponse, ServeError>>;
type AckRx = mpsc::Receiver<anyhow::Result<()>>;

/// Everything a scenario run produced.
pub struct ScenarioRun {
    /// Canonically-ordered event log.
    pub events: Vec<Event>,
    /// Per-request generated tokens (`None` = the request failed).
    pub tokens: Vec<Option<Vec<i32>>>,
    /// Per-request stage breakdown (DESIGN.md §16), indexed like
    /// `tokens`. Successful requests always carry one (`sum() == e2e`
    /// exactly); failures carry one when the request was tracked, with
    /// `terminal` naming the stage the failure struck in.
    pub stages: Vec<Option<StageBreakdown>>,
    /// Canonically-sorted lifecycle spans, drained at trace end
    /// (empty when `spec.trace` is off). Byte-identical across runs,
    /// compute-thread counts, and worker counts under the virtual
    /// clock.
    pub spans: Vec<Span>,
    /// Prometheus text exposition rendered from the final quiescent
    /// snapshot (empty when the pool was unreachable).
    pub metrics_text: String,
    pub summary: ScenarioSummary,
}

impl ScenarioRun {
    /// The golden-trace artifact: one stable text line per event.
    pub fn log(&self) -> String {
        render(&self.events)
    }

    /// The Chrome trace-event export of [`Self::spans`]
    /// (`chrome://tracing` / Perfetto). Byte-identical whenever the
    /// span list is.
    pub fn trace_json(&self) -> String {
        chrome_trace_json(&self.spans)
    }
}

/// Aggregate results of one scenario.
#[derive(Debug, Clone)]
pub struct ScenarioSummary {
    pub name: String,
    pub strategy: MergeStrategy,
    pub workers: usize,
    pub requests: usize,
    pub ok: usize,
    pub failed: usize,
    /// Scenario-clock offset of the last completion.
    pub makespan: Duration,
    /// First submission → last completion (the throughput denominator:
    /// excludes pool startup and registration).
    pub trace_span: Duration,
    /// End-to-end latency order statistics over completed requests.
    pub latency: LatencyStats,
    /// Per-adapter latency order statistics (registry id order).
    pub per_adapter: Vec<(AdapterId, LatencyStats)>,
    /// Pool-wide exact per-stage latency stats over completed requests
    /// (DESIGN.md §16): for every sample, Σ stages == e2e.
    pub stage_latency: Vec<(Stage, LatencyStats)>,
    /// Per-adapter per-stage stats, next to `per_adapter` (registry id
    /// order).
    pub per_adapter_stages: Vec<(AdapterId, Vec<(Stage, LatencyStats)>)>,
    pub batches: u64,
    pub factor_batches: u64,
    pub mean_batch: f64,
    pub tokens_generated: u64,
    /// Step forward passes across the pool — the virtual decode-step
    /// count the continuous-vs-lockstep acceptance compares.
    pub decode_steps: u64,
    /// Prefill/admission forward passes across the pool.
    pub prefill_passes: u64,
    pub cache: CacheStats,
    /// In-RAM factor-cache stats (all zero unless the spec is tiered).
    pub factor_cache: CacheStats,
    /// Disk-tier loads completed (zero unless tiered).
    pub disk_loads: u64,
    /// Adapters spilled to the disk tier at registration (zero unless
    /// tiered).
    pub spilled: u64,
    /// Requests retired past their deadline (queued or mid-decode).
    pub timeouts: u64,
    /// Requests retired by a cancel token.
    pub cancellations: u64,
    /// Requests shed at admission by the queue depth cap.
    pub sheds: u64,
    /// Disk-tier load retries that ran (zero unless faults scripted).
    pub disk_retries: u64,
    /// Quarantine transitions observed (scripted churn or permanent
    /// load failure).
    pub quarantined: u64,
    /// Merge/fetch pool workers respawned after a contained panic.
    pub worker_respawns: u64,
    /// Failure counts keyed by [`FailKind`] kebab-case name. The driver
    /// asserts `ok + Σ failed_by_kind == submitted` before returning.
    pub failed_by_kind: BTreeMap<String, usize>,
    pub merges: MergeStatsSnapshot,
    /// Real wall-clock time the whole run took (the virtual-clock payoff:
    /// seconds of simulated trace in milliseconds of wall).
    pub real_wall: Duration,
}

impl ScenarioSummary {
    /// Multi-line human-readable report.
    pub fn render(&self) -> String {
        let mut out = format!(
            "scenario {} | strategy={} workers={} | {}/{} ok ({} failed)\n\
             makespan={:?} p50={:?} p95={:?} max={:?}\n\
             batches={} (factor={}) mean_batch={:.2} tokens={} steps={} prefills={}\n\
             cache: hits={} misses={} evictions={} | merges: started={} peak_overlap={}\n\
             tier: spilled={} disk_loads={} factor_cache: hits={} misses={} evictions={}\n\
             real wall: {:?}\n",
            self.name,
            self.strategy,
            self.workers,
            self.ok,
            self.requests,
            self.failed,
            self.makespan,
            self.latency.quantile(0.5),
            self.latency.quantile(0.95),
            self.latency.max(),
            self.batches,
            self.factor_batches,
            self.mean_batch,
            self.tokens_generated,
            self.decode_steps,
            self.prefill_passes,
            self.cache.hits,
            self.cache.misses,
            self.cache.evictions,
            self.merges.started,
            self.merges.peak_overlap,
            self.spilled,
            self.disk_loads,
            self.factor_cache.hits,
            self.factor_cache.misses,
            self.factor_cache.evictions,
            self.real_wall,
        );
        if self.timeouts + self.cancellations + self.sheds + self.disk_retries
            + self.quarantined
            + self.worker_respawns
            > 0
        {
            out.push_str(&format!(
                "faults: timeouts={} cancels={} sheds={} disk_retries={} quarantined={} \
                 respawns={}\n",
                self.timeouts,
                self.cancellations,
                self.sheds,
                self.disk_retries,
                self.quarantined,
                self.worker_respawns,
            ));
        }
        fn stage_line(indent: &str, stages: &[(Stage, LatencyStats)]) -> String {
            let mut line = format!("{indent}stages:");
            for (stage, stats) in stages {
                line.push_str(&format!(
                    " {}(p50={:?} p95={:?})",
                    stage.label(),
                    stats.quantile(0.5),
                    stats.quantile(0.95),
                ));
            }
            line.push('\n');
            line
        }
        if !self.stage_latency.is_empty() {
            out.push_str(&stage_line("", &self.stage_latency));
        }
        for (id, stats) in &self.per_adapter {
            out.push_str(&format!(
                "  adapter {id}: n={} p50={:?} p95={:?} max={:?}\n",
                stats.count(),
                stats.quantile(0.5),
                stats.quantile(0.95),
                stats.max(),
            ));
            if let Some((_, stages)) =
                self.per_adapter_stages.iter().find(|(aid, _)| aid == id)
            {
                out.push_str(&stage_line("    ", stages));
            }
        }
        out
    }
}

/// Replay `spec` through a full coordinator in `env`. See the module
/// docs for the determinism contract.
pub fn run_scenario(spec: &ScenarioSpec, env: &ScenarioEnv) -> anyhow::Result<ScenarioRun> {
    let wall0 = Instant::now();
    let vc = match spec.mode {
        ClockMode::Virtual => Some(VirtualClock::new()),
        ClockMode::RealTime => None,
    };
    let clock = vc.as_ref().map_or_else(Clock::real, Clock::virtual_from);
    let origin = clock.now();
    let events: Arc<Mutex<Vec<Event>>> = Arc::new(Mutex::new(Vec::new()));
    // Lifecycle tracing (DESIGN.md §16): spans are offsets from the
    // scenario origin, so the export is origin-independent.
    let trace = spec.trace.then(|| TraceRecorder::new(origin, TraceRecorder::DEFAULT_CAP));

    // The merge hook records merge starts, fires any scripted panic
    // (contained by the pool's catch_unwind; only the target adapter's
    // parked requests fail), and applies the scripted slow merge by
    // parking the merge thread on the scenario clock.
    let hook = {
        let events = Arc::clone(&events);
        let clock = clock.clone();
        let slow: Option<SlowMerge> = spec.faults.slow_merge;
        let scripted_panic: Option<ScriptedPanic> = spec.faults.panic;
        let panics_fired = Arc::new(AtomicU32::new(0));
        MergeHook::new(move |id| {
            let now = clock.now();
            let t = now.duration_since(origin);
            events
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .push(Event { t, kind: EventKind::MergeBegin { adapter: id } });
            if let Some(p) = scripted_panic {
                if p.adapter == id && panics_fired.fetch_add(1, Ordering::SeqCst) < p.first_n {
                    events
                        .lock()
                        .unwrap_or_else(|e| e.into_inner())
                        .push(Event { t, kind: EventKind::Panic { adapter: id } });
                    panic!("scripted merge panic: adapter {id}");
                }
            }
            if let Some(sm) = slow {
                if sm.adapter.is_none_or(|a| a == id) {
                    clock.sleep_until(now + sm.delay);
                }
            }
        })
    };

    // The scenario owns the spill directory: unique per run so parallel
    // tests never share files, removed after the pool drains.
    let tier_dir = if spec.tiered {
        static SEQ: AtomicUsize = AtomicUsize::new(0);
        let seq = SEQ.fetch_add(1, Ordering::Relaxed);
        Some(std::env::temp_dir().join(format!("lq_tier_{}_{seq}", std::process::id())))
    } else {
        None
    };
    let tier_cfg = tier_dir.as_ref().map(|dir| {
        let events = Arc::clone(&events);
        let clock = clock.clone();
        let mut t = TierConfig::new(dir, spec.factor_cache_bytes);
        t.predictive_prefetch = spec.predictive_prefetch;
        t.disk_fault = spec
            .faults
            .disk_latency
            .map(|d| DiskFault { adapter: d.adapter, delay: d.delay });
        t.disk_error = spec
            .faults
            .disk_error
            .map(|d| DiskErrorFault { adapter: d.adapter, first_n: d.first_n });
        t.max_retries = spec.disk_retries;
        t.backoff = spec.disk_backoff;
        // records DiskError/Quarantine on the loading merge-pool thread
        // as the retry loop observes them (mirrors the MergeBegin hook)
        let tier_events = Arc::clone(&events);
        let tier_clock = clock.clone();
        t.event_hook = Some(TierEventHook::new(move |ev| {
            let t_off = tier_clock.now().duration_since(origin);
            let kind = match *ev {
                TierEvent::LoadError { adapter, attempt } => {
                    EventKind::DiskError { adapter, attempt }
                }
                TierEvent::Quarantined { adapter } => EventKind::Quarantine { adapter },
            };
            tier_events.lock().unwrap_or_else(|e| e.into_inner()).push(Event { t: t_off, kind });
        }));
        // records DiskLoad on the loading merge-pool thread, before any
        // scripted latency parks it (mirrors the MergeBegin hook)
        t.load_hook = Some(LoadHook::new(move |id| {
            let now = clock.now();
            events
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .push(Event { t: now.duration_since(origin), kind: EventKind::DiskLoad { adapter: id } });
        }));
        t
    });

    let mut cfg = CoordinatorConfig::new(&env.artifacts, &env.model)
        .with_workers(spec.workers)
        .with_buckets(spec.buckets.clone())
        .with_merge_strategy(spec.strategy)
        .with_continuous(spec.continuous)
        .with_prefill_chunk(spec.prefill_chunk)
        .with_clock(clock.clone());
    cfg.max_wait = spec.max_wait;
    cfg.cache_budget_bytes = spec.cache_budget_bytes;
    cfg.merge_workers = spec.merge_workers;
    cfg.compute_threads = spec.compute_threads;
    cfg.request_timeout = spec.request_timeout;
    cfg.queue_cap = spec.queue_cap;
    cfg.merge_hook = Some(hook);
    cfg.tier = tier_cfg;
    cfg.trace = trace.clone();
    let (coord, join) = Coordinator::start(cfg).context("starting scenario coordinator")?;

    let mut driver = Driver {
        spec,
        env,
        coord: &coord,
        vc,
        clock,
        origin,
        events,
        ids: Vec::new(),
        schedule: Vec::new(),
        prompts: Vec::new(),
        submit_offset: Vec::new(),
        outstanding: Vec::new(),
        tokens: Vec::new(),
        e2e: Vec::new(),
        stages: Vec::new(),
        stage_violations: Vec::new(),
        trace,
        submitted: 0,
        completed: 0,
        failed: 0,
        failed_by_kind: BTreeMap::new(),
    };
    let result = driver.run();
    // Wake any merge thread still parked on the virtual clock (possible
    // when bailing out mid-fault) so the pool can drain, then shut down.
    if let Some(vc) = &driver.vc {
        vc.advance(Duration::from_secs(1 << 20));
    }
    coord.shutdown();
    drop(driver);
    let joined = join.join();
    if let Some(dir) = &tier_dir {
        let _ = std::fs::remove_dir_all(dir);
    }
    let run = result?;
    let _ = joined;

    let mut run = run;
    run.summary.real_wall = wall0.elapsed();
    Ok(run)
}

struct Driver<'a> {
    spec: &'a ScenarioSpec,
    env: &'a ScenarioEnv,
    coord: &'a Coordinator,
    vc: Option<Arc<VirtualClock>>,
    clock: Clock,
    origin: Instant,
    events: Arc<Mutex<Vec<Event>>>,
    /// Initially-registered adapter ids (churn targets index into this).
    ids: Vec<AdapterId>,
    schedule: Vec<Arrival>,
    prompts: Vec<Vec<i32>>,
    /// Scenario-clock offset each request was submitted at.
    submit_offset: Vec<Duration>,
    outstanding: Vec<(usize, GenRx)>,
    tokens: Vec<Option<Vec<i32>>>,
    /// Completed requests' (adapter, e2e) for the summary.
    e2e: Vec<(AdapterId, Duration)>,
    /// Per-request stage breakdowns (indexed like `tokens`).
    stages: Vec<Option<StageBreakdown>>,
    /// Broken `Σ stages == e2e` invariants, surfaced as one error at
    /// finish (never expected: the breakdown telescopes by
    /// construction).
    stage_violations: Vec<String>,
    /// Lifecycle span recorder shared with the pool (`None`: tracing
    /// off).
    trace: Option<TraceRecorder>,
    submitted: usize,
    completed: usize,
    failed: usize,
    /// Failure counts keyed by `FailKind` kebab-case name.
    failed_by_kind: BTreeMap<String, usize>,
}

impl Driver<'_> {
    fn offset(&self) -> Duration {
        self.clock.now().duration_since(self.origin)
    }

    fn push_event(&self, t: Duration, kind: EventKind) {
        self.events.lock().unwrap_or_else(|e| e.into_inner()).push(Event { t, kind });
    }

    fn run(&mut self) -> anyhow::Result<ScenarioRun> {
        // ---- setup: register the tenant fleet ---------------------------
        for i in 0..self.spec.n_adapters.max(1) {
            let (task, ad) = &self.env.adapters[i % self.env.adapters.len()];
            let id = self.coord.register_adapter(ad.clone(), task.clone())?;
            self.push_event(self.offset(), EventKind::Register { adapter: id });
            self.ids.push(id);
        }
        self.schedule = generate(&self.spec.workload, &self.ids);
        if self.spec.round_robin {
            for (i, arr) in self.schedule.iter_mut().enumerate() {
                arr.adapter = self.ids[i % self.ids.len()];
            }
        }
        let n = self.schedule.len();
        let mut prng = Rng::new(self.spec.prompt_seed);
        self.prompts = (0..n)
            .map(|_| {
                let d1 = TOKENS::DIGIT0 + prng.below(10) as i32;
                let d2 = TOKENS::DIGIT0 + prng.below(10) as i32;
                vec![TOKENS::BOS, d1, TOKENS::MARK, d2, TOKENS::SEP]
            })
            .collect();
        self.submit_offset = vec![Duration::ZERO; n];
        self.tokens = vec![None; n];
        self.stages = vec![None; n];

        if self.spec.prefetch {
            self.prefetch_all()?;
        }
        match self.spec.mode {
            ClockMode::Virtual => self.replay_virtual()?,
            ClockMode::RealTime => self.replay_real()?,
        }
        self.finish()
    }

    /// Whether the merge pipeline can make no further progress at the
    /// current virtual time. `worker_inflight` is the worker-side count
    /// (submit → `Merged` ingested); `held` the completions the ingest
    /// sequencer is deliberately holding for an earlier-submitted merge
    /// (those are time-blocked, not in-progress); `mstats.inflight` the
    /// pool-side count (dequeue → done-callback fired). Settled means
    /// every dequeued merge is parked on the clock, and any job still
    /// *queued* (worker-side, minus held, > pool-side) is blocked because
    /// every merge thread is occupied by a sleeper — a queued job with a
    /// free thread, or a completion awaiting ingest, is real-time
    /// progress: keep polling.
    fn merges_settled(
        &self,
        worker_inflight: usize,
        held: usize,
        sleepers: usize,
        mstats: &MergeStatsSnapshot,
    ) -> bool {
        let pool_threads = self.spec.merge_workers.max(1);
        let undequeued = worker_inflight.saturating_sub(mstats.inflight + held);
        mstats.inflight == sleepers
            && (undequeued == 0 || mstats.inflight >= pool_threads)
            && worker_inflight >= mstats.inflight + held
    }

    // ---- prefetch ------------------------------------------------------

    fn prefetch_all(&mut self) -> anyhow::Result<()> {
        let mut pending: Vec<(AdapterId, AckRx)> =
            self.ids.iter().map(|&id| (id, self.coord.prefetch(id))).collect();
        let t0 = Instant::now();
        while !pending.is_empty() {
            pending.retain(|(id, rx)| match rx.try_recv() {
                Ok(res) => {
                    self.push_event(
                        self.offset(),
                        EventKind::Prefetch { adapter: *id, ok: res.is_ok() },
                    );
                    false
                }
                Err(mpsc::TryRecvError::Empty) => true,
                Err(mpsc::TryRecvError::Disconnected) => {
                    self.push_event(self.offset(), EventKind::Prefetch { adapter: *id, ok: false });
                    false
                }
            });
            if pending.is_empty() {
                break;
            }
            if let Some(vc) = self.vc.as_ref().map(Arc::clone) {
                // A scripted slow merge can gate prefetch too: when the
                // merge pipeline is settled with threads parked on the
                // clock, advance to the earliest wake; otherwise real
                // host work is still running — poll.
                let snaps = self.coord.metrics_per_worker()?;
                let inflight: usize =
                    snaps.iter().map(|s| s.inflight_merges + s.inflight_fetches).sum();
                let held: usize = snaps.iter().map(|s| s.held_merges).sum();
                let (sleepers, earliest) = vc.sleepers();
                let mstats = self.coord.merge_stats();
                if sleepers > 0 && self.merges_settled(inflight, held, sleepers, &mstats) {
                    if let Some(t) = earliest {
                        vc.advance_to(t);
                    }
                } else {
                    std::thread::sleep(POLL);
                }
            } else {
                std::thread::sleep(Duration::from_millis(1));
            }
            if t0.elapsed() > STALL_TIMEOUT {
                bail!("prefetch stalled: {} adapters never acked", pending.len());
            }
        }
        Ok(())
    }

    // ---- virtual-time replay (discrete-event loop) ---------------------

    fn replay_virtual(&mut self) -> anyhow::Result<()> {
        let vc = Arc::clone(self.vc.as_ref().expect("virtual replay needs a virtual clock"));
        let churn = self.spec.sorted_churn();
        let (mut next_arrival, mut next_churn) = (0usize, 0usize);
        loop {
            let snaps = self.quiesce(&vc)?;
            // Earliest next event: arrival, churn action, batch deadline,
            // or scripted merge wake.
            let now_off = vc.elapsed();
            let mut cand: Option<Duration> = None;
            let mut consider = |t: Duration| {
                cand = Some(cand.map_or(t, |c: Duration| c.min(t)));
            };
            if next_arrival < self.schedule.len() {
                consider(self.schedule[next_arrival].at);
            }
            if next_churn < churn.len() {
                consider(churn[next_churn].at());
            }
            for s in &snaps {
                if let Some(d) = s.next_release_in {
                    consider(now_off + d);
                }
            }
            let (sleepers, earliest) = vc.sleepers();
            if sleepers > 0 {
                if let Some(t) = earliest {
                    consider(t);
                }
            }
            let Some(t) = cand else {
                if self.outstanding.is_empty() {
                    return Ok(());
                }
                bail!(
                    "scenario stalled at t={now_off:?}: {} requests outstanding with no \
                     future event",
                    self.outstanding.len()
                );
            };
            vc.advance_to(t.max(now_off));
            // Same-instant ordering: force every worker's release pass at
            // the new time before churn or arrivals at that instant, so a
            // deadline tying an arrival releases deterministically first.
            let _ = self.coord.metrics_per_worker()?;
            while next_churn < churn.len() && churn[next_churn].at() <= vc.elapsed() {
                self.apply_churn(&churn[next_churn])?;
                next_churn += 1;
            }
            while next_arrival < self.schedule.len()
                && self.schedule[next_arrival].at <= vc.elapsed()
            {
                self.submit(next_arrival);
                next_arrival += 1;
            }
        }
    }

    /// Poll metrics barriers until the pool can make no further progress
    /// at the current virtual time. Each barrier wakes every worker,
    /// forces its release pass, and snapshots post-release state; the
    /// merge counters and the clock's sleeper registry distinguish "merge
    /// still running on real time" (keep polling) from "merge parked on
    /// the virtual clock" (quiescent, time-blocked).
    fn quiesce(&mut self, vc: &VirtualClock) -> anyhow::Result<Vec<WorkerSnapshot>> {
        let t0 = Instant::now();
        loop {
            let snaps = self.coord.metrics_per_worker()?;
            self.drain_responses();
            let queued: usize = snaps.iter().map(|s| s.queued_requests).sum();
            let parked: usize = snaps.iter().map(|s| s.parked_requests).sum();
            let inflight: usize =
                snaps.iter().map(|s| s.inflight_merges + s.inflight_fetches).sum();
            let held: usize = snaps.iter().map(|s| s.held_merges).sum();
            let (sleepers, _) = vc.sleepers();
            let mstats = self.coord.merge_stats();
            let accounted = self.completed + queued + parked == self.submitted;
            let merges_settled = self.merges_settled(inflight, held, sleepers, &mstats);
            if accounted && merges_settled {
                return Ok(snaps);
            }
            if t0.elapsed() > STALL_TIMEOUT {
                bail!(
                    "quiesce stalled: submitted={} completed={} queued={queued} \
                     parked={parked} inflight={inflight} sleepers={sleepers} \
                     pool_inflight={}",
                    self.submitted,
                    self.completed,
                    mstats.inflight,
                );
            }
            std::thread::sleep(POLL);
        }
    }

    // ---- real-time replay ----------------------------------------------

    fn replay_real(&mut self) -> anyhow::Result<()> {
        let churn = self.spec.sorted_churn();
        let (mut next_arrival, mut next_churn) = (0usize, 0usize);
        let t_start = self.clock.now();
        while next_arrival < self.schedule.len() || next_churn < churn.len() {
            let t_a = self.schedule.get(next_arrival).map(|a| a.at);
            let t_c = churn.get(next_churn).map(ChurnAction::at);
            let due = match (t_a, t_c) {
                (Some(a), Some(c)) => a.min(c),
                (Some(a), None) => a,
                (None, Some(c)) => c,
                (None, None) => break,
            };
            let elapsed = self.clock.now().duration_since(t_start);
            if due > elapsed {
                std::thread::sleep(due - elapsed);
            }
            if t_c.is_some_and(|c| c <= due) {
                self.apply_churn(&churn[next_churn])?;
                next_churn += 1;
            } else {
                self.submit(next_arrival);
                next_arrival += 1;
            }
        }
        // Collect every outstanding response (blocking).
        let pending = std::mem::take(&mut self.outstanding);
        for (idx, rx) in pending {
            match rx.recv_timeout(STALL_TIMEOUT) {
                Ok(res) => self.record_response(idx, res),
                Err(_) => {
                    self.record_response(
                        idx,
                        Err(ServeError::new(FailKind::Internal, "response timed out")),
                    );
                }
            }
        }
        Ok(())
    }

    // ---- shared mechanics ----------------------------------------------

    fn submit(&mut self, idx: usize) {
        let adapter = self.schedule[idx].adapter;
        let off = self.offset();
        self.submit_offset[idx] = off;
        self.push_event(off, EventKind::Submit { req: idx, adapter });
        let max_new = if self.spec.max_new_spread > 0 {
            1 + (3 * idx + 1) % self.spec.max_new_spread
        } else {
            self.spec.max_new
        };
        // the tag is the request's trace-track identity: submission
        // indices are schedule-derived, so exported traces are stable
        // across thread interleavings (DESIGN.md §16)
        let rx = self.coord.generate_async(
            GenRequest::new(adapter, self.prompts[idx].clone(), max_new).with_tag(idx as u64),
        );
        self.outstanding.push((idx, rx));
        self.submitted += 1;
    }

    fn apply_churn(&mut self, action: &ChurnAction) -> anyhow::Result<()> {
        match *action {
            ChurnAction::Register { pool_index, .. } => {
                let (task, ad) = &self.env.adapters[pool_index % self.env.adapters.len()];
                let id = self.coord.register_adapter(ad.clone(), task.clone())?;
                self.push_event(self.offset(), EventKind::Register { adapter: id });
            }
            ChurnAction::Remove { target, .. } => {
                let id = self.ids[target % self.ids.len()];
                let _ = self.coord.remove_adapter(id)?;
                self.push_event(self.offset(), EventKind::Remove { adapter: id });
            }
            ChurnAction::Quarantine { target, .. } => {
                let id = self.ids[target % self.ids.len()];
                if self.coord.quarantine_adapter(id) {
                    self.push_event(self.offset(), EventKind::Quarantine { adapter: id });
                }
            }
            ChurnAction::Recover { target, .. } => {
                let id = self.ids[target % self.ids.len()];
                if self.coord.recover_adapter(id) {
                    self.push_event(self.offset(), EventKind::Recover { adapter: id });
                }
            }
        }
        Ok(())
    }

    fn drain_responses(&mut self) {
        let mut still = Vec::with_capacity(self.outstanding.len());
        for (idx, rx) in std::mem::take(&mut self.outstanding) {
            match rx.try_recv() {
                Ok(res) => self.record_response(idx, res),
                Err(mpsc::TryRecvError::Empty) => still.push((idx, rx)),
                Err(mpsc::TryRecvError::Disconnected) => {
                    self.record_response(
                        idx,
                        Err(ServeError::new(FailKind::Internal, "responder dropped")),
                    );
                }
            }
        }
        self.outstanding = still;
    }

    fn record_response(&mut self, idx: usize, res: Result<GenResponse, ServeError>) {
        let adapter = self.schedule[idx].adapter;
        match res {
            Ok(resp) => {
                // Completion instant = submission + worker-measured e2e:
                // exact under the virtual clock, consistent in real time.
                let t = self.submit_offset[idx] + resp.e2e;
                self.push_event(
                    t,
                    EventKind::Complete {
                        req: idx,
                        adapter,
                        e2e: resp.e2e,
                        tokens: resp.tokens.clone(),
                    },
                );
                self.e2e.push((adapter, resp.e2e));
                self.tokens[idx] = Some(resp.tokens);
                // the §16 accounting invariant: exact, not approximate
                if resp.stages.sum() != resp.e2e {
                    self.stage_violations.push(format!(
                        "req {idx}: Σ stages {:?} != e2e {:?}",
                        resp.stages.sum(),
                        resp.e2e
                    ));
                }
                self.stages[idx] = Some(resp.stages);
            }
            Err(e) => {
                self.push_event(
                    self.offset(),
                    EventKind::Fail { req: idx, adapter, error: format!("{e}") },
                );
                *self.failed_by_kind.entry(e.kind.to_string()).or_insert(0) += 1;
                self.failed += 1;
                self.stages[idx] = e.stages;
            }
        }
        self.completed += 1;
    }

    fn finish(&mut self) -> anyhow::Result<ScenarioRun> {
        // One snapshot round-trip feeds both the summary aggregates and
        // the Prometheus registry, so the two exports can't disagree.
        let snaps = self.coord.metrics_per_worker()?;
        let mut m = ServerMetrics::new();
        let mut cache = CacheStats::default();
        for s in &snaps {
            m.absorb(&s.metrics);
            cache.hits += s.cache.hits;
            cache.misses += s.cache.misses;
            cache.evictions += s.cache.evictions;
        }
        let factor_cache = self.coord.factor_cache_stats()?;
        let (disk_loads, spilled) = self.coord.tier_stats();
        let merges = self.coord.merge_stats();
        let mut events = {
            let mut guard = self.events.lock().unwrap_or_else(|e| e.into_inner());
            std::mem::take(&mut *guard)
        };
        sort_canonical(&mut events);
        let makespan = events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::Complete { .. }))
            .map(|e| e.t)
            .max()
            .unwrap_or(Duration::ZERO);
        let first_submit = events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::Submit { .. }))
            .map(|e| e.t)
            .min()
            .unwrap_or(Duration::ZERO);
        let all: Vec<Duration> = self.e2e.iter().map(|&(_, d)| d).collect();
        let mut by_adapter: BTreeMap<AdapterId, Vec<Duration>> = BTreeMap::new();
        for &(id, d) in &self.e2e {
            by_adapter.entry(id).or_default().push(d);
        }
        // The counting contract (DESIGN.md §15): every submitted request
        // retires exactly once, as a completion or as one typed failure.
        let failed_total: usize = self.failed_by_kind.values().sum();
        ensure!(
            failed_total == self.failed,
            "failure accounting broke: Σ failed_by_kind={failed_total} != failed={}",
            self.failed
        );
        ensure!(
            self.e2e.len() + failed_total == self.submitted,
            "request accounting broke: ok={} + failed={failed_total} != submitted={}",
            self.e2e.len(),
            self.submitted
        );
        let quarantined = events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::Quarantine { .. }))
            .count() as u64;
        // The §16 invariant is exact (the breakdown telescopes by
        // construction), so any violation is a bug, not noise.
        ensure!(
            self.stage_violations.is_empty(),
            "stage accounting broke: {}",
            self.stage_violations.join("; ")
        );
        // Per-stage latency over successfully retired requests, exact
        // percentiles pool-wide and per adapter (DESIGN.md §16).
        let mut stage_samples: Vec<Vec<Duration>> = vec![Vec::new(); STAGES.len()];
        let mut adapter_stage: BTreeMap<AdapterId, Vec<Vec<Duration>>> = BTreeMap::new();
        for (idx, b) in self.stages.iter().enumerate() {
            if self.tokens[idx].is_none() {
                continue; // failures report their terminal stage via spans
            }
            let Some(b) = b else { continue };
            let per = adapter_stage
                .entry(self.schedule[idx].adapter)
                .or_insert_with(|| vec![Vec::new(); STAGES.len()]);
            for (i, &s) in STAGES.iter().enumerate() {
                stage_samples[i].push(b.get(s));
                per[i].push(b.get(s));
            }
        }
        let stage_latency: Vec<(Stage, LatencyStats)> = STAGES
            .iter()
            .enumerate()
            .map(|(i, &s)| (s, LatencyStats::from_samples(&stage_samples[i])))
            .collect();
        let per_adapter_stages: Vec<(AdapterId, Vec<(Stage, LatencyStats)>)> = adapter_stage
            .into_iter()
            .map(|(id, per)| {
                let by_stage = STAGES
                    .iter()
                    .enumerate()
                    .map(|(i, &s)| (s, LatencyStats::from_samples(&per[i])))
                    .collect();
                (id, by_stage)
            })
            .collect();
        // Drain the trace shards (all threads quiesced) and render the
        // Prometheus exposition from the same worker snapshots.
        let spans = self.trace.as_ref().map(|t| t.drain()).unwrap_or_default();
        let quarantined_adapters = self.coord.with_registry(|r| r.quarantined_ids().len());
        let metrics_text = pool_registry(
            &snaps,
            quarantined_adapters,
            self.trace.as_ref().map(|t| t.dropped()),
        )
        .render();
        let summary = ScenarioSummary {
            name: self.spec.name.clone(),
            strategy: self.spec.strategy,
            workers: self.spec.workers.max(1),
            requests: self.schedule.len(),
            ok: self.e2e.len(),
            failed: self.failed,
            makespan,
            trace_span: makespan.saturating_sub(first_submit),
            latency: LatencyStats::from_samples(&all),
            per_adapter: by_adapter
                .into_iter()
                .map(|(id, ds)| (id, LatencyStats::from_samples(&ds)))
                .collect(),
            stage_latency,
            per_adapter_stages,
            batches: m.batches,
            factor_batches: m.factor_batches,
            mean_batch: m.mean_batch_size(),
            tokens_generated: m.tokens_generated,
            decode_steps: m.decode_steps,
            prefill_passes: m.prefill_passes,
            cache,
            factor_cache,
            disk_loads,
            spilled,
            timeouts: m.timeouts,
            cancellations: m.cancellations,
            sheds: m.sheds,
            disk_retries: self.coord.disk_retries(),
            quarantined,
            worker_respawns: merges.worker_respawns,
            failed_by_kind: std::mem::take(&mut self.failed_by_kind),
            merges,
            real_wall: Duration::ZERO, // stamped by run_scenario
        };
        Ok(ScenarioRun {
            events,
            tokens: std::mem::take(&mut self.tokens),
            stages: std::mem::take(&mut self.stages),
            spans,
            metrics_text,
            summary,
        })
    }
}
