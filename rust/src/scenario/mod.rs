//! Deterministic serving scenarios: replay a multi-tenant workload trace
//! through the full [`Coordinator`](crate::coordinator::Coordinator)
//! under a **virtual clock**, with scripted faults, and get back a
//! canonical event log + summary suitable for golden-trace assertions.
//!
//! The paper's deployment setting (§1/App. D — one frozen base model,
//! many resident adapters) lives or dies on scheduling behavior: batch
//! max-wait deadlines, cold-miss parking, the auto strategy's merge
//! races. Those used to be testable only with real sleeps. Here the
//! whole pipeline runs in simulated time:
//!
//! * [`spec`] — what to run: adapters × workload trace × execution
//!   strategy × fault schedule ([`ScenarioSpec`], [`FaultPlan`]).
//! * [`events`] — what happened: a timestamped, canonically-ordered
//!   event log ([`Event`]) rendered as stable text lines.
//! * [`sim`] — the driver: a discrete-event loop that advances a
//!   [`VirtualClock`](crate::clock::VirtualClock) from event to event
//!   (arrival, batch deadline, fault action, scripted merge wake),
//!   quiescing the pool between advances so every timestamp is exactly
//!   reproducible. The same driver also runs specs against the real
//!   clock ([`ClockMode::RealTime`]) for throughput benches, so benches
//!   and tests execute the same code path.
//!
//! ## Determinism contract
//!
//! Under [`ClockMode::Virtual`], two runs of the same spec produce
//! byte-identical event logs, and per-request **token output** is
//! additionally identical across worker-pool sizes (results, not
//! schedule: the reference engine's forward is per-lane independent, so
//! batch composition cannot change any request's tokens). This holds at
//! any `merge_workers` count: virtual-clock workers ingest merge
//! completions through a **submission-order sequencer** (DESIGN.md
//! §11), so concurrent merges racing on the pool threads cannot change
//! cache-insert order — and therefore cannot change LRU eviction under
//! thrash. (Real-time serving ingests on arrival instead; scripted-fault
//! overlap is still observable through
//! [`MergeStatsSnapshot`](crate::coordinator::MergeStatsSnapshot).)
//!
//! ## Fault injection points
//!
//! * **Slow merge** ([`SlowMerge`]) — the merge hook parks the merge
//!   thread on the virtual clock for a scripted delay, modelling a
//!   multi-second dequant+merge. Under `merged` the affected batches
//!   park for the full delay; under `auto` they are served factor-form
//!   with zero added virtual latency.
//! * **Registry churn** ([`ChurnAction`]) — adapters registered/removed
//!   mid-trace at scripted virtual times (arrivals for a removed tenant
//!   fail fast; in-flight merges abort safely).
//! * **Cache-budget thrash** — a spec-level `cache_budget_bytes` small
//!   enough that resident adapters evict each other; decode correctness
//!   must be unaffected (an adapter is never evicted mid-decode).
//! * **Disk errors** ([`DiskError`]) — the first N tier loads of an
//!   adapter return `Err`, driving the bounded retry/backoff loop and,
//!   past the budget, quarantine (DESIGN.md §15).
//! * **Scripted panics** ([`ScriptedPanic`]) — the first N merge jobs
//!   for an adapter panic on the pool thread; only that adapter's parked
//!   requests fail, and the supervisor respawns the worker.
//! * **Quarantine churn** ([`ChurnAction::Quarantine`] /
//!   [`ChurnAction::Recover`]) — scripted availability flaps: requests
//!   fail fast while quarantined and serve normally after recovery.
//!
//! See rust/DESIGN.md §9 and §15.

pub mod events;
pub mod sim;
pub mod spec;

pub use events::{Event, EventKind};
pub use sim::{run_scenario, ScenarioRun, ScenarioSummary};
pub use spec::{
    ChurnAction, ClockMode, DiskError, DiskLatency, FaultPlan, ScenarioEnv, ScenarioSpec,
    ScriptedPanic, SlowMerge,
};
