//! Scenario specification: adapters × workload × strategy × faults.

use crate::adapter::LoraAdapter;
use crate::coordinator::{AdapterId, MergeStrategy, StoredAdapter};
use crate::loraquant::{quantize_site, LoraQuantConfig, QuantizedLora};
use crate::testutil::{synth_model_config, synth_quantized_adapter, write_synth_model};
use crate::workload::WorkloadConfig;
use anyhow::Context;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

/// Which timeline the scenario runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ClockMode {
    /// Deterministic discrete-event simulation on a virtual clock: a
    /// multi-second trace replays in milliseconds of wall clock and the
    /// event log is byte-reproducible.
    #[default]
    Virtual,
    /// Real clock, real sleeps: for throughput/speedup numbers where
    /// actual execution time is the measurement.
    RealTime,
}

/// A scripted slow merge: every merge for `adapter` (or every merge at
/// all when `None`) blocks for `delay` on the scenario clock before the
/// real dequant+merge runs.
#[derive(Debug, Clone, Copy)]
pub struct SlowMerge {
    pub adapter: Option<AdapterId>,
    pub delay: Duration,
}

/// Scripted disk-read latency for the adapter tier: every tier load of
/// `adapter` (or every load when `None`) parks for `delay` on the
/// scenario clock before reading. Only meaningful with `tiered` set.
#[derive(Debug, Clone, Copy)]
pub struct DiskLatency {
    pub adapter: Option<AdapterId>,
    pub delay: Duration,
}

/// Scripted disk-read *failures* for the adapter tier: the first
/// `first_n` tier loads of `adapter` (or of any adapter when `None`)
/// return `Err`, exercising the retry/backoff/quarantine path
/// (DESIGN.md §15). Only meaningful with `tiered` set.
#[derive(Debug, Clone, Copy)]
pub struct DiskError {
    pub adapter: Option<AdapterId>,
    pub first_n: u32,
}

/// A scripted merge-task panic: the first `first_n` merge jobs for
/// `adapter` panic inside the merge pool. Exercises panic containment
/// (DESIGN.md §15): only the requests parked on that adapter fail with
/// a structured `Internal` error; the supervisor respawns the worker.
#[derive(Debug, Clone, Copy)]
pub struct ScriptedPanic {
    pub adapter: AdapterId,
    pub first_n: u32,
}

/// A scripted registry mutation at a virtual offset from trace start.
#[derive(Debug, Clone, Copy)]
pub enum ChurnAction {
    /// Register one more adapter (cloned from the environment pool by
    /// index) at time `at`.
    Register { at: Duration, pool_index: usize },
    /// Remove the `target`-th initially-registered adapter at time `at`
    /// (its remaining arrivals fail fast — the scripted outage).
    Remove { at: Duration, target: usize },
    /// Quarantine the `target`-th initially-registered adapter at time
    /// `at`: its arrivals fail fast with `AdapterUnavailable` until a
    /// matching `Recover` lifts the quarantine.
    Quarantine { at: Duration, target: usize },
    /// Lift the quarantine on the `target`-th initially-registered
    /// adapter at time `at` (no-op if it was never quarantined).
    Recover { at: Duration, target: usize },
}

impl ChurnAction {
    pub fn at(&self) -> Duration {
        match *self {
            ChurnAction::Register { at, .. }
            | ChurnAction::Remove { at, .. }
            | ChurnAction::Quarantine { at, .. }
            | ChurnAction::Recover { at, .. } => at,
        }
    }
}

/// The fault schedule riding on a scenario.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    pub slow_merge: Option<SlowMerge>,
    /// Registry churn, applied in `at` order.
    pub churn: Vec<ChurnAction>,
    /// Scripted disk-read latency on the adapter tier (DESIGN.md §14).
    pub disk_latency: Option<DiskLatency>,
    /// Scripted disk-read failures on the adapter tier (DESIGN.md §15).
    pub disk_error: Option<DiskError>,
    /// Scripted merge-task panics (DESIGN.md §15).
    pub panic: Option<ScriptedPanic>,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.slow_merge.is_none()
            && self.churn.is_empty()
            && self.disk_latency.is_none()
            && self.disk_error.is_none()
            && self.panic.is_none()
    }
}

/// A complete scenario: pool shape, tenant count, workload trace,
/// execution strategy and fault schedule. `Default` is a small 4-tenant
/// Zipf trace on one worker under the virtual clock.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    pub name: String,
    pub mode: ClockMode,
    pub strategy: MergeStrategy,
    pub workers: usize,
    pub merge_workers: usize,
    /// Per-engine prefill worker threads (1 = serial). Thread count never
    /// changes logits, so golden traces hold at any value; the default 1
    /// additionally pins the serial execution schedule.
    pub compute_threads: usize,
    /// Continuous-batching decode (DESIGN.md §11; the default). `false`
    /// pins the per-batch lock-step path — token outputs are identical,
    /// only the decode-step count and TTFT change, which is exactly what
    /// the continuous-vs-lockstep acceptance scenario compares.
    pub continuous: bool,
    /// Prompt-chunk size for incremental prefill inside continuous decode
    /// groups (DESIGN.md §13). `0` (the default) pins monolithic one-pass
    /// admission; any value > 0 produces bit-identical tokens while
    /// letting short requests start decoding under a long prompt.
    pub prefill_chunk: usize,
    pub buckets: Vec<usize>,
    pub max_wait: Duration,
    pub cache_budget_bytes: usize,
    /// Tenants registered before the trace starts (cycling the
    /// environment's adapter pool).
    pub n_adapters: usize,
    /// Arrival trace (Poisson rate × Zipf popularity × request count).
    pub workload: WorkloadConfig,
    /// Override the Zipf adapter mix with strict round-robin (adjacent
    /// arrivals never share an adapter — the worst case for per-adapter
    /// batching, the best case for factor-form mixed batches). Arrival
    /// *times* still come from `workload`.
    pub round_robin: bool,
    /// Seed for per-request prompt variation.
    pub prompt_seed: u64,
    /// Max new tokens per request.
    pub max_new: usize,
    /// When > 0, override `max_new` with a deterministic mixed-length
    /// pattern: request `i` gets `1 + (3i + 1) mod spread` new-token
    /// budget (a full residue cycle for spread coprime with 3). Mixed
    /// lengths are what make continuous batching pay: short lanes free
    /// up mid-flight while long lanes keep decoding.
    pub max_new_spread: usize,
    /// Warm every adapter's merged weights before the trace.
    pub prefetch: bool,
    /// Enable the disk tier (DESIGN.md §14): adapters spill to a
    /// scenario-owned directory at registration; packed factors page back
    /// in through the merge pool, bounded by `factor_cache_bytes`.
    pub tiered: bool,
    /// Total in-RAM factor-cache budget (split across workers). Only
    /// meaningful with `tiered`.
    pub factor_cache_bytes: usize,
    /// Warm adapters ahead of their predicted next arrival
    /// (`workload::ArrivalPredictor`). Only meaningful with `tiered`.
    pub predictive_prefetch: bool,
    /// Per-request deadline measured from submission (DESIGN.md §15):
    /// requests past their deadline retire with a structured `Timeout`
    /// whether queued or mid-decode. `None` (the default) disables
    /// deadline enforcement.
    pub request_timeout: Option<Duration>,
    /// Admission-queue depth cap (DESIGN.md §15): submissions beyond the
    /// cap are shed with `Overloaded { retry_after }`. `None` disables
    /// shedding.
    pub queue_cap: Option<usize>,
    /// Bounded retries for failing tier loads (DESIGN.md §15). 0 = fail
    /// on first error.
    pub disk_retries: u32,
    /// Virtual-clock backoff between tier-load retries.
    pub disk_backoff: Duration,
    /// Record request-lifecycle spans (DESIGN.md §16). On by default:
    /// ring-buffer recording is off the latency path, and the run's
    /// `trace_json()` export + per-stage breakdowns need it. `false`
    /// pins the tracing-disabled configuration (bench baseline).
    pub trace: bool,
    pub faults: FaultPlan,
}

impl Default for ScenarioSpec {
    fn default() -> Self {
        Self {
            name: "default".into(),
            mode: ClockMode::Virtual,
            strategy: MergeStrategy::Merged,
            workers: 1,
            merge_workers: 1,
            compute_threads: 1,
            continuous: true,
            prefill_chunk: 0,
            // the buckets aot.py actually exports, so specs run unchanged
            // against real PJRT artifacts
            buckets: vec![1, 8],
            max_wait: Duration::from_millis(5),
            cache_budget_bytes: 64 << 20,
            n_adapters: 4,
            workload: WorkloadConfig { rate: 200.0, zipf_alpha: 1.1, n_requests: 64, seed: 7 },
            round_robin: false,
            prompt_seed: 11,
            max_new: 2,
            max_new_spread: 0,
            prefetch: false,
            tiered: false,
            factor_cache_bytes: 1 << 20,
            predictive_prefetch: false,
            request_timeout: None,
            queue_cap: None,
            disk_retries: 0,
            disk_backoff: Duration::ZERO,
            trace: true,
            faults: FaultPlan::default(),
        }
    }
}

impl ScenarioSpec {
    /// Builder sugar.
    pub fn named(mut self, name: impl Into<String>) -> Self {
        self.name = name.into();
        self
    }

    pub fn with_strategy(mut self, strategy: MergeStrategy) -> Self {
        self.strategy = strategy;
        self
    }

    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    pub fn with_mode(mut self, mode: ClockMode) -> Self {
        self.mode = mode;
        self
    }

    /// Churn actions sorted by time (the driver consumes them in order).
    pub(crate) fn sorted_churn(&self) -> Vec<ChurnAction> {
        let mut churn = self.faults.churn.clone();
        churn.sort_by_key(ChurnAction::at);
        churn
    }
}

/// Where a scenario runs: an artifacts directory, a model name, and a
/// pool of pre-built adapters to register (cycled when the spec asks for
/// more tenants than the pool holds). Built either from real
/// `make artifacts` output or synthesized hermetically.
pub struct ScenarioEnv {
    pub artifacts: PathBuf,
    pub model: String,
    pub adapters: Vec<(String, StoredAdapter)>,
    /// Temp dir owned by this env (removed on drop).
    cleanup: Option<PathBuf>,
}

impl ScenarioEnv {
    /// Wrap existing artifacts + adapters (nothing owned).
    pub fn new(
        artifacts: impl Into<PathBuf>,
        model: impl Into<String>,
        adapters: Vec<(String, StoredAdapter)>,
    ) -> Self {
        Self { artifacts: artifacts.into(), model: model.into(), adapters, cleanup: None }
    }

    /// Build the standard adapter pool from trained `make artifacts`
    /// output: one LoRAQuant(2@0.9) adapter per task. Shared by the
    /// `serve-sim` CLI and `bench_serving` so every entry point serves
    /// the same adapters.
    pub fn from_artifacts(
        artifacts: impl Into<PathBuf>,
        model: impl Into<String>,
    ) -> anyhow::Result<Self> {
        let artifacts = artifacts.into();
        let model = model.into();
        let qcfg = LoraQuantConfig::variant(2, 0.9);
        let mut adapters = Vec::new();
        for task in crate::eval::tasks::TASKS {
            let lora =
                LoraAdapter::load(artifacts.join(&model).join(format!("{task}.lora.bin")))
                    .with_context(|| format!("loading trained adapter for task {task}"))?;
            let mut q = QuantizedLora::default();
            for (site, (a, b)) in &lora.sites {
                q.sites.insert(site.clone(), quantize_site(b, a, &qcfg)?);
            }
            adapters.push((task.to_string(), StoredAdapter::Quantized(q)));
        }
        Ok(Self { artifacts, model, adapters, cleanup: None })
    }

    /// Synthesize a tiny model + `n_adapters` quantized adapters in a
    /// fresh temp directory (reference engine only). The directory is
    /// removed when the env drops.
    pub fn synth(tag: &str, n_adapters: usize) -> anyhow::Result<Self> {
        // (tag, pid, counter): two live envs sharing a tag in one process
        // must not clobber each other's model files
        static SEQ: AtomicUsize = AtomicUsize::new(0);
        let seq = SEQ.fetch_add(1, Ordering::Relaxed);
        let dir = std::env::temp_dir()
            .join(format!("lq_scenario_{tag}_{}_{seq}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = synth_model_config();
        write_synth_model(&dir, "synth", &cfg, &[1, 4, 8], 17)
            .context("writing synthetic scenario model")?;
        let adapters = (0..n_adapters.max(1))
            .map(|i| (format!("task{i}"), synth_quantized_adapter(&cfg, 100 + i as u64)))
            .collect();
        Ok(Self {
            artifacts: dir.clone(),
            model: "synth".into(),
            adapters,
            cleanup: Some(dir),
        })
    }
}

impl Drop for ScenarioEnv {
    fn drop(&mut self) {
        if let Some(dir) = &self.cleanup {
            let _ = std::fs::remove_dir_all(dir);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_is_virtual_and_small() {
        let s = ScenarioSpec::default();
        assert_eq!(s.mode, ClockMode::Virtual);
        assert!(s.n_adapters >= 1);
        assert!(s.workload.n_requests > 0);
        assert!(s.faults.is_empty());
    }

    #[test]
    fn churn_sorts_by_time() {
        let spec = ScenarioSpec {
            faults: FaultPlan {
                churn: vec![
                    ChurnAction::Remove { at: Duration::from_millis(30), target: 0 },
                    ChurnAction::Register { at: Duration::from_millis(10), pool_index: 1 },
                ],
                ..Default::default()
            },
            ..Default::default()
        };
        let sorted = spec.sorted_churn();
        assert_eq!(sorted[0].at(), Duration::from_millis(10));
        assert_eq!(sorted[1].at(), Duration::from_millis(30));
    }

    #[test]
    fn synth_env_builds_and_cleans_up() {
        let dir;
        {
            let env = ScenarioEnv::synth("spec_unit", 3).unwrap();
            dir = env.artifacts.clone();
            assert!(dir.join("synth").join("base.bin").exists());
            assert_eq!(env.adapters.len(), 3);
        }
        assert!(!dir.exists(), "env must remove its temp dir on drop");
    }
}
