//! Minimal CLI argument parser (clap is unavailable offline):
//! `binary <subcommand> [--key value]... [--flag]...`.

use anyhow::{bail, Context};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from raw args (excluding argv[0]). Options with values use
    /// `--key value` or `--key=value`; bare `--key` entries become flags.
    pub fn parse(raw: &[String]) -> anyhow::Result<Self> {
        let mut out = Args::default();
        let mut it = raw.iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(key) = arg.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    out.options.insert(key.to_string(), it.next().unwrap().clone());
                } else {
                    out.flags.push(key.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg.clone());
            } else {
                out.positional.push(arg.clone());
            }
        }
        Ok(out)
    }

    /// Parse from the process environment.
    pub fn from_env() -> anyhow::Result<Self> {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&raw)
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key}: bad integer '{v}'")),
        }
    }

    pub fn f32_or(&self, key: &str, default: f32) -> anyhow::Result<f32> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key}: bad float '{v}'")),
        }
    }

    /// Comma-separated integer list, e.g. `--buckets 1,8`.
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> anyhow::Result<Vec<usize>> {
        match self.opt(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|x| {
                    x.trim()
                        .parse()
                        .with_context(|| format!("--{key}: bad integer '{x}' in '{v}'"))
                })
                .collect(),
        }
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn require(&self, key: &str) -> anyhow::Result<&str> {
        match self.opt(key) {
            Some(v) => Ok(v),
            None => bail!("missing required option --{key}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        // NOTE: `--flag value`-style ambiguity is resolved toward options
        // (`--verbose extra` would parse as verbose=extra), so flags go
        // last or use `=`; this test reflects the documented behavior.
        let a = Args::parse(&s(&["serve", "--model", "tiny-llama-s", "--bucket=8", "extra", "--verbose"]))
            .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.opt("model"), Some("tiny-llama-s"));
        assert_eq!(a.usize_or("bucket", 0).unwrap(), 8);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = Args::parse(&s(&["x", "--fast"])).unwrap();
        assert!(a.has_flag("fast"));
    }

    #[test]
    fn required_and_typed_errors() {
        let a = Args::parse(&s(&["x", "--n", "abc"])).unwrap();
        assert!(a.require("missing").is_err());
        assert!(a.usize_or("n", 0).is_err());
        assert_eq!(a.f32_or("absent", 1.5).unwrap(), 1.5);
    }

    #[test]
    fn integer_lists() {
        let a = Args::parse(&s(&["x", "--buckets", "1, 4,8"])).unwrap();
        assert_eq!(a.usize_list_or("buckets", &[8]).unwrap(), vec![1, 4, 8]);
        assert_eq!(a.usize_list_or("absent", &[1, 8]).unwrap(), vec![1, 8]);
        let bad = Args::parse(&s(&["x", "--buckets", "1,x"])).unwrap();
        assert!(bad.usize_list_or("buckets", &[]).is_err());
    }
}
