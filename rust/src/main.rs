//! `loraquant` — CLI entrypoint for the quantization pipeline and the
//! multi-LoRA serving coordinator.
//!
//! ```text
//! loraquant quantize --model tiny-llama-s --task modadd --bits 2 --rho 0.9 --out q.bin
//! loraquant eval     --model tiny-llama-s --task modadd [--quantized q.bin] [--n 100]
//! loraquant serve    --model tiny-llama-s --requests 200 --rate 200 --adapters 12 \
//!                    [--workers 4] [--merge-workers 2] [--compute-threads 2] \
//!                    [--buckets 1,8] [--prefetch] [--lockstep] \
//!                    [--prefill-chunk N] [--merge-strategy merged|factor|auto] \
//!                    [--adapter-dir DIR] [--factor-cache-kb N] [--disk-latency-ms N] \
//!                    [--request-timeout-ms N] [--queue-cap N] [--disk-retries N] \
//!                    [--disk-backoff-ms N] [--metrics-out PATH]
//! loraquant serve-sim --requests 200 --rate 200 --adapters 4 --merge-strategy all \
//!                    [--workers 4] [--compute-threads 2] [--zipf 1.1] [--seed 7] \
//!                    [--slow-merge-ms 50] [--churn] [--prefetch] [--log] \
//!                    [--lockstep] [--prefill-chunk N] [--golden PATH] [--model NAME] \
//!                    [--tiered] [--factor-cache-kb N] [--disk-latency-ms N] \
//!                    [--predictive-prefetch] [--trace-out PATH] [--metrics-out PATH] \
//!                    [--no-trace]
//!
//! `--lockstep` disables the continuous-batching scheduler (DESIGN.md
//! §11) and decodes batch by batch — the comparison baseline for the
//! scheduler's decode-step and TTFT numbers. `--prefill-chunk N` splits
//! long-prompt prefill into N-token chunks inside the continuous
//! scheduler (DESIGN.md §13) so short requests are not blocked behind a
//! long prompt; 0 (the default) keeps monolithic admission. Tokens are
//! bit-identical at every chunk size. `--adapter-dir` (serve) and
//! `--tiered` (serve-sim) spill packed adapters to an on-disk tier at
//! registration and page factors back on miss through a byte-budgeted
//! per-worker cache (DESIGN.md §14); `--disk-latency-ms` scripts the
//! read latency, and `--predictive-prefetch` warms tenants whose
//! arrival cadence says they are due.
//! loraquant info     --model tiny-llama-s
//! ```
//!
//! `serve-sim` replays a scenario spec through the coordinator under a
//! **virtual clock** (DESIGN.md §9): seconds of simulated trace run in
//! milliseconds of wall clock with a deterministic event log. Without
//! `--model` it synthesizes a hermetic model, so it needs no artifacts.
//! `--trace-out` writes the request-lifecycle trace as Chrome
//! trace-event JSON (load in Perfetto / `chrome://tracing`) and
//! `--metrics-out` the Prometheus text exposition (DESIGN.md §16); with
//! `--merge-strategy all` the files get a `.{strategy}` suffix like
//! `--golden`. `--no-trace` disables span recording (the bench
//! baseline).
//!
//! Everything else runs without python (`make artifacts` must have run).

use anyhow::{bail, Context};
use loraquant::adapter::{store, LoraAdapter};
use loraquant::cli::Args;
use loraquant::coordinator::{
    pool_registry, Coordinator, CoordinatorConfig, DiskFault, GenRequest, MergeStrategy,
    StoredAdapter, TierConfig,
};
use loraquant::eval::{evaluate, EvalSet};
use loraquant::loraquant::{quantize_site, LoraQuantConfig, QuantizedLora};
use loraquant::model::{merge_adapter, BaseWeights};
use loraquant::runtime::Engine;
use loraquant::workload::{generate, WorkloadConfig};
use std::time::{Duration, Instant};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("quantize") => cmd_quantize(&args),
        Some("eval") => cmd_eval(&args),
        Some("serve") => cmd_serve(&args),
        Some("serve-sim") => cmd_serve_sim(&args),
        Some("info") => cmd_info(&args),
        Some(other) => {
            bail!("unknown subcommand '{other}' (try quantize|eval|serve|serve-sim|info)")
        }
        None => {
            eprintln!(
                "usage: loraquant <quantize|eval|serve|serve-sim|info> [--artifacts DIR] [--model NAME] ..."
            );
            Ok(())
        }
    }
}

fn artifacts_dir(args: &Args) -> String {
    args.str_or("artifacts", "artifacts")
}

/// Quantize a trained adapter with LoRAQuant and write the packed file.
fn cmd_quantize(args: &Args) -> anyhow::Result<()> {
    let dir = artifacts_dir(args);
    let model = args.require("model")?;
    let task = args.require("task")?;
    let bits = args.usize_or("bits", 2)? as u32;
    let rho = args.f32_or("rho", 0.9)?;
    let out = args.str_or("out", &format!("{dir}/{model}/{task}.lq{bits}r{rho}.bin"));

    let lora = LoraAdapter::load(format!("{dir}/{model}/{task}.lora.bin"))?;
    let cfg = LoraQuantConfig::variant(bits, rho);
    let t0 = Instant::now();
    let mut q = QuantizedLora::default();
    for (site, (a, b)) in &lora.sites {
        q.sites.insert(site.clone(), quantize_site(b, a, &cfg)?);
    }
    let dt = t0.elapsed();
    store::save(&out, &q)?;
    println!("quantized {model}/{task}: LoRAQuant({bits}@{rho})");
    println!("  sites          : {}", q.sites.len());
    println!("  avg bits       : {:.3} (fp16 = 16)", q.avg_bits());
    println!("  packed bytes   : {} (fp16 = {})", q.packed_bytes(), lora.fp16_bytes());
    println!("  compression    : {:.1}x", lora.fp16_bytes() as f64 / q.packed_bytes() as f64);
    println!("  pipeline time  : {dt:?}");
    println!("  wrote {out}");
    Ok(())
}

/// Evaluate an adapter (FP16 or a packed quantized file) on its task.
fn cmd_eval(args: &Args) -> anyhow::Result<()> {
    let dir = artifacts_dir(args);
    let model = args.require("model")?;
    let task = args.require("task")?;
    let n = args.usize_or("n", 200)?;
    let bucket = args.usize_or("bucket", 8)?;

    let base = BaseWeights::load(format!("{dir}/{model}"))?;
    let mut engine = Engine::new(&dir)?;
    engine.load_model_fwd(model, bucket, base.cfg.param_names().len())?;
    let set = EvalSet::load(format!("{dir}/{model}/{task}.eval.bin"))?.truncated(n);

    let deltas = match args.opt("quantized") {
        Some(path) => {
            let q = store::load(path)?;
            println!("evaluating quantized adapter ({:.3} avg bits)", q.avg_bits());
            loraquant::model::merge::quant_deltas(&q)
        }
        None => {
            let lora = LoraAdapter::load(format!("{dir}/{model}/{task}.lora.bin"))?;
            println!("evaluating FP16 adapter");
            loraquant::model::merge::fp_deltas(&lora)
        }
    };
    let merged = merge_adapter(&base, &deltas)?;
    let weights = engine.upload_weights(&merged)?;
    let t0 = Instant::now();
    let outcome = evaluate(&engine, model, bucket, &base.cfg, &weights, &set)?;
    println!(
        "{model}/{task}: score = {:.2} ({} examples, {}, {:?})",
        outcome.score,
        set.len(),
        if outcome.exact { "exact match" } else { "ROUGE-L" },
        t0.elapsed()
    );
    Ok(())
}

/// Serve a synthetic multi-adapter workload and report latency/throughput.
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let dir = artifacts_dir(args);
    let model = args.str_or("model", "tiny-llama-s");
    let n_adapters = args.usize_or("adapters", 12)?;
    let n_requests = args.usize_or("requests", 200)?;
    let rate = args.f32_or("rate", 200.0)? as f64;
    let cache_mb = args.usize_or("cache-mb", 64)?;

    let mut cfg = CoordinatorConfig::new(&dir, &model);
    cfg.workers = args.usize_or("workers", 1)?;
    cfg.merge_workers = args.usize_or("merge-workers", 2)?;
    cfg.compute_threads = args.usize_or("compute-threads", 1)?;
    cfg.buckets = args.usize_list_or("buckets", &[1, 8])?;
    cfg.cache_budget_bytes = cache_mb << 20;
    cfg.max_wait = Duration::from_millis(args.usize_or("max-wait-ms", 10)? as u64);
    cfg.merge_strategy = args.str_or("merge-strategy", "merged").parse()?;
    cfg.continuous = !args.has_flag("lockstep");
    cfg.prefill_chunk = args.usize_or("prefill-chunk", 0)?;
    if let Some(ms) = args.opt("request-timeout-ms") {
        let timeout = Duration::from_millis(ms.parse().context("--request-timeout-ms: bad integer")?);
        cfg.request_timeout = Some(timeout);
    }
    if let Some(cap) = args.opt("queue-cap") {
        cfg.queue_cap = Some(cap.parse().context("--queue-cap: bad integer")?);
    }
    if let Some(adapter_dir) = args.opt("adapter-dir") {
        let mut tier = TierConfig::new(adapter_dir, args.usize_or("factor-cache-kb", 1 << 10)? << 10);
        if let Some(ms) = args.opt("disk-latency-ms") {
            let delay = Duration::from_millis(ms.parse().context("--disk-latency-ms: bad integer")?);
            tier.disk_fault = Some(DiskFault { adapter: None, delay });
        }
        tier.max_retries = args.usize_or("disk-retries", 0)? as u32;
        tier.backoff =
            Duration::from_millis(args.usize_or("disk-backoff-ms", 0)? as u64);
        tier.predictive_prefetch = args.has_flag("predictive-prefetch");
        cfg.tier = Some(tier);
    }
    let workers = cfg.workers;
    let strategy = cfg.merge_strategy;
    let (coord, join) = Coordinator::start(cfg)?;

    // Register n_adapters quantized clones of the trained task adapters.
    let tasks = ["modadd", "modchain", "transform", "keyword"];
    let qcfg = LoraQuantConfig::variant(2, 0.9);
    let mut ids = Vec::new();
    for i in 0..n_adapters {
        let task = tasks[i % tasks.len()];
        let lora = LoraAdapter::load(format!("{dir}/{model}/{task}.lora.bin"))?;
        let mut q = QuantizedLora::default();
        for (site, (a, b)) in &lora.sites {
            q.sites.insert(site.clone(), quantize_site(b, a, &qcfg)?);
        }
        ids.push(coord.register_adapter(StoredAdapter::Quantized(q), task)?);
    }
    println!(
        "registered {} quantized adapters across {workers} worker(s), strategy={strategy}",
        ids.len()
    );

    if args.has_flag("prefetch") {
        let t0 = Instant::now();
        let waits: Vec<_> = ids.iter().map(|&id| coord.prefetch(id)).collect();
        for rx in waits {
            rx.recv().context("prefetch ack")??;
        }
        println!("prefetched {} adapters in {:?}", ids.len(), t0.elapsed());
    }

    let wl = WorkloadConfig { rate, n_requests, ..Default::default() };
    let schedule = generate(&wl, &ids);
    let start = Instant::now();
    let mut receivers = Vec::new();
    for arr in &schedule {
        let elapsed = start.elapsed();
        if arr.at > elapsed {
            std::thread::sleep(arr.at - elapsed);
        }
        receivers.push(coord.generate_async(GenRequest::new(
            arr.adapter,
            vec![1, 5, 4, 7, 3], // BOS d0 MARK d2 SEP
            4,
        )));
    }
    let mut ok = 0;
    for rx in receivers {
        if rx.recv()?.is_ok() {
            ok += 1;
        }
    }
    let wall = start.elapsed();
    let (metrics, cache, reg) = coord.metrics()?;
    println!("served {ok}/{n_requests} requests in {wall:?} ({:.1} req/s)", ok as f64 / wall.as_secs_f64());
    println!("  {}", metrics.summary());
    println!(
        "  cache: hit_rate={:.2} evictions={} | registry: {} adapters",
        cache.hit_rate(),
        cache.evictions,
        reg
    );
    let (disk_loads, spilled) = coord.tier_stats();
    if spilled > 0 {
        let fc = coord.factor_cache_stats()?;
        println!(
            "  tier: spilled={spilled} disk_loads={disk_loads} factor-cache: hits={} misses={} evictions={}",
            fc.hits, fc.misses, fc.evictions
        );
    }
    if workers > 1 {
        for s in coord.metrics_per_worker()? {
            println!(
                "  worker {}: requests={} batches={} cached={} ({} KB)",
                s.worker,
                s.metrics.requests,
                s.metrics.batches,
                s.cached_adapters,
                s.cache_used_bytes / 1024,
            );
        }
    }
    if let Some(path) = args.opt("metrics-out") {
        let snaps = coord.metrics_per_worker()?;
        let quarantined = coord.with_registry(|r| r.quarantined_ids().len());
        std::fs::write(path, pool_registry(&snaps, quarantined, None).render())?;
        println!("wrote {path}");
    }
    coord.shutdown();
    let _ = join.join();
    Ok(())
}

/// Replay a deterministic serving scenario under virtual time.
fn cmd_serve_sim(args: &Args) -> anyhow::Result<()> {
    use loraquant::scenario::{
        run_scenario, ChurnAction, ClockMode, DiskError, DiskLatency, FaultPlan, ScenarioEnv,
        ScenarioSpec, ScriptedPanic, SlowMerge,
    };

    if cfg!(feature = "pjrt") && args.opt("model").is_none() {
        bail!("serve-sim needs --model under --features pjrt (the synthetic fallback model \
               has no HLO artifacts)");
    }
    let n_requests = args.usize_or("requests", 200)?;
    let n_adapters = args.usize_or("adapters", 4)?;
    let rate = args.f32_or("rate", 200.0)? as f64;
    let zipf = args.f32_or("zipf", 1.1)? as f64;
    let seed = args.usize_or("seed", 7)? as u64;

    // Environment: trained adapters when --model is given, hermetic
    // synthetic model otherwise.
    let env = match args.opt("model") {
        Some(model) => ScenarioEnv::from_artifacts(artifacts_dir(args), model)?,
        None => ScenarioEnv::synth("cli", 4)?,
    };

    let mut faults = FaultPlan::default();
    if let Some(ms) = args.opt("slow-merge-ms") {
        let delay = Duration::from_millis(ms.parse().context("--slow-merge-ms: bad integer")?);
        let adapter = args
            .opt("slow-merge-adapter")
            .map(|v| v.parse().context("--slow-merge-adapter: bad id"))
            .transpose()?;
        faults.slow_merge = Some(SlowMerge { adapter, delay });
    }
    if let Some(ms) = args.opt("disk-latency-ms") {
        let delay = Duration::from_millis(ms.parse().context("--disk-latency-ms: bad integer")?);
        let adapter = args
            .opt("disk-latency-adapter")
            .map(|v| v.parse().context("--disk-latency-adapter: bad id"))
            .transpose()?;
        faults.disk_latency = Some(DiskLatency { adapter, delay });
    }
    if let Some(n) = args.opt("disk-error-first-n") {
        let first_n = n.parse().context("--disk-error-first-n: bad integer")?;
        let adapter = args
            .opt("disk-error-adapter")
            .map(|v| v.parse().context("--disk-error-adapter: bad id"))
            .transpose()?;
        faults.disk_error = Some(DiskError { adapter, first_n });
    }
    if let Some(id) = args.opt("panic-adapter") {
        let adapter = id.parse().context("--panic-adapter: bad id")?;
        let first_n = args.usize_or("panic-first-n", 1)? as u32;
        faults.panic = Some(ScriptedPanic { adapter, first_n });
    }
    if args.has_flag("churn") {
        // a scripted mid-trace outage + arrival: remove tenant 0 a third
        // of the way in, register a fresh tenant two thirds of the way in
        let span = Duration::from_secs_f64(n_requests as f64 / rate.max(1e-9));
        faults.churn = vec![
            ChurnAction::Remove { at: span / 3, target: 0 },
            ChurnAction::Register { at: span * 2 / 3, pool_index: 0 },
        ];
    }

    let strategies: Vec<MergeStrategy> = match args.str_or("merge-strategy", "all").as_str() {
        "all" => {
            if cfg!(feature = "pjrt") {
                vec![MergeStrategy::Merged]
            } else {
                vec![MergeStrategy::Merged, MergeStrategy::Factor, MergeStrategy::Auto]
            }
        }
        s => vec![s.parse()?],
    };
    let multi = strategies.len() > 1;

    for strategy in strategies {
        let spec = ScenarioSpec {
            name: format!("serve-sim/{strategy}"),
            mode: ClockMode::Virtual,
            strategy,
            workers: args.usize_or("workers", 1)?,
            merge_workers: args.usize_or("merge-workers", 1)?,
            compute_threads: args.usize_or("compute-threads", 1)?,
            continuous: !args.has_flag("lockstep"),
            prefill_chunk: args.usize_or("prefill-chunk", 0)?,
            buckets: args.usize_list_or("buckets", &[1, 8])?,
            max_wait: Duration::from_millis(args.usize_or("max-wait-ms", 5)? as u64),
            cache_budget_bytes: args.usize_or("cache-kb", 64 << 10)? << 10,
            n_adapters,
            workload: WorkloadConfig { rate, zipf_alpha: zipf, n_requests, seed },
            round_robin: args.has_flag("round-robin"),
            prompt_seed: seed ^ 0x5eed,
            max_new: args.usize_or("max-new", 2)?,
            max_new_spread: args.usize_or("max-new-spread", 0)?,
            prefetch: args.has_flag("prefetch"),
            faults: faults.clone(),
            tiered: args.has_flag("tiered"),
            factor_cache_bytes: args.usize_or("factor-cache-kb", 1 << 10)? << 10,
            predictive_prefetch: args.has_flag("predictive-prefetch"),
            request_timeout: args
                .opt("request-timeout-ms")
                .map(|v| v.parse().context("--request-timeout-ms: bad integer"))
                .transpose()?
                .map(Duration::from_millis),
            queue_cap: args
                .opt("queue-cap")
                .map(|v| v.parse().context("--queue-cap: bad integer"))
                .transpose()?,
            disk_retries: args.usize_or("disk-retries", 0)? as u32,
            disk_backoff: Duration::from_millis(args.usize_or("disk-backoff-ms", 0)? as u64),
            trace: !args.has_flag("no-trace"),
        };
        let run = run_scenario(&spec, &env)?;
        print!("{}", run.summary.render());
        if args.has_flag("log") {
            print!("{}", run.log());
        }
        if let Some(path) = args.opt("golden") {
            let file = format!("{path}.{strategy}.log");
            std::fs::write(&file, run.log())?;
            println!("wrote {file} ({} events)", run.events.len());
        }
        // one strategy → the exact path (Perfetto-loadable as named);
        // `all` → a `.{strategy}` suffix like --golden
        if let Some(path) = args.opt("trace-out") {
            let file =
                if multi { format!("{path}.{strategy}") } else { path.to_string() };
            std::fs::write(&file, run.trace_json())?;
            println!("wrote {file} ({} spans)", run.spans.len());
        }
        if let Some(path) = args.opt("metrics-out") {
            let file =
                if multi { format!("{path}.{strategy}") } else { path.to_string() };
            std::fs::write(&file, &run.metrics_text)?;
            println!("wrote {file}");
        }
        println!();
    }
    Ok(())
}

/// Print model + adapter inventory.
fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let dir = artifacts_dir(args);
    let model = args.require("model")?;
    let base = BaseWeights::load(format!("{dir}/{model}"))
        .with_context(|| "run `make artifacts` first")?;
    println!("{model}: {:#?}", base.cfg);
    println!("base params: {} ({} fp16 bytes)", base.param_count(), base.fp16_bytes());
    for task in ["modadd", "modchain", "transform", "keyword"] {
        if let Ok(lora) = LoraAdapter::load(format!("{dir}/{model}/{task}.lora.bin")) {
            println!(
                "  adapter {task}: {} sites, rank {}, {} params, {} fp16 bytes",
                lora.sites.len(),
                lora.rank(),
                lora.param_count(),
                lora.fp16_bytes()
            );
        }
    }
    Ok(())
}
