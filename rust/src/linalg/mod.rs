//! Numerical linear algebra substrate (no LAPACK offline — built from
//! scratch): thin QR, one-sided Jacobi SVD, and the low-rank product SVD
//! that the LoRAQuant pipeline actually calls.

mod jacobi;
mod qr;

pub use jacobi::svd_jacobi;
pub use qr::qr_thin;

use crate::tensor::{matmul, Matrix};

/// Full SVD result `A = U * diag(s) * Vt`, singular values descending.
#[derive(Debug, Clone)]
pub struct Svd {
    /// m×k left singular vectors (columns orthonormal).
    pub u: Matrix,
    /// k singular values, descending, non-negative.
    pub s: Vec<f32>,
    /// k×n right singular vectors, transposed (rows orthonormal).
    pub vt: Matrix,
}

impl Svd {
    /// Reconstruct `U diag(s) Vt`.
    pub fn reconstruct(&self) -> Matrix {
        let k = self.s.len();
        let mut us = Matrix::zeros(self.u.rows(), k);
        for i in 0..self.u.rows() {
            for j in 0..k {
                us.set(i, j, self.u.at(i, j) * self.s[j]);
            }
        }
        matmul(&us, &self.vt)
    }
}

/// SVD of the low-rank product `B @ A` (B: m×r, A: r×n) **without**
/// materializing the m×n product — the core primitive behind the paper's
/// Eq. (1).
///
/// Method: thin-QR both factors,
///   `B = Qb Rb` (m×r),  `Aᵀ = Qa Ra` (n×r)  ⇒  `BA = Qb (Rb Raᵀ) Qaᵀ`,
/// then a Jacobi SVD of the tiny r×r core `Rb Raᵀ`. Cost O((m+n)r² + r³).
pub fn svd_lowrank_product(b: &Matrix, a: &Matrix) -> Svd {
    assert_eq!(b.cols(), a.rows(), "svd_lowrank_product: B {:?} A {:?}", b.shape(), a.shape());
    let r = b.cols();
    let (qb, rb) = qr_thin(b);
    let (qa, ra) = qr_thin(&a.transpose());
    // core = Rb @ Raᵀ  (r×r)
    let core = matmul(&rb, &ra.transpose());
    let small = svd_jacobi(&core);
    let u = matmul(&qb, &small.u);
    // Vt = small.vt @ Qaᵀ  ⇒ V = Qa @ small.v
    let vt = matmul(&small.vt, &qa.transpose());
    debug_assert_eq!(u.cols(), r);
    Svd { u, s: small.s, vt }
}

/// SVD of a general dense matrix (delegates to one-sided Jacobi; used by the
/// JD-Diagonal baseline's shared-basis computation and in tests).
pub fn svd(a: &Matrix) -> Svd {
    svd_jacobi(a)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;

    #[test]
    fn lowrank_product_reconstructs() {
        let mut rng = Rng::new(42);
        let b = rng.matrix(64, 16, 1.0);
        let a = rng.matrix(16, 48, 1.0);
        let ba = matmul(&b, &a);
        let svd = svd_lowrank_product(&b, &a);
        assert!(svd.reconstruct().rel_err(&ba) < 1e-4, "err {}", svd.reconstruct().rel_err(&ba));
        // singular values sorted descending
        for w in svd.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
    }

    #[test]
    fn lowrank_orthonormal_factors() {
        let mut rng = Rng::new(7);
        let b = rng.matrix(40, 8, 1.0);
        let a = rng.matrix(8, 56, 1.0);
        let svd = svd_lowrank_product(&b, &a);
        let utu = crate::tensor::matmul_at_b(&svd.u, &svd.u);
        let vvt = crate::tensor::matmul_a_bt(&svd.vt, &svd.vt);
        assert!(utu.rel_err(&Matrix::eye(8)) < 1e-4);
        assert!(vvt.rel_err(&Matrix::eye(8)) < 1e-4);
    }

    #[test]
    fn handles_rank_deficiency() {
        let mut rng = Rng::new(3);
        // B has two identical columns -> product rank < r
        let mut b = rng.matrix(32, 4, 1.0);
        for i in 0..32 {
            let v = b.at(i, 0);
            b.set(i, 1, v);
        }
        let a = rng.matrix(4, 24, 1.0);
        let ba = matmul(&b, &a);
        let svd = svd_lowrank_product(&b, &a);
        assert!(svd.reconstruct().rel_err(&ba) < 1e-3);
    }
}
