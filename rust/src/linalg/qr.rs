//! Thin QR via modified Gram-Schmidt with one reorthogonalization pass
//! (the "MGS2" scheme — numerically equivalent to Householder for these
//! well-scaled LoRA factors, and much simpler).

use crate::tensor::{dot, norm2, Matrix};

/// Thin QR of an m×k matrix (m >= k not required; k columns are
/// orthonormalized in order): returns (Q m×k with orthonormal columns —
/// zero columns where rank-deficient — and R k×k upper-triangular) with
/// `A = Q R`.
pub fn qr_thin(a: &Matrix) -> (Matrix, Matrix) {
    let (m, k) = a.shape();
    // Work with columns as contiguous rows of the transpose.
    let mut qt = a.transpose(); // k×m, row j = column j
    let mut r = Matrix::zeros(k, k);
    for j in 0..k {
        // two-pass orthogonalization of column j against 0..j
        for _pass in 0..2 {
            for i in 0..j {
                let (qi, qj) = rows_pair(&mut qt, i, j, m);
                let proj = dot(qi, qj);
                r.set(i, j, r.at(i, j) + proj);
                for t in 0..m {
                    qj[t] -= proj * qi[t];
                }
            }
        }
        let qj = qt.row_mut(j);
        let nrm = norm2(qj);
        r.set(j, j, nrm);
        if nrm > 1e-12 {
            let inv = 1.0 / nrm;
            for v in qj.iter_mut() {
                *v *= inv;
            }
        } else {
            // rank-deficient column: leave Q column zero, R row zero.
            for v in qj.iter_mut() {
                *v = 0.0;
            }
            r.set(j, j, 0.0);
        }
    }
    (qt.transpose(), r)
}

/// Disjoint mutable/immutable access to rows i (read) and j (write) of a
/// k×m row-major matrix.
fn rows_pair<'a>(mat: &'a mut Matrix, i: usize, j: usize, m: usize) -> (&'a [f32], &'a mut [f32]) {
    assert_ne!(i, j);
    let ptr = mat.data_mut().as_mut_ptr();
    unsafe {
        let qi = std::slice::from_raw_parts(ptr.add(i * m), m);
        let qj = std::slice::from_raw_parts_mut(ptr.add(j * m), m);
        (qi, qj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul, matmul_at_b};
    use crate::testutil::Rng;

    #[test]
    fn qr_reconstructs_and_q_orthonormal() {
        let mut rng = Rng::new(11);
        let a = rng.matrix(50, 12, 1.0);
        let (q, r) = qr_thin(&a);
        assert!(matmul(&q, &r).rel_err(&a) < 1e-4);
        assert!(matmul_at_b(&q, &q).rel_err(&Matrix::eye(12)) < 1e-4);
        // R upper triangular
        for i in 0..12 {
            for j in 0..i {
                assert!(r.at(i, j).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn qr_rank_deficient() {
        let mut rng = Rng::new(12);
        let mut a = rng.matrix(30, 6, 1.0);
        // col 3 = 2 * col 1
        for i in 0..30 {
            let v = a.at(i, 1);
            a.set(i, 3, 2.0 * v);
        }
        let (q, r) = qr_thin(&a);
        assert!(matmul(&q, &r).rel_err(&a) < 1e-4);
        assert!(r.at(3, 3).abs() < 1e-4);
    }
}
