//! One-sided Jacobi SVD.
//!
//! Orthogonalizes pairs of columns of A by plane rotations until all pairs
//! are numerically orthogonal; then the column norms are the singular
//! values, the normalized columns are U, and the accumulated rotations give
//! V.  We operate on Aᵀ so that "columns" are contiguous rows — cache-
//! friendly and autovectorizable.
//!
//! Used directly on small cores (r×r from the low-rank product SVD, or the
//! stacked matrices of the JD-Diagonal baseline).

use super::Svd;
use crate::tensor::{dot, norm2, Matrix};

const MAX_SWEEPS: usize = 60;
const TOL: f32 = 1e-7;

/// One-sided Jacobi SVD of an m×n matrix. Returns k = n factors.
pub fn svd_jacobi(a: &Matrix) -> Svd {
    let (m, n) = a.shape();
    let mut at = a.transpose(); // n×m: row j == column j of A
    let mut v = Matrix::eye(n); // accumulates right rotations; columns of V
    let mut vt = v.transpose(); // keep V as rows for cache: vt row j == V column j

    for _sweep in 0..MAX_SWEEPS {
        let mut off = 0.0f32;
        let mut converged = true;
        for p in 0..n {
            for q in (p + 1)..n {
                let (cp, cq) = rows_pair(&mut at, p, q, m);
                let app = dot(cp, cp);
                let aqq = dot(cq, cq);
                let apq = dot(cp, cq);
                if app <= 1e-30 || aqq <= 1e-30 {
                    continue;
                }
                off += apq.abs();
                if apq.abs() <= TOL * (app * aqq).sqrt() {
                    continue;
                }
                converged = false;
                // Jacobi rotation zeroing the (p,q) entry of AᵀA
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                rotate(cp, cq, c, s);
                let (vp, vq) = rows_pair(&mut vt, p, q, n);
                rotate(vp, vq, c, s);
            }
        }
        let _ = off;
        if converged {
            break;
        }
    }

    // Extract singular values & sort descending.
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f32> = (0..n).map(|j| norm2(at.row(j))).collect();
    order.sort_by(|&i, &j| norms[j].partial_cmp(&norms[i]).unwrap());

    let mut u = Matrix::zeros(m, n);
    let mut s = Vec::with_capacity(n);
    let mut vt_sorted = Matrix::zeros(n, n);
    for (k, &j) in order.iter().enumerate() {
        let nrm = norms[j];
        s.push(nrm);
        if nrm > 1e-12 {
            let inv = 1.0 / nrm;
            for i in 0..m {
                u.set(i, k, at.at(j, i) * inv);
            }
        }
        vt_sorted.row_mut(k).copy_from_slice(vt.row(j));
    }
    v = vt_sorted; // rows of vt_sorted are V columns in sorted order == rows of Vᵀ
    Svd { u, s, vt: v }
}

#[inline]
fn rotate(x: &mut [f32], y: &mut [f32], c: f32, s: f32) {
    for (xi, yi) in x.iter_mut().zip(y.iter_mut()) {
        let xv = *xi;
        let yv = *yi;
        *xi = c * xv - s * yv;
        *yi = s * xv + c * yv;
    }
}

#[inline]
fn rows_pair<'a>(mat: &'a mut Matrix, i: usize, j: usize, m: usize) -> (&'a mut [f32], &'a mut [f32]) {
    assert_ne!(i, j);
    let ptr = mat.data_mut().as_mut_ptr();
    unsafe {
        (
            std::slice::from_raw_parts_mut(ptr.add(i * m), m),
            std::slice::from_raw_parts_mut(ptr.add(j * m), m),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul, matmul_a_bt, matmul_at_b};
    use crate::testutil::Rng;

    #[test]
    fn reconstructs_square() {
        let mut rng = Rng::new(1);
        let a = rng.matrix(16, 16, 1.0);
        let svd = svd_jacobi(&a);
        assert!(svd.reconstruct().rel_err(&a) < 1e-4);
    }

    #[test]
    fn reconstructs_tall() {
        let mut rng = Rng::new(2);
        let a = rng.matrix(48, 12, 1.0);
        let svd = svd_jacobi(&a);
        assert!(svd.reconstruct().rel_err(&a) < 1e-4);
        let utu = matmul_at_b(&svd.u, &svd.u);
        assert!(utu.rel_err(&Matrix::eye(12)) < 1e-4);
        let vvt = matmul_a_bt(&svd.vt, &svd.vt);
        assert!(vvt.rel_err(&Matrix::eye(12)) < 1e-4);
    }

    #[test]
    fn known_singular_values() {
        // A = diag(3, 2) embedded in 3x2
        let a = Matrix::from_vec(3, 2, vec![3.0, 0.0, 0.0, 2.0, 0.0, 0.0]);
        let svd = svd_jacobi(&a);
        assert!((svd.s[0] - 3.0).abs() < 1e-5);
        assert!((svd.s[1] - 2.0).abs() < 1e-5);
    }

    #[test]
    fn zero_matrix() {
        let a = Matrix::zeros(8, 4);
        let svd = svd_jacobi(&a);
        assert!(svd.s.iter().all(|&x| x == 0.0));
        assert!(svd.reconstruct().fro_norm() < 1e-12);
    }

    #[test]
    fn energy_preserved() {
        let mut rng = Rng::new(9);
        let a = rng.matrix(20, 10, 2.0);
        let svd = svd_jacobi(&a);
        let energy: f32 = svd.s.iter().map(|s| s * s).sum();
        let fro2 = a.fro_norm().powi(2);
        assert!((energy - fro2).abs() / fro2 < 1e-4);
        let _ = matmul(&svd.u, &svd.vt); // shape sanity
    }
}
