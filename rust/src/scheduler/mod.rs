//! Continuous-batching decode scheduler (DESIGN.md §11).
//!
//! Three pieces, layered bottom-up:
//!
//! * [`workers`] — the **work-stealing task executor** (DESIGN.md §13):
//!   long-lived workers with per-worker deques plus a global injector
//!   queue, replacing `matmul_flat_threaded`'s per-call `thread::scope`
//!   (~6L+1 spawn/join barriers per prefill). The engine threads
//!   projections, the attention inner loop, and decode-step matmuls
//!   through it; tasks own disjoint output rows, so steal order never
//!   changes any reduction order and results are bit-identical at any
//!   width.
//! * [`queue`] — the **admission queue**: per-tenant FIFOs drained under
//!   token-budget fair scheduling (least-spent tenant wins each freed
//!   lane; preemption-free slot reuse).
//! * [`engine_loop`] — the **step loop**: retire finished lanes, admit
//!   queued requests into the freed slots ([`crate::runtime::Engine`]'s
//!   `new_session`/`admit` surface prefills into a *warm* session),
//!   advance chunked prefills (`prefill_chunk` > 0 splits long prompts
//!   into fixed-size chunk tasks interleaved with decode steps, §13),
//!   step the survivors. One long-lived `DecodeState` per pool worker
//!   serves every decode group, so a short request never waits for the
//!   slowest lane of a lock-step batch — or for a long prompt's
//!   monolithic prefill. Reference engine only — PJRT's AOT programs
//!   bake full-sequence shapes, so the pool keeps the lock-step path
//!   there.

pub mod queue;
pub mod workers;

#[cfg(not(feature = "pjrt"))]
pub mod engine_loop;

pub use queue::{AdmissionQueue, LaneRequest};
pub use workers::ComputePool;

#[cfg(not(feature = "pjrt"))]
pub use engine_loop::{
    run_continuous, ContinuousConfig, FinishedRequest, LoopStats, SessionStepper,
};
