//! The continuous-batching step loop (DESIGN.md §11, §13).
//!
//! [`run_continuous`] owns the retire → admit → chunk → step cycle over
//! one decode session:
//!
//! 1. **retire** — lanes that hit EOS, their budget, or the end of the
//!    sequence are retired the moment the finishing token is consumed
//!    (inside the consume step below), freeing their slot immediately;
//! 2. **admit** — every free lane is offered to the [`AdmissionQueue`],
//!    which picks requests in token-budget-fair order; all admissions of
//!    one cycle share a single prefill-shaped forward over their prompt
//!    rows ([`DecodeStep::admit`]), and each admitted lane's first token
//!    comes straight out of that pass. With a non-zero
//!    [`ContinuousConfig::prefill_chunk`], a prompt longer than the
//!    chunk size claims its lane but **streams in chunked**: each cycle
//!    advances every chunking lane by one fixed-size prompt slice
//!    ([`DecodeStep::admit_chunk`]) instead of paying the whole prefill
//!    up front, so short requests keep admitting and stepping while a
//!    long prompt trickles into the cache — the S-LoRA-style unification
//!    of prefill and decode into one schedulable work stream;
//! 3. **step** — one incremental forward over every live lane
//!    ([`DecodeStep::step`]); mid-chunk lanes are excluded (they have no
//!    next token yet). The step pass only runs once the queue is drained
//!    or every lane is occupied, so each step carries the maximum
//!    occupancy available.
//!
//! Unlike the lock-step protocol (`eval::decode::decode_lockstep`),
//! a finished lane never waits for the slowest lane of its batch: its
//! slot is reused mid-flight. Token outputs are identical either way —
//! every row-wise kernel in the engine is per-lane independent, so a
//! lane's logits do not depend on who its neighbors are (pinned by
//! `prop_continuous_matches_lockstep_oracle`).
//!
//! [`SessionStepper`] is the production [`DecodeStep`]: it drives
//! `Engine::new_session` / `Engine::admit` / `Engine::decode_step` over
//! a **persistent** session slot owned by the caller (the pool worker),
//! so the KV cache and scratch arena are allocated once per worker and
//! reused across every decode group, and it re-binds per-lane
//! factor-form adapters at admission — one heterogeneous session serves
//! many tenants over the shared base weights.

use super::queue::{AdmissionQueue, LaneRequest};
use crate::clock::Clock;
use crate::coordinator::registry::AdapterId;
use crate::eval::decode::{consume_greedy, DecodeStep};
use crate::eval::tasks::TOKENS;
use crate::loraquant::FactorSource;
use crate::runtime::{DecodeState, DeviceWeights, Engine};
use anyhow::{bail, Context};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Session shape for one continuous run.
#[derive(Debug, Clone, Copy)]
pub struct ContinuousConfig {
    /// Concurrent decode lanes (the worker's largest compiled bucket).
    pub lanes: usize,
    pub seq_len: usize,
    pub vocab: usize,
    /// Prompt-chunk size for incremental prefill. `0` = monolithic
    /// admission (the oracle path, byte-identical to the pre-chunking
    /// loop); otherwise prompts longer than this stream in
    /// `prefill_chunk`-token slices, one slice per loop cycle, while the
    /// other lanes keep admitting and stepping. Token outputs are
    /// bit-identical at every chunk size (DESIGN.md §13).
    pub prefill_chunk: usize,
}

/// Why a request retired (DESIGN.md §15). Early retirement never
/// perturbs the surviving lanes: every row-wise kernel is per-lane
/// independent, so survivors stay bit-identical to an unfaulted run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestOutcome {
    /// Ran to EOS, budget, or the end of the sequence.
    Done,
    /// The deadline passed — while queued, at admission, or mid-decode.
    Timeout,
    /// The cancel token was observed set (takes precedence over an
    /// expired deadline: an explicit caller action beats the clock).
    Cancelled,
}

/// One request's outcome.
#[derive(Debug, Clone)]
pub struct FinishedRequest {
    /// The id the caller stamped on the [`LaneRequest`].
    pub id: u64,
    pub tenant: AdapterId,
    /// How the request retired. `tokens` holds whatever was generated
    /// before an early retirement (possibly empty).
    pub outcome: RequestOutcome,
    /// Generated tokens, EOS excluded (identical to the lock-step path).
    pub tokens: Vec<i32>,
    /// Enqueue → first consumed token (admission wait + prefill; zero
    /// virtual time under the scenario clock).
    pub ttft: Duration,
    /// Clock instant the first token was consumed. `None` when the
    /// request retired without producing output (queued expiry, zero
    /// budget, a pre-output cancel) — unlike `ttft`, which reads as the
    /// retirement time on those paths, this is unambiguous, so stage
    /// attribution splits prefill from decode on it (DESIGN.md §16).
    pub first_token: Option<Instant>,
    /// [`LoopStats::work_rows`] at the moment the first token was
    /// consumed — a deterministic, clock-independent TTFT proxy (forward
    /// rows the session computed before this request produced output).
    /// Under the virtual clock compute is zero-time, so this is what the
    /// chunked-prefill TTFT assertions compare.
    pub first_token_work: u64,
}

/// Counters of one [`run_continuous`] call.
#[derive(Debug, Clone, Copy, Default)]
pub struct LoopStats {
    /// Step forward passes — the "virtual decode-step count" the
    /// continuous-vs-lockstep acceptance compares.
    pub decode_steps: u64,
    /// Admission forward passes (mid-flight prefills).
    pub admits: u64,
    /// Requests completed with [`RequestOutcome::Done`].
    pub finished: u64,
    /// Requests retired past their deadline (queued or mid-decode).
    pub timeouts: u64,
    /// Requests retired by a cancel token.
    pub cancellations: u64,
    /// Tokens generated (EOS excluded).
    pub tokens: u64,
    /// High-water mark of concurrently occupied lanes.
    pub peak_lanes: usize,
    /// Cumulative forward rows (prompt rows of every admission pass or
    /// prefill chunk + one row per active lane per step) — the loop's
    /// deterministic work clock; see [`FinishedRequest::first_token_work`].
    pub work_rows: u64,
}

/// A lane's occupant.
struct LaneState {
    id: u64,
    tenant: AdapterId,
    budget: usize,
    generated: Vec<i32>,
    enqueued: Instant,
    ttft: Option<Duration>,
    first_token: Option<Instant>,
    /// `work_rows` when the first token was consumed.
    first_token_work: Option<u64>,
    deadline: Option<Instant>,
    cancel: Option<Arc<AtomicBool>>,
}

/// Fault status of a queued-or-running request at `now`: `Cancelled`
/// wins over `Timeout` (see [`RequestOutcome`]), `None` = keep going.
fn fault_outcome(
    deadline: Option<Instant>,
    cancel: Option<&Arc<AtomicBool>>,
    now: Instant,
) -> Option<RequestOutcome> {
    if cancel.is_some_and(|c| c.load(Ordering::Relaxed)) {
        return Some(RequestOutcome::Cancelled);
    }
    if deadline.is_some_and(|d| d <= now) {
        return Some(RequestOutcome::Timeout);
    }
    None
}

fn count_outcome(stats: &mut LoopStats, outcome: RequestOutcome) {
    match outcome {
        RequestOutcome::Done => stats.finished += 1,
        RequestOutcome::Timeout => stats.timeouts += 1,
        RequestOutcome::Cancelled => stats.cancellations += 1,
    }
}

/// In-flight chunked prefill of a lane's prompt.
struct Chunking {
    /// Next prompt position to feed (previous chunks cover `0..next`).
    next: usize,
    /// Adapter handed to the stepper with the first chunk, then taken.
    adapter: Option<Arc<dyn FactorSource>>,
}

/// Consume one next-token logits row for `lane` through the **shared**
/// greedy rule ([`consume_greedy`] — the same function `decode_lockstep`
/// runs, so the two paths cannot drift), charge the tenant, and finish
/// the lane on EOS / budget / sequence-full. Finishing retires the lane
/// with the stepper and emits the result.
#[allow(clippy::too_many_arguments)] // the loop's one consume point, not an API
fn consume_row(
    lane: usize,
    row: &[f32],
    seqs: &mut [Vec<i32>],
    pos: &mut [usize],
    occ: &mut [Option<LaneState>],
    queue: &mut AdmissionQueue,
    stepper: &mut dyn DecodeStep,
    clock: &Clock,
    seq_len: usize,
    stats: &mut LoopStats,
    on_done: &mut dyn FnMut(FinishedRequest),
) {
    let Some(ls) = occ[lane].as_mut() else { return };
    let done = consume_greedy(
        row,
        &mut seqs[lane],
        &mut pos[lane],
        &mut ls.generated,
        ls.budget,
        seq_len,
    );
    queue.charge(ls.tenant, 1);
    if ls.ttft.is_none() {
        let now = clock.now();
        ls.ttft = Some(now.duration_since(ls.enqueued));
        ls.first_token = Some(now);
        ls.first_token_work = Some(stats.work_rows);
    }
    if done {
        let ls = occ[lane].take().expect("lane occupied");
        stepper.retire(lane);
        queue.release(ls.tenant);
        stats.finished += 1;
        stats.tokens += ls.generated.len() as u64;
        on_done(FinishedRequest {
            id: ls.id,
            tenant: ls.tenant,
            outcome: RequestOutcome::Done,
            tokens: ls.generated,
            ttft: ls.ttft.unwrap_or_default(),
            first_token: ls.first_token,
            first_token_work: ls.first_token_work.unwrap_or_default(),
        });
    }
}

/// Drive `stepper` until `queue` and every lane drain. See the module
/// docs for the cycle; `on_done` fires once per request, in completion
/// order. Requests whose room-clamped budget is zero complete instantly
/// without touching a lane (the lock-step zero-budget rule).
pub fn run_continuous(
    stepper: &mut dyn DecodeStep,
    cfg: &ContinuousConfig,
    queue: &mut AdmissionQueue,
    clock: &Clock,
    mut on_done: impl FnMut(FinishedRequest),
) -> anyhow::Result<LoopStats> {
    let lanes = cfg.lanes.max(1);
    stepper.begin(lanes)?;
    let mut seqs = vec![vec![TOKENS::PAD; cfg.seq_len]; lanes];
    let mut pos = vec![0usize; lanes];
    let mut occ: Vec<Option<LaneState>> = (0..lanes).map(|_| None).collect();
    let mut chunking: Vec<Option<Chunking>> = (0..lanes).map(|_| None).collect();
    let mut stats = LoopStats::default();
    // reused logits copy: `consume_row` needs the stepper mutably (to
    // retire), so the borrowed logits are staged here — one allocation
    // for the whole run
    let mut out: Vec<f32> = Vec::new();
    loop {
        // ---- fault scan: retire cancelled / expired lanes early ----
        // Runs before admission so a freed slot is refilled this very
        // cycle. Survivors are untouched (per-lane independence), so
        // their tokens stay bit-identical to an unfaulted run.
        let now = clock.now();
        for l in 0..lanes {
            let Some(outcome) = occ[l]
                .as_ref()
                .and_then(|ls| fault_outcome(ls.deadline, ls.cancel.as_ref(), now))
            else {
                continue;
            };
            let ls = occ[l].take().expect("lane occupied");
            chunking[l] = None;
            stepper.retire(l);
            queue.release(ls.tenant);
            count_outcome(&mut stats, outcome);
            stats.tokens += ls.generated.len() as u64;
            on_done(FinishedRequest {
                id: ls.id,
                tenant: ls.tenant,
                outcome,
                tokens: ls.generated,
                ttft: ls.ttft.unwrap_or_default(),
                first_token: ls.first_token,
                first_token_work: ls.first_token_work.unwrap_or_default(),
            });
        }
        // ---- admit into free lanes, fairness order ----
        let mut admitted: Vec<usize> = Vec::new();
        let mut bound: Vec<Option<Arc<dyn FactorSource>>> = Vec::new();
        'fill: for l in 0..lanes {
            if occ[l].is_some() {
                continue;
            }
            let (req, budget) = loop {
                let Some(r) = queue.pop_next() else { break 'fill };
                // expired or cancelled while queued: retire without
                // claiming a lane or paying any forward pass
                if let Some(outcome) = fault_outcome(r.deadline, r.cancel.as_ref(), clock.now()) {
                    queue.release(r.tenant);
                    count_outcome(&mut stats, outcome);
                    on_done(FinishedRequest {
                        id: r.id,
                        tenant: r.tenant,
                        outcome,
                        tokens: Vec::new(),
                        ttft: clock.now().duration_since(r.enqueued),
                        first_token: None,
                        first_token_work: stats.work_rows,
                    });
                    continue;
                }
                if r.prompt.is_empty() || r.prompt.len() >= cfg.seq_len {
                    bail!(
                        "run_continuous: inadmissible prompt length {} (seq_len {})",
                        r.prompt.len(),
                        cfg.seq_len
                    );
                }
                let budget = r.budget.min(cfg.seq_len - r.prompt.len());
                if budget == 0 {
                    // zero budget: completes instantly, no lane, no forward
                    queue.release(r.tenant);
                    stats.finished += 1;
                    on_done(FinishedRequest {
                        id: r.id,
                        tenant: r.tenant,
                        outcome: RequestOutcome::Done,
                        tokens: Vec::new(),
                        ttft: clock.now().duration_since(r.enqueued),
                        first_token: None,
                        first_token_work: stats.work_rows,
                    });
                    continue;
                }
                break (r, budget);
            };
            seqs[l].fill(TOKENS::PAD);
            seqs[l][..req.prompt.len()].copy_from_slice(&req.prompt);
            pos[l] = req.prompt.len();
            occ[l] = Some(LaneState {
                id: req.id,
                tenant: req.tenant,
                budget,
                generated: Vec::new(),
                enqueued: req.enqueued,
                ttft: None,
                first_token: None,
                first_token_work: None,
                deadline: req.deadline,
                cancel: req.cancel.clone(),
            });
            if cfg.prefill_chunk > 0 && req.prompt.len() > cfg.prefill_chunk {
                // long prompt: claim the lane now, stream the prefill in
                // `prefill_chunk`-row slices across the coming cycles
                chunking[l] = Some(Chunking { next: 0, adapter: req.adapter });
            } else {
                admitted.push(l);
                bound.push(req.adapter);
            }
        }
        if !admitted.is_empty() {
            let logits = stepper.admit(&seqs, &pos, &admitted, &bound)?;
            if logits.len() != lanes * cfg.vocab {
                bail!(
                    "run_continuous: admit returned {} logits, expected {}",
                    logits.len(),
                    lanes * cfg.vocab
                );
            }
            out.clear();
            out.extend_from_slice(logits);
            stats.admits += 1;
            stats.work_rows += admitted.iter().map(|&l| pos[l] as u64).sum::<u64>();
            for &l in &admitted {
                consume_row(
                    l,
                    &out[l * cfg.vocab..(l + 1) * cfg.vocab],
                    &mut seqs,
                    &mut pos,
                    &mut occ,
                    queue,
                    stepper,
                    clock,
                    cfg.seq_len,
                    &mut stats,
                    &mut on_done,
                );
            }
        }
        // ---- advance chunked prefills: one slice per lane per cycle ----
        for l in 0..lanes {
            let Some(ch) = chunking[l].as_mut() else { continue };
            let plen = pos[l]; // full prompt length (no tokens consumed yet)
            let start = ch.next;
            let len = cfg.prefill_chunk.min(plen - start);
            let last = start + len == plen;
            let adapter = if start == 0 { ch.adapter.take() } else { None };
            let logits = stepper.admit_chunk(&seqs, l, start, len, last, adapter)?;
            if logits.len() != lanes * cfg.vocab {
                bail!(
                    "run_continuous: admit_chunk returned {} logits, expected {}",
                    logits.len(),
                    lanes * cfg.vocab
                );
            }
            stats.admits += 1; // each chunk is one admission forward pass
            stats.work_rows += len as u64;
            if last {
                out.clear();
                out.extend_from_slice(logits);
                chunking[l] = None;
                consume_row(
                    l,
                    &out[l * cfg.vocab..(l + 1) * cfg.vocab],
                    &mut seqs,
                    &mut pos,
                    &mut occ,
                    queue,
                    stepper,
                    clock,
                    cfg.seq_len,
                    &mut stats,
                    &mut on_done,
                );
            } else {
                ch.next = start + len;
            }
        }
        stats.peak_lanes = stats.peak_lanes.max(occ.iter().filter(|o| o.is_some()).count());

        // steppable = occupied and not mid-chunk (a chunking lane has no
        // next token yet)
        let active: Vec<bool> =
            occ.iter().enumerate().map(|(l, o)| o.is_some() && chunking[l].is_none()).collect();
        if occ.iter().all(Option::is_none) {
            if queue.is_empty() {
                break;
            }
            continue; // everything finished at admission; admit more
        }
        if !active.iter().any(|&a| a) {
            continue; // only mid-chunk lanes live: keep their slices coming
        }
        // a lane freed during admission-consume: top occupancy back up
        // before paying a step
        if occ.iter().any(Option::is_none) && !queue.is_empty() {
            continue;
        }
        // ---- step every live lane ----
        let logits = stepper.step(&seqs, &pos, &active)?;
        if logits.len() != lanes * cfg.vocab {
            bail!(
                "run_continuous: step returned {} logits, expected {}",
                logits.len(),
                lanes * cfg.vocab
            );
        }
        out.clear();
        out.extend_from_slice(logits);
        stats.decode_steps += 1;
        stats.work_rows += active.iter().filter(|&&a| a).count() as u64;
        for (l, &a) in active.iter().enumerate() {
            if !a {
                continue;
            }
            consume_row(
                l,
                &out[l * cfg.vocab..(l + 1) * cfg.vocab],
                &mut seqs,
                &mut pos,
                &mut occ,
                queue,
                stepper,
                clock,
                cfg.seq_len,
                &mut stats,
                &mut on_done,
            );
        }
    }
    Ok(stats)
}

/// The production continuous stepper: a heterogeneous multi-tenant
/// session over one engine + weight set, with per-lane factor-form
/// adapters bound **into the session** at admission. The [`DecodeState`]
/// lives in a caller-owned slot, so its KV cache and scratch arena
/// persist across sessions (one allocation per worker, not per batch).
///
/// Adapter plumbing: each admitted lane's `Arc<dyn FactorSource>` is
/// handed to [`DecodeState::bind_adapter`] once (shape-validated at bind
/// time); every subsequent step resolves sites straight from the bound
/// sources via `FactorSource::site`. This retires the factor path's old
/// known cost — a borrowed `QFactors` view over an `Arc` this stepper
/// owned couldn't be cached across calls in safe Rust, so steps with any
/// bound adapter used to rebuild every lane's site map per call.
pub struct SessionStepper<'a> {
    engine: &'a Engine,
    prog: &'a str,
    weights: &'a DeviceWeights,
    slot: &'a mut Option<DecodeState>,
    /// Reusable newest-token buffer.
    last: Vec<i32>,
}

impl<'a> SessionStepper<'a> {
    pub fn new(
        engine: &'a Engine,
        prog: &'a str,
        weights: &'a DeviceWeights,
        slot: &'a mut Option<DecodeState>,
    ) -> Self {
        Self { engine, prog, weights, slot, last: Vec::new() }
    }

    /// Resident KV bytes of the live session.
    pub fn kv_bytes(&self) -> Option<usize> {
        self.slot.as_ref().map(DecodeState::kv_bytes)
    }
}

impl DecodeStep for SessionStepper<'_> {
    fn prefill(&mut self, _seqs: &[Vec<i32>], _pos: &[usize]) -> anyhow::Result<&[f32]> {
        bail!("continuous sessions begin empty — drive begin/admit, not prefill")
    }

    fn begin(&mut self, lanes: usize) -> anyhow::Result<()> {
        match self.slot.as_mut() {
            // warm slot of the right shape: keep the allocations, drop
            // the previous group's lane state (reset also unbinds every
            // lane's adapter source)
            Some(state) if state.lanes() == lanes && state.program() == self.prog => {
                state.reset();
            }
            _ => *self.slot = Some(self.engine.new_session(self.prog, lanes, self.weights)?),
        }
        Ok(())
    }

    fn admit(
        &mut self,
        seqs: &[Vec<i32>],
        pos: &[usize],
        lanes: &[usize],
        adapters: &[Option<Arc<dyn FactorSource>>],
    ) -> anyhow::Result<&[f32]> {
        if adapters.len() != lanes.len() {
            bail!("admit: {} adapters for {} lanes", adapters.len(), lanes.len());
        }
        let state = self.slot.as_mut().context("admit before begin")?;
        // bind once per admission; steps resolve sites from the sources
        for (&l, ad) in lanes.iter().zip(adapters) {
            state.bind_adapter(l, ad.clone())?;
        }
        let prompts: Vec<&[i32]> = lanes.iter().map(|&l| &seqs[l][..pos[l]]).collect();
        self.engine.admit(state, lanes, &prompts, self.weights, &[])
    }

    fn admit_chunk(
        &mut self,
        seqs: &[Vec<i32>],
        lane: usize,
        start: usize,
        len: usize,
        last: bool,
        adapter: Option<Arc<dyn FactorSource>>,
    ) -> anyhow::Result<&[f32]> {
        let state = self.slot.as_mut().context("admit_chunk before begin")?;
        if start == 0 {
            // bind (or clear a stale binding) once, at the first chunk
            state.bind_adapter(lane, adapter)?;
        }
        let chunk = &seqs[lane][start..start + len];
        self.engine.prefill_chunk(state, lane, chunk, start, last, self.weights, &[])
    }

    fn step(
        &mut self,
        seqs: &[Vec<i32>],
        pos: &[usize],
        active: &[bool],
    ) -> anyhow::Result<&[f32]> {
        let state = self.slot.as_mut().context("step before begin")?;
        self.last.clear();
        for k in 0..seqs.len() {
            self.last.push(if pos[k] == 0 { 0 } else { seqs[k][pos[k] - 1] });
        }
        for (k, &a) in active.iter().enumerate() {
            if !a && !state.is_retired(k) {
                state.retire(k);
            }
        }
        self.engine.decode_step(state, self.weights, &[], &self.last)
    }

    fn retire(&mut self, lane: usize) {
        if let Some(state) = self.slot.as_mut() {
            if !state.is_retired(lane) {
                state.retire(lane);
            }
            if lane < state.lanes() {
                // in-range unbind with `None` cannot fail
                let _ = state.bind_adapter(lane, None);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::decode::{decode_lockstep, EngineStepper};
    use crate::model::{merge_adapter, BaseWeights, ModelConfig};
    use crate::testutil::synth::{synth_model_config, write_synth_model};
    use std::path::PathBuf;

    fn fixture(tag: &str) -> (PathBuf, ModelConfig, Engine, DeviceWeights) {
        let dir = std::env::temp_dir().join(format!("lq_loop_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = synth_model_config();
        write_synth_model(&dir, "synth", &cfg, &[4], 91).unwrap();
        let base = BaseWeights::load(dir.join("synth")).unwrap();
        let mut engine = Engine::new(&dir).unwrap();
        engine.load_model_fwd("synth", 4, base.cfg.param_names().len()).unwrap();
        let w = engine
            .upload_weights(&merge_adapter(&base, &std::collections::BTreeMap::new()).unwrap())
            .unwrap();
        (dir, cfg, engine, w)
    }

    fn req(id: u64, tenant: AdapterId, prompt: Vec<i32>, budget: usize) -> LaneRequest {
        LaneRequest {
            id,
            tenant,
            prompt,
            budget,
            adapter: None,
            enqueued: Instant::now(),
            deadline: None,
            cancel: None,
        }
    }

    /// Lock-step oracle for one request alone (per-lane independence
    /// makes this the exact expected output for any lane composition).
    fn solo(engine: &Engine, cfg: &ModelConfig, w: &DeviceWeights, prompt: &[i32], budget: usize)
        -> Vec<i32> {
        let mut seqs = vec![vec![TOKENS::PAD; cfg.seq_len]];
        seqs[0][..prompt.len()].copy_from_slice(prompt);
        let mut pos = vec![prompt.len()];
        let mut stepper = EngineStepper::new(engine, "synth/b4", w, &[]);
        decode_lockstep(cfg.seq_len, cfg.vocab, &mut seqs, &mut pos, &[budget], &mut stepper)
            .unwrap()
            .remove(0)
    }

    #[test]
    fn continuous_tokens_match_solo_lockstep_and_lanes_are_reused() {
        let (dir, cfg, engine, w) = fixture("oracle");
        let clock = Clock::real();
        let prompts: Vec<Vec<i32>> =
            (0..5).map(|i| vec![1 + i as i32, 4, 2 + i as i32]).collect();
        let budgets = [4usize, 1, 3, 2, 5];
        let mut queue = AdmissionQueue::new();
        for (i, p) in prompts.iter().enumerate() {
            queue.push(req(i as u64, 0, p.clone(), budgets[i]));
        }
        let mut slot = None;
        let mut stepper = SessionStepper::new(&engine, "synth/b4", &w, &mut slot);
        let ccfg = ContinuousConfig { lanes: 2, seq_len: cfg.seq_len, vocab: cfg.vocab, prefill_chunk: 0 };
        let mut got: Vec<Option<Vec<i32>>> = vec![None; prompts.len()];
        let stats = run_continuous(&mut stepper, &ccfg, &mut queue, &clock, |fin| {
            got[fin.id as usize] = Some(fin.tokens);
        })
        .unwrap();
        assert_eq!(stats.finished, 5);
        // peak is sampled post-consume, so instant finishers (budget 1 /
        // early EOS) can keep it below the lane count — bound it instead
        assert!((1..=2).contains(&stats.peak_lanes), "peak {}", stats.peak_lanes);
        assert!(stats.admits >= 3, "5 requests through 2 lanes need ≥ 3 admit waves");
        for (i, p) in prompts.iter().enumerate() {
            let want = solo(&engine, &cfg, &w, p, budgets[i]);
            assert_eq!(got[i].as_deref(), Some(&want[..]), "request {i}");
        }
        assert!(slot.is_some(), "the session slot survives for the next group");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn session_slot_is_reused_across_groups() {
        let (dir, cfg, engine, w) = fixture("reuse");
        let clock = Clock::real();
        let ccfg = ContinuousConfig { lanes: 2, seq_len: cfg.seq_len, vocab: cfg.vocab, prefill_chunk: 0 };
        let mut slot = None;
        for group in 0..3u64 {
            let mut queue = AdmissionQueue::new();
            queue.push(req(group, 0, vec![1, 2, 3], 2));
            let mut stepper = SessionStepper::new(&engine, "synth/b4", &w, &mut slot);
            let mut done = 0;
            run_continuous(&mut stepper, &ccfg, &mut queue, &clock, |_| done += 1).unwrap();
            assert_eq!(done, 1, "group {group}");
        }
        // three groups, one session allocation: tokens of every group
        // match the solo oracle (checked above); here we pin slot reuse
        assert!(slot.is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn zero_budget_requests_finish_without_a_lane() {
        let (dir, cfg, engine, w) = fixture("zero");
        let clock = Clock::real();
        let mut queue = AdmissionQueue::new();
        queue.push(req(0, 0, vec![1, 2], 0));
        // a full-prompt request has zero room — also completes instantly
        queue.push(req(1, 0, vec![1; cfg.seq_len - 1], 0));
        let mut slot = None;
        let mut stepper = SessionStepper::new(&engine, "synth/b4", &w, &mut slot);
        let ccfg = ContinuousConfig { lanes: 2, seq_len: cfg.seq_len, vocab: cfg.vocab, prefill_chunk: 0 };
        let mut done = Vec::new();
        let stats = run_continuous(&mut stepper, &ccfg, &mut queue, &clock, |fin| {
            done.push((fin.id, fin.tokens.clone()));
        })
        .unwrap();
        assert_eq!(stats.finished, 2);
        assert_eq!((stats.admits, stats.decode_steps), (0, 0), "no forward may run");
        assert!(done.iter().all(|(_, t)| t.is_empty()));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn scarce_lanes_interleave_tenants_fairly() {
        let (dir, cfg, engine, w) = fixture("fair");
        let clock = Clock::real();
        let mut queue = AdmissionQueue::new();
        // tenants 1 and 2, three requests each, all queued up front
        for i in 0..3u64 {
            queue.push(req(i, 1, vec![1, 2], 1));
            queue.push(req(10 + i, 2, vec![1, 3], 1));
        }
        let mut slot = None;
        let mut stepper = SessionStepper::new(&engine, "synth/b4", &w, &mut slot);
        let ccfg = ContinuousConfig { lanes: 1, seq_len: cfg.seq_len, vocab: cfg.vocab, prefill_chunk: 0 };
        let mut order = Vec::new();
        run_continuous(&mut stepper, &ccfg, &mut queue, &clock, |fin| order.push(fin.tenant))
            .unwrap();
        assert_eq!(order, vec![1, 2, 1, 2, 1, 2], "token charges must alternate the tenants");
        assert!(queue.spent(1) >= 3 && queue.spent(2) >= 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn expired_request_times_out_and_survivors_match_the_oracle() {
        let (dir, cfg, engine, w) = fixture("deadline");
        let clock = Clock::real();
        let mut queue = AdmissionQueue::new();
        // request 0 is already past its deadline when the loop starts;
        // request 1 is unconstrained and must be byte-identical to its
        // solo lock-step run despite the neighbor's early retirement
        let mut dead = req(0, 1, vec![1, 2, 3], 4);
        dead.deadline = Some(Instant::now());
        queue.push(dead);
        queue.push(req(1, 2, vec![2, 4, 6], 3));
        let mut slot = None;
        let mut stepper = SessionStepper::new(&engine, "synth/b4", &w, &mut slot);
        let ccfg =
            ContinuousConfig { lanes: 2, seq_len: cfg.seq_len, vocab: cfg.vocab, prefill_chunk: 0 };
        let mut fins: Vec<FinishedRequest> = Vec::new();
        let stats =
            run_continuous(&mut stepper, &ccfg, &mut queue, &clock, |fin| fins.push(fin)).unwrap();
        assert_eq!((stats.finished, stats.timeouts, stats.cancellations), (1, 1, 0));
        let timed_out = fins.iter().find(|f| f.id == 0).unwrap();
        assert_eq!(timed_out.outcome, RequestOutcome::Timeout);
        assert!(timed_out.tokens.is_empty(), "expired in queue: no lane, no tokens");
        let survivor = fins.iter().find(|f| f.id == 1).unwrap();
        assert_eq!(survivor.outcome, RequestOutcome::Done);
        assert_eq!(survivor.tokens, solo(&engine, &cfg, &w, &[2, 4, 6], 3));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cancel_token_retires_a_lane_mid_decode_keeping_partial_tokens() {
        let (dir, cfg, engine, w) = fixture("cancel");
        let clock = Clock::real();
        let oracle = solo(&engine, &cfg, &w, &[1, 2, 3], 6);
        assert!(oracle.len() >= 2, "fixture must decode several tokens for the test to bite");
        let token = Arc::new(AtomicBool::new(false));
        let mut queue = AdmissionQueue::new();
        let mut victim = req(0, 1, vec![1, 2, 3], 6);
        victim.cancel = Some(token.clone());
        queue.push(victim);
        // the trigger request: budget 1, so it finishes in the admission
        // wave; its completion callback flips the victim's cancel token —
        // a deterministic mid-decode cancellation point
        queue.push(req(1, 2, vec![2, 4], 1));
        let mut slot = None;
        let mut stepper = SessionStepper::new(&engine, "synth/b4", &w, &mut slot);
        let ccfg =
            ContinuousConfig { lanes: 2, seq_len: cfg.seq_len, vocab: cfg.vocab, prefill_chunk: 0 };
        let mut fins: Vec<FinishedRequest> = Vec::new();
        let stats = run_continuous(&mut stepper, &ccfg, &mut queue, &clock, |fin| {
            if fin.id == 1 {
                token.store(true, Ordering::Relaxed);
            }
            fins.push(fin);
        })
        .unwrap();
        assert_eq!((stats.finished, stats.timeouts, stats.cancellations), (1, 0, 1));
        let cancelled = fins.iter().find(|f| f.id == 0).unwrap();
        assert_eq!(cancelled.outcome, RequestOutcome::Cancelled);
        assert!(
            !cancelled.tokens.is_empty() && cancelled.tokens.len() < oracle.len(),
            "cancelled mid-decode: {} of {} tokens",
            cancelled.tokens.len(),
            oracle.len()
        );
        assert_eq!(
            cancelled.tokens[..],
            oracle[..cancelled.tokens.len()],
            "partial tokens are a prefix of the uncancelled oracle"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// One run of the mixed long + short workload at a given chunk size,
    /// returning `(tokens, first_token_work)` per request id plus stats.
    fn ragged_run(
        engine: &Engine,
        cfg: &ModelConfig,
        w: &DeviceWeights,
        chunk: usize,
    ) -> (Vec<(Vec<i32>, u64)>, LoopStats) {
        let clock = Clock::real();
        let mut queue = AdmissionQueue::new();
        // a long prompt first, then short requests stuck behind it
        queue.push(req(0, 0, vec![1, 2, 3, 4, 5, 6, 7, 8, 1, 2], 3));
        queue.push(req(1, 1, vec![2, 4, 6], 2));
        queue.push(req(2, 2, vec![3, 5], 2));
        queue.push(req(3, 3, vec![4, 1, 2], 2));
        let mut slot = None;
        let mut stepper = SessionStepper::new(engine, "synth/b4", w, &mut slot);
        let ccfg = ContinuousConfig {
            lanes: 2,
            seq_len: cfg.seq_len,
            vocab: cfg.vocab,
            prefill_chunk: chunk,
        };
        let mut got = vec![(Vec::new(), 0u64); 4];
        let stats = run_continuous(&mut stepper, &ccfg, &mut queue, &clock, |fin| {
            got[fin.id as usize] = (fin.tokens, fin.first_token_work);
        })
        .unwrap();
        assert_eq!(stats.finished, 4, "chunk={chunk}");
        (got, stats)
    }

    #[test]
    fn chunked_prefill_matches_monolithic_and_unblocks_short_requests() {
        let (dir, cfg, engine, w) = fixture("chunked");
        let (mono, mono_stats) = ragged_run(&engine, &cfg, &w, 0);
        for chunk in [1usize, 2, 3, 64] {
            let (got, stats) = ragged_run(&engine, &cfg, &w, chunk);
            for id in 0..4 {
                assert_eq!(got[id].0, mono[id].0, "chunk={chunk} request {id}: tokens");
            }
            // every generated token costs exactly one forward row on both
            // paths (prompt rows + one step row per later token), so the
            // total work clock is invariant under chunking
            assert_eq!(stats.work_rows, mono_stats.work_rows, "chunk={chunk}");
            if chunk < 10 {
                // the short request behind the long prompt sees first
                // output after strictly less computed work: it admits and
                // decodes while the long prompt is still chunking in
                assert!(
                    got[1].1 < mono[1].1,
                    "chunk={chunk}: short-request TTFT work {} must beat monolithic {}",
                    got[1].1,
                    mono[1].1
                );
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
