//! Admission queue for the continuous-batching scheduler: per-tenant
//! FIFOs drained under **token-budget fair scheduling** (DESIGN.md §11).
//!
//! Every decode-step row a tenant consumes is charged to its lifetime
//! `spent` counter; when a lane frees, the next admission comes from the
//! tenant with the **least spent tokens** (ties broken by oldest queued
//! request, then tenant id — fully deterministic). A tenant that goes
//! idle banks no credit: on re-arrival its counter is floored to the
//! queue's **watermark** — the fairness frontier, advanced at every
//! admission (to the granted tenant's spent) and at every lane release
//! (to the minimum spent over tenants still queued or in service; to
//! the releaser's own spent when it was the last one) — so a returning
//! or brand-new tenant competes from the frontier instead of
//! monopolizing every freed lane. Because the floor consults only the
//! monotone watermark, it does not depend on the order a group's
//! requests are pushed in.
//!
//! Admission is **preemption-free**: once a request holds a lane it runs
//! to completion; fairness only decides who gets each freed slot.

use crate::coordinator::registry::AdapterId;
use crate::loraquant::FactorSource;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Instant;

/// One request waiting for (or holding) a decode lane.
pub struct LaneRequest {
    /// Caller-side handle (e.g. index into the submitting group).
    pub id: u64,
    pub tenant: AdapterId,
    /// Unpadded prompt tokens (non-empty, shorter than `seq_len`).
    pub prompt: Vec<i32>,
    /// Max new tokens (clamped to sequence room at admission).
    pub budget: usize,
    /// Factor-form adapter bound to this request's lane for its whole
    /// occupancy (`None` = the session's weights already carry it).
    pub adapter: Option<Arc<dyn FactorSource>>,
    /// Submission instant (TTFT accounting; scenario clock or real).
    pub enqueued: Instant,
    /// Absolute deadline: past it the request retires with a `Timeout`
    /// outcome instead of decoding further (checked at admission and
    /// between decode steps; DESIGN.md §15).
    pub deadline: Option<Instant>,
    /// Cooperative cancellation token: when set to `true` the request
    /// retires with a `Cancelled` outcome at the next lane scan,
    /// keeping whatever tokens it already generated.
    pub cancel: Option<Arc<AtomicBool>>,
}

impl std::fmt::Debug for LaneRequest {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LaneRequest")
            .field("id", &self.id)
            .field("tenant", &self.tenant)
            .field("prompt_len", &self.prompt.len())
            .field("budget", &self.budget)
            .field("adapter", &self.adapter.is_some())
            .field("deadline", &self.deadline.is_some())
            .finish()
    }
}

/// The fair admission queue. Plain data, driven by the engine loop —
/// fully unit-testable without an engine.
#[derive(Default)]
pub struct AdmissionQueue {
    /// Per-tenant FIFO of `(arrival_seq, request)`.
    queues: BTreeMap<AdapterId, VecDeque<(u64, LaneRequest)>>,
    /// Lifetime decode-token charge per tenant (the fairness currency).
    spent: BTreeMap<AdapterId, u64>,
    /// Monotone fairness-frontier watermark (see module docs).
    /// Newly-arriving tenants floor to it; it survives fully-drained
    /// queues and is independent of intra-group push order.
    watermark: u64,
    /// Lanes currently held per tenant (popped, not yet released).
    in_service: BTreeMap<AdapterId, usize>,
    /// Monotone arrival stamp for FIFO tie-breaks across tenants.
    arrivals: u64,
    pending: usize,
    /// Load-shed depth cap: [`AdmissionQueue::try_push`] refuses new
    /// work once `pending()` reaches it (`None` = unbounded).
    depth_cap: Option<usize>,
}

impl AdmissionQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Set (or clear) the load-shed depth cap consulted by
    /// [`AdmissionQueue::try_push`]. In-service lanes don't count —
    /// only not-yet-admitted requests.
    pub fn set_depth_cap(&mut self, cap: Option<usize>) {
        self.depth_cap = cap;
    }

    pub fn depth_cap(&self) -> Option<usize> {
        self.depth_cap
    }

    /// Enqueue unless the depth cap is reached, in which case the
    /// request is handed back untouched so the caller can answer
    /// `Overloaded` (HTTP-429 semantics; DESIGN.md §15). Fairness
    /// counters are not perturbed by a shed.
    pub fn try_push(&mut self, req: LaneRequest) -> Result<(), LaneRequest> {
        if let Some(cap) = self.depth_cap {
            if self.pending >= cap {
                return Err(req);
            }
        }
        self.push(req);
        Ok(())
    }

    /// Enqueue a request. A tenant whose queue was empty re-enters at the
    /// admission watermark / active spending floor (see module docs).
    pub fn push(&mut self, req: LaneRequest) {
        let tenant = req.tenant;
        if self.queues.get(&tenant).is_none_or(VecDeque::is_empty) {
            let entry = self.spent.entry(tenant).or_insert(0);
            *entry = (*entry).max(self.watermark);
        }
        let seq = self.arrivals;
        self.arrivals += 1;
        self.queues.entry(tenant).or_default().push_back((seq, req));
        self.pending += 1;
    }

    /// Queued (not yet admitted) requests.
    pub fn pending(&self) -> usize {
        self.pending
    }

    pub fn is_empty(&self) -> bool {
        self.pending == 0
    }

    /// Tokens charged to `tenant` so far.
    pub fn spent(&self, tenant: AdapterId) -> u64 {
        self.spent.get(&tenant).copied().unwrap_or(0)
    }

    /// Charge `tokens` decode-step rows to `tenant`.
    pub fn charge(&mut self, tenant: AdapterId, tokens: u64) {
        *self.spent.entry(tenant).or_insert(0) += tokens;
    }

    /// Pop the next admission: the head request of the least-spent tenant
    /// (ties: oldest head arrival, then tenant id). Deterministic for a
    /// given push/charge history. Advances the watermark to the granted
    /// tenant's spent level and marks a lane in service for it (pair
    /// every pop with a [`AdmissionQueue::release`] when the request
    /// finishes).
    pub fn pop_next(&mut self) -> Option<LaneRequest> {
        let tenant = self
            .queues
            .iter()
            .filter(|(_, q)| !q.is_empty())
            .min_by_key(|(id, q)| {
                let head_seq = q.front().map(|(s, _)| *s).unwrap_or(u64::MAX);
                (self.spent.get(id).copied().unwrap_or(0), head_seq, **id)
            })
            .map(|(&id, _)| id)?;
        self.watermark = self.watermark.max(self.spent.get(&tenant).copied().unwrap_or(0));
        *self.in_service.entry(tenant).or_insert(0) += 1;
        let q = self.queues.get_mut(&tenant).expect("selected tenant has a queue");
        let (_, req) = q.pop_front().expect("selected tenant queue non-empty");
        if q.is_empty() {
            self.queues.remove(&tenant);
        }
        self.pending -= 1;
        Some(req)
    }

    /// A popped request finished (or was abandoned): release its lane.
    /// Advances the watermark to the new fairness frontier — the minimum
    /// spent over tenants still queued or in service, or the releaser's
    /// own spent when it was the last active tenant — so a later
    /// arrival's floor reflects everything consumed so far.
    pub fn release(&mut self, tenant: AdapterId) {
        if let Some(n) = self.in_service.get_mut(&tenant) {
            *n -= 1;
            if *n == 0 {
                self.in_service.remove(&tenant);
            }
        }
        let frontier = self
            .in_service
            .keys()
            .chain(self.queues.iter().filter(|(_, q)| !q.is_empty()).map(|(id, _)| id))
            .map(|id| self.spent.get(id).copied().unwrap_or(0))
            .min()
            .unwrap_or_else(|| self.spent.get(&tenant).copied().unwrap_or(0));
        self.watermark = self.watermark.max(frontier);
    }

    /// Drain everything still queued (error recovery: a failed session
    /// must answer its not-yet-admitted requests too). Fairness counters
    /// survive; in-service bookkeeping resets (the session is gone).
    pub fn drain_pending(&mut self) -> Vec<LaneRequest> {
        let mut out = Vec::with_capacity(self.pending);
        while let Some(req) = self.pop_next() {
            out.push(req);
        }
        self.in_service.clear();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, tenant: AdapterId) -> LaneRequest {
        LaneRequest {
            id,
            tenant,
            prompt: vec![1, 2, 3],
            budget: 4,
            adapter: None,
            enqueued: Instant::now(),
            deadline: None,
            cancel: None,
        }
    }

    #[test]
    fn fifo_within_a_tenant() {
        let mut q = AdmissionQueue::new();
        for i in 0..4 {
            q.push(req(i, 7));
        }
        assert_eq!(q.pending(), 4);
        let ids: Vec<u64> = std::iter::from_fn(|| q.pop_next().map(|r| r.id)).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        assert!(q.is_empty());
    }

    #[test]
    fn least_spent_tenant_admits_first() {
        let mut q = AdmissionQueue::new();
        q.push(req(0, 1));
        q.push(req(1, 2));
        q.charge(1, 100);
        // tenant 2 has spent nothing — it must win the freed lane
        assert_eq!(q.pop_next().unwrap().tenant, 2);
        assert_eq!(q.pop_next().unwrap().tenant, 1);
    }

    #[test]
    fn arrival_order_breaks_spending_ties() {
        let mut q = AdmissionQueue::new();
        q.push(req(0, 9)); // same spent (0), older arrival
        q.push(req(1, 3));
        assert_eq!(q.pop_next().unwrap().tenant, 9, "oldest head wins the tie");
        assert_eq!(q.pop_next().unwrap().tenant, 3);
    }

    #[test]
    fn charges_interleave_admissions_fairly() {
        // two tenants, four requests each; charging the admitted tenant
        // makes pops alternate instead of draining one tenant first
        let mut q = AdmissionQueue::new();
        for i in 0..4 {
            q.push(req(i, 1));
            q.push(req(10 + i, 2));
        }
        let mut order = Vec::new();
        while let Some(r) = q.pop_next() {
            order.push(r.tenant);
            q.charge(r.tenant, 5);
        }
        assert_eq!(order, vec![1, 2, 1, 2, 1, 2, 1, 2]);
    }

    #[test]
    fn idle_tenant_banks_no_credit() {
        let mut q = AdmissionQueue::new();
        // tenant 1 works (spends), tenant 2 idles the whole time
        q.push(req(0, 1));
        let r = q.pop_next().unwrap();
        q.charge(r.tenant, 50);
        q.release(r.tenant); // last active tenant: watermark → 50
        // both arrive again: tenant 2's counter floors to the watermark
        // (50), so it does not sweep every freed lane
        q.push(req(1, 1));
        q.push(req(2, 2));
        assert_eq!(q.spent(2), 50, "arriving tenant enters at the watermark");
        // tie at 50 → arrival order decides
        assert_eq!(q.pop_next().unwrap().tenant, 1);
    }

    #[test]
    fn newcomer_floors_to_the_watermark_regardless_of_push_order() {
        // Group 1: tenant 1 works alone, consuming 20 tokens over two
        // requests; releasing the last lane advances the watermark to its
        // full spend. Group 2 then pushes a brand-new tenant either side
        // of tenant 1's next request — the newcomer's floor must be the
        // watermark (20) in BOTH orders; with the old min-over-queued
        // floor it entered at 0 when pushed first and the heavy spender's
        // level when pushed second.
        let run = |new_tenant_first: bool| {
            let mut q = AdmissionQueue::new();
            q.push(req(0, 1));
            q.push(req(1, 1));
            for _ in 0..2 {
                let r = q.pop_next().unwrap();
                q.charge(r.tenant, 10);
                q.release(r.tenant);
            }
            // group 2: tenants 1 (spent 20) and 9 (new)
            if new_tenant_first {
                q.push(req(2, 9));
                q.push(req(3, 1));
            } else {
                q.push(req(3, 1));
                q.push(req(2, 9));
            }
            q.spent(9)
        };
        assert_eq!(run(true), 20, "newcomer pushed first floors to the watermark");
        assert_eq!(run(false), 20, "newcomer pushed second floors identically");
    }

    #[test]
    fn drain_pending_empties_in_fair_order() {
        let mut q = AdmissionQueue::new();
        q.push(req(0, 4));
        q.push(req(1, 2));
        q.charge(4, 9);
        let drained = q.drain_pending();
        assert_eq!(drained.len(), 2);
        assert_eq!(drained[0].tenant, 2);
        assert!(q.is_empty());
        assert_eq!(q.spent(4), 9, "fairness counters survive a drain");
    }

    #[test]
    fn depth_cap_sheds_without_touching_fairness() {
        let mut q = AdmissionQueue::new();
        q.set_depth_cap(Some(2));
        assert!(q.try_push(req(0, 1)).is_ok());
        assert!(q.try_push(req(1, 2)).is_ok());
        let shed = q.try_push(req(2, 3)).expect_err("cap reached: request comes back");
        assert_eq!(shed.id, 2);
        assert_eq!(q.pending(), 2);
        assert_eq!(q.spent(3), 0, "a shed tenant is never floored to the watermark");
        // admitting one request frees queue depth (in-service lanes
        // don't count against the cap)
        let r = q.pop_next().unwrap();
        assert!(q.try_push(req(3, 3)).is_ok());
        q.release(r.tenant);
        // uncapped queues never shed
        q.set_depth_cap(None);
        for i in 0..16 {
            assert!(q.try_push(req(10 + i, 4)).is_ok());
        }
    }

    #[test]
    fn pop_on_empty_is_none_and_deterministic_iteration() {
        let mut q = AdmissionQueue::new();
        assert!(q.pop_next().is_none());
        // determinism smoke: same push/charge history → same pop order
        let run = |charges: &[(AdapterId, u64)]| {
            let mut q = AdmissionQueue::new();
            for i in 0..6 {
                q.push(req(i, (i % 3) as AdapterId));
            }
            for &(t, c) in charges {
                q.charge(t, c);
            }
            std::iter::from_fn(|| q.pop_next().map(|r| (r.tenant, r.id))).collect::<Vec<_>>()
        };
        assert_eq!(run(&[(0, 3), (1, 1)]), run(&[(0, 3), (1, 1)]));
    }
}
