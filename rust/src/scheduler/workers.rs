//! The persistent per-engine compute pool — a work-stealing task
//! executor (DESIGN.md §11, §13).
//!
//! `tensor::ops::matmul_flat_threaded` partitions output rows across a
//! fresh `std::thread::scope` on **every call** — ~6L+1 spawn/join
//! barriers per prefill — which on small models can cost more than the
//! parallelism buys (the old §10 crossover). [`ComputePool`] replaces
//! that with `threads - 1` long-lived workers parked on a condvar: a
//! partitioned kernel call is two lock/notify handshakes instead of a
//! round of OS thread spawns, so the decode *step* path (tiny row
//! counts, called once per generated token) can afford to be partitioned
//! too.
//!
//! Task distribution is work-stealing (the databend `PipelineExecutor`
//! shape): a [`ComputePool::run`] call seeds every task index into a
//! **global injector queue**; each thread keeps a **local deque**, pops
//! work from its own front, refills in batches from the injector, and
//! when both run dry **steals one task from the back of a sibling's
//! deque** before parking. Under ragged per-task costs (heterogeneous
//! factor groups, chunked prefill slices next to one-row decode tasks)
//! a thread that finishes early drains the stragglers' backlogs instead
//! of idling at the barrier.
//!
//! Determinism contract: the pool never changes results. Every task of a
//! [`ComputePool::run`] call computes a fixed, disjoint output partition
//! with the identical serial kernel, so which worker claims which task —
//! the only scheduling freedom steal order adds — cannot affect a single
//! output bit. `threads = 1` (or a single task) degenerates to a plain
//! serial call on the caller's thread.
//!
//! Fault contract (DESIGN.md §15): a panicking task is *contained* — it
//! counts as completed for the park-gate/quiescence accounting, every
//! sibling task still runs, the worker threads survive, and
//! [`ComputePool::run`] returns a structured `Err` carrying the lowest
//! panicking task's payload instead of re-raising. One poisoned request
//! group fails; the engine and the process do not.

use crate::tensor::{matmul_flat, matmul_flat_rows};
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

/// A broadcast job: a lifetime-erased pointer to the caller's task
/// closure plus the task count. [`ComputePool::run`] blocks until every
/// task has completed, so the pointee strictly outlives every use.
struct Job {
    f: *const (dyn Fn(usize) + Sync),
    tasks: usize,
}

// Safety: the pointer is only dereferenced between job publication and
// the completion of the last task, a window the publishing `run` call
// spans while holding the closure alive; the pointee is `Sync`, so
// shared calls from several workers are sound.
unsafe impl Send for Job {}

#[derive(Default)]
struct PoolState {
    job: Option<Job>,
    /// Tasks claimed but not yet completed, plus tasks never claimed.
    remaining: usize,
    /// Lowest-task-index panic of the current job, with its payload.
    /// `run` reports it as a structured `Err` instead of re-raising; the
    /// park-gate accounting treats a panicked task as completed, so the
    /// quiescence barrier still drains.
    panic: Option<(usize, String)>,
    shutdown: bool,
}

/// Render a `catch_unwind` payload as text (panics carry `String` or
/// `&'static str` in practice). Shared with the merge pool's
/// panic-containment path.
pub(crate) fn payload_str(p: Box<dyn std::any::Any + Send>) -> String {
    match p.downcast::<String>() {
        Ok(s) => *s,
        Err(p) => match p.downcast::<&'static str>() {
            Ok(s) => (*s).to_string(),
            Err(_) => "<non-string panic payload>".into(),
        },
    }
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Wakes parked workers when a job lands (or on shutdown).
    work: Condvar,
    /// Wakes the caller when the last task completes.
    done: Condvar,
    /// Tasks sitting in some queue (injector or a local deque), not yet
    /// claimed for execution. The park gate: a worker only blocks on
    /// `work` after observing `unclaimed == 0` **under the state mutex**,
    /// and `run` publishes the 0 → `tasks` transition under the same
    /// mutex, so a wakeup can never be missed. During a job the counter
    /// only decreases (one `fetch_sub` per claim), so it can transiently
    /// read positive while a batch refill is in flight between queue
    /// locks — scanners treat that as "work exists somewhere" and rescan
    /// after a yield instead of parking.
    unclaimed: AtomicUsize,
    /// Global injector: `run` seeds all task indices here.
    injector: Mutex<VecDeque<usize>>,
    /// Per-thread local deques; slot 0 belongs to the calling thread,
    /// slots `1..threads` to the spawned workers. Owners pop from the
    /// front, thieves steal from the back.
    locals: Vec<Mutex<VecDeque<usize>>>,
}

fn lock(shared: &PoolShared) -> MutexGuard<'_, PoolState> {
    // poisoning is handled explicitly via `panicked`
    shared.state.lock().unwrap_or_else(|e| e.into_inner())
}

fn lockq(q: &Mutex<VecDeque<usize>>) -> MutexGuard<'_, VecDeque<usize>> {
    q.lock().unwrap_or_else(|e| e.into_inner())
}

/// Claim one task for thread `me`: own deque front, else a batch refill
/// from the injector (first task returned, the rest parked in `me`'s
/// local for siblings to steal), else one task stolen from the back of
/// a sibling's deque, scanned in a fixed ring order from `me`.
/// Decrements `unclaimed` exactly once per returned task.
fn try_claim(shared: &PoolShared, me: usize) -> Option<usize> {
    if let Some(t) = lockq(&shared.locals[me]).pop_front() {
        shared.unclaimed.fetch_sub(1, Ordering::AcqRel);
        return Some(t);
    }
    let batch: Vec<usize> = {
        let mut inj = lockq(&shared.injector);
        let take = (inj.len() / shared.locals.len()).clamp(1, 16).min(inj.len());
        inj.drain(..take).collect()
    };
    if let Some((&first, rest)) = batch.split_first() {
        if !rest.is_empty() {
            lockq(&shared.locals[me]).extend(rest.iter().copied());
        }
        shared.unclaimed.fetch_sub(1, Ordering::AcqRel);
        return Some(first);
    }
    let n = shared.locals.len();
    for off in 1..n {
        if let Some(t) = lockq(&shared.locals[(me + off) % n]).pop_back() {
            shared.unclaimed.fetch_sub(1, Ordering::AcqRel);
            return Some(t);
        }
    }
    None
}

/// A persistent pool of `threads - 1` compute workers plus the calling
/// thread. Owned by one engine; `run` is not reentrant and must be
/// driven from one thread at a time (the engine's, which is
/// thread-confined anyway).
pub struct ComputePool {
    shared: Arc<PoolShared>,
    threads: usize,
    joins: Vec<std::thread::JoinHandle<()>>,
}

impl ComputePool {
    /// Build a pool that partitions work `threads` ways (the caller's
    /// thread counts as one; `threads <= 1` spawns nothing).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState::default()),
            work: Condvar::new(),
            done: Condvar::new(),
            unclaimed: AtomicUsize::new(0),
            injector: Mutex::new(VecDeque::new()),
            locals: (0..threads).map(|_| Mutex::new(VecDeque::new())).collect(),
        });
        let joins = (1..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("lq-compute-{i}"))
                    .spawn(move || worker_loop(&shared, i))
                    .expect("spawning compute worker")
            })
            .collect();
        Self { shared, threads, joins }
    }

    /// Partition width (including the calling thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(0) .. f(tasks - 1)` across the pool, returning when all
    /// have completed. Tasks are claimed dynamically through the
    /// injector/steal queues (the caller claims too), so `f` must
    /// produce the same output for task `i` no matter which thread runs
    /// it — true by construction for the disjoint output partitions this
    /// pool exists for.
    ///
    /// A panicking task is contained, not re-raised: every other task
    /// still runs, the barrier still drains, the worker threads survive,
    /// and `run` returns `Err` carrying the panic payload of the
    /// *lowest* panicking task index (deterministic when several tasks
    /// panic). Callers fail only the work of this call — one poisoned
    /// request group never kills the engine or the process.
    pub fn run(&self, tasks: usize, f: &(dyn Fn(usize) + Sync)) -> Result<(), String> {
        if tasks <= 1 || self.threads <= 1 {
            // serial fast path: same containment contract — every task
            // runs, the lowest-index payload is the one reported
            let mut panic: Option<(usize, String)> = None;
            for t in 0..tasks {
                if let Err(p) = catch_unwind(AssertUnwindSafe(|| f(t))) {
                    if panic.is_none() {
                        panic = Some((t, payload_str(p)));
                    }
                }
            }
            return match panic {
                None => Ok(()),
                Some((t, msg)) => Err(format!("task {t} panicked: {msg}")),
            };
        }
        // Erase the closure's lifetime for the shared job cell (fat
        // reference → fat raw pointer, same layout); the wait below keeps
        // the borrow alive past the last worker's use.
        let erased: *const (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute::<&(dyn Fn(usize) + Sync), _>(f) };
        {
            let mut st = lock(&self.shared);
            debug_assert!(st.job.is_none(), "ComputePool::run is not reentrant");
            st.job = Some(Job { f: erased, tasks });
            st.remaining = tasks;
            st.panic = None;
            // Publish the park-gate count under the state mutex *before*
            // seeding the injector: a worker that scans between runs must
            // never find a queued task whose count isn't visible yet
            // (claiming it would underflow `unclaimed`). The converse
            // window — count visible, injector still empty — only makes
            // scanners yield and rescan.
            self.shared.unclaimed.store(tasks, Ordering::Release);
        }
        lockq(&self.shared.injector).extend(0..tasks);
        self.shared.work.notify_all();
        // The caller participates in its own job instead of just waiting.
        while let Some(task) = try_claim(&self.shared, 0) {
            let res = catch_unwind(AssertUnwindSafe(|| f(task)));
            finish_task(&self.shared, res.err().map(|p| (task, payload_str(p))));
        }
        let mut st = lock(&self.shared);
        while st.remaining > 0 {
            st = self.shared.done.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.job = None; // idempotent: the last finisher already cleared it
        let panicked = st.panic.take();
        drop(st);
        match panicked {
            None => Ok(()),
            Some((t, msg)) => Err(format!("task {t} panicked: {msg}")),
        }
    }

    /// `C[m,n] = A[m,k] @ B[k,n]` with output rows partitioned across the
    /// pool — the persistent-pool replacement for
    /// [`crate::tensor::matmul_flat_threaded`]. Bit-identical to the
    /// serial kernel at every thread count (each row accumulates in the
    /// same order; partitioning only distributes whole rows). A panicking
    /// partition surfaces as `Err` (see [`ComputePool::run`]).
    pub fn matmul_flat(
        &self,
        a: &[f32],
        m: usize,
        k: usize,
        b: &[f32],
        n: usize,
        c: &mut [f32],
    ) -> Result<(), String> {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), k * n);
        debug_assert_eq!(c.len(), m * n);
        let t = self.threads.min(m.max(1));
        if t <= 1 || n == 0 {
            matmul_flat(a, m, k, b, n, c);
            return Ok(());
        }
        let chunk = m.div_ceil(t);
        let tasks = m.div_ceil(chunk);
        let cptr = SendPtr(c.as_mut_ptr());
        self.run(tasks, &|i| {
            let lo = i * chunk;
            let hi = (lo + chunk).min(m);
            // Safety: tasks write disjoint row ranges of `c`.
            let cs = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(lo * n), (hi - lo) * n) };
            cs.fill(0.0);
            matmul_flat_rows(&a[lo * k..hi * k], hi - lo, k, b, n, cs);
        })
    }
}

impl Drop for ComputePool {
    fn drop(&mut self) {
        {
            let mut st = lock(&self.shared);
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

fn worker_loop(shared: &PoolShared, me: usize) {
    loop {
        let (f, task) = loop {
            if let Some(t) = try_claim(shared, me) {
                // A claimed task implies `remaining > 0`, and the job
                // cell is only cleared when `remaining` hits zero — so
                // the job is still published.
                let st = lock(shared);
                let job = st.job.as_ref().expect("claimed a task with no job published");
                break (job.f, t);
            }
            let st = lock(shared);
            if st.shutdown {
                return;
            }
            if shared.unclaimed.load(Ordering::Acquire) == 0 {
                // Park. The publisher stores `unclaimed` under this mutex
                // before notifying, so the wakeup cannot be missed.
                let _unused = shared.work.wait(st).unwrap_or_else(|e| e.into_inner());
            } else {
                // Work exists but wasn't visible (a batch refill is in
                // flight between queue locks, or a sibling claimed the
                // last visible task first) — rescan shortly.
                drop(st);
                std::thread::yield_now();
            }
        };
        // Safety: see `Job` — the publishing `run` call keeps the closure
        // alive until `remaining` reaches zero, which happens strictly
        // after this call returns.
        let res = catch_unwind(AssertUnwindSafe(|| unsafe { (*f)(task) }));
        finish_task(shared, res.err().map(|p| (task, payload_str(p))));
    }
}

/// Book one task as completed — panicked or not, it decrements
/// `remaining`, so the barrier in `run` always drains. When several
/// tasks panic, the lowest task index's payload wins (claim order is
/// scheduling-dependent; the reported error must not be).
fn finish_task(shared: &PoolShared, panic: Option<(usize, String)>) {
    let mut st = lock(shared);
    if let Some((t, msg)) = panic {
        if st.panic.as_ref().is_none_or(|(p, _)| t < *p) {
            st.panic = Some((t, msg));
        }
    }
    st.remaining -= 1;
    if st.remaining == 0 {
        st.job = None;
        shared.done.notify_all();
    }
}

/// A raw mutable base pointer smuggled into `Fn` tasks that carve
/// disjoint sub-slices out of one output buffer. Soundness rests on the
/// caller's partition arithmetic (ranges never overlap) and on the
/// `run` barrier (no use outlives the borrow).
#[derive(Clone, Copy)]
pub(crate) struct SendPtr(pub(crate) *mut f32);

// Safety: dereferenced only inside disjoint, barrier-bounded partitions.
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = ComputePool::new(4);
        assert_eq!(pool.threads(), 4);
        for tasks in [0usize, 1, 2, 3, 4, 9, 33] {
            let hits: Vec<AtomicUsize> = (0..tasks).map(|_| AtomicUsize::new(0)).collect();
            pool.run(tasks, &|i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            })
            .unwrap();
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "task {i} of {tasks}");
            }
        }
    }

    #[test]
    fn pool_is_reusable_across_many_calls() {
        // the amortization claim: one pool, many cheap dispatches — and
        // no stale queue entries may leak between jobs
        let pool = ComputePool::new(3);
        let total = AtomicUsize::new(0);
        for _ in 0..200 {
            pool.run(3, &|i| {
                total.fetch_add(i + 1, Ordering::SeqCst);
            })
            .unwrap();
        }
        assert_eq!(total.load(Ordering::SeqCst), 200 * 6);
    }

    #[test]
    fn steal_loop_completes_ragged_task_costs_exactly_once() {
        // One task is ~1000x heavier than the rest: the thread stuck on
        // it must have its local backlog stolen by the others, and every
        // task still runs exactly once.
        let pool = ComputePool::new(4);
        let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        let sink = AtomicUsize::new(0);
        pool.run(64, &|i| {
            if i == 0 {
                let mut acc = 0usize;
                for j in 0..200_000 {
                    acc = acc.wrapping_add(j);
                }
                sink.fetch_add(acc, Ordering::Relaxed);
            }
            hits[i].fetch_add(1, Ordering::SeqCst);
        })
        .unwrap();
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "task {i}");
        }
    }

    #[test]
    fn task_panic_is_contained_with_payload_and_pool_survives() {
        let pool = ComputePool::new(3);
        let err = pool
            .run(8, &|i| {
                assert!(i != 5, "induced task failure");
            })
            .expect_err("the task panic must surface as a structured error, not re-raise");
        assert!(
            err.contains("task 5") && err.contains("induced task failure"),
            "error must name the task and carry its payload: {err}"
        );
        // the park gate treated the panicked task as completed, so the
        // barrier drained and every worker thread is still alive
        let hits = AtomicUsize::new(0);
        pool.run(6, &|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        })
        .unwrap();
        assert_eq!(hits.load(Ordering::SeqCst), 6);
    }

    #[test]
    fn lowest_index_panic_wins_and_serial_path_contains_too() {
        // several tasks panic: which worker claims which task is
        // scheduling-dependent, the reported payload must not be
        let pool = ComputePool::new(4);
        for _ in 0..20 {
            let err = pool
                .run(16, &|i| {
                    if i % 3 == 2 {
                        panic!("boom {i}");
                    }
                })
                .unwrap_err();
            assert!(err.contains("task 2 panicked: boom 2"), "{err}");
        }
        // threads=1 degenerates to the serial loop — same contract
        let serial = ComputePool::new(1);
        let hits = AtomicUsize::new(0);
        let err = serial
            .run(4, &|i| {
                hits.fetch_add(1, Ordering::SeqCst);
                if i >= 1 {
                    panic!("boom {i}");
                }
            })
            .unwrap_err();
        assert!(err.contains("task 1 panicked: boom 1"), "{err}");
        assert_eq!(hits.load(Ordering::SeqCst), 4, "remaining serial tasks still ran");
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = ComputePool::new(1);
        let mut out = vec![0usize; 5];
        let ptr = SendPtr(out.as_mut_ptr() as *mut f32);
        let _ = ptr; // SendPtr is exercised by matmul tests below
        let hits = AtomicUsize::new(0);
        pool.run(5, &|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        })
        .unwrap();
        assert_eq!(hits.load(Ordering::SeqCst), 5);
        out[0] = 1;
        assert_eq!(out[0], 1);
    }

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut s = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        (0..n)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                (s as f64 / u64::MAX as f64) as f32 - 0.5
            })
            .collect()
    }

    #[test]
    fn pool_matmul_bit_identical_to_serial_at_every_width() {
        // ragged row counts so chunking hits partial final partitions
        for m in [1usize, 2, 5, 8, 13] {
            let (k, n) = (11usize, 6usize);
            let a = rand_vec(m * k, 31 + m as u64);
            let b = rand_vec(k * n, 32);
            let mut serial = vec![0.0f32; m * n];
            matmul_flat(&a, m, k, &b, n, &mut serial);
            for threads in [1usize, 2, 3, 4, 16] {
                let pool = ComputePool::new(threads);
                let mut par = vec![f32::NAN; m * n];
                pool.matmul_flat(&a, m, k, &b, n, &mut par).unwrap();
                assert_eq!(par, serial, "m={m} threads={threads} must be bit-identical");
            }
        }
    }

    /// Strict-IEEE contract under partitioning: with NaN, ±∞ and −0.0
    /// planted in the inputs, the pool kernel must agree **bitwise**
    /// with the scalar oracle at every thread count — no sparsity skip
    /// may swallow a `0 · NaN`, and signed zeros must survive.
    #[test]
    fn pool_matmul_propagates_hazards_bit_identically() {
        let (m, k, n) = (5usize, 9usize, 7usize);
        let mut a = rand_vec(m * k, 77);
        let mut b = rand_vec(k * n, 78);
        a[0] = 0.0; // meets b's NaN column: 0·NaN must stay NaN
        a[k + 1] = -0.0;
        a[2 * k + 2] = f32::INFINITY;
        b[n + 3] = f32::NAN;
        b[2 * n + 4] = f32::NEG_INFINITY;
        let mut oracle = vec![0.0f32; m * n];
        crate::tensor::scalar::matmul_flat(&a, m, k, &b, n, &mut oracle);
        assert!(oracle.iter().any(|v| v.is_nan()), "fixture must exercise NaN rows");
        for threads in [1usize, 2, 4] {
            let pool = ComputePool::new(threads);
            let mut par = vec![0.0f32; m * n];
            pool.matmul_flat(&a, m, k, &b, n, &mut par).unwrap();
            for (i, (p, o)) in par.iter().zip(&oracle).enumerate() {
                assert!(
                    p.to_bits() == o.to_bits() || (p.is_nan() && o.is_nan()),
                    "threads={threads} elem {i}: {p:?} vs {o:?}"
                );
            }
        }
    }

    #[test]
    fn pool_matmul_reuse_stays_identical() {
        // the same pool over different shapes in sequence — no stale-job
        // bleed-through between calls
        let pool = ComputePool::new(4);
        for (m, k, n, seed) in [(7usize, 5usize, 9usize, 1u64), (3, 8, 2, 2), (12, 4, 4, 3)] {
            let a = rand_vec(m * k, seed);
            let b = rand_vec(k * n, seed + 100);
            let mut serial = vec![0.0f32; m * n];
            matmul_flat(&a, m, k, &b, n, &mut serial);
            let mut par = vec![f32::NAN; m * n];
            pool.matmul_flat(&a, m, k, &b, n, &mut par).unwrap();
            assert_eq!(par, serial);
        }
    }

    #[test]
    fn drop_joins_workers() {
        // constructing and dropping pools repeatedly must not leak or hang
        for _ in 0..8 {
            let pool = ComputePool::new(3);
            pool.run(2, &|_| {}).unwrap();
            drop(pool);
        }
    }
}
