//! Experiment harness shared by the paper-reproduction benches
//! (`rust/benches/bench_*.rs`) and the examples: loads the trained
//! adapters + eval sets, applies any Table-1 method, and scores through
//! the PJRT runtime.
//!
//! Environment knobs (so `cargo bench` stays fast by default):
//! * `LQ_ARTIFACTS` — artifacts dir (default `artifacts`)
//! * `LQ_MODELS`    — comma list (default: every model with artifacts)
//! * `LQ_N`         — eval examples per cell (default 100; paper-full = 200)

use crate::adapter::LoraAdapter;
use crate::baselines::{BiLlm, FlatQuantizer, Gptq, JdDiagonal, PbLlm, Quantizer};
use crate::eval::{evaluate, EvalSet};
use crate::loraquant::{quantize_site, HSelect, LoraQuantConfig, LowMode, QuantizedLora};
use crate::model::{merge_adapter, BaseWeights};
use crate::runtime::Engine;
use crate::tensor::Matrix;
use anyhow::Context;
use std::collections::BTreeMap;
use std::path::PathBuf;

/// The evaluation grid's task list (paper column order).
pub const TASKS: [&str; 4] = ["modadd", "modchain", "transform", "keyword"];

/// The three model substitutes (paper row blocks).
pub const MODELS: [&str; 3] = ["tiny-llama-s", "tiny-llama-m", "tiny-mistral-s"];

/// Env-configured harness settings.
#[derive(Debug, Clone)]
pub struct Settings {
    pub artifacts: PathBuf,
    pub models: Vec<String>,
    pub eval_n: usize,
}

impl Settings {
    /// Read from the environment, keeping only models whose artifacts exist.
    pub fn from_env() -> Self {
        let artifacts: PathBuf =
            std::env::var("LQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()).into();
        let models: Vec<String> = match std::env::var("LQ_MODELS") {
            Ok(s) => s.split(',').map(|x| x.trim().to_string()).collect(),
            Err(_) => MODELS.iter().map(|s| s.to_string()).collect(),
        };
        let models = models
            .into_iter()
            .filter(|m| {
                artifacts.join(m).join("base.bin").exists()
                    && artifacts.join(format!("{m}.fwd.b8.hlo.txt")).exists()
            })
            .collect();
        let eval_n = std::env::var("LQ_N").ok().and_then(|v| v.parse().ok()).unwrap_or(100);
        Self { artifacts, models, eval_n }
    }
}

/// Everything needed to evaluate one (model, task) cell.
pub struct TaskData {
    pub task: String,
    pub lora: LoraAdapter,
    /// Per-site calibration activations (GPTQ).
    pub calib: BTreeMap<String, Matrix>,
    pub eval: EvalSet,
}

/// One loaded model with its per-task data and a live engine.
pub struct ModelCtx {
    pub name: String,
    pub base: BaseWeights,
    pub engine: Engine,
    pub bucket: usize,
    pub tasks: Vec<TaskData>,
}

impl ModelCtx {
    /// Load a model + all task adapters/eval sets and compile its fwd.
    pub fn load(settings: &Settings, model: &str) -> anyhow::Result<Self> {
        let dir = settings.artifacts.join(model);
        let base = BaseWeights::load(&dir)?;
        let mut engine = Engine::new(&settings.artifacts)?;
        let bucket = 8;
        engine.load_model_fwd(model, bucket, base.cfg.param_names().len())?;
        let mut tasks = Vec::new();
        for task in TASKS {
            let lora_path = dir.join(format!("{task}.lora.bin"));
            if !lora_path.exists() {
                continue;
            }
            let lora = LoraAdapter::load(&lora_path)?;
            let calib = load_calib(dir.join(format!("{task}.calib.bin")))?;
            let eval = EvalSet::load(dir.join(format!("{task}.eval.bin")))?
                .truncated(settings.eval_n);
            tasks.push(TaskData { task: task.to_string(), lora, calib, eval });
        }
        Ok(Self { name: model.to_string(), base, engine, bucket, tasks })
    }

    /// Evaluate per-site deltas (merged into the base) on one task.
    pub fn eval_deltas(
        &self,
        deltas: &BTreeMap<String, Matrix>,
        eval: &EvalSet,
    ) -> anyhow::Result<f64> {
        let merged = merge_adapter(&self.base, deltas)?;
        let weights = self.engine.upload_weights(&merged)?;
        Ok(evaluate(&self.engine, &self.name, self.bucket, &self.base.cfg, &weights, eval)?.score)
    }
}

fn load_calib(path: PathBuf) -> anyhow::Result<BTreeMap<String, Matrix>> {
    let mut out = BTreeMap::new();
    if !path.exists() {
        return Ok(out);
    }
    for (name, t) in crate::adapter::fmt::load_tensorfile(&path)? {
        let m = t.to_matrix().with_context(|| format!("calib {name}"))?;
        out.insert(name, m);
    }
    Ok(out)
}

/// A Table-1 method row: name + a closure producing (deltas, avg_bits).
pub enum Method {
    Fp16,
    Flat(FlatQuantizer),
    Gptq(Gptq),
    PbLlm(PbLlm),
    BiLlm(BiLlm),
    /// JD-Diagonal over the cluster of all task adapters of the model.
    JdDiagonal,
    LoraQuant(LoraQuantConfig),
}

impl Method {
    pub fn name(&self) -> String {
        match self {
            Method::Fp16 => "FP16".into(),
            Method::Flat(q) => q.name(),
            Method::Gptq(q) => q.name(),
            Method::PbLlm(q) => q.name(),
            Method::BiLlm(q) => q.name(),
            Method::JdDiagonal => "JD-Diagonal".into(),
            Method::LoraQuant(cfg) => match cfg.hselect {
                HSelect::Ratio(rho) => format!("LoRAQuant ({}@{rho})", cfg.bits_high),
                HSelect::Static(h) => format!("LoRAQuant ({}@h={h})", cfg.bits_high),
            },
        }
    }

    /// The paper's Table 1 rows 1–12 (group 128, like the paper).
    pub fn table1_rows() -> Vec<Method> {
        vec![
            Method::Fp16,
            Method::Flat(FlatQuantizer::bin(128)),
            Method::Flat(FlatQuantizer::rtn(1, 128)),
            Method::JdDiagonal,
            Method::Flat(FlatQuantizer::rtn(2, 128)),
            Method::Gptq(Gptq::new(2, 128)),
            Method::PbLlm(PbLlm::default()),
            Method::BiLlm(BiLlm::default()),
            Method::LoraQuant(lq(2, 0.8)),
            Method::LoraQuant(lq(2, 0.9)),
            Method::LoraQuant(lq(3, 0.8)),
            Method::LoraQuant(lq(3, 0.9)),
        ]
    }
}

/// LoRAQuant `i@ρ` with the paper's group size (128).
pub fn lq(bits: u32, rho: f32) -> LoraQuantConfig {
    LoraQuantConfig { group: 128, ..LoraQuantConfig::variant(bits, rho) }
}

/// Apply a method to one task adapter: returns (deltas, avg_bits).
///
/// `cluster` provides the sibling task adapters of the same model for
/// JD-Diagonal (the paper treats a model's task adapters as one cluster).
pub fn apply_method(
    method: &Method,
    td: &TaskData,
    cluster: &[&LoraAdapter],
) -> (BTreeMap<String, Matrix>, f64) {
    match method {
        Method::Fp16 => (crate::model::merge::fp_deltas(&td.lora), 16.0),
        Method::Flat(q) => apply_pairwise(&td.lora, &td.calib, |b, a, c| q.quantize(b, a, c)),
        Method::Gptq(q) => apply_pairwise(&td.lora, &td.calib, |b, a, c| q.quantize(b, a, c)),
        Method::PbLlm(q) => apply_pairwise(&td.lora, &td.calib, |b, a, c| q.quantize(b, a, c)),
        Method::BiLlm(q) => apply_pairwise(&td.lora, &td.calib, |b, a, c| q.quantize(b, a, c)),
        Method::LoraQuant(cfg) => {
            let mut q = QuantizedLora::default();
            for (site, (a, b)) in &td.lora.sites {
                q.sites.insert(
                    site.clone(),
                    quantize_site(b, a, cfg).expect("experiment grids use well-formed configs"),
                );
            }
            let deltas = crate::model::merge::quant_deltas(&q);
            (deltas, q.avg_bits())
        }
        Method::JdDiagonal => {
            // per-site cluster across this model's task adapters
            let mut deltas = BTreeMap::new();
            let mut bits_num = 0.0f64;
            let mut bits_den = 0.0f64;
            // index of this task inside the cluster
            let me = cluster
                .iter()
                .position(|l| std::ptr::eq(*l, &td.lora))
                .unwrap_or(0);
            for (site, (_a, b)) in &td.lora.sites {
                let pairs: Vec<(Matrix, Matrix)> = cluster
                    .iter()
                    .filter_map(|l| l.sites.get(site))
                    .map(|(a2, b2)| (b2.clone(), a2.clone()))
                    .collect();
                let k = b.cols();
                let fitted = JdDiagonal { k }.fit(&pairs);
                deltas.insert(site.clone(), fitted.dequant_delta(me));
                bits_num += fitted.storage_bits_per_adapter() as f64;
                bits_den += fitted.params_per_adapter as f64;
            }
            (deltas, bits_num / bits_den)
        }
    }
}

fn apply_pairwise(
    lora: &LoraAdapter,
    calib: &BTreeMap<String, Matrix>,
    f: impl Fn(&Matrix, &Matrix, Option<&Matrix>) -> Box<dyn crate::baselines::CompressedPair>,
) -> (BTreeMap<String, Matrix>, f64) {
    let mut deltas = BTreeMap::new();
    let mut bits = 0u64;
    let mut params = 0usize;
    for (site, (a, b)) in &lora.sites {
        let c = f(b, a, calib.get(site));
        deltas.insert(site.clone(), c.dequant_delta());
        bits += c.storage_bits();
        params += c.param_count();
    }
    (deltas, bits as f64 / params as f64)
}

/// LoRAQuant with every ablation switch of Figure 3.
pub fn fig3_variant(kind: &str, rho: f32, group: usize) -> LoraQuantConfig {
    let base = LoraQuantConfig { group, ..LoraQuantConfig::variant(2, rho) };
    match kind {
        "loraquant" => base,
        "no_opt" => LoraQuantConfig { ste: None, ..base },
        "prune" => LoraQuantConfig { low_mode: LowMode::Prune, ..base },
        "rtn_low" => LoraQuantConfig { low_mode: LowMode::Rtn1, ..base },
        _ => panic!("unknown fig3 variant {kind}"),
    }
}
