//! Round-to-nearest group-wise quantization (paper §3.2, Eqs. 6–7).

use super::{pack_codes, unpack_codes, unpack_codes_f32_into};
use crate::tensor::{DequantRows, Matrix};

/// A group-wise RTN-quantized matrix (grouping along the last axis).
#[derive(Debug, Clone)]
pub struct RtnQuantized {
    pub rows: usize,
    pub cols: usize,
    pub bits: u32,
    pub group: usize,
    /// Packed codes, row-major, `rows * cols` codes of `bits` bits.
    pub packed: Vec<u8>,
    /// fp scale per (row, group), row-major `rows * cols/group`.
    pub scale: Vec<f32>,
    /// integer zero-point per (row, group), stored as f32.
    pub zero: Vec<f32>,
}

impl RtnQuantized {
    /// Number of groups per row.
    pub fn groups_per_row(&self) -> usize {
        self.cols.div_ceil(self.group)
    }

    /// Storage cost in bits under the paper's Eq. 10 accounting, counting
    /// the groups actually materialized (per-row grouping — short rows pay
    /// real overhead; see DESIGN.md §7 on grouping axes).
    pub fn storage_bits(&self) -> u64 {
        let groups = (self.rows * self.groups_per_row()) as u64;
        (self.rows * self.cols) as u64 * self.bits as u64
            + groups * (crate::quant::SCALE_BITS + self.bits as u64)
    }

    /// In-memory packed size in bytes (codes + fp16 scales + packed zeros).
    pub fn packed_bytes(&self) -> usize {
        self.packed.len() + self.scale.len() * 2 + (self.zero.len() * self.bits as usize).div_ceil(8)
    }

    /// Dequantize one stored row into `out` (`out.len() == cols`) without
    /// touching any other row — the streaming-GEMM building block.
    /// Allocation-free: codes decode straight into `out` as f32 via the
    /// LUT group unpacker, then the per-group affine `S * (q - Z)` runs
    /// as a second vectorizable pass in place. Since `u8 → f32` is exact,
    /// the result is bit-identical to dequantizing from a codes buffer.
    pub fn dequant_row_into(&self, i: usize, out: &mut [f32]) {
        debug_assert!(i < self.rows);
        debug_assert_eq!(out.len(), self.cols);
        unpack_codes_f32_into(&self.packed, self.bits, i * self.cols, out);
        let gpr = self.groups_per_row();
        for g in 0..gpr {
            let s = self.scale[i * gpr + g];
            let z = self.zero[i * gpr + g];
            for v in &mut out[g * self.group..((g + 1) * self.group).min(self.cols)] {
                *v = s * (*v - z);
            }
        }
    }
}

impl DequantRows for RtnQuantized {
    fn src_rows(&self) -> usize {
        self.rows
    }

    fn src_cols(&self) -> usize {
        self.cols
    }

    fn dequant_row_into(&self, i: usize, out: &mut [f32]) {
        RtnQuantized::dequant_row_into(self, i, out)
    }
}

/// Quantize `w` group-wise along rows at `bits` bits.
///
/// `cols` need not divide `group`; the final group of each row is shorter.
/// Degenerate (constant) groups quantize to code 0 with scale 1, zero 0 —
/// dequantizing exactly to the constant only when it is 0; otherwise RTN
/// cannot represent it better anyway (max==min ⇒ S would be 0).
pub fn rtn_quant(w: &Matrix, bits: u32, group: usize) -> RtnQuantized {
    assert!((1..=8).contains(&bits), "bits {bits}");
    assert!(group > 0);
    let (rows, cols) = w.shape();
    let gpr = cols.div_ceil(group);
    let qmax = (1u32 << bits) - 1;
    let mut codes = Vec::with_capacity(rows * cols);
    let mut scale = Vec::with_capacity(rows * gpr);
    let mut zero = Vec::with_capacity(rows * gpr);
    for i in 0..rows {
        let row = w.row(i);
        for g in 0..gpr {
            let chunk = &row[g * group..((g + 1) * group).min(cols)];
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for &v in chunk {
                lo = lo.min(v);
                hi = hi.max(v);
            }
            let range = hi - lo;
            if range <= 0.0 {
                // degenerate group: represent (w - w) exactly iff w == 0
                scale.push(if lo == 0.0 { 1.0 } else { lo });
                zero.push(0.0);
                // code 1 * scale reproduces a constant nonzero value:
                // dequant = S*(q - Z) = lo*1. For lo==0, code 0.
                let code = if lo == 0.0 { 0 } else { 1u8 };
                codes.extend(std::iter::repeat_n(code, chunk.len()));
                continue;
            }
            let s = range / qmax as f32;
            let z = (-lo / s).round();
            scale.push(s);
            zero.push(z);
            for &v in chunk {
                let q = ((v / s).round() + z).clamp(0.0, qmax as f32);
                codes.push(q as u8);
            }
        }
    }
    RtnQuantized { rows, cols, bits, group, packed: pack_codes(&codes, bits), scale, zero }
}

/// Dequantize back to a dense matrix: `S * (q - Z)` per group.
pub fn rtn_dequant(q: &RtnQuantized) -> Matrix {
    let codes = unpack_codes(&q.packed, q.bits, q.rows * q.cols);
    let gpr = q.groups_per_row();
    let mut out = Matrix::zeros(q.rows, q.cols);
    for i in 0..q.rows {
        let row = out.row_mut(i);
        for g in 0..gpr {
            let s = q.scale[i * gpr + g];
            let z = q.zero[i * gpr + g];
            let start = g * q.group;
            let end = ((g + 1) * q.group).min(q.cols);
            for j in start..end {
                row[j] = s * (codes[i * q.cols + j] as f32 - z);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;

    #[test]
    fn dequant_bounded_by_half_step() {
        let mut rng = Rng::new(21);
        let w = rng.matrix(16, 128, 1.0);
        for bits in [2, 3, 4, 8] {
            let q = rtn_quant(&w, bits, 64);
            let wd = rtn_dequant(&q);
            let gpr = q.groups_per_row();
            for i in 0..16 {
                for g in 0..gpr {
                    let s = q.scale[i * gpr + g];
                    for j in g * 64..((g + 1) * 64).min(128) {
                        let err = (w.at(i, j) - wd.at(i, j)).abs();
                        // rounding error <= S/2 (+ Z rounding slack of S/2)
                        assert!(err <= s * 1.01, "bits={bits} err={err} s={s}");
                    }
                }
            }
        }
    }

    #[test]
    fn more_bits_less_error() {
        let mut rng = Rng::new(22);
        let w = rng.matrix(8, 256, 1.0);
        let errs: Vec<f32> = [1u32, 2, 4, 8]
            .iter()
            .map(|&b| rtn_dequant(&rtn_quant(&w, b, 64)).rel_err(&w))
            .collect();
        assert!(errs[0] > errs[1] && errs[1] > errs[2] && errs[2] > errs[3], "{errs:?}");
        assert!(errs[3] < 1e-2);
    }

    #[test]
    fn zero_matrix_exact() {
        let w = Matrix::zeros(4, 64);
        let q = rtn_quant(&w, 2, 32);
        assert_eq!(rtn_dequant(&q).fro_norm(), 0.0);
    }

    #[test]
    fn constant_group_exact() {
        let w = Matrix::from_fn(2, 32, |_, _| 3.5);
        let q = rtn_quant(&w, 2, 32);
        let wd = rtn_dequant(&q);
        assert!(wd.rel_err(&w) < 1e-6, "constant groups should reconstruct");
    }

    #[test]
    fn ragged_final_group() {
        let mut rng = Rng::new(23);
        let w = rng.matrix(3, 100, 1.0); // 100 = 64 + 36
        let q = rtn_quant(&w, 4, 64);
        assert_eq!(q.groups_per_row(), 2);
        let wd = rtn_dequant(&q);
        assert!(wd.rel_err(&w) < 0.1);
    }

    #[test]
    fn row_dequant_matches_full_dequant() {
        let mut rng = Rng::new(25);
        let w = rng.matrix(5, 100, 1.0); // ragged final group at 3-bit rows
        for bits in [1u32, 2, 3, 4, 8] {
            let q = rtn_quant(&w, bits, 64);
            let full = rtn_dequant(&q);
            let mut row = vec![0.0f32; q.cols];
            for i in 0..q.rows {
                q.dequant_row_into(i, &mut row);
                assert_eq!(row.as_slice(), full.row(i), "bits={bits} row {i}");
            }
        }
    }

    #[test]
    fn one_bit_rtn_collapses_to_two_levels() {
        let mut rng = Rng::new(24);
        let w = rng.matrix(2, 64, 1.0);
        let q = rtn_quant(&w, 1, 64);
        let wd = rtn_dequant(&q);
        for i in 0..2 {
            let distinct: std::collections::BTreeSet<i64> =
                wd.row(i).iter().map(|v| (v * 1e6) as i64).collect();
            assert!(distinct.len() <= 2);
        }
    }
}
