//! Quantization-axis handling (paper Appendix B, Fig. 5).
//!
//! All quantizers in this crate group along the **last axis** (row-wise).
//! The paper's default is B' quantized **column-wise** and A' **row-wise**,
//! so that √S singular factors fold into the per-column/-row scales; the
//! appendix ablates all four (B-axis × A-axis) combinations. [`QuantAxis`]
//! expresses an orientation and transposes around the row-wise primitive.

use crate::tensor::Matrix;

/// Orientation of grouping for one factor matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Axis {
    /// Group along rows (contiguous elements of a row share a scale).
    Row,
    /// Group along columns.
    Col,
}

impl Axis {
    /// Orient `w` so that row-wise grouping implements this axis.
    pub fn orient(&self, w: &Matrix) -> Matrix {
        match self {
            Axis::Row => w.clone(),
            Axis::Col => w.transpose(),
        }
    }

    /// Undo [`Axis::orient`] on a dequantized matrix.
    pub fn restore(&self, w: Matrix) -> Matrix {
        match self {
            Axis::Row => w,
            Axis::Col => w.transpose(),
        }
    }
}

impl std::fmt::Display for Axis {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Axis::Row => "row",
            Axis::Col => "col",
        })
    }
}

/// Axis pair for the two LoRA factors — the paper's Fig. 5 design space.
///
/// Default (`B(col) A(row)`): each SVD component's √sᵢ multiplies a column
/// of B' and a row of A', so per-column/-row scales absorb the singular
/// values exactly (App. B).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct QuantAxis {
    pub b_axis: Axis,
    pub a_axis: Axis,
}

impl Default for QuantAxis {
    fn default() -> Self {
        Self { b_axis: Axis::Col, a_axis: Axis::Row }
    }
}

impl QuantAxis {
    /// All four combinations, in the order Fig. 5 reports them.
    pub fn all() -> [QuantAxis; 4] {
        [
            QuantAxis { b_axis: Axis::Col, a_axis: Axis::Row },
            QuantAxis { b_axis: Axis::Col, a_axis: Axis::Col },
            QuantAxis { b_axis: Axis::Row, a_axis: Axis::Row },
            QuantAxis { b_axis: Axis::Row, a_axis: Axis::Col },
        ]
    }
}

impl std::fmt::Display for QuantAxis {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "B({}) A({})", self.b_axis, self.a_axis)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{rtn_dequant, rtn_quant};
    use crate::testutil::Rng;

    #[test]
    fn orient_restore_roundtrip() {
        let mut rng = Rng::new(41);
        let w = rng.matrix(5, 9, 1.0);
        for ax in [Axis::Row, Axis::Col] {
            assert_eq!(ax.restore(ax.orient(&w)), w);
        }
    }

    #[test]
    fn col_axis_groups_along_columns() {
        // A matrix whose columns are constants quantizes exactly under
        // column-wise grouping (each group is degenerate-constant).
        let w = Matrix::from_fn(64, 4, |_i, j| j as f32 + 1.0);
        let orient = Axis::Col.orient(&w);
        let q = rtn_quant(&orient, 2, 64);
        let wd = Axis::Col.restore(rtn_dequant(&q));
        assert!(wd.rel_err(&w) < 1e-6);
    }
}
