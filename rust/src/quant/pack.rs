//! k-bit code packing into a little-endian bit stream.
//!
//! Code `j` occupies bits `[j*k, (j+1)*k)` of the stream, least-significant
//! bit first within each byte. For k ∈ {1, 2, 4, 8} this matches the Pallas
//! kernel layout (python/compile/kernels/ref.py `pack1`/`pack2`); k = 3/5/6/7
//! codes straddle byte boundaries, which only the rust storage path uses.

/// Pack `codes` (each `< 2^bits`) into a byte vector.
pub fn pack_codes(codes: &[u8], bits: u32) -> Vec<u8> {
    assert!((1..=8).contains(&bits));
    let total_bits = codes.len() * bits as usize;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mut bitpos = 0usize;
    for &c in codes {
        debug_assert!(u32::from(c) < (1u32 << bits), "code {c} out of range for {bits} bits");
        let byte = bitpos / 8;
        let off = bitpos % 8;
        out[byte] |= c << off;
        if off + bits as usize > 8 {
            out[byte + 1] |= c >> (8 - off);
        }
        bitpos += bits as usize;
    }
    out
}

/// Unpack `count` codes of `bits` bits each.
pub fn unpack_codes(packed: &[u8], bits: u32, count: usize) -> Vec<u8> {
    assert!((1..=8).contains(&bits));
    // byte-parallel fast paths for the widths the hot path uses
    match bits {
        1 => return unpack_parallel::<8>(packed, count, |b, j| (b >> j) & 1),
        2 => return unpack_parallel::<4>(packed, count, |b, j| (b >> (2 * j)) & 3),
        4 => return unpack_parallel::<2>(packed, count, |b, j| (b >> (4 * j)) & 15),
        _ => {}
    }
    unpack_scalar(packed, bits, 0, count)
}

/// Unpack `count` codes starting at code index `start` of the stream —
/// the row-streaming entry point: callers address one packed row as
/// `start = row * cols, count = cols` without unpacking what precedes it.
pub fn unpack_codes_range(packed: &[u8], bits: u32, start: usize, count: usize) -> Vec<u8> {
    assert!((1..=8).contains(&bits));
    let first_bit = start * bits as usize;
    if first_bit % 8 == 0 {
        // byte-aligned: reuse the fast paths on the tail slice
        return unpack_codes(&packed[first_bit / 8..], bits, count);
    }
    unpack_scalar(packed, bits, first_bit, count)
}

/// The generic bit-extraction loop, starting at an arbitrary bit offset.
fn unpack_scalar(packed: &[u8], bits: u32, first_bit: usize, count: usize) -> Vec<u8> {
    let mask = if bits == 8 { 0xFF } else { (1u16 << bits) - 1 } as u16;
    let mut out = Vec::with_capacity(count);
    let mut bitpos = first_bit;
    for _ in 0..count {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let mut v = (packed[byte] >> off) as u16;
        if off + bits as usize > 8 {
            v |= (packed[byte + 1] as u16) << (8 - off);
        }
        out.push((v & mask) as u8);
        bitpos += bits as usize;
    }
    out
}

/// Unpack LANES codes per byte with a per-lane extractor (autovectorizes).
#[inline]
fn unpack_parallel<const LANES: usize>(
    packed: &[u8],
    count: usize,
    lane: impl Fn(u8, usize) -> u8,
) -> Vec<u8> {
    let mut out = vec![0u8; count];
    let full = count / LANES;
    for (i, &b) in packed.iter().take(full).enumerate() {
        for j in 0..LANES {
            out[i * LANES + j] = lane(b, j);
        }
    }
    for k in full * LANES..count {
        out[k] = lane(packed[k / LANES], k % LANES);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;

    #[test]
    fn roundtrip_all_bitwidths() {
        let mut rng = Rng::new(99);
        for bits in 1..=8u32 {
            for len in [0usize, 1, 7, 8, 9, 63, 64, 100] {
                let codes: Vec<u8> =
                    (0..len).map(|_| (rng.next_u64() & ((1 << bits) - 1)) as u8).collect();
                let packed = pack_codes(&codes, bits);
                assert_eq!(packed.len(), (len * bits as usize).div_ceil(8));
                assert_eq!(unpack_codes(&packed, bits, len), codes, "bits={bits} len={len}");
            }
        }
    }

    #[test]
    fn layout_matches_kernel_2bit() {
        // codes [1,2,3,0] -> byte 0b00_11_10_01 = 0x39
        let packed = pack_codes(&[1, 2, 3, 0], 2);
        assert_eq!(packed, vec![0b0011_1001]);
    }

    #[test]
    fn layout_matches_kernel_1bit() {
        // bit j at position j%8, bit=1 <=> code 1
        let packed = pack_codes(&[1, 0, 0, 0, 0, 0, 0, 1], 1);
        assert_eq!(packed, vec![0b1000_0001]);
    }

    #[test]
    fn range_unpack_matches_full_unpack() {
        let mut rng = Rng::new(101);
        for bits in 1..=8u32 {
            let codes: Vec<u8> =
                (0..97).map(|_| (rng.next_u64() & ((1 << bits) - 1)) as u8).collect();
            let packed = pack_codes(&codes, bits);
            for (start, count) in [(0usize, 97usize), (1, 10), (7, 13), (32, 65), (96, 1), (50, 0)]
            {
                assert_eq!(
                    unpack_codes_range(&packed, bits, start, count),
                    codes[start..start + count].to_vec(),
                    "bits={bits} start={start} count={count}"
                );
            }
        }
    }

    #[test]
    fn three_bit_straddles_bytes() {
        let codes = vec![0b111, 0b101, 0b010, 0b110, 0b001];
        let packed = pack_codes(&codes, 3);
        assert_eq!(unpack_codes(&packed, 3, 5), codes);
        assert_eq!(packed.len(), 2);
    }
}
