//! k-bit code packing into a little-endian bit stream.
//!
//! Code `j` occupies bits `[j*k, (j+1)*k)` of the stream, least-significant
//! bit first within each byte. For k ∈ {1, 2, 4, 8} this matches the Pallas
//! kernel layout (python/compile/kernels/ref.py `pack1`/`pack2`); k = 3/5/6/7
//! codes straddle byte boundaries, which only the rust storage path uses.

/// Pack `codes` (each `< 2^bits`) into a byte vector.
pub fn pack_codes(codes: &[u8], bits: u32) -> Vec<u8> {
    assert!((1..=8).contains(&bits));
    let total_bits = codes.len() * bits as usize;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mut bitpos = 0usize;
    for &c in codes {
        debug_assert!(u32::from(c) < (1u32 << bits), "code {c} out of range for {bits} bits");
        let byte = bitpos / 8;
        let off = bitpos % 8;
        out[byte] |= c << off;
        if off + bits as usize > 8 {
            out[byte + 1] |= c >> (8 - off);
        }
        bitpos += bits as usize;
    }
    out
}

/// Unpack `count` codes of `bits` bits each.
pub fn unpack_codes(packed: &[u8], bits: u32, count: usize) -> Vec<u8> {
    unpack_codes_range(packed, bits, 0, count)
}

/// Unpack `count` codes starting at code index `start` of the stream —
/// the row-streaming entry point: callers address one packed row as
/// `start = row * cols, count = cols` without unpacking what precedes it.
pub fn unpack_codes_range(packed: &[u8], bits: u32, start: usize, count: usize) -> Vec<u8> {
    let mut out = vec![0u8; count];
    unpack_codes_into(packed, bits, start, &mut out);
    out
}

// Byte-indexed decode tables, built at compile time: table[b] is the
// codes a whole byte `b` expands to at that width (8/4/2 codes for
// 1/2/4-bit). One 256-entry load replaces per-code shift/mask chains and
// feeds the group unpacker a fixed-size store the compiler vectorizes.
static LUT1: [[u8; 8]; 256] = build_lut::<8>(1);
static LUT2: [[u8; 4]; 256] = build_lut::<4>(2);
static LUT4: [[u8; 2]; 256] = build_lut::<2>(4);

const fn build_lut<const N: usize>(bits: u32) -> [[u8; N]; 256] {
    let mask = ((1u16 << bits) - 1) as u8;
    let mut t = [[0u8; N]; 256];
    let mut b = 0usize;
    while b < 256 {
        let mut j = 0usize;
        while j < N {
            t[b][j] = ((b >> (j as u32 * bits)) as u8) & mask;
            j += 1;
        }
        b += 1;
    }
    t
}

/// The LUT-based group unpacker: decode `out.len()` codes starting at
/// code index `start` into `out`, allocation-free.
///
/// Layout guarantee exploited: for bits ∈ {1, 2, 4, 8} a code boundary
/// falls on a byte boundary every 8/bits codes; for bits = 3 every 8
/// codes span exactly 3 bytes. So the body decodes a scalar prefix until
/// the stream is byte-aligned, then whole bytes through [`LUT1`]/
/// [`LUT2`]/[`LUT4`] (or 3-byte → 8-code groups for 3-bit, a plain copy
/// for 8-bit), then a scalar tail. Widths 5/6/7 stay scalar — no stored
/// format uses them.
pub fn unpack_codes_into(packed: &[u8], bits: u32, start: usize, out: &mut [u8]) {
    assert!((1..=8).contains(&bits));
    let count = out.len();
    let bits_us = bits as usize;
    // scalar prefix: decode until the bit cursor is byte-aligned
    let mut done = 0usize;
    while done < count && (start + done) * bits_us % 8 != 0 {
        out[done] = unpack_one(packed, bits, start + done);
        done += 1;
    }
    let mut byte = (start + done) * bits_us / 8;
    match bits {
        1 | 2 | 4 => {
            let per = 8 / bits_us;
            while count - done >= per {
                let group = &mut out[done..done + per];
                match bits {
                    1 => group.copy_from_slice(&LUT1[packed[byte] as usize]),
                    2 => group.copy_from_slice(&LUT2[packed[byte] as usize]),
                    _ => group.copy_from_slice(&LUT4[packed[byte] as usize]),
                }
                byte += 1;
                done += per;
            }
        }
        3 => {
            // 8 codes per 3 bytes: one u32 window, eight fixed shifts
            while count - done >= 8 {
                let w = packed[byte] as u32
                    | (packed[byte + 1] as u32) << 8
                    | (packed[byte + 2] as u32) << 16;
                let group = &mut out[done..done + 8];
                group[0] = (w & 7) as u8;
                group[1] = ((w >> 3) & 7) as u8;
                group[2] = ((w >> 6) & 7) as u8;
                group[3] = ((w >> 9) & 7) as u8;
                group[4] = ((w >> 12) & 7) as u8;
                group[5] = ((w >> 15) & 7) as u8;
                group[6] = ((w >> 18) & 7) as u8;
                group[7] = ((w >> 21) & 7) as u8;
                byte += 3;
                done += 8;
            }
        }
        8 => {
            out[done..count].copy_from_slice(&packed[byte..byte + (count - done)]);
            done = count;
        }
        _ => {}
    }
    // scalar tail (and the whole body for widths 5/6/7)
    while done < count {
        out[done] = unpack_one(packed, bits, start + done);
        done += 1;
    }
}

/// Decode `out.len()` codes starting at code index `start` directly as
/// f32 values — the dequant kernels' first pass. Codes stream through a
/// small stack tile, so the call is allocation-free; tile size is a
/// multiple of 8 codes so chunk boundaries preserve byte alignment for
/// every bitwidth.
pub fn unpack_codes_f32_into(packed: &[u8], bits: u32, start: usize, out: &mut [f32]) {
    const TILE: usize = 64;
    let mut tile = [0u8; TILE];
    let mut done = 0usize;
    while done < out.len() {
        let take = (out.len() - done).min(TILE);
        unpack_codes_into(packed, bits, start + done, &mut tile[..take]);
        for (o, &c) in out[done..done + take].iter_mut().zip(&tile[..take]) {
            *o = c as f32;
        }
        done += take;
    }
}

/// Extract the single code at index `idx` (the scalar prefix/tail path).
#[inline]
fn unpack_one(packed: &[u8], bits: u32, idx: usize) -> u8 {
    let mask = if bits == 8 { 0xFF } else { (1u16 << bits) - 1 };
    let bitpos = idx * bits as usize;
    let byte = bitpos / 8;
    let off = bitpos % 8;
    let mut v = (packed[byte] >> off) as u16;
    if off + bits as usize > 8 {
        v |= (packed[byte + 1] as u16) << (8 - off);
    }
    (v & mask) as u8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;

    #[test]
    fn roundtrip_all_bitwidths() {
        let mut rng = Rng::new(99);
        for bits in 1..=8u32 {
            for len in [0usize, 1, 7, 8, 9, 63, 64, 100] {
                let codes: Vec<u8> =
                    (0..len).map(|_| (rng.next_u64() & ((1 << bits) - 1)) as u8).collect();
                let packed = pack_codes(&codes, bits);
                assert_eq!(packed.len(), (len * bits as usize).div_ceil(8));
                assert_eq!(unpack_codes(&packed, bits, len), codes, "bits={bits} len={len}");
            }
        }
    }

    #[test]
    fn layout_matches_kernel_2bit() {
        // codes [1,2,3,0] -> byte 0b00_11_10_01 = 0x39
        let packed = pack_codes(&[1, 2, 3, 0], 2);
        assert_eq!(packed, vec![0b0011_1001]);
    }

    #[test]
    fn layout_matches_kernel_1bit() {
        // bit j at position j%8, bit=1 <=> code 1
        let packed = pack_codes(&[1, 0, 0, 0, 0, 0, 0, 1], 1);
        assert_eq!(packed, vec![0b1000_0001]);
    }

    #[test]
    fn range_unpack_matches_full_unpack() {
        let mut rng = Rng::new(101);
        for bits in 1..=8u32 {
            let codes: Vec<u8> =
                (0..97).map(|_| (rng.next_u64() & ((1 << bits) - 1)) as u8).collect();
            let packed = pack_codes(&codes, bits);
            for (start, count) in [(0usize, 97usize), (1, 10), (7, 13), (32, 65), (96, 1), (50, 0)]
            {
                assert_eq!(
                    unpack_codes_range(&packed, bits, start, count),
                    codes[start..start + count].to_vec(),
                    "bits={bits} start={start} count={count}"
                );
            }
        }
    }

    #[test]
    fn three_bit_straddles_bytes() {
        let codes = vec![0b111, 0b101, 0b010, 0b110, 0b001];
        let packed = pack_codes(&codes, 3);
        assert_eq!(unpack_codes(&packed, 3, 5), codes);
        assert_eq!(packed.len(), 2);
    }

    /// Exhaustive cross-check of the LUT group unpacker against the
    /// single-code scalar extractor, sweeping every alignment the scalar
    /// prefix can see (all starts 0..17, ragged counts).
    #[test]
    fn lut_unpacker_matches_scalar_at_every_offset() {
        let mut rng = Rng::new(202);
        for bits in 1..=8u32 {
            let codes: Vec<u8> =
                (0..131).map(|_| (rng.next_u64() & ((1 << bits) - 1)) as u8).collect();
            let packed = pack_codes(&codes, bits);
            for start in 0..17usize {
                for count in [0usize, 1, 3, 7, 8, 9, 24, 63, 64, 65, 100] {
                    if start + count > codes.len() {
                        continue;
                    }
                    let mut out = vec![0xAAu8; count];
                    unpack_codes_into(&packed, bits, start, &mut out);
                    assert_eq!(
                        out,
                        &codes[start..start + count],
                        "bits={bits} start={start} count={count}"
                    );
                }
            }
        }
    }

    #[test]
    fn f32_unpack_matches_u8_unpack_across_tile_boundaries() {
        let mut rng = Rng::new(203);
        for bits in [1u32, 2, 3, 4, 8] {
            let codes: Vec<u8> =
                (0..200).map(|_| (rng.next_u64() & ((1 << bits) - 1)) as u8).collect();
            let packed = pack_codes(&codes, bits);
            // counts straddling the 64-code stack tile, at odd starts
            for (start, count) in [(0usize, 200usize), (5, 130), (7, 64), (3, 65), (11, 127)] {
                let mut out = vec![f32::NAN; count];
                unpack_codes_f32_into(&packed, bits, start, &mut out);
                let want: Vec<f32> =
                    codes[start..start + count].iter().map(|&c| c as f32).collect();
                assert_eq!(out, want, "bits={bits} start={start} count={count}");
            }
        }
    }
}
