//! Sign binarization with L1-optimal group scales (paper §3.2, Eq. 8;
//! Rastegari et al., 2016).

use super::{pack_codes, unpack_codes, unpack_codes_f32_into, SCALE_BITS};
use crate::tensor::{DequantRows, Matrix};

/// A group-wise sign-binarized matrix (grouping along the last axis).
#[derive(Debug, Clone)]
pub struct BinQuantized {
    pub rows: usize,
    pub cols: usize,
    pub group: usize,
    /// Packed sign bits (bit = 1 ⇔ +1), row-major.
    pub packed: Vec<u8>,
    /// L1-mean scale per (row, group).
    pub scale: Vec<f32>,
}

impl BinQuantized {
    pub fn groups_per_row(&self) -> usize {
        self.cols.div_ceil(self.group)
    }

    /// Storage cost in bits under the paper's Eq. 10 accounting (actual
    /// per-row groups).
    pub fn storage_bits(&self) -> u64 {
        let groups = (self.rows * self.groups_per_row()) as u64;
        (self.rows * self.cols) as u64 + groups * SCALE_BITS
    }

    /// In-memory packed size in bytes (sign bits + fp16 scales).
    pub fn packed_bytes(&self) -> usize {
        self.packed.len() + self.scale.len() * (SCALE_BITS as usize / 8)
    }

    /// Dequantize one stored row into `out` (`out.len() == cols`) without
    /// touching any other row — the streaming-GEMM building block.
    /// Allocation-free: sign bits decode straight into `out` as f32 via
    /// the LUT group unpacker, then the branchless `S * (2c - 1)` maps
    /// code 1 → exactly `S` and code 0 → exactly `-S` (multiplying by
    /// ±1.0 is exact), bit-identical to the branching form.
    pub fn dequant_row_into(&self, i: usize, out: &mut [f32]) {
        debug_assert!(i < self.rows);
        debug_assert_eq!(out.len(), self.cols);
        unpack_codes_f32_into(&self.packed, 1, i * self.cols, out);
        let gpr = self.groups_per_row();
        for g in 0..gpr {
            let s = self.scale[i * gpr + g];
            for v in &mut out[g * self.group..((g + 1) * self.group).min(self.cols)] {
                *v = s * (2.0 * *v - 1.0);
            }
        }
    }
}

impl DequantRows for BinQuantized {
    fn src_rows(&self) -> usize {
        self.rows
    }

    fn src_cols(&self) -> usize {
        self.cols
    }

    fn dequant_row_into(&self, i: usize, out: &mut [f32]) {
        BinQuantized::dequant_row_into(self, i, out)
    }
}

/// Binarize `w` group-wise: `sign(w)` with `S = mean |w|` per group.
pub fn bin_quant(w: &Matrix, group: usize) -> BinQuantized {
    assert!(group > 0);
    let (rows, cols) = w.shape();
    let gpr = cols.div_ceil(group);
    let mut bits = Vec::with_capacity(rows * cols);
    let mut scale = Vec::with_capacity(rows * gpr);
    for i in 0..rows {
        let row = w.row(i);
        for g in 0..gpr {
            let chunk = &row[g * group..((g + 1) * group).min(cols)];
            let s = chunk.iter().map(|v| v.abs()).sum::<f32>() / chunk.len() as f32;
            scale.push(s);
            for &v in chunk {
                bits.push(u8::from(v >= 0.0));
            }
        }
    }
    BinQuantized { rows, cols, group, packed: pack_codes(&bits, 1), scale }
}

/// Dequantize: `S * sign`.
pub fn bin_dequant(q: &BinQuantized) -> Matrix {
    let bits = unpack_codes(&q.packed, 1, q.rows * q.cols);
    let gpr = q.groups_per_row();
    let mut out = Matrix::zeros(q.rows, q.cols);
    for i in 0..q.rows {
        let row = out.row_mut(i);
        for g in 0..gpr {
            let s = q.scale[i * gpr + g];
            for j in g * q.group..((g + 1) * q.group).min(q.cols) {
                row[j] = if bits[i * q.cols + j] == 1 { s } else { -s };
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;

    #[test]
    fn sign_preserved() {
        let mut rng = Rng::new(31);
        let w = rng.matrix(8, 128, 1.0);
        let wd = bin_dequant(&bin_quant(&w, 64));
        for (a, b) in w.data().iter().zip(wd.data()) {
            assert_eq!(*a >= 0.0, *b >= 0.0);
        }
    }

    /// The L1-mean scale minimizes ||W - S*sign(W)||_F over S (Rastegari
    /// et al. 2016): check against a scan of nearby scales.
    #[test]
    fn l1_scale_is_optimal() {
        let mut rng = Rng::new(32);
        let w = rng.matrix(1, 64, 1.0);
        let q = bin_quant(&w, 64);
        let err = bin_dequant(&q).sub(&w).fro_norm();
        for factor in [0.8, 0.9, 1.1, 1.2] {
            let mut alt = q.clone();
            alt.scale[0] *= factor;
            let alt_err = bin_dequant(&alt).sub(&w).fro_norm();
            assert!(alt_err >= err, "factor {factor}: {alt_err} < {err}");
        }
    }

    #[test]
    fn never_collapses_to_zero() {
        // Unlike 1-bit RTN, sign binarization keeps every weight at ±S
        // (the paper's argument for Eq. 8 over Eq. 6 at 1 bit).
        let mut rng = Rng::new(33);
        let w = rng.matrix(4, 64, 0.5);
        let wd = bin_dequant(&bin_quant(&w, 32));
        assert!(wd.data().iter().all(|&v| v != 0.0));
    }

    #[test]
    fn ragged_group() {
        let mut rng = Rng::new(34);
        let w = rng.matrix(2, 70, 1.0);
        let q = bin_quant(&w, 64);
        assert_eq!(q.groups_per_row(), 2);
        assert_eq!(bin_dequant(&q).shape(), (2, 70));
    }

    #[test]
    fn row_dequant_matches_full_dequant() {
        let mut rng = Rng::new(35);
        let w = rng.matrix(4, 70, 1.0); // ragged: rows start mid-byte
        let q = bin_quant(&w, 32);
        let full = bin_dequant(&q);
        let mut row = vec![0.0f32; q.cols];
        for i in 0..q.rows {
            q.dequant_row_into(i, &mut row);
            assert_eq!(row.as_slice(), full.row(i), "row {i}");
        }
    }

    #[test]
    fn storage_accounting() {
        let w = Matrix::zeros(16, 128);
        let q = bin_quant(&w, 128);
        // 16*128 sign bits + 16 groups * 16-bit scale
        assert_eq!(q.storage_bits(), 16 * 128 + 16 * 16);
    }
}
