//! Group-wise quantization substrate: RTN (1–8 bits), sign binarization,
//! bit-packing, quantization-axis handling, and the paper's average-bits
//! accounting (Eq. 10).
//!
//! Conventions (identical to python/compile/kernels/ref.py — the oracle):
//!
//! * Grouping is along the **last axis** (each row is cut into contiguous
//!   groups of `group` elements). Column-wise quantization is expressed by
//!   transposing first (see [`axis`]).
//! * RTN: `dequant(q) = S * (q - Z)` with `S = (max-min)/(2^k-1)`,
//!   `Z = round(-min/S)`, codes clipped to `[0, 2^k-1]` (paper Eqs. 6–7).
//! * Binary: `sign(w) * S` with the L1-optimal `S = mean |w|` per group
//!   (paper Eq. 8, XNOR-Net).
//! * Storage cost (Eq. 10 accounting): each k-bit code costs k bits, each
//!   group stores an fp16 scale (16 bits) and — RTN only — a k-bit integer
//!   zero-point. This reproduces the paper's 2.14 (RTN-2, g=128) and 1.125
//!   (BIN, g=128) average bitwidths exactly.

pub mod axis;
mod binary;
mod pack;
mod rtn;

pub use axis::{Axis, QuantAxis};
pub use binary::{bin_dequant, bin_quant, BinQuantized};
pub use pack::{
    pack_codes, unpack_codes, unpack_codes_f32_into, unpack_codes_into, unpack_codes_range,
};
pub use rtn::{rtn_dequant, rtn_quant, RtnQuantized};

/// Bits of an fp16 scale / zero-point, for Eq. 10 accounting.
pub const SCALE_BITS: u64 = 16;

/// Storage cost in bits of a group-wise RTN quantization of `count` weights
/// at `bits` bits with groups of `group` (scale fp16 + k-bit zero per group).
pub fn rtn_storage_bits(count: usize, bits: u32, group: usize) -> u64 {
    let groups = count.div_ceil(group) as u64;
    count as u64 * bits as u64 + groups * (SCALE_BITS + bits as u64)
}

/// Storage cost in bits of group-wise sign binarization (scale fp16/group).
pub fn bin_storage_bits(count: usize, group: usize) -> u64 {
    let groups = count.div_ceil(group) as u64;
    count as u64 + groups * SCALE_BITS
}

/// Average bits per parameter given total storage bits and parameter count
/// (paper Eq. 10).
pub fn avg_bits(total_bits: u64, params: usize) -> f64 {
    total_bits as f64 / params as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_accounting_examples() {
        // Paper Table 1: RTN 2-bit @ group 128 -> 2.14 avg bits.
        let bits = rtn_storage_bits(128 * 100, 2, 128);
        let avg = avg_bits(bits, 128 * 100);
        assert!((avg - 2.140625).abs() < 1e-9, "rtn2 {avg}");
        // BIN @ group 128 -> 1.125.
        let avg = avg_bits(bin_storage_bits(128 * 100, 128), 128 * 100);
        assert!((avg - 1.125).abs() < 1e-9, "bin {avg}");
    }

    #[test]
    fn partial_group_rounds_up() {
        // 130 weights, group 128 -> 2 groups.
        let bits = rtn_storage_bits(130, 2, 128);
        assert_eq!(bits, 130 * 2 + 2 * (16 + 2));
    }
}
