//! Serving metrics: log-scale latency histogram + throughput counters,
//! plus the Prometheus-registry builder for the `/metrics` exposition
//! (DESIGN.md §16).

use super::pool::WorkerSnapshot;
use crate::obs::MetricsRegistry;
use std::time::Duration;

/// Log-bucketed latency histogram (1µs … ~17min, 2× buckets).
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: Vec<u64>, // bucket i: [2^i, 2^{i+1}) microseconds
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self { buckets: vec![0; 30], count: 0, sum_us: 0, max_us: 0 }
    }

    pub fn record(&mut self, d: Duration) {
        // Saturate: a pathological duration (> u64::MAX µs) lands in the
        // top bucket instead of wrapping into a small one.
        let us = u64::try_from(d.as_micros()).unwrap_or(u64::MAX).max(1);
        let idx = (63 - us.leading_zeros() as usize).min(self.buckets.len() - 1);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us = self.sum_us.saturating_add(us);
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us / self.count)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us)
    }

    /// Full bucket export for the Prometheus exposition
    /// (DESIGN.md §16): ascending `(upper edge µs, cumulative count)`
    /// pairs with trailing empty buckets trimmed. The `+Inf` row is
    /// appended by the renderer ([`crate::obs::prom`]).
    pub fn bucket_export(&self) -> Vec<(u64, u64)> {
        let last = self.buckets.iter().rposition(|&c| c > 0).map_or(0, |i| i + 1);
        let mut acc = 0;
        self.buckets[..last]
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                acc += c;
                (1u64 << (i + 1), acc)
            })
            .collect()
    }

    /// Running sum in microseconds (the exposition's `_sum`).
    pub fn sum_us(&self) -> u64 {
        self.sum_us
    }

    /// Fold another histogram into this one (per-worker aggregation).
    pub fn absorb(&mut self, other: &Histogram) {
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }

    /// Approximate quantile from bucket boundaries. Reports the
    /// **upper edge** of the bucket holding the target rank — bucket
    /// `i` spans `[2^i, 2^{i+1})` µs, so the result over-reports the
    /// true quantile by up to 2×. Assertions should use exact stats
    /// ([`LatencyStats`], the per-stage
    /// [`crate::obs::StageBreakdown`] sums); this histogram exists for
    /// cheap streaming aggregation and the `/metrics` exposition.
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        // rank clamp mirrors LatencyStats::quantile: q = 0.0 must land
        // on the first *occupied* bucket, not bucket 0's upper edge
        let target = ((q * self.count as f64).ceil() as u64).max(1);
        let mut acc = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Duration::from_micros(1u64 << (i + 1));
            }
        }
        self.max()
    }
}

/// Aggregate serving metrics (owned by the server loop; snapshot on read).
#[derive(Debug, Clone, Default)]
pub struct ServerMetrics {
    pub e2e_latency: Option<Histogram>,
    pub exec_latency: Option<Histogram>,
    pub merge_latency: Option<Histogram>,
    /// Submission → first generated token, per request (continuous
    /// scheduler; admission wait + prefill).
    pub ttft_latency: Option<Histogram>,
    pub requests: u64,
    /// Decode groups (continuous scheduler: one group may span several
    /// released batches whose requests share one session).
    pub batches: u64,
    /// Batches decoded on the factor-form path (unmerged base weights +
    /// activation-path deltas); the remainder ran on merged weights.
    pub factor_batches: u64,
    pub tokens_generated: u64,
    /// Step forward passes (the virtual decode-step count; DESIGN.md §11
    /// — the continuous-vs-lockstep acceptance observable).
    pub decode_steps: u64,
    /// Prefill/admission forward passes.
    pub prefill_passes: u64,
    /// Requests retired past their deadline (DESIGN.md §15).
    pub timeouts: u64,
    /// Requests retired by a cancel token.
    pub cancellations: u64,
    /// Requests shed at admission (`Overloaded`; queue depth cap).
    pub sheds: u64,
}

impl ServerMetrics {
    pub fn new() -> Self {
        Self {
            e2e_latency: Some(Histogram::new()),
            exec_latency: Some(Histogram::new()),
            merge_latency: Some(Histogram::new()),
            ttft_latency: Some(Histogram::new()),
            ..Default::default()
        }
    }

    /// Fold another worker's metrics into this one. The coordinator's
    /// `metrics()` reports the pool-wide aggregate by absorbing every
    /// worker snapshot into a fresh `ServerMetrics`.
    pub fn absorb(&mut self, other: &ServerMetrics) {
        fn merge_hist(dst: &mut Option<Histogram>, src: &Option<Histogram>) {
            match (dst.as_mut(), src) {
                (Some(d), Some(s)) => d.absorb(s),
                (None, Some(s)) => *dst = Some(s.clone()),
                _ => {}
            }
        }
        merge_hist(&mut self.e2e_latency, &other.e2e_latency);
        merge_hist(&mut self.exec_latency, &other.exec_latency);
        merge_hist(&mut self.merge_latency, &other.merge_latency);
        merge_hist(&mut self.ttft_latency, &other.ttft_latency);
        self.requests += other.requests;
        self.batches += other.batches;
        self.factor_batches += other.factor_batches;
        self.tokens_generated += other.tokens_generated;
        self.decode_steps += other.decode_steps;
        self.prefill_passes += other.prefill_passes;
        self.timeouts += other.timeouts;
        self.cancellations += other.cancellations;
        self.sheds += other.sheds;
    }

    /// Mean batch occupancy.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    /// One-line human-readable summary. A `Default`-constructed value
    /// has no histograms; render a zero-count summary instead of
    /// panicking.
    pub fn summary(&self) -> String {
        let zero = Histogram::new();
        let e2e = self.e2e_latency.as_ref().unwrap_or(&zero);
        format!(
            "requests={} batches={} (factor={}) mean_batch={:.2} steps={} p50={:?} p95={:?} p99={:?} mean={:?}",
            self.requests,
            self.batches,
            self.factor_batches,
            self.mean_batch_size(),
            self.decode_steps,
            e2e.quantile(0.5),
            e2e.quantile(0.95),
            e2e.quantile(0.99),
            e2e.mean(),
        )
    }
}

/// Build the pool's Prometheus registry from per-worker snapshots
/// (DESIGN.md §16): pool-wide counters and full-bucket latency
/// histograms, per-worker occupancy gauges (queue depth, parked
/// requests, merge/fetch in-flight, cache bytes), plus the quarantine
/// gauge and the trace ring-buffer drop counter. Deterministic given
/// the snapshots — rendering sorts by name and label.
pub fn pool_registry(
    snaps: &[WorkerSnapshot],
    quarantined: usize,
    trace_dropped: Option<u64>,
) -> MetricsRegistry {
    let mut total = ServerMetrics::new();
    for s in snaps {
        total.absorb(&s.metrics);
    }
    let mut reg = MetricsRegistry::new();
    for (name, help, v) in [
        ("lq_requests_total", "Requests retired successfully.", total.requests),
        ("lq_tokens_generated_total", "Generated tokens across all requests.", total.tokens_generated),
        ("lq_batches_total", "Decode batches/groups executed.", total.batches),
        ("lq_factor_batches_total", "Batches decoded on the factor-form path.", total.factor_batches),
        ("lq_decode_steps_total", "Decode-step forward passes.", total.decode_steps),
        ("lq_prefill_passes_total", "Prefill/admission forward passes.", total.prefill_passes),
        ("lq_timeouts_total", "Requests retired past their deadline.", total.timeouts),
        ("lq_cancellations_total", "Requests retired by a cancel token.", total.cancellations),
        ("lq_sheds_total", "Requests shed at admission (queue cap).", total.sheds),
    ] {
        reg.counter(name, help, &[], v);
    }
    for (name, help, h) in [
        ("lq_e2e_latency_us", "End-to-end request latency (µs).", &total.e2e_latency),
        ("lq_ttft_latency_us", "Submission to first token (µs).", &total.ttft_latency),
        ("lq_exec_latency_us", "Batch/group execution latency (µs).", &total.exec_latency),
        ("lq_merge_latency_us", "Host dequant+merge latency (µs).", &total.merge_latency),
    ] {
        if let Some(h) = h {
            reg.histogram(name, help, &[], h.bucket_export(), h.sum_us() as f64, h.count());
        }
    }
    for s in snaps {
        let w = s.worker.to_string();
        let labels: &[(&str, &str)] = &[("worker", &w)];
        for (name, help, v) in [
            ("lq_queue_depth", "Admission-queued requests.", s.queued_requests as f64),
            ("lq_parked_requests", "Requests parked behind merges/fetches.", s.parked_requests as f64),
            ("lq_inflight_merges", "Adapters with a merge in flight.", s.inflight_merges as f64),
            ("lq_held_merges", "Merge completions held by the ingest sequencer.", s.held_merges as f64),
            ("lq_inflight_fetches", "Adapters with a disk-tier fetch in flight.", s.inflight_fetches as f64),
            ("lq_cache_bytes", "Merged-weight cache bytes resident.", s.cache_used_bytes as f64),
            ("lq_cache_entries", "Adapters with merged weights cached.", s.cached_adapters as f64),
            ("lq_factor_cache_bytes", "Packed-factor cache bytes resident.", s.factor_cache_used_bytes as f64),
        ] {
            reg.gauge(name, help, labels, v);
        }
        for (name, help, v) in [
            ("lq_cache_hits_total", "Merged-weight cache hits.", s.cache.hits),
            ("lq_cache_misses_total", "Merged-weight cache misses.", s.cache.misses),
            ("lq_cache_evictions_total", "Merged-weight cache evictions.", s.cache.evictions),
            ("lq_factor_cache_hits_total", "Packed-factor cache hits.", s.factor_cache.hits),
            ("lq_factor_cache_misses_total", "Packed-factor cache misses.", s.factor_cache.misses),
            ("lq_factor_cache_evictions_total", "Packed-factor cache evictions.", s.factor_cache.evictions),
        ] {
            reg.counter(name, help, labels, v);
        }
    }
    reg.gauge(
        "lq_quarantined_adapters",
        "Adapters quarantined after permanent load failure.",
        &[],
        quarantined as f64,
    );
    if let Some(d) = trace_dropped {
        reg.counter(
            "lq_trace_dropped_spans_total",
            "Trace spans discarded to ring-buffer overflow.",
            &[],
            d,
        );
    }
    reg
}

/// Exact order statistics over a set of latency samples — the scenario
/// simulator's per-adapter summary unit. Unlike [`Histogram`] (log-scale
/// buckets, built for cheap streaming aggregation), this sorts the raw
/// samples, so golden-trace assertions get exact, reproducible
/// percentiles instead of bucket upper edges.
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    /// Sorted samples, microseconds.
    sorted_us: Vec<u64>,
}

impl LatencyStats {
    pub fn from_samples(samples: &[Duration]) -> Self {
        // Saturating, like Histogram::record: never wrap a pathological
        // duration into a small sample.
        let mut sorted_us: Vec<u64> = samples
            .iter()
            .map(|d| u64::try_from(d.as_micros()).unwrap_or(u64::MAX))
            .collect();
        sorted_us.sort_unstable();
        Self { sorted_us }
    }

    pub fn count(&self) -> usize {
        self.sorted_us.len()
    }

    /// Exact quantile (nearest-rank: smallest sample with cumulative
    /// frequency ≥ q). Zero on an empty set.
    pub fn quantile(&self, q: f64) -> Duration {
        if self.sorted_us.is_empty() {
            return Duration::ZERO;
        }
        let n = self.sorted_us.len();
        let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
        Duration::from_micros(self.sorted_us[rank - 1])
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.sorted_us.last().copied().unwrap_or(0))
    }

    pub fn mean(&self) -> Duration {
        if self.sorted_us.is_empty() {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sorted_us.iter().sum::<u64>() / self.sorted_us.len() as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_stats_exact_percentiles() {
        let samples: Vec<Duration> = (1..=100).map(Duration::from_micros).collect();
        let s = LatencyStats::from_samples(&samples);
        assert_eq!(s.count(), 100);
        assert_eq!(s.quantile(0.5), Duration::from_micros(50));
        assert_eq!(s.quantile(0.95), Duration::from_micros(95));
        assert_eq!(s.quantile(1.0), Duration::from_micros(100));
        assert_eq!(s.quantile(0.0), Duration::from_micros(1), "rank clamps to the first sample");
        assert_eq!(s.max(), Duration::from_micros(100));
        assert_eq!(s.mean(), Duration::from_micros(50)); // 5050/100 truncated
    }

    #[test]
    fn latency_stats_empty_and_unsorted_input() {
        let s = LatencyStats::from_samples(&[]);
        assert_eq!(s.count(), 0);
        assert_eq!(s.quantile(0.99), Duration::ZERO);
        assert_eq!(s.mean(), Duration::ZERO);
        let s = LatencyStats::from_samples(&[
            Duration::from_micros(30),
            Duration::from_micros(10),
            Duration::from_micros(20),
        ]);
        assert_eq!(s.quantile(0.5), Duration::from_micros(20));
        assert_eq!(s.max(), Duration::from_micros(30));
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = Histogram::new();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        assert!(h.quantile(0.0) <= p50, "q=0.0 is the distribution minimum");
        assert!(h.mean() >= Duration::from_micros(400));
        assert!(h.max() >= Duration::from_micros(1000));

        // regression: on a histogram whose smallest sample is large,
        // q = 0.0 must report that sample's bucket, not bucket 0's
        // upper edge (2µs) via a zero target rank
        let mut big = Histogram::new();
        big.record(Duration::from_micros(1000));
        assert!(
            big.quantile(0.0) >= Duration::from_micros(1000),
            "q=0.0 fell below the only sample: {:?}",
            big.quantile(0.0)
        );
        assert_eq!(big.quantile(0.0), big.quantile(1.0));
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.99), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }

    #[test]
    fn summary_on_default_metrics_does_not_panic() {
        // regression: summary() unwrapped e2e_latency, which is None on
        // a Default-constructed value
        let s = ServerMetrics::default().summary();
        assert!(s.contains("requests=0"), "zero-count summary expected: {s}");
        let s = ServerMetrics::new().summary();
        assert!(s.contains("requests=0"));
    }

    #[test]
    fn record_saturates_pathological_durations() {
        // regression: `d.as_micros() as u64` wrapped u128 → u64, filing
        // a ~584-million-year duration into a small bucket
        let mut h = Histogram::new();
        h.record(Duration::MAX);
        assert_eq!(h.max(), Duration::from_micros(u64::MAX));
        // the sample lands in the *top* bucket, whose upper edge is
        // what quantile reports
        assert_eq!(h.quantile(1.0), Duration::from_micros(1 << 30));
        let s = LatencyStats::from_samples(&[Duration::MAX]);
        assert_eq!(s.max(), Duration::from_micros(u64::MAX));
    }

    #[test]
    fn quantile_reports_bucket_upper_edge() {
        // Documented contract (DESIGN.md §16): the histogram quantile is
        // the holding bucket's upper edge — up to 2× above the true
        // value — so exact per-stage stats are the assertion source of
        // truth, not this.
        let mut h = Histogram::new();
        h.record(Duration::from_micros(3)); // bucket [2,4)
        assert_eq!(h.quantile(1.0), Duration::from_micros(4));
    }

    #[test]
    fn bucket_export_is_cumulative_and_trimmed() {
        let mut h = Histogram::new();
        h.record(Duration::from_micros(1)); // bucket [1,2) → edge 2
        h.record(Duration::from_micros(3)); // bucket [2,4) → edge 4
        h.record(Duration::from_micros(3));
        let buckets = h.bucket_export();
        assert_eq!(buckets, vec![(2, 1), (4, 3)]);
        assert!(Histogram::new().bucket_export().is_empty());
        assert_eq!(h.sum_us(), 7);
    }

    #[test]
    fn pool_registry_renders_golden() {
        use super::super::cache::CacheStats;
        let mut m = ServerMetrics::new();
        m.requests = 2;
        m.tokens_generated = 5;
        m.batches = 1;
        m.e2e_latency.as_mut().unwrap().record(Duration::from_micros(3));
        let snap = WorkerSnapshot {
            worker: 0,
            metrics: m,
            cache: CacheStats { hits: 4, misses: 1, evictions: 0 },
            cache_used_bytes: 1024,
            cached_adapters: 1,
            queued_requests: 2,
            next_release_in: None,
            inflight_merges: 1,
            parked_requests: 3,
            held_merges: 0,
            inflight_fetches: 0,
            factor_cache: CacheStats::default(),
            factor_cache_used_bytes: 0,
        };
        let reg = pool_registry(&[snap], 1, Some(0));
        let text = reg.render();
        // line order is stable (BTreeMap by name, then label) — pin a
        // representative slice of the exposition
        for line in [
            "# TYPE lq_e2e_latency_us histogram",
            "lq_e2e_latency_us_bucket{le=\"4\"} 1",
            "lq_e2e_latency_us_bucket{le=\"+Inf\"} 1",
            "lq_e2e_latency_us_sum 3",
            "lq_e2e_latency_us_count 1",
            "lq_requests_total 2",
            "lq_tokens_generated_total 5",
            "lq_queue_depth{worker=\"0\"} 2",
            "lq_parked_requests{worker=\"0\"} 3",
            "lq_inflight_merges{worker=\"0\"} 1",
            "lq_cache_bytes{worker=\"0\"} 1024",
            "lq_cache_hits_total{worker=\"0\"} 4",
            "lq_quarantined_adapters 1",
            "lq_trace_dropped_spans_total 0",
        ] {
            assert!(text.contains(line), "missing `{line}` in:\n{text}");
        }
        // rendering is a pure function of the snapshots
        let reg2 = pool_registry(
            &[WorkerSnapshot {
                worker: 0,
                metrics: {
                    let mut m = ServerMetrics::new();
                    m.requests = 2;
                    m.tokens_generated = 5;
                    m.batches = 1;
                    m.e2e_latency.as_mut().unwrap().record(Duration::from_micros(3));
                    m
                },
                cache: CacheStats { hits: 4, misses: 1, evictions: 0 },
                cache_used_bytes: 1024,
                cached_adapters: 1,
                queued_requests: 2,
                next_release_in: None,
                inflight_merges: 1,
                parked_requests: 3,
                held_merges: 0,
                inflight_fetches: 0,
                factor_cache: CacheStats::default(),
                factor_cache_used_bytes: 0,
            }],
            1,
            Some(0),
        );
        assert_eq!(text, reg2.render());
    }

    #[test]
    fn batch_occupancy() {
        let mut m = ServerMetrics::new();
        m.requests = 10;
        m.batches = 4;
        assert!((m.mean_batch_size() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_absorb_sums() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for i in 1..=10u64 {
            a.record(Duration::from_micros(i));
            b.record(Duration::from_micros(i * 100));
        }
        a.absorb(&b);
        assert_eq!(a.count(), 20);
        assert_eq!(a.max(), Duration::from_micros(1000));
        assert!(a.mean() >= Duration::from_micros(200));
    }

    #[test]
    fn server_metrics_absorb_aggregates_workers() {
        let mut w0 = ServerMetrics::new();
        let mut w1 = ServerMetrics::new();
        w0.requests = 3;
        w0.batches = 2;
        w0.timeouts = 1;
        w0.e2e_latency.as_mut().unwrap().record(Duration::from_millis(1));
        w1.requests = 5;
        w1.batches = 1;
        w1.tokens_generated = 9;
        w1.cancellations = 2;
        w1.sheds = 4;
        w1.e2e_latency.as_mut().unwrap().record(Duration::from_millis(4));
        let mut total = ServerMetrics::new();
        total.absorb(&w0);
        total.absorb(&w1);
        assert_eq!(total.requests, 8);
        assert_eq!(total.batches, 3);
        assert_eq!(total.tokens_generated, 9);
        assert_eq!((total.timeouts, total.cancellations, total.sheds), (1, 2, 4));
        assert_eq!(total.e2e_latency.as_ref().unwrap().count(), 2);
    }
}
