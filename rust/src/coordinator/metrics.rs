//! Serving metrics: log-scale latency histogram + throughput counters.

use std::time::Duration;

/// Log-bucketed latency histogram (1µs … ~17min, 2× buckets).
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: Vec<u64>, // bucket i: [2^i, 2^{i+1}) microseconds
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self { buckets: vec![0; 30], count: 0, sum_us: 0, max_us: 0 }
    }

    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let idx = (63 - us.leading_zeros() as usize).min(self.buckets.len() - 1);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us / self.count)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us)
    }

    /// Fold another histogram into this one (per-worker aggregation).
    pub fn absorb(&mut self, other: &Histogram) {
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }

    /// Approximate quantile from bucket boundaries (upper edge).
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        // rank clamp mirrors LatencyStats::quantile: q = 0.0 must land
        // on the first *occupied* bucket, not bucket 0's upper edge
        let target = ((q * self.count as f64).ceil() as u64).max(1);
        let mut acc = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Duration::from_micros(1u64 << (i + 1));
            }
        }
        self.max()
    }
}

/// Aggregate serving metrics (owned by the server loop; snapshot on read).
#[derive(Debug, Clone, Default)]
pub struct ServerMetrics {
    pub e2e_latency: Option<Histogram>,
    pub exec_latency: Option<Histogram>,
    pub merge_latency: Option<Histogram>,
    /// Submission → first generated token, per request (continuous
    /// scheduler; admission wait + prefill).
    pub ttft_latency: Option<Histogram>,
    pub requests: u64,
    /// Decode groups (continuous scheduler: one group may span several
    /// released batches whose requests share one session).
    pub batches: u64,
    /// Batches decoded on the factor-form path (unmerged base weights +
    /// activation-path deltas); the remainder ran on merged weights.
    pub factor_batches: u64,
    pub tokens_generated: u64,
    /// Step forward passes (the virtual decode-step count; DESIGN.md §11
    /// — the continuous-vs-lockstep acceptance observable).
    pub decode_steps: u64,
    /// Prefill/admission forward passes.
    pub prefill_passes: u64,
    /// Requests retired past their deadline (DESIGN.md §15).
    pub timeouts: u64,
    /// Requests retired by a cancel token.
    pub cancellations: u64,
    /// Requests shed at admission (`Overloaded`; queue depth cap).
    pub sheds: u64,
}

impl ServerMetrics {
    pub fn new() -> Self {
        Self {
            e2e_latency: Some(Histogram::new()),
            exec_latency: Some(Histogram::new()),
            merge_latency: Some(Histogram::new()),
            ttft_latency: Some(Histogram::new()),
            ..Default::default()
        }
    }

    /// Fold another worker's metrics into this one. The coordinator's
    /// `metrics()` reports the pool-wide aggregate by absorbing every
    /// worker snapshot into a fresh `ServerMetrics`.
    pub fn absorb(&mut self, other: &ServerMetrics) {
        fn merge_hist(dst: &mut Option<Histogram>, src: &Option<Histogram>) {
            match (dst.as_mut(), src) {
                (Some(d), Some(s)) => d.absorb(s),
                (None, Some(s)) => *dst = Some(s.clone()),
                _ => {}
            }
        }
        merge_hist(&mut self.e2e_latency, &other.e2e_latency);
        merge_hist(&mut self.exec_latency, &other.exec_latency);
        merge_hist(&mut self.merge_latency, &other.merge_latency);
        merge_hist(&mut self.ttft_latency, &other.ttft_latency);
        self.requests += other.requests;
        self.batches += other.batches;
        self.factor_batches += other.factor_batches;
        self.tokens_generated += other.tokens_generated;
        self.decode_steps += other.decode_steps;
        self.prefill_passes += other.prefill_passes;
        self.timeouts += other.timeouts;
        self.cancellations += other.cancellations;
        self.sheds += other.sheds;
    }

    /// Mean batch occupancy.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        let e2e = self.e2e_latency.as_ref().unwrap();
        format!(
            "requests={} batches={} (factor={}) mean_batch={:.2} steps={} p50={:?} p95={:?} p99={:?} mean={:?}",
            self.requests,
            self.batches,
            self.factor_batches,
            self.mean_batch_size(),
            self.decode_steps,
            e2e.quantile(0.5),
            e2e.quantile(0.95),
            e2e.quantile(0.99),
            e2e.mean(),
        )
    }
}

/// Exact order statistics over a set of latency samples — the scenario
/// simulator's per-adapter summary unit. Unlike [`Histogram`] (log-scale
/// buckets, built for cheap streaming aggregation), this sorts the raw
/// samples, so golden-trace assertions get exact, reproducible
/// percentiles instead of bucket upper edges.
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    /// Sorted samples, microseconds.
    sorted_us: Vec<u64>,
}

impl LatencyStats {
    pub fn from_samples(samples: &[Duration]) -> Self {
        let mut sorted_us: Vec<u64> = samples.iter().map(|d| d.as_micros() as u64).collect();
        sorted_us.sort_unstable();
        Self { sorted_us }
    }

    pub fn count(&self) -> usize {
        self.sorted_us.len()
    }

    /// Exact quantile (nearest-rank: smallest sample with cumulative
    /// frequency ≥ q). Zero on an empty set.
    pub fn quantile(&self, q: f64) -> Duration {
        if self.sorted_us.is_empty() {
            return Duration::ZERO;
        }
        let n = self.sorted_us.len();
        let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
        Duration::from_micros(self.sorted_us[rank - 1])
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.sorted_us.last().copied().unwrap_or(0))
    }

    pub fn mean(&self) -> Duration {
        if self.sorted_us.is_empty() {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sorted_us.iter().sum::<u64>() / self.sorted_us.len() as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_stats_exact_percentiles() {
        let samples: Vec<Duration> = (1..=100).map(Duration::from_micros).collect();
        let s = LatencyStats::from_samples(&samples);
        assert_eq!(s.count(), 100);
        assert_eq!(s.quantile(0.5), Duration::from_micros(50));
        assert_eq!(s.quantile(0.95), Duration::from_micros(95));
        assert_eq!(s.quantile(1.0), Duration::from_micros(100));
        assert_eq!(s.quantile(0.0), Duration::from_micros(1), "rank clamps to the first sample");
        assert_eq!(s.max(), Duration::from_micros(100));
        assert_eq!(s.mean(), Duration::from_micros(50)); // 5050/100 truncated
    }

    #[test]
    fn latency_stats_empty_and_unsorted_input() {
        let s = LatencyStats::from_samples(&[]);
        assert_eq!(s.count(), 0);
        assert_eq!(s.quantile(0.99), Duration::ZERO);
        assert_eq!(s.mean(), Duration::ZERO);
        let s = LatencyStats::from_samples(&[
            Duration::from_micros(30),
            Duration::from_micros(10),
            Duration::from_micros(20),
        ]);
        assert_eq!(s.quantile(0.5), Duration::from_micros(20));
        assert_eq!(s.max(), Duration::from_micros(30));
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = Histogram::new();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        assert!(h.quantile(0.0) <= p50, "q=0.0 is the distribution minimum");
        assert!(h.mean() >= Duration::from_micros(400));
        assert!(h.max() >= Duration::from_micros(1000));

        // regression: on a histogram whose smallest sample is large,
        // q = 0.0 must report that sample's bucket, not bucket 0's
        // upper edge (2µs) via a zero target rank
        let mut big = Histogram::new();
        big.record(Duration::from_micros(1000));
        assert!(
            big.quantile(0.0) >= Duration::from_micros(1000),
            "q=0.0 fell below the only sample: {:?}",
            big.quantile(0.0)
        );
        assert_eq!(big.quantile(0.0), big.quantile(1.0));
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.99), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }

    #[test]
    fn batch_occupancy() {
        let mut m = ServerMetrics::new();
        m.requests = 10;
        m.batches = 4;
        assert!((m.mean_batch_size() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_absorb_sums() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for i in 1..=10u64 {
            a.record(Duration::from_micros(i));
            b.record(Duration::from_micros(i * 100));
        }
        a.absorb(&b);
        assert_eq!(a.count(), 20);
        assert_eq!(a.max(), Duration::from_micros(1000));
        assert!(a.mean() >= Duration::from_micros(200));
    }

    #[test]
    fn server_metrics_absorb_aggregates_workers() {
        let mut w0 = ServerMetrics::new();
        let mut w1 = ServerMetrics::new();
        w0.requests = 3;
        w0.batches = 2;
        w0.timeouts = 1;
        w0.e2e_latency.as_mut().unwrap().record(Duration::from_millis(1));
        w1.requests = 5;
        w1.batches = 1;
        w1.tokens_generated = 9;
        w1.cancellations = 2;
        w1.sheds = 4;
        w1.e2e_latency.as_mut().unwrap().record(Duration::from_millis(4));
        let mut total = ServerMetrics::new();
        total.absorb(&w0);
        total.absorb(&w1);
        assert_eq!(total.requests, 8);
        assert_eq!(total.batches, 3);
        assert_eq!(total.tokens_generated, 9);
        assert_eq!((total.timeouts, total.cancellations, total.sheds), (1, 2, 4));
        assert_eq!(total.e2e_latency.as_ref().unwrap().count(), 2);
    }
}
