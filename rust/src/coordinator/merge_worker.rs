//! The off-hot-path merge pipeline.
//!
//! Dequantize + merge is the expensive part of an adapter cache miss
//! (milliseconds of host compute); the device upload is cheap. The
//! executor pool therefore never merges inline: on a miss the batch parks
//! in the owning worker's per-adapter pending queue and a [`MergePool`]
//! thread produces the host-side merged weight list; only the upload runs
//! on the executor. Two different adapters' misses merge concurrently
//! (bounded by the pool size), so one cold tenant no longer stalls every
//! other tenant behind its merge.
//!
//! The pool is deliberately generic over the merge function: production
//! wires [`host_merge_fn`] (registry lookup → dequant → merge against the
//! shared base), while tests inject gated functions to prove concurrency
//! deterministically.

use super::registry::{AdapterId, AdapterRegistry, StoredAdapter};
use super::tier::AdapterTier;
use crate::adapter::fmt::Tensor;
use crate::clock::Clock;
use crate::model::{merge_adapter, BaseWeights};
use crate::obs::{SpanKind, TraceRecorder};
use anyhow::anyhow;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

/// State shared between the coordinator handle, the executor workers, and
/// the merge pool: the frozen base model plus the adapter registry.
pub(crate) struct Shared {
    pub base: BaseWeights,
    pub registry: RwLock<AdapterRegistry>,
    /// The disk tier, when adapter tiering is enabled.
    pub tier: Option<AdapterTier>,
}

impl Shared {
    pub(crate) fn new(base: BaseWeights, tier: Option<AdapterTier>) -> Self {
        Self { base, registry: RwLock::new(AdapterRegistry::new()), tier }
    }

    /// Resolve an adapter's packed factors wherever they live: resident
    /// registry arc (cheap clone) or a disk-tier read. Callers must be
    /// on a merge-pool thread — the tier may park on the clock for a
    /// scripted disk fault.
    pub(crate) fn load_adapter(&self, id: AdapterId) -> anyhow::Result<Arc<StoredAdapter>> {
        enum Slot {
            Resident(Arc<StoredAdapter>),
            Tiered,
            Gone,
            Quarantined,
        }
        let slot = self.with_registry(|r| match r.get(id) {
            Some(e) if e.is_quarantined() => Slot::Quarantined,
            Some(e) => match e.resident() {
                Some(a) => Slot::Resident(Arc::clone(a)),
                None => Slot::Tiered,
            },
            None => Slot::Gone,
        });
        match slot {
            Slot::Resident(a) => Ok(a),
            Slot::Tiered => {
                let tier =
                    self.tier.as_ref().ok_or_else(|| anyhow!("adapter {id} tiered but no tier"))?;
                match tier.load(id) {
                    Ok(a) => Ok(a),
                    Err(e) => {
                        // the tier's retry policy is exhausted — this is
                        // a permanent failure. Quarantine the slot so
                        // subsequent requests fail fast instead of
                        // re-parking on the broken disk path.
                        if self.with_registry_mut(|r| r.quarantine(id)) {
                            tier.note_quarantined(id);
                        }
                        Err(e)
                    }
                }
            }
            Slot::Gone => Err(anyhow!("adapter {id} vanished before load")),
            Slot::Quarantined => {
                Err(anyhow!("adapter {id} unavailable: quarantined after permanent load failure"))
            }
        }
    }

    /// Run `f` under the registry read lock (poisoning is benign here —
    /// the registry holds plain data — so a poisoned lock is recovered).
    pub(crate) fn with_registry<R>(&self, f: impl FnOnce(&AdapterRegistry) -> R) -> R {
        let guard = self.registry.read().unwrap_or_else(|e| e.into_inner());
        f(&guard)
    }

    /// Run `f` under the registry write lock.
    pub(crate) fn with_registry_mut<R>(&self, f: impl FnOnce(&mut AdapterRegistry) -> R) -> R {
        let mut guard = self.registry.write().unwrap_or_else(|e| e.into_inner());
        f(&mut guard)
    }
}

/// Test/ops instrumentation: called with the adapter id at the start of
/// every merge, on the merge-worker thread. Lets tests gate merges to
/// prove two adapters' misses merge in parallel.
#[derive(Clone)]
pub struct MergeHook(Arc<dyn Fn(AdapterId) + Send + Sync>);

impl MergeHook {
    pub fn new(f: impl Fn(AdapterId) + Send + Sync + 'static) -> Self {
        Self(Arc::new(f))
    }

    pub fn call(&self, id: AdapterId) {
        (self.0)(id)
    }
}

impl std::fmt::Debug for MergeHook {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("MergeHook(..)")
    }
}

/// Completion callback: receives the merged host weights (or the error)
/// and the host merge time. Workers route this back into their own
/// message loop.
pub(crate) type MergeDone = Box<dyn FnOnce(anyhow::Result<Vec<Tensor>>, Duration) + Send>;

/// Completion callback for a factor fetch: the packed adapter loaded
/// from the disk tier (or the error) and the host load time.
pub(crate) type FetchDone = Box<dyn FnOnce(anyhow::Result<Arc<StoredAdapter>>, Duration) + Send>;

/// What a pool thread should do with the adapter.
pub(crate) enum JobKind {
    /// Dequantize + merge against the base (merged execution path).
    Merge(MergeDone),
    /// Load packed factors from the disk tier (factor execution path).
    Fetch(FetchDone),
}

/// One queued job.
pub(crate) struct MergeJob {
    pub adapter: AdapterId,
    pub kind: JobKind,
}

/// The merge function: adapter id → merged host weight list.
pub(crate) type MergeFn = Arc<dyn Fn(AdapterId) -> anyhow::Result<Vec<Tensor>> + Send + Sync>;

/// The fetch function: adapter id → packed factors.
pub(crate) type FetchFn =
    Arc<dyn Fn(AdapterId) -> anyhow::Result<Arc<StoredAdapter>> + Send + Sync>;

/// Production merge function: resolve the stored adapter (resident arc
/// or disk-tier read), then dequantize + merge against the shared base
/// outside any lock.
pub(crate) fn host_merge_fn(shared: Arc<Shared>, hook: Option<MergeHook>) -> MergeFn {
    Arc::new(move |id| {
        if let Some(h) = &hook {
            h.call(id);
        }
        let stored = shared.load_adapter(id)?;
        let deltas = stored.deltas();
        merge_adapter(&shared.base, &deltas)
    })
}

/// Production fetch function: resident arc or disk-tier read.
pub(crate) fn host_fetch_fn(shared: Arc<Shared>) -> FetchFn {
    Arc::new(move |id| shared.load_adapter(id))
}

/// Merge-pipeline concurrency counters, shared between the pool threads
/// and the coordinator handle. `inflight` counts merges from dequeue
/// until the done-callback has fired (so an "inflight" merge's completion
/// message is guaranteed to be in its worker's channel once the count
/// drops); `peak_overlap` is the high-water mark of concurrent merges —
/// the observable behind "two adapters' misses merge in parallel".
#[derive(Debug, Default)]
pub struct MergeStats {
    inflight: AtomicUsize,
    peak_overlap: AtomicUsize,
    started: AtomicU64,
    completed: AtomicU64,
    /// Worker threads respawned after a contained job panic.
    worker_respawns: AtomicU64,
}

/// A point-in-time copy of [`MergeStats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MergeStatsSnapshot {
    pub inflight: usize,
    pub peak_overlap: usize,
    pub started: u64,
    pub completed: u64,
    pub worker_respawns: u64,
}

impl MergeStats {
    fn enter(&self) {
        let now = self.inflight.fetch_add(1, Ordering::SeqCst) + 1;
        self.started.fetch_add(1, Ordering::SeqCst);
        self.peak_overlap.fetch_max(now, Ordering::SeqCst);
    }

    fn exit(&self) {
        self.completed.fetch_add(1, Ordering::SeqCst);
        self.inflight.fetch_sub(1, Ordering::SeqCst);
    }

    pub fn snapshot(&self) -> MergeStatsSnapshot {
        MergeStatsSnapshot {
            inflight: self.inflight.load(Ordering::SeqCst),
            peak_overlap: self.peak_overlap.load(Ordering::SeqCst),
            started: self.started.load(Ordering::SeqCst),
            completed: self.completed.load(Ordering::SeqCst),
            worker_respawns: self.worker_respawns.load(Ordering::SeqCst),
        }
    }
}

/// Everything a merge-worker thread needs — cloneable so a panicked
/// worker's replacement can be spawned with the same context (the
/// "phoenix" supervision path; DESIGN.md §15).
#[derive(Clone)]
struct WorkerCtx {
    name: String,
    rx: Arc<Mutex<mpsc::Receiver<MergeJob>>>,
    merge_fn: MergeFn,
    fetch_fn: FetchFn,
    clock: Clock,
    stats: Arc<MergeStats>,
    /// Join handles of respawned workers, drained at shutdown.
    respawned: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
    /// Job-span recorder (DESIGN.md §16); `None` records nothing.
    trace: Option<TraceRecorder>,
}

fn spawn_worker(ctx: WorkerCtx) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name(ctx.name.clone())
        .spawn(move || worker_loop(ctx))
        .expect("spawning merge worker")
}

/// One worker's drain loop. A panic inside the merge/fetch function is
/// **contained**: the job's requests get a structured `Err` carrying the
/// panic payload, the concurrency accounting still exits (so the
/// coordinator's quiescence tracking holds), and the worker respawns a
/// replacement thread with a clean stack before retiring itself.
fn worker_loop(ctx: WorkerCtx) {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    // one trace shard per pool thread, taken on the thread itself — a
    // phoenix replacement re-enters worker_loop and gets a fresh shard
    let trace = ctx.trace.as_ref().map(TraceRecorder::handle);
    loop {
        // hold the lock only for the dequeue, not the work
        let job = {
            let guard = ctx.rx.lock().unwrap_or_else(|e| e.into_inner());
            guard.recv()
        };
        let Ok(job) = job else { return }; // all senders gone
        ctx.stats.enter();
        // clock-based host time: under a virtual clock unfaulted work is
        // instantaneous (real host work doesn't advance simulated time)
        // while an injected slow merge or disk fault shows its scripted
        // delay.
        let t0 = ctx.clock.now();
        let adapter = job.adapter;
        let panicked = match job.kind {
            JobKind::Merge(done) => {
                let result = catch_unwind(AssertUnwindSafe(|| (ctx.merge_fn)(adapter)));
                let t1 = ctx.clock.now();
                let (r, panicked) = match result {
                    Ok(r) => (r, false),
                    Err(p) => (Err(panic_err(adapter, p)), true),
                };
                if let Some(h) = &trace {
                    h.span(t0, t1, SpanKind::MergeJob {
                        adapter: u64::from(adapter),
                        ok: r.is_ok(),
                    });
                }
                done(r, t1.duration_since(t0));
                panicked
            }
            JobKind::Fetch(done) => {
                let result = catch_unwind(AssertUnwindSafe(|| (ctx.fetch_fn)(adapter)));
                let t1 = ctx.clock.now();
                let (r, panicked) = match result {
                    Ok(r) => (r, false),
                    Err(p) => (Err(panic_err(adapter, p)), true),
                };
                if let Some(h) = &trace {
                    h.span(t0, t1, SpanKind::FetchJob {
                        adapter: u64::from(adapter),
                        ok: r.is_ok(),
                    });
                }
                done(r, t1.duration_since(t0));
                panicked
            }
        };
        ctx.stats.exit();
        if panicked {
            // phoenix: hand the queue to a fresh thread (clean stack, no
            // stale thread-local state) and retire this one
            ctx.stats.worker_respawns.fetch_add(1, Ordering::SeqCst);
            let replacement = spawn_worker(ctx.clone());
            ctx.respawned.lock().unwrap_or_else(|e| e.into_inner()).push(replacement);
            return;
        }
    }
}

fn panic_err(adapter: AdapterId, p: Box<dyn std::any::Any + Send>) -> anyhow::Error {
    anyhow!(
        "merge worker panicked on adapter {adapter}: {}",
        crate::scheduler::workers::payload_str(p)
    )
}

/// A fixed pool of merge-worker threads draining one shared job queue.
pub(crate) struct MergePool {
    tx: Option<mpsc::Sender<MergeJob>>,
    joins: Vec<std::thread::JoinHandle<()>>,
    respawned: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
    stats: Arc<MergeStats>,
}

impl MergePool {
    pub(crate) fn new(
        n_workers: usize,
        merge_fn: MergeFn,
        fetch_fn: FetchFn,
        clock: Clock,
        trace: Option<TraceRecorder>,
    ) -> Self {
        let n = n_workers.max(1);
        let (tx, rx) = mpsc::channel::<MergeJob>();
        let rx = Arc::new(Mutex::new(rx));
        let stats = Arc::new(MergeStats::default());
        let respawned = Arc::new(Mutex::new(Vec::new()));
        let mut joins = Vec::with_capacity(n);
        for i in 0..n {
            joins.push(spawn_worker(WorkerCtx {
                name: format!("lq-merge-{i}"),
                rx: Arc::clone(&rx),
                merge_fn: Arc::clone(&merge_fn),
                fetch_fn: Arc::clone(&fetch_fn),
                clock: clock.clone(),
                stats: Arc::clone(&stats),
                respawned: Arc::clone(&respawned),
                trace: trace.clone(),
            }));
        }
        Self { tx: Some(tx), joins, respawned, stats }
    }

    /// Shared concurrency counters (held by the coordinator handle).
    pub(crate) fn stats(&self) -> Arc<MergeStats> {
        Arc::clone(&self.stats)
    }

    /// A submit handle for an executor worker.
    pub(crate) fn sender(&self) -> mpsc::Sender<MergeJob> {
        self.tx.as_ref().expect("merge pool already shut down").clone()
    }

    /// Drop the queue and join every merge thread — including workers
    /// respawned after contained panics (a joined phoenix may itself
    /// have respawned, so drain until the list is empty). Callers must
    /// ensure all other senders (worker-held clones) are gone first, or
    /// this blocks until they are.
    pub(crate) fn shutdown(mut self) {
        self.tx = None;
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
        loop {
            let handles: Vec<_> = {
                let mut guard = self.respawned.lock().unwrap_or_else(|e| e.into_inner());
                std::mem::take(&mut *guard)
            };
            if handles.is_empty() {
                break;
            }
            for j in handles {
                let _ = j.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn noop_weights() -> anyhow::Result<Vec<Tensor>> {
        Ok(Vec::new())
    }

    fn no_tier_fetch() -> FetchFn {
        Arc::new(|id| Err(anyhow!("no tier for adapter {id}")))
    }

    #[test]
    fn jobs_complete_and_report_duration() {
        let pool =
            MergePool::new(2, Arc::new(|_id| noop_weights()), no_tier_fetch(), Clock::real(), None);
        let (tx, rx) = channel();
        for id in 0..8u32 {
            let tx = tx.clone();
            pool.sender()
                .send(MergeJob {
                    adapter: id,
                    kind: JobKind::Merge(Box::new(move |res, dt| {
                        let _ = tx.send((id, res.is_ok(), dt));
                    })),
                })
                .unwrap();
        }
        for _ in 0..8 {
            let (_, ok, _) = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert!(ok);
        }
        pool.shutdown();
    }

    #[test]
    fn errors_propagate_to_done() {
        let pool = MergePool::new(
            1,
            Arc::new(|id| Err(anyhow!("no adapter {id}"))),
            no_tier_fetch(),
            Clock::real(),
            None,
        );
        let (tx, rx) = channel();
        pool.sender()
            .send(MergeJob {
                adapter: 7,
                kind: JobKind::Merge(Box::new(move |res, _| {
                    let _ = tx.send(res.unwrap_err().to_string());
                })),
            })
            .unwrap();
        let msg = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(msg.contains("no adapter 7"));
        pool.shutdown();
    }

    /// The load-bearing concurrency proof: two merges must be in flight at
    /// the same time. Each merge function announces entry, then blocks on
    /// its own gate; the test only releases the gates after observing BOTH
    /// entries. With a serialized pipeline the second entry never arrives
    /// and the recv_timeout fails (no deadlock).
    #[test]
    fn two_merges_run_in_parallel() {
        let (entered_tx, entered_rx) = channel::<AdapterId>();
        let (gate0_tx, gate0_rx) = channel::<()>();
        let (gate1_tx, gate1_rx) = channel::<()>();
        let gates = Mutex::new(vec![gate0_rx, gate1_rx]);
        let merge_fn: MergeFn = Arc::new(move |id| {
            entered_tx.send(id).unwrap();
            let gate = {
                let mut g = gates.lock().unwrap();
                g.remove(if id == 0 { 0 } else { g.len() - 1 })
            };
            gate.recv_timeout(Duration::from_secs(10)).expect("gate released");
            noop_weights()
        });
        let pool = MergePool::new(2, merge_fn, no_tier_fetch(), Clock::real(), None);
        let (done_tx, done_rx) = channel();
        for id in [0u32, 1] {
            let done_tx = done_tx.clone();
            pool.sender()
                .send(MergeJob {
                    adapter: id,
                    kind: JobKind::Merge(Box::new(move |res, _| {
                        let _ = done_tx.send((id, res.is_ok()));
                    })),
                })
                .unwrap();
        }
        let first = entered_rx.recv_timeout(Duration::from_secs(5)).expect("first merge starts");
        let second = entered_rx
            .recv_timeout(Duration::from_secs(5))
            .expect("second merge must start while the first is still blocked");
        assert_ne!(first, second);
        gate0_tx.send(()).unwrap();
        gate1_tx.send(()).unwrap();
        for _ in 0..2 {
            let (_, ok) = done_rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert!(ok);
        }
        // `exit()` runs just after the done callback fires; poll briefly
        // rather than racing it.
        let t0 = std::time::Instant::now();
        loop {
            let stats = pool.stats().snapshot();
            if stats
                == MergeStatsSnapshot {
                    inflight: 0,
                    peak_overlap: 2,
                    started: 2,
                    completed: 2,
                    worker_respawns: 0,
                }
            {
                break;
            }
            assert!(t0.elapsed() < Duration::from_secs(5), "stats never settled: {stats:?}");
            std::thread::yield_now();
        }
        pool.shutdown();
    }

    /// The fault-containment proof: a merge that panics fails only its
    /// own job (structured error carrying the payload), the pool keeps
    /// serving later jobs on a respawned worker, and shutdown still
    /// joins cleanly.
    #[test]
    fn merge_panic_is_contained_and_the_worker_respawns() {
        let merge_fn: MergeFn = Arc::new(|id| {
            if id == 13 {
                panic!("scripted merge panic on {id}");
            }
            noop_weights()
        });
        let pool = MergePool::new(1, merge_fn, no_tier_fetch(), Clock::real(), None);
        let (tx, rx) = channel();
        for id in [7u32, 13, 9] {
            let tx = tx.clone();
            pool.sender()
                .send(MergeJob {
                    adapter: id,
                    kind: JobKind::Merge(Box::new(move |res, _| {
                        let _ = tx.send((id, res.map_err(|e| e.to_string())));
                    })),
                })
                .unwrap();
        }
        let mut results = std::collections::BTreeMap::new();
        for _ in 0..3 {
            let (id, res) = rx.recv_timeout(Duration::from_secs(5)).expect(
                "every job must answer: a swallowed panic would hang the third request here",
            );
            results.insert(id, res);
        }
        assert!(results[&7].is_ok());
        assert!(results[&9].is_ok(), "job after the panic runs on the respawned worker");
        let err = results[&13].as_ref().unwrap_err();
        assert!(
            err.contains("panicked on adapter 13") && err.contains("scripted merge panic"),
            "{err}"
        );
        let t0 = std::time::Instant::now();
        loop {
            let stats = pool.stats().snapshot();
            if stats
                == MergeStatsSnapshot {
                    inflight: 0,
                    peak_overlap: 1,
                    started: 3,
                    completed: 3,
                    worker_respawns: 1,
                }
            {
                break;
            }
            assert!(t0.elapsed() < Duration::from_secs(5), "stats never settled: {stats:?}");
            std::thread::yield_now();
        }
        pool.shutdown();
    }

    /// Same containment contract on the fetch path.
    #[test]
    fn fetch_panic_answers_with_structured_error() {
        let fetch_fn: FetchFn = Arc::new(|_id| panic!("fetch blew up"));
        let pool = MergePool::new(2, Arc::new(|_| noop_weights()), fetch_fn, Clock::real(), None);
        let (tx, rx) = channel();
        pool.sender()
            .send(MergeJob {
                adapter: 3,
                kind: JobKind::Fetch(Box::new(move |res, _| {
                    let _ = tx.send(res.map(|_| ()).map_err(|e| e.to_string()));
                })),
            })
            .unwrap();
        let err = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap_err();
        assert!(err.contains("panicked on adapter 3") && err.contains("fetch blew up"), "{err}");
        pool.shutdown();
    }
}
