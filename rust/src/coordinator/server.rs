//! The serving front end: a cloneable, `Send` [`Coordinator`] handle over
//! an executor **pool** ([`super::pool`]) and a merge pipeline
//! ([`super::merge_worker`]).
//!
//! ```text
//! Coordinator ── rendezvous-route(adapter) ──► worker w (own Engine)
//!   worker: batch → cache hit? ── yes ──► decode on smallest bucket ≥ |batch|
//!                          └── no ───► park batch, submit merge job
//!   merge pool: dequant + merge on host (N threads, concurrent misses)
//!   worker:  Merged ──► upload (cheap) → cache → drain parked batches
//! ```
//!
//! The adapter registry is shared behind the handle (registrations are
//! immediate, no executor round-trip); metrics are aggregated across
//! workers on read. `prefetch` warms an adapter's merged weights ahead of
//! traffic through the same merge pipeline.

use super::cache::CacheStats;
use super::merge_worker::{
    host_fetch_fn, host_merge_fn, MergeHook, MergePool, MergeStats, MergeStatsSnapshot, Shared,
};
use super::metrics::ServerMetrics;
use super::pool::{route, worker_main, WorkerConfig, WorkerMsg, WorkerSnapshot};
use super::registry::{AdapterId, AdapterRegistry, StoredAdapter};
use super::tier::{AdapterTier, DiskErrorFault, DiskFault, LoadHook, TierEventHook};
use crate::clock::Clock;
use crate::model::BaseWeights;
use crate::obs::{StageBreakdown, TraceRecorder};
use anyhow::{bail, Context};
use std::path::PathBuf;
use std::str::FromStr;
use std::sync::atomic::AtomicBool;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// How adapters execute (DESIGN.md §8).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MergeStrategy {
    /// Dequantize + merge into dense weights on first use, cache the
    /// merged set, batch per adapter (the classical path; the only
    /// option under `--features pjrt`).
    #[default]
    Merged,
    /// Never merge: serve every request over unmerged base weights with
    /// the adapter applied in factor form on the activation path. Mixed
    /// heterogeneous batches; zero merge-queue traffic; per-adapter
    /// device cache unused.
    Factor,
    /// Serve cache misses in factor form immediately (no merge on the
    /// request path) while a background merge warms the cache; once
    /// merged weights land, later batches take the merged path.
    Auto,
}

impl FromStr for MergeStrategy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s {
            "merged" => Ok(Self::Merged),
            "factor" => Ok(Self::Factor),
            "auto" => Ok(Self::Auto),
            other => bail!("unknown merge strategy '{other}' (try merged|factor|auto)"),
        }
    }
}

impl std::fmt::Display for MergeStrategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::Merged => "merged",
            Self::Factor => "factor",
            Self::Auto => "auto",
        })
    }
}

/// Disk-tier configuration (DESIGN.md §14). When set, quantized
/// adapters spill to `adapter_dir` at registration (the registry keeps
/// metadata only) and their packed factors page back in through the
/// merge pool on demand, bounded in RAM by a byte-budgeted per-worker
/// factor cache.
#[derive(Debug, Clone)]
pub struct TierConfig {
    /// Directory holding one packed tensorfile per adapter.
    pub adapter_dir: PathBuf,
    /// Total in-RAM factor-cache budget in bytes, split across workers.
    pub factor_cache_bytes: usize,
    /// Scripted disk-read latency (scenario faults; DESIGN.md §14).
    pub disk_fault: Option<DiskFault>,
    /// Warm adapters ahead of their predicted next arrival (per-tenant
    /// inter-arrival EWMA; `workload::ArrivalPredictor`).
    pub predictive_prefetch: bool,
    /// Instrumentation called at the start of every disk load.
    pub load_hook: Option<LoadHook>,
    /// Retries after a failed disk load before the adapter is
    /// quarantined (DESIGN.md §15). `0` = fail on the first error.
    pub max_retries: u32,
    /// Base retry backoff, doubled per attempt on the pool clock.
    pub backoff: Duration,
    /// Scripted disk-read failures (scenario faults; DESIGN.md §15).
    pub disk_error: Option<DiskErrorFault>,
    /// Observer for disk-load errors and quarantines.
    pub event_hook: Option<TierEventHook>,
}

impl TierConfig {
    pub fn new(adapter_dir: impl Into<PathBuf>, factor_cache_bytes: usize) -> Self {
        Self {
            adapter_dir: adapter_dir.into(),
            factor_cache_bytes,
            disk_fault: None,
            predictive_prefetch: false,
            load_hook: None,
            max_retries: 0,
            backoff: Duration::ZERO,
            disk_error: None,
            event_hook: None,
        }
    }

    /// Builder sugar: bounded retry with exponential backoff on disk
    /// load errors.
    pub fn with_retry(mut self, max_retries: u32, backoff: Duration) -> Self {
        self.max_retries = max_retries;
        self.backoff = backoff;
        self
    }
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub artifacts_dir: PathBuf,
    /// Model name (artifact prefix + weights subdirectory).
    pub model: String,
    /// Executor pool size (each worker owns an engine + compiled
    /// programs; adapters are rendezvous-routed across workers).
    pub workers: usize,
    /// Compiled batch buckets (aot.py exports 1 and 8). A batch decodes
    /// on the smallest bucket that fits it.
    pub buckets: Vec<usize>,
    /// Dynamic batching max wait.
    pub max_wait: Duration,
    /// Merged-weight cache budget in bytes, split evenly across workers.
    pub cache_budget_bytes: usize,
    /// Merge pipeline threads (host-side dequant+merge on cache miss).
    pub merge_workers: usize,
    /// Per-engine worker threads for prefill/full-forward matmuls
    /// (reference engine; row-partitioned, bit-identical results at any
    /// count). Default 1: fully serial, so virtual-clock scenario traces
    /// stay byte-identical to the single-threaded schedule.
    pub compute_threads: usize,
    /// Adapter execution strategy.
    pub merge_strategy: MergeStrategy,
    /// Continuous-batching decode (DESIGN.md §11): workers drive released
    /// batches through a persistent scheduler session — finished lanes
    /// are reused mid-flight instead of waiting out the slowest lane.
    /// `false` falls back to per-batch lock-step (the pre-§11 protocol;
    /// the only mode under `--features pjrt`). Token outputs are
    /// identical either way.
    pub continuous: bool,
    /// Prompt-chunk size for incremental prefill inside continuous
    /// decode groups (DESIGN.md §13): long prompts prefill `prefill_chunk`
    /// tokens at a time, letting the scheduler retire/admit/step other
    /// lanes between chunks. `0` (the default) keeps monolithic one-pass
    /// admission — the lock-step-equivalent oracle path. Token outputs
    /// are bit-identical at every chunk size.
    pub prefill_chunk: usize,
    /// Test/ops instrumentation called at the start of every merge.
    pub merge_hook: Option<MergeHook>,
    /// Time source for every deadline, latency and park decision in the
    /// pool. Real by default; the scenario simulator injects a virtual
    /// clock here to replay traces deterministically (DESIGN.md §9).
    pub clock: Clock,
    /// Optional disk tier below the caches (DESIGN.md §14). `None` keeps
    /// every registered adapter RAM-resident (the pre-tiering behavior).
    pub tier: Option<TierConfig>,
    /// Default per-request deadline, measured from submission
    /// (DESIGN.md §15). A request's own `deadline` wins when set.
    /// `None` = requests never expire.
    pub request_timeout: Option<Duration>,
    /// Admission-queue depth cap per worker: requests arriving beyond
    /// this many pending are shed with [`FailKind::Overloaded`] and a
    /// `retry_after` hint (HTTP-429 semantics). `None` = unbounded.
    pub queue_cap: Option<usize>,
    /// Request-lifecycle span recorder (DESIGN.md §16). Executor and
    /// merge-pool threads record stage/job spans into per-thread shards
    /// of this recorder; `None` (the default) records nothing.
    pub trace: Option<TraceRecorder>,
}

impl CoordinatorConfig {
    pub fn new(artifacts_dir: impl Into<PathBuf>, model: impl Into<String>) -> Self {
        Self {
            artifacts_dir: artifacts_dir.into(),
            model: model.into(),
            workers: 1,
            buckets: vec![1, 8],
            max_wait: Duration::from_millis(10),
            cache_budget_bytes: 64 << 20,
            merge_workers: 2,
            compute_threads: 1,
            merge_strategy: MergeStrategy::default(),
            continuous: true,
            prefill_chunk: 0,
            merge_hook: None,
            clock: Clock::real(),
            tier: None,
            request_timeout: None,
            queue_cap: None,
            trace: None,
        }
    }

    /// Builder sugar: set the executor pool size.
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Builder sugar: set the compiled batch buckets.
    pub fn with_buckets(mut self, buckets: Vec<usize>) -> Self {
        self.buckets = buckets;
        self
    }

    /// Builder sugar: set the adapter execution strategy.
    pub fn with_merge_strategy(mut self, strategy: MergeStrategy) -> Self {
        self.merge_strategy = strategy;
        self
    }

    /// Builder sugar: set the per-engine prefill worker-thread count.
    pub fn with_compute_threads(mut self, threads: usize) -> Self {
        self.compute_threads = threads;
        self
    }

    /// Builder sugar: toggle the continuous-batching scheduler (`false`
    /// = per-batch lock-step decode).
    pub fn with_continuous(mut self, continuous: bool) -> Self {
        self.continuous = continuous;
        self
    }

    /// Builder sugar: set the prompt-chunk size for incremental prefill
    /// (`0` = monolithic admission).
    pub fn with_prefill_chunk(mut self, chunk: usize) -> Self {
        self.prefill_chunk = chunk;
        self
    }

    /// Builder sugar: set the time source (virtual clocks make the whole
    /// pool run in simulated time).
    pub fn with_clock(mut self, clock: Clock) -> Self {
        self.clock = clock;
        self
    }

    /// Builder sugar: enable the disk tier.
    pub fn with_tier(mut self, tier: TierConfig) -> Self {
        self.tier = Some(tier);
        self
    }

    /// Builder sugar: set the default per-request deadline.
    pub fn with_request_timeout(mut self, timeout: Duration) -> Self {
        self.request_timeout = Some(timeout);
        self
    }

    /// Builder sugar: cap the per-worker admission queue (load shedding).
    pub fn with_queue_cap(mut self, cap: usize) -> Self {
        self.queue_cap = Some(cap);
        self
    }

    /// Builder sugar: record request-lifecycle spans into `trace`.
    pub fn with_trace(mut self, trace: TraceRecorder) -> Self {
        self.trace = Some(trace);
        self
    }

    /// Buckets sorted ascending, deduplicated, validated.
    fn normalized_buckets(&self) -> anyhow::Result<Vec<usize>> {
        let mut b = self.buckets.clone();
        b.sort_unstable();
        b.dedup();
        if b.is_empty() {
            bail!("CoordinatorConfig.buckets must not be empty");
        }
        if b[0] == 0 {
            bail!("batch bucket 0 is invalid");
        }
        Ok(b)
    }
}

/// A generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub adapter: AdapterId,
    /// Prompt tokens `[BOS, …, SEP]` (unpadded).
    pub prompt: Vec<i32>,
    /// Maximum new tokens (generation also stops at EOS).
    pub max_new: usize,
    /// Per-request lifecycle options (DESIGN.md §15).
    pub options: RequestOptions,
    /// Caller-assigned trace tag: the identity of this request's track
    /// in the lifecycle trace (DESIGN.md §16). The scenario driver
    /// stamps submission indices here so exported traces are stable
    /// across thread interleavings; `0` for untagged callers.
    pub tag: u64,
}

impl GenRequest {
    pub fn new(adapter: AdapterId, prompt: Vec<i32>, max_new: usize) -> Self {
        Self { adapter, prompt, max_new, options: RequestOptions::default(), tag: 0 }
    }

    /// Builder sugar: tag this request's lifecycle-trace track.
    pub fn with_tag(mut self, tag: u64) -> Self {
        self.tag = tag;
        self
    }

    /// Builder sugar: absolute deadline for this request.
    pub fn with_deadline(mut self, deadline: Instant) -> Self {
        self.options.deadline = Some(deadline);
        self
    }

    /// Builder sugar: attach a cancel token (set it to `true` to retire
    /// the request at the scheduler's next cancel-check).
    pub fn with_cancel(mut self, cancel: Arc<AtomicBool>) -> Self {
        self.options.cancel = Some(cancel);
        self
    }
}

/// Per-request lifecycle options: deadline + cancellation
/// (DESIGN.md §15). Default (`None`/`None`) = run to completion.
#[derive(Debug, Clone, Default)]
pub struct RequestOptions {
    /// Absolute deadline; past it the request retires with
    /// [`FailKind::Timeout`] wherever it is (queued, batched, or
    /// mid-decode). Overrides `CoordinatorConfig::request_timeout`.
    pub deadline: Option<Instant>,
    /// Cooperative cancel token; flip to `true` and the scheduler
    /// retires the request with [`FailKind::Cancelled`] at its next
    /// cancel-check. Cancellation wins over a simultaneous timeout.
    pub cancel: Option<Arc<AtomicBool>>,
}

/// A generation response.
#[derive(Debug, Clone)]
pub struct GenResponse {
    /// Generated tokens (EOS stripped).
    pub tokens: Vec<i32>,
    /// End-to-end latency (enqueue → response).
    pub e2e: Duration,
    /// Per-stage latency attribution (DESIGN.md §16). Telescoping by
    /// construction: `stages.sum() == e2e` exactly.
    pub stages: StageBreakdown,
}

/// Why a request failed (DESIGN.md §15). The typed channel lets
/// callers branch on the failure class (retry on `Overloaded`, give up
/// on `AdapterUnavailable`, …) without parsing message strings.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FailKind {
    /// Deadline passed before the request finished.
    Timeout,
    /// Caller flipped the cancel token.
    Cancelled,
    /// Shed at admission: queue depth cap reached (HTTP-429).
    Overloaded,
    /// Adapter quarantined after a permanent disk-load failure, or
    /// unknown to the registry.
    AdapterUnavailable,
    /// A worker task panicked or another invariant broke; the failure
    /// is contained to this request's group.
    Internal,
    /// Request was malformed (empty prompt, missing BOS, …).
    Rejected,
}

impl std::fmt::Display for FailKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::Timeout => "timeout",
            Self::Cancelled => "cancelled",
            Self::Overloaded => "overloaded",
            Self::AdapterUnavailable => "adapter-unavailable",
            Self::Internal => "internal",
            Self::Rejected => "rejected",
        })
    }
}

/// A structured request failure: the class, an optional client backoff
/// hint (`Overloaded` only), and a human-readable message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServeError {
    pub kind: FailKind,
    /// Suggested client backoff before resubmitting (shed responses;
    /// derived from queue depth).
    pub retry_after: Option<Duration>,
    pub msg: String,
    /// Stage attribution up to the failure (DESIGN.md §16): the
    /// breakdown's `terminal` names the stage the failure struck in.
    /// `None` on failures raised outside the tracked request path.
    pub stages: Option<StageBreakdown>,
}

impl ServeError {
    pub fn new(kind: FailKind, msg: impl Into<String>) -> Self {
        Self { kind, retry_after: None, msg: msg.into(), stages: None }
    }

    pub fn overloaded(retry_after: Duration, msg: impl Into<String>) -> Self {
        Self {
            kind: FailKind::Overloaded,
            retry_after: Some(retry_after),
            msg: msg.into(),
            stages: None,
        }
    }

    /// Attach the failed request's stage breakdown.
    pub fn with_stages(mut self, stages: StageBreakdown) -> Self {
        self.stages = Some(stages);
        self
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.kind, self.msg)?;
        if let Some(ra) = self.retry_after {
            write!(f, " (retry after {ra:?})")?;
        }
        Ok(())
    }
}

impl std::error::Error for ServeError {}

pub(crate) type Responder = mpsc::Sender<Result<GenResponse, ServeError>>;

/// The handle's shared links. Dropping the last clone shuts the pool
/// down (workers drain in-flight work first).
struct Links {
    workers: Vec<mpsc::Sender<WorkerMsg>>,
    shared: Arc<Shared>,
    merge_stats: Arc<MergeStats>,
}

impl Drop for Links {
    fn drop(&mut self) {
        for tx in &self.workers {
            let _ = tx.send(WorkerMsg::Shutdown);
        }
    }
}

/// Cloneable, `Send` handle to the serving pool.
#[derive(Clone)]
pub struct Coordinator {
    links: Arc<Links>,
}

impl Coordinator {
    /// Start the pool: loads base weights once, spawns
    /// `cfg.workers` executor threads (each compiling its own programs
    /// for every bucket) and `cfg.merge_workers` merge threads. Returns
    /// (handle, supervisor join-handle).
    pub fn start(cfg: CoordinatorConfig) -> anyhow::Result<(Self, std::thread::JoinHandle<()>)> {
        let buckets = cfg.normalized_buckets()?;
        if cfg!(feature = "pjrt") && cfg.merge_strategy != MergeStrategy::Merged {
            bail!(
                "merge strategy '{}' needs activation-path adapter application, which the \
                 AOT-compiled PJRT programs cannot do; use 'merged'",
                cfg.merge_strategy
            );
        }
        let n_workers = cfg.workers.max(1);
        let base = BaseWeights::load(cfg.artifacts_dir.join(&cfg.model))?;
        let tier = match &cfg.tier {
            Some(t) => Some(
                AdapterTier::new(
                    t.adapter_dir.clone(),
                    cfg.clock.clone(),
                    t.disk_fault,
                    t.load_hook.clone(),
                )?
                .with_retry(t.max_retries, t.backoff)
                .with_disk_errors(t.disk_error)
                .with_events(t.event_hook.clone()),
            ),
            None => None,
        };
        let shared = Arc::new(Shared::new(base, tier));
        let merge_pool = MergePool::new(
            cfg.merge_workers,
            host_merge_fn(Arc::clone(&shared), cfg.merge_hook.clone()),
            host_fetch_fn(Arc::clone(&shared)),
            cfg.clock.clone(),
            cfg.trace.clone(),
        );
        let merge_stats = merge_pool.stats();
        let wcfg = WorkerConfig {
            artifacts_dir: cfg.artifacts_dir.clone(),
            model: cfg.model.clone(),
            buckets,
            max_wait: cfg.max_wait,
            cache_budget_bytes: (cfg.cache_budget_bytes / n_workers).max(1),
            strategy: cfg.merge_strategy,
            compute_threads: cfg.compute_threads.max(1),
            // PJRT programs bake full-sequence shapes: no warm-session
            // admission, so its workers always decode lock-step
            continuous: cfg.continuous && cfg!(not(feature = "pjrt")),
            prefill_chunk: cfg.prefill_chunk,
            clock: cfg.clock.clone(),
            factor_cache_bytes: cfg
                .tier
                .as_ref()
                .map(|t| (t.factor_cache_bytes / n_workers).max(1))
                .unwrap_or(1),
            predictive_prefetch: cfg.tier.as_ref().is_some_and(|t| t.predictive_prefetch),
            request_timeout: cfg.request_timeout,
            queue_cap: cfg.queue_cap,
            trace: cfg.trace.clone(),
        };

        let mut txs = Vec::with_capacity(n_workers);
        let mut joins = Vec::with_capacity(n_workers);
        let mut readies = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let (tx, rx) = mpsc::channel::<WorkerMsg>();
            let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<()>>();
            let wcfg = wcfg.clone();
            let shared = Arc::clone(&shared);
            let self_tx = tx.clone();
            let merge_tx = merge_pool.sender();
            let join = std::thread::Builder::new()
                .name(format!("lq-worker-{w}"))
                .spawn(move || worker_main(w, wcfg, shared, rx, self_tx, merge_tx, ready_tx))
                .context("spawning executor worker")?;
            txs.push(tx);
            joins.push(join);
            readies.push(ready_rx);
        }

        let mut startup: anyhow::Result<()> = Ok(());
        for (w, ready) in readies.into_iter().enumerate() {
            let r = ready
                .recv()
                .with_context(|| format!("worker {w} died during startup"))
                .and_then(|r| r);
            if startup.is_ok() {
                startup = r;
            }
        }
        if let Err(e) = startup {
            for tx in &txs {
                let _ = tx.send(WorkerMsg::Shutdown);
            }
            drop(txs);
            for j in joins {
                let _ = j.join();
            }
            merge_pool.shutdown();
            return Err(e);
        }

        let links = Arc::new(Links { workers: txs, shared, merge_stats });
        let supervisor = std::thread::Builder::new()
            .name("lq-supervisor".into())
            .spawn(move || {
                for j in joins {
                    let _ = j.join();
                }
                // all worker-held merge senders are gone; release the pool
                merge_pool.shutdown();
            })
            .context("spawning supervisor")?;
        Ok((Self { links }, supervisor))
    }

    fn worker_for(&self, adapter: AdapterId) -> &mpsc::Sender<WorkerMsg> {
        &self.links.workers[route(adapter, self.links.workers.len())]
    }

    /// Submit a request and return a receiver for its (typed) response.
    pub fn generate_async(
        &self,
        req: GenRequest,
    ) -> mpsc::Receiver<Result<GenResponse, ServeError>> {
        let (tx, rx) = mpsc::channel();
        // send failure surfaces as a dropped responder → RecvError
        let _ = self.worker_for(req.adapter).send(WorkerMsg::Gen(req, tx));
        rx
    }

    /// Submit and wait. Failures flatten into `anyhow` (the typed
    /// [`ServeError`] stays downcastable); use [`Self::generate_async`]
    /// to branch on [`FailKind`] directly.
    pub fn generate(&self, req: GenRequest) -> anyhow::Result<GenResponse> {
        Ok(self.generate_async(req).recv().context("executor gone")??)
    }

    /// Warm an adapter's merged weights on its owning worker ahead of
    /// traffic. The returned receiver resolves once the weights are
    /// device-resident (drop it for fire-and-forget).
    pub fn prefetch(&self, adapter: AdapterId) -> mpsc::Receiver<anyhow::Result<()>> {
        let (tx, rx) = mpsc::channel();
        let _ = self.worker_for(adapter).send(WorkerMsg::Prefetch(adapter, tx));
        rx
    }

    /// Register an adapter (quantized or FP16) for a task. Immediate —
    /// the registry is shared, not executor-owned.
    pub fn register_adapter(
        &self,
        adapter: StoredAdapter,
        task: impl Into<String>,
    ) -> anyhow::Result<AdapterId> {
        let task = task.into();
        let id = self.links.shared.with_registry_mut(|r| r.register(adapter, task));
        if let Some(tier) = self.links.shared.tier.as_ref() {
            let arc = self
                .links
                .shared
                .with_registry(|r| r.get(id).and_then(|e| e.resident().cloned()));
            if let Some(a) = arc {
                match tier.put(id, &a) {
                    // spilled: drop the resident copy — the factor cache
                    // and merge pool page it back in on demand
                    Ok(true) => {
                        self.links.shared.with_registry_mut(|r| r.demote(id));
                    }
                    // FP16 adapters have no at-rest codec: stay resident
                    Ok(false) => {}
                    Err(e) => {
                        self.links.shared.with_registry_mut(|r| r.remove(id));
                        return Err(e);
                    }
                }
            }
        }
        Ok(id)
    }

    /// Remove an adapter and invalidate its cached merged weights.
    pub fn remove_adapter(&self, id: AdapterId) -> anyhow::Result<bool> {
        let existed = self.links.shared.with_registry_mut(|r| r.remove(id));
        if existed {
            if let Some(tier) = self.links.shared.tier.as_ref() {
                tier.remove(id);
            }
            let _ = self.worker_for(id).send(WorkerMsg::Invalidate(id));
        }
        Ok(existed)
    }

    /// Run `f` over the shared registry (read-only snapshot access).
    pub fn with_registry<R>(&self, f: impl FnOnce(&AdapterRegistry) -> R) -> R {
        self.links.shared.with_registry(f)
    }

    /// Merge-pipeline concurrency counters (in-flight, peak overlap,
    /// started/completed totals).
    pub fn merge_stats(&self) -> MergeStatsSnapshot {
        self.links.merge_stats.snapshot()
    }

    /// Per-worker metrics snapshots (one round-trip per worker).
    pub fn metrics_per_worker(&self) -> anyhow::Result<Vec<WorkerSnapshot>> {
        let mut rxs = Vec::with_capacity(self.links.workers.len());
        for tx in &self.links.workers {
            let (stx, srx) = mpsc::channel();
            tx.send(WorkerMsg::Metrics(stx)).ok().context("executor gone")?;
            rxs.push(srx);
        }
        rxs.into_iter().map(|rx| rx.recv().context("executor gone")).collect()
    }

    /// Pool-wide snapshot (metrics, cache stats, registry size),
    /// aggregated across workers.
    pub fn metrics(&self) -> anyhow::Result<(ServerMetrics, CacheStats, usize)> {
        let snaps = self.metrics_per_worker()?;
        let mut metrics = ServerMetrics::new();
        let mut cache = CacheStats::default();
        for s in &snaps {
            metrics.absorb(&s.metrics);
            cache.hits += s.cache.hits;
            cache.misses += s.cache.misses;
            cache.evictions += s.cache.evictions;
        }
        let n = self.links.shared.with_registry(|r| r.len());
        Ok((metrics, cache, n))
    }

    /// Aggregated factor-cache stats across workers (all zero when
    /// tiering is off).
    pub fn factor_cache_stats(&self) -> anyhow::Result<CacheStats> {
        let snaps = self.metrics_per_worker()?;
        let mut st = CacheStats::default();
        for s in &snaps {
            st.hits += s.factor_cache.hits;
            st.misses += s.factor_cache.misses;
            st.evictions += s.factor_cache.evictions;
        }
        Ok(st)
    }

    /// Disk-tier counters `(disk_loads, spilled)`; zeros when tiering is
    /// off.
    pub fn tier_stats(&self) -> (u64, u64) {
        self.links
            .shared
            .tier
            .as_ref()
            .map(|t| (t.disk_loads(), t.spilled()))
            .unwrap_or((0, 0))
    }

    /// Disk-load retries absorbed by the tier's backoff loop; zero when
    /// tiering (or retry) is off.
    pub fn disk_retries(&self) -> u64 {
        self.links.shared.tier.as_ref().map(|t| t.disk_retries()).unwrap_or(0)
    }

    /// Quarantine an adapter: later requests fail fast with
    /// [`FailKind::AdapterUnavailable`] until [`Self::recover_adapter`].
    /// Cached merged weights are invalidated so the fault is visible
    /// immediately, not only on the next cache miss. Returns `false` if
    /// the adapter is unknown or already quarantined.
    pub fn quarantine_adapter(&self, id: AdapterId) -> bool {
        let changed = self.links.shared.with_registry_mut(|r| r.quarantine(id));
        if changed {
            let _ = self.worker_for(id).send(WorkerMsg::Invalidate(id));
        }
        changed
    }

    /// Lift a quarantine. Returns `false` if the adapter is unknown or
    /// not quarantined.
    pub fn recover_adapter(&self, id: AdapterId) -> bool {
        self.links.shared.with_registry_mut(|r| r.recover(id))
    }

    /// Stop the pool (in-flight and parked requests finish first).
    pub fn shutdown(&self) {
        for tx in &self.links.workers {
            let _ = tx.send(WorkerMsg::Shutdown);
        }
    }
}
