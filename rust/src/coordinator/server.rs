//! The serving loop: a thread-confined PJRT executor behind an mpsc
//! request channel.
//!
//! PJRT objects are not `Send`, so ONE executor thread owns the
//! [`Engine`], the adapter registry, and the merged-weight cache; callers
//! hold a cloneable [`Coordinator`] handle. The loop:
//!
//! ```text
//! recv_timeout(batcher deadline) → enqueue
//! pop_ready batches → ensure merged weights cached (dequant+merge+upload
//!   on miss) → batched greedy decode → respond per request
//! ```

use super::batcher::{BatcherConfig, DynamicBatcher, PendingRequest};
use super::cache::{CacheStats, LruCache};
use super::metrics::ServerMetrics;
use super::registry::{AdapterId, AdapterRegistry, StoredAdapter};
use crate::eval::tasks::TOKENS;
use crate::model::{merge_adapter, BaseWeights};
use crate::runtime::{DeviceWeights, Engine};
use anyhow::{bail, Context};
use std::path::PathBuf;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub artifacts_dir: PathBuf,
    /// Model name (artifact prefix + weights subdirectory).
    pub model: String,
    /// Batch bucket (a compiled batch size; aot.py exports 1 and 8).
    pub bucket: usize,
    /// Dynamic batching max wait.
    pub max_wait: Duration,
    /// Merged-weight cache budget in bytes.
    pub cache_budget_bytes: usize,
}

impl CoordinatorConfig {
    pub fn new(artifacts_dir: impl Into<PathBuf>, model: impl Into<String>) -> Self {
        Self {
            artifacts_dir: artifacts_dir.into(),
            model: model.into(),
            bucket: 8,
            max_wait: Duration::from_millis(10),
            cache_budget_bytes: 64 << 20,
        }
    }
}

/// A generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub adapter: AdapterId,
    /// Prompt tokens `[BOS, …, SEP]` (unpadded).
    pub prompt: Vec<i32>,
    /// Maximum new tokens (generation also stops at EOS).
    pub max_new: usize,
}

/// A generation response.
#[derive(Debug, Clone)]
pub struct GenResponse {
    /// Generated tokens (EOS stripped).
    pub tokens: Vec<i32>,
    /// End-to-end latency (enqueue → response).
    pub e2e: Duration,
}

type Responder = mpsc::Sender<anyhow::Result<GenResponse>>;

enum Msg {
    Gen(GenRequest, Responder),
    Register(Box<StoredAdapter>, String, mpsc::Sender<AdapterId>),
    Remove(AdapterId, mpsc::Sender<bool>),
    Metrics(mpsc::Sender<(ServerMetrics, CacheStats, usize)>),
    Shutdown,
}

/// Cloneable, `Send` handle to the serving loop.
#[derive(Clone)]
pub struct Coordinator {
    tx: mpsc::Sender<Msg>,
}

impl Coordinator {
    /// Start the executor thread: loads base weights + the fwd program for
    /// the configured bucket, then serves until [`Coordinator::shutdown`].
    /// Returns (handle, join-handle).
    pub fn start(cfg: CoordinatorConfig) -> anyhow::Result<(Self, std::thread::JoinHandle<()>)> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<()>>();
        let join = std::thread::Builder::new()
            .name("lq-executor".into())
            .spawn(move || executor_main(cfg, rx, ready_tx))
            .context("spawning executor thread")?;
        ready_rx.recv().context("executor thread died during startup")??;
        Ok((Self { tx }, join))
    }

    /// Submit a request and return a receiver for its response.
    pub fn generate_async(
        &self,
        req: GenRequest,
    ) -> mpsc::Receiver<anyhow::Result<GenResponse>> {
        let (tx, rx) = mpsc::channel();
        // send failure surfaces as a dropped responder → RecvError
        let _ = self.tx.send(Msg::Gen(req, tx));
        rx
    }

    /// Submit and wait.
    pub fn generate(&self, req: GenRequest) -> anyhow::Result<GenResponse> {
        self.generate_async(req).recv().context("executor gone")?
    }

    /// Register an adapter (quantized or FP16) for a task.
    pub fn register_adapter(
        &self,
        adapter: StoredAdapter,
        task: impl Into<String>,
    ) -> anyhow::Result<AdapterId> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Register(Box::new(adapter), task.into(), tx))
            .ok()
            .context("executor gone")?;
        rx.recv().context("executor gone")
    }

    /// Remove an adapter.
    pub fn remove_adapter(&self, id: AdapterId) -> anyhow::Result<bool> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Msg::Remove(id, tx)).ok().context("executor gone")?;
        rx.recv().context("executor gone")
    }

    /// Snapshot (metrics, cache stats, registry size).
    pub fn metrics(&self) -> anyhow::Result<(ServerMetrics, CacheStats, usize)> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Msg::Metrics(tx)).ok().context("executor gone")?;
        rx.recv().context("executor gone")
    }

    /// Stop the executor loop (in-flight requests finish first).
    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
    }
}

struct Executor {
    engine: Engine,
    base: BaseWeights,
    prog: String,
    bucket: usize,
    registry: AdapterRegistry,
    cache: LruCache<AdapterId, DeviceWeights>,
    metrics: ServerMetrics,
}

fn executor_main(
    cfg: CoordinatorConfig,
    rx: mpsc::Receiver<Msg>,
    ready: mpsc::Sender<anyhow::Result<()>>,
) {
    let mut exec = match Executor::new(&cfg) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    // payload carries the request plus its responder
    let mut batcher: DynamicBatcher<(GenRequest, Responder)> =
        DynamicBatcher::new(BatcherConfig { bucket: cfg.bucket, max_wait: cfg.max_wait });

    loop {
        let now = Instant::now();
        let timeout = batcher.next_deadline(now).unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(Msg::Gen(req, resp)) => {
                let adapter = req.adapter;
                if exec.registry.get(adapter).is_none() {
                    let _ = resp.send(Err(anyhow::anyhow!("unknown adapter {adapter}")));
                } else {
                    batcher.push(PendingRequest {
                        adapter,
                        enqueued: Instant::now(),
                        payload: (req, resp),
                    });
                }
            }
            Ok(Msg::Register(adapter, task, tx)) => {
                let _ = tx.send(exec.registry.register(*adapter, task));
            }
            Ok(Msg::Remove(id, tx)) => {
                exec.cache.remove(&id);
                let _ = tx.send(exec.registry.remove(id));
            }
            Ok(Msg::Metrics(tx)) => {
                let _ = tx.send((exec.metrics.clone(), exec.cache.stats(), exec.registry.len()));
            }
            Ok(Msg::Shutdown) => {
                // flush remaining batches before exiting
                while let Some(batch) = batcher.pop_ready(Instant::now() + Duration::from_secs(3600))
                {
                    exec.run_batch(batch.adapter, batch.requests);
                }
                return;
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => return,
        }
        let now = Instant::now();
        while let Some(batch) = batcher.pop_ready(now) {
            exec.run_batch(batch.adapter, batch.requests);
        }
    }
}

impl Executor {
    fn new(cfg: &CoordinatorConfig) -> anyhow::Result<Self> {
        let base = BaseWeights::load(cfg.artifacts_dir.join(&cfg.model))?;
        let mut engine = Engine::new(&cfg.artifacts_dir)?;
        let n_params = base.cfg.param_names().len();
        engine.load_model_fwd(&cfg.model, cfg.bucket, n_params)?;
        Ok(Self {
            engine,
            prog: format!("{}/b{}", cfg.model, cfg.bucket),
            bucket: cfg.bucket,
            base,
            registry: AdapterRegistry::new(),
            cache: LruCache::new(cfg.cache_budget_bytes),
            metrics: ServerMetrics::new(),
        })
    }

    /// Dequantize + merge + upload on cache miss.
    fn ensure_weights(&mut self, id: AdapterId) -> anyhow::Result<()> {
        if self.cache.get(&id).is_some() {
            return Ok(());
        }
        let t0 = Instant::now();
        let entry = match self.registry.get(id) {
            Some(e) => e,
            None => bail!("adapter {id} vanished"),
        };
        let deltas = entry.adapter.deltas();
        let merged = merge_adapter(&self.base, &deltas)?;
        let dev = self.engine.upload_weights(&merged)?;
        let bytes = dev.bytes();
        self.cache.insert(id, dev, bytes);
        if let Some(h) = self.metrics.merge_latency.as_mut() {
            h.record(t0.elapsed());
        }
        Ok(())
    }

    fn run_batch(&mut self, adapter: AdapterId, requests: Vec<PendingRequest<(GenRequest, Responder)>>) {
        if let Err(e) = self.ensure_weights(adapter) {
            let msg = format!("{e:#}");
            for r in requests {
                let _ = r.payload.1.send(Err(anyhow::anyhow!("{msg}")));
            }
            return;
        }
        match self.decode_batch(adapter, &requests) {
            Ok(outputs) => {
                let now = Instant::now();
                for (r, tokens) in requests.into_iter().zip(outputs) {
                    let e2e = now.duration_since(r.enqueued);
                    if let Some(h) = self.metrics.e2e_latency.as_mut() {
                        h.record(e2e);
                    }
                    self.metrics.requests += 1;
                    self.metrics.tokens_generated += tokens.len() as u64;
                    let _ = r.payload.1.send(Ok(GenResponse { tokens, e2e }));
                }
                self.metrics.batches += 1;
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for r in requests {
                    let _ = r.payload.1.send(Err(anyhow::anyhow!("{msg}")));
                }
            }
        }
    }

    /// Lock-step batched greedy decode (same protocol as eval::decode).
    fn decode_batch(
        &mut self,
        adapter: AdapterId,
        requests: &[PendingRequest<(GenRequest, Responder)>],
    ) -> anyhow::Result<Vec<Vec<i32>>> {
        let t_len = self.base.cfg.seq_len;
        let vocab = self.base.cfg.vocab;
        let bsz = self.bucket;
        let n = requests.len();
        assert!(n <= bsz);
        let mut seqs = vec![vec![TOKENS::PAD; t_len]; bsz];
        let mut pos = vec![0usize; bsz];
        let mut budget = vec![0usize; bsz];
        for k in 0..bsz {
            let req = &requests[k.min(n - 1)].payload.0;
            let plen = req.prompt.len().min(t_len);
            seqs[k][..plen].copy_from_slice(&req.prompt[..plen]);
            pos[k] = plen;
            budget[k] = req.max_new.min(t_len - plen);
        }
        let mut done = vec![false; bsz];
        let mut generated: Vec<Vec<i32>> = vec![Vec::new(); bsz];
        let t_exec = Instant::now();
        while !done.iter().all(|&d| d) {
            let flat: Vec<i32> = seqs.iter().flatten().copied().collect();
            let weights = self.cache.peek(&adapter).expect("weights ensured");
            let logits = self.engine.forward(&self.prog, &flat, &[bsz, t_len], weights)?;
            for k in 0..bsz {
                if done[k] {
                    continue;
                }
                if generated[k].len() >= budget[k] || pos[k] >= t_len {
                    done[k] = true;
                    continue;
                }
                let base = (k * t_len + pos[k] - 1) * vocab;
                let row = &logits[base..base + vocab];
                let mut best = 0usize;
                for v in 1..vocab {
                    if row[v] > row[best] {
                        best = v;
                    }
                }
                let tok = best as i32;
                seqs[k][pos[k]] = tok;
                pos[k] += 1;
                if tok == TOKENS::EOS {
                    done[k] = true;
                } else {
                    generated[k].push(tok);
                }
            }
        }
        if let Some(h) = self.metrics.exec_latency.as_mut() {
            h.record(t_exec.elapsed());
        }
        generated.truncate(n);
        Ok(generated)
    }
}
