//! The executor pool: N worker threads, each owning its own [`Engine`]
//! and compiled forward programs (PJRT objects are not `Send`, so engines
//! are thread-confined exactly like the original single executor — there
//! are just N of them now).
//!
//! * **Adapter-affinity routing** — the coordinator handle routes every
//!   request for an adapter to one worker chosen by rendezvous (highest
//!   random weight) hashing, so each adapter's merged-weight cache entry
//!   lives on exactly one worker and resizing the pool remaps only
//!   `1/(n+1)` of the adapters.
//! * **Off-hot-path merges** — a cache miss parks the batch in a
//!   per-adapter pending queue and submits a job to the merge pool
//!   ([`super::merge_worker`]); the worker keeps serving other adapters
//!   and only performs the cheap device upload when the merged host
//!   weights come back.
//! * **Multi-bucket decode** — each worker loads one compiled program per
//!   configured bucket and decodes a batch on the smallest bucket that
//!   fits it, instead of always padding to the largest.
//! * **Execution strategies** — `Merged` is the classical path above;
//!   `Factor` never merges at all (heterogeneous batches decode over
//!   unmerged base weights with per-request factor-form deltas); `Auto`
//!   serves cold adapters factor-form immediately while a background
//!   merge warms the cache (DESIGN.md §8).
//! * **Continuous batching** (DESIGN.md §11, default on the reference
//!   engine) — a drain collects every releasable batch, groups them by
//!   weight context (one heterogeneous group for factor serving, one
//!   per adapter for merged), and runs each group through the
//!   `scheduler` engine loop over a **persistent per-worker session**:
//!   lanes freed by short requests are re-admitted mid-flight, so a
//!   group of several batches costs far fewer decode steps than
//!   lock-stepping each batch. Post-merge drains feed *all* parked
//!   batches of an adapter into one group.
//! * **Deterministic merge ingest** — under a **virtual clock** each
//!   worker ingests `Merged` results in submission order (a completed
//!   merge holds until every earlier-submitted one lands), so
//!   cache-insert order — and therefore LRU eviction under thrash — is
//!   reproducible even with `merge_workers > 1`. Real-time serving
//!   ingests on arrival: no cross-adapter head-of-line blocking.

use super::batcher::{Batch, BatcherConfig, DynamicBatcher, PendingRequest};
use super::cache::{CacheStats, LruCache};
use super::merge_worker::{JobKind, MergeJob, Shared};
use super::metrics::ServerMetrics;
use super::registry::{AdapterId, StoredAdapter};
use super::server::{FailKind, GenRequest, GenResponse, MergeStrategy, Responder, ServeError};
use crate::adapter::fmt::Tensor;
use crate::clock::Clock;
use crate::eval::decode::{decode_lockstep, EngineStepper};
use crate::eval::tasks::TOKENS;
#[cfg(not(feature = "pjrt"))]
use crate::loraquant::FactorSource;
use crate::loraquant::QFactors;
use crate::model::merge::base_weight_list;
use crate::obs::{Stage, StageBreakdown, StageTrack, TraceHandle, TraceRecorder};
use crate::workload::ArrivalPredictor;
#[cfg(not(feature = "pjrt"))]
use crate::runtime::DecodeState;
use crate::runtime::{DeviceWeights, Engine};
#[cfg(not(feature = "pjrt"))]
use crate::scheduler::engine_loop::{
    run_continuous, ContinuousConfig, RequestOutcome, SessionStepper,
};
#[cfg(not(feature = "pjrt"))]
use crate::scheduler::queue::{AdmissionQueue, LaneRequest};
use anyhow::anyhow;
use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// 64-bit finalizer (murmur3-style) for rendezvous scores.
fn mix64(mut z: u64) -> u64 {
    z ^= z >> 33;
    z = z.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    z ^= z >> 33;
    z = z.wrapping_mul(0xC4CE_B9FE_1A85_EC53);
    z ^ (z >> 33)
}

/// Rendezvous (highest-random-weight) routing: the worker owning
/// `adapter`. Stable in `adapter` and minimally disruptive in
/// `n_workers`: growing the pool by one only remaps keys whose new
/// highest score lands on the new worker.
pub fn route(adapter: AdapterId, n_workers: usize) -> usize {
    assert!(n_workers > 0, "route over an empty pool");
    (0..n_workers)
        .max_by_key(|&w| mix64((u64::from(adapter) << 32) | (w as u64 + 1)))
        .unwrap()
}

/// Per-worker configuration (derived from `CoordinatorConfig`).
#[derive(Debug, Clone)]
pub(crate) struct WorkerConfig {
    pub artifacts_dir: PathBuf,
    pub model: String,
    /// Compiled batch buckets, ascending and deduplicated.
    pub buckets: Vec<usize>,
    pub max_wait: Duration,
    /// This worker's share of the merged-weight cache budget.
    pub cache_budget_bytes: usize,
    /// Adapter execution strategy (merged / factor / auto).
    pub strategy: MergeStrategy,
    /// Engine worker threads for prefill matmuls (1 = serial; thread
    /// count never changes logits, see `runtime::sim`).
    pub compute_threads: usize,
    /// Continuous-batching decode (false = per-batch lock-step; always
    /// false under `--features pjrt`).
    pub continuous: bool,
    /// Prompt-chunk size for incremental prefill inside continuous
    /// decode groups (0 = monolithic admission; see
    /// `scheduler::ContinuousConfig::prefill_chunk`).
    pub prefill_chunk: usize,
    /// Time source: real in production, virtual under the scenario
    /// simulator (see `crate::clock`).
    pub clock: Clock,
    /// This worker's share of the in-RAM packed-factor cache budget
    /// (only consulted when the shared disk tier is enabled).
    pub factor_cache_bytes: usize,
    /// Warm adapters ahead of their predicted next arrival (per-tenant
    /// inter-arrival EWMA; see `workload::ArrivalPredictor`).
    pub predictive_prefetch: bool,
    /// Default per-request deadline (a request's own deadline wins).
    pub request_timeout: Option<Duration>,
    /// Admission-queue depth cap: arrivals beyond this many pending shed
    /// with `FailKind::Overloaded` (DESIGN.md §15).
    pub queue_cap: Option<usize>,
    /// Request-lifecycle span recorder (DESIGN.md §16). Each worker
    /// thread takes its own [`TraceHandle`] at startup; `None` records
    /// nothing.
    pub trace: Option<TraceRecorder>,
}

/// One worker's metrics snapshot. Taken **after** the worker's release
/// pass, so at the instant of the snapshot no queued batch was releasable
/// at the worker's current clock — a metrics round-trip therefore doubles
/// as a quiescence barrier for the scenario simulator.
#[derive(Debug, Clone)]
pub struct WorkerSnapshot {
    pub worker: usize,
    pub metrics: ServerMetrics,
    pub cache: CacheStats,
    pub cache_used_bytes: usize,
    pub cached_adapters: usize,
    pub queued_requests: usize,
    /// Time until the oldest queued request's max-wait deadline (`None`
    /// when the batcher is idle; strictly positive after a release pass).
    pub next_release_in: Option<Duration>,
    /// Adapters with a merge in flight on this worker.
    pub inflight_merges: usize,
    /// Requests parked in batches behind in-flight merges.
    pub parked_requests: usize,
    /// Merge completions held by the ingest sequencer (completed, but
    /// waiting for an earlier-submitted merge to land first).
    pub held_merges: usize,
    /// Adapters with a disk-tier factor fetch in flight on this worker.
    pub inflight_fetches: usize,
    /// In-RAM packed-factor cache stats (all zero when tiering is off).
    pub factor_cache: CacheStats,
    pub factor_cache_used_bytes: usize,
}

type Payload = (GenRequest, Responder, StageTrack);
type Queued = PendingRequest<Payload>;

/// Stamp a stage transition on every request of a parking batch: the
/// time since each request's last boundary books to the stage it is
/// leaving (see [`StageTrack::advance`]).
fn park_stage(clock: &Clock, requests: &mut [Queued], stage: Stage) {
    let now = clock.now();
    for r in requests.iter_mut() {
        r.payload.2.advance(now, stage);
    }
}

/// Messages a worker thread consumes.
pub(crate) enum WorkerMsg {
    Gen(GenRequest, Responder),
    Prefetch(AdapterId, mpsc::Sender<anyhow::Result<()>>),
    Invalidate(AdapterId),
    Metrics(mpsc::Sender<WorkerSnapshot>),
    Merged {
        /// Submission sequence number (the ingest sequencer applies
        /// completions in submission order).
        seq: u64,
        adapter: AdapterId,
        result: anyhow::Result<Vec<Tensor>>,
        host_time: Duration,
    },
    /// A disk-tier factor fetch completed (shares the merge sequencer's
    /// numbering, so merge and fetch completions ingest in one
    /// deterministic submission order).
    Fetched {
        seq: u64,
        adapter: AdapterId,
        result: anyhow::Result<Arc<StoredAdapter>>,
        host_time: Duration,
    },
    Shutdown,
}

/// A completed merge or fetch waiting in the ingest sequencer.
enum HeldJob {
    Merge { adapter: AdapterId, result: anyhow::Result<Vec<Tensor>>, host_time: Duration },
    Fetch { adapter: AdapterId, result: anyhow::Result<Arc<StoredAdapter>>, host_time: Duration },
}

/// A merge in flight for one adapter on this worker.
struct Inflight {
    /// Whether the initiating lookup already counted a cache miss (false
    /// for prefetch-initiated merges).
    miss_counted: bool,
    /// Batches parked until the merged weights arrive.
    parked: Vec<Vec<Queued>>,
    /// Prefetch acks to fire once the weights are resident.
    waiters: Vec<mpsc::Sender<anyhow::Result<()>>>,
}

/// A disk-tier factor fetch in flight for one adapter on this worker.
/// The initiating request-path probe counted exactly one factor-cache
/// miss; requests arriving while the fetch is in flight park silently,
/// so `factor_cache.misses == disk_loads` on the request path.
#[derive(Default)]
struct FetchInflight {
    /// Requests parked until the packed factors arrive.
    parked: Vec<Queued>,
    /// Prefetch acks to fire once the factors are resident.
    waiters: Vec<mpsc::Sender<anyhow::Result<()>>>,
}

pub(crate) fn worker_main(
    idx: usize,
    cfg: WorkerConfig,
    shared: Arc<Shared>,
    rx: mpsc::Receiver<WorkerMsg>,
    self_tx: mpsc::Sender<WorkerMsg>,
    merge_tx: mpsc::Sender<MergeJob>,
    ready: mpsc::Sender<anyhow::Result<()>>,
) {
    let mut w = match Worker::new(idx, cfg, shared, self_tx, merge_tx) {
        Ok(w) => {
            let _ = ready.send(Ok(()));
            w
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let mut draining = false;
    loop {
        // Under a virtual clock batcher deadlines are simulated durations
        // — meaningless as real-time waits. The driver's barrier messages
        // wake the loop after every clock advance, so a fixed real poll
        // interval is only a liveness backstop there.
        let timeout = if w.clock.is_virtual() {
            Duration::from_millis(50)
        } else {
            w.batcher.next_deadline(w.clock.now()).unwrap_or(Duration::from_millis(50))
        };
        // A metrics reply is deferred until after the release pass so the
        // snapshot (queue depth, next deadline, parked work) reflects a
        // fully-drained state — the round-trip is the simulator's barrier.
        let mut metrics_reply = None;
        match rx.recv_timeout(timeout) {
            Ok(WorkerMsg::Gen(req, resp)) => w.on_gen(req, resp),
            Ok(WorkerMsg::Prefetch(id, ack)) => w.on_prefetch(id, ack),
            Ok(WorkerMsg::Invalidate(id)) => w.on_invalidate(id),
            Ok(WorkerMsg::Metrics(tx)) => metrics_reply = Some(tx),
            Ok(WorkerMsg::Merged { seq, adapter, result, host_time }) => {
                w.ingest(seq, HeldJob::Merge { adapter, result, host_time });
            }
            Ok(WorkerMsg::Fetched { seq, adapter, result, host_time }) => {
                w.ingest(seq, HeldJob::Fetch { adapter, result, host_time });
            }
            Ok(WorkerMsg::Shutdown) => draining = true,
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            // Unreachable while the worker holds self_tx, but harmless.
            Err(mpsc::RecvTimeoutError::Disconnected) => draining = true,
        }
        loop {
            // Collect every currently-releasable batch, then decode them
            // together: the continuous scheduler merges co-releasable
            // batches into shared sessions. When draining, partial
            // batches release immediately instead of waiting out their
            // deadline.
            let mut batches = Vec::new();
            // deadlines that passed while queued retire here, before the
            // release pass — an expired request never occupies a lane
            w.expire_queued();
            loop {
                let batch = if draining {
                    w.batcher.pop_flush()
                } else {
                    w.batcher.pop_ready(w.clock.now())
                };
                match batch {
                    Some(batch) => batches.push(batch),
                    None => break,
                }
            }
            if batches.is_empty() {
                break;
            }
            w.on_batches(batches);
        }
        if let Some(tx) = metrics_reply {
            let _ = tx.send(w.snapshot());
        }
        if draining && w.batcher.pending() == 0 && w.inflight.is_empty() && w.fetching.is_empty()
        {
            return;
        }
    }
}

struct Worker {
    idx: usize,
    shared: Arc<Shared>,
    engine: Engine,
    /// (bucket, program key), ascending by bucket.
    progs: Vec<(usize, String)>,
    batcher: DynamicBatcher<Payload>,
    cache: LruCache<AdapterId, DeviceWeights>,
    /// Byte-budgeted in-RAM cache of tiered adapters' packed factors
    /// (the layer between the merged-weight cache above and the disk
    /// tier below; untouched when tiering is off).
    factor_cache: LruCache<AdapterId, Arc<StoredAdapter>>,
    metrics: ServerMetrics,
    inflight: HashMap<AdapterId, Inflight>,
    /// Disk-tier factor fetches in flight.
    fetching: HashMap<AdapterId, FetchInflight>,
    /// Predictive warm-ahead state (None unless enabled).
    predictor: Option<ArrivalPredictor>,
    merge_tx: mpsc::Sender<MergeJob>,
    self_tx: mpsc::Sender<WorkerMsg>,
    strategy: MergeStrategy,
    /// Continuous-batching decode (always false under pjrt).
    #[cfg_attr(feature = "pjrt", allow(dead_code))]
    continuous: bool,
    /// Prompt-chunk size for incremental prefill (0 = monolithic).
    #[cfg_attr(feature = "pjrt", allow(dead_code))]
    prefill_chunk: usize,
    clock: Clock,
    /// Batcher max wait (the shed path's `retry_after` unit).
    max_wait: Duration,
    /// Default per-request deadline (a request's own deadline wins).
    request_timeout: Option<Duration>,
    /// Admission depth cap (None = never shed).
    queue_cap: Option<usize>,
    /// This worker thread's span-recording endpoint (DESIGN.md §16).
    trace: Option<TraceHandle>,
    /// Unmerged base weights, resident once per worker — the substrate the
    /// factor-form path decodes over (None under `Merged`).
    base_weights: Option<DeviceWeights>,
    /// Next merge submission sequence number.
    merge_seq: u64,
    /// Next sequence number the ingest sequencer will apply.
    next_ingest: u64,
    /// Completed merges/fetches waiting on an earlier-submitted one.
    held: BTreeMap<u64, HeldJob>,
    /// The persistent continuous-batching session (lazily created; its
    /// KV cache and scratch arena are reused across every decode group).
    #[cfg(not(feature = "pjrt"))]
    session: Option<DecodeState>,
    /// Persistent per-tenant fairness state for lane admission.
    #[cfg(not(feature = "pjrt"))]
    admission: AdmissionQueue,
}

impl Worker {
    fn new(
        idx: usize,
        cfg: WorkerConfig,
        shared: Arc<Shared>,
        self_tx: mpsc::Sender<WorkerMsg>,
        merge_tx: mpsc::Sender<MergeJob>,
    ) -> anyhow::Result<Self> {
        let n_params = shared.base.cfg.param_names().len();
        let mut engine = Engine::new(&cfg.artifacts_dir)?;
        engine.set_compute_threads(cfg.compute_threads.max(1));
        let mut progs = Vec::with_capacity(cfg.buckets.len());
        for &b in &cfg.buckets {
            engine.load_model_fwd(&cfg.model, b, n_params)?;
            progs.push((b, format!("{}/b{}", cfg.model, b)));
        }
        let max_bucket = *cfg.buckets.last().expect("buckets validated non-empty");
        let base_weights = if cfg.strategy == MergeStrategy::Merged {
            None
        } else {
            Some(engine.upload_weights(&base_weight_list(&shared.base)?)?)
        };
        Ok(Self {
            idx,
            shared,
            engine,
            progs,
            batcher: DynamicBatcher::new(BatcherConfig {
                bucket: max_bucket,
                max_wait: cfg.max_wait,
                // pure factor serving mixes adapters in one batch; merged
                // and auto keep per-adapter batches for the weight cache
                group_by_adapter: cfg.strategy != MergeStrategy::Factor,
            }),
            cache: LruCache::new(cfg.cache_budget_bytes),
            factor_cache: LruCache::new(cfg.factor_cache_bytes.max(1)),
            metrics: ServerMetrics::new(),
            inflight: HashMap::new(),
            fetching: HashMap::new(),
            predictor: cfg.predictive_prefetch.then(ArrivalPredictor::new),
            merge_tx,
            self_tx,
            strategy: cfg.strategy,
            continuous: cfg.continuous,
            prefill_chunk: cfg.prefill_chunk,
            clock: cfg.clock,
            max_wait: cfg.max_wait,
            request_timeout: cfg.request_timeout,
            queue_cap: cfg.queue_cap,
            // one shard per worker thread: `new` runs on the spawned
            // thread, so a respawned worker gets a fresh shard too
            trace: cfg.trace.as_ref().map(TraceRecorder::handle),
            base_weights,
            merge_seq: 0,
            next_ingest: 0,
            held: BTreeMap::new(),
            #[cfg(not(feature = "pjrt"))]
            session: None,
            #[cfg(not(feature = "pjrt"))]
            admission: AdmissionQueue::new(),
        })
    }

    fn snapshot(&self) -> WorkerSnapshot {
        WorkerSnapshot {
            worker: self.idx,
            metrics: self.metrics.clone(),
            cache: self.cache.stats(),
            cache_used_bytes: self.cache.used_bytes(),
            cached_adapters: self.cache.len(),
            queued_requests: self.batcher.pending(),
            next_release_in: self.batcher.next_deadline(self.clock.now()),
            inflight_merges: self.inflight.len(),
            parked_requests: self
                .inflight
                .values()
                .map(|fl| fl.parked.iter().map(Vec::len).sum::<usize>())
                .sum::<usize>()
                + self.fetching.values().map(|fl| fl.parked.len()).sum::<usize>(),
            held_merges: self.held.len(),
            inflight_fetches: self.fetching.len(),
            factor_cache: self.factor_cache.stats(),
            factor_cache_used_bytes: self.factor_cache.used_bytes(),
        }
    }

    /// Reject a request at admission (never queued): a zero-length
    /// stage breakdown (terminal `Queued`) keeps the driver's
    /// `Σ stages == e2e` accounting total, and the `Failed` marker
    /// lands in the trace.
    fn reject(&self, req: &GenRequest, resp: Responder, err: ServeError) {
        let b = StageBreakdown::default();
        if let Some(h) = &self.trace {
            let now = self.clock.now();
            h.record_request(
                req.tag,
                u64::from(req.adapter),
                now,
                &b,
                Some(&err.kind.to_string()),
            );
        }
        let _ = resp.send(Err(err.with_stages(b)));
    }

    /// Fail one tracked request: close its stage track (the tail books
    /// to the stage the failure struck in, which becomes `terminal`),
    /// attach the breakdown to the error, and record the span timeline.
    fn fail_request(&self, q: Queued, err: &ServeError, now: Instant) {
        let (req, resp, track) = q.payload;
        let start = track.started();
        let b = track.finish(now);
        if let Some(h) = &self.trace {
            h.record_request(
                req.tag,
                u64::from(req.adapter),
                start,
                &b,
                Some(&err.kind.to_string()),
            );
        }
        let _ = resp.send(Err(err.clone().with_stages(b)));
    }

    /// Retire one successful request: close its stage track, attach the
    /// breakdown to the response, and record its span timeline. With a
    /// known first-token instant the tail splits prefill from decode;
    /// without one (lock-step path, zero-budget completions) the whole
    /// tail books to the track's current stage.
    fn respond_ok(
        &self,
        mut r: Queued,
        tokens: Vec<i32>,
        e2e: Duration,
        first_token: Option<Instant>,
        now: Instant,
    ) {
        if let Some(ft) = first_token {
            r.payload.2.advance(ft, Stage::Decode);
        }
        let (req, resp, track) = r.payload;
        let start = track.started();
        let b = track.finish(now);
        debug_assert_eq!(b.sum(), e2e, "stage breakdown must telescope to e2e");
        if let Some(h) = &self.trace {
            h.record_request(req.tag, u64::from(req.adapter), start, &b, None);
        }
        let _ = resp.send(Ok(GenResponse { tokens, e2e, stages: b }));
    }

    fn on_gen(&mut self, req: GenRequest, resp: Responder) {
        let adapter = req.adapter;
        enum Known {
            Ok,
            Quarantined,
            Unknown,
        }
        let known = self.shared.with_registry(|r| match r.get(adapter) {
            None => Known::Unknown,
            Some(e) if e.is_quarantined() => Known::Quarantined,
            Some(_) => Known::Ok,
        });
        match known {
            Known::Ok => {}
            Known::Unknown => {
                self.reject(
                    &req,
                    resp,
                    ServeError::new(
                        FailKind::AdapterUnavailable,
                        format!("unknown adapter {adapter}"),
                    ),
                );
                return;
            }
            // fail fast instead of re-parking behind a doomed disk load
            Known::Quarantined => {
                self.reject(
                    &req,
                    resp,
                    ServeError::new(
                        FailKind::AdapterUnavailable,
                        format!(
                            "adapter {adapter} unavailable: quarantined after permanent load failure"
                        ),
                    ),
                );
                return;
            }
        }
        // An empty prompt has no logits row to decode from (rejected
        // again inside decode_lockstep, but failing early is cheaper).
        if req.prompt.is_empty() {
            self.reject(&req, resp, ServeError::new(FailKind::Rejected, "empty prompt"));
            return;
        }
        let t_len = self.shared.base.cfg.seq_len;
        if req.prompt.len() >= t_len {
            self.reject(
                &req,
                resp,
                ServeError::new(
                    FailKind::Rejected,
                    format!(
                        "prompt length {} leaves no room to generate (seq_len {t_len})",
                        req.prompt.len()
                    ),
                ),
            );
            return;
        }
        if let Some(cap) = self.queue_cap {
            let pending = self.batcher.pending();
            if pending >= cap {
                // HTTP-429 semantics: the hint scales with how far past
                // capacity the queue is, in units of the batcher's max
                // wait (one "drain generation" per cap's worth of depth)
                let retry_after =
                    self.max_wait.saturating_mul((pending + 1) as u32) / (cap as u32).max(1);
                self.metrics.sheds += 1;
                self.reject(
                    &req,
                    resp,
                    ServeError::overloaded(retry_after, format!("queue depth {pending} at cap {cap}")),
                );
                return;
            }
        }
        if self.predictor.is_some() {
            // predictive warm-ahead: note this arrival, then pull any
            // adapter whose predicted next arrival is due toward RAM
            let now = self.clock.now();
            let due = {
                let p = self.predictor.as_mut().expect("checked");
                p.observe(adapter, now);
                p.due(now)
            };
            for id in due {
                if id != adapter {
                    self.warm(id);
                }
            }
        }
        let now = self.clock.now();
        // a request's own deadline wins over the pool-wide default
        let deadline = req
            .options
            .deadline
            .or_else(|| self.request_timeout.map(|t| now + t));
        // the stage track opens at the same instant as `enqueued`, so
        // the breakdown telescopes to exactly the reported e2e
        let track = StageTrack::begin(now);
        self.batcher
            .push(PendingRequest { adapter, enqueued: now, deadline, payload: (req, resp, track) });
    }

    /// Retire queued requests whose deadline passed while they waited
    /// for release — they never reach a decode lane.
    fn expire_queued(&mut self) {
        let now = self.clock.now();
        for r in self.batcher.expire(now) {
            self.metrics.timeouts += 1;
            let waited = now.duration_since(r.enqueued);
            let err = ServeError::new(
                FailKind::Timeout,
                format!("deadline exceeded after {waited:?} queued"),
            );
            self.fail_request(r, &err, now);
        }
    }

    /// Drop an adapter's cached state (removal or quarantine): merged
    /// weights, packed factors, and its predictive-prefetch track (a
    /// quarantined adapter must not be pulled back toward RAM by the
    /// predictor).
    fn on_invalidate(&mut self, id: AdapterId) {
        self.cache.remove(&id);
        self.factor_cache.remove(&id);
        if let Some(p) = self.predictor.as_mut() {
            p.forget(id);
        }
    }

    fn on_prefetch(&mut self, id: AdapterId, ack: mpsc::Sender<anyhow::Result<()>>) {
        if self.strategy == MergeStrategy::Factor {
            if self.shared.with_registry(|r| r.get(id).is_none()) {
                let _ = ack.send(Err(anyhow!("unknown adapter {id}")));
                return;
            }
            // factors already in RAM (registry-resident, or in the factor
            // cache — refresh its recency): nothing to load. Without a
            // disk tier this is every registered adapter.
            if self.factor_cache.touch(&id) || self.factors_available(id) {
                let _ = ack.send(Ok(()));
                return;
            }
            if let Some(fl) = self.fetching.get_mut(&id) {
                fl.waiters.push(ack);
                return;
            }
            self.fetching.insert(id, FetchInflight { parked: Vec::new(), waiters: vec![ack] });
            self.submit_fetch(id);
            return;
        }
        if self.cache.touch(&id) {
            // already resident: refresh recency (the caller wants it
            // protected ahead of traffic) without counting a hit
            let _ = ack.send(Ok(()));
            return;
        }
        if self.shared.with_registry(|r| r.get(id).is_none()) {
            let _ = ack.send(Err(anyhow!("unknown adapter {id}")));
            return;
        }
        if let Some(fl) = self.inflight.get_mut(&id) {
            fl.waiters.push(ack);
            return;
        }
        self.inflight
            .insert(id, Inflight { miss_counted: false, parked: Vec::new(), waiters: vec![ack] });
        self.submit_merge(id);
    }

    /// One drain's releasable batches, decoded together. The continuous
    /// scheduler groups them by weight context and runs each group
    /// through a shared session; the lock-step fallback (and PJRT)
    /// decodes each batch separately as before.
    fn on_batches(&mut self, batches: Vec<Batch<Payload>>) {
        #[cfg(not(feature = "pjrt"))]
        if self.continuous {
            self.on_batches_continuous(batches);
            return;
        }
        for batch in batches {
            self.on_batch(batch);
        }
    }

    /// Group co-releasable batches by weight context, preserving the
    /// legacy metric contract: one counted cache lookup per merged/auto
    /// decode group (parked drains count theirs at miss time), and
    /// `batches` counts groups.
    #[cfg(not(feature = "pjrt"))]
    fn on_batches_continuous(&mut self, batches: Vec<Batch<Payload>>) {
        enum Group {
            /// Heterogeneous factor-form group (mixed tenants). The `u64`
            /// is how many metric batches the group represents: factor-form
            /// lanes are disjoint, so cold auto batches coalesce into one
            /// decode session instead of running back to back (no idle
            /// lanes between them), but each still counted its own cache
            /// miss — `finish_group` books `counted` batches to keep
            /// `hits + misses == batches` intact.
            Factor(Vec<Queued>, u64),
            /// One adapter's merged-weight group (may span batches).
            Merged(AdapterId, Vec<Queued>),
        }
        let mut groups: Vec<Group> = Vec::new();
        for batch in batches {
            match (self.strategy, batch.adapter) {
                (MergeStrategy::Factor, _) => {
                    // pure factor serving: every batch of the drain joins
                    // one heterogeneous session, counted as one batch per
                    // drain (the merged cache is never consulted on this
                    // path; tiered adapters whose factors are on disk park
                    // behind a fetch instead of joining the group)
                    let ready = self.partition_tiered(batch.requests);
                    if ready.is_empty() {
                        continue;
                    }
                    match groups.iter_mut().find_map(|g| match g {
                        Group::Factor(reqs, _) => Some(reqs),
                        Group::Merged(..) => None,
                    }) {
                        Some(reqs) => reqs.extend(ready),
                        None => groups.push(Group::Factor(ready, 1)),
                    }
                }
                (MergeStrategy::Merged, Some(id)) => {
                    if let Some(fl) = self.inflight.get_mut(&id) {
                        // merge already in flight — park behind it; the
                        // post-merge drain feeds every parked batch into
                        // one group
                        let mut requests = batch.requests;
                        park_stage(&self.clock, &mut requests, Stage::MergeWait);
                        fl.parked.push(requests);
                        continue;
                    }
                    if let Some(reqs) = groups.iter_mut().find_map(|g| match g {
                        Group::Merged(gid, reqs) if *gid == id => Some(reqs),
                        _ => None,
                    }) {
                        reqs.extend(batch.requests);
                        continue;
                    }
                    if self.cache.get(&id).is_some() {
                        groups.push(Group::Merged(id, batch.requests));
                    } else {
                        let mut requests = batch.requests;
                        park_stage(&self.clock, &mut requests, Stage::MergeWait);
                        self.inflight.insert(
                            id,
                            Inflight {
                                miss_counted: true,
                                parked: vec![requests],
                                waiters: Vec::new(),
                            },
                        );
                        self.submit_merge(id);
                    }
                }
                (MergeStrategy::Auto, Some(id)) => {
                    if let Some(reqs) = groups.iter_mut().find_map(|g| match g {
                        Group::Merged(gid, reqs) if *gid == id => Some(reqs),
                        _ => None,
                    }) {
                        reqs.extend(batch.requests);
                        continue;
                    }
                    // tiered adapter whose factors are on disk: the
                    // no-cold-cliff factor fallback can't bind, so park
                    // behind the in-flight merge without a second counted
                    // lookup (mirrors the Merged strategy's park path)
                    if self.inflight.contains_key(&id) && !self.factors_available(id) {
                        let mut requests = batch.requests;
                        park_stage(&self.clock, &mut requests, Stage::MergeWait);
                        self.inflight.get_mut(&id).expect("checked").parked.push(requests);
                        continue;
                    }
                    if self.cache.get(&id).is_some() {
                        groups.push(Group::Merged(id, batch.requests));
                    } else {
                        // no cold cliff: factor-form now, background merge
                        // warms the cache. Factor lanes are disjoint, so
                        // every cold batch of the drain shares one decode
                        // session (no idle workers between back-to-back
                        // groups); the group's counter remembers how many
                        // counted misses it absorbed.
                        if !self.inflight.contains_key(&id) {
                            self.inflight.insert(
                                id,
                                Inflight {
                                    miss_counted: true,
                                    parked: Vec::new(),
                                    waiters: Vec::new(),
                                },
                            );
                            self.submit_merge(id);
                        }
                        if !self.factors_available(id) {
                            // factors on disk: ride out the merge parked
                            let mut requests = batch.requests;
                            park_stage(&self.clock, &mut requests, Stage::MergeWait);
                            self.inflight
                                .get_mut(&id)
                                .expect("just ensured")
                                .parked
                                .push(requests);
                            continue;
                        }
                        match groups.iter_mut().find_map(|g| match g {
                            Group::Factor(reqs, counted) => Some((reqs, counted)),
                            Group::Merged(..) => None,
                        }) {
                            Some((reqs, counted)) => {
                                reqs.extend(batch.requests);
                                *counted += 1;
                            }
                            None => groups.push(Group::Factor(batch.requests, 1)),
                        }
                    }
                }
                (_, None) => {
                    // per-adapter batchers always tag their batches
                    let err = ServeError::new(FailKind::Internal, "untagged adapter batch");
                    let now = self.clock.now();
                    for r in batch.requests {
                        self.fail_request(r, &err, now);
                    }
                }
            }
        }
        for group in groups {
            match group {
                Group::Factor(requests, counted) => self.run_group_factor(requests, counted),
                Group::Merged(id, requests) => self.run_group_merged(id, requests),
            }
        }
    }

    fn on_batch(&mut self, batch: Batch<Payload>) {
        match (self.strategy, batch.adapter) {
            // pure factor serving: heterogeneous batch, no merged cache,
            // no merge queue — straight to decode (tiered adapters park
            // behind a disk fetch first)
            (MergeStrategy::Factor, _) => {
                let ready = self.partition_tiered(batch.requests);
                if !ready.is_empty() {
                    self.run_batch_factor(ready);
                }
            }
            (MergeStrategy::Merged, Some(id)) => self.on_batch_merged(id, batch.requests),
            (MergeStrategy::Auto, Some(id)) => {
                // tiered factors on disk: no factor fallback — park behind
                // the in-flight merge without a second counted lookup
                if self.inflight.contains_key(&id) && !self.factors_available(id) {
                    let mut requests = batch.requests;
                    park_stage(&self.clock, &mut requests, Stage::MergeWait);
                    self.inflight.get_mut(&id).expect("checked").parked.push(requests);
                    return;
                }
                // one counted lookup per batch, same as the merged path
                if self.cache.get(&id).is_some() {
                    self.run_batch_merged(id, batch.requests);
                } else {
                    // no cold-adapter cliff: serve this batch unmerged now
                    // and let a background merge warm the cache for later
                    if !self.inflight.contains_key(&id) {
                        self.inflight.insert(
                            id,
                            Inflight {
                                miss_counted: true,
                                parked: Vec::new(),
                                waiters: Vec::new(),
                            },
                        );
                        self.submit_merge(id);
                    }
                    if self.factors_available(id) {
                        self.run_batch_factor(batch.requests);
                    } else {
                        let mut requests = batch.requests;
                        park_stage(&self.clock, &mut requests, Stage::MergeWait);
                        self.inflight
                            .get_mut(&id)
                            .expect("just ensured")
                            .parked
                            .push(requests);
                    }
                }
            }
            (_, None) => {
                // per-adapter batchers always tag their batches
                let err = ServeError::new(FailKind::Internal, "untagged adapter batch");
                let now = self.clock.now();
                for r in batch.requests {
                    self.fail_request(r, &err, now);
                }
            }
        }
    }

    fn on_batch_merged(&mut self, id: AdapterId, mut requests: Vec<Queued>) {
        if let Some(fl) = self.inflight.get_mut(&id) {
            // merge already in flight — park behind it. The batch's cache
            // lookup is deferred to the drain, so on the error-free path
            // every decoded batch performs exactly one counted lookup
            // (hits + misses == batches); failed merges abort their
            // parked batches before decode, so neither counter moves in
            // lock-step there.
            park_stage(&self.clock, &mut requests, Stage::MergeWait);
            fl.parked.push(requests);
            return;
        }
        if self.cache.get(&id).is_some() {
            self.run_batch_merged(id, requests);
        } else {
            park_stage(&self.clock, &mut requests, Stage::MergeWait);
            self.inflight.insert(
                id,
                Inflight { miss_counted: true, parked: vec![requests], waiters: Vec::new() },
            );
            self.submit_merge(id);
        }
    }

    fn submit_merge(&mut self, id: AdapterId) {
        let seq = self.merge_seq;
        self.merge_seq += 1;
        let tx = self.self_tx.clone();
        let job = MergeJob {
            adapter: id,
            kind: JobKind::Merge(Box::new(move |result, host_time| {
                let _ = tx.send(WorkerMsg::Merged { seq, adapter: id, result, host_time });
            })),
        };
        if self.merge_tx.send(job).is_err() {
            self.ingest(
                seq,
                HeldJob::Merge {
                    adapter: id,
                    result: Err(anyhow!("merge pool unavailable")),
                    host_time: Duration::ZERO,
                },
            );
        }
    }

    /// Queue a disk-tier factor fetch on the merge pool (same threads, so
    /// scripted disk latency parks off the executor workers; same
    /// sequence numbering, so merge and fetch completions share one
    /// deterministic ingest order under the virtual clock).
    fn submit_fetch(&mut self, id: AdapterId) {
        let seq = self.merge_seq;
        self.merge_seq += 1;
        let tx = self.self_tx.clone();
        let job = MergeJob {
            adapter: id,
            kind: JobKind::Fetch(Box::new(move |result, host_time| {
                let _ = tx.send(WorkerMsg::Fetched { seq, adapter: id, result, host_time });
            })),
        };
        if self.merge_tx.send(job).is_err() {
            self.ingest(
                seq,
                HeldJob::Fetch {
                    adapter: id,
                    result: Err(anyhow!("merge pool unavailable")),
                    host_time: Duration::ZERO,
                },
            );
        }
    }

    /// The merge completion sequencer (virtual clock only): apply
    /// completions in submission order. A merge that finishes before an
    /// earlier-submitted one is held (visible as
    /// `WorkerSnapshot::held_merges`) until its predecessors land, so
    /// cache-insert order — and LRU eviction under thrash — is a pure
    /// function of the deterministic submission order even with several
    /// merge threads racing. That is what makes `merge_workers > 1`
    /// traces byte-reproducible (DESIGN.md §11).
    ///
    /// In **real time** completions apply on arrival instead: strict
    /// sequencing would park a fast adapter's batches behind another
    /// adapter's slow merge (cross-adapter head-of-line blocking), and
    /// production has no byte-identical-trace contract to pay for.
    fn ingest(&mut self, seq: u64, job: HeldJob) {
        if !self.clock.is_virtual() {
            self.apply_job(job);
            return;
        }
        self.held.insert(seq, job);
        while let Some(j) = self.held.remove(&self.next_ingest) {
            self.next_ingest += 1;
            self.apply_job(j);
        }
    }

    fn apply_job(&mut self, job: HeldJob) {
        match job {
            HeldJob::Merge { adapter, result, host_time } => {
                self.on_merged(adapter, result, host_time)
            }
            HeldJob::Fetch { adapter, result, host_time } => {
                self.on_fetched(adapter, result, host_time)
            }
        }
    }

    /// A disk-tier fetch landed: install the packed factors in the factor
    /// cache, ack prefetch waiters, and decode everything parked behind
    /// the load. Fetch host time (including scripted disk latency) records
    /// into the merge-latency histogram — it is the same class of
    /// background host work.
    fn on_fetched(
        &mut self,
        id: AdapterId,
        result: anyhow::Result<Arc<StoredAdapter>>,
        host_time: Duration,
    ) {
        let Some(fl) = self.fetching.remove(&id) else { return };
        match result {
            Ok(arc) => {
                if let Some(h) = self.metrics.merge_latency.as_mut() {
                    h.record(host_time);
                }
                let bytes = arc.bytes();
                self.factor_cache.insert(id, arc, bytes);
                for ack in fl.waiters {
                    let _ = ack.send(Ok(()));
                }
                if !fl.parked.is_empty() {
                    self.drain_fetch_parked(fl.parked);
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                let err = self.load_failure(id, &msg);
                let now = self.clock.now();
                for ack in fl.waiters {
                    let _ = ack.send(Err(anyhow!("{msg}")));
                }
                // stranded requests fail in `FetchWait` — the stage the
                // fault struck in becomes the breakdown's terminal
                for r in fl.parked {
                    self.fail_request(r, &err, now);
                }
            }
        }
    }

    /// Classify a background load/merge failure for the requests it
    /// strands: a quarantined adapter (permanent disk failure) is
    /// `AdapterUnavailable`; anything else (worker panic, upload error)
    /// is `Internal`.
    fn load_failure(&self, id: AdapterId, msg: &str) -> ServeError {
        let quarantined =
            self.shared.with_registry(|r| r.get(id).is_some_and(|e| e.is_quarantined()));
        let kind = if quarantined { FailKind::AdapterUnavailable } else { FailKind::Internal };
        ServeError::new(kind, msg)
    }

    /// Decode the requests that parked behind a completed fetch. The
    /// continuous scheduler feeds them all into one factor-form session
    /// (lanes admit incrementally, so the group may exceed a bucket); the
    /// lock-step fallback chunks to the largest compiled bucket.
    fn drain_fetch_parked(&mut self, parked: Vec<Queued>) {
        #[cfg(not(feature = "pjrt"))]
        if self.continuous {
            self.run_group_factor(parked, 1);
            return;
        }
        let bucket = self.progs.last().expect("buckets validated non-empty").0;
        let mut head = parked;
        while !head.is_empty() {
            let tail = head.split_off(head.len().min(bucket));
            self.run_batch_factor(std::mem::take(&mut head));
            head = tail;
        }
    }

    /// Split factor-path requests into ready (factors in RAM) vs parked
    /// behind a disk fetch. Exactly one factor-cache miss is counted per
    /// submitted fetch and none while one is in flight, so on the request
    /// path `factor_cache.misses == tier disk loads`.
    fn partition_tiered(&mut self, requests: Vec<Queued>) -> Vec<Queued> {
        if self.shared.tier.is_none() {
            return requests;
        }
        enum Place {
            Resident,
            Tiered,
            Quarantined,
            Gone,
        }
        let mut ready = Vec::with_capacity(requests.len());
        for mut q in requests {
            let id = q.adapter;
            let place = self.shared.with_registry(|r| match r.get(id) {
                Some(e) if e.is_quarantined() => Place::Quarantined,
                Some(e) if e.resident().is_some() => Place::Resident,
                Some(_) => Place::Tiered,
                None => Place::Gone,
            });
            match place {
                Place::Resident => ready.push(q),
                Place::Gone => {
                    let err = ServeError::new(
                        FailKind::AdapterUnavailable,
                        format!("unknown adapter {id}"),
                    );
                    self.fail_request(q, &err, self.clock.now());
                }
                // quarantined mid-queue: fail fast, never re-park behind
                // a disk load that is known to fail
                Place::Quarantined => {
                    let err = ServeError::new(
                        FailKind::AdapterUnavailable,
                        format!(
                            "adapter {id} unavailable: quarantined after permanent load failure"
                        ),
                    );
                    self.fail_request(q, &err, self.clock.now());
                }
                Place::Tiered => {
                    if let Some(fl) = self.fetching.get_mut(&id) {
                        // fetch already in flight: park without counting
                        q.payload.2.advance(self.clock.now(), Stage::FetchWait);
                        fl.parked.push(q);
                    } else if self.factor_cache.get(&id).is_some() {
                        ready.push(q);
                    } else {
                        // the probe above counted this load's one miss
                        q.payload.2.advance(self.clock.now(), Stage::FetchWait);
                        self.fetching
                            .insert(id, FetchInflight { parked: vec![q], waiters: Vec::new() });
                        self.submit_fetch(id);
                    }
                }
            }
        }
        ready
    }

    /// Whether `id`'s packed factors can be bound right now (registry
    /// resident, or in the factor cache). Unknown adapters report `true`
    /// so the caller's normal unknown-adapter error path fires instead.
    fn factors_available(&self, id: AdapterId) -> bool {
        if self.shared.tier.is_none() {
            return true;
        }
        if self.factor_cache.peek(&id).is_some() {
            return true;
        }
        self.shared.with_registry(|r| r.get(id).is_none_or(|e| e.resident().is_some()))
    }

    /// Predictive warm-ahead: pull an adapter toward the serving tier
    /// ahead of its predicted next arrival. Never counts cache stats and
    /// never parks requests — purely a background fill.
    fn warm(&mut self, id: AdapterId) {
        // unknown or quarantined: never pull toward RAM in the background
        if self.shared.with_registry(|r| r.get(id).is_none_or(|e| e.is_quarantined())) {
            return;
        }
        if self.strategy == MergeStrategy::Factor {
            if self.factor_cache.touch(&id)
                || self.fetching.contains_key(&id)
                || self.factors_available(id)
            {
                return;
            }
            self.fetching.insert(id, FetchInflight::default());
            self.submit_fetch(id);
        } else {
            if self.cache.touch(&id) || self.inflight.contains_key(&id) {
                return;
            }
            self.inflight.insert(
                id,
                Inflight { miss_counted: false, parked: Vec::new(), waiters: Vec::new() },
            );
            self.submit_merge(id);
        }
    }

    fn on_merged(
        &mut self,
        id: AdapterId,
        result: anyhow::Result<Vec<Tensor>>,
        host_time: Duration,
    ) {
        let Some(fl) = self.inflight.remove(&id) else { return };
        let uploaded = result.and_then(|merged| {
            if self.shared.with_registry(|r| r.get(id).is_none()) {
                return Err(anyhow!("adapter {id} removed during merge"));
            }
            let t0 = self.clock.now();
            let dev = self.engine.upload_weights(&merged)?;
            Ok((dev, host_time + self.clock.now().duration_since(t0)))
        });
        match uploaded {
            Ok((dev, total)) => {
                let bytes = dev.bytes();
                self.cache.insert(id, dev, bytes);
                if let Some(h) = self.metrics.merge_latency.as_mut() {
                    h.record(total);
                }
                for ack in fl.waiters {
                    let _ = ack.send(Ok(()));
                }
                self.drain_parked(id, fl.miss_counted, fl.parked);
            }
            Err(e) => {
                let msg = format!("{e:#}");
                let err = self.load_failure(id, &msg);
                let now = self.clock.now();
                for ack in fl.waiters {
                    let _ = ack.send(Err(anyhow!("{msg}")));
                }
                // stranded requests fail in `MergeWait`
                for requests in fl.parked {
                    for r in requests {
                        self.fail_request(r, &err, now);
                    }
                }
            }
        }
    }

    /// Decode the batches that parked behind a completed merge. The
    /// continuous scheduler feeds them all into **one** session — this is
    /// the drain where freed lanes pay off hardest: every batch that
    /// piled up behind the merge shares one group, so short requests
    /// finish and hand their lanes to the next batch's requests instead
    /// of lock-stepping batch by batch.
    fn drain_parked(&mut self, id: AdapterId, miss_counted: bool, parked: Vec<Vec<Queued>>) {
        #[cfg(not(feature = "pjrt"))]
        if self.continuous {
            let all: Vec<Queued> = parked.into_iter().flatten().collect();
            if all.is_empty() {
                return;
            }
            // one counted lookup per decode group: the initiator's miss
            // (if any) was counted when the merge was triggered
            if !miss_counted {
                let _ = self.cache.get(&id);
            }
            self.run_group_merged(id, all);
            return;
        }
        for (i, requests) in parked.into_iter().enumerate() {
            // exactly one counted lookup per batch: the initiator's
            // miss was counted when the merge was triggered
            if i > 0 || !miss_counted {
                let _ = self.cache.get(&id);
            }
            self.run_batch_merged(id, requests);
        }
    }

    /// Smallest compiled bucket that fits `n` requests (largest if none):
    /// returns (bucket, index into `progs`).
    fn pick_bucket(&self, n: usize) -> (usize, usize) {
        let last = self.progs.len() - 1;
        let i = self.progs.iter().position(|(b, _)| *b >= n).unwrap_or(last);
        (self.progs[i].0, i)
    }

    fn run_batch_merged(&mut self, adapter: AdapterId, requests: Vec<Queued>) {
        let t_exec = self.clock.now();
        let outcome = self.decode_merged(adapter, &requests);
        self.finish_batch(requests, outcome, false, t_exec);
    }

    /// Factor-form decode: resolve every request's adapter to a packed
    /// factor view and serve the (possibly heterogeneous) batch over the
    /// unmerged base weights. No cache, no merge queue.
    fn run_batch_factor(&mut self, requests: Vec<Queued>) {
        let (valid, adapters) = self.resolve_factors(requests);
        if valid.is_empty() {
            return;
        }
        let t_exec = self.clock.now();
        let outcome = self.decode_factor(&valid, &adapters);
        self.finish_batch(valid, outcome, true, t_exec);
    }

    /// Resolve each request's adapter to packed factors: the registry's
    /// resident arc, else the worker's factor cache (peek — the request
    /// path's counted probe already happened in `partition_tiered`).
    /// A vanished or unexpectedly non-resident adapter fails only its own
    /// requests.
    fn resolve_factors(&mut self, requests: Vec<Queued>) -> (Vec<Queued>, Vec<Arc<StoredAdapter>>) {
        enum Got {
            Resident(Arc<StoredAdapter>),
            Tiered,
            Gone,
        }
        let got: Vec<Got> = self.shared.with_registry(|r| {
            requests
                .iter()
                .map(|q| match r.get(q.adapter) {
                    Some(e) => match e.resident() {
                        Some(a) => Got::Resident(Arc::clone(a)),
                        None => Got::Tiered,
                    },
                    None => Got::Gone,
                })
                .collect()
        });
        let mut valid = Vec::with_capacity(requests.len());
        let mut adapters = Vec::with_capacity(requests.len());
        for (r, g) in requests.into_iter().zip(got) {
            match g {
                Got::Resident(a) => {
                    valid.push(r);
                    adapters.push(a);
                }
                Got::Tiered => match self.factor_cache.peek(&r.adapter).cloned() {
                    Some(a) => {
                        valid.push(r);
                        adapters.push(a);
                    }
                    None => {
                        let err = ServeError::new(
                            FailKind::Internal,
                            format!("adapter {} factors not resident", r.adapter),
                        );
                        self.fail_request(r, &err, self.clock.now());
                    }
                },
                Got::Gone => {
                    let err = ServeError::new(
                        FailKind::AdapterUnavailable,
                        format!("unknown adapter {}", r.adapter),
                    );
                    self.fail_request(r, &err, self.clock.now());
                }
            }
        }
        (valid, adapters)
    }

    /// Respond + account for one decoded (or failed) batch. `t_exec` is
    /// the instant the batch entered execution: the lock-step path has
    /// no per-request prefill/decode boundary, so the whole execution
    /// window books to `Decode` in the stage breakdown (DESIGN.md §16).
    fn finish_batch(
        &mut self,
        requests: Vec<Queued>,
        outcome: anyhow::Result<Vec<Vec<i32>>>,
        factor: bool,
        t_exec: Instant,
    ) {
        match outcome {
            Ok(outputs) => {
                let now = self.clock.now();
                for (mut r, tokens) in requests.into_iter().zip(outputs) {
                    r.payload.2.advance(t_exec, Stage::Decode);
                    let e2e = now.duration_since(r.enqueued);
                    if let Some(h) = self.metrics.e2e_latency.as_mut() {
                        h.record(e2e);
                    }
                    self.metrics.requests += 1;
                    self.metrics.tokens_generated += tokens.len() as u64;
                    self.respond_ok(r, tokens, e2e, None, now);
                }
                self.metrics.batches += 1;
                if factor {
                    self.metrics.factor_batches += 1;
                }
            }
            Err(e) => {
                // a contained compute panic or decode error fails only
                // this batch's requests (DESIGN.md §15)
                let err = ServeError::new(FailKind::Internal, format!("{e:#}"));
                let now = self.clock.now();
                for mut r in requests {
                    r.payload.2.advance(t_exec, Stage::Decode);
                    self.fail_request(r, &err, now);
                }
            }
        }
    }

    /// Decode one merged-weight group through the continuous scheduler:
    /// every request of the group (possibly several released batches of
    /// one adapter) flows through the worker's persistent session, with
    /// freed lanes re-admitted mid-flight.
    #[cfg(not(feature = "pjrt"))]
    fn run_group_merged(&mut self, adapter: AdapterId, requests: Vec<Queued>) {
        let t_exec = self.clock.now();
        let outcome = self.decode_group(Some(adapter), &requests, &[]);
        self.finish_group(requests, outcome, false, 1, t_exec);
    }

    /// Decode one heterogeneous factor-form group: per-request adapters
    /// resolved from the registry (a vanished adapter fails only its own
    /// requests), then one continuous session over the base weights.
    /// `counted` is how many metric batches the group absorbed (see
    /// `on_batches_continuous`).
    #[cfg(not(feature = "pjrt"))]
    fn run_group_factor(&mut self, requests: Vec<Queued>, counted: u64) {
        let (valid, adapters) = self.resolve_factors(requests);
        if valid.is_empty() {
            return;
        }
        let t_exec = self.clock.now();
        let outcome = self.decode_group(None, &valid, &adapters);
        self.finish_group(valid, outcome, true, counted, t_exec);
    }

    /// Run one decode group through `scheduler::run_continuous` over the
    /// worker's persistent session. `merged` selects the weight context:
    /// `Some(id)` decodes on that adapter's cached merged weights with no
    /// per-lane adapters; `None` decodes on the resident base weights
    /// with `adapters[i]` bound to request `i`'s lanes.
    #[cfg(not(feature = "pjrt"))]
    fn decode_group(
        &mut self,
        merged: Option<AdapterId>,
        requests: &[Queued],
        adapters: &[Arc<StoredAdapter>],
    ) -> anyhow::Result<Vec<Option<(Vec<i32>, RequestOutcome, Option<Instant>)>>> {
        let cfg = &self.shared.base.cfg;
        let (t_len, vocab) = (cfg.seq_len, cfg.vocab);
        let (lanes, prog) = {
            let (bucket, key) = self.progs.last().expect("buckets validated non-empty");
            (*bucket, key.as_str())
        };
        // resolve weights before touching the admission queue, so an
        // error here leaves no orphaned queue entries
        let weights = match merged {
            Some(id) => self
                .cache
                .peek(&id)
                .ok_or_else(|| anyhow!("merged weights missing for adapter {id}"))?,
            None => self
                .base_weights
                .as_ref()
                .ok_or_else(|| anyhow!("factor path requires resident base weights"))?,
        };
        for (i, q) in requests.iter().enumerate() {
            let req = &q.payload.0;
            self.admission.push(LaneRequest {
                id: i as u64,
                tenant: q.adapter,
                prompt: req.prompt.clone(),
                budget: req.max_new,
                adapter: adapters.get(i).map(|a| {
                    let src: Arc<dyn FactorSource> = Arc::clone(a);
                    src
                }),
                enqueued: q.enqueued,
                deadline: q.deadline,
                cancel: req.options.cancel.clone(),
            });
        }
        let mut outputs: Vec<Option<(Vec<i32>, RequestOutcome, Option<Instant>)>> =
            vec![None; requests.len()];
        let mut ttfts: Vec<Duration> = Vec::with_capacity(requests.len());
        let ccfg =
            ContinuousConfig { lanes, seq_len: t_len, vocab, prefill_chunk: self.prefill_chunk };
        let t_exec = self.clock.now();
        let run = {
            let mut stepper = SessionStepper::new(&self.engine, prog, weights, &mut self.session);
            run_continuous(&mut stepper, &ccfg, &mut self.admission, &self.clock, |fin| {
                // ttft measures completed service; a request retired by
                // its deadline or a cancel token never produced a first
                // token the caller saw
                if fin.outcome == RequestOutcome::Done {
                    ttfts.push(fin.ttft);
                }
                outputs[fin.id as usize] = Some((fin.tokens, fin.outcome, fin.first_token));
            })
        };
        match run {
            Ok(stats) => {
                let exec = self.clock.now().duration_since(t_exec);
                if let Some(h) = self.metrics.exec_latency.as_mut() {
                    h.record(exec);
                }
                if let Some(h) = self.metrics.ttft_latency.as_mut() {
                    for t in ttfts {
                        h.record(t);
                    }
                }
                self.metrics.decode_steps += stats.decode_steps;
                self.metrics.prefill_passes += stats.admits;
                Ok(outputs)
            }
            Err(e) => {
                // a failed session leaves not-yet-admitted requests in
                // the queue; drain them so the error answers everyone and
                // the next group starts clean
                let _ = self.admission.drain_pending();
                Err(e)
            }
        }
    }

    /// Respond + account for one decoded (or failed) continuous group.
    /// `counted` is how many metric batches the group represents — 1 for
    /// merged groups, possibly more for factor groups that coalesced
    /// several counted cache misses into one session.
    #[cfg(not(feature = "pjrt"))]
    fn finish_group(
        &mut self,
        requests: Vec<Queued>,
        outcome: anyhow::Result<Vec<Option<(Vec<i32>, RequestOutcome, Option<Instant>)>>>,
        factor: bool,
        counted: u64,
        t_exec: Instant,
    ) {
        match outcome {
            Ok(outputs) => {
                let now = self.clock.now();
                for (mut r, out) in requests.into_iter().zip(outputs) {
                    // entering execution ends the wait stages; the window
                    // up to the first consumed token is prefill, the rest
                    // decode (DESIGN.md §16)
                    r.payload.2.advance(t_exec, Stage::Prefill);
                    match out {
                        Some((tokens, RequestOutcome::Done, first)) => {
                            let e2e = now.duration_since(r.enqueued);
                            if let Some(h) = self.metrics.e2e_latency.as_mut() {
                                h.record(e2e);
                            }
                            self.metrics.requests += 1;
                            self.metrics.tokens_generated += tokens.len() as u64;
                            self.respond_ok(r, tokens, e2e, first, now);
                        }
                        Some((tokens, RequestOutcome::Timeout, first)) => {
                            self.metrics.timeouts += 1;
                            if let Some(ft) = first {
                                r.payload.2.advance(ft, Stage::Decode);
                            }
                            let err = ServeError::new(
                                FailKind::Timeout,
                                format!(
                                    "deadline exceeded after {} generated token(s)",
                                    tokens.len()
                                ),
                            );
                            self.fail_request(r, &err, now);
                        }
                        Some((tokens, RequestOutcome::Cancelled, first)) => {
                            self.metrics.cancellations += 1;
                            if let Some(ft) = first {
                                r.payload.2.advance(ft, Stage::Decode);
                            }
                            let err = ServeError::new(
                                FailKind::Cancelled,
                                format!(
                                    "cancelled after {} generated token(s)",
                                    tokens.len()
                                ),
                            );
                            self.fail_request(r, &err, now);
                        }
                        None => {
                            // unreachable: run_continuous completes every
                            // admitted request or errors the whole group
                            let err = ServeError::new(
                                FailKind::Internal,
                                "request missed by scheduler",
                            );
                            self.fail_request(r, &err, now);
                        }
                    }
                }
                self.metrics.batches += counted;
                if factor {
                    self.metrics.factor_batches += counted;
                }
            }
            Err(e) => {
                // a contained compute panic or session error fails only
                // this group's requests (DESIGN.md §15)
                let err = ServeError::new(FailKind::Internal, format!("{e:#}"));
                let now = self.clock.now();
                for mut r in requests {
                    r.payload.2.advance(t_exec, Stage::Prefill);
                    self.fail_request(r, &err, now);
                }
            }
        }
    }

    /// Seed decode lanes from a batch on the smallest fitting bucket.
    /// Padding lanes replicate the last request's prompt with a **zero
    /// budget**: they are prefilled (the bucket shape is fixed) but the
    /// decode loop retires them before the first step, so padding costs
    /// no per-token work.
    fn build_lanes(&self, requests: &[Queued]) -> Lanes {
        let t_len = self.shared.base.cfg.seq_len;
        let n = requests.len();
        let (bsz, prog_idx) = self.pick_bucket(n);
        assert!(n <= bsz, "batcher released more than the largest bucket");
        let mut seqs = vec![vec![TOKENS::PAD; t_len]; bsz];
        let mut pos = vec![0usize; bsz];
        let mut budgets = vec![0usize; bsz];
        for k in 0..bsz {
            let req = &requests[k.min(n - 1)].payload.0;
            let plen = req.prompt.len().min(t_len);
            seqs[k][..plen].copy_from_slice(&req.prompt[..plen]);
            pos[k] = plen;
            budgets[k] = if k < n { req.max_new.min(t_len - plen) } else { 0 };
        }
        Lanes { seqs, pos, budgets, bsz, prog_idx }
    }

    /// Lock-step batched greedy decode over this adapter's cached merged
    /// weights (shared protocol: [`decode_lockstep`] over an incremental
    /// [`EngineStepper`] — prefill once, then O(T·d) per step per lane,
    /// with EOS-finished lanes retired).
    fn decode_merged(
        &mut self,
        adapter: AdapterId,
        requests: &[Queued],
    ) -> anyhow::Result<Vec<Vec<i32>>> {
        let t_len = self.shared.base.cfg.seq_len;
        let vocab = self.shared.base.cfg.vocab;
        let Lanes { mut seqs, mut pos, budgets, bsz: _, prog_idx } = self.build_lanes(requests);
        let t_exec = self.clock.now();
        let (mut generated, fwd) = {
            let engine = &self.engine;
            let weights = self
                .cache
                .peek(&adapter)
                .ok_or_else(|| anyhow!("merged weights missing for adapter {adapter}"))?;
            let prog = self.progs[prog_idx].1.as_str();
            let mut stepper = EngineStepper::new(engine, prog, weights, &[]);
            let g = decode_lockstep(t_len, vocab, &mut seqs, &mut pos, &budgets, &mut stepper)?;
            (g, (stepper.prefills(), stepper.steps()))
        };
        let exec = self.clock.now().duration_since(t_exec);
        if let Some(h) = self.metrics.exec_latency.as_mut() {
            h.record(exec);
        }
        self.metrics.prefill_passes += fwd.0;
        self.metrics.decode_steps += fwd.1;
        generated.truncate(requests.len());
        Ok(generated)
    }

    /// Lock-step batched greedy decode over the **unmerged** base weights,
    /// applying each lane's adapter in factor form on the activation path
    /// — per-request adapters, so the batch may mix tenants. Same
    /// incremental stepper as the merged path: the per-step factor delta
    /// touches only each active lane's single activation row.
    fn decode_factor(
        &mut self,
        requests: &[Queued],
        adapters: &[Arc<StoredAdapter>],
    ) -> anyhow::Result<Vec<Vec<i32>>> {
        let t_len = self.shared.base.cfg.seq_len;
        let vocab = self.shared.base.cfg.vocab;
        let Lanes { mut seqs, mut pos, budgets, bsz, prog_idx } = self.build_lanes(requests);
        let n = requests.len();
        let factors: Vec<QFactors<'_>> = adapters.iter().map(|a| a.factors()).collect();
        let lane_factors: Vec<Option<&QFactors<'_>>> =
            (0..bsz).map(|k| Some(&factors[k.min(n - 1)])).collect();
        let t_exec = self.clock.now();
        let (mut generated, fwd) = {
            let engine = &self.engine;
            let weights = self
                .base_weights
                .as_ref()
                .ok_or_else(|| anyhow!("factor path requires resident base weights"))?;
            let prog = self.progs[prog_idx].1.as_str();
            let mut stepper = EngineStepper::new(engine, prog, weights, &lane_factors);
            let g = decode_lockstep(t_len, vocab, &mut seqs, &mut pos, &budgets, &mut stepper)?;
            (g, (stepper.prefills(), stepper.steps()))
        };
        let exec = self.clock.now().duration_since(t_exec);
        if let Some(h) = self.metrics.exec_latency.as_mut() {
            h.record(exec);
        }
        self.metrics.prefill_passes += fwd.0;
        self.metrics.decode_steps += fwd.1;
        generated.truncate(n);
        Ok(generated)
    }
}

/// Decode lanes seeded from one batch (see [`Worker::build_lanes`]).
struct Lanes {
    seqs: Vec<Vec<i32>>,
    pos: Vec<usize>,
    budgets: Vec<usize>,
    /// Bucket size actually decoded (≥ batch size).
    bsz: usize,
    /// Index into `Worker::progs`.
    prog_idx: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn route_is_stable_and_in_range() {
        for n in 1..=8usize {
            for id in 0..200u32 {
                let w = route(id, n);
                assert!(w < n);
                assert_eq!(w, route(id, n), "route must be deterministic");
            }
        }
    }

    #[test]
    fn route_spreads_adapters() {
        let n = 4;
        let mut counts = vec![0usize; n];
        for id in 0..400u32 {
            counts[route(id, n)] += 1;
        }
        for (w, &c) in counts.iter().enumerate() {
            assert!(c > 40, "worker {w} owns only {c}/400 adapters");
        }
    }

    #[test]
    fn route_growth_is_minimally_disruptive() {
        // rendezvous property: going from n to n+1 workers either keeps a
        // key's owner or moves it to the NEW worker — never shuffles
        // between existing workers.
        for n in 1..6usize {
            for id in 0..300u32 {
                let before = route(id, n);
                let after = route(id, n + 1);
                assert!(
                    after == before || after == n,
                    "id {id}: {before} -> {after} with pool {n}->{}",
                    n + 1
                );
            }
        }
    }
}
