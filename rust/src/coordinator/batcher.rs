//! Dynamic batching, in one of two grouping modes.
//!
//! * **Per-adapter** (`group_by_adapter: true`, the default): all requests
//!   in a batch share one adapter — they execute against one merged
//!   weight set (the S-LoRA batching model restated for merged serving).
//! * **Mixed** (`group_by_adapter: false`): requests batch in arrival
//!   order regardless of adapter — the factor-form execution path applies
//!   each request's adapter on the activation path, so one forward serves
//!   a heterogeneous multi-adapter batch.
//!
//! Either way a batch is released when it reaches the bucket size, or when
//! its oldest request has waited `max_wait`; queues are drained in
//! oldest-request-first order (no tenant starves).

use crate::coordinator::registry::AdapterId;
use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Target batch size (must equal a compiled batch bucket).
    pub bucket: usize,
    /// Maximum time the oldest request may wait before a partial batch is
    /// released.
    pub max_wait: Duration,
    /// `true` ⇒ per-adapter batches (merged serving); `false` ⇒ mixed
    /// heterogeneous batches (factor-form serving).
    pub group_by_adapter: bool,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { bucket: 8, max_wait: Duration::from_millis(20), group_by_adapter: true }
    }
}

/// A queued request (payload opaque to the batcher).
#[derive(Debug)]
pub struct PendingRequest<T> {
    pub adapter: AdapterId,
    pub enqueued: Instant,
    /// Absolute per-request deadline: once it passes, the request is
    /// handed back by [`DynamicBatcher::expire`] instead of being
    /// released in a batch (`None` = no deadline).
    pub deadline: Option<Instant>,
    pub payload: T,
}

/// A released batch. `adapter` is `Some` in per-adapter mode (every
/// request shares it) and `None` for a mixed heterogeneous batch.
#[derive(Debug)]
pub struct Batch<T> {
    pub adapter: Option<AdapterId>,
    pub requests: Vec<PendingRequest<T>>,
}

/// The dynamic batcher. Pure data structure — driven by the server loop,
/// fully unit-testable without threads.
#[derive(Debug)]
pub struct DynamicBatcher<T> {
    cfg: BatcherConfig,
    /// Per-adapter queues, or the single `None` queue in mixed mode.
    queues: BTreeMap<Option<AdapterId>, VecDeque<PendingRequest<T>>>,
    pending: usize,
}

impl<T> DynamicBatcher<T> {
    pub fn new(cfg: BatcherConfig) -> Self {
        Self { cfg, queues: BTreeMap::new(), pending: 0 }
    }

    /// Enqueue a request.
    pub fn push(&mut self, req: PendingRequest<T>) {
        let key = self.cfg.group_by_adapter.then_some(req.adapter);
        self.queues.entry(key).or_default().push_back(req);
        self.pending += 1;
    }

    /// Number of queued requests.
    pub fn pending(&self) -> usize {
        self.pending
    }

    /// Pop the next releasable batch at time `now`:
    /// 1. any adapter with ≥ bucket requests (oldest such first), else
    /// 2. the adapter whose oldest request exceeded `max_wait`.
    pub fn pop_ready(&mut self, now: Instant) -> Option<Batch<T>> {
        self.pop(Some(now))
    }

    /// Pop the next batch regardless of deadline (oldest head first) —
    /// the shutdown drain, where partial batches release immediately
    /// instead of waiting out `max_wait`.
    pub fn pop_flush(&mut self) -> Option<Batch<T>> {
        self.pop(None)
    }

    /// `deadline_at` is the release clock: `Some(now)` applies the
    /// max-wait policy at that instant, `None` means no deadline — every
    /// queue is considered expired (flush).
    fn pop(&mut self, deadline_at: Option<Instant>) -> Option<Batch<T>> {
        // full batches first, choosing the adapter with the oldest head
        let full = self
            .queues
            .iter()
            .filter(|(_, q)| q.len() >= self.cfg.bucket)
            .min_by_key(|(_, q)| q.front().map(|r| r.enqueued).unwrap())
            .map(|(&id, _)| id);
        if let Some(id) = full {
            return Some(self.drain(id));
        }
        let expired = self
            .queues
            .iter()
            .filter(|(_, q)| {
                q.front().is_some_and(|r| match deadline_at {
                    Some(now) => now.duration_since(r.enqueued) >= self.cfg.max_wait,
                    None => true,
                })
            })
            .min_by_key(|(_, q)| q.front().map(|r| r.enqueued).unwrap())
            .map(|(&id, _)| id);
        expired.map(|id| self.drain(id))
    }

    /// Remove and return every queued request whose deadline is at or
    /// before `now` — the batcher-level timeout pass. Requests that
    /// expire here never reach a worker; the caller answers each with a
    /// `Timeout`. Queue order among survivors is preserved.
    pub fn expire(&mut self, now: Instant) -> Vec<PendingRequest<T>> {
        let mut out = Vec::new();
        self.queues.retain(|_, q| {
            let mut kept = VecDeque::with_capacity(q.len());
            for r in q.drain(..) {
                if r.deadline.is_some_and(|d| d <= now) {
                    out.push(r);
                } else {
                    kept.push_back(r);
                }
            }
            *q = kept;
            !q.is_empty()
        });
        self.pending -= out.len();
        out
    }

    /// Time until the oldest queued request expires (drives the server's
    /// `recv_timeout`); `None` when idle. Considers both the max-wait
    /// release clock and every queued request's own deadline, so the
    /// server wakes in time to run the [`DynamicBatcher::expire`] pass.
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.queues
            .values()
            .flat_map(|q| {
                let release = q.front().map(|r| {
                    let waited = now.duration_since(r.enqueued);
                    self.cfg.max_wait.saturating_sub(waited)
                });
                let request = q
                    .iter()
                    .filter_map(|r| r.deadline)
                    .map(|d| d.saturating_duration_since(now))
                    .min();
                [release, request].into_iter().flatten().collect::<Vec<_>>()
            })
            .min()
    }

    fn drain(&mut self, key: Option<AdapterId>) -> Batch<T> {
        let q = self.queues.get_mut(&key).expect("drain of empty adapter queue");
        let take = q.len().min(self.cfg.bucket);
        let requests: Vec<_> = q.drain(..take).collect();
        self.pending -= requests.len();
        if q.is_empty() {
            self.queues.remove(&key);
        }
        Batch { adapter: key, requests }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(adapter: AdapterId, t: Instant) -> PendingRequest<u32> {
        PendingRequest { adapter, enqueued: t, deadline: None, payload: 0 }
    }

    #[test]
    fn releases_full_bucket_immediately() {
        let t0 = Instant::now();
        let mut b = DynamicBatcher::new(BatcherConfig { bucket: 3, max_wait: Duration::from_secs(9), ..Default::default() });
        for _ in 0..3 {
            b.push(req(7, t0));
        }
        let batch = b.pop_ready(t0).expect("full bucket must release");
        assert_eq!(batch.adapter, Some(7));
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn partial_batch_waits_until_deadline() {
        let t0 = Instant::now();
        let cfg = BatcherConfig { bucket: 4, max_wait: Duration::from_millis(10), ..Default::default() };
        let mut b = DynamicBatcher::new(cfg);
        b.push(req(1, t0));
        assert!(b.pop_ready(t0).is_none(), "fresh partial batch must wait");
        let later = t0 + Duration::from_millis(11);
        let batch = b.pop_ready(later).expect("expired partial batch must release");
        assert_eq!(batch.requests.len(), 1);
    }

    #[test]
    fn batches_never_mix_adapters() {
        let t0 = Instant::now();
        let mut b = DynamicBatcher::new(BatcherConfig { bucket: 2, max_wait: Duration::ZERO, ..Default::default() });
        b.push(req(1, t0));
        b.push(req(2, t0));
        b.push(req(1, t0));
        let mut seen = Vec::new();
        while let Some(batch) = b.pop_ready(t0 + Duration::from_millis(1)) {
            assert!(batch.requests.iter().all(|r| Some(r.adapter) == batch.adapter));
            seen.push((batch.adapter.unwrap(), batch.requests.len()));
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![(1, 2), (2, 1)]);
    }

    #[test]
    fn oldest_head_served_first() {
        let t0 = Instant::now();
        let mut b = DynamicBatcher::new(BatcherConfig { bucket: 1, max_wait: Duration::ZERO, ..Default::default() });
        b.push(req(5, t0 + Duration::from_millis(2)));
        b.push(req(3, t0)); // older head
        let batch = b.pop_ready(t0 + Duration::from_secs(1)).unwrap();
        assert_eq!(batch.adapter, Some(3));
    }

    #[test]
    fn deadline_reflects_oldest() {
        let t0 = Instant::now();
        let cfg = BatcherConfig { bucket: 8, max_wait: Duration::from_millis(20), ..Default::default() };
        let mut b = DynamicBatcher::new(cfg);
        assert!(b.next_deadline(t0).is_none());
        b.push(req(1, t0));
        let d = b.next_deadline(t0 + Duration::from_millis(5)).unwrap();
        assert!(d <= Duration::from_millis(15));
    }

    #[test]
    fn drain_caps_at_bucket() {
        let t0 = Instant::now();
        let mut b = DynamicBatcher::new(BatcherConfig { bucket: 2, max_wait: Duration::ZERO, ..Default::default() });
        for _ in 0..5 {
            b.push(req(1, t0));
        }
        let batch = b.pop_ready(t0).unwrap();
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(b.pending(), 3);
    }

    // ---- injected-Instant coverage of the release policy ---------------

    #[test]
    fn full_bucket_releases_before_deadline_batches() {
        // adapter 9 is old but partial; adapter 2 is fresh but full — the
        // full bucket must win the pop.
        let t0 = Instant::now();
        let cfg = BatcherConfig { bucket: 2, max_wait: Duration::from_millis(5), ..Default::default() };
        let mut b = DynamicBatcher::new(cfg);
        b.push(req(9, t0));
        b.push(req(2, t0 + Duration::from_millis(20)));
        b.push(req(2, t0 + Duration::from_millis(20)));
        let batch = b.pop_ready(t0 + Duration::from_millis(30)).unwrap();
        assert_eq!(batch.adapter, Some(2), "full bucket outranks older partial");
        assert_eq!(batch.requests.len(), 2);
        let batch = b.pop_ready(t0 + Duration::from_millis(30)).unwrap();
        assert_eq!(batch.adapter, Some(9));
    }

    #[test]
    fn max_wait_release_is_exact_at_the_deadline() {
        let t0 = Instant::now();
        let cfg = BatcherConfig { bucket: 8, max_wait: Duration::from_millis(10), ..Default::default() };
        let mut b = DynamicBatcher::new(cfg);
        b.push(req(1, t0));
        assert!(b.pop_ready(t0 + Duration::from_millis(9)).is_none(), "before deadline");
        let batch = b
            .pop_ready(t0 + Duration::from_millis(10))
            .expect("release exactly at max_wait (>=, not >)");
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn expired_adapters_drain_oldest_first() {
        // three expired adapters, distinct head ages — pops must come back
        // oldest-head-first so no tenant starves behind a busier one.
        let t0 = Instant::now();
        let cfg = BatcherConfig { bucket: 8, max_wait: Duration::from_millis(1), ..Default::default() };
        let mut b = DynamicBatcher::new(cfg);
        b.push(req(4, t0 + Duration::from_millis(2)));
        b.push(req(7, t0));
        b.push(req(5, t0 + Duration::from_millis(1)));
        let now = t0 + Duration::from_secs(1);
        let order: Vec<Option<AdapterId>> =
            std::iter::from_fn(|| b.pop_ready(now).map(|x| x.adapter)).collect();
        assert_eq!(order, vec![Some(7), Some(5), Some(4)]);
    }

    #[test]
    fn mixed_mode_batches_across_adapters() {
        let t0 = Instant::now();
        let cfg = BatcherConfig {
            bucket: 4,
            max_wait: Duration::from_millis(10),
            group_by_adapter: false,
        };
        let mut b = DynamicBatcher::new(cfg);
        for adapter in [3, 1, 4, 1] {
            b.push(req(adapter, t0));
        }
        let batch = b.pop_ready(t0).expect("full mixed bucket must release");
        assert_eq!(batch.adapter, None, "mixed batches carry no single adapter");
        assert_eq!(batch.requests.len(), 4);
        let adapters: Vec<AdapterId> = batch.requests.iter().map(|r| r.adapter).collect();
        assert_eq!(adapters, vec![3, 1, 4, 1], "arrival order preserved");
        assert_eq!(b.pending(), 0);
        // a partial mixed batch still honors max_wait
        b.push(req(9, t0));
        assert!(b.pop_ready(t0).is_none());
        assert!(b.pop_ready(t0 + Duration::from_millis(10)).is_some());
    }

    #[test]
    fn pop_flush_releases_partial_batches_immediately() {
        // shutdown drain: fresh partial batches release without waiting
        // out max_wait, oldest head first, full buckets still first.
        let t0 = Instant::now();
        let cfg = BatcherConfig { bucket: 2, max_wait: Duration::from_secs(3600), ..Default::default() };
        let mut b = DynamicBatcher::new(cfg);
        b.push(req(5, t0 + Duration::from_millis(1)));
        b.push(req(3, t0)); // older partial head
        b.push(req(7, t0 + Duration::from_millis(2)));
        b.push(req(7, t0 + Duration::from_millis(2))); // full bucket
        assert!(b.pop_ready(t0 + Duration::from_millis(3)).map(|x| x.adapter) == Some(Some(7)));
        let order: Vec<Option<AdapterId>> =
            std::iter::from_fn(|| b.pop_flush().map(|x| x.adapter)).collect();
        assert_eq!(order, vec![Some(3), Some(5)], "flush drains oldest head first");
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn next_deadline_none_when_empty() {
        let t0 = Instant::now();
        let cfg = BatcherConfig { bucket: 4, max_wait: Duration::from_millis(10), ..Default::default() };
        let mut b = DynamicBatcher::new(cfg);
        assert!(b.next_deadline(t0).is_none(), "idle batcher has no deadline");
        b.push(req(1, t0));
        assert!(b.next_deadline(t0).is_some());
        b.pop_ready(t0 + Duration::from_millis(10)).unwrap();
        let later = t0 + Duration::from_millis(11);
        assert!(b.next_deadline(later).is_none(), "idle again after drain");
    }

    #[test]
    fn expire_removes_only_past_deadline_requests_preserving_order() {
        let t0 = Instant::now();
        let cfg =
            BatcherConfig { bucket: 8, max_wait: Duration::from_secs(3600), ..Default::default() };
        let mut b = DynamicBatcher::new(cfg);
        let mut push = |adapter, payload, deadline_ms: Option<u64>| {
            b.push(PendingRequest {
                adapter,
                enqueued: t0,
                deadline: deadline_ms.map(|ms| t0 + Duration::from_millis(ms)),
                payload,
            });
        };
        push(1, 10u32, Some(5)); // expires
        push(1, 11, None); // survives (no deadline)
        push(1, 12, Some(50)); // survives (future deadline)
        push(2, 20, Some(5)); // expires
        let expired = b.expire(t0 + Duration::from_millis(5));
        let mut gone: Vec<u32> = expired.iter().map(|r| r.payload).collect();
        gone.sort_unstable();
        assert_eq!(gone, vec![10, 20], "deadline <= now expires (inclusive)");
        assert_eq!(b.pending(), 2);
        // survivors keep their FIFO order inside the adapter queue
        let batch = b.pop_flush().unwrap();
        assert_eq!(batch.adapter, Some(1));
        let payloads: Vec<u32> = batch.requests.iter().map(|r| r.payload).collect();
        assert_eq!(payloads, vec![11, 12]);
        // expiring an empty batcher is a no-op
        assert!(b.expire(t0 + Duration::from_secs(9)).is_empty() || b.pending() == 0);
    }

    #[test]
    fn next_deadline_sees_request_deadlines() {
        let t0 = Instant::now();
        let cfg =
            BatcherConfig { bucket: 8, max_wait: Duration::from_secs(3600), ..Default::default() };
        let mut b = DynamicBatcher::new(cfg);
        b.push(PendingRequest {
            adapter: 1,
            enqueued: t0,
            deadline: Some(t0 + Duration::from_millis(7)),
            payload: 0u32,
        });
        // max_wait is an hour away: the wake-up must come from the
        // request's own deadline instead
        let d = b.next_deadline(t0).unwrap();
        assert_eq!(d, Duration::from_millis(7));
        assert_eq!(b.next_deadline(t0 + Duration::from_millis(9)), Some(Duration::ZERO));
    }

    #[test]
    fn next_deadline_saturates_past_due() {
        let t0 = Instant::now();
        let cfg = BatcherConfig { bucket: 4, max_wait: Duration::from_millis(10), ..Default::default() };
        let mut b = DynamicBatcher::new(cfg);
        b.push(req(1, t0));
        // long past the deadline: the wait must clamp to zero, not wrap
        assert_eq!(b.next_deadline(t0 + Duration::from_secs(5)), Some(Duration::ZERO));
    }
}
