//! Adapter registry: the at-rest store of every adapter the deployment
//! serves. LoRAQuant-compressed adapters stay packed until activated.

use crate::adapter::LoraAdapter;
use crate::loraquant::{fp_factors, QFactors, QuantizedLora};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Registry key for one adapter (tenant/task).
pub type AdapterId = u32;

/// An adapter at rest.
#[derive(Debug, Clone)]
pub enum StoredAdapter {
    /// Uncompressed FP16 baseline (2 bytes/param).
    Fp16(LoraAdapter),
    /// LoRAQuant-packed.
    Quantized(QuantizedLora),
}

impl StoredAdapter {
    /// Resident bytes at rest.
    pub fn bytes(&self) -> usize {
        match self {
            StoredAdapter::Fp16(a) => a.fp16_bytes(),
            StoredAdapter::Quantized(q) => q.packed_bytes(),
        }
    }

    /// Average bits per original parameter (Eq. 10; 16 for FP16).
    pub fn avg_bits(&self) -> f64 {
        match self {
            StoredAdapter::Fp16(_) => 16.0,
            StoredAdapter::Quantized(q) => q.avg_bits(),
        }
    }

    /// Per-site deltas `ΔW = B A` (dequantizing if packed) — the merged
    /// execution path's input.
    pub fn deltas(&self) -> BTreeMap<String, crate::tensor::Matrix> {
        match self {
            StoredAdapter::Fp16(a) => crate::model::merge::fp_deltas(a),
            StoredAdapter::Quantized(q) => crate::model::merge::quant_deltas(q),
        }
    }

    /// Borrowed factor-form view — the unmerged execution path's input.
    /// Nothing is dequantized or densified; quantized adapters stay
    /// packed, FP adapters expose their dense factors directly.
    pub fn factors(&self) -> QFactors<'_> {
        match self {
            StoredAdapter::Fp16(a) => fp_factors(a),
            StoredAdapter::Quantized(q) => q.factors(),
        }
    }
}

/// Type-erased handle for the continuous-batching scheduler: a lane can
/// hold `Arc<dyn FactorSource>` without the engine layer knowing about
/// registry types.
impl crate::loraquant::FactorSource for StoredAdapter {
    fn factors(&self) -> QFactors<'_> {
        StoredAdapter::factors(self)
    }

    /// Direct per-site lookup — the decode hot path asks the bound source
    /// per (layer, site) instead of materializing the whole map.
    fn site(&self, name: &str) -> Option<crate::loraquant::SiteFactors<'_>> {
        match self {
            StoredAdapter::Fp16(a) => {
                a.sites.get(name).map(|(a, b)| crate::loraquant::fp_site_factors(a, b))
            }
            StoredAdapter::Quantized(q) => q.sites.get(name).map(|s| s.factors()),
        }
    }
}

/// Entry metadata kept alongside the adapter. The adapter itself is
/// `Arc`-shared so executor workers can hold a batch's adapters across a
/// factor-form decode without copying packed bytes or holding the
/// registry lock.
#[derive(Debug, Clone)]
pub struct RegistryEntry {
    pub adapter: Arc<StoredAdapter>,
    /// Which eval task this adapter serves (used by examples/benches).
    pub task: String,
}

/// The adapter store.
#[derive(Debug, Default)]
pub struct AdapterRegistry {
    entries: BTreeMap<AdapterId, RegistryEntry>,
    next_id: AdapterId,
}

impl AdapterRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register an adapter; returns its id.
    pub fn register(&mut self, adapter: StoredAdapter, task: impl Into<String>) -> AdapterId {
        let id = self.next_id;
        self.next_id += 1;
        self.entries.insert(id, RegistryEntry { adapter: Arc::new(adapter), task: task.into() });
        id
    }

    /// Remove an adapter (returns whether it existed).
    pub fn remove(&mut self, id: AdapterId) -> bool {
        self.entries.remove(&id).is_some()
    }

    pub fn get(&self, id: AdapterId) -> Option<&RegistryEntry> {
        self.entries.get(&id)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn ids(&self) -> Vec<AdapterId> {
        self.entries.keys().copied().collect()
    }

    /// Total at-rest bytes across all adapters (Fig. 6 y-axis).
    pub fn total_bytes(&self) -> usize {
        self.entries.values().map(|e| e.adapter.bytes()).sum()
    }

    /// Mean avg-bits across adapters.
    pub fn mean_avg_bits(&self) -> f64 {
        if self.entries.is_empty() {
            return 0.0;
        }
        self.entries.values().map(|e| e.adapter.avg_bits()).sum::<f64>() / self.entries.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loraquant::{quantize_site, LoraQuantConfig, QuantizedLora};
    use crate::testutil::Rng;

    fn quantized(rng: &mut Rng) -> StoredAdapter {
        let (b, a) = rng.lora_pair(64, 64, 8, 0.7);
        let mut q = QuantizedLora::default();
        q.sites.insert("l0.wq".into(), quantize_site(&b, &a, &LoraQuantConfig::default()));
        StoredAdapter::Quantized(q)
    }

    #[test]
    fn register_get_remove() {
        let mut rng = Rng::new(141);
        let mut reg = AdapterRegistry::new();
        let id0 = reg.register(quantized(&mut rng), "modadd");
        let id1 = reg.register(quantized(&mut rng), "keyword");
        assert_ne!(id0, id1);
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.get(id0).unwrap().task, "modadd");
        assert!(reg.remove(id0));
        assert!(!reg.remove(id0));
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn quantized_is_smaller_at_rest() {
        let mut rng = Rng::new(142);
        let (b, a) = rng.lora_pair(64, 64, 8, 0.7);
        let fp = {
            let mut ad = LoraAdapter::default();
            ad.sites.insert("l0.wq".into(), (a.clone(), b.clone()));
            StoredAdapter::Fp16(ad)
        };
        let mut rng2 = Rng::new(142);
        let q = quantized(&mut rng2);
        assert!(q.bytes() * 4 < fp.bytes(), "quant {} vs fp16 {}", q.bytes(), fp.bytes());
        assert!(q.avg_bits() < 2.5);
        assert_eq!(fp.avg_bits(), 16.0);
    }

    #[test]
    fn total_bytes_accumulates() {
        let mut rng = Rng::new(143);
        let mut reg = AdapterRegistry::new();
        let a1 = quantized(&mut rng);
        let unit = a1.bytes();
        reg.register(a1, "t");
        let before = reg.total_bytes();
        assert_eq!(before, unit);
        let mut rng2 = Rng::new(144);
        reg.register(quantized(&mut rng2), "t");
        assert_eq!(reg.total_bytes(), before * 2);
    }
}
