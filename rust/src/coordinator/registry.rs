//! Adapter registry: the at-rest store of every adapter the deployment
//! serves. LoRAQuant-compressed adapters stay packed until activated.

use crate::adapter::LoraAdapter;
use crate::loraquant::{fp_factors, QFactors, QuantizedLora};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Registry key for one adapter (tenant/task).
pub type AdapterId = u32;

/// An adapter at rest.
#[derive(Debug, Clone)]
pub enum StoredAdapter {
    /// Uncompressed FP16 baseline (2 bytes/param).
    Fp16(LoraAdapter),
    /// LoRAQuant-packed.
    Quantized(QuantizedLora),
}

impl StoredAdapter {
    /// Resident bytes at rest.
    pub fn bytes(&self) -> usize {
        match self {
            StoredAdapter::Fp16(a) => a.fp16_bytes(),
            StoredAdapter::Quantized(q) => q.packed_bytes(),
        }
    }

    /// Average bits per original parameter (Eq. 10; 16 for FP16).
    pub fn avg_bits(&self) -> f64 {
        match self {
            StoredAdapter::Fp16(_) => 16.0,
            StoredAdapter::Quantized(q) => q.avg_bits(),
        }
    }

    /// Per-site deltas `ΔW = B A` (dequantizing if packed) — the merged
    /// execution path's input.
    pub fn deltas(&self) -> BTreeMap<String, crate::tensor::Matrix> {
        match self {
            StoredAdapter::Fp16(a) => crate::model::merge::fp_deltas(a),
            StoredAdapter::Quantized(q) => crate::model::merge::quant_deltas(q),
        }
    }

    /// Borrowed factor-form view — the unmerged execution path's input.
    /// Nothing is dequantized or densified; quantized adapters stay
    /// packed, FP adapters expose their dense factors directly.
    pub fn factors(&self) -> QFactors<'_> {
        match self {
            StoredAdapter::Fp16(a) => fp_factors(a),
            StoredAdapter::Quantized(q) => q.factors(),
        }
    }
}

/// Type-erased handle for the continuous-batching scheduler: a lane can
/// hold `Arc<dyn FactorSource>` without the engine layer knowing about
/// registry types.
impl crate::loraquant::FactorSource for StoredAdapter {
    fn factors(&self) -> QFactors<'_> {
        StoredAdapter::factors(self)
    }

    /// Direct per-site lookup — the decode hot path asks the bound source
    /// per (layer, site) instead of materializing the whole map.
    fn site(&self, name: &str) -> Option<crate::loraquant::SiteFactors<'_>> {
        match self {
            StoredAdapter::Fp16(a) => {
                a.sites.get(name).map(|(a, b)| crate::loraquant::fp_site_factors(a, b))
            }
            StoredAdapter::Quantized(q) => q.sites.get(name).map(|s| s.factors()),
        }
    }
}

/// Where an adapter's packed factors currently live.
#[derive(Debug, Clone)]
pub enum AdapterSlot {
    /// Factors resident in RAM, `Arc`-shared so executor workers can
    /// hold a batch's adapters across a factor-form decode without
    /// copying packed bytes or holding the registry lock.
    Resident(Arc<StoredAdapter>),
    /// Factors demoted to the on-disk tier (`coordinator::tier`); the
    /// registry keeps only metadata and the tier loads on miss.
    Tiered,
}

/// Entry metadata kept alongside the adapter. Size/precision accounting
/// is captured at registration so it survives demotion to disk.
#[derive(Debug, Clone)]
pub struct RegistryEntry {
    slot: AdapterSlot,
    /// Which eval task this adapter serves (used by examples/benches).
    pub task: String,
    bytes: usize,
    avg_bits: f64,
    /// Set after a permanent tier-load failure (or by scripted churn):
    /// requests for a quarantined adapter fail fast with
    /// `AdapterUnavailable` instead of re-parking on a broken disk path
    /// (DESIGN.md §15). Metadata survives; `recover` clears the flag.
    quarantined: bool,
}

impl RegistryEntry {
    /// The resident factors, if any.
    pub fn resident(&self) -> Option<&Arc<StoredAdapter>> {
        match &self.slot {
            AdapterSlot::Resident(a) => Some(a),
            AdapterSlot::Tiered => None,
        }
    }

    /// Whether the factors have been demoted to the disk tier.
    pub fn is_tiered(&self) -> bool {
        matches!(self.slot, AdapterSlot::Tiered)
    }

    /// Whether the adapter is quarantined (fail fast, don't load).
    pub fn is_quarantined(&self) -> bool {
        self.quarantined
    }

    /// At-rest packed bytes (valid whether resident or tiered).
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Average bits per original parameter (Eq. 10; 16 for FP16).
    pub fn avg_bits(&self) -> f64 {
        self.avg_bits
    }
}

/// The adapter store.
#[derive(Debug, Default)]
pub struct AdapterRegistry {
    entries: BTreeMap<AdapterId, RegistryEntry>,
    next_id: AdapterId,
}

impl AdapterRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register an adapter (resident); returns its id.
    pub fn register(&mut self, adapter: StoredAdapter, task: impl Into<String>) -> AdapterId {
        let id = self.next_id;
        self.next_id += 1;
        let (bytes, avg_bits) = (adapter.bytes(), adapter.avg_bits());
        self.entries.insert(
            id,
            RegistryEntry {
                slot: AdapterSlot::Resident(Arc::new(adapter)),
                task: task.into(),
                bytes,
                avg_bits,
                quarantined: false,
            },
        );
        id
    }

    /// Quarantine an adapter: keep its metadata but make every lookup
    /// fail fast until [`AdapterRegistry::recover`]. Returns whether the
    /// adapter exists and was not already quarantined.
    pub fn quarantine(&mut self, id: AdapterId) -> bool {
        match self.entries.get_mut(&id) {
            Some(e) if !e.quarantined => {
                e.quarantined = true;
                true
            }
            _ => false,
        }
    }

    /// Lift a quarantine (the operator fixed the disk / re-uploaded the
    /// artifact). Returns whether the adapter exists and was quarantined.
    pub fn recover(&mut self, id: AdapterId) -> bool {
        match self.entries.get_mut(&id) {
            Some(e) if e.quarantined => {
                e.quarantined = false;
                true
            }
            _ => false,
        }
    }

    /// Ids currently quarantined (scenario summary accounting).
    pub fn quarantined_ids(&self) -> Vec<AdapterId> {
        self.entries.iter().filter(|(_, e)| e.quarantined).map(|(&id, _)| id).collect()
    }

    /// Demote an adapter's factors to the disk tier, dropping the
    /// resident `Arc` (in-flight batches holding clones keep decoding).
    /// Returns the dropped handle, or `None` if absent/already tiered.
    pub fn demote(&mut self, id: AdapterId) -> Option<Arc<StoredAdapter>> {
        let e = self.entries.get_mut(&id)?;
        match std::mem::replace(&mut e.slot, AdapterSlot::Tiered) {
            AdapterSlot::Resident(a) => Some(a),
            AdapterSlot::Tiered => None,
        }
    }

    /// Remove an adapter (returns whether it existed).
    pub fn remove(&mut self, id: AdapterId) -> bool {
        self.entries.remove(&id).is_some()
    }

    pub fn get(&self, id: AdapterId) -> Option<&RegistryEntry> {
        self.entries.get(&id)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn ids(&self) -> Vec<AdapterId> {
        self.entries.keys().copied().collect()
    }

    /// Total at-rest bytes across all adapters (Fig. 6 y-axis),
    /// wherever they live.
    pub fn total_bytes(&self) -> usize {
        self.entries.values().map(|e| e.bytes).sum()
    }

    /// RAM-resident at-rest bytes only (excludes tiered adapters).
    pub fn resident_bytes(&self) -> usize {
        self.entries.values().filter(|e| !e.is_tiered()).map(|e| e.bytes).sum()
    }

    /// Mean avg-bits across adapters.
    pub fn mean_avg_bits(&self) -> f64 {
        if self.entries.is_empty() {
            return 0.0;
        }
        self.entries.values().map(|e| e.avg_bits).sum::<f64>() / self.entries.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loraquant::{quantize_site, LoraQuantConfig, QuantizedLora};
    use crate::testutil::Rng;

    fn quantized(rng: &mut Rng) -> StoredAdapter {
        let (b, a) = rng.lora_pair(64, 64, 8, 0.7);
        let mut q = QuantizedLora::default();
        q.sites
            .insert("l0.wq".into(), quantize_site(&b, &a, &LoraQuantConfig::default()).unwrap());
        StoredAdapter::Quantized(q)
    }

    #[test]
    fn register_get_remove() {
        let mut rng = Rng::new(141);
        let mut reg = AdapterRegistry::new();
        let id0 = reg.register(quantized(&mut rng), "modadd");
        let id1 = reg.register(quantized(&mut rng), "keyword");
        assert_ne!(id0, id1);
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.get(id0).unwrap().task, "modadd");
        assert!(reg.remove(id0));
        assert!(!reg.remove(id0));
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn quantized_is_smaller_at_rest() {
        let mut rng = Rng::new(142);
        let (b, a) = rng.lora_pair(64, 64, 8, 0.7);
        let fp = {
            let mut ad = LoraAdapter::default();
            ad.sites.insert("l0.wq".into(), (a.clone(), b.clone()));
            StoredAdapter::Fp16(ad)
        };
        let mut rng2 = Rng::new(142);
        let q = quantized(&mut rng2);
        assert!(q.bytes() * 4 < fp.bytes(), "quant {} vs fp16 {}", q.bytes(), fp.bytes());
        assert!(q.avg_bits() < 2.5);
        assert_eq!(fp.avg_bits(), 16.0);
    }

    #[test]
    fn demote_keeps_metadata_but_drops_residency() {
        let mut rng = Rng::new(145);
        let mut reg = AdapterRegistry::new();
        let a = quantized(&mut rng);
        let (bytes, bits) = (a.bytes(), a.avg_bits());
        let id = reg.register(a, "t");
        assert!(reg.get(id).unwrap().resident().is_some());
        assert_eq!(reg.resident_bytes(), bytes);

        let dropped = reg.demote(id).expect("first demotion returns the arc");
        assert_eq!(dropped.bytes(), bytes);
        let e = reg.get(id).unwrap();
        assert!(e.is_tiered() && e.resident().is_none());
        // accounting survives demotion; residency accounting does not
        assert_eq!((e.bytes(), reg.total_bytes()), (bytes, bytes));
        assert_eq!(e.avg_bits(), bits);
        assert_eq!(reg.resident_bytes(), 0);

        assert!(reg.demote(id).is_none(), "already tiered");
        assert!(reg.demote(999).is_none(), "unknown id");
    }

    #[test]
    fn quarantine_and_recover_toggle_without_losing_metadata() {
        let mut rng = Rng::new(146);
        let mut reg = AdapterRegistry::new();
        let a = quantized(&mut rng);
        let bytes = a.bytes();
        let id = reg.register(a, "t");
        assert!(!reg.get(id).unwrap().is_quarantined());
        assert!(reg.quarantine(id));
        assert!(!reg.quarantine(id), "second quarantine is a no-op");
        assert!(reg.get(id).unwrap().is_quarantined());
        assert_eq!(reg.quarantined_ids(), vec![id]);
        // metadata and residency accounting are untouched
        assert_eq!(reg.get(id).unwrap().bytes(), bytes);
        assert!(reg.get(id).unwrap().resident().is_some());
        assert!(reg.recover(id));
        assert!(!reg.recover(id), "second recover is a no-op");
        assert!(!reg.get(id).unwrap().is_quarantined());
        assert!(reg.quarantined_ids().is_empty());
        assert!(!reg.quarantine(999), "unknown id");
        assert!(!reg.recover(999), "unknown id");
    }

    #[test]
    fn total_bytes_accumulates() {
        let mut rng = Rng::new(143);
        let mut reg = AdapterRegistry::new();
        let a1 = quantized(&mut rng);
        let unit = a1.bytes();
        reg.register(a1, "t");
        let before = reg.total_bytes();
        assert_eq!(before, unit);
        let mut rng2 = Rng::new(144);
        reg.register(quantized(&mut rng2), "t");
        assert_eq!(reg.total_bytes(), before * 2);
    }
}
