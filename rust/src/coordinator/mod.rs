//! L3 coordinator: the multi-LoRA serving system around the quantization
//! pipeline (the deployment context of the paper's §1/App. D: one frozen
//! base model, many task-/user-specific adapters resident simultaneously).
//!
//! * [`registry`] — adapter store: LoRAQuant-compressed (or FP16) adapters
//!   at rest, with exact byte/bit accounting (the Fig. 6 memory axis).
//! * [`cache`] — byte-budgeted LRU of **merged, device-resident** weights:
//!   dequantize + merge happens once per adapter activation, then requests
//!   hit device buffers.
//! * [`batcher`] — adapter-grouped dynamic batching with a max-wait
//!   deadline (S-LoRA-style: a batch shares one merged weight set).
//! * [`server`] — thread-confined PJRT executor behind an mpsc request
//!   loop; callers hold a cloneable, `Send` handle.
//! * [`metrics`] — latency histogram + counters.

pub mod batcher;
pub mod cache;
pub mod metrics;
pub mod registry;
pub mod server;

pub use batcher::{Batch, BatcherConfig, DynamicBatcher, PendingRequest};
pub use cache::LruCache;
pub use metrics::{Histogram, ServerMetrics};
pub use registry::{AdapterId, AdapterRegistry, StoredAdapter};
pub use server::{Coordinator, CoordinatorConfig, GenRequest, GenResponse};
