//! L3 coordinator: the multi-LoRA serving system around the quantization
//! pipeline (the deployment context of the paper's §1/App. D: one frozen
//! base model, many task-/user-specific adapters resident simultaneously).
//!
//! * [`registry`] — adapter store: LoRAQuant-compressed (or FP16) adapters
//!   at rest, with exact byte/bit accounting (the Fig. 6 memory axis);
//!   shared across the pool behind the [`Coordinator`] handle.
//! * [`cache`] — byte-budgeted LRU of **merged, device-resident** weights,
//!   one per worker: dequantize + merge happens once per adapter
//!   activation, then requests hit device buffers. One of two execution
//!   strategies ([`MergeStrategy`]): the **factor** path instead serves
//!   adapters unmerged, applying packed factors on the activation path
//!   and skipping the merge queue entirely (DESIGN.md §8).
//! * [`batcher`] — dynamic batching with a max-wait deadline: grouped per
//!   adapter for merged serving (S-LoRA-style: a batch shares one merged
//!   weight set) or mixed across adapters for factor-form serving.
//! * [`pool`] — the executor pool: N thread-confined engines with
//!   rendezvous-hashed adapter affinity and multi-bucket decode.
//! * [`merge_worker`] — the off-hot-path merge pipeline: cache-miss
//!   dequant+merge runs on background threads while the batch parks;
//!   different adapters' misses merge in parallel.
//! * [`server`] — configuration plus the cloneable, `Send`
//!   [`Coordinator`] handle (generate / prefetch / register / metrics).
//! * [`metrics`] — latency histograms + counters, aggregated per worker,
//!   plus the Prometheus exposition registry builder (DESIGN.md §16).
//!
//! See rust/DESIGN.md §4 for the serving architecture.

pub mod batcher;
pub mod cache;
pub mod merge_worker;
pub mod metrics;
pub mod pool;
pub mod registry;
pub mod server;
pub mod tier;

pub use batcher::{Batch, BatcherConfig, DynamicBatcher, PendingRequest};
pub use cache::{CacheStats, LruCache};
pub use merge_worker::{MergeHook, MergeStatsSnapshot};
pub use metrics::{pool_registry, Histogram, LatencyStats, ServerMetrics};
pub use pool::{route, WorkerSnapshot};
pub use registry::{AdapterId, AdapterRegistry, AdapterSlot, StoredAdapter};
pub use server::{
    Coordinator, CoordinatorConfig, FailKind, GenRequest, GenResponse, MergeStrategy,
    RequestOptions, ServeError, TierConfig,
};
pub use tier::{AdapterTier, DiskErrorFault, DiskFault, LoadHook, TierEvent, TierEventHook};
