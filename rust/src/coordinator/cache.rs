//! Byte-budgeted LRU cache for merged, device-resident adapter weights.
//!
//! Dequantize + merge + upload costs milliseconds; under a Zipf-skewed
//! multi-tenant workload the hot adapters should pay it once. The budget
//! bounds device memory: when inserting would exceed it, the
//! least-recently-used entries are evicted (never the entry being
//! inserted, even if it alone exceeds the budget — a request must be able
//! to run).

use std::collections::{BTreeMap, HashMap};
use std::hash::Hash;

/// LRU statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// A byte-budgeted LRU keyed by `K`; values report their size via the
/// closure passed at construction.
pub struct LruCache<K, V> {
    budget_bytes: usize,
    used_bytes: usize,
    clock: u64,
    entries: HashMap<K, (V, usize, u64)>, // value, bytes, last-used
    /// Recency index: last-used clock → key, mirroring `entries`. The
    /// clock is bumped on every access, so keys are unique and the
    /// first entry is always the LRU — eviction pops from the front
    /// instead of scanning all entries per victim (O(log n) vs O(n²)
    /// for a mass eviction at 10k resident tenants).
    order: BTreeMap<u64, K>,
    stats: CacheStats,
}

impl<K: Eq + Hash + Clone, V> LruCache<K, V> {
    /// Create with a byte budget.
    pub fn new(budget_bytes: usize) -> Self {
        Self {
            budget_bytes,
            used_bytes: 0,
            clock: 0,
            entries: HashMap::new(),
            order: BTreeMap::new(),
            stats: CacheStats::default(),
        }
    }

    /// Look up, refreshing recency. Counts a hit/miss.
    pub fn get(&mut self, k: &K) -> Option<&V> {
        self.clock += 1;
        match self.entries.get_mut(k) {
            Some((v, _, used)) => {
                self.order.remove(used);
                *used = self.clock;
                self.order.insert(self.clock, k.clone());
                self.stats.hits += 1;
                Some(v)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Peek without touching recency or stats.
    pub fn peek(&self, k: &K) -> Option<&V> {
        self.entries.get(k).map(|(v, _, _)| v)
    }

    /// Refresh recency without counting a hit or miss (e.g. a prefetch
    /// of an already-resident entry must protect it from eviction
    /// without skewing request-path stats). Returns whether it exists.
    pub fn touch(&mut self, k: &K) -> bool {
        self.clock += 1;
        match self.entries.get_mut(k) {
            Some((_, _, used)) => {
                self.order.remove(used);
                *used = self.clock;
                self.order.insert(self.clock, k.clone());
                true
            }
            None => false,
        }
    }

    /// Insert, evicting LRU entries until within budget. The inserted
    /// entry itself is never evicted.
    pub fn insert(&mut self, k: K, v: V, bytes: usize) {
        self.clock += 1;
        if let Some((_, old_bytes, used)) = self.entries.remove(&k) {
            self.used_bytes -= old_bytes;
            self.order.remove(&used);
        }
        self.used_bytes += bytes;
        self.entries.insert(k.clone(), (v, bytes, self.clock));
        self.order.insert(self.clock, k.clone());
        while self.used_bytes > self.budget_bytes && self.entries.len() > 1 {
            // front of the recency index = LRU; skip k itself (it holds
            // the max clock, so this only matters when it is alone)
            let victim = self
                .order
                .iter()
                .map(|(&used, key)| (used, key.clone()))
                .find(|(_, key)| *key != k);
            match victim {
                Some((used, vk)) => {
                    self.order.remove(&used);
                    if let Some((_, b, _)) = self.entries.remove(&vk) {
                        self.used_bytes -= b;
                        self.stats.evictions += 1;
                    }
                }
                None => break,
            }
        }
    }

    /// Remove an entry explicitly (e.g. adapter unregistered).
    pub fn remove(&mut self, k: &K) -> Option<V> {
        self.entries.remove(k).map(|(v, b, used)| {
            self.used_bytes -= b;
            self.order.remove(&used);
            v
        })
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_miss_accounting() {
        let mut c: LruCache<u32, String> = LruCache::new(100);
        assert!(c.get(&1).is_none());
        c.insert(1, "a".into(), 10);
        assert_eq!(c.get(&1), Some(&"a".to_string()));
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn evicts_lru_when_over_budget() {
        let mut c: LruCache<u32, u32> = LruCache::new(30);
        c.insert(1, 10, 10);
        c.insert(2, 20, 10);
        c.insert(3, 30, 10);
        // touch 1 so 2 becomes LRU
        c.get(&1);
        c.insert(4, 40, 10);
        assert!(c.peek(&2).is_none(), "2 was LRU and must be evicted");
        assert!(c.peek(&1).is_some());
        assert_eq!(c.stats().evictions, 1);
        assert!(c.used_bytes() <= 30);
    }

    #[test]
    fn oversized_entry_survives() {
        let mut c: LruCache<u32, u32> = LruCache::new(5);
        c.insert(1, 1, 50);
        assert!(c.peek(&1).is_some(), "sole entry must never be evicted");
        c.insert(2, 2, 50);
        assert!(c.peek(&2).is_some());
        assert_eq!(c.len(), 1, "previous entry evicted to make room");
    }

    #[test]
    fn reinsert_updates_bytes() {
        let mut c: LruCache<u32, u32> = LruCache::new(100);
        c.insert(1, 1, 40);
        c.insert(1, 2, 10);
        assert_eq!(c.used_bytes(), 10);
        assert_eq!(c.peek(&1), Some(&2));
    }

    #[test]
    fn remove_releases_bytes() {
        let mut c: LruCache<u32, u32> = LruCache::new(100);
        c.insert(1, 1, 40);
        assert_eq!(c.remove(&1), Some(1));
        assert_eq!(c.used_bytes(), 0);
        assert_eq!(c.remove(&1), None);
    }

    #[test]
    fn eviction_follows_lru_order_across_multiple_victims() {
        // one oversized insert must evict in strict LRU order until the
        // budget fits: 2 (oldest untouched), then 3, sparing 1 (touched).
        let mut c: LruCache<u32, u32> = LruCache::new(30);
        c.insert(1, 10, 10);
        c.insert(2, 20, 10);
        c.insert(3, 30, 10);
        c.get(&1); // recency: 2 < 3 < 1
        c.insert(4, 40, 15); // 45 bytes resident: needs exactly two victims
        assert!(c.peek(&2).is_none(), "LRU entry 2 evicted first");
        assert!(c.peek(&3).is_none(), "still over budget: 3 evicted next");
        assert!(c.peek(&1).is_some(), "recently-touched entry survives");
        assert!(c.peek(&4).is_some(), "inserted entry is never a victim");
        assert_eq!(c.stats().evictions, 2);
        assert_eq!(c.used_bytes(), 25, "1(10) + 4(15)");
    }

    #[test]
    fn remove_then_reinsert_keeps_accounting_exact() {
        let mut c: LruCache<u32, u32> = LruCache::new(100);
        c.insert(1, 1, 40);
        c.insert(2, 2, 30);
        assert_eq!(c.used_bytes(), 70);
        assert_eq!(c.remove(&1), Some(1));
        assert_eq!(c.used_bytes(), 30);
        c.insert(1, 9, 25);
        assert_eq!(c.used_bytes(), 55);
        assert_eq!(c.len(), 2);
        assert_eq!(c.peek(&1), Some(&9));
        // removal must not have counted as an eviction or touched hit/miss
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (0, 0, 0));
    }

    #[test]
    fn touch_refreshes_recency_without_stats() {
        let mut c: LruCache<u32, u32> = LruCache::new(20);
        c.insert(1, 1, 10);
        c.insert(2, 2, 10);
        assert!(c.touch(&1), "1 is resident");
        assert!(!c.touch(&9), "9 is not");
        c.insert(3, 3, 10); // over budget: LRU is now 2, not 1
        assert!(c.peek(&1).is_some(), "touched entry survives eviction");
        assert!(c.peek(&2).is_none());
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (0, 0), "touch must not count");
    }

    /// The recency index must pin exact LRU order at scale: insert 400
    /// one-byte entries, refresh a scattered subset, then squeeze the
    /// budget with oversized inserts — victims must leave in precisely
    /// ascending last-used order, refreshed entries last.
    #[test]
    fn mass_eviction_preserves_exact_lru_order_at_hundreds_of_entries() {
        const N: u64 = 400;
        let mut c: LruCache<u64, u64> = LruCache::new(N as usize);
        for k in 0..N {
            c.insert(k, k, 1);
        }
        // refresh every 7th key; recency is now: non-multiples of 7 in
        // insertion order, then multiples of 7 in ascending order
        let mut expected: Vec<u64> = (0..N).filter(|k| k % 7 != 0).collect();
        expected.extend((0..N).filter(|k| k % 7 == 0));
        for &k in expected.iter().filter(|k| **k % 7 == 0) {
            assert!(c.touch(&k));
        }
        // one oversized insert forces a 300-victim mass eviction
        c.insert(N, N, 300);
        assert_eq!(c.stats().evictions, 300);
        assert_eq!(c.len(), (N as usize - 300) + 1);
        let (gone, kept) = expected.split_at(300);
        for k in gone {
            assert!(c.peek(k).is_none(), "{k} should have been evicted");
        }
        for k in kept {
            assert!(c.peek(k).is_some(), "{k} should have survived");
        }
        assert!(c.peek(&N).is_some(), "inserted entry is never a victim");
        assert_eq!(c.used_bytes(), N as usize);
    }

    #[test]
    fn stats_match_scripted_access_sequence() {
        let mut c: LruCache<u32, &'static str> = LruCache::new(100);
        assert!(c.get(&1).is_none()); // miss
        c.insert(1, "a", 10);
        assert!(c.get(&1).is_some()); // hit
        assert!(c.get(&2).is_none()); // miss
        c.insert(2, "b", 10);
        assert!(c.get(&2).is_some()); // hit
        assert!(c.get(&1).is_some()); // hit
        c.peek(&3); // peek never counts
        assert!(c.get(&3).is_none()); // miss
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (3, 3, 0));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }
}
