//! The on-disk adapter tier: packed factors at rest, one tensorfile per
//! adapter, loaded by explicit read-on-miss (no libc / mmap dependency).
//!
//! The paper's ultra-low-bit factors are exactly small enough to page in
//! on demand: a 2@0.9 adapter is a few KB, so the registry can hold
//! metadata for millions of tenants while only the working set's factors
//! occupy RAM (the per-worker factor cache, `coordinator/pool.rs`) and
//! only the hot subset's merged weights occupy the device LruCache above
//! it. All loads run on merge-pool threads — never on an executor worker
//! — so a scripted disk-latency fault can park on the virtual clock
//! without deadlocking the scenario driver's metrics barrier (the same
//! contract as `SlowMerge`; DESIGN.md §14).

use super::registry::{AdapterId, StoredAdapter};
use crate::adapter::store;
use crate::clock::Clock;
use anyhow::Context;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Observer called with the adapter id at the start of every disk load,
/// on the loading (merge-pool) thread — the scenario harness records
/// `DiskLoad` events through it, mirroring `MergeHook`.
#[derive(Clone)]
pub struct LoadHook(Arc<dyn Fn(AdapterId) + Send + Sync>);

impl LoadHook {
    pub fn new(f: impl Fn(AdapterId) + Send + Sync + 'static) -> Self {
        Self(Arc::new(f))
    }

    pub fn call(&self, id: AdapterId) {
        (self.0)(id)
    }
}

impl std::fmt::Debug for LoadHook {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("LoadHook(..)")
    }
}

/// Scripted disk-read latency (`FaultPlan::disk_latency`): every load of
/// a matching adapter parks on the clock for `delay` before reading.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DiskFault {
    /// Restrict to one adapter; `None` hits every load.
    pub adapter: Option<AdapterId>,
    pub delay: Duration,
}

/// The disk tier. Thread-safe: loads may run concurrently on several
/// merge-pool threads.
pub struct AdapterTier {
    dir: PathBuf,
    clock: Clock,
    fault: Option<DiskFault>,
    hook: Option<LoadHook>,
    disk_loads: AtomicU64,
    spilled: AtomicU64,
}

impl std::fmt::Debug for AdapterTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AdapterTier")
            .field("dir", &self.dir)
            .field("fault", &self.fault)
            .field("disk_loads", &self.disk_loads)
            .finish_non_exhaustive()
    }
}

impl AdapterTier {
    /// Open (creating if needed) a tier rooted at `dir`.
    pub fn new(
        dir: impl Into<PathBuf>,
        clock: Clock,
        fault: Option<DiskFault>,
        hook: Option<LoadHook>,
    ) -> anyhow::Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating adapter tier dir {}", dir.display()))?;
        Ok(Self {
            dir,
            clock,
            fault,
            hook,
            disk_loads: AtomicU64::new(0),
            spilled: AtomicU64::new(0),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn path(&self, id: AdapterId) -> PathBuf {
        self.dir.join(format!("adapter-{id:08}.lq.bin"))
    }

    /// Spill an adapter's packed factors to disk. Returns `true` when it
    /// was written (and may therefore be demoted). FP16 adapters have no
    /// at-rest codec (`LoraAdapter` is load-only) and stay RAM-resident:
    /// `false` without touching disk.
    pub fn put(&self, id: AdapterId, adapter: &StoredAdapter) -> anyhow::Result<bool> {
        match adapter {
            StoredAdapter::Quantized(q) => {
                store::save(self.path(id), q)
                    .with_context(|| format!("spilling adapter {id} to tier"))?;
                self.spilled.fetch_add(1, Ordering::SeqCst);
                Ok(true)
            }
            StoredAdapter::Fp16(_) => Ok(false),
        }
    }

    /// Read an adapter back from disk. Must only be called from a
    /// merge-pool thread: a scripted disk fault parks here on the clock,
    /// and executor workers sleeping on the virtual clock would deadlock
    /// the quiescence barrier.
    pub fn load(&self, id: AdapterId) -> anyhow::Result<Arc<StoredAdapter>> {
        if let Some(h) = &self.hook {
            h.call(id);
        }
        if let Some(f) = &self.fault {
            if f.adapter.is_none_or(|a| a == id) {
                let now = self.clock.now();
                self.clock.sleep_until(now + f.delay);
            }
        }
        let q = store::load(self.path(id))
            .with_context(|| format!("loading adapter {id} from tier"))?;
        self.disk_loads.fetch_add(1, Ordering::SeqCst);
        Ok(Arc::new(StoredAdapter::Quantized(q)))
    }

    /// Best-effort removal of a spilled file (adapter unregistered).
    pub fn remove(&self, id: AdapterId) {
        let _ = std::fs::remove_file(self.path(id));
    }

    /// Completed disk loads since construction.
    pub fn disk_loads(&self) -> u64 {
        self.disk_loads.load(Ordering::SeqCst)
    }

    /// Adapters spilled since construction.
    pub fn spilled(&self) -> u64 {
        self.spilled.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::LoraAdapter;
    use crate::testutil::{synth_model_config, synth_quantized_adapter, Rng};

    fn tmp_tier(tag: &str) -> AdapterTier {
        let dir = std::env::temp_dir().join(format!("lq_tier_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        AdapterTier::new(dir, Clock::real(), None, None).unwrap()
    }

    #[test]
    fn put_load_remove_roundtrip() {
        let tier = tmp_tier("rt");
        let cfg = synth_model_config();
        let adapter = synth_quantized_adapter(&cfg, 7);
        assert!(tier.put(3, &adapter).unwrap());
        let back = tier.load(3).unwrap();
        assert_eq!(tier.disk_loads(), 1);
        assert_eq!(back.bytes(), adapter.bytes());
        // dequantized deltas are bitwise-stable through the codec
        let (d0, d1) = (adapter.deltas(), back.deltas());
        assert_eq!(d0.len(), d1.len());
        for (site, m) in &d0 {
            assert!(m.sub(&d1[site]).fro_norm() == 0.0, "{site} drifted through disk");
        }
        tier.remove(3);
        assert!(tier.load(3).is_err(), "removed file must not load");
        let _ = std::fs::remove_dir_all(tier.dir());
    }

    #[test]
    fn fp16_adapters_stay_resident() {
        let tier = tmp_tier("fp");
        let mut rng = Rng::new(9);
        let (b, a) = rng.lora_pair(16, 16, 4, 0.7);
        let mut fp = LoraAdapter::default();
        fp.sites.insert("l0.wq".into(), (a, b));
        assert!(!tier.put(1, &StoredAdapter::Fp16(fp)).unwrap());
        assert!(tier.load(1).is_err(), "nothing was spilled");
        assert_eq!(tier.spilled(), 0);
        let _ = std::fs::remove_dir_all(tier.dir());
    }

    #[test]
    fn missing_file_is_err_not_panic() {
        let tier = tmp_tier("miss");
        let err = tier.load(42).unwrap_err().to_string();
        assert!(err.contains("adapter 42"), "{err}");
        let _ = std::fs::remove_dir_all(tier.dir());
    }
}
