//! The on-disk adapter tier: packed factors at rest, one tensorfile per
//! adapter, loaded by explicit read-on-miss (no libc / mmap dependency).
//!
//! The paper's ultra-low-bit factors are exactly small enough to page in
//! on demand: a 2@0.9 adapter is a few KB, so the registry can hold
//! metadata for millions of tenants while only the working set's factors
//! occupy RAM (the per-worker factor cache, `coordinator/pool.rs`) and
//! only the hot subset's merged weights occupy the device LruCache above
//! it. All loads run on merge-pool threads — never on an executor worker
//! — so a scripted disk-latency fault can park on the virtual clock
//! without deadlocking the scenario driver's metrics barrier (the same
//! contract as `SlowMerge`; DESIGN.md §14).

use super::registry::{AdapterId, StoredAdapter};
use crate::adapter::store;
use crate::clock::Clock;
use anyhow::{bail, Context};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Observer called with the adapter id at the start of every disk load,
/// on the loading (merge-pool) thread — the scenario harness records
/// `DiskLoad` events through it, mirroring `MergeHook`.
#[derive(Clone)]
pub struct LoadHook(Arc<dyn Fn(AdapterId) + Send + Sync>);

impl LoadHook {
    pub fn new(f: impl Fn(AdapterId) + Send + Sync + 'static) -> Self {
        Self(Arc::new(f))
    }

    pub fn call(&self, id: AdapterId) {
        (self.0)(id)
    }
}

impl std::fmt::Debug for LoadHook {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("LoadHook(..)")
    }
}

/// Scripted disk-read latency (`FaultPlan::disk_latency`): every load of
/// a matching adapter parks on the clock for `delay` before reading.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DiskFault {
    /// Restrict to one adapter; `None` hits every load.
    pub adapter: Option<AdapterId>,
    pub delay: Duration,
}

/// Scripted disk-read **errors** (`FaultPlan::disk_error`): the first
/// `first_n` load attempts of a matching adapter fail with an injected
/// I/O error, counted per adapter, deterministically. Interplay with the
/// retry policy: `first_n <= max_retries` means the load eventually
/// succeeds with `first_n` visible retries; `first_n > max_retries`
/// means a permanent failure the caller quarantines (DESIGN.md §15).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DiskErrorFault {
    /// Restrict to one adapter; `None` hits every load.
    pub adapter: Option<AdapterId>,
    /// How many leading attempts fail per adapter.
    pub first_n: u32,
}

/// Structured tier fault telemetry, fired on the loading (merge-pool)
/// thread — the scenario harness records `DiskError` / `Quarantine`
/// events through it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TierEvent {
    /// One load attempt failed (`attempt` is 0-based).
    LoadError { adapter: AdapterId, attempt: u32 },
    /// The adapter was quarantined after a permanent load failure.
    Quarantined { adapter: AdapterId },
}

/// Observer for [`TierEvent`]s, mirroring [`LoadHook`].
#[derive(Clone)]
pub struct TierEventHook(Arc<dyn Fn(&TierEvent) + Send + Sync>);

impl TierEventHook {
    pub fn new(f: impl Fn(&TierEvent) + Send + Sync + 'static) -> Self {
        Self(Arc::new(f))
    }

    pub fn call(&self, ev: &TierEvent) {
        (self.0)(ev)
    }
}

impl std::fmt::Debug for TierEventHook {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("TierEventHook(..)")
    }
}

/// The disk tier. Thread-safe: loads may run concurrently on several
/// merge-pool threads.
pub struct AdapterTier {
    dir: PathBuf,
    clock: Clock,
    fault: Option<DiskFault>,
    hook: Option<LoadHook>,
    disk_loads: AtomicU64,
    spilled: AtomicU64,
    /// Failed attempts retried (not counting the final give-up).
    disk_retries: AtomicU64,
    /// Extra attempts after a failed load before giving up (0 = none).
    max_retries: u32,
    /// Base delay before the first retry; doubles per attempt, parked on
    /// the (virtual) clock so backoff is deterministic under a driver.
    backoff: Duration,
    error_fault: Option<DiskErrorFault>,
    /// Per-adapter injected-failure counters for `error_fault`.
    error_counts: Mutex<BTreeMap<AdapterId, u32>>,
    events: Option<TierEventHook>,
}

impl std::fmt::Debug for AdapterTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AdapterTier")
            .field("dir", &self.dir)
            .field("fault", &self.fault)
            .field("disk_loads", &self.disk_loads)
            .finish_non_exhaustive()
    }
}

impl AdapterTier {
    /// Open (creating if needed) a tier rooted at `dir`.
    pub fn new(
        dir: impl Into<PathBuf>,
        clock: Clock,
        fault: Option<DiskFault>,
        hook: Option<LoadHook>,
    ) -> anyhow::Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating adapter tier dir {}", dir.display()))?;
        Ok(Self {
            dir,
            clock,
            fault,
            hook,
            disk_loads: AtomicU64::new(0),
            spilled: AtomicU64::new(0),
            disk_retries: AtomicU64::new(0),
            max_retries: 0,
            backoff: Duration::ZERO,
            error_fault: None,
            error_counts: Mutex::new(BTreeMap::new()),
            events: None,
        })
    }

    /// Retry policy for failed loads: up to `max_retries` extra attempts
    /// with exponential backoff starting at `backoff` (doubling per
    /// attempt, slept on the tier's clock).
    pub fn with_retry(mut self, max_retries: u32, backoff: Duration) -> Self {
        self.max_retries = max_retries;
        self.backoff = backoff;
        self
    }

    /// Scripted disk-error injection (see [`DiskErrorFault`]).
    pub fn with_disk_errors(mut self, fault: Option<DiskErrorFault>) -> Self {
        self.error_fault = fault;
        self
    }

    /// Structured fault telemetry (see [`TierEventHook`]).
    pub fn with_events(mut self, hook: Option<TierEventHook>) -> Self {
        self.events = hook;
        self
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn emit(&self, ev: TierEvent) {
        if let Some(h) = &self.events {
            h.call(&ev);
        }
    }

    /// Record (and publish) that the caller quarantined `id` after a
    /// permanent load failure — the tier owns the event hook, the
    /// registry owns the flag.
    pub fn note_quarantined(&self, id: AdapterId) {
        self.emit(TierEvent::Quarantined { adapter: id });
    }

    fn path(&self, id: AdapterId) -> PathBuf {
        self.dir.join(format!("adapter-{id:08}.lq.bin"))
    }

    /// Spill an adapter's packed factors to disk. Returns `true` when it
    /// was written (and may therefore be demoted). FP16 adapters have no
    /// at-rest codec (`LoraAdapter` is load-only) and stay RAM-resident:
    /// `false` without touching disk.
    pub fn put(&self, id: AdapterId, adapter: &StoredAdapter) -> anyhow::Result<bool> {
        match adapter {
            StoredAdapter::Quantized(q) => {
                store::save(self.path(id), q)
                    .with_context(|| format!("spilling adapter {id} to tier"))?;
                self.spilled.fetch_add(1, Ordering::SeqCst);
                Ok(true)
            }
            StoredAdapter::Fp16(_) => Ok(false),
        }
    }

    /// Read an adapter back from disk, retrying failed attempts under
    /// the tier's backoff policy. Must only be called from a merge-pool
    /// thread: scripted disk faults and retry backoff park here on the
    /// clock, and executor workers sleeping on the virtual clock would
    /// deadlock the quiescence barrier. An `Err` is **permanent** — the
    /// policy is already exhausted — so callers quarantine on it.
    pub fn load(&self, id: AdapterId) -> anyhow::Result<Arc<StoredAdapter>> {
        let mut attempt: u32 = 0;
        loop {
            match self.load_once(id) {
                Ok(a) => return Ok(a),
                Err(e) => {
                    self.emit(TierEvent::LoadError { adapter: id, attempt });
                    if attempt >= self.max_retries {
                        return Err(e.context(format!(
                            "adapter {id}: tier load failed permanently after {} attempt(s)",
                            attempt + 1
                        )));
                    }
                    self.disk_retries.fetch_add(1, Ordering::SeqCst);
                    let delay = self.backoff.saturating_mul(1u32 << attempt.min(16));
                    if !delay.is_zero() {
                        let now = self.clock.now();
                        self.clock.sleep_until(now + delay);
                    }
                    attempt += 1;
                }
            }
        }
    }

    /// One load attempt: observer hook, scripted latency, scripted
    /// error, then the real read.
    fn load_once(&self, id: AdapterId) -> anyhow::Result<Arc<StoredAdapter>> {
        if let Some(h) = &self.hook {
            h.call(id);
        }
        if let Some(f) = &self.fault {
            if f.adapter.is_none_or(|a| a == id) {
                let now = self.clock.now();
                self.clock.sleep_until(now + f.delay);
            }
        }
        if let Some(ef) = &self.error_fault {
            if ef.adapter.is_none_or(|a| a == id) {
                let mut counts = self.error_counts.lock().unwrap_or_else(|e| e.into_inner());
                let n = counts.entry(id).or_insert(0);
                if *n < ef.first_n {
                    *n += 1;
                    let k = *n;
                    bail!("injected disk error on adapter {id} (failure {k} of {})", ef.first_n);
                }
            }
        }
        let q = store::load(self.path(id))
            .with_context(|| format!("loading adapter {id} from tier"))?;
        self.disk_loads.fetch_add(1, Ordering::SeqCst);
        Ok(Arc::new(StoredAdapter::Quantized(q)))
    }

    /// Best-effort removal of a spilled file (adapter unregistered).
    pub fn remove(&self, id: AdapterId) {
        let _ = std::fs::remove_file(self.path(id));
    }

    /// Completed disk loads since construction.
    pub fn disk_loads(&self) -> u64 {
        self.disk_loads.load(Ordering::SeqCst)
    }

    /// Adapters spilled since construction.
    pub fn spilled(&self) -> u64 {
        self.spilled.load(Ordering::SeqCst)
    }

    /// Failed load attempts that were retried (permanent give-ups not
    /// included — those surface as `Err` from [`AdapterTier::load`]).
    pub fn disk_retries(&self) -> u64 {
        self.disk_retries.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::LoraAdapter;
    use crate::testutil::{synth_model_config, synth_quantized_adapter, Rng};

    fn tmp_tier(tag: &str) -> AdapterTier {
        let dir = std::env::temp_dir().join(format!("lq_tier_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        AdapterTier::new(dir, Clock::real(), None, None).unwrap()
    }

    #[test]
    fn put_load_remove_roundtrip() {
        let tier = tmp_tier("rt");
        let cfg = synth_model_config();
        let adapter = synth_quantized_adapter(&cfg, 7);
        assert!(tier.put(3, &adapter).unwrap());
        let back = tier.load(3).unwrap();
        assert_eq!(tier.disk_loads(), 1);
        assert_eq!(back.bytes(), adapter.bytes());
        // dequantized deltas are bitwise-stable through the codec
        let (d0, d1) = (adapter.deltas(), back.deltas());
        assert_eq!(d0.len(), d1.len());
        for (site, m) in &d0 {
            assert!(m.sub(&d1[site]).fro_norm() == 0.0, "{site} drifted through disk");
        }
        tier.remove(3);
        assert!(tier.load(3).is_err(), "removed file must not load");
        let _ = std::fs::remove_dir_all(tier.dir());
    }

    #[test]
    fn fp16_adapters_stay_resident() {
        let tier = tmp_tier("fp");
        let mut rng = Rng::new(9);
        let (b, a) = rng.lora_pair(16, 16, 4, 0.7);
        let mut fp = LoraAdapter::default();
        fp.sites.insert("l0.wq".into(), (a, b));
        assert!(!tier.put(1, &StoredAdapter::Fp16(fp)).unwrap());
        assert!(tier.load(1).is_err(), "nothing was spilled");
        assert_eq!(tier.spilled(), 0);
        let _ = std::fs::remove_dir_all(tier.dir());
    }

    #[test]
    fn missing_file_is_err_not_panic() {
        let tier = tmp_tier("miss");
        let err = tier.load(42).unwrap_err().to_string();
        assert!(err.contains("adapter 42"), "{err}");
        let _ = std::fs::remove_dir_all(tier.dir());
    }

    #[test]
    fn transient_disk_errors_are_retried_to_success() {
        // 2 injected failures, 3 retries allowed: the load must succeed
        // with exactly 2 retries on the counter and the events visible
        let events = Arc::new(Mutex::new(Vec::new()));
        let ev2 = Arc::clone(&events);
        let tier = tmp_tier("retry_ok")
            .with_retry(3, Duration::ZERO)
            .with_disk_errors(Some(DiskErrorFault { adapter: Some(5), first_n: 2 }))
            .with_events(Some(TierEventHook::new(move |ev| ev2.lock().unwrap().push(*ev))));
        let cfg = synth_model_config();
        let adapter = synth_quantized_adapter(&cfg, 11);
        tier.put(5, &adapter).unwrap();
        let back = tier.load(5).expect("first_n <= max_retries must succeed");
        assert_eq!(back.bytes(), adapter.bytes());
        assert_eq!(tier.disk_retries(), 2);
        assert_eq!(tier.disk_loads(), 1);
        assert_eq!(
            *events.lock().unwrap(),
            vec![
                TierEvent::LoadError { adapter: 5, attempt: 0 },
                TierEvent::LoadError { adapter: 5, attempt: 1 },
            ]
        );
        // the per-adapter failure budget is spent: later loads are clean
        tier.load(5).unwrap();
        assert_eq!(tier.disk_retries(), 2);
        let _ = std::fs::remove_dir_all(tier.dir());
    }

    #[test]
    fn exhausted_retries_fail_permanently_and_spare_other_adapters() {
        let tier = tmp_tier("retry_perm")
            .with_retry(1, Duration::ZERO)
            .with_disk_errors(Some(DiskErrorFault { adapter: Some(5), first_n: 9 }));
        let cfg = synth_model_config();
        tier.put(5, &synth_quantized_adapter(&cfg, 12)).unwrap();
        tier.put(6, &synth_quantized_adapter(&cfg, 13)).unwrap();
        let err = tier.load(5).unwrap_err().to_string();
        assert!(err.contains("permanently after 2 attempt(s)"), "{err}");
        assert_eq!(tier.disk_retries(), 1);
        tier.load(6).expect("fault targets adapter 5 only");
        let _ = std::fs::remove_dir_all(tier.dir());
    }

    #[test]
    fn retry_backoff_parks_on_the_virtual_clock() {
        use crate::clock::VirtualClock;
        let vc = VirtualClock::new();
        let dir = std::env::temp_dir().join(format!("lq_tier_vbk_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let tier = Arc::new(
            AdapterTier::new(dir, Clock::virtual_from(&vc), None, None)
                .unwrap()
                .with_retry(2, Duration::from_millis(10))
                .with_disk_errors(Some(DiskErrorFault { adapter: None, first_n: 2 })),
        );
        let cfg = synth_model_config();
        tier.put(1, &synth_quantized_adapter(&cfg, 14)).unwrap();
        let t2 = Arc::clone(&tier);
        let j = std::thread::spawn(move || t2.load(1).map(|a| a.bytes()));
        // drive the backoff sleeps: 10ms after attempt 0, 20ms after
        // attempt 1 — advance in steps until both sleepers release
        let t0 = std::time::Instant::now();
        while !j.is_finished() {
            vc.advance(Duration::from_millis(5));
            assert!(t0.elapsed() < Duration::from_secs(10), "load never finished");
            std::thread::sleep(Duration::from_micros(200));
        }
        j.join().unwrap().expect("retries succeed after backoff");
        assert_eq!(tier.disk_retries(), 2);
        let _ = std::fs::remove_dir_all(tier.dir());
    }
}
