//! Adapter model + on-disk formats.
//!
//! * [`fmt`] — the `tensorfile` container (mirrors python/compile/tensorfile.py).
//! * [`lora`] — an FP LoRA adapter: per-site `(A, B)` factor pairs.
//! * [`store`] — serialization of quantized adapters (the registry's
//!   at-rest format).

pub mod fmt;
pub mod lora;
pub mod store;

pub use fmt::{load_tensorfile, save_tensorfile, Tensor, TensorData};
pub use lora::LoraAdapter;
