//! Full-precision LoRA adapter: per-site `(A r×n, B m×r)` factor pairs,
//! as exported by python/compile/train.py (`<task>.lora.bin`).

use super::fmt::load_tensorfile;
use crate::tensor::Matrix;
use anyhow::{bail, Context};
use std::collections::BTreeMap;
use std::path::Path;

/// One trained LoRA adapter (all sites of one model, one task).
#[derive(Debug, Clone, Default)]
pub struct LoraAdapter {
    /// site name (e.g. `l0.wq`) → (A r×n, B m×r), paper orientation.
    pub sites: BTreeMap<String, (Matrix, Matrix)>,
}

impl LoraAdapter {
    /// Load from a `tensorfile` with `<site>.A` / `<site>.B` entries.
    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let tensors = load_tensorfile(&path)?;
        let mut sites: BTreeMap<String, (Option<Matrix>, Option<Matrix>)> = BTreeMap::new();
        for (name, t) in &tensors {
            let (site, kind) = match name.rsplit_once('.') {
                Some((s, k)) if k == "A" || k == "B" => (s.to_string(), k),
                _ => bail!("unexpected tensor name {name}"),
            };
            let m = t.to_matrix().with_context(|| name.clone())?;
            let entry = sites.entry(site).or_default();
            if kind == "A" {
                entry.0 = Some(m);
            } else {
                entry.1 = Some(m);
            }
        }
        let mut out = BTreeMap::new();
        for (site, (a, b)) in sites {
            let (a, b) = match (a, b) {
                (Some(a), Some(b)) => (a, b),
                _ => bail!("site {site} missing A or B"),
            };
            if a.rows() != b.cols() {
                bail!("site {site}: rank mismatch A {:?} B {:?}", a.shape(), b.shape());
            }
            out.insert(site, (a, b));
        }
        Ok(Self { sites: out })
    }

    /// LoRA rank (assumes uniform across sites, as trained).
    pub fn rank(&self) -> usize {
        self.sites.values().next().map(|(a, _)| a.rows()).unwrap_or(0)
    }

    /// Total parameter count Σ r(m+n).
    pub fn param_count(&self) -> usize {
        self.sites.values().map(|(a, b)| a.len() + b.len()).sum()
    }

    /// FP16 storage bytes (the paper's baseline memory: 2 bytes/param).
    pub fn fp16_bytes(&self) -> usize {
        self.param_count() * 2
    }

    /// Per-site delta `ΔW = B A` (m×n).
    pub fn delta(&self, site: &str) -> Option<Matrix> {
        self.sites.get(site).map(|(a, b)| crate::tensor::matmul(b, a))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::fmt::{save_tensorfile, Tensor};
    use std::collections::BTreeMap;

    fn write_adapter(path: &std::path::Path) {
        let mut t = BTreeMap::new();
        t.insert("l0.wq.A".into(), Tensor::f32(vec![2, 4], vec![0.1; 8]));
        t.insert("l0.wq.B".into(), Tensor::f32(vec![3, 2], vec![0.2; 6]));
        save_tensorfile(path, &t).unwrap();
    }

    #[test]
    fn load_and_shapes() {
        let tmp = std::env::temp_dir().join("lq_lora_test.bin");
        write_adapter(&tmp);
        let ad = LoraAdapter::load(&tmp).unwrap();
        assert_eq!(ad.rank(), 2);
        assert_eq!(ad.param_count(), 14);
        assert_eq!(ad.fp16_bytes(), 28);
        let d = ad.delta("l0.wq").unwrap();
        assert_eq!(d.shape(), (3, 4));
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn missing_factor_rejected() {
        let tmp = std::env::temp_dir().join("lq_lora_bad.bin");
        let mut t = BTreeMap::new();
        t.insert("l0.wq.A".into(), Tensor::f32(vec![2, 4], vec![0.1; 8]));
        save_tensorfile(&tmp, &t).unwrap();
        assert!(LoraAdapter::load(&tmp).is_err());
        std::fs::remove_file(tmp).ok();
    }
}
