//! At-rest serialization of quantized adapters.
//!
//! The registry stores LoRAQuant-compressed adapters in the same
//! `tensorfile` container used for FP weights, with a per-site layout:
//!
//! ```text
//! <site>.meta        i32[10]  m n r h bits_high group axis_b axis_a low_mode flags
//! <site>.bh.packed   u8       <site>.bh.scale f32   <site>.bh.zero f32
//! <site>.ah.*        (same)
//! <site>.bl.packed   u8       <site>.bl.scale f32  [<site>.bl.zero f32]
//! <site>.al.*        (same)
//! ```
//!
//! axis: 0 = row, 1 = col. low_mode: 0 = none/pruned, 1 = bin, 2 = rtn1.

use super::fmt::{load_tensorfile, save_tensorfile, Tensor};
use crate::loraquant::{LowQuantized, QuantizedLora, QuantizedSite};
use crate::quant::{Axis, BinQuantized, QuantAxis, RtnQuantized};
use anyhow::{bail, Context};
use std::collections::BTreeMap;
use std::path::Path;

fn axis_code(a: Axis) -> i32 {
    match a {
        Axis::Row => 0,
        Axis::Col => 1,
    }
}

fn axis_from(c: i32) -> anyhow::Result<Axis> {
    match c {
        0 => Ok(Axis::Row),
        1 => Ok(Axis::Col),
        _ => bail!("bad axis code {c}"),
    }
}

/// Encode one quantized adapter into tensorfile entries.
pub fn encode(lora: &QuantizedLora) -> BTreeMap<String, Tensor> {
    let mut out = BTreeMap::new();
    for (site, q) in &lora.sites {
        let low_mode = match (&q.bl, &q.al) {
            (None, None) => 0,
            (Some(LowQuantized::Bin(_)), _) => 1,
            (Some(LowQuantized::Rtn1(_)), _) => 2,
            _ => 0,
        };
        let meta = vec![
            q.m as i32,
            q.n as i32,
            q.r as i32,
            q.h as i32,
            q.bh.as_ref().map(|x| x.bits as i32).unwrap_or(0),
            q.bh
                .as_ref()
                .map(|x| x.group as i32)
                .or_else(|| low_group(q).map(|g| g as i32))
                .unwrap_or(0),
            axis_code(q.axis.b_axis),
            axis_code(q.axis.a_axis),
            low_mode,
            0,
        ];
        out.insert(format!("{site}.meta"), Tensor::i32(vec![10], meta));
        if let Some(x) = &q.bh {
            put_rtn(&mut out, site, "bh", x);
        }
        if let Some(x) = &q.ah {
            put_rtn(&mut out, site, "ah", x);
        }
        if let Some(x) = &q.bl {
            put_low(&mut out, site, "bl", x);
        }
        if let Some(x) = &q.al {
            put_low(&mut out, site, "al", x);
        }
    }
    out
}

fn low_group(q: &QuantizedSite) -> Option<usize> {
    match &q.bl {
        Some(LowQuantized::Bin(b)) => Some(b.group),
        Some(LowQuantized::Rtn1(r)) => Some(r.group),
        None => None,
    }
}

fn put_rtn(out: &mut BTreeMap<String, Tensor>, site: &str, part: &str, q: &RtnQuantized) {
    out.insert(
        format!("{site}.{part}.shape"),
        Tensor::i32(vec![4], vec![q.rows as i32, q.cols as i32, q.bits as i32, q.group as i32]),
    );
    out.insert(format!("{site}.{part}.packed"), Tensor::u8(vec![q.packed.len()], q.packed.clone()));
    out.insert(format!("{site}.{part}.scale"), Tensor::f32(vec![q.scale.len()], q.scale.clone()));
    out.insert(format!("{site}.{part}.zero"), Tensor::f32(vec![q.zero.len()], q.zero.clone()));
}

fn put_low(out: &mut BTreeMap<String, Tensor>, site: &str, part: &str, q: &LowQuantized) {
    match q {
        LowQuantized::Bin(b) => {
            out.insert(
                format!("{site}.{part}.shape"),
                Tensor::i32(vec![4], vec![b.rows as i32, b.cols as i32, 1, b.group as i32]),
            );
            out.insert(format!("{site}.{part}.packed"), Tensor::u8(vec![b.packed.len()], b.packed.clone()));
            out.insert(format!("{site}.{part}.scale"), Tensor::f32(vec![b.scale.len()], b.scale.clone()));
        }
        LowQuantized::Rtn1(r) => put_rtn(out, site, part, r),
    }
}

fn get_rtn(t: &BTreeMap<String, Tensor>, site: &str, part: &str) -> anyhow::Result<RtnQuantized> {
    let shape = t
        .get(&format!("{site}.{part}.shape"))
        .with_context(|| format!("{site}.{part}.shape missing"))?
        .as_i32()?;
    Ok(RtnQuantized {
        rows: shape[0] as usize,
        cols: shape[1] as usize,
        bits: shape[2] as u32,
        group: shape[3] as usize,
        packed: t[&format!("{site}.{part}.packed")].as_u8()?.to_vec(),
        scale: t[&format!("{site}.{part}.scale")].as_f32()?.to_vec(),
        zero: t[&format!("{site}.{part}.zero")].as_f32()?.to_vec(),
    })
}

fn get_bin(t: &BTreeMap<String, Tensor>, site: &str, part: &str) -> anyhow::Result<BinQuantized> {
    let shape = t
        .get(&format!("{site}.{part}.shape"))
        .with_context(|| format!("{site}.{part}.shape missing"))?
        .as_i32()?;
    Ok(BinQuantized {
        rows: shape[0] as usize,
        cols: shape[1] as usize,
        group: shape[3] as usize,
        packed: t[&format!("{site}.{part}.packed")].as_u8()?.to_vec(),
        scale: t[&format!("{site}.{part}.scale")].as_f32()?.to_vec(),
    })
}

/// Decode tensorfile entries back into a quantized adapter.
pub fn decode(tensors: &BTreeMap<String, Tensor>) -> anyhow::Result<QuantizedLora> {
    let mut lora = QuantizedLora::default();
    for (name, t) in tensors {
        let Some(site) = name.strip_suffix(".meta") else { continue };
        let meta = t.as_i32()?;
        if meta.len() != 10 {
            bail!("{name}: bad meta length {}", meta.len());
        }
        let (m, n, r, h) = (meta[0] as usize, meta[1] as usize, meta[2] as usize, meta[3] as usize);
        let axis = QuantAxis { b_axis: axis_from(meta[6])?, a_axis: axis_from(meta[7])? };
        let (bh, ah) = if h > 0 {
            (Some(get_rtn(tensors, site, "bh")?), Some(get_rtn(tensors, site, "ah")?))
        } else {
            (None, None)
        };
        let (bl, al) = match meta[8] {
            0 => (None, None),
            1 => (
                Some(LowQuantized::Bin(get_bin(tensors, site, "bl")?)),
                Some(LowQuantized::Bin(get_bin(tensors, site, "al")?)),
            ),
            2 => (
                Some(LowQuantized::Rtn1(get_rtn(tensors, site, "bl")?)),
                Some(LowQuantized::Rtn1(get_rtn(tensors, site, "al")?)),
            ),
            x => bail!("bad low_mode {x}"),
        };
        lora.sites.insert(site.to_string(), QuantizedSite { m, n, r, h, bh, ah, bl, al, axis });
    }
    Ok(lora)
}

/// Save a quantized adapter to disk.
pub fn save(path: impl AsRef<Path>, lora: &QuantizedLora) -> anyhow::Result<()> {
    save_tensorfile(path, &encode(lora))
}

/// Load a quantized adapter from disk.
pub fn load(path: impl AsRef<Path>) -> anyhow::Result<QuantizedLora> {
    decode(&load_tensorfile(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loraquant::{quantize_site, LoraQuantConfig, LowMode};
    use crate::testutil::Rng;

    #[test]
    fn roundtrip_preserves_delta_and_bits() {
        let mut rng = Rng::new(81);
        let (b, a) = rng.lora_pair(64, 48, 8, 0.7);
        let mut lora = QuantizedLora::default();
        lora.sites.insert("l0.wq".into(), quantize_site(&b, &a, &LoraQuantConfig::default()));
        lora.sites.insert(
            "l0.w1".into(),
            quantize_site(&b, &a, &LoraQuantConfig { low_mode: LowMode::Prune, ..Default::default() }),
        );
        let enc = encode(&lora);
        let dec = decode(&enc).unwrap();
        assert_eq!(dec.sites.len(), 2);
        assert_eq!(dec.storage_bits(), lora.storage_bits());
        for site in ["l0.wq", "l0.w1"] {
            let d0 = lora.sites[site].dequant_delta();
            let d1 = dec.sites[site].dequant_delta();
            assert!(d0.sub(&d1).fro_norm() < 1e-6, "{site}");
        }
    }

    #[test]
    fn file_roundtrip() {
        let mut rng = Rng::new(82);
        let (b, a) = rng.lora_pair(32, 32, 4, 0.6);
        let mut lora = QuantizedLora::default();
        lora.sites.insert("l1.wo".into(), quantize_site(&b, &a, &LoraQuantConfig::variant(3, 0.8)));
        let tmp = std::env::temp_dir().join("lq_store_test.bin");
        save(&tmp, &lora).unwrap();
        let back = load(&tmp).unwrap();
        assert_eq!(back.sites["l1.wo"].h, lora.sites["l1.wo"].h);
        std::fs::remove_file(tmp).ok();
    }
}
