//! At-rest serialization of quantized adapters.
//!
//! The registry stores LoRAQuant-compressed adapters in the same
//! `tensorfile` container used for FP weights, with a per-site layout:
//!
//! ```text
//! <site>.meta        i32[10]  m n r h bits_high group axis_b axis_a low_mode flags
//! <site>.bh.packed   u8       <site>.bh.scale f32   <site>.bh.zero f32
//! <site>.ah.*        (same)
//! <site>.bl.packed   u8       <site>.bl.scale f32  [<site>.bl.zero f32]
//! <site>.al.*        (same)
//! ```
//!
//! axis: 0 = row, 1 = col. low_mode: 0 = none/pruned, 1 = bin, 2 = rtn1.

use super::fmt::{load_tensorfile, save_tensorfile, Tensor};
use crate::loraquant::{LowQuantized, QuantizedLora, QuantizedSite};
use crate::quant::{Axis, BinQuantized, QuantAxis, RtnQuantized};
use anyhow::{bail, Context};
use std::collections::BTreeMap;
use std::path::Path;

fn axis_code(a: Axis) -> i32 {
    match a {
        Axis::Row => 0,
        Axis::Col => 1,
    }
}

fn axis_from(c: i32) -> anyhow::Result<Axis> {
    match c {
        0 => Ok(Axis::Row),
        1 => Ok(Axis::Col),
        _ => bail!("bad axis code {c}"),
    }
}

/// Encode one quantized adapter into tensorfile entries.
///
/// The container stores a single `low_mode` per site, so `bl`/`al` must
/// be symmetric (both present or both absent) and homogeneous (same
/// variant). Anything else would silently round-trip into a different
/// adapter — bail instead of corrupting.
pub fn encode(lora: &QuantizedLora) -> anyhow::Result<BTreeMap<String, Tensor>> {
    let mut out = BTreeMap::new();
    for (site, q) in &lora.sites {
        let low_mode = match (&q.bl, &q.al) {
            (None, None) => 0,
            (Some(LowQuantized::Bin(_)), Some(LowQuantized::Bin(_))) => 1,
            (Some(LowQuantized::Rtn1(_)), Some(LowQuantized::Rtn1(_))) => 2,
            (Some(_), Some(_)) => {
                bail!("{site}: heterogeneous low parts (bl/al quantized with different modes) cannot be encoded")
            }
            (None, Some(_)) | (Some(_), None) => {
                bail!("{site}: asymmetric low parts (exactly one of bl/al present) cannot be encoded")
            }
        };
        let meta = vec![
            q.m as i32,
            q.n as i32,
            q.r as i32,
            q.h as i32,
            q.bh.as_ref().map(|x| x.bits as i32).unwrap_or(0),
            q.bh
                .as_ref()
                .map(|x| x.group as i32)
                .or_else(|| low_group(q).map(|g| g as i32))
                .unwrap_or(0),
            axis_code(q.axis.b_axis),
            axis_code(q.axis.a_axis),
            low_mode,
            0,
        ];
        out.insert(format!("{site}.meta"), Tensor::i32(vec![10], meta));
        if let Some(x) = &q.bh {
            put_rtn(&mut out, site, "bh", x);
        }
        if let Some(x) = &q.ah {
            put_rtn(&mut out, site, "ah", x);
        }
        if let Some(x) = &q.bl {
            put_low(&mut out, site, "bl", x);
        }
        if let Some(x) = &q.al {
            put_low(&mut out, site, "al", x);
        }
    }
    Ok(out)
}

fn low_group(q: &QuantizedSite) -> Option<usize> {
    match &q.bl {
        Some(LowQuantized::Bin(b)) => Some(b.group),
        Some(LowQuantized::Rtn1(r)) => Some(r.group),
        None => None,
    }
}

fn put_rtn(out: &mut BTreeMap<String, Tensor>, site: &str, part: &str, q: &RtnQuantized) {
    out.insert(
        format!("{site}.{part}.shape"),
        Tensor::i32(vec![4], vec![q.rows as i32, q.cols as i32, q.bits as i32, q.group as i32]),
    );
    out.insert(format!("{site}.{part}.packed"), Tensor::u8(vec![q.packed.len()], q.packed.clone()));
    out.insert(format!("{site}.{part}.scale"), Tensor::f32(vec![q.scale.len()], q.scale.clone()));
    out.insert(format!("{site}.{part}.zero"), Tensor::f32(vec![q.zero.len()], q.zero.clone()));
}

fn put_low(out: &mut BTreeMap<String, Tensor>, site: &str, part: &str, q: &LowQuantized) {
    match q {
        LowQuantized::Bin(b) => {
            out.insert(
                format!("{site}.{part}.shape"),
                Tensor::i32(vec![4], vec![b.rows as i32, b.cols as i32, 1, b.group as i32]),
            );
            out.insert(format!("{site}.{part}.packed"), Tensor::u8(vec![b.packed.len()], b.packed.clone()));
            out.insert(format!("{site}.{part}.scale"), Tensor::f32(vec![b.scale.len()], b.scale.clone()));
        }
        LowQuantized::Rtn1(r) => put_rtn(out, site, part, r),
    }
}

/// Look up `<site>.<part>.<leaf>`, returning `Err` (not a panic) when a
/// truncated or partial tensorfile lacks it — a disk tier makes missing
/// keys a reachable state, not a programming error.
fn field<'a>(
    t: &'a BTreeMap<String, Tensor>,
    site: &str,
    part: &str,
    leaf: &str,
) -> anyhow::Result<&'a Tensor> {
    t.get(&format!("{site}.{part}.{leaf}"))
        .with_context(|| format!("{site}.{part}.{leaf} missing"))
}

/// Fetch and validate a part's `[rows, cols, bits, group]` shape record.
fn part_shape(
    t: &BTreeMap<String, Tensor>,
    site: &str,
    part: &str,
) -> anyhow::Result<[i32; 4]> {
    let shape = field(t, site, part, "shape")?.as_i32()?;
    let &[rows, cols, bits, group] = shape else {
        bail!("{site}.{part}.shape: expected 4 entries, got {}", shape.len());
    };
    if rows < 0 || cols < 0 || group < 0 {
        bail!("{site}.{part}.shape: negative dimension [{rows}, {cols}, {bits}, {group}]");
    }
    Ok([rows, cols, bits, group])
}

fn get_rtn(t: &BTreeMap<String, Tensor>, site: &str, part: &str) -> anyhow::Result<RtnQuantized> {
    let [rows, cols, bits, group] = part_shape(t, site, part)?;
    if !(1..=8).contains(&bits) {
        bail!("{site}.{part}: rtn bits {bits} outside 1..=8");
    }
    Ok(RtnQuantized {
        rows: rows as usize,
        cols: cols as usize,
        bits: bits as u32,
        group: group as usize,
        packed: field(t, site, part, "packed")?.as_u8()?.to_vec(),
        scale: field(t, site, part, "scale")?.as_f32()?.to_vec(),
        zero: field(t, site, part, "zero")?.as_f32()?.to_vec(),
    })
}

fn get_bin(t: &BTreeMap<String, Tensor>, site: &str, part: &str) -> anyhow::Result<BinQuantized> {
    let [rows, cols, bits, group] = part_shape(t, site, part)?;
    if bits != 1 {
        bail!("{site}.{part}: sign-binarized part must have bits == 1, got {bits}");
    }
    Ok(BinQuantized {
        rows: rows as usize,
        cols: cols as usize,
        group: group as usize,
        packed: field(t, site, part, "packed")?.as_u8()?.to_vec(),
        scale: field(t, site, part, "scale")?.as_f32()?.to_vec(),
    })
}

/// Decode tensorfile entries back into a quantized adapter.
pub fn decode(tensors: &BTreeMap<String, Tensor>) -> anyhow::Result<QuantizedLora> {
    let mut lora = QuantizedLora::default();
    for (name, t) in tensors {
        let Some(site) = name.strip_suffix(".meta") else { continue };
        let meta = t.as_i32()?;
        if meta.len() != 10 {
            bail!("{name}: bad meta length {}", meta.len());
        }
        let (m, n, r, h) = (meta[0] as usize, meta[1] as usize, meta[2] as usize, meta[3] as usize);
        let axis = QuantAxis { b_axis: axis_from(meta[6])?, a_axis: axis_from(meta[7])? };
        let (bh, ah) = if h > 0 {
            (Some(get_rtn(tensors, site, "bh")?), Some(get_rtn(tensors, site, "ah")?))
        } else {
            (None, None)
        };
        let (bl, al) = match meta[8] {
            0 => (None, None),
            1 => (
                Some(LowQuantized::Bin(get_bin(tensors, site, "bl")?)),
                Some(LowQuantized::Bin(get_bin(tensors, site, "al")?)),
            ),
            2 => (
                Some(LowQuantized::Rtn1(get_rtn(tensors, site, "bl")?)),
                Some(LowQuantized::Rtn1(get_rtn(tensors, site, "al")?)),
            ),
            x => bail!("bad low_mode {x}"),
        };
        lora.sites.insert(site.to_string(), QuantizedSite { m, n, r, h, bh, ah, bl, al, axis });
    }
    Ok(lora)
}

/// Save a quantized adapter to disk.
pub fn save(path: impl AsRef<Path>, lora: &QuantizedLora) -> anyhow::Result<()> {
    save_tensorfile(path, &encode(lora)?)
}

/// Load a quantized adapter from disk.
pub fn load(path: impl AsRef<Path>) -> anyhow::Result<QuantizedLora> {
    decode(&load_tensorfile(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loraquant::{quantize_site, HSelect, LoraQuantConfig, LowMode};
    use crate::testutil::Rng;

    /// `h = 2 < r = 4`: both sub-LoRAs always present, STE off for speed.
    fn low_cfg(low_mode: LowMode) -> LoraQuantConfig {
        LoraQuantConfig { hselect: HSelect::Static(2), ste: None, low_mode, ..Default::default() }
    }

    #[test]
    fn roundtrip_preserves_delta_and_bits() {
        let mut rng = Rng::new(81);
        let (b, a) = rng.lora_pair(64, 48, 8, 0.7);
        let mut lora = QuantizedLora::default();
        lora.sites
            .insert("l0.wq".into(), quantize_site(&b, &a, &LoraQuantConfig::default()).unwrap());
        lora.sites.insert(
            "l0.w1".into(),
            quantize_site(&b, &a, &LoraQuantConfig { low_mode: LowMode::Prune, ..Default::default() })
                .unwrap(),
        );
        let enc = encode(&lora).unwrap();
        let dec = decode(&enc).unwrap();
        assert_eq!(dec.sites.len(), 2);
        assert_eq!(dec.storage_bits(), lora.storage_bits());
        for site in ["l0.wq", "l0.w1"] {
            let d0 = lora.sites[site].dequant_delta();
            let d1 = dec.sites[site].dequant_delta();
            assert!(d0.sub(&d1).fro_norm() < 1e-6, "{site}");
        }
    }

    /// Regression (ISSUE 8): `bl: Bin` + `al: Rtn1` used to encode
    /// `low_mode = 1` from `bl` alone, so decode re-read the Rtn1 codes
    /// as sign bits and dropped the `zero` tensor — silent corruption.
    #[test]
    fn encode_rejects_heterogeneous_low_parts() {
        let mut rng = Rng::new(83);
        let (b, a) = rng.lora_pair(32, 24, 4, 0.7);
        let bin = quantize_site(&b, &a, &low_cfg(LowMode::Bin)).unwrap();
        let rtn = quantize_site(&b, &a, &low_cfg(LowMode::Rtn1)).unwrap();
        let mut site = bin.clone();
        site.al = rtn.al.clone();
        assert!(matches!(site.bl, Some(LowQuantized::Bin(_))), "setup needs a Bin bl");
        assert!(matches!(site.al, Some(LowQuantized::Rtn1(_))), "setup needs an Rtn1 al");
        let mut lora = QuantizedLora::default();
        lora.sites.insert("l0.wq".into(), site);
        let err = encode(&lora).unwrap_err().to_string();
        assert!(err.contains("heterogeneous"), "unexpected error: {err}");
    }

    /// Regression (ISSUE 8): `bl: None` + `al: Some` used to encode
    /// `low_mode = 0`, silently dropping `al` from the file.
    #[test]
    fn encode_rejects_asymmetric_low_parts() {
        let mut rng = Rng::new(84);
        let (b, a) = rng.lora_pair(32, 24, 4, 0.7);
        let mut site = quantize_site(&b, &a, &low_cfg(LowMode::Bin)).unwrap();
        assert!(site.al.is_some(), "setup needs a low part");
        site.bl = None;
        let mut lora = QuantizedLora::default();
        lora.sites.insert("l0.wq".into(), site);
        let err = encode(&lora).unwrap_err().to_string();
        assert!(err.contains("asymmetric"), "unexpected error: {err}");
    }

    /// Regression (ISSUE 8): a truncated tensorfile (missing `.packed`)
    /// must decode to `Err`, not panic via direct map indexing.
    #[test]
    fn truncated_file_decodes_to_err_not_panic() {
        let mut rng = Rng::new(85);
        let (b, a) = rng.lora_pair(32, 24, 4, 0.7);
        let mut lora = QuantizedLora::default();
        lora.sites.insert("l0.wq".into(), quantize_site(&b, &a, &low_cfg(LowMode::Bin)).unwrap());
        let full = encode(&lora).unwrap();
        for leaf in ["packed", "scale", "zero"] {
            let mut t = full.clone();
            assert!(t.remove(&format!("l0.wq.bh.{leaf}")).is_some());
            let err = decode(&t).unwrap_err().to_string();
            assert!(err.contains(&format!("l0.wq.bh.{leaf} missing")), "{leaf}: {err}");
        }
    }

    /// A bin part whose shape record claims a multi-bit width is
    /// corrupt: `get_bin` must reject it instead of misreading codes.
    #[test]
    fn bin_shape_with_wrong_bits_is_rejected() {
        let mut rng = Rng::new(86);
        let (b, a) = rng.lora_pair(32, 24, 4, 0.7);
        let mut lora = QuantizedLora::default();
        lora.sites.insert("l0.wq".into(), quantize_site(&b, &a, &low_cfg(LowMode::Bin)).unwrap());
        let mut t = encode(&lora).unwrap();
        let shape = t["l0.wq.bl.shape"].as_i32().unwrap().to_vec();
        t.insert(
            "l0.wq.bl.shape".into(),
            Tensor::i32(vec![4], vec![shape[0], shape[1], 2, shape[3]]),
        );
        let err = decode(&t).unwrap_err().to_string();
        assert!(err.contains("bits == 1"), "unexpected error: {err}");
    }

    #[test]
    fn file_roundtrip() {
        let mut rng = Rng::new(82);
        let (b, a) = rng.lora_pair(32, 32, 4, 0.6);
        let mut lora = QuantizedLora::default();
        lora.sites.insert(
            "l1.wo".into(),
            quantize_site(&b, &a, &LoraQuantConfig::variant(3, 0.8)).unwrap(),
        );
        let tmp = std::env::temp_dir().join("lq_store_test.bin");
        save(&tmp, &lora).unwrap();
        let back = load(&tmp).unwrap();
        assert_eq!(back.sites["l1.wo"].h, lora.sites["l1.wo"].h);
        std::fs::remove_file(tmp).ok();
    }
}
