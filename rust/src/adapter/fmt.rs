//! `tensorfile` — little-endian tensor container shared with the python
//! build path (python/compile/tensorfile.py). Layout:
//!
//! ```text
//! magic   b"LQTF"
//! version u32 (=1)
//! count   u32
//! per tensor:
//!   name_len u16, name utf-8
//!   dtype    u8   (0 = f32, 1 = i32, 2 = u8)
//!   ndim     u8
//!   dims     u32 * ndim
//!   data     raw little-endian, row-major
//! ```

use anyhow::{bail, Context};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"LQTF";
const VERSION: u32 = 1;

/// Tensor payload variants.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U8(Vec<u8>),
}

impl TensorData {
    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
            TensorData::U8(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A named n-dimensional tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn f32(dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Self { dims, data: TensorData::F32(data) }
    }

    pub fn i32(dims: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Self { dims, data: TensorData::I32(data) }
    }

    pub fn u8(dims: Vec<usize>, data: Vec<u8>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Self { dims, data: TensorData::U8(data) }
    }

    /// Borrow as f32 slice (errors on dtype mismatch).
    pub fn as_f32(&self) -> anyhow::Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> anyhow::Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn as_u8(&self) -> anyhow::Result<&[u8]> {
        match &self.data {
            TensorData::U8(v) => Ok(v),
            _ => bail!("tensor is not u8"),
        }
    }

    /// View a 2-D f32 tensor as a [`crate::tensor::Matrix`].
    pub fn to_matrix(&self) -> anyhow::Result<crate::tensor::Matrix> {
        if self.dims.len() != 2 {
            bail!("expected 2-D tensor, got dims {:?}", self.dims);
        }
        Ok(crate::tensor::Matrix::from_vec(self.dims[0], self.dims[1], self.as_f32()?.to_vec()))
    }
}

/// Load a tensorfile into an ordered name → tensor map.
pub fn load_tensorfile(path: impl AsRef<Path>) -> anyhow::Result<BTreeMap<String, Tensor>> {
    let path = path.as_ref();
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    parse_tensorfile(&bytes).with_context(|| format!("parsing {}", path.display()))
}

/// Parse tensorfile bytes.
pub fn parse_tensorfile(bytes: &[u8]) -> anyhow::Result<BTreeMap<String, Tensor>> {
    let mut r = bytes;
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("bad magic {magic:?}");
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("unsupported version {version}");
    }
    let count = read_u32(&mut r)?;
    let mut out = BTreeMap::new();
    for _ in 0..count {
        let nlen = read_u16(&mut r)? as usize;
        let mut name = vec![0u8; nlen];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name)?;
        let mut hdr = [0u8; 2];
        r.read_exact(&mut hdr)?;
        let (dtype, ndim) = (hdr[0], hdr[1] as usize);
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u32(&mut r)? as usize);
        }
        let n: usize = dims.iter().product();
        let data = match dtype {
            0 => {
                let mut buf = vec![0u8; n * 4];
                r.read_exact(&mut buf)?;
                TensorData::F32(buf.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
            }
            1 => {
                let mut buf = vec![0u8; n * 4];
                r.read_exact(&mut buf)?;
                TensorData::I32(buf.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect())
            }
            2 => {
                let mut buf = vec![0u8; n];
                r.read_exact(&mut buf)?;
                TensorData::U8(buf)
            }
            _ => bail!("unknown dtype {dtype} for tensor {name}"),
        };
        out.insert(name, Tensor { dims, data });
    }
    Ok(out)
}

/// Save tensors (iteration order preserved as written order).
pub fn save_tensorfile(
    path: impl AsRef<Path>,
    tensors: &BTreeMap<String, Tensor>,
) -> anyhow::Result<()> {
    let mut buf: Vec<u8> = Vec::new();
    buf.write_all(MAGIC)?;
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for (name, t) in tensors {
        let nb = name.as_bytes();
        buf.extend_from_slice(&(nb.len() as u16).to_le_bytes());
        buf.extend_from_slice(nb);
        let dtype = match &t.data {
            TensorData::F32(_) => 0u8,
            TensorData::I32(_) => 1,
            TensorData::U8(_) => 2,
        };
        buf.push(dtype);
        buf.push(t.dims.len() as u8);
        for &d in &t.dims {
            buf.extend_from_slice(&(d as u32).to_le_bytes());
        }
        match &t.data {
            TensorData::F32(v) => {
                for x in v {
                    buf.extend_from_slice(&x.to_le_bytes());
                }
            }
            TensorData::I32(v) => {
                for x in v {
                    buf.extend_from_slice(&x.to_le_bytes());
                }
            }
            TensorData::U8(v) => buf.extend_from_slice(v),
        }
    }
    std::fs::write(path, buf)?;
    Ok(())
}

fn read_u32(r: &mut &[u8]) -> anyhow::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u16(r: &mut &[u8]) -> anyhow::Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut t = BTreeMap::new();
        t.insert("a".to_string(), Tensor::f32(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]));
        t.insert("b".to_string(), Tensor::i32(vec![4], vec![-1, 0, 1, 2]));
        t.insert("c".to_string(), Tensor::u8(vec![2, 2], vec![0, 255, 7, 9]));
        let tmp = std::env::temp_dir().join("lq_fmt_test.bin");
        save_tensorfile(&tmp, &t).unwrap();
        let back = load_tensorfile(&tmp).unwrap();
        assert_eq!(t, back);
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(parse_tensorfile(b"XXXX\x01\x00\x00\x00\x00\x00\x00\x00").is_err());
    }

    #[test]
    fn matrix_view() {
        let t = Tensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let m = t.to_matrix().unwrap();
        assert_eq!(m.at(1, 0), 3.0);
        assert!(Tensor::i32(vec![2], vec![1, 2]).to_matrix().is_err());
    }
}
