//! Batched greedy decoding + scoring.
//!
//! [`decode_lockstep`] is the **single** lock-step greedy-decode protocol
//! shared by the evaluator here and the serving pool
//! (`coordinator::pool`). Since DESIGN.md §10 it drives a stateful
//! [`DecodeStep`] instead of a full-sequence closure:
//!
//! * the first iteration calls [`DecodeStep::prefill`] once over the
//!   seeded prompts; every later iteration calls [`DecodeStep::step`]
//!   with just the newest token per lane, so a KV-cached stepper pays
//!   O(L·T·d) per generated token instead of O(L·T²·d);
//! * lane `k` generates until its budget is exhausted, the sequence is
//!   full, or greedy argmax emits EOS — EOS is written into the sequence
//!   but never returned as a generated token. A lane that finishes is
//!   handed to the stepper as inactive, which retires it: finished lanes
//!   stop costing work;
//! * [`EngineStepper`] is the production stepper (incremental on the
//!   reference engine, full recompute on PJRT); [`FullRecompute`] wraps
//!   the old full-sequence closure shape and is kept as the oracle the
//!   incremental path is property-tested against.

use super::rouge::rouge_l;
use super::tasks::{EvalSet, TOKENS};
use crate::loraquant::{FactorSource, QFactors};
use crate::model::ModelConfig;
use crate::runtime::{DecodeState, DeviceWeights, Engine};
use anyhow::{bail, Context};
use std::sync::Arc;

/// Result of evaluating one adapter on one task.
#[derive(Debug, Clone)]
pub struct EvalOutcome {
    /// Task score in percent (EM rate or mean ROUGE-L × 100).
    pub score: f64,
    /// Per-example scores (0/1 for EM; ROUGE-L otherwise).
    pub per_example: Vec<f64>,
    /// Whether the metric was exact match.
    pub exact: bool,
}

/// One decode "model" driven by [`decode_lockstep`] (and by the
/// continuous-batching loop in `scheduler::engine_loop`): a stateful
/// prefill-then-step protocol. Logits-returning methods hand back the
/// batch's **next-token logits**, `lanes × vocab` flat (row `k` = logits
/// after lane `k`'s newest token), borrowed from the stepper's own
/// storage.
///
/// The `begin`/`admit`/`retire` hooks are the continuous-batching
/// extension (DESIGN.md §11): a scheduler opens an *empty* session,
/// admits prompts into freed lanes mid-flight, and retires lanes the
/// moment they finish. Lock-step-only steppers (the [`FullRecompute`]
/// oracle, scripted test steppers) keep the bailing defaults.
pub trait DecodeStep {
    /// Consume the seeded prompts: lane `k` holds `pos[k]` tokens at the
    /// front of `seqs[k]`. Called exactly once, before any step.
    fn prefill(&mut self, seqs: &[Vec<i32>], pos: &[usize]) -> anyhow::Result<&[f32]>;

    /// Consume the newest token of every still-`active` lane
    /// (`seqs[k][pos[k] - 1]`; lanes with `pos == 0` have never been
    /// admitted and are skipped). Rows of inactive lanes are
    /// unspecified, and an inactive lane must stop costing compute.
    fn step(&mut self, seqs: &[Vec<i32>], pos: &[usize], active: &[bool])
        -> anyhow::Result<&[f32]>;

    /// Open an **empty** continuous session of `lanes` retired lanes (no
    /// forward runs). Lanes come live through [`DecodeStep::admit`].
    fn begin(&mut self, lanes: usize) -> anyhow::Result<()> {
        let _ = lanes;
        bail!("this stepper does not support continuous decode")
    }

    /// Admit fresh prompts into currently-retired lanes mid-flight: lane
    /// `lanes[i]` holds `pos[lanes[i]]` prompt tokens at the front of
    /// `seqs[lanes[i]]`, and `adapters[i]` is the factor-form adapter to
    /// bind to that lane for its whole occupancy (`None` = the session
    /// weights already carry it). Returns the session-wide logits buffer
    /// with each admitted lane's next-token row filled.
    fn admit(
        &mut self,
        seqs: &[Vec<i32>],
        pos: &[usize],
        lanes: &[usize],
        adapters: &[Option<Arc<dyn FactorSource>>],
    ) -> anyhow::Result<&[f32]> {
        let _ = (seqs, pos, lanes, adapters);
        bail!("this stepper does not support continuous admission")
    }

    /// Admit one **chunk** of a long prompt into a retired lane — the
    /// incremental form of [`DecodeStep::admit`] (DESIGN.md §13). The
    /// prompt occupying lane `lane` is `seqs[lane][..]`'s prompt prefix;
    /// this call consumes its tokens at positions `start .. start + len`.
    /// `adapter` is bound at the first chunk (`start == 0`) for the
    /// lane's whole occupancy. Returns the session-wide logits buffer;
    /// the lane's row is filled only by the `last` chunk, which also
    /// brings the lane live for stepping. Between its first and last
    /// chunks the lane must be treated as neither free nor steppable.
    #[allow(clippy::too_many_arguments)]
    fn admit_chunk(
        &mut self,
        seqs: &[Vec<i32>],
        lane: usize,
        start: usize,
        len: usize,
        last: bool,
        adapter: Option<Arc<dyn FactorSource>>,
    ) -> anyhow::Result<&[f32]> {
        let _ = (seqs, lane, start, len, last, adapter);
        bail!("this stepper does not support chunked prefill")
    }

    /// A lane the decode loop finished (EOS / budget / sequence full):
    /// free its slot so a later [`DecodeStep::admit`] can reuse it.
    fn retire(&mut self, lane: usize) {
        let _ = lane;
    }
}

/// The O(L·T²·d)-per-token **oracle**: re-runs a full-sequence forward
/// (the supplied closure, `flat tokens → lanes · seq_len · vocab` logits)
/// at every step and extracts each lane's row. This was the only decode
/// path before KV caching; it remains the reference the incremental
/// stepper is property-tested against, and the protocol shim for
/// scripted step closures in tests.
pub struct FullRecompute<F> {
    seq_len: usize,
    vocab: usize,
    forward: F,
    out: Vec<f32>,
}

impl<F: FnMut(&[i32]) -> anyhow::Result<Vec<f32>>> FullRecompute<F> {
    pub fn new(seq_len: usize, vocab: usize, forward: F) -> Self {
        Self { seq_len, vocab, forward, out: Vec::new() }
    }

    fn recompute(&mut self, seqs: &[Vec<i32>], pos: &[usize]) -> anyhow::Result<&[f32]> {
        let lanes = seqs.len();
        let flat: Vec<i32> = seqs.iter().flatten().copied().collect();
        let logits = (self.forward)(&flat)?;
        if logits.len() != lanes * self.seq_len * self.vocab {
            bail!(
                "decode_lockstep: step returned {} logits, expected {}",
                logits.len(),
                lanes * self.seq_len * self.vocab
            );
        }
        self.out.clear();
        self.out.resize(lanes * self.vocab, 0.0);
        for k in 0..lanes {
            let src = (k * self.seq_len + pos[k] - 1) * self.vocab;
            self.out[k * self.vocab..(k + 1) * self.vocab]
                .copy_from_slice(&logits[src..src + self.vocab]);
        }
        Ok(&self.out)
    }
}

impl<F: FnMut(&[i32]) -> anyhow::Result<Vec<f32>>> DecodeStep for FullRecompute<F> {
    fn prefill(&mut self, seqs: &[Vec<i32>], pos: &[usize]) -> anyhow::Result<&[f32]> {
        self.recompute(seqs, pos)
    }

    fn step(
        &mut self,
        seqs: &[Vec<i32>],
        pos: &[usize],
        _active: &[bool],
    ) -> anyhow::Result<&[f32]> {
        self.recompute(seqs, pos)
    }
}

/// The production stepper: drives `Engine::prefill` / `Engine::decode_step`
/// over a runtime engine. On the reference backend that is the KV-cached
/// incremental path — prefill runs one batched forward over the prompts,
/// each step costs O(L·T·d) per active lane, and lanes the decode loop
/// deactivates are retired so they stop costing work. `adapters` is
/// per-lane factor-form (empty for merged weights).
pub struct EngineStepper<'a> {
    engine: &'a Engine,
    prog: &'a str,
    weights: &'a DeviceWeights,
    adapters: &'a [Option<&'a QFactors<'a>>],
    state: Option<DecodeState>,
    /// Prefill logits (owned: `Engine::prefill` hands them over).
    first: Vec<f32>,
    /// Reusable per-lane newest-token buffer.
    last: Vec<i32>,
    /// Forward-pass counters: prefill/admit passes and step passes (the
    /// "virtual decode-step count" the scheduler benchmarks compare).
    prefills: u64,
    steps: u64,
}

impl<'a> EngineStepper<'a> {
    pub fn new(
        engine: &'a Engine,
        prog: &'a str,
        weights: &'a DeviceWeights,
        adapters: &'a [Option<&'a QFactors<'a>>],
    ) -> Self {
        Self {
            engine,
            prog,
            weights,
            adapters,
            state: None,
            first: Vec::new(),
            last: Vec::new(),
            prefills: 0,
            steps: 0,
        }
    }

    /// Resident KV bytes of the live session (None before prefill).
    pub fn kv_bytes(&self) -> Option<usize> {
        self.state.as_ref().map(DecodeState::kv_bytes)
    }

    /// Prefill/admit forward passes run so far.
    pub fn prefills(&self) -> u64 {
        self.prefills
    }

    /// Step forward passes run so far.
    pub fn steps(&self) -> u64 {
        self.steps
    }
}

impl DecodeStep for EngineStepper<'_> {
    fn prefill(&mut self, seqs: &[Vec<i32>], pos: &[usize]) -> anyhow::Result<&[f32]> {
        let (state, logits) =
            self.engine.prefill(self.prog, seqs, pos, self.weights, self.adapters)?;
        self.state = Some(state);
        self.first = logits;
        self.prefills += 1;
        Ok(&self.first)
    }

    fn step(
        &mut self,
        seqs: &[Vec<i32>],
        pos: &[usize],
        active: &[bool],
    ) -> anyhow::Result<&[f32]> {
        self.last.clear();
        for k in 0..seqs.len() {
            // a lane with pos == 0 was never admitted (continuous
            // sessions); it is retired, so its token is never consumed
            self.last.push(if pos[k] == 0 { 0 } else { seqs[k][pos[k] - 1] });
        }
        let state = self.state.as_mut().context("decode step before prefill")?;
        for (k, &a) in active.iter().enumerate() {
            if !a && !state.is_retired(k) {
                state.retire(k);
            }
        }
        self.steps += 1;
        self.engine.decode_step(state, self.weights, self.adapters, &self.last)
    }

    /// Continuous-batching hooks (reference engine only: PJRT's AOT
    /// programs bake full-sequence shapes and keep the bailing defaults).
    #[cfg(not(feature = "pjrt"))]
    fn begin(&mut self, lanes: usize) -> anyhow::Result<()> {
        self.state = Some(self.engine.new_session(self.prog, lanes, self.weights)?);
        Ok(())
    }

    #[cfg(not(feature = "pjrt"))]
    fn admit(
        &mut self,
        seqs: &[Vec<i32>],
        pos: &[usize],
        lanes: &[usize],
        adapters: &[Option<Arc<dyn FactorSource>>],
    ) -> anyhow::Result<&[f32]> {
        if adapters.iter().any(Option::is_some) {
            bail!(
                "EngineStepper binds adapters at construction; per-lane admission \
                 adapters need the scheduler's SessionStepper"
            );
        }
        let state = self.state.as_mut().context("admit before begin")?;
        let prompts: Vec<&[i32]> = lanes.iter().map(|&l| &seqs[l][..pos[l]]).collect();
        self.prefills += 1;
        self.engine.admit(state, lanes, &prompts, self.weights, self.adapters)
    }

    fn retire(&mut self, lane: usize) {
        if let Some(state) = self.state.as_mut() {
            if !state.is_retired(lane) {
                state.retire(lane);
            }
        }
    }
}

/// The **single** greedy consume rule, shared by [`decode_lockstep`] and
/// the continuous scheduler's loop (`scheduler::engine_loop`) so the two
/// decode paths cannot drift: lowest-index argmax wins ties, the token
/// is written into the sequence (EOS included), EOS is never pushed to
/// `generated`, and the lane finishes on EOS, on reaching `budget`
/// generated tokens, or on filling the sequence. Returns `true` when the
/// lane is finished.
pub fn consume_greedy(
    row: &[f32],
    seq: &mut [i32],
    pos: &mut usize,
    generated: &mut Vec<i32>,
    budget: usize,
    seq_len: usize,
) -> bool {
    let mut best = 0usize;
    for v in 1..row.len() {
        if row[v] > row[best] {
            best = v;
        }
    }
    let tok = best as i32;
    seq[*pos] = tok;
    *pos += 1;
    if tok == TOKENS::EOS {
        return true;
    }
    generated.push(tok);
    generated.len() >= budget || *pos >= seq_len
}

/// Lock-step batched greedy decode over pre-seeded lanes.
///
/// * `seqs[k]` — the padded working sequence of lane `k` (`seq_len` long,
///   prompt already written at the front).
/// * `pos[k]` — the next write position (= prompt length, ≥ 1).
/// * `budgets[k]` — maximum new tokens (clamped to the sequence room).
/// * `stepper` — the decode model ([`DecodeStep`]): prefilled once over
///   the prompts, then stepped one token at a time.
///
/// Returns the generated tokens per lane, EOS excluded.
pub fn decode_lockstep(
    seq_len: usize,
    vocab: usize,
    seqs: &mut [Vec<i32>],
    pos: &mut [usize],
    budgets: &[usize],
    stepper: &mut dyn DecodeStep,
) -> anyhow::Result<Vec<Vec<i32>>> {
    let lanes = seqs.len();
    if pos.len() != lanes || budgets.len() != lanes {
        bail!("decode_lockstep: {} lanes vs {} pos / {} budgets", lanes, pos.len(), budgets.len());
    }
    for k in 0..lanes {
        if seqs[k].len() != seq_len {
            bail!("decode_lockstep: lane {k} sequence is {} long, not {seq_len}", seqs[k].len());
        }
        if pos[k] == 0 || pos[k] > seq_len {
            bail!("decode_lockstep: lane {k} position {} out of range 1..={seq_len}", pos[k]);
        }
    }
    let mut generated: Vec<Vec<i32>> = vec![Vec::new(); lanes];
    // A lane is active until its (room-clamped) budget is spent.
    let mut active: Vec<bool> =
        (0..lanes).map(|k| budgets[k].min(seq_len - pos[k]) > 0).collect();
    if !active.iter().any(|&a| a) {
        return Ok(generated); // no forward may run when every budget is zero
    }
    let mut first = true;
    while active.iter().any(|&a| a) {
        let logits = if first {
            first = false;
            stepper.prefill(seqs, pos)?
        } else {
            stepper.step(seqs, pos, &active)?
        };
        if logits.len() != lanes * vocab {
            bail!(
                "decode_lockstep: stepper returned {} logits, expected {}",
                logits.len(),
                lanes * vocab
            );
        }
        for k in 0..lanes {
            if !active[k] {
                continue;
            }
            let row = &logits[k * vocab..(k + 1) * vocab];
            let done = consume_greedy(
                row,
                &mut seqs[k],
                &mut pos[k],
                &mut generated[k],
                budgets[k],
                seq_len,
            );
            if done {
                active[k] = false;
            }
        }
    }
    Ok(generated)
}

/// Greedy-decode every example and score it (paper §4.1 protocol: the model
/// generates after SEP; EM for math/code analogs, ROUGE-L for the
/// summarization analog).
///
/// Decoding is batched through the `<model>/b<bucket>` program: examples are
/// packed `bucket` at a time (the final batch padded by repeating its last
/// example) and advanced via [`decode_lockstep`] over an incremental
/// [`EngineStepper`], with per-example budgets of `|reference|` tokens.
pub fn evaluate(
    engine: &Engine,
    model: &str,
    bucket: usize,
    cfg: &ModelConfig,
    weights: &DeviceWeights,
    set: &EvalSet,
) -> anyhow::Result<EvalOutcome> {
    let prog = format!("{model}/b{bucket}");
    let t_len = cfg.seq_len;
    let vocab = cfg.vocab;
    let n = set.len();
    let mut per_example = Vec::with_capacity(n);

    let mut start = 0;
    while start < n {
        let idx: Vec<usize> = (0..bucket).map(|k| (start + k).min(n - 1)).collect();
        // working copies of the padded prompts
        let mut seqs: Vec<Vec<i32>> = idx.iter().map(|&i| set.prompts[i].clone()).collect();
        let mut pos: Vec<usize> = idx.iter().map(|&i| set.plens[i]).collect();
        // Generation protocol (matches train.py quick_eval): up to
        // |reference| tokens per example. Padded duplicate lanes (the
        // repeats of the final example — the lanes the scoring loop
        // below skips) get budget 0, so the stepper retires them before
        // the first step instead of decoding tokens that are discarded.
        let budgets: Vec<usize> = idx
            .iter()
            .enumerate()
            .map(|(k, &i)| if k > 0 && idx[k - 1] == i { 0 } else { set.refs[i].len() })
            .collect();
        let mut stepper = EngineStepper::new(engine, &prog, weights, &[]);
        let generated =
            decode_lockstep(t_len, vocab, &mut seqs, &mut pos, &budgets, &mut stepper)?;
        // score the real (non-padding) examples of this batch
        for (k, &i) in idx.iter().enumerate() {
            if i < start {
                continue; // padded duplicate
            }
            if k > 0 && idx[k - 1] == i {
                continue;
            }
            let score = if set.exact {
                f64::from(generated[k] == set.refs[i])
            } else {
                rouge_l(&generated[k], &set.refs[i])
            };
            per_example.push(score);
        }
        start += bucket;
    }

    let score = 100.0 * per_example.iter().sum::<f64>() / per_example.len().max(1) as f64;
    Ok(EvalOutcome { score, per_example, exact: set.exact })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A scripted "model": always emits `next` as the argmax token
    /// (old full-sequence closure shape, shimmed through the oracle).
    fn scripted_step(
        lanes: usize,
        seq_len: usize,
        vocab: usize,
        next: impl Fn(usize, usize) -> i32,
    ) -> FullRecompute<impl FnMut(&[i32]) -> anyhow::Result<Vec<f32>>> {
        let mut calls = 0usize;
        FullRecompute::new(seq_len, vocab, move |_flat| {
            let mut logits = vec![0.0f32; lanes * seq_len * vocab];
            for k in 0..lanes {
                for p in 0..seq_len {
                    let tok = next(k, calls) as usize;
                    logits[(k * seq_len + p) * vocab + tok] = 10.0;
                }
            }
            calls += 1;
            Ok(logits)
        })
    }

    #[test]
    fn budgets_and_eos_semantics() {
        let (seq_len, vocab) = (8, 16);
        // lane 0: emits 7 forever — stops at budget 3.
        // lane 1: emits 5 then EOS — returns [5], EOS excluded.
        let mut seqs = vec![vec![TOKENS::PAD; seq_len]; 2];
        seqs[0][0] = TOKENS::BOS;
        seqs[1][0] = TOKENS::BOS;
        let mut pos = vec![1, 1];
        let mut stepper = scripted_step(2, seq_len, vocab, |k, call| {
            if k == 0 {
                7
            } else if call == 0 {
                5
            } else {
                TOKENS::EOS
            }
        });
        let gen =
            decode_lockstep(seq_len, vocab, &mut seqs, &mut pos, &[3, 5], &mut stepper).unwrap();
        assert_eq!(gen[0], vec![7, 7, 7]);
        assert_eq!(gen[1], vec![5]);
        assert_eq!(pos, vec![4, 3], "EOS is written into the sequence");
        assert_eq!(seqs[1][2], TOKENS::EOS);
    }

    #[test]
    fn budget_clamped_to_sequence_room() {
        let (seq_len, vocab) = (4, 8);
        let mut seqs = vec![vec![TOKENS::PAD; seq_len]];
        seqs[0][..3].copy_from_slice(&[1, 5, 3]);
        let mut pos = vec![3];
        let mut stepper = scripted_step(1, seq_len, vocab, |_, _| 6);
        let gen =
            decode_lockstep(seq_len, vocab, &mut seqs, &mut pos, &[100], &mut stepper).unwrap();
        assert_eq!(gen[0], vec![6], "only one slot of room");
        assert_eq!(pos[0], seq_len);
    }

    #[test]
    fn zero_budget_runs_no_forward() {
        let (seq_len, vocab) = (4, 8);
        let mut seqs = vec![vec![1, 0, 0, 0]];
        let mut pos = vec![1];
        let mut stepper = FullRecompute::new(seq_len, vocab, |_flat: &[i32]| {
            panic!("no forward may run when every budget is zero")
        });
        let gen =
            decode_lockstep(seq_len, vocab, &mut seqs, &mut pos, &[0], &mut stepper).unwrap();
        assert!(gen[0].is_empty());
    }

    #[test]
    fn rejects_malformed_lanes() {
        let (seq_len, vocab) = (4, 8);
        let step = |_: &[i32]| -> anyhow::Result<Vec<f32>> { unreachable!() };
        let mut stepper = FullRecompute::new(seq_len, vocab, step);
        let mut seqs = vec![vec![1, 0, 0, 0]];
        let mut pos = vec![0]; // pos 0 has no logits row to read
        assert!(
            decode_lockstep(seq_len, vocab, &mut seqs, &mut pos, &[1], &mut stepper).is_err()
        );
        let mut short = vec![vec![1, 0]];
        let mut pos = vec![1];
        assert!(
            decode_lockstep(seq_len, vocab, &mut short, &mut pos, &[1], &mut stepper).is_err()
        );
    }

    /// A stepper that records the protocol it is driven with: prefill
    /// exactly once, then steps whose `active` flags drop lanes the
    /// moment they finish (the retirement contract).
    struct Recording {
        vocab: usize,
        emit: Vec<Vec<i32>>, // per call, per lane
        calls: usize,
        active_log: Vec<Vec<bool>>,
        out: Vec<f32>,
    }

    impl DecodeStep for Recording {
        fn prefill(&mut self, seqs: &[Vec<i32>], _pos: &[usize]) -> anyhow::Result<&[f32]> {
            assert_eq!(self.calls, 0, "prefill must be the first and only first call");
            self.fill(seqs.len())
        }

        fn step(
            &mut self,
            seqs: &[Vec<i32>],
            _pos: &[usize],
            active: &[bool],
        ) -> anyhow::Result<&[f32]> {
            assert!(self.calls > 0, "step before prefill");
            self.active_log.push(active.to_vec());
            self.fill(seqs.len())
        }
    }

    impl Recording {
        fn fill(&mut self, lanes: usize) -> anyhow::Result<&[f32]> {
            let emit = &self.emit[self.calls.min(self.emit.len() - 1)];
            self.out.clear();
            self.out.resize(lanes * self.vocab, 0.0);
            for k in 0..lanes {
                self.out[k * self.vocab + emit[k] as usize] = 1.0;
            }
            self.calls += 1;
            Ok(&self.out)
        }
    }

    #[test]
    fn finished_lanes_are_deactivated_for_the_stepper() {
        let (seq_len, vocab) = (8, 16);
        // lane 0 emits EOS on the 2nd forward; lane 1 runs 4 tokens
        let mut stepper = Recording {
            vocab,
            emit: vec![vec![7, 9], vec![TOKENS::EOS, 9], vec![5, 9]],
            calls: 0,
            active_log: Vec::new(),
            out: Vec::new(),
        };
        let mut seqs = vec![vec![TOKENS::PAD; seq_len]; 2];
        seqs[0][0] = TOKENS::BOS;
        seqs[1][0] = TOKENS::BOS;
        let mut pos = vec![1, 1];
        let gen =
            decode_lockstep(seq_len, vocab, &mut seqs, &mut pos, &[4, 4], &mut stepper).unwrap();
        assert_eq!(gen[0], vec![7], "EOS on the second forward ends lane 0");
        assert_eq!(gen[1], vec![9, 9, 9, 9]);
        // steps 1.. : lane 0 goes inactive right after its EOS
        assert_eq!(stepper.active_log[0], vec![true, true]);
        for log in &stepper.active_log[1..] {
            assert_eq!(log, &vec![false, true], "finished lane must be handed over inactive");
        }
    }

    /// The continuous hooks on the production stepper: begin opens an
    /// empty session, admit brings lanes live (bit-identical to a fresh
    /// prefill), retire frees them for reuse; the lock-step oracle keeps
    /// the bailing defaults.
    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn engine_stepper_continuous_hooks_reuse_freed_lanes() {
        use crate::model::{merge_adapter, BaseWeights};
        use crate::testutil::synth::{synth_model_config, write_synth_model};

        let dir = std::env::temp_dir()
            .join(format!("lq_decode_hooks_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = synth_model_config();
        write_synth_model(&dir, "synth", &cfg, &[2], 55).unwrap();
        let base = BaseWeights::load(dir.join("synth")).unwrap();
        let mut engine = Engine::new(&dir).unwrap();
        engine.load_model_fwd("synth", 2, base.cfg.param_names().len()).unwrap();
        let w = engine
            .upload_weights(&merge_adapter(&base, &std::collections::BTreeMap::new()).unwrap())
            .unwrap();
        let vocab = cfg.vocab;

        // fresh-prefill oracle row for the prompt
        let prompt = [3i32, 1, 4];
        let mut oseqs = vec![vec![TOKENS::PAD; cfg.seq_len]];
        oseqs[0][..3].copy_from_slice(&prompt);
        let mut oracle = EngineStepper::new(&engine, "synth/b2", &w, &[]);
        let want = oracle.prefill(&oseqs, &[3]).unwrap().to_vec();
        assert_eq!(oracle.prefills(), 1);

        // continuous: begin empty, admit into lane 1, retire, re-admit
        let mut stepper = EngineStepper::new(&engine, "synth/b2", &w, &[]);
        stepper.begin(2).unwrap();
        let mut seqs = vec![vec![TOKENS::PAD; cfg.seq_len]; 2];
        seqs[1][..3].copy_from_slice(&prompt);
        let pos = vec![0usize, 3];
        let out = stepper.admit(&seqs, &pos, &[1], &[None]).unwrap().to_vec();
        assert_eq!(&out[vocab..2 * vocab], &want[..vocab], "admit row == fresh prefill row");
        assert!(out[..vocab].iter().all(|&x| x == 0.0), "un-admitted lane row stays zero");
        stepper.retire(1);
        let out2 = stepper.admit(&seqs, &pos, &[1], &[None]).unwrap().to_vec();
        assert_eq!(out2, out, "a freed lane re-admits bit-identically");
        assert_eq!(stepper.prefills(), 2);
        assert_eq!(stepper.steps(), 0);

        // the oracle stepper family keeps the bailing defaults
        let mut full = FullRecompute::new(cfg.seq_len, vocab, |_: &[i32]| Ok(vec![]));
        assert!(full.begin(2).is_err());
        assert!(full.admit(&seqs, &pos, &[1], &[None]).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn em_scoring_semantics() {
        // the EOS-stop + equality path, replicated inline
        let generated = vec![5, 6, TOKENS::EOS, 9];
        let cut: Vec<i32> = generated.iter().copied().take_while(|&t| t != TOKENS::EOS).collect();
        assert_eq!(cut, vec![5, 6]);
        assert_eq!(f64::from(cut == vec![5, 6]), 1.0);
        assert_eq!(f64::from(cut == vec![5, 7]), 0.0);
    }
}
