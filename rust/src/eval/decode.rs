//! Batched greedy decoding + scoring through the PJRT runtime.

use super::rouge::rouge_l;
use super::tasks::{EvalSet, TOKENS};
use crate::model::ModelConfig;
use crate::runtime::{DeviceWeights, Engine};

/// Result of evaluating one adapter on one task.
#[derive(Debug, Clone)]
pub struct EvalOutcome {
    /// Task score in percent (EM rate or mean ROUGE-L × 100).
    pub score: f64,
    /// Per-example scores (0/1 for EM; ROUGE-L otherwise).
    pub per_example: Vec<f64>,
    /// Whether the metric was exact match.
    pub exact: bool,
}

/// Greedy-decode every example and score it (paper §4.1 protocol: the model
/// generates after SEP; EM for math/code analogs, ROUGE-L for the
/// summarization analog).
///
/// Decoding is batched through the `<model>/b<bucket>` program: examples are
/// packed `bucket` at a time (the final batch padded by repeating its last
/// example) and advanced in lock-step; each step is one full-sequence
/// forward, with per-example write positions.
pub fn evaluate(
    engine: &Engine,
    model: &str,
    bucket: usize,
    cfg: &ModelConfig,
    weights: &DeviceWeights,
    set: &EvalSet,
) -> anyhow::Result<EvalOutcome> {
    let prog = format!("{model}/b{bucket}");
    let t_len = cfg.seq_len;
    let vocab = cfg.vocab;
    let n = set.len();
    let mut per_example = Vec::with_capacity(n);

    let mut start = 0;
    while start < n {
        let idx: Vec<usize> = (0..bucket).map(|k| (start + k).min(n - 1)).collect();
        // working copies of the padded prompts
        let mut seqs: Vec<Vec<i32>> = idx.iter().map(|&i| set.prompts[i].clone()).collect();
        let mut pos: Vec<usize> = idx.iter().map(|&i| set.plens[i]).collect();
        // Generation protocol (matches train.py quick_eval): produce exactly
        // |reference| tokens per example — EM then compares the full answer
        // without conditioning on the model's EOS placement.
        let budgets: Vec<usize> = idx.iter().map(|&i| set.refs[i].len()).collect();
        let steps = budgets.iter().copied().max().unwrap_or(0);
        let mut done = vec![false; bucket];
        for _ in 0..steps {
            if done.iter().all(|&d| d) {
                break;
            }
            let flat: Vec<i32> = seqs.iter().flatten().copied().collect();
            let logits = engine.forward(&prog, &flat, &[bucket, t_len], weights)?;
            for k in 0..bucket {
                if done[k] || pos[k] >= t_len || pos[k] - set.plens[idx[k]] >= budgets[k] {
                    done[k] = true;
                    continue;
                }
                // logits row for (k, pos[k]-1)
                let base = (k * t_len + pos[k] - 1) * vocab;
                let row = &logits[base..base + vocab];
                let mut best = 0usize;
                for v in 1..vocab {
                    if row[v] > row[best] {
                        best = v;
                    }
                }
                let tok = best as i32;
                seqs[k][pos[k]] = tok;
                pos[k] += 1;
            }
        }
        // score the real (non-padding) examples of this batch
        for (k, &i) in idx.iter().enumerate() {
            if i < start {
                continue; // padded duplicate
            }
            if k > 0 && idx[k - 1] == i {
                continue;
            }
            let gen_full = &seqs[k][set.plens[i]..pos[k]];
            // strip EOS and everything after
            let gen: Vec<i32> = gen_full.iter().copied().take_while(|&t| t != TOKENS::EOS).collect();
            let score = if set.exact {
                f64::from(gen == set.refs[i])
            } else {
                rouge_l(&gen, &set.refs[i])
            };
            per_example.push(score);
        }
        start += bucket;
    }

    let score = 100.0 * per_example.iter().sum::<f64>() / per_example.len().max(1) as f64;
    Ok(EvalOutcome { score, per_example, exact: set.exact })
}

#[cfg(test)]
mod tests {
    // evaluate() needs artifacts + a PJRT engine; covered by
    // rust/tests/runtime_e2e.rs. Here we only test scoring helpers.
    use super::*;

    #[test]
    fn em_scoring_semantics() {
        // the take_while(EOS) + equality path, replicated inline
        let generated = vec![5, 6, TOKENS::EOS, 9];
        let cut: Vec<i32> = generated.iter().copied().take_while(|&t| t != TOKENS::EOS).collect();
        assert_eq!(cut, vec![5, 6]);
        assert_eq!(f64::from(cut == vec![5, 6]), 1.0);
        assert_eq!(f64::from(cut == vec![5, 7]), 0.0);
    }
}
