//! Batched greedy decoding + scoring.
//!
//! [`decode_lockstep`] is the **single** lock-step greedy-decode protocol
//! shared by the evaluator here and the serving pool
//! (`coordinator::pool`). Since DESIGN.md §10 it drives a stateful
//! [`DecodeStep`] instead of a full-sequence closure:
//!
//! * the first iteration calls [`DecodeStep::prefill`] once over the
//!   seeded prompts; every later iteration calls [`DecodeStep::step`]
//!   with just the newest token per lane, so a KV-cached stepper pays
//!   O(L·T·d) per generated token instead of O(L·T²·d);
//! * lane `k` generates until its budget is exhausted, the sequence is
//!   full, or greedy argmax emits EOS — EOS is written into the sequence
//!   but never returned as a generated token. A lane that finishes is
//!   handed to the stepper as inactive, which retires it: finished lanes
//!   stop costing work;
//! * [`EngineStepper`] is the production stepper (incremental on the
//!   reference engine, full recompute on PJRT); [`FullRecompute`] wraps
//!   the old full-sequence closure shape and is kept as the oracle the
//!   incremental path is property-tested against.

use super::rouge::rouge_l;
use super::tasks::{EvalSet, TOKENS};
use crate::loraquant::QFactors;
use crate::model::ModelConfig;
use crate::runtime::{DecodeState, DeviceWeights, Engine};
use anyhow::{bail, Context};

/// Result of evaluating one adapter on one task.
#[derive(Debug, Clone)]
pub struct EvalOutcome {
    /// Task score in percent (EM rate or mean ROUGE-L × 100).
    pub score: f64,
    /// Per-example scores (0/1 for EM; ROUGE-L otherwise).
    pub per_example: Vec<f64>,
    /// Whether the metric was exact match.
    pub exact: bool,
}

/// One decode "model" driven by [`decode_lockstep`]: a stateful
/// prefill-then-step protocol. Both methods return the batch's
/// **next-token logits**, `lanes × vocab` flat (row `k` = logits after
/// lane `k`'s newest token), borrowed from the stepper's own storage.
pub trait DecodeStep {
    /// Consume the seeded prompts: lane `k` holds `pos[k]` tokens at the
    /// front of `seqs[k]`. Called exactly once, before any step.
    fn prefill(&mut self, seqs: &[Vec<i32>], pos: &[usize]) -> anyhow::Result<&[f32]>;

    /// Consume the newest token of every still-`active` lane
    /// (`seqs[k][pos[k] - 1]`). Rows of inactive lanes are unspecified,
    /// and an inactive lane must stop costing compute.
    fn step(&mut self, seqs: &[Vec<i32>], pos: &[usize], active: &[bool])
        -> anyhow::Result<&[f32]>;
}

/// The O(L·T²·d)-per-token **oracle**: re-runs a full-sequence forward
/// (the supplied closure, `flat tokens → lanes · seq_len · vocab` logits)
/// at every step and extracts each lane's row. This was the only decode
/// path before KV caching; it remains the reference the incremental
/// stepper is property-tested against, and the protocol shim for
/// scripted step closures in tests.
pub struct FullRecompute<F> {
    seq_len: usize,
    vocab: usize,
    forward: F,
    out: Vec<f32>,
}

impl<F: FnMut(&[i32]) -> anyhow::Result<Vec<f32>>> FullRecompute<F> {
    pub fn new(seq_len: usize, vocab: usize, forward: F) -> Self {
        Self { seq_len, vocab, forward, out: Vec::new() }
    }

    fn recompute(&mut self, seqs: &[Vec<i32>], pos: &[usize]) -> anyhow::Result<&[f32]> {
        let lanes = seqs.len();
        let flat: Vec<i32> = seqs.iter().flatten().copied().collect();
        let logits = (self.forward)(&flat)?;
        if logits.len() != lanes * self.seq_len * self.vocab {
            bail!(
                "decode_lockstep: step returned {} logits, expected {}",
                logits.len(),
                lanes * self.seq_len * self.vocab
            );
        }
        self.out.clear();
        self.out.resize(lanes * self.vocab, 0.0);
        for k in 0..lanes {
            let src = (k * self.seq_len + pos[k] - 1) * self.vocab;
            self.out[k * self.vocab..(k + 1) * self.vocab]
                .copy_from_slice(&logits[src..src + self.vocab]);
        }
        Ok(&self.out)
    }
}

impl<F: FnMut(&[i32]) -> anyhow::Result<Vec<f32>>> DecodeStep for FullRecompute<F> {
    fn prefill(&mut self, seqs: &[Vec<i32>], pos: &[usize]) -> anyhow::Result<&[f32]> {
        self.recompute(seqs, pos)
    }

    fn step(
        &mut self,
        seqs: &[Vec<i32>],
        pos: &[usize],
        _active: &[bool],
    ) -> anyhow::Result<&[f32]> {
        self.recompute(seqs, pos)
    }
}

/// The production stepper: drives `Engine::prefill` / `Engine::decode_step`
/// over a runtime engine. On the reference backend that is the KV-cached
/// incremental path — prefill runs one batched forward over the prompts,
/// each step costs O(L·T·d) per active lane, and lanes the decode loop
/// deactivates are retired so they stop costing work. `adapters` is
/// per-lane factor-form (empty for merged weights).
pub struct EngineStepper<'a> {
    engine: &'a Engine,
    prog: &'a str,
    weights: &'a DeviceWeights,
    adapters: &'a [Option<&'a QFactors<'a>>],
    state: Option<DecodeState>,
    /// Prefill logits (owned: `Engine::prefill` hands them over).
    first: Vec<f32>,
    /// Reusable per-lane newest-token buffer.
    last: Vec<i32>,
}

impl<'a> EngineStepper<'a> {
    pub fn new(
        engine: &'a Engine,
        prog: &'a str,
        weights: &'a DeviceWeights,
        adapters: &'a [Option<&'a QFactors<'a>>],
    ) -> Self {
        Self { engine, prog, weights, adapters, state: None, first: Vec::new(), last: Vec::new() }
    }

    /// Resident KV bytes of the live session (None before prefill).
    pub fn kv_bytes(&self) -> Option<usize> {
        self.state.as_ref().map(DecodeState::kv_bytes)
    }
}

impl DecodeStep for EngineStepper<'_> {
    fn prefill(&mut self, seqs: &[Vec<i32>], pos: &[usize]) -> anyhow::Result<&[f32]> {
        let (state, logits) =
            self.engine.prefill(self.prog, seqs, pos, self.weights, self.adapters)?;
        self.state = Some(state);
        self.first = logits;
        Ok(&self.first)
    }

    fn step(
        &mut self,
        seqs: &[Vec<i32>],
        pos: &[usize],
        active: &[bool],
    ) -> anyhow::Result<&[f32]> {
        self.last.clear();
        for k in 0..seqs.len() {
            self.last.push(seqs[k][pos[k] - 1]);
        }
        let state = self.state.as_mut().context("decode step before prefill")?;
        for (k, &a) in active.iter().enumerate() {
            if !a && !state.is_retired(k) {
                state.retire(k);
            }
        }
        self.engine.decode_step(state, self.weights, self.adapters, &self.last)
    }
}

/// Lock-step batched greedy decode over pre-seeded lanes.
///
/// * `seqs[k]` — the padded working sequence of lane `k` (`seq_len` long,
///   prompt already written at the front).
/// * `pos[k]` — the next write position (= prompt length, ≥ 1).
/// * `budgets[k]` — maximum new tokens (clamped to the sequence room).
/// * `stepper` — the decode model ([`DecodeStep`]): prefilled once over
///   the prompts, then stepped one token at a time.
///
/// Returns the generated tokens per lane, EOS excluded.
pub fn decode_lockstep(
    seq_len: usize,
    vocab: usize,
    seqs: &mut [Vec<i32>],
    pos: &mut [usize],
    budgets: &[usize],
    stepper: &mut dyn DecodeStep,
) -> anyhow::Result<Vec<Vec<i32>>> {
    let lanes = seqs.len();
    if pos.len() != lanes || budgets.len() != lanes {
        bail!("decode_lockstep: {} lanes vs {} pos / {} budgets", lanes, pos.len(), budgets.len());
    }
    for k in 0..lanes {
        if seqs[k].len() != seq_len {
            bail!("decode_lockstep: lane {k} sequence is {} long, not {seq_len}", seqs[k].len());
        }
        if pos[k] == 0 || pos[k] > seq_len {
            bail!("decode_lockstep: lane {k} position {} out of range 1..={seq_len}", pos[k]);
        }
    }
    let mut generated: Vec<Vec<i32>> = vec![Vec::new(); lanes];
    // A lane is active until its (room-clamped) budget is spent.
    let mut active: Vec<bool> =
        (0..lanes).map(|k| budgets[k].min(seq_len - pos[k]) > 0).collect();
    if !active.iter().any(|&a| a) {
        return Ok(generated); // no forward may run when every budget is zero
    }
    let mut first = true;
    while active.iter().any(|&a| a) {
        let logits = if first {
            first = false;
            stepper.prefill(seqs, pos)?
        } else {
            stepper.step(seqs, pos, &active)?
        };
        if logits.len() != lanes * vocab {
            bail!(
                "decode_lockstep: stepper returned {} logits, expected {}",
                logits.len(),
                lanes * vocab
            );
        }
        for k in 0..lanes {
            if !active[k] {
                continue;
            }
            let row = &logits[k * vocab..(k + 1) * vocab];
            let mut best = 0usize;
            for v in 1..vocab {
                if row[v] > row[best] {
                    best = v;
                }
            }
            let tok = best as i32;
            seqs[k][pos[k]] = tok;
            pos[k] += 1;
            if tok == TOKENS::EOS {
                active[k] = false;
            } else {
                generated[k].push(tok);
                if generated[k].len() >= budgets[k] || pos[k] >= seq_len {
                    active[k] = false;
                }
            }
        }
    }
    Ok(generated)
}

/// Greedy-decode every example and score it (paper §4.1 protocol: the model
/// generates after SEP; EM for math/code analogs, ROUGE-L for the
/// summarization analog).
///
/// Decoding is batched through the `<model>/b<bucket>` program: examples are
/// packed `bucket` at a time (the final batch padded by repeating its last
/// example) and advanced via [`decode_lockstep`] over an incremental
/// [`EngineStepper`], with per-example budgets of `|reference|` tokens.
pub fn evaluate(
    engine: &Engine,
    model: &str,
    bucket: usize,
    cfg: &ModelConfig,
    weights: &DeviceWeights,
    set: &EvalSet,
) -> anyhow::Result<EvalOutcome> {
    let prog = format!("{model}/b{bucket}");
    let t_len = cfg.seq_len;
    let vocab = cfg.vocab;
    let n = set.len();
    let mut per_example = Vec::with_capacity(n);

    let mut start = 0;
    while start < n {
        let idx: Vec<usize> = (0..bucket).map(|k| (start + k).min(n - 1)).collect();
        // working copies of the padded prompts
        let mut seqs: Vec<Vec<i32>> = idx.iter().map(|&i| set.prompts[i].clone()).collect();
        let mut pos: Vec<usize> = idx.iter().map(|&i| set.plens[i]).collect();
        // Generation protocol (matches train.py quick_eval): up to
        // |reference| tokens per example. Padded duplicate lanes (the
        // repeats of the final example — the lanes the scoring loop
        // below skips) get budget 0, so the stepper retires them before
        // the first step instead of decoding tokens that are discarded.
        let budgets: Vec<usize> = idx
            .iter()
            .enumerate()
            .map(|(k, &i)| if k > 0 && idx[k - 1] == i { 0 } else { set.refs[i].len() })
            .collect();
        let mut stepper = EngineStepper::new(engine, &prog, weights, &[]);
        let generated =
            decode_lockstep(t_len, vocab, &mut seqs, &mut pos, &budgets, &mut stepper)?;
        // score the real (non-padding) examples of this batch
        for (k, &i) in idx.iter().enumerate() {
            if i < start {
                continue; // padded duplicate
            }
            if k > 0 && idx[k - 1] == i {
                continue;
            }
            let score = if set.exact {
                f64::from(generated[k] == set.refs[i])
            } else {
                rouge_l(&generated[k], &set.refs[i])
            };
            per_example.push(score);
        }
        start += bucket;
    }

    let score = 100.0 * per_example.iter().sum::<f64>() / per_example.len().max(1) as f64;
    Ok(EvalOutcome { score, per_example, exact: set.exact })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A scripted "model": always emits `next` as the argmax token
    /// (old full-sequence closure shape, shimmed through the oracle).
    fn scripted_step(
        lanes: usize,
        seq_len: usize,
        vocab: usize,
        next: impl Fn(usize, usize) -> i32,
    ) -> FullRecompute<impl FnMut(&[i32]) -> anyhow::Result<Vec<f32>>> {
        let mut calls = 0usize;
        FullRecompute::new(seq_len, vocab, move |_flat| {
            let mut logits = vec![0.0f32; lanes * seq_len * vocab];
            for k in 0..lanes {
                for p in 0..seq_len {
                    let tok = next(k, calls) as usize;
                    logits[(k * seq_len + p) * vocab + tok] = 10.0;
                }
            }
            calls += 1;
            Ok(logits)
        })
    }

    #[test]
    fn budgets_and_eos_semantics() {
        let (seq_len, vocab) = (8, 16);
        // lane 0: emits 7 forever — stops at budget 3.
        // lane 1: emits 5 then EOS — returns [5], EOS excluded.
        let mut seqs = vec![vec![TOKENS::PAD; seq_len]; 2];
        seqs[0][0] = TOKENS::BOS;
        seqs[1][0] = TOKENS::BOS;
        let mut pos = vec![1, 1];
        let mut stepper = scripted_step(2, seq_len, vocab, |k, call| {
            if k == 0 {
                7
            } else if call == 0 {
                5
            } else {
                TOKENS::EOS
            }
        });
        let gen =
            decode_lockstep(seq_len, vocab, &mut seqs, &mut pos, &[3, 5], &mut stepper).unwrap();
        assert_eq!(gen[0], vec![7, 7, 7]);
        assert_eq!(gen[1], vec![5]);
        assert_eq!(pos, vec![4, 3], "EOS is written into the sequence");
        assert_eq!(seqs[1][2], TOKENS::EOS);
    }

    #[test]
    fn budget_clamped_to_sequence_room() {
        let (seq_len, vocab) = (4, 8);
        let mut seqs = vec![vec![TOKENS::PAD; seq_len]];
        seqs[0][..3].copy_from_slice(&[1, 5, 3]);
        let mut pos = vec![3];
        let mut stepper = scripted_step(1, seq_len, vocab, |_, _| 6);
        let gen =
            decode_lockstep(seq_len, vocab, &mut seqs, &mut pos, &[100], &mut stepper).unwrap();
        assert_eq!(gen[0], vec![6], "only one slot of room");
        assert_eq!(pos[0], seq_len);
    }

    #[test]
    fn zero_budget_runs_no_forward() {
        let (seq_len, vocab) = (4, 8);
        let mut seqs = vec![vec![1, 0, 0, 0]];
        let mut pos = vec![1];
        let mut stepper = FullRecompute::new(seq_len, vocab, |_flat: &[i32]| {
            panic!("no forward may run when every budget is zero")
        });
        let gen =
            decode_lockstep(seq_len, vocab, &mut seqs, &mut pos, &[0], &mut stepper).unwrap();
        assert!(gen[0].is_empty());
    }

    #[test]
    fn rejects_malformed_lanes() {
        let (seq_len, vocab) = (4, 8);
        let step = |_: &[i32]| -> anyhow::Result<Vec<f32>> { unreachable!() };
        let mut stepper = FullRecompute::new(seq_len, vocab, step);
        let mut seqs = vec![vec![1, 0, 0, 0]];
        let mut pos = vec![0]; // pos 0 has no logits row to read
        assert!(
            decode_lockstep(seq_len, vocab, &mut seqs, &mut pos, &[1], &mut stepper).is_err()
        );
        let mut short = vec![vec![1, 0]];
        let mut pos = vec![1];
        assert!(
            decode_lockstep(seq_len, vocab, &mut short, &mut pos, &[1], &mut stepper).is_err()
        );
    }

    /// A stepper that records the protocol it is driven with: prefill
    /// exactly once, then steps whose `active` flags drop lanes the
    /// moment they finish (the retirement contract).
    struct Recording {
        vocab: usize,
        emit: Vec<Vec<i32>>, // per call, per lane
        calls: usize,
        active_log: Vec<Vec<bool>>,
        out: Vec<f32>,
    }

    impl DecodeStep for Recording {
        fn prefill(&mut self, seqs: &[Vec<i32>], _pos: &[usize]) -> anyhow::Result<&[f32]> {
            assert_eq!(self.calls, 0, "prefill must be the first and only first call");
            self.fill(seqs.len())
        }

        fn step(
            &mut self,
            seqs: &[Vec<i32>],
            _pos: &[usize],
            active: &[bool],
        ) -> anyhow::Result<&[f32]> {
            assert!(self.calls > 0, "step before prefill");
            self.active_log.push(active.to_vec());
            self.fill(seqs.len())
        }
    }

    impl Recording {
        fn fill(&mut self, lanes: usize) -> anyhow::Result<&[f32]> {
            let emit = &self.emit[self.calls.min(self.emit.len() - 1)];
            self.out.clear();
            self.out.resize(lanes * self.vocab, 0.0);
            for k in 0..lanes {
                self.out[k * self.vocab + emit[k] as usize] = 1.0;
            }
            self.calls += 1;
            Ok(&self.out)
        }
    }

    #[test]
    fn finished_lanes_are_deactivated_for_the_stepper() {
        let (seq_len, vocab) = (8, 16);
        // lane 0 emits EOS on the 2nd forward; lane 1 runs 4 tokens
        let mut stepper = Recording {
            vocab,
            emit: vec![vec![7, 9], vec![TOKENS::EOS, 9], vec![5, 9]],
            calls: 0,
            active_log: Vec::new(),
            out: Vec::new(),
        };
        let mut seqs = vec![vec![TOKENS::PAD; seq_len]; 2];
        seqs[0][0] = TOKENS::BOS;
        seqs[1][0] = TOKENS::BOS;
        let mut pos = vec![1, 1];
        let gen =
            decode_lockstep(seq_len, vocab, &mut seqs, &mut pos, &[4, 4], &mut stepper).unwrap();
        assert_eq!(gen[0], vec![7], "EOS on the second forward ends lane 0");
        assert_eq!(gen[1], vec![9, 9, 9, 9]);
        // steps 1.. : lane 0 goes inactive right after its EOS
        assert_eq!(stepper.active_log[0], vec![true, true]);
        for log in &stepper.active_log[1..] {
            assert_eq!(log, &vec![false, true], "finished lane must be handed over inactive");
        }
    }

    #[test]
    fn em_scoring_semantics() {
        // the EOS-stop + equality path, replicated inline
        let generated = vec![5, 6, TOKENS::EOS, 9];
        let cut: Vec<i32> = generated.iter().copied().take_while(|&t| t != TOKENS::EOS).collect();
        assert_eq!(cut, vec![5, 6]);
        assert_eq!(f64::from(cut == vec![5, 6]), 1.0);
        assert_eq!(f64::from(cut == vec![5, 7]), 0.0);
    }
}
