//! Batched greedy decoding + scoring.
//!
//! [`decode_lockstep`] is the **single** lock-step greedy-decode protocol
//! shared by the evaluator here and the serving pool
//! (`coordinator::pool`) — the two copies had drifted in budget/EOS
//! semantics, so the protocol now lives in one place:
//!
//! * every step runs one full-sequence forward over the whole batch
//!   (supplied by the caller as a closure, so merged-weight and
//!   factor-form execution share the loop);
//! * lane `k` generates until its budget is exhausted, the sequence is
//!   full, or greedy argmax emits EOS — EOS is written into the sequence
//!   but never returned as a generated token.

use super::rouge::rouge_l;
use super::tasks::{EvalSet, TOKENS};
use crate::model::ModelConfig;
use crate::runtime::{DeviceWeights, Engine};
use anyhow::bail;

/// Result of evaluating one adapter on one task.
#[derive(Debug, Clone)]
pub struct EvalOutcome {
    /// Task score in percent (EM rate or mean ROUGE-L × 100).
    pub score: f64,
    /// Per-example scores (0/1 for EM; ROUGE-L otherwise).
    pub per_example: Vec<f64>,
    /// Whether the metric was exact match.
    pub exact: bool,
}

/// Lock-step batched greedy decode over pre-seeded lanes.
///
/// * `seqs[k]` — the padded working sequence of lane `k` (`seq_len` long,
///   prompt already written at the front).
/// * `pos[k]` — the next write position (= prompt length, ≥ 1).
/// * `budgets[k]` — maximum new tokens (clamped to the sequence room).
/// * `step` — one full-sequence forward: flat tokens → flat logits
///   (`lanes · seq_len · vocab`).
///
/// Returns the generated tokens per lane, EOS excluded.
pub fn decode_lockstep(
    seq_len: usize,
    vocab: usize,
    seqs: &mut [Vec<i32>],
    pos: &mut [usize],
    budgets: &[usize],
    mut step: impl FnMut(&[i32]) -> anyhow::Result<Vec<f32>>,
) -> anyhow::Result<Vec<Vec<i32>>> {
    let lanes = seqs.len();
    if pos.len() != lanes || budgets.len() != lanes {
        bail!("decode_lockstep: {} lanes vs {} pos / {} budgets", lanes, pos.len(), budgets.len());
    }
    for k in 0..lanes {
        if seqs[k].len() != seq_len {
            bail!("decode_lockstep: lane {k} sequence is {} long, not {seq_len}", seqs[k].len());
        }
        if pos[k] == 0 || pos[k] > seq_len {
            bail!("decode_lockstep: lane {k} position {} out of range 1..={seq_len}", pos[k]);
        }
    }
    let mut generated: Vec<Vec<i32>> = vec![Vec::new(); lanes];
    // A lane is done once its (room-clamped) budget is spent.
    let mut done: Vec<bool> = (0..lanes)
        .map(|k| budgets[k].min(seq_len - pos[k]) == 0)
        .collect();
    while !done.iter().all(|&d| d) {
        let flat: Vec<i32> = seqs.iter().flatten().copied().collect();
        let logits = step(&flat)?;
        if logits.len() != lanes * seq_len * vocab {
            bail!(
                "decode_lockstep: step returned {} logits, expected {}",
                logits.len(),
                lanes * seq_len * vocab
            );
        }
        for k in 0..lanes {
            if done[k] {
                continue;
            }
            let base = (k * seq_len + pos[k] - 1) * vocab;
            let row = &logits[base..base + vocab];
            let mut best = 0usize;
            for v in 1..vocab {
                if row[v] > row[best] {
                    best = v;
                }
            }
            let tok = best as i32;
            seqs[k][pos[k]] = tok;
            pos[k] += 1;
            if tok == TOKENS::EOS {
                done[k] = true;
            } else {
                generated[k].push(tok);
                if generated[k].len() >= budgets[k] || pos[k] >= seq_len {
                    done[k] = true;
                }
            }
        }
    }
    Ok(generated)
}

/// Greedy-decode every example and score it (paper §4.1 protocol: the model
/// generates after SEP; EM for math/code analogs, ROUGE-L for the
/// summarization analog).
///
/// Decoding is batched through the `<model>/b<bucket>` program: examples are
/// packed `bucket` at a time (the final batch padded by repeating its last
/// example) and advanced via [`decode_lockstep`] with per-example budgets
/// of `|reference|` tokens.
pub fn evaluate(
    engine: &Engine,
    model: &str,
    bucket: usize,
    cfg: &ModelConfig,
    weights: &DeviceWeights,
    set: &EvalSet,
) -> anyhow::Result<EvalOutcome> {
    let prog = format!("{model}/b{bucket}");
    let t_len = cfg.seq_len;
    let vocab = cfg.vocab;
    let n = set.len();
    let mut per_example = Vec::with_capacity(n);

    let mut start = 0;
    while start < n {
        let idx: Vec<usize> = (0..bucket).map(|k| (start + k).min(n - 1)).collect();
        // working copies of the padded prompts
        let mut seqs: Vec<Vec<i32>> = idx.iter().map(|&i| set.prompts[i].clone()).collect();
        let mut pos: Vec<usize> = idx.iter().map(|&i| set.plens[i]).collect();
        // Generation protocol (matches train.py quick_eval): up to
        // |reference| tokens per example; generation past the model's own
        // EOS never scored anyway, so the lane stops there.
        let budgets: Vec<usize> = idx.iter().map(|&i| set.refs[i].len()).collect();
        let generated =
            decode_lockstep(t_len, vocab, &mut seqs, &mut pos, &budgets, |flat| {
                engine.forward(&prog, flat, &[bucket, t_len], weights)
            })?;
        // score the real (non-padding) examples of this batch
        for (k, &i) in idx.iter().enumerate() {
            if i < start {
                continue; // padded duplicate
            }
            if k > 0 && idx[k - 1] == i {
                continue;
            }
            let score = if set.exact {
                f64::from(generated[k] == set.refs[i])
            } else {
                rouge_l(&generated[k], &set.refs[i])
            };
            per_example.push(score);
        }
        start += bucket;
    }

    let score = 100.0 * per_example.iter().sum::<f64>() / per_example.len().max(1) as f64;
    Ok(EvalOutcome { score, per_example, exact: set.exact })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A scripted "model": always emits `next` as the argmax token.
    fn scripted_step(
        lanes: usize,
        seq_len: usize,
        vocab: usize,
        next: impl Fn(usize, usize) -> i32,
    ) -> impl FnMut(&[i32]) -> anyhow::Result<Vec<f32>> {
        let mut calls = 0usize;
        move |_flat| {
            let mut logits = vec![0.0f32; lanes * seq_len * vocab];
            for k in 0..lanes {
                for p in 0..seq_len {
                    let tok = next(k, calls) as usize;
                    logits[(k * seq_len + p) * vocab + tok] = 10.0;
                }
            }
            calls += 1;
            Ok(logits)
        }
    }

    #[test]
    fn budgets_and_eos_semantics() {
        let (seq_len, vocab) = (8, 16);
        // lane 0: emits 7 forever — stops at budget 3.
        // lane 1: emits 5 then EOS — returns [5], EOS excluded.
        let mut seqs = vec![vec![TOKENS::PAD; seq_len]; 2];
        seqs[0][0] = TOKENS::BOS;
        seqs[1][0] = TOKENS::BOS;
        let mut pos = vec![1, 1];
        let gen = decode_lockstep(
            seq_len,
            vocab,
            &mut seqs,
            &mut pos,
            &[3, 5],
            scripted_step(2, seq_len, vocab, |k, call| {
                if k == 0 {
                    7
                } else if call == 0 {
                    5
                } else {
                    TOKENS::EOS
                }
            }),
        )
        .unwrap();
        assert_eq!(gen[0], vec![7, 7, 7]);
        assert_eq!(gen[1], vec![5]);
        assert_eq!(pos, vec![4, 3], "EOS is written into the sequence");
        assert_eq!(seqs[1][2], TOKENS::EOS);
    }

    #[test]
    fn budget_clamped_to_sequence_room() {
        let (seq_len, vocab) = (4, 8);
        let mut seqs = vec![vec![TOKENS::PAD; seq_len]];
        seqs[0][..3].copy_from_slice(&[1, 5, 3]);
        let mut pos = vec![3];
        let gen = decode_lockstep(
            seq_len,
            vocab,
            &mut seqs,
            &mut pos,
            &[100],
            scripted_step(1, seq_len, vocab, |_, _| 6),
        )
        .unwrap();
        assert_eq!(gen[0], vec![6], "only one slot of room");
        assert_eq!(pos[0], seq_len);
    }

    #[test]
    fn zero_budget_runs_no_forward() {
        let (seq_len, vocab) = (4, 8);
        let mut seqs = vec![vec![1, 0, 0, 0]];
        let mut pos = vec![1];
        let gen = decode_lockstep(seq_len, vocab, &mut seqs, &mut pos, &[0], |_flat| {
            panic!("no forward may run when every budget is zero")
        })
        .unwrap();
        assert!(gen[0].is_empty());
    }

    #[test]
    fn rejects_malformed_lanes() {
        let (seq_len, vocab) = (4, 8);
        let step = |_: &[i32]| -> anyhow::Result<Vec<f32>> { unreachable!() };
        let mut seqs = vec![vec![1, 0, 0, 0]];
        let mut pos = vec![0]; // pos 0 has no logits row to read
        assert!(decode_lockstep(seq_len, vocab, &mut seqs, &mut pos, &[1], step).is_err());
        let mut short = vec![vec![1, 0]];
        let mut pos = vec![1];
        assert!(decode_lockstep(seq_len, vocab, &mut short, &mut pos, &[1], step).is_err());
    }

    #[test]
    fn em_scoring_semantics() {
        // the EOS-stop + equality path, replicated inline
        let generated = vec![5, 6, TOKENS::EOS, 9];
        let cut: Vec<i32> = generated.iter().copied().take_while(|&t| t != TOKENS::EOS).collect();
        assert_eq!(cut, vec![5, 6]);
        assert_eq!(f64::from(cut == vec![5, 6]), 1.0);
        assert_eq!(f64::from(cut == vec![5, 7]), 0.0);
    }
}
