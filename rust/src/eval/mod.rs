//! Task evaluation harness: eval-set loading, greedy decoding through the
//! PJRT runtime, and the paper's metrics (exact match for math/code-style
//! tasks, ROUGE-L for summarization-style tasks).

pub mod decode;
pub mod rouge;
pub mod tasks;

pub use decode::{
    consume_greedy, decode_lockstep, evaluate, DecodeStep, EngineStepper, EvalOutcome,
    FullRecompute,
};
pub use rouge::rouge_l;
pub use tasks::{EvalSet, TOKENS};
