//! ROUGE-L (Lin, 2004): LCS-based F-measure over token sequences — the
//! paper's summarization metric.

/// Longest common subsequence length (O(mn) DP, single row).
pub fn lcs_len(a: &[i32], b: &[i32]) -> usize {
    if a.is_empty() || b.is_empty() {
        return 0;
    }
    let mut prev = vec![0usize; b.len() + 1];
    let mut cur = vec![0usize; b.len() + 1];
    for &x in a {
        for (j, &y) in b.iter().enumerate() {
            cur[j + 1] = if x == y { prev[j] + 1 } else { cur[j].max(prev[j + 1]) };
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// ROUGE-L F1 between a candidate and a reference (β = 1).
pub fn rouge_l(candidate: &[i32], reference: &[i32]) -> f64 {
    if candidate.is_empty() || reference.is_empty() {
        return if candidate.is_empty() && reference.is_empty() { 1.0 } else { 0.0 };
    }
    let lcs = lcs_len(candidate, reference) as f64;
    if lcs == 0.0 {
        return 0.0;
    }
    let p = lcs / candidate.len() as f64;
    let r = lcs / reference.len() as f64;
    2.0 * p * r / (p + r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_sequences() {
        assert_eq!(rouge_l(&[1, 2, 3], &[1, 2, 3]), 1.0);
    }

    #[test]
    fn disjoint_sequences() {
        assert_eq!(rouge_l(&[1, 2], &[3, 4]), 0.0);
    }

    #[test]
    fn known_lcs() {
        // LCS("abcde", "ace") = 3
        assert_eq!(lcs_len(&[1, 2, 3, 4, 5], &[1, 3, 5]), 3);
        let f = rouge_l(&[1, 3, 5], &[1, 2, 3, 4, 5]);
        // p = 1, r = 0.6 -> F1 = 0.75
        assert!((f - 0.75).abs() < 1e-9);
    }

    #[test]
    fn order_sensitivity() {
        // reversal destroys subsequence structure
        let f = rouge_l(&[3, 2, 1], &[1, 2, 3]);
        assert!(f < 0.5);
    }

    #[test]
    fn empty_edge_cases() {
        assert_eq!(rouge_l(&[], &[]), 1.0);
        assert_eq!(rouge_l(&[], &[1]), 0.0);
        assert_eq!(rouge_l(&[1], &[]), 0.0);
    }

    #[test]
    fn lcs_is_symmetric_and_bounded() {
        let cases: [(&[i32], &[i32]); 4] = [
            (&[1, 2, 3, 4], &[2, 4, 1]),
            (&[5, 5, 5], &[5, 5]),
            (&[1, 3, 5, 7, 9], &[9, 7, 5, 3, 1]),
            (&[6], &[1, 2, 6, 3]),
        ];
        for (a, b) in cases {
            let l = lcs_len(a, b);
            assert_eq!(l, lcs_len(b, a), "LCS must be symmetric");
            assert!(l <= a.len().min(b.len()), "LCS can never exceed the shorter input");
        }
        // reversal of a strictly increasing sequence shares exactly one
        // element as a subsequence
        assert_eq!(lcs_len(&[1, 3, 5, 7, 9], &[9, 7, 5, 3, 1]), 1);
    }

    #[test]
    fn lcs_finds_non_contiguous_subsequences() {
        // the classic: LCS("ABCBDAB", "BDCABA") = 4 ("BCAB")
        let a = [1, 2, 3, 2, 4, 1, 2];
        let b = [2, 4, 3, 1, 2, 1];
        assert_eq!(lcs_len(&a, &b), 4);
    }

    #[test]
    fn rouge_is_symmetric_and_in_unit_interval() {
        // β = 1: precision and recall swap roles under argument swap, so
        // the F-measure is symmetric.
        let cases: [(&[i32], &[i32]); 3] =
            [(&[1, 2, 3], &[1, 3]), (&[4, 4, 4], &[4]), (&[1, 2], &[3, 1, 2, 4])];
        for (a, b) in cases {
            let f = rouge_l(a, b);
            assert!((0.0..=1.0).contains(&f), "F1 {f} out of range");
            assert!((f - rouge_l(b, a)).abs() < 1e-12, "F1 must be symmetric");
        }
    }

    #[test]
    fn rouge_rewards_longer_overlap() {
        // against reference [1,2,3,4]: growing the matching prefix of the
        // candidate must never lower the score
        let reference = [1, 2, 3, 4];
        let mut prev = 0.0;
        for k in 1..=4 {
            let f = rouge_l(&reference[..k], &reference);
            assert!(f >= prev, "score must grow with overlap: {f} < {prev} at k={k}");
            prev = f;
        }
        assert_eq!(prev, 1.0);
    }

    #[test]
    fn rouge_known_value_precision_recall() {
        // candidate [1,2,9,9]: LCS=2, p=0.5, r=2/3 → F1 = 4/7
        let f = rouge_l(&[1, 2, 9, 9], &[1, 2, 3]);
        assert!((f - 4.0 / 7.0).abs() < 1e-12, "got {f}");
    }
}
