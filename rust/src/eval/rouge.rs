//! ROUGE-L (Lin, 2004): LCS-based F-measure over token sequences — the
//! paper's summarization metric.

/// Longest common subsequence length (O(mn) DP, single row).
pub fn lcs_len(a: &[i32], b: &[i32]) -> usize {
    if a.is_empty() || b.is_empty() {
        return 0;
    }
    let mut prev = vec![0usize; b.len() + 1];
    let mut cur = vec![0usize; b.len() + 1];
    for &x in a {
        for (j, &y) in b.iter().enumerate() {
            cur[j + 1] = if x == y { prev[j] + 1 } else { cur[j].max(prev[j + 1]) };
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// ROUGE-L F1 between a candidate and a reference (β = 1).
pub fn rouge_l(candidate: &[i32], reference: &[i32]) -> f64 {
    if candidate.is_empty() || reference.is_empty() {
        return if candidate.is_empty() && reference.is_empty() { 1.0 } else { 0.0 };
    }
    let lcs = lcs_len(candidate, reference) as f64;
    if lcs == 0.0 {
        return 0.0;
    }
    let p = lcs / candidate.len() as f64;
    let r = lcs / reference.len() as f64;
    2.0 * p * r / (p + r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_sequences() {
        assert_eq!(rouge_l(&[1, 2, 3], &[1, 2, 3]), 1.0);
    }

    #[test]
    fn disjoint_sequences() {
        assert_eq!(rouge_l(&[1, 2], &[3, 4]), 0.0);
    }

    #[test]
    fn known_lcs() {
        // LCS("abcde", "ace") = 3
        assert_eq!(lcs_len(&[1, 2, 3, 4, 5], &[1, 3, 5]), 3);
        let f = rouge_l(&[1, 3, 5], &[1, 2, 3, 4, 5]);
        // p = 1, r = 0.6 -> F1 = 0.75
        assert!((f - 0.75).abs() < 1e-9);
    }

    #[test]
    fn order_sensitivity() {
        // reversal destroys subsequence structure
        let f = rouge_l(&[3, 2, 1], &[1, 2, 3]);
        assert!(f < 0.5);
    }

    #[test]
    fn empty_edge_cases() {
        assert_eq!(rouge_l(&[], &[]), 1.0);
        assert_eq!(rouge_l(&[], &[1]), 0.0);
        assert_eq!(rouge_l(&[1], &[]), 0.0);
    }
}
