//! Synthetic task vocabulary + eval-set container (mirrors
//! python/compile/tasks.py — token ids are a cross-layer contract).

use crate::adapter::fmt::load_tensorfile;
use anyhow::Context;
use std::path::Path;

/// Token id constants shared with python/compile/tasks.py.
pub mod TOKENS {
    #![allow(non_snake_case)]
    pub const PAD: i32 = 0;
    pub const BOS: i32 = 1;
    pub const EOS: i32 = 2;
    pub const SEP: i32 = 3;
    pub const MARK: i32 = 4;
    pub const DIGIT0: i32 = 5;
    pub const LETTER0: i32 = 15;
    pub const OP0: i32 = 31;
    pub const VOCAB: usize = 64;
    pub const SEQ_LEN: usize = 32;
}

/// The task names of the evaluation grid, in paper column order
/// (math, math-hard, code, summarization analogs).
pub const TASKS: [&str; 4] = ["modadd", "modchain", "transform", "keyword"];

/// A held-out eval set exported by train.py (`<task>.eval.bin`).
#[derive(Debug, Clone)]
pub struct EvalSet {
    /// Prompts, padded to SEQ_LEN: `[BOS, prompt..., SEP, PAD...]`.
    pub prompts: Vec<Vec<i32>>,
    /// Prompt lengths (generation starts at this index).
    pub plens: Vec<usize>,
    /// Reference answers (unpadded).
    pub refs: Vec<Vec<i32>>,
    /// true ⇒ exact match; false ⇒ ROUGE-L.
    pub exact: bool,
}

impl EvalSet {
    /// Load from a tensorfile.
    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let t = load_tensorfile(&path)?;
        let prompts_t = t.get("prompts").context("eval set missing prompts")?;
        let n = prompts_t.dims[0];
        let tlen = prompts_t.dims[1];
        let flat = prompts_t.as_i32()?;
        let prompts = (0..n).map(|i| flat[i * tlen..(i + 1) * tlen].to_vec()).collect();
        let plens: Vec<usize> =
            t["plens"].as_i32()?.iter().map(|&x| x as usize).collect();
        let rflat = t["refs"].as_i32()?;
        let rlen = t["refs"].dims[1];
        let rlens: Vec<usize> = t["rlens"].as_i32()?.iter().map(|&x| x as usize).collect();
        let refs = (0..n).map(|i| rflat[i * rlen..i * rlen + rlens[i]].to_vec()).collect();
        let exact = t["exact"].as_i32()?[0] == 1;
        Ok(Self { prompts, plens, refs, exact })
    }

    pub fn len(&self) -> usize {
        self.prompts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.prompts.is_empty()
    }

    /// Truncate to the first `n` examples (faster sweeps).
    pub fn truncated(&self, n: usize) -> Self {
        let n = n.min(self.len());
        Self {
            prompts: self.prompts[..n].to_vec(),
            plens: self.plens[..n].to_vec(),
            refs: self.refs[..n].to_vec(),
            exact: self.exact,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::fmt::{save_tensorfile, Tensor};
    use std::collections::BTreeMap;

    #[test]
    fn load_roundtrip() {
        let mut t = BTreeMap::new();
        t.insert("prompts".into(), Tensor::i32(vec![2, 4], vec![1, 5, 3, 0, 1, 6, 3, 0]));
        t.insert("plens".into(), Tensor::i32(vec![2], vec![3, 3]));
        t.insert("refs".into(), Tensor::i32(vec![2, 4], vec![7, 0, 0, 0, 8, 9, 0, 0]));
        t.insert("rlens".into(), Tensor::i32(vec![2], vec![1, 2]));
        t.insert("exact".into(), Tensor::i32(vec![1], vec![1]));
        let tmp = std::env::temp_dir().join("lq_eval_test.bin");
        save_tensorfile(&tmp, &t).unwrap();
        let es = EvalSet::load(&tmp).unwrap();
        assert_eq!(es.len(), 2);
        assert_eq!(es.refs[1], vec![8, 9]);
        assert!(es.exact);
        assert_eq!(es.truncated(1).len(), 1);
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn token_contract_matches_python_layout() {
        // The token id layout is a cross-layer contract with
        // python/compile/tasks.py — pin it.
        assert_eq!((TOKENS::PAD, TOKENS::BOS, TOKENS::EOS, TOKENS::SEP, TOKENS::MARK), (0, 1, 2, 3, 4));
        assert_eq!(TOKENS::DIGIT0, 5);
        assert_eq!(TOKENS::LETTER0, 15, "10 digits after DIGIT0");
        assert_eq!(TOKENS::OP0, 31, "16 letters after LETTER0");
        // every named range fits the vocabulary
        assert!(TOKENS::OP0 + 4 < TOKENS::VOCAB as i32);
        assert_eq!(TOKENS::VOCAB, 64);
        assert_eq!(TOKENS::SEQ_LEN, 32);
        assert_eq!(TASKS.len(), 4);
    }

    #[test]
    fn rouge_metric_flag_and_empty_reference() {
        // exact = 0 ⇒ ROUGE-L scoring; a zero-length reference row must
        // load as an empty answer, not a slice panic.
        let mut t = BTreeMap::new();
        t.insert("prompts".into(), Tensor::i32(vec![1, 4], vec![1, 5, 3, 0]));
        t.insert("plens".into(), Tensor::i32(vec![1], vec![3]));
        t.insert("refs".into(), Tensor::i32(vec![1, 4], vec![7, 8, 0, 0]));
        t.insert("rlens".into(), Tensor::i32(vec![1], vec![0]));
        t.insert("exact".into(), Tensor::i32(vec![1], vec![0]));
        let tmp = std::env::temp_dir().join("lq_eval_test_rouge.bin");
        save_tensorfile(&tmp, &t).unwrap();
        let es = EvalSet::load(&tmp).unwrap();
        assert!(!es.exact);
        assert!(es.refs[0].is_empty());
        assert!(!es.is_empty());
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn truncation_clamps_and_preserves_alignment() {
        let mut t = BTreeMap::new();
        t.insert("prompts".into(), Tensor::i32(vec![3, 4], vec![1, 5, 3, 0, 1, 6, 3, 0, 1, 7, 3, 0]));
        t.insert("plens".into(), Tensor::i32(vec![3], vec![3, 3, 3]));
        t.insert("refs".into(), Tensor::i32(vec![3, 2], vec![7, 0, 8, 9, 6, 0]));
        t.insert("rlens".into(), Tensor::i32(vec![3], vec![1, 2, 1]));
        t.insert("exact".into(), Tensor::i32(vec![1], vec![1]));
        let tmp = std::env::temp_dir().join("lq_eval_test_trunc.bin");
        save_tensorfile(&tmp, &t).unwrap();
        let es = EvalSet::load(&tmp).unwrap();
        // truncation past the end clamps to the full set
        assert_eq!(es.truncated(99).len(), 3);
        let cut = es.truncated(2);
        assert_eq!(cut.len(), 2);
        assert_eq!(cut.prompts[1], vec![1, 6, 3, 0]);
        assert_eq!(cut.refs[1], vec![8, 9], "prompt/ref alignment preserved");
        assert_eq!(cut.plens, vec![3, 3]);
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn missing_prompts_key_is_a_clean_error() {
        let mut t = BTreeMap::new();
        t.insert("plens".into(), Tensor::i32(vec![1], vec![1]));
        let tmp = std::env::temp_dir().join("lq_eval_test_bad.bin");
        save_tensorfile(&tmp, &t).unwrap();
        let err = EvalSet::load(&tmp).unwrap_err();
        assert!(err.to_string().contains("prompts"), "got: {err}");
        std::fs::remove_file(tmp).ok();
    }
}
