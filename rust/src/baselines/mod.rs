//! Quantization baselines from the paper's Table 1 (Rows 2–8) and Fig. 3.
//!
//! Every baseline implements [`Quantizer`]: it consumes one adapter matrix
//! pair `(B m×r, A r×n)` and yields a dequantizable compressed form with
//! Eq. 10 bit accounting — so the bench harness can run the whole method
//! grid uniformly.
//!
//! | Table 1 row | type |
//! |---|---|
//! | BIN | [`FlatQuantizer`] sign-binarization of B and A |
//! | RTN (1/2 bits) | [`FlatQuantizer`] group-wise RTN of B and A |
//! | GPTQ (2 bits) | [`Gptq`] — Hessian-guided error compensation |
//! | PB-LLM | [`PbLlm`] — salient weights int8 + indicator bit, rest binary |
//! | BiLLM | [`BiLlm`] — salient columns residual-binarized, rest split-binary |
//! | JD-Diagonal | [`jd::JdDiagonal`] — shared basis + per-adapter diagonal |
//! | LoRAQuant | [`crate::loraquant`] (the paper's method) |

pub mod billm;
pub mod flat;
pub mod gptq;
pub mod jd;
pub mod pbllm;

pub use billm::BiLlm;
pub use flat::{FlatKind, FlatQuantizer};
pub use gptq::Gptq;
pub use jd::JdDiagonal;
pub use pbllm::PbLlm;

use crate::tensor::Matrix;

/// A compressed adapter pair that can be dequantized back to a delta.
pub trait CompressedPair: std::fmt::Debug {
    /// Dequantized `ΔW = B̂ Â` (m×n).
    fn dequant_delta(&self) -> Matrix;
    /// Eq. 10 numerator (bits), including scales/zero-points/indicators.
    fn storage_bits(&self) -> u64;
    /// Original LoRA parameter count `r(m+n)`.
    fn param_count(&self) -> usize;
    /// Average bits per original parameter.
    fn avg_bits(&self) -> f64 {
        self.storage_bits() as f64 / self.param_count() as f64
    }
}

/// A baseline quantization method over one adapter pair.
pub trait Quantizer {
    /// Human-readable method name (Table 1 row label).
    fn name(&self) -> String;
    /// Compress one adapter pair. `calib` is the per-site input-activation
    /// sample (rows = tokens) used by Hessian-based methods; identity
    /// statistics are assumed when absent.
    fn quantize(&self, b: &Matrix, a: &Matrix, calib: Option<&Matrix>) -> Box<dyn CompressedPair>;
}
