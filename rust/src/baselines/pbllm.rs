//! PB-LLM (Shang et al., 2024) applied to LoRA factors (Table 1 row 7).
//!
//! Partially-binarized quantization: the top `salient_frac` of weights **by
//! magnitude** keep an 8-bit RTN representation, the rest are sign-binarized
//! group-wise. Because salient weights are scattered, every weight carries a
//! 1-bit membership indicator — the overhead the paper criticizes
//! (Table 1 shows 2.83 avg bits at 10% salient).

use super::{CompressedPair, Quantizer};
use crate::quant::{rtn_dequant, rtn_quant, SCALE_BITS};
use crate::tensor::{matmul, Matrix};

/// PB-LLM configuration.
#[derive(Debug, Clone, Copy)]
pub struct PbLlm {
    /// Fraction of weights kept at `salient_bits` (paper setup: 0.1).
    pub salient_frac: f32,
    /// Bitwidth of salient weights (8-bit RTN).
    pub salient_bits: u32,
    pub group: usize,
}

impl Default for PbLlm {
    fn default() -> Self {
        Self { salient_frac: 0.1, salient_bits: 8, group: 128 }
    }
}

/// One PB-LLM-compressed factor.
#[derive(Debug)]
struct PbFactor {
    deq: Matrix,
    bits: u64,
}

fn compress_factor(w: &Matrix, cfg: &PbLlm) -> PbFactor {
    let (rows, cols) = w.shape();
    let count = rows * cols;
    // global magnitude threshold for saliency
    let mut mags: Vec<f32> = w.data().iter().map(|v| v.abs()).collect();
    let k = ((count as f32 * cfg.salient_frac) as usize).min(count.saturating_sub(1));
    mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let thresh = if k == 0 { f32::INFINITY } else { mags[k - 1] };

    let mut deq = Matrix::zeros(rows, cols);
    let mut n_salient = 0usize;
    let gpr = cols.div_ceil(cfg.group);
    // Salient weights: 8-bit RTN over the salient set per row-group;
    // non-salient: sign-binarized with L1 scale over the non-salient set.
    for i in 0..rows {
        for g in 0..gpr {
            let lo_j = g * cfg.group;
            let hi_j = ((g + 1) * cfg.group).min(cols);
            // partition the group
            let mut sal: Vec<(usize, f32)> = Vec::new();
            let mut rest: Vec<(usize, f32)> = Vec::new();
            for j in lo_j..hi_j {
                let v = w.at(i, j);
                if v.abs() >= thresh {
                    sal.push((j, v));
                } else {
                    rest.push((j, v));
                }
            }
            n_salient += sal.len();
            if !sal.is_empty() {
                let vals: Vec<f32> = sal.iter().map(|&(_, v)| v).collect();
                let m = Matrix::from_vec(1, vals.len(), vals);
                let dq = rtn_dequant(&rtn_quant(&m, cfg.salient_bits, cfg.group));
                for (t, &(j, _)) in sal.iter().enumerate() {
                    deq.set(i, j, dq.at(0, t));
                }
            }
            if !rest.is_empty() {
                let s = rest.iter().map(|&(_, v)| v.abs()).sum::<f32>() / rest.len() as f32;
                for &(j, v) in &rest {
                    deq.set(i, j, if v >= 0.0 { s } else { -s });
                }
            }
        }
    }
    // Eq. 10 accounting: 1 indicator/weight + 1 bit per binarized weight +
    // salient_bits per salient + per-group: one binary scale (fp16) and one
    // RTN scale+zero (fp16 + salient_bits).
    let groups = (rows * gpr) as u64;
    let bits = count as u64 // indicators
        + (count - n_salient) as u64
        + n_salient as u64 * cfg.salient_bits as u64
        + groups * SCALE_BITS
        + groups * (SCALE_BITS + cfg.salient_bits as u64);
    PbFactor { deq, bits }
}

/// Compressed pair produced by [`PbLlm`].
#[derive(Debug)]
pub struct PbCompressed {
    b: PbFactor,
    a: PbFactor,
    params: usize,
}

impl CompressedPair for PbCompressed {
    fn dequant_delta(&self) -> Matrix {
        matmul(&self.b.deq.transpose(), &self.a.deq)
    }

    fn storage_bits(&self) -> u64 {
        self.b.bits + self.a.bits
    }

    fn param_count(&self) -> usize {
        self.params
    }
}

impl Quantizer for PbLlm {
    fn name(&self) -> String {
        "PBLLM".to_string()
    }

    fn quantize(&self, b: &Matrix, a: &Matrix, _calib: Option<&Matrix>) -> Box<dyn CompressedPair> {
        // B compressed column-wise (transposed) so groups/saliency run
        // along the long m axis — see DESIGN.md §7.
        Box::new(PbCompressed {
            b: compress_factor(&b.transpose(), self),
            a: compress_factor(a, self),
            params: b.len() + a.len(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::FlatQuantizer;
    use crate::testutil::Rng;

    #[test]
    fn beats_pure_binarization() {
        let mut rng = Rng::new(111);
        let (b, a) = rng.lora_pair(64, 128, 16, 0.7);
        let ba = matmul(&b, &a);
        let e_pb = PbLlm::default().quantize(&b, &a, None).dequant_delta().rel_err(&ba);
        let e_bin = FlatQuantizer::bin(128).quantize(&b, &a, None).dequant_delta().rel_err(&ba);
        assert!(e_pb < e_bin, "pbllm {e_pb} vs bin {e_bin}");
    }

    #[test]
    fn avg_bits_in_paper_range() {
        let mut rng = Rng::new(112);
        let (b, a) = rng.lora_pair(128, 128, 16, 0.7);
        let q = PbLlm::default().quantize(&b, &a, None);
        // paper reports 2.83 for this setup; 16-row LoRA factors pay extra
        // per-group scale overhead (DESIGN.md §7)
        assert!(
            (q.avg_bits() - 2.9).abs() < 0.3,
            "avg bits {} should be ~2.83-3.0",
            q.avg_bits()
        );
    }

    #[test]
    fn salient_zero_frac_degenerates_to_binary_plus_indicator() {
        let mut rng = Rng::new(113);
        let (b, a) = rng.lora_pair(32, 64, 8, 0.7);
        let cfg = PbLlm { salient_frac: 0.0, ..Default::default() };
        let q = cfg.quantize(&b, &a, None);
        let e_bin = FlatQuantizer::bin(128).quantize(&b, &a, None).dequant_delta();
        assert!(q.dequant_delta().sub(&e_bin).fro_norm() < 1e-5);
    }
}
