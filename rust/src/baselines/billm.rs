//! BiLLM (Huang et al., 2024) applied to LoRA factors (Table 1 row 8).
//!
//! Structured mixed binarization:
//! * **salient columns** (top fraction by column L2 norm — structural, so no
//!   per-weight indicator; a negligible column bitmap instead) are binarized
//!   twice: a first sign pass plus a sign pass on the residual ("residual
//!   approximation", ≈2 effective bits);
//! * **non-salient columns** use *split binarization*: each group is split
//!   into a low-magnitude and a high-magnitude half with separate scales,
//!   which needs a 1-bit group-membership indicator per weight (the extra
//!   bit the paper calls out).

use super::{CompressedPair, Quantizer};
use crate::quant::SCALE_BITS;
use crate::tensor::{matmul, norm2, Matrix};

/// BiLLM configuration.
#[derive(Debug, Clone, Copy)]
pub struct BiLlm {
    /// Fraction of columns treated as salient (paper setup: ~0.1).
    pub salient_frac: f32,
    pub group: usize,
}

impl Default for BiLlm {
    fn default() -> Self {
        Self { salient_frac: 0.1, group: 128 }
    }
}

#[derive(Debug)]
struct BiFactor {
    deq: Matrix,
    bits: u64,
}

/// Sign-binarize a slice with L1-optimal scale; returns reconstruction.
fn binarize(vals: &[f32]) -> Vec<f32> {
    if vals.is_empty() {
        return vec![];
    }
    let s = vals.iter().map(|v| v.abs()).sum::<f32>() / vals.len() as f32;
    vals.iter().map(|v| if *v >= 0.0 { s } else { -s }).collect()
}

fn compress_factor(w: &Matrix, cfg: &BiLlm) -> BiFactor {
    let (rows, cols) = w.shape();
    // 1) salient columns by L2 norm
    let mut scored: Vec<(usize, f32)> = (0..cols).map(|j| (j, norm2(&w.col(j)))).collect();
    scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let n_sal = ((cols as f32 * cfg.salient_frac).round() as usize).min(cols);
    let salient: std::collections::BTreeSet<usize> =
        scored.iter().take(n_sal).map(|&(j, _)| j).collect();

    let mut deq = Matrix::zeros(rows, cols);
    let mut bits = cols as u64; // column bitmap
    let gpr = cols.div_ceil(cfg.group);

    for i in 0..rows {
        // --- salient: residual double binarization, per row over salient set
        let sal_idx: Vec<usize> = salient.iter().copied().collect();
        let sal_vals: Vec<f32> = sal_idx.iter().map(|&j| w.at(i, j)).collect();
        if !sal_vals.is_empty() {
            let first = binarize(&sal_vals);
            let resid: Vec<f32> = sal_vals.iter().zip(&first).map(|(v, f)| v - f).collect();
            let second = binarize(&resid);
            for (t, &j) in sal_idx.iter().enumerate() {
                deq.set(i, j, first[t] + second[t]);
            }
        }
        // --- non-salient: split binarization per group
        for g in 0..gpr {
            let lo_j = g * cfg.group;
            let hi_j = ((g + 1) * cfg.group).min(cols);
            let idx: Vec<usize> = (lo_j..hi_j).filter(|j| !salient.contains(j)).collect();
            if idx.is_empty() {
                continue;
            }
            let vals: Vec<f32> = idx.iter().map(|&j| w.at(i, j)).collect();
            // split by magnitude at the group median |w|
            let mut mags: Vec<f32> = vals.iter().map(|v| v.abs()).collect();
            mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let median = mags[mags.len() / 2];
            let (mut lo_set, mut hi_set) = (Vec::new(), Vec::new());
            for (t, v) in vals.iter().enumerate() {
                if v.abs() < median {
                    lo_set.push((t, *v));
                } else {
                    hi_set.push((t, *v));
                }
            }
            for set in [&lo_set, &hi_set] {
                let rec = binarize(&set.iter().map(|&(_, v)| v).collect::<Vec<_>>());
                for (&(t, _), r) in set.iter().zip(&rec) {
                    deq.set(i, idx[t], *r);
                }
            }
        }
    }

    // Eq. 10 accounting:
    // salient: 2 sign bits/weight + 2 fp16 scales per (row, group-of-salient)
    let n_sal_w = (rows * n_sal) as u64;
    let sal_groups = (rows * n_sal.div_ceil(cfg.group).max(usize::from(n_sal > 0))) as u64;
    bits += 2 * n_sal_w + sal_groups * 2 * SCALE_BITS;
    // non-salient: 1 sign + 1 membership bit per weight + 2 fp16 scales/group
    let n_rest_w = (rows * (cols - n_sal)) as u64;
    bits += 2 * n_rest_w + (rows * gpr) as u64 * 2 * SCALE_BITS;
    BiFactor { deq, bits }
}

/// Compressed pair produced by [`BiLlm`].
#[derive(Debug)]
pub struct BiCompressed {
    b: BiFactor,
    a: BiFactor,
    params: usize,
}

impl CompressedPair for BiCompressed {
    fn dequant_delta(&self) -> Matrix {
        matmul(&self.b.deq.transpose(), &self.a.deq)
    }

    fn storage_bits(&self) -> u64 {
        self.b.bits + self.a.bits
    }

    fn param_count(&self) -> usize {
        self.params
    }
}

impl Quantizer for BiLlm {
    fn name(&self) -> String {
        "BiLLM".to_string()
    }

    fn quantize(&self, b: &Matrix, a: &Matrix, _calib: Option<&Matrix>) -> Box<dyn CompressedPair> {
        // B compressed column-wise (transposed): salient "columns" of B are
        // its rank components' long m-axis slices — see DESIGN.md §7.
        Box::new(BiCompressed {
            b: compress_factor(&b.transpose(), self),
            a: compress_factor(a, self),
            params: b.len() + a.len(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::FlatQuantizer;
    use crate::testutil::Rng;

    #[test]
    fn beats_pure_binarization() {
        let mut rng = Rng::new(121);
        let (b, a) = rng.lora_pair(64, 128, 16, 0.7);
        let ba = matmul(&b, &a);
        let e_bi = BiLlm::default().quantize(&b, &a, None).dequant_delta().rel_err(&ba);
        let e_bin = FlatQuantizer::bin(128).quantize(&b, &a, None).dequant_delta().rel_err(&ba);
        assert!(e_bi < e_bin, "billm {e_bi} vs bin {e_bin}");
    }

    #[test]
    fn avg_bits_near_paper() {
        let mut rng = Rng::new(122);
        let (b, a) = rng.lora_pair(128, 128, 16, 0.7);
        let q = BiLlm::default().quantize(&b, &a, None);
        // paper reports 2.24 at group 128; our adapters' 16-row factors pay
        // proportionally more fp16-scale overhead, so allow a wider band
        assert!((q.avg_bits() - 2.4).abs() < 0.45, "avg bits {}", q.avg_bits());
    }

    #[test]
    fn residual_binarization_refines_salient() {
        let v = [3.0f32, -1.0, 2.0, -2.5];
        let first = binarize(&v);
        let resid: Vec<f32> = v.iter().zip(&first).map(|(a, b)| a - b).collect();
        let second = binarize(&resid);
        let rec: Vec<f32> = first.iter().zip(&second).map(|(a, b)| a + b).collect();
        let e1: f32 = v.iter().zip(&first).map(|(a, b)| (a - b).powi(2)).sum();
        let e2: f32 = v.iter().zip(&rec).map(|(a, b)| (a - b).powi(2)).sum();
        assert!(e2 < e1);
    }
}
