//! Flat (structure-oblivious) baselines: group-wise RTN at k bits and sign
//! binarization, applied directly to the B and A factors (Table 1 rows
//! 2, 3, 5 — "BIN", "RTN (1 bit)", "RTN (2 bits)").

use super::{CompressedPair, Quantizer};
use crate::quant::{bin_dequant, bin_quant, rtn_dequant, rtn_quant, BinQuantized, RtnQuantized};
use crate::tensor::{matmul, Matrix};

/// Which flat method.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlatKind {
    /// Group-wise RTN at `bits`.
    Rtn { bits: u32 },
    /// Sign binarization (1 bit).
    Bin,
}

/// Flat quantizer over both factors, row-wise grouping.
#[derive(Debug, Clone, Copy)]
pub struct FlatQuantizer {
    pub kind: FlatKind,
    pub group: usize,
}

impl FlatQuantizer {
    pub fn rtn(bits: u32, group: usize) -> Self {
        Self { kind: FlatKind::Rtn { bits }, group }
    }

    pub fn bin(group: usize) -> Self {
        Self { kind: FlatKind::Bin, group }
    }
}

#[derive(Debug)]
enum Factor {
    Rtn(RtnQuantized),
    Bin(BinQuantized),
}

impl Factor {
    fn dequant(&self) -> Matrix {
        match self {
            Factor::Rtn(q) => rtn_dequant(q),
            Factor::Bin(q) => bin_dequant(q),
        }
    }

    fn bits(&self) -> u64 {
        match self {
            Factor::Rtn(q) => q.storage_bits(),
            Factor::Bin(q) => q.storage_bits(),
        }
    }
}

/// Compressed pair produced by [`FlatQuantizer`].
#[derive(Debug)]
pub struct FlatCompressed {
    b: Factor,
    a: Factor,
    params: usize,
}

impl CompressedPair for FlatCompressed {
    fn dequant_delta(&self) -> Matrix {
        // b was stored transposed (column-wise quantization)
        matmul(&self.b.dequant().transpose(), &self.a.dequant())
    }

    fn storage_bits(&self) -> u64 {
        self.b.bits() + self.a.bits()
    }

    fn param_count(&self) -> usize {
        self.params
    }
}

impl Quantizer for FlatQuantizer {
    fn name(&self) -> String {
        match self.kind {
            FlatKind::Rtn { bits } => format!("RTN ({bits} bit{})", if bits > 1 { "s" } else { "" }),
            FlatKind::Bin => "BIN".to_string(),
        }
    }

    fn quantize(&self, b: &Matrix, a: &Matrix, _calib: Option<&Matrix>) -> Box<dyn CompressedPair> {
        let params = b.len() + a.len();
        let q = |w: &Matrix| match self.kind {
            FlatKind::Rtn { bits } => Factor::Rtn(rtn_quant(w, bits, self.group)),
            FlatKind::Bin => Factor::Bin(bin_quant(w, self.group)),
        };
        // B is quantized column-wise (transposed): groups run along the long
        // m axis, matching the paper's App. B default and its bit economics.
        Box::new(FlatCompressed { b: q(&b.transpose()), a: q(a), params })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;

    #[test]
    fn rtn2_beats_rtn1_beats_nothing() {
        let mut rng = Rng::new(91);
        let (b, a) = rng.lora_pair(64, 64, 16, 0.7);
        let ba = matmul(&b, &a);
        let e1 = FlatQuantizer::rtn(1, 64).quantize(&b, &a, None).dequant_delta().rel_err(&ba);
        let e2 = FlatQuantizer::rtn(2, 64).quantize(&b, &a, None).dequant_delta().rel_err(&ba);
        let eb = FlatQuantizer::bin(64).quantize(&b, &a, None).dequant_delta().rel_err(&ba);
        assert!(e2 < e1, "rtn2 {e2} vs rtn1 {e1}");
        // the paper's point: 1-bit RTN collapses (most codes -> 0) and is
        // far worse than sign binarization at the same bitwidth
        assert!(eb < e1, "bin {eb} vs rtn1 {e1}");
    }

    #[test]
    fn paper_avg_bits() {
        let mut rng = Rng::new(92);
        let (b, a) = rng.lora_pair(128, 128, 16, 0.7);
        // group 128 reproduces Table 1's bit column exactly
        let q = FlatQuantizer::rtn(2, 128).quantize(&b, &a, None);
        assert!((q.avg_bits() - 2.140625).abs() < 1e-9, "{}", q.avg_bits());
        let q = FlatQuantizer::bin(128).quantize(&b, &a, None);
        assert!((q.avg_bits() - 1.125).abs() < 1e-9);
    }

    #[test]
    fn names() {
        assert_eq!(FlatQuantizer::rtn(1, 64).name(), "RTN (1 bit)");
        assert_eq!(FlatQuantizer::rtn(2, 64).name(), "RTN (2 bits)");
        assert_eq!(FlatQuantizer::bin(64).name(), "BIN");
    }
}
