//! GPTQ (Frantar et al., 2023) applied to LoRA factors (Table 1 row 6).
//!
//! Column-sequential quantization with second-order error compensation:
//! process input dimensions in order; after quantizing column j of W, the
//! remaining columns absorb the error weighted by the inverse Hessian
//! `H⁻¹ = (XᵀX + λI)⁻¹` of the layer inputs. We use the OBQ-style
//! rank-1 Hinv downdate (mathematically identical to the Cholesky
//! formulation in the paper, and simpler without LAPACK).
//!
//! Hessians for the two factors:
//! * `A (r×n)` sees layer inputs `x` directly → `H = XᵀX` (n×n),
//! * `B (m×r)` sees `t = x Aᵀ` → `H = (XAᵀ)ᵀ(XAᵀ)` (r×r),
//! with X the calibration activations captured at train time
//! (`<task>.calib.bin`). Without calibration, H = I and GPTQ degenerates
//! to plain RTN (no compensation paths).

use super::{CompressedPair, Quantizer};
use crate::quant::SCALE_BITS;
use crate::tensor::{matmul, matmul_a_bt, matmul_at_b, Matrix};

/// GPTQ configuration.
#[derive(Debug, Clone, Copy)]
pub struct Gptq {
    pub bits: u32,
    pub group: usize,
    /// Hessian damping as a fraction of mean diagonal (paper: 0.01).
    pub damp: f32,
}

impl Gptq {
    pub fn new(bits: u32, group: usize) -> Self {
        Self { bits, group, damp: 0.01 }
    }
}

/// GPTQ output for one factor: we keep the dequantized weights (codes are
/// implicit) plus exact Eq. 10 bit accounting.
#[derive(Debug)]
pub struct GptqCompressed {
    b_deq: Matrix,
    a_deq: Matrix,
    bits: u64,
    params: usize,
}

impl CompressedPair for GptqCompressed {
    fn dequant_delta(&self) -> Matrix {
        matmul(&self.b_deq, &self.a_deq)
    }

    fn storage_bits(&self) -> u64 {
        self.bits
    }

    fn param_count(&self) -> usize {
        self.params
    }
}

impl Quantizer for Gptq {
    fn name(&self) -> String {
        format!("GPTQ ({} bits)", self.bits)
    }

    fn quantize(&self, b: &Matrix, a: &Matrix, calib: Option<&Matrix>) -> Box<dyn CompressedPair> {
        let params = b.len() + a.len();
        // Hessian for A from raw inputs; for B from inputs pushed through Aᵀ.
        let (ha, hb) = match calib {
            Some(x) => {
                let t = matmul_a_bt(x, a); // rows × r
                (Some(xtx(x)), Some(xtx(&t)))
            }
            None => (None, None),
        };
        let a_deq = gptq_matrix(a, ha.as_ref(), self.bits, self.group, self.damp);
        let b_deq = gptq_matrix(b, hb.as_ref(), self.bits, self.group, self.damp);
        // Actual layout accounting: A groups along n (r rows), B along its
        // rank axis (m rows of r codes) — GPTQ must traverse input dims, so
        // B's groups are short and cost more than the paper's flat 2.14
        // estimate (DESIGN.md §7).
        let bits = layout_bits(b.rows(), b.cols(), self.bits, self.group)
            + layout_bits(a.rows(), a.cols(), self.bits, self.group);
        Box::new(GptqCompressed { b_deq, a_deq, bits, params })
    }
}

/// Eq. 10 bits of a rows×cols matrix grouped along cols.
fn layout_bits(rows: usize, cols: usize, bits: u32, group: usize) -> u64 {
    let groups = (rows * cols.div_ceil(group)) as u64;
    (rows * cols) as u64 * bits as u64 + groups * (SCALE_BITS + bits as u64)
}

/// `XᵀX` of a rows×d activation sample, normalized by rows.
fn xtx(x: &Matrix) -> Matrix {
    let h = matmul_at_b(x, x);
    h.scale(1.0 / x.rows() as f32)
}

/// Quantize W (rows × d) column-sequentially against Hessian H (d×d);
/// returns the dequantized result.
pub fn gptq_matrix(w: &Matrix, h: Option<&Matrix>, bits: u32, group: usize, damp: f32) -> Matrix {
    let (rows, d) = w.shape();
    let qmax = (1u32 << bits) - 1;
    let mut hinv = match h {
        Some(h) => {
            assert_eq!(h.shape(), (d, d));
            let mut hd = h.clone();
            let mean_diag = (0..d).map(|i| hd.at(i, i)).sum::<f32>() / d as f32;
            let lambda = (damp * mean_diag).max(1e-8);
            for i in 0..d {
                hd.set(i, i, hd.at(i, i) + lambda);
            }
            invert_spd(&hd)
        }
        None => Matrix::eye(d),
    };

    let mut wk = w.clone(); // working copy, compensated in place
    let mut out = Matrix::zeros(rows, d);
    // per-row group scale/zero, refreshed at group boundaries
    let mut scale = vec![1.0f32; rows];
    let mut zero = vec![0.0f32; rows];

    for j in 0..d {
        if j % group == 0 {
            let hi_col = (j + group).min(d);
            for i in 0..rows {
                let chunk = &wk.row(i)[j..hi_col];
                let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
                for &v in chunk {
                    lo = lo.min(v);
                    hi = hi.max(v);
                }
                if hi - lo <= 0.0 {
                    scale[i] = if lo == 0.0 { 1.0 } else { lo };
                    zero[i] = if lo == 0.0 { 0.0 } else { -1.0 }; // code 0 -> S*(0-(-1)) = S = lo
                } else {
                    scale[i] = (hi - lo) / qmax as f32;
                    zero[i] = (-lo / scale[i]).round();
                }
            }
        }
        let djj = hinv.at(j, j).max(1e-10);
        // quantize column j for all rows; propagate error to columns > j
        let mut errs = vec![0.0f32; rows];
        for i in 0..rows {
            let v = wk.at(i, j);
            let q = ((v / scale[i]).round() + zero[i]).clamp(0.0, qmax as f32);
            let deq = scale[i] * (q - zero[i]);
            out.set(i, j, deq);
            errs[i] = (v - deq) / djj;
        }
        for i in 0..rows {
            let e = errs[i];
            if e == 0.0 {
                continue;
            }
            let hrow = hinv.row(j);
            let wrow = wk.row_mut(i);
            for k in (j + 1)..d {
                wrow[k] -= e * hrow[k];
            }
        }
        // OBQ downdate: condition Hinv on dimension j being fixed
        if j + 1 < d {
            let col_j: Vec<f32> = (0..d).map(|t| hinv.at(t, j)).collect();
            let row_j: Vec<f32> = hinv.row(j).to_vec();
            let inv_djj = 1.0 / djj;
            for t in 0..d {
                let c = col_j[t] * inv_djj;
                if c == 0.0 {
                    continue;
                }
                let hrow = hinv.row_mut(t);
                for k in 0..d {
                    hrow[k] -= c * row_j[k];
                }
            }
        }
    }
    out
}

/// Inverse of a symmetric positive-definite matrix via Cholesky.
fn invert_spd(h: &Matrix) -> Matrix {
    let d = h.rows();
    let l = cholesky_lower(h);
    // Solve L Y = I, then Lᵀ X = Y  ⇒  X = H⁻¹
    let mut inv = Matrix::zeros(d, d);
    for col in 0..d {
        // forward solve
        let mut y = vec![0.0f32; d];
        for i in 0..d {
            let mut s = if i == col { 1.0 } else { 0.0 };
            for k in 0..i {
                s -= l.at(i, k) * y[k];
            }
            y[i] = s / l.at(i, i);
        }
        // back solve
        for i in (0..d).rev() {
            let mut s = y[i];
            for k in (i + 1)..d {
                s -= l.at(k, i) * inv.at(k, col);
            }
            inv.set(i, col, s / l.at(i, i));
        }
    }
    inv
}

/// Cholesky factor L (lower) with H = L Lᵀ; diagonal floored for safety.
fn cholesky_lower(h: &Matrix) -> Matrix {
    let d = h.rows();
    let mut l = Matrix::zeros(d, d);
    for i in 0..d {
        for j in 0..=i {
            let mut s = h.at(i, j);
            for k in 0..j {
                s -= l.at(i, k) * l.at(j, k);
            }
            if i == j {
                l.set(i, j, s.max(1e-12).sqrt());
            } else {
                l.set(i, j, s / l.at(j, j));
            }
        }
    }
    l
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{FlatQuantizer, Quantizer};
    use crate::testutil::Rng;

    #[test]
    fn spd_inverse_correct() {
        let mut rng = Rng::new(101);
        let x = rng.matrix(40, 8, 1.0);
        let mut h = matmul_at_b(&x, &x);
        for i in 0..8 {
            h.set(i, i, h.at(i, i) + 0.5);
        }
        let inv = invert_spd(&h);
        let prod = matmul(&h, &inv);
        assert!(prod.rel_err(&Matrix::eye(8)) < 1e-3, "{}", prod.rel_err(&Matrix::eye(8)));
    }

    #[test]
    fn identity_hessian_equals_rtn() {
        // With H = I there are no compensation paths: per-matrix GPTQ must
        // coincide with plain row-wise RTN in the same orientation.
        use crate::quant::{rtn_dequant, rtn_quant};
        let mut rng = Rng::new(102);
        let (_, a) = rng.lora_pair(48, 64, 8, 0.7);
        let g = gptq_matrix(&a, None, 2, 64, 0.01);
        let r = rtn_dequant(&rtn_quant(&a, 2, 64));
        assert!(g.sub(&r).fro_norm() < 1e-4, "no-calib GPTQ must equal RTN");
    }

    #[test]
    fn calibrated_gptq_beats_rtn_on_activations() {
        let mut rng = Rng::new(103);
        let (b, a) = rng.lora_pair(48, 64, 8, 0.7);
        // anisotropic inputs: some directions matter much more
        let mut x = rng.matrix(128, 64, 1.0);
        for i in 0..128 {
            for j in 0..64 {
                let w = if j < 8 { 4.0 } else { 0.25 };
                x.set(i, j, x.at(i, j) * w);
            }
        }
        let ba = matmul(&b, &a);
        // functional error: ||X (ΔW - ΔŴ)ᵀ|| — what GPTQ minimizes
        let f_err = |delta: &Matrix| matmul_a_bt(&x, &delta.sub(&ba)).fro_norm();
        let e_gptq = f_err(&Gptq::new(2, 64).quantize(&b, &a, Some(&x)).dequant_delta());
        let e_rtn = f_err(&FlatQuantizer::rtn(2, 64).quantize(&b, &a, None).dequant_delta());
        assert!(e_gptq < e_rtn, "gptq {e_gptq} vs rtn {e_rtn}");
    }

    #[test]
    fn avg_bits_matches_layout_accounting() {
        let mut rng = Rng::new(104);
        let (b, a) = rng.lora_pair(128, 128, 16, 0.7);
        let q = Gptq::new(2, 128).quantize(&b, &a, None);
        // B 128x16: 4096 + 128*18 = 6400; A 16x128: 4096 + 16*18 = 4384
        assert!((q.avg_bits() - 10784.0 / 4096.0).abs() < 1e-9, "{}", q.avg_bits());
    }
}
