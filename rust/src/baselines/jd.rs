//! JD-Diagonal (Gabrielsson et al., 2024) — "compress then serve"
//! (Table 1 row 4).
//!
//! Not a quantization method: a **cluster** of adapters shares a joint
//! basis `U (m×k), V (n×k)` and each adapter keeps only a k-vector diagonal:
//! `ΔWᵢ ≈ U diag(σᵢ) Vᵀ`. Storage per adapter is the diagonal plus the
//! amortized share of the basis — ~16/C bits/param for a C-adapter cluster
//! (the paper's 5.33 at C = 3).
//!
//! Basis computation: U spans the dominant eigenvectors of
//! `Σᵢ ΔWᵢ ΔWᵢᵀ = Σᵢ Bᵢ (AᵢAᵢᵀ) Bᵢᵀ`, computed in factored form via a thin
//! QR of `[B₁ … B_C]` and a small (Cr×Cr) Jacobi eigen-solve — the m×n
//! products are never materialized. V likewise from `Σᵢ Aᵢᵀ(BᵢᵀBᵢ)Aᵢ`.

use crate::linalg::{qr_thin, svd_jacobi};
use crate::quant::SCALE_BITS;
use crate::tensor::{matmul, matmul_at_b, Matrix};

/// JD-Diagonal configuration.
#[derive(Debug, Clone, Copy)]
pub struct JdDiagonal {
    /// Shared-basis rank (paper: the LoRA rank).
    pub k: usize,
}

/// A fitted cluster: shared basis + per-adapter diagonals.
#[derive(Debug, Clone)]
pub struct JdCluster {
    pub u: Matrix,
    pub v: Matrix,
    /// Per-adapter diagonal coefficients (k each).
    pub diags: Vec<Vec<f32>>,
    /// Original per-adapter parameter count r(m+n).
    pub params_per_adapter: usize,
}

impl JdDiagonal {
    /// Fit the shared basis over a cluster of factor pairs `(B m×r, A r×n)`.
    pub fn fit(&self, adapters: &[(Matrix, Matrix)]) -> JdCluster {
        assert!(!adapters.is_empty());
        let (m, r) = adapters[0].0.shape();
        let n = adapters[0].1.cols();
        let u = shared_basis(adapters.iter().map(|(b, a)| (b.clone(), a.clone())).collect(), self.k);
        // V: same construction with roles swapped (Aᵀ plays B, Bᵀ plays A)
        let v = shared_basis(
            adapters.iter().map(|(b, a)| (a.transpose(), b.transpose())).collect(),
            self.k,
        );
        let diags = adapters
            .iter()
            .map(|(b, a)| {
                // diag(Uᵀ B A V)
                let ub = matmul_at_b(&u, b); // k×r
                let av = matmul(a, &v); // r×k
                let p = matmul(&ub, &av); // k×k
                (0..self.k.min(p.rows())).map(|i| p.at(i, i)).collect()
            })
            .collect();
        JdCluster { u, v, diags, params_per_adapter: r * (m + n) }
    }
}

/// Dominant-k eigenbasis of `Σᵢ Bᵢ (AᵢAᵢᵀ) Bᵢᵀ` in factored form.
fn shared_basis(pairs: Vec<(Matrix, Matrix)>, k: usize) -> Matrix {
    // Concat all B factors: m × (C·r)
    let mut bcat = pairs[0].0.clone();
    for (b, _) in pairs.iter().skip(1) {
        bcat = bcat.hcat(b);
    }
    let (q, rr) = qr_thin(&bcat); // q: m×Cr
    // core = R · blockdiag(AᵢAᵢᵀ) · Rᵀ  (Cr × Cr, symmetric PSD)
    let cr = bcat.cols();
    let r = pairs[0].0.cols();
    let mut block = Matrix::zeros(cr, cr);
    for (i, (_, a)) in pairs.iter().enumerate() {
        let w = crate::tensor::matmul_a_bt(a, a); // r×r = A Aᵀ
        for p in 0..r {
            for t in 0..r {
                block.set(i * r + p, i * r + t, w.at(p, t));
            }
        }
    }
    let core = matmul(&matmul(&rr, &block), &rr.transpose());
    // symmetric PSD ⇒ SVD = eigendecomposition
    let eig = svd_jacobi(&core);
    let uk = eig.u.slice_cols(0, k.min(eig.u.cols()));
    matmul(&q, &uk)
}

impl JdCluster {
    /// Reconstruct adapter `i`: `U diag(σᵢ) Vᵀ` (m×n).
    pub fn dequant_delta(&self, i: usize) -> Matrix {
        let k = self.diags[i].len();
        let mut us = Matrix::zeros(self.u.rows(), k);
        for row in 0..self.u.rows() {
            for c in 0..k {
                us.set(row, c, self.u.at(row, c) * self.diags[i][c]);
            }
        }
        crate::tensor::matmul_a_bt(&us, &self.v)
    }

    /// Eq. 10 storage per adapter: fp16 diagonal + amortized fp16 basis.
    pub fn storage_bits_per_adapter(&self) -> u64 {
        let c = self.diags.len() as u64;
        let basis = (self.u.len() + self.v.len()) as u64 * SCALE_BITS;
        let diag = self.diags[0].len() as u64 * SCALE_BITS;
        diag + basis / c
    }

    /// Average bits per original LoRA parameter.
    pub fn avg_bits(&self) -> f64 {
        self.storage_bits_per_adapter() as f64 / self.params_per_adapter as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;

    #[test]
    fn single_adapter_cluster_reconstructs_well() {
        // With C = 1 the shared basis is exactly the adapter's own SVD basis,
        // so the diagonal reconstruction equals the rank-k truncation.
        let mut rng = Rng::new(131);
        let (b, a) = rng.lora_pair(48, 40, 8, 0.6);
        let ba = matmul(&b, &a);
        let cluster = JdDiagonal { k: 8 }.fit(&[(b, a)]);
        let err = cluster.dequant_delta(0).rel_err(&ba);
        assert!(err < 1e-3, "err {err}");
    }

    #[test]
    fn disjoint_adapters_interfere() {
        // Adapters with disjoint dominant subspaces cannot share one
        // diagonal basis — reconstruction degrades. (The paper's observed
        // failure mode on heterogeneous tasks.)
        let mut rng = Rng::new(132);
        let pairs: Vec<_> = (0..3).map(|_| rng.lora_pair(48, 40, 8, 0.6)).collect();
        let cluster = JdDiagonal { k: 8 }.fit(&pairs);
        let mut worst = 0.0f32;
        for (i, (b, a)) in pairs.iter().enumerate() {
            let err = cluster.dequant_delta(i).rel_err(&matmul(b, a));
            worst = worst.max(err);
        }
        assert!(worst > 0.3, "independent adapters should not share a basis: {worst}");
    }

    #[test]
    fn avg_bits_matches_paper() {
        let mut rng = Rng::new(133);
        let pairs: Vec<_> = (0..3).map(|_| rng.lora_pair(128, 128, 16, 0.6)).collect();
        let cluster = JdDiagonal { k: 16 }.fit(&pairs);
        // 16/C = 5.33 plus the tiny diagonal term
        assert!((cluster.avg_bits() - 5.33).abs() < 0.1, "{}", cluster.avg_bits());
    }

    #[test]
    fn shared_basis_orthonormal() {
        let mut rng = Rng::new(134);
        let pairs: Vec<_> = (0..2).map(|_| rng.lora_pair(32, 24, 4, 0.7)).collect();
        let cluster = JdDiagonal { k: 4 }.fit(&pairs);
        let utu = matmul_at_b(&cluster.u, &cluster.u);
        assert!(utu.rel_err(&Matrix::eye(4)) < 1e-3);
    }
}
