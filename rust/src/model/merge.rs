//! LoRA merging: `W_eff = W + s · (B A)ᵀ` per site (model.py `merge_lora`).
//!
//! The serving path stores weights in the `x @ W` orientation (n_in ×
//! m_out), while the paper's LoRA algebra is column-vector (`ΔW = B A`,
//! m_out × n_in) — hence the transpose.

use super::schema::{BaseWeights, ModelConfig};
use crate::adapter::fmt::Tensor;
use crate::adapter::LoraAdapter;
use crate::loraquant::QuantizedLora;
use crate::tensor::Matrix;
use anyhow::{bail, Context};
use std::collections::BTreeMap;

/// Merge a per-site delta (m_out × n_in) into a weight tensor (n_in × m_out).
pub fn merge_delta(w: &Tensor, delta: &Matrix, scaling: f32) -> anyhow::Result<Tensor> {
    let wm = w.to_matrix()?;
    if (wm.cols(), wm.rows()) != delta.shape() {
        bail!("merge shape mismatch: W {:?} vs ΔW {:?}", wm.shape(), delta.shape());
    }
    let mut out = wm.clone();
    for i in 0..out.rows() {
        for j in 0..out.cols() {
            let v = out.at(i, j) + scaling * delta.at(j, i);
            out.set(i, j, v);
        }
    }
    Ok(Tensor::f32(vec![out.rows(), out.cols()], out.into_vec()))
}

/// Per-site deltas from an FP adapter.
pub fn fp_deltas(adapter: &LoraAdapter) -> BTreeMap<String, Matrix> {
    adapter
        .sites
        .iter()
        .map(|(site, (a, b))| (site.clone(), crate::tensor::matmul(b, a)))
        .collect()
}

/// Per-site deltas from a quantized adapter (dequantize-on-merge).
pub fn quant_deltas(q: &QuantizedLora) -> BTreeMap<String, Matrix> {
    q.sites.iter().map(|(site, qs)| (site.clone(), qs.dequant_delta())).collect()
}

/// The **unmerged** base weight list in `param_names` order — the
/// substrate the factor-form execution path decodes over (adapters are
/// applied on the activation path instead of being merged in).
pub fn base_weight_list(base: &BaseWeights) -> anyhow::Result<Vec<Tensor>> {
    merge_adapter(base, &BTreeMap::new())
}

/// Produce the merged flat weight list for one adapter, in `param_names`
/// order, ready to feed the HLO executable. Non-LoRA tensors pass through.
pub fn merge_adapter(
    base: &BaseWeights,
    deltas: &BTreeMap<String, Matrix>,
) -> anyhow::Result<Vec<Tensor>> {
    let cfg: &ModelConfig = &base.cfg;
    let s = cfg.lora_scaling();
    let mut out = Vec::with_capacity(base.tensors.len());
    for name in cfg.param_names() {
        let w = base.tensors.get(&name).with_context(|| name.clone())?;
        match deltas.get(&name) {
            Some(d) => out.push(merge_delta(w, d, s).with_context(|| name.clone())?),
            None => out.push(w.clone()),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_delta_transposes_and_scales() {
        // W (2x3, x@W orientation), delta (3x2, paper orientation)
        let w = Tensor::f32(vec![2, 3], vec![0.0; 6]);
        let delta = Matrix::from_fn(3, 2, |i, j| (i * 2 + j) as f32);
        let merged = merge_delta(&w, &delta, 2.0).unwrap();
        let m = merged.to_matrix().unwrap();
        // merged[i][j] = 2 * delta[j][i]
        assert_eq!(m.at(0, 1), 2.0 * delta.at(1, 0));
        assert_eq!(m.at(1, 2), 2.0 * delta.at(2, 1));
    }

    #[test]
    fn shape_mismatch_rejected() {
        let w = Tensor::f32(vec![2, 3], vec![0.0; 6]);
        let delta = Matrix::zeros(2, 3); // wrong orientation
        assert!(merge_delta(&w, &delta, 1.0).is_err());
    }
}
