//! Model weight schema + LoRA merging (mirrors python/compile/model.py —
//! the two MUST stay in lockstep; the HLO artifacts take weights as
//! positional inputs in `param_names` order).

pub mod merge;
pub mod schema;

pub use merge::{base_weight_list, merge_adapter, merge_delta};
pub use schema::{BaseWeights, ModelConfig};
