//! Weight schema of the tiny transformer (L2), mirrored from
//! python/compile/model.py.

use crate::adapter::fmt::{load_tensorfile, Tensor};
use anyhow::{bail, Context};
use std::collections::BTreeMap;
use std::path::Path;

/// Model hyper-parameters (exported by train.py as `<model>/meta.bin`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelConfig {
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub lora_rank: usize,
    pub lora_alpha: usize,
    pub act_silu: bool,
}

impl ModelConfig {
    /// Load from `<model_dir>/meta.bin`.
    pub fn load(model_dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let t = load_tensorfile(model_dir.as_ref().join("meta.bin"))?;
        let get = |k: &str| -> anyhow::Result<usize> {
            Ok(t.get(k).with_context(|| format!("meta missing {k}"))?.as_i32()?[0] as usize)
        };
        Ok(Self {
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            d_ff: get("d_ff")?,
            vocab: get("vocab")?,
            seq_len: get("seq_len")?,
            lora_rank: get("lora_rank")?,
            lora_alpha: get("lora_alpha")?,
            act_silu: get("act_silu")? == 1,
        })
    }

    /// Write `<model_dir>/meta.bin` (inverse of [`ModelConfig::load`];
    /// used by the synthetic-artifact writer in `testutil::synth`).
    pub fn save(&self, model_dir: impl AsRef<Path>) -> anyhow::Result<()> {
        use crate::adapter::fmt::{save_tensorfile, Tensor};
        let dir = model_dir.as_ref();
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let mut t = BTreeMap::new();
        let mut put = |k: &str, v: usize| {
            t.insert(k.to_string(), Tensor::i32(vec![1], vec![v as i32]));
        };
        put("d_model", self.d_model);
        put("n_layers", self.n_layers);
        put("n_heads", self.n_heads);
        put("d_ff", self.d_ff);
        put("vocab", self.vocab);
        put("seq_len", self.seq_len);
        put("lora_rank", self.lora_rank);
        put("lora_alpha", self.lora_alpha);
        put("act_silu", usize::from(self.act_silu));
        save_tensorfile(dir.join("meta.bin"), &t)
    }

    /// LoRA merge scaling `s = alpha / r`.
    pub fn lora_scaling(&self) -> f32 {
        self.lora_alpha as f32 / self.lora_rank as f32
    }

    /// Canonical parameter order — MUST match model.py `param_names`.
    pub fn param_names(&self) -> Vec<String> {
        let mut names = vec!["embed".to_string(), "pos".to_string()];
        for i in 0..self.n_layers {
            names.push(format!("l{i}.ln1.g"));
            names.push(format!("l{i}.ln1.b"));
            for w in ["wq", "wk", "wv", "wo"] {
                names.push(format!("l{i}.{w}"));
            }
            names.push(format!("l{i}.ln2.g"));
            names.push(format!("l{i}.ln2.b"));
            names.push(format!("l{i}.w1"));
            names.push(format!("l{i}.w2"));
        }
        names.push("lnf.g".into());
        names.push("lnf.b".into());
        names.push("head".into());
        names
    }

    /// LoRA site names in layer-major order — matches model.py.
    pub fn lora_site_names(&self) -> Vec<String> {
        let mut names = Vec::new();
        for i in 0..self.n_layers {
            for s in ["wq", "wk", "wv", "wo", "w1", "w2"] {
                names.push(format!("l{i}.{s}"));
            }
        }
        names
    }

    /// (n_in, m_out) of a LoRA site given its short name.
    pub fn site_shape(&self, short: &str) -> anyhow::Result<(usize, usize)> {
        let d = self.d_model;
        let f = self.d_ff;
        Ok(match short {
            "wq" | "wk" | "wv" | "wo" => (d, d),
            "w1" => (d, f),
            "w2" => (f, d),
            _ => bail!("unknown site {short}"),
        })
    }
}

/// Base-model weights: name → tensor, plus the config.
#[derive(Debug, Clone)]
pub struct BaseWeights {
    pub cfg: ModelConfig,
    pub tensors: BTreeMap<String, Tensor>,
}

impl BaseWeights {
    /// Load `<model_dir>/{meta,base}.bin` and validate the schema.
    pub fn load(model_dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = model_dir.as_ref();
        let cfg = ModelConfig::load(dir)?;
        let tensors = load_tensorfile(dir.join("base.bin"))?;
        for name in cfg.param_names() {
            if !tensors.contains_key(&name) {
                bail!("base.bin missing parameter {name}");
            }
        }
        Ok(Self { cfg, tensors })
    }

    /// Parameter count of the base model.
    pub fn param_count(&self) -> usize {
        self.tensors.values().map(|t| t.data.len()).sum()
    }

    /// FP16 bytes of the base model (for the Fig. 6 memory axis).
    pub fn fp16_bytes(&self) -> usize {
        self.param_count() * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            d_ff: 512,
            vocab: 64,
            seq_len: 32,
            lora_rank: 16,
            lora_alpha: 32,
            act_silu: false,
        }
    }

    #[test]
    fn param_names_order_and_count() {
        let names = cfg().param_names();
        // 2 + 4*(2+4+2+2) + 3 = 2 + 40 + 3
        assert_eq!(names.len(), 45);
        assert_eq!(names[0], "embed");
        assert_eq!(names[2], "l0.ln1.g");
        assert_eq!(names[4], "l0.wq");
        assert_eq!(names[names.len() - 1], "head");
    }

    #[test]
    fn lora_sites() {
        let sites = cfg().lora_site_names();
        assert_eq!(sites.len(), 24);
        assert_eq!(sites[0], "l0.wq");
        assert_eq!(sites[23], "l3.w2");
    }

    #[test]
    fn site_shapes() {
        let c = cfg();
        assert_eq!(c.site_shape("wq").unwrap(), (128, 128));
        assert_eq!(c.site_shape("w1").unwrap(), (128, 512));
        assert_eq!(c.site_shape("w2").unwrap(), (512, 128));
        assert!(c.site_shape("nope").is_err());
    }

    #[test]
    fn scaling() {
        assert_eq!(cfg().lora_scaling(), 2.0);
    }

    #[test]
    fn meta_save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("lq_schema_meta_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        cfg().save(&dir).unwrap();
        let back = ModelConfig::load(&dir).unwrap();
        assert_eq!(back, cfg());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
