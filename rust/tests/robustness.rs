//! Fault-contained serving acceptance (DESIGN.md §15): deadlines,
//! panic isolation, retrying disk loads, quarantine, and load shedding
//! must fail *only the requests a fault targets*, with structured error
//! kinds, while every survivor decodes bit-identically to an unfaulted
//! oracle run. Everything rides the full coordinator under the virtual
//! clock, so every trace — including the fault events themselves — is
//! byte-reproducible.
//!
//! Reference engine only: the synthetic scenario environment has no HLO
//! artifacts for the PJRT backend.
#![cfg(not(feature = "pjrt"))]

use loraquant::coordinator::MergeStrategy;
use loraquant::scenario::{
    run_scenario, ChurnAction, DiskError, EventKind, FaultPlan, ScenarioEnv, ScenarioRun,
    ScenarioSpec, ScriptedPanic,
};
use loraquant::workload::WorkloadConfig;
use std::time::Duration;

const MS: fn(u64) -> Duration = Duration::from_millis;

/// Every request that survived the faulted run must have decoded the
/// exact tokens the unfaulted oracle produced at the same trace index.
fn assert_survivors_match_oracle(faulted: &ScenarioRun, oracle: &ScenarioRun, what: &str) {
    assert_eq!(faulted.tokens.len(), oracle.tokens.len());
    for (i, (got, want)) in faulted.tokens.iter().zip(&oracle.tokens).enumerate() {
        if let Some(got) = got {
            assert_eq!(
                Some(got),
                want.as_ref(),
                "{what}: survivor req {i} must be bit-identical to the oracle"
            );
        }
    }
}

/// The `(req, adapter, error)` triples of every `Fail` event.
fn fails(run: &ScenarioRun) -> Vec<(usize, u32, String)> {
    run.events
        .iter()
        .filter_map(|e| match &e.kind {
            EventKind::Fail { req, adapter, error } => Some((*req, *adapter, error.clone())),
            _ => None,
        })
        .collect()
}

fn count_kind(run: &ScenarioRun, pred: impl Fn(&EventKind) -> bool) -> usize {
    run.events.iter().filter(|e| pred(&e.kind)).count()
}

/// A deadline storm: 200 requests at 2000/s against a max-wait far past
/// the 15 ms per-request deadline, so only bucket-full releases beat the
/// clock. Rare tenants' stragglers retire with a structured `Timeout`
/// at *exactly* submit + deadline; every survivor is bit-identical to a
/// deadline-free oracle; and the whole trace — including the timeout
/// schedule — is byte-reproducible across runs, compute threads, and
/// worker counts.
#[test]
fn deadline_storm_times_out_stragglers_and_pins_survivors() {
    let env = ScenarioEnv::synth("rb_deadline", 4).unwrap();
    let timeout = MS(15);
    let spec = |threads: usize, workers: usize| ScenarioSpec {
        name: "robustness/deadline".into(),
        strategy: MergeStrategy::Merged,
        compute_threads: threads,
        workers,
        max_wait: Duration::from_secs(1),
        request_timeout: Some(timeout),
        workload: WorkloadConfig { rate: 2000.0, zipf_alpha: 1.1, n_requests: 200, seed: 7 },
        ..Default::default()
    };
    let run = run_scenario(&spec(1, 1), &env).unwrap();
    assert!(run.summary.ok > 0, "hot tenants must still complete under the storm");
    assert!(run.summary.failed > 0, "stragglers must time out under a 15ms deadline");
    assert_eq!(run.summary.ok + run.summary.failed, 200, "every request resolves");
    assert_eq!(run.summary.timeouts, run.summary.failed as u64);
    assert_eq!(run.summary.cancellations, 0);
    assert_eq!(run.summary.sheds, 0);
    assert_eq!(
        run.summary.failed_by_kind.get("timeout"),
        Some(&run.summary.failed),
        "every failure must be a structured timeout: {:?}",
        run.summary.failed_by_kind
    );
    // a timeout retires at exactly submit + deadline on the virtual clock
    for (req, _, error) in fails(&run) {
        assert!(error.starts_with("timeout:"), "req {req}: {error}");
        let submit = run
            .events
            .iter()
            .find(|e| matches!(e.kind, EventKind::Submit { req: r, .. } if r == req))
            .expect("every failed request was submitted");
        let fail = run
            .events
            .iter()
            .find(|e| matches!(e.kind, EventKind::Fail { req: r, .. } if r == req))
            .unwrap();
        assert_eq!(
            fail.t - submit.t,
            timeout,
            "req {req}: queued expiry must fire exactly at the deadline"
        );
    }
    let oracle = run_scenario(
        &ScenarioSpec { request_timeout: None, ..spec(1, 1) },
        &env,
    )
    .unwrap();
    assert_eq!(oracle.summary.ok, 200, "the deadline-free oracle completes everything");
    assert_survivors_match_oracle(&run, &oracle, "deadline storm");
    // byte-reproducible: across runs, compute threads, and worker counts
    let again = run_scenario(&spec(1, 1), &env).unwrap();
    assert_eq!(run.log(), again.log(), "storm trace must be reproducible");
    let threaded = run_scenario(&spec(4, 1), &env).unwrap();
    assert_eq!(run.log(), threaded.log(), "trace must not depend on compute threads");
    let two_workers = run_scenario(&spec(1, 2), &env).unwrap();
    assert_eq!(
        run.log(),
        two_workers.log(),
        "per-adapter queues are worker-count invariant, so the trace is too"
    );
}

/// Panic containment: the first merge for adapter 1 panics on the pool
/// thread. Only the requests parked on that merge fail (structured
/// `Internal`), the supervisor respawns the dead worker exactly once,
/// and the very next adapter-1 batch re-merges and serves normally.
#[test]
fn scripted_panic_fails_only_target_adapter_and_respawns_worker() {
    let env = ScenarioEnv::synth("rb_panic", 4).unwrap();
    let spec = |threads: usize| ScenarioSpec {
        name: "robustness/panic".into(),
        strategy: MergeStrategy::Merged,
        compute_threads: threads,
        round_robin: true,
        faults: FaultPlan {
            panic: Some(ScriptedPanic { adapter: 1, first_n: 1 }),
            ..Default::default()
        },
        ..Default::default()
    };
    let run = run_scenario(&spec(1), &env).unwrap();
    let failed = run.summary.failed;
    assert!(failed >= 1, "the panicked merge must fail its parked requests");
    assert_eq!(run.summary.ok, 64 - failed);
    for (req, adapter, error) in fails(&run) {
        assert_eq!(adapter, 1, "req {req}: a panic must only fail its own adapter's group");
        assert!(error.starts_with("internal:"), "req {req}: {error}");
    }
    assert_eq!(run.summary.failed_by_kind.get("internal"), Some(&failed));
    assert_eq!(run.summary.failed_by_kind.len(), 1);
    assert_eq!(count_kind(&run, |k| matches!(k, EventKind::Panic { adapter: 1 })), 1);
    assert_eq!(run.summary.worker_respawns, 1, "the supervisor must respawn the dead worker");
    // recovery: later adapter-1 batches re-merge and complete
    let adapter1_completes =
        count_kind(&run, |k| matches!(k, EventKind::Complete { adapter: 1, .. }));
    assert_eq!(adapter1_completes, 16 - failed, "post-respawn adapter-1 traffic must serve");
    let oracle =
        run_scenario(&ScenarioSpec { faults: FaultPlan::default(), ..spec(1) }, &env).unwrap();
    assert_eq!(oracle.summary.ok, 64);
    assert_survivors_match_oracle(&run, &oracle, "scripted panic");
    let again = run_scenario(&spec(1), &env).unwrap();
    assert_eq!(run.log(), again.log(), "panic trace must be reproducible");
    let threaded = run_scenario(&spec(4), &env).unwrap();
    assert_eq!(run.log(), threaded.log(), "trace must not depend on compute threads");
}

/// A tiered spec for the disk-fault tests: every adapter on disk, a
/// factor cache generous enough that each adapter loads exactly once.
fn disk_spec(env: &ScenarioEnv, name: &str) -> ScenarioSpec {
    let unit = env.adapters[0].1.bytes();
    ScenarioSpec {
        name: name.into(),
        strategy: MergeStrategy::Factor,
        round_robin: true,
        tiered: true,
        factor_cache_bytes: unit * 8,
        ..Default::default()
    }
}

/// Transient disk faults: the first two loads of adapter 2 fail, the
/// bounded retry loop (2 retries, 1 ms virtual backoff) absorbs both,
/// and not a single request fails or decodes differently.
#[test]
fn disk_error_retries_recover_without_failures() {
    let env = ScenarioEnv::synth("rb_retry", 4).unwrap();
    let spec = |threads: usize| ScenarioSpec {
        compute_threads: threads,
        disk_retries: 2,
        disk_backoff: MS(1),
        faults: FaultPlan {
            disk_error: Some(DiskError { adapter: Some(2), first_n: 2 }),
            ..Default::default()
        },
        ..disk_spec(&env, "robustness/disk-retry")
    };
    let run = run_scenario(&spec(1), &env).unwrap();
    assert_eq!(run.summary.failed, 0, "retries must absorb the transient fault");
    assert_eq!(run.summary.ok, 64);
    assert_eq!(run.summary.disk_retries, 2, "both scripted failures cost one retry each");
    let attempts: Vec<u32> = run
        .events
        .iter()
        .filter_map(|e| match e.kind {
            EventKind::DiskError { adapter: 2, attempt } => Some(attempt),
            _ => None,
        })
        .collect();
    assert_eq!(attempts, vec![0, 1], "initial try then first retry fail; second retry lands");
    let oracle = run_scenario(
        &ScenarioSpec {
            disk_retries: 0,
            disk_backoff: Duration::ZERO,
            faults: FaultPlan::default(),
            ..spec(1)
        },
        &env,
    )
    .unwrap();
    assert_eq!(run.tokens, oracle.tokens, "retried loads must not change a single token");
    let again = run_scenario(&spec(1), &env).unwrap();
    assert_eq!(run.log(), again.log(), "retry trace must be reproducible");
    let threaded = run_scenario(&spec(4), &env).unwrap();
    assert_eq!(run.log(), threaded.log(), "trace must not depend on compute threads");
}

/// Permanent disk faults: every load of adapter 2 fails, the retry
/// budget (1 retry) exhausts, and the adapter is quarantined — all 16 of
/// its round-robin requests fail fast with `AdapterUnavailable` while
/// the other 48 serve bit-identically to an unfaulted oracle.
#[test]
fn disk_error_exhaustion_quarantines_adapter() {
    let env = ScenarioEnv::synth("rb_quarantine", 4).unwrap();
    let spec = ScenarioSpec {
        disk_retries: 1,
        disk_backoff: MS(1),
        faults: FaultPlan {
            disk_error: Some(DiskError { adapter: Some(2), first_n: u32::MAX }),
            ..Default::default()
        },
        ..disk_spec(&env, "robustness/disk-quarantine")
    };
    let run = run_scenario(&spec, &env).unwrap();
    assert_eq!(run.summary.failed, 16, "exactly the quarantined tenant's requests fail");
    assert_eq!(run.summary.ok, 48);
    assert_eq!(run.summary.failed_by_kind.get("adapter-unavailable"), Some(&16));
    assert_eq!(run.summary.failed_by_kind.len(), 1);
    for (req, adapter, _) in fails(&run) {
        assert_eq!(adapter, 2, "req {req}: quarantine must not leak to other tenants");
    }
    assert_eq!(run.summary.quarantined, 1);
    assert_eq!(count_kind(&run, |k| matches!(k, EventKind::Quarantine { adapter: 2 })), 1);
    assert_eq!(run.summary.disk_retries, 1, "one retry, then the budget exhausts");
    assert_eq!(
        count_kind(&run, |k| matches!(k, EventKind::DiskError { adapter: 2, .. })),
        2,
        "initial try + one retry, then no further load is attempted"
    );
    let oracle = run_scenario(
        &ScenarioSpec {
            disk_retries: 0,
            disk_backoff: Duration::ZERO,
            faults: FaultPlan::default(),
            ..spec.clone()
        },
        &env,
    )
    .unwrap();
    assert_eq!(oracle.summary.ok, 64);
    assert_survivors_match_oracle(&run, &oracle, "disk quarantine");
    let again = run_scenario(&spec, &env).unwrap();
    assert_eq!(run.log(), again.log(), "quarantine trace must be reproducible");
}

/// Scripted availability flaps: adapter 3 is quarantined at 80 ms and
/// recovered at 160 ms. Its requests inside the window fail fast with
/// the quarantine error; traffic before and after the window serves
/// normally, bit-identical to a churn-free oracle.
#[test]
fn quarantine_churn_flaps_availability_deterministically() {
    let env = ScenarioEnv::synth("rb_churn", 4).unwrap();
    let spec = ScenarioSpec {
        name: "robustness/quarantine-churn".into(),
        strategy: MergeStrategy::Merged,
        round_robin: true,
        faults: FaultPlan {
            churn: vec![
                ChurnAction::Quarantine { at: MS(80), target: 3 },
                ChurnAction::Recover { at: MS(160), target: 3 },
            ],
            ..Default::default()
        },
        ..Default::default()
    };
    let run = run_scenario(&spec, &env).unwrap();
    assert!(run.summary.failed > 0, "in-window adapter-3 requests must fail fast");
    assert_eq!(run.summary.ok + run.summary.failed, 64);
    for (req, adapter, error) in fails(&run) {
        assert_eq!(adapter, 3, "req {req}: the flap must only fail the quarantined tenant");
        assert!(error.contains("quarantined"), "req {req}: {error}");
    }
    assert_eq!(
        run.summary.failed_by_kind.get("adapter-unavailable"),
        Some(&run.summary.failed)
    );
    assert_eq!(run.summary.quarantined, 1);
    assert_eq!(count_kind(&run, |k| matches!(k, EventKind::Quarantine { adapter: 3 })), 1);
    assert_eq!(count_kind(&run, |k| matches!(k, EventKind::Recover { adapter: 3 })), 1);
    // the tenant serves on both sides of the outage window
    let complete_at = |pred: &dyn Fn(Duration) -> bool| {
        run.events.iter().any(
            |e| matches!(e.kind, EventKind::Complete { adapter: 3, .. } if pred(e.t)),
        )
    };
    assert!(complete_at(&|t| t < MS(80)), "adapter 3 must serve before the quarantine");
    assert!(complete_at(&|t| t > MS(160)), "adapter 3 must serve again after recovery");
    let oracle =
        run_scenario(&ScenarioSpec { faults: FaultPlan::default(), ..spec.clone() }, &env)
            .unwrap();
    assert_eq!(oracle.summary.ok, 64);
    assert_survivors_match_oracle(&run, &oracle, "quarantine churn");
    let again = run_scenario(&spec, &env).unwrap();
    assert_eq!(run.log(), again.log(), "churn trace must be reproducible");
}

/// Load shedding: a depth-2 admission cap against a 4000/s arrival burst
/// sheds deterministically with a structured `Overloaded` carrying a
/// `retry_after` hint; admitted requests all complete.
#[test]
fn queue_cap_sheds_overload_with_retry_hint() {
    let env = ScenarioEnv::synth("rb_shed", 4).unwrap();
    let spec = |threads: usize| ScenarioSpec {
        name: "robustness/shed".into(),
        strategy: MergeStrategy::Factor,
        compute_threads: threads,
        queue_cap: Some(2),
        workload: WorkloadConfig { rate: 4000.0, zipf_alpha: 1.1, n_requests: 64, seed: 7 },
        ..Default::default()
    };
    let run = run_scenario(&spec(1), &env).unwrap();
    assert!(run.summary.failed > 0, "a depth-2 cap must shed under a 4000/s burst");
    assert!(run.summary.ok >= 2, "admitted requests must complete");
    assert_eq!(run.summary.ok + run.summary.failed, 64);
    assert_eq!(run.summary.sheds, run.summary.failed as u64, "every failure is a shed");
    assert_eq!(run.summary.failed_by_kind.get("overloaded"), Some(&run.summary.failed));
    assert_eq!(run.summary.failed_by_kind.len(), 1);
    for (req, _, error) in fails(&run) {
        assert!(error.starts_with("overloaded:"), "req {req}: {error}");
        assert!(error.contains("retry after"), "req {req}: shed must carry a backoff hint");
    }
    let again = run_scenario(&spec(1), &env).unwrap();
    assert_eq!(run.log(), again.log(), "shed trace must be reproducible");
    let threaded = run_scenario(&spec(4), &env).unwrap();
    assert_eq!(run.log(), threaded.log(), "trace must not depend on compute threads");
}

/// The combined storm the issue asks for: a deadline storm, a scripted
/// merge panic (adapter 1), and permanently failing disk loads
/// (adapter 2 → quarantine) all in one tiered trace. Non-timeout
/// failures stay pinned to their target adapters, every fault counter
/// fires, survivors are bit-identical to an unfaulted oracle, and the
/// whole trace is byte-reproducible.
#[test]
fn combined_fault_storm_is_reproducible_and_contained() {
    let env = ScenarioEnv::synth("rb_storm", 4).unwrap();
    let unit = env.adapters[0].1.bytes();
    let spec = |threads: usize, workers: usize| ScenarioSpec {
        name: "robustness/combined".into(),
        strategy: MergeStrategy::Merged,
        compute_threads: threads,
        workers,
        buckets: vec![1, 4],
        max_wait: Duration::from_secs(1),
        request_timeout: Some(MS(15)),
        tiered: true,
        factor_cache_bytes: unit * 8,
        disk_retries: 2,
        disk_backoff: MS(1),
        workload: WorkloadConfig { rate: 2000.0, zipf_alpha: 1.1, n_requests: 200, seed: 7 },
        faults: FaultPlan {
            panic: Some(ScriptedPanic { adapter: 1, first_n: 1 }),
            disk_error: Some(DiskError { adapter: Some(2), first_n: u32::MAX }),
            ..Default::default()
        },
        ..Default::default()
    };
    let run = run_scenario(&spec(1, 1), &env).unwrap();
    assert!(run.summary.ok > 0, "the hot tenant must keep serving through the storm");
    assert!(run.summary.failed > 0);
    assert_eq!(run.summary.ok + run.summary.failed, 200);
    // every fault family fired
    assert!(run.summary.timeouts > 0, "the deadline storm must retire stragglers");
    assert_eq!(run.summary.worker_respawns, 1);
    assert_eq!(count_kind(&run, |k| matches!(k, EventKind::Panic { adapter: 1 })), 1);
    assert_eq!(run.summary.quarantined, 1);
    assert_eq!(run.summary.disk_retries, 2);
    assert_eq!(
        count_kind(&run, |k| matches!(k, EventKind::DiskError { adapter: 2, .. })),
        3,
        "initial try + both retries fail, then the adapter quarantines"
    );
    // structured accounting: only the three expected failure classes
    assert_eq!(run.summary.timeouts as usize, run.summary.failed_by_kind["timeout"]);
    for kind in run.summary.failed_by_kind.keys() {
        assert!(
            ["timeout", "internal", "adapter-unavailable"].contains(&kind.as_str()),
            "unexpected failure class {kind}"
        );
    }
    // non-timeout failures stay pinned to the adapter their fault targets
    for (req, adapter, error) in fails(&run) {
        if error.starts_with("internal:") {
            assert_eq!(adapter, 1, "req {req}: panic fallout must stay on adapter 1");
        } else if error.starts_with("adapter-unavailable:") {
            assert_eq!(adapter, 2, "req {req}: quarantine fallout must stay on adapter 2");
        } else {
            assert!(error.starts_with("timeout:"), "req {req}: {error}");
        }
    }
    let oracle = run_scenario(
        &ScenarioSpec {
            request_timeout: None,
            disk_retries: 0,
            disk_backoff: Duration::ZERO,
            faults: FaultPlan::default(),
            ..spec(1, 1)
        },
        &env,
    )
    .unwrap();
    assert_eq!(oracle.summary.ok, 200, "the unfaulted oracle completes everything");
    assert_survivors_match_oracle(&run, &oracle, "combined storm");
    // byte-reproducible across runs and compute threads; worker-count
    // invariant in results (tokens + failure set)
    let again = run_scenario(&spec(1, 1), &env).unwrap();
    assert_eq!(run.log(), again.log(), "combined trace must be reproducible");
    let threaded = run_scenario(&spec(4, 1), &env).unwrap();
    assert_eq!(run.log(), threaded.log(), "trace must not depend on compute threads");
    let two_workers = run_scenario(&spec(1, 2), &env).unwrap();
    assert_eq!(run.tokens, two_workers.tokens, "tokens must not depend on pool size");
    assert_eq!(
        fails(&run).iter().map(|(r, ..)| *r).collect::<Vec<_>>(),
        fails(&two_workers).iter().map(|(r, ..)| *r).collect::<Vec<_>>(),
        "the failure set must not depend on pool size"
    );
}
