//! Tiered adapter-store acceptance (DESIGN.md §14): the disk tier +
//! factor cache below the merged-weight cache must change *where* packed
//! factors live, never *what* gets decoded. Everything runs the full
//! coordinator under the virtual clock; the serving contract under test:
//!
//! * tiered decode tokens are byte-identical to fully-resident serving
//!   for every strategy, at a factor-cache budget far below the fleet;
//! * the factor cache's counted request-path misses equal the tier's
//!   completed disk loads (no silent double-loading);
//! * tiered traces — including scripted disk-latency faults — are
//!   byte-reproducible across runs and compute-thread counts.
//!
//! Reference engine only: the synthetic scenario environment has no HLO
//! artifacts for the PJRT backend.
#![cfg(not(feature = "pjrt"))]

use loraquant::coordinator::MergeStrategy;
use loraquant::scenario::{
    run_scenario, ClockMode, DiskLatency, EventKind, FaultPlan, ScenarioEnv, ScenarioSpec,
};
use loraquant::workload::WorkloadConfig;
use std::time::Duration;

/// A tiered spec whose factor cache holds ~`cache_adapters` of the
/// fleet's packed adapters (well under 5% in every test that uses it).
fn tiered_spec(env: &ScenarioEnv, strategy: MergeStrategy, tenants: usize) -> ScenarioSpec {
    let unit = env.adapters[0].1.bytes();
    ScenarioSpec {
        name: format!("tiering/{strategy}"),
        mode: ClockMode::Virtual,
        strategy,
        n_adapters: tenants,
        tiered: true,
        factor_cache_bytes: unit * 2,
        workload: WorkloadConfig { rate: 400.0, zipf_alpha: 1.1, n_requests: 200, seed: 23 },
        ..Default::default()
    }
}

/// The headline contract: spilling every adapter to disk and paging
/// factors through a cache that holds 2 of 50 tenants (4%) must not
/// change a single decoded token relative to fully-resident serving.
/// Merged and factor runs compare against their own resident twins (the
/// decode path is unchanged, so the codec round-trip must be exact);
/// auto compares against tiered merged — with factors on disk a cold
/// auto batch parks behind its merge instead of decoding factor-form, so
/// every auto request rides the merged path bit-for-bit.
#[test]
fn tiered_tokens_bit_identical_to_resident_serving() {
    let env = ScenarioEnv::synth("tierid", 4).unwrap();
    let mut merged_tiered_tokens = None;
    for strategy in [MergeStrategy::Merged, MergeStrategy::Factor] {
        let tiered = tiered_spec(&env, strategy, 50);
        let resident = ScenarioSpec { tiered: false, ..tiered.clone() };
        let a = run_scenario(&tiered, &env).unwrap();
        let b = run_scenario(&resident, &env).unwrap();
        assert_eq!(a.summary.ok, 200, "{strategy}: tiered run must complete every request");
        assert_eq!(b.summary.ok, 200);
        assert_eq!(a.tokens, b.tokens, "{strategy}: tiering must not change a single token");
        assert_eq!(a.summary.spilled, 50, "{strategy}: every quantized tenant spills");
        assert!(a.summary.disk_loads > 0, "{strategy}: the tier must actually serve loads");
        if strategy == MergeStrategy::Merged {
            merged_tiered_tokens = Some(a.tokens);
        }
    }
    let auto = run_scenario(&tiered_spec(&env, MergeStrategy::Auto, 50), &env).unwrap();
    assert_eq!(auto.summary.ok, 200, "auto: tiered run must complete every request");
    assert_eq!(
        Some(auto.tokens),
        merged_tiered_tokens,
        "auto with factors on disk must ride the merged path bit-for-bit"
    );
}

/// The counting contract on the factor path: exactly one counted
/// factor-cache miss per submitted disk fetch, none while one is in
/// flight, so `misses == disk_loads` (no prefetch, no predictor — those
/// warm without counting).
#[test]
fn factor_cache_misses_equal_disk_loads() {
    let env = ScenarioEnv::synth("tiercount", 4).unwrap();
    let spec = tiered_spec(&env, MergeStrategy::Factor, 40);
    let run = run_scenario(&spec, &env).unwrap();
    assert_eq!(run.summary.ok, 200);
    assert!(run.summary.disk_loads > 0, "a 2-of-40 cache must page from disk");
    assert_eq!(
        run.summary.factor_cache.misses, run.summary.disk_loads,
        "every counted miss is one disk load and vice versa"
    );
    assert!(run.summary.factor_cache.evictions > 0, "the tight budget must evict");
    // the log records each load on the merge-pool thread
    let loads =
        run.events.iter().filter(|e| matches!(e.kind, EventKind::DiskLoad { .. })).count() as u64;
    assert_eq!(loads, run.summary.disk_loads);
}

/// Scripted disk latency is a first-class fault: every tier load parks
/// for the scripted delay on the virtual clock, the whole trace stays
/// byte-reproducible across runs and compute-thread counts, and no
/// request fails.
#[test]
fn disk_latency_fault_is_deterministic_across_runs_and_threads() {
    let env = ScenarioEnv::synth("tierfault", 4).unwrap();
    for strategy in [MergeStrategy::Factor, MergeStrategy::Merged] {
        let spec = |threads: usize| ScenarioSpec {
            compute_threads: threads,
            faults: FaultPlan {
                disk_latency: Some(DiskLatency {
                    adapter: None,
                    delay: Duration::from_millis(3),
                }),
                ..Default::default()
            },
            ..tiered_spec(&env, strategy, 30)
        };
        let a = run_scenario(&spec(1), &env).unwrap();
        assert_eq!(a.summary.ok, 200, "{strategy}: faulted tiered run must still complete");
        // some request really rode out a scripted disk read
        assert!(
            a.summary.latency.max() >= Duration::from_millis(3),
            "{strategy}: scripted disk latency must be visible end to end ({:?})",
            a.summary.latency.max()
        );
        let b = run_scenario(&spec(1), &env).unwrap();
        assert_eq!(a.log(), b.log(), "{strategy}: faulted tiered trace must be reproducible");
        let c = run_scenario(&spec(4), &env).unwrap();
        assert_eq!(a.log(), c.log(), "{strategy}: trace must not depend on compute threads");
        assert_eq!(a.tokens, c.tokens);
    }
}

/// Pool-size invariance carries over to tiered serving: per-request
/// tokens are identical with 1 and 4 workers (routing and per-worker
/// factor caches change, results don't).
#[test]
fn tiered_tokens_identical_across_worker_counts() {
    let env = ScenarioEnv::synth("tierworkers", 4).unwrap();
    for strategy in [MergeStrategy::Merged, MergeStrategy::Factor] {
        let one = run_scenario(&tiered_spec(&env, strategy, 30).with_workers(1), &env).unwrap();
        let four = run_scenario(&tiered_spec(&env, strategy, 30).with_workers(4), &env).unwrap();
        assert_eq!(one.summary.ok, 200);
        assert_eq!(four.summary.ok, 200);
        assert_eq!(
            one.tokens, four.tokens,
            "{strategy}: tiered tokens must not depend on pool size"
        );
    }
}

/// Predictive prefetch rides the trace's own arrival cadence: it may
/// only move loads earlier (warm fills never count misses), must not
/// change tokens, and the predictor-driven trace is itself
/// deterministic.
#[test]
fn predictive_prefetch_keeps_tokens_and_is_deterministic() {
    let env = ScenarioEnv::synth("tierpred", 4).unwrap();
    let base = tiered_spec(&env, MergeStrategy::Factor, 40);
    let predictive = ScenarioSpec { predictive_prefetch: true, ..base.clone() };
    let plain = run_scenario(&base, &env).unwrap();
    let a = run_scenario(&predictive, &env).unwrap();
    assert_eq!(a.summary.ok, 200, "predictive run must complete every request");
    assert_eq!(a.tokens, plain.tokens, "warm-ahead must not change tokens");
    // warm fills load from disk without counting a miss, so loads can
    // only meet or exceed the counted request-path misses
    assert!(
        a.summary.disk_loads >= a.summary.factor_cache.misses,
        "warm fills must never count request-path misses ({} loads < {} misses)",
        a.summary.disk_loads,
        a.summary.factor_cache.misses
    );
    let b = run_scenario(&predictive, &env).unwrap();
    assert_eq!(a.log(), b.log(), "predictor-driven trace must be reproducible");
}

/// Scale: a 1000-tenant Zipf fleet served through a factor cache holding
/// 2 adapters (0.2% of the fleet) completes with zero decode failures
/// and — with no faults — zero added latency: under the virtual clock an
/// unfaulted disk load is instantaneous, so nothing waits longer than
/// the batcher deadline.
#[test]
fn thousand_tenants_through_two_adapter_cache() {
    let env = ScenarioEnv::synth("tierscale", 8).unwrap();
    let spec = ScenarioSpec {
        workload: WorkloadConfig { rate: 800.0, zipf_alpha: 1.1, n_requests: 300, seed: 31 },
        ..tiered_spec(&env, MergeStrategy::Factor, 1000)
    };
    let run = run_scenario(&spec, &env).unwrap();
    assert_eq!(run.summary.failed, 0, "no decode failures at 1000 tenants");
    assert_eq!(run.summary.ok, 300);
    assert_eq!(run.summary.spilled, 1000);
    assert!(run.summary.disk_loads > 0);
    assert!(
        run.summary.latency.max() <= spec.max_wait,
        "unfaulted tiered p100 must stay within the batcher deadline ({:?})",
        run.summary.latency.max()
    );
}
